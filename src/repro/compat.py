"""Pinned-vs-latest jax API shims.

CI pins ``jax==0.4.37`` (the oldest supported leg) while the latest-jax
legs track current releases, and a few collective/mesh APIs moved
between the two:

* ``jax.shard_map`` — top-level alias of
  ``jax.experimental.shard_map.shard_map`` on recent jax; only the
  experimental path exists on 0.4.37 (where replication checking is the
  legacy ``check_rep`` analysis — disabled here to match the manual
  ``pvary`` annotations the new API expects instead).
* ``jax.lax.pvary`` — explicit "this value varies over these axes"
  annotation required by the new varying-manual-axes checker; a no-op
  on jax versions without the checker.
* ``jax.lax.axis_size`` — collective axis size inside manual regions;
  the 0.4.37 equivalent is the classic ``psum(1, axis)``.
* ``jax.sharding.AxisType`` / ``jax.make_mesh(axis_types=...)`` — the
  explicit-sharding mesh axis types; 0.4.37 meshes are implicitly Auto,
  so the kwarg is simply dropped there.

Everything importing these symbols goes through this module so the
version split lives in exactly one place.
"""

from __future__ import annotations

import jax

__all__ = ["axis_size", "make_mesh", "pvary", "shard_map"]


def make_mesh(axis_shapes, axis_names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports
    them (they are the implicit default on older jax)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
    return jax.make_mesh(
        tuple(axis_shapes), tuple(axis_names),
        axis_types=(axis_type.Auto,) * len(tuple(axis_names)))


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, *, mesh, in_specs, out_specs):
        # check_rep=False: the legacy replication analysis predates
        # pvary and rejects the manual-psum patterns the new checker
        # (given pvary annotations) accepts.
        return _shard_map_legacy(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)


if hasattr(jax.lax, "pvary"):
    pvary = jax.lax.pvary
else:
    def pvary(x, axis_name):
        return x


if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:
    def axis_size(axis_name):
        return jax.lax.psum(1, axis_name)
