from repro.data.pipeline import (DataConfig, PrefetchIterator, host_slice,
                                 image_batch, token_batch)
