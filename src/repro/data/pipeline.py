"""Synthetic data pipeline: deterministic, host-sharded, prefetched.

At 1000+-node scale every host generates only its own shard of the global
batch (``host_slice``), keyed by (seed, step, host) so restarts resume the
exact stream with no coordination. A background thread keeps ``prefetch``
batches ahead of the training loop.

Token streams are Zipf-distributed over the vocab (more realistic gradient
sparsity for embedding/MoE paths than uniform); image batches for the
DCN nets are mixtures of Gabor-ish blobs so deformable offsets see real
spatial structure.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 32000
    seq: int = 128
    global_batch: int = 8
    n_codebooks: int = 1
    n_hosts: int = 1
    host_id: int = 0
    zipf_a: float = 1.2
    cross_tokens: int = 0
    cross_dim: int = 0


def host_slice(cfg: DataConfig) -> tuple[int, int]:
    per = cfg.global_batch // cfg.n_hosts
    return cfg.host_id * per, per


def token_batch(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """Deterministic batch for (seed, step, host)."""
    start, per = host_slice(cfg)
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
    shape = ((per, cfg.seq + 1, cfg.n_codebooks) if cfg.n_codebooks > 1
             else (per, cfg.seq + 1))
    z = rng.zipf(cfg.zipf_a, size=shape)
    tokens = np.minimum(z - 1, cfg.vocab - 1).astype(np.int32)
    out = {"tokens": tokens}
    if cfg.cross_tokens:
        out["cross_states"] = rng.standard_normal(
            (per, cfg.cross_tokens, cfg.cross_dim)).astype(np.float32)
    return out


def image_batch(cfg: DataConfig, step: int, img: int = 32,
                channels: int = 3, classes: int = 10):
    start, per = host_slice(cfg)
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id, 7]))
    yy, xx = np.mgrid[0:img, 0:img].astype(np.float32) / img
    x = np.zeros((per, img, img, channels), np.float32)
    labels = rng.integers(0, classes, size=(per,))
    for i in range(per):
        for _ in range(3):  # blob mixture; label modulates frequency
            cy, cx = rng.uniform(0.2, 0.8, 2)
            f = 2.0 + labels[i] + rng.uniform(0, 2)
            blob = np.exp(-((yy - cy) ** 2 + (xx - cx) ** 2) * 24.0)
            wave = np.sin(2 * np.pi * f * (xx * rng.uniform(-1, 1)
                                           + yy * rng.uniform(-1, 1)))
            x[i] += (blob * wave)[..., None] * rng.standard_normal(channels)
    # label-dependent radial pattern: a learnable but non-trivial signal
    for i in range(per):
        r = np.sqrt((yy - 0.5) ** 2 + (xx - 0.5) ** 2)
        x[i, :, :, 0] += 0.8 * np.cos(2 * np.pi * (labels[i] + 1) * r)
    x += 0.05 * rng.standard_normal(x.shape).astype(np.float32)
    return {"images": x, "labels": labels.astype(np.int32)}


class PrefetchIterator:
    """Background-thread prefetch over a ``step -> batch`` function."""

    def __init__(self, fn, start_step: int = 0, prefetch: int = 2):
        self._fn = fn
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._fn(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
