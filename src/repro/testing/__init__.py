from repro.testing.faults import (ALL_FAULT_KINDS, FaultError, FaultInjector,
                                  FaultPlan)

__all__ = ["ALL_FAULT_KINDS", "FaultError", "FaultInjector", "FaultPlan"]
