"""Deterministic, seedable fault injection for the DCN serving stack.

The resilience layer (ISSUE 8) needs faults that are *repeatable* — a
chaos bench gate or a regression test is useless if the failure pattern
shifts run to run — so every injection decision here is a pure function
of ``(seed, kind, call-or-step index)`` via a sha256 draw, never of wall
time or a shared global RNG. The injector is threaded through
``PipelineConfig.faults`` / ``GraphConfig.faults`` and consulted by the
executors at four sites, plus one bench-side corruption helper:

======================  ====================================================
kind                    where it fires
======================  ====================================================
``prepass``             per-image schedule build (TDT + Algorithm-1) in
                        both executors — raises :class:`FaultError`
                        tagged with the image index.
``dispatch``            kernel-dispatch entry of the batched /
                        batch-fused exec paths — raises
                        :class:`FaultError` (image picked
                        deterministically when only the batch width is
                        known).
``worker_stall``        start of a staged prepass in ``run_staged`` —
                        sleeps ``stall_s`` on the staging worker, which
                        a ``watchdog_s`` deadline converts into a
                        failover to synchronous prepass.
``cache_miss``          schedule-cache key construction — salts the key
                        with a unique token, forcing a rebuild (a
                        miss *storm* at rate 1.0).
``nan_image``           not an executor site: :meth:`FaultInjector.corrupt`
                        NaN-poisons an input image *before* submit, so
                        the engine's finite-input validation is what
                        gets exercised.
======================  ====================================================

Two firing modes (``FaultPlan.mode``):

* ``"call"`` (default) — every site consultation draws independently at
  ``rate``. With ``rate=1.0`` (+ ``max_fires``) this gives tests exact
  control: "the first dispatch faults, nothing else does".
* ``"step"`` — the serving engine calls :meth:`begin_step` before each
  step; each kind *arms* for that step with probability ``rate`` and
  fires on one deterministically-picked consultation. This keeps the
  chaos bench's faulted-step fraction ~``1-(1-rate)^kinds`` instead of
  compounding per consultation (a 5-layer prepass would otherwise fault
  almost every step at rate 0.1). ``nan_image`` decisions happen outside
  steps and always draw per call.

The runtime never imports this module — executors duck-type
``cfg.faults`` (``check`` / ``stall`` / ``miss_salt`` are the whole
protocol), so production configs carry ``faults=None`` and pay one
``is not None`` test per site.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time

import numpy as np

ALL_FAULT_KINDS = ("prepass", "dispatch", "worker_stall", "cache_miss",
                   "nan_image")


class FaultError(RuntimeError):
    """An injected fault. ``image`` (when tagged) is the index of the
    offending image *within the faulting batch*, which is what the
    serving engine's evict-and-retry isolation consumes."""

    def __init__(self, kind: str, image: int | None = None):
        self.kind = kind
        self.image = image
        at = f" (image {image})" if image is not None else ""
        super().__init__(f"injected {kind} fault{at}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """The (immutable, hashable) description of an injection campaign."""

    kinds: tuple[str, ...] = ALL_FAULT_KINDS
    rate: float = 0.1            # firing probability per call / per step
    seed: int = 0
    stall_s: float = 0.25        # worker_stall sleep (keep finite: the
    #                              abandoned worker thread must exit)
    tag_image: bool = True       # attach the image index to FaultError —
    #                              False exercises the degrade path (the
    #                              engine cannot evict an unknown slot)
    max_fires: int | None = None  # total fires across all kinds
    mode: str = "call"           # "call" | "step" (see module docstring)

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")
        if self.mode not in ("call", "step"):
            raise ValueError(f"unknown fault mode: {self.mode!r}")
        unknown = set(self.kinds) - set(ALL_FAULT_KINDS)
        if unknown:
            raise ValueError(f"unknown fault kinds: {sorted(unknown)}")


class FaultInjector:
    """Thread-safe deterministic injector over a :class:`FaultPlan`.

    Construct either from a plan or directly from plan kwargs::

        FaultInjector(kinds=("dispatch",), rate=1.0, max_fires=1)

    ``fired`` (per-kind fire counts) is the test/bench observability
    surface.
    """

    def __init__(self, plan: FaultPlan | None = None, **kw):
        if plan is not None and kw:
            raise ValueError("pass a FaultPlan or kwargs, not both")
        self.plan = plan if plan is not None else FaultPlan(**kw)
        self._lock = threading.RLock()
        self.fired: dict[str, int] = {k: 0 for k in self.plan.kinds}
        self._calls: dict[str, int] = {}        # per-call mode counters
        self._step: int | None = None           # step-mode: current step
        self._armed: dict[str, int] = {}        # kind -> firing call idx
        self._step_calls: dict[str, int] = {}
        self._prev_calls: dict[str, int] = {}
        self._total_fired = 0

    # -- deterministic draws ------------------------------------------------

    def _hash01(self, *parts) -> float:
        h = hashlib.sha256(
            repr((self.plan.seed,) + parts).encode()).digest()
        return int.from_bytes(h[:8], "big") / 2.0**64

    def begin_step(self) -> None:
        """Step-scoped arming (serving engine hook). No-op in per-call
        mode so tests driving the engine keep exact per-call control."""
        if self.plan.mode != "step":
            return
        with self._lock:
            self._step = 0 if self._step is None else self._step + 1
            self._prev_calls = dict(self._step_calls)
            self._step_calls = {}
            self._armed = {}
            for k in self.plan.kinds:
                if k == "nan_image":
                    continue
                if self._hash01("arm", k, self._step) < self.plan.rate:
                    # Fire on one consultation of this kind; the previous
                    # step's call count stands in for this step's (the
                    # site count per step is stable in steady state).
                    span = max(1, self._prev_calls.get(k, 1))
                    self._armed[k] = int(
                        self._hash01("at", k, self._step) * span)

    def _fire(self, kind: str) -> bool:
        with self._lock:
            if kind not in self.plan.kinds:
                return False
            if (self.plan.max_fires is not None
                    and self._total_fired >= self.plan.max_fires):
                return False
            per_call = (self.plan.mode == "call" or kind == "nan_image"
                        or self._step is None)
            if per_call:
                n = self._calls.get(kind, 0)
                self._calls[kind] = n + 1
                fire = self._hash01("call", kind, n) < self.plan.rate
            else:
                n = self._step_calls.get(kind, 0)
                self._step_calls[kind] = n + 1
                fire = self._armed.get(kind) == n
                if fire:
                    del self._armed[kind]
            if fire:
                self.fired[kind] = self.fired.get(kind, 0) + 1
                self._total_fired += 1
            return fire

    # -- executor sites -----------------------------------------------------

    def check(self, kind: str, image: int | None = None,
              images: int | None = None) -> None:
        """Raise :class:`FaultError` if this consultation fires.

        ``image`` names the offending image when the site knows it
        (per-image prepass); ``images`` gives the batch width when it
        does not (whole-batch dispatch) and the injector picks one
        deterministically. ``tag_image=False`` strips the index either
        way."""
        if not self._fire(kind):
            return
        img = image
        if img is None and images:
            img = int(self._hash01("img", kind, self._total_fired)
                      * images)
        if not self.plan.tag_image:
            img = None
        raise FaultError(kind, image=img)

    def stall(self, kind: str = "worker_stall") -> None:
        """Sleep ``stall_s`` if firing — a slow/stuck staging worker."""
        if self._fire(kind):
            time.sleep(self.plan.stall_s)

    def miss_salt(self, kind: str = "cache_miss"):
        """A unique cache-key salt when firing (forces a miss), else
        None. Each fire salts differently so a storm never self-heals
        by colliding with its own junk entries."""
        if self._fire(kind):
            with self._lock:
                return ("fault-miss", self._total_fired)
        return None

    # -- bench-side helper --------------------------------------------------

    def corrupt(self, x: np.ndarray, kind: str = "nan_image") -> np.ndarray:
        """NaN-poison one deterministic pixel of a copy of ``x`` when
        firing, else return ``x`` unchanged. Used *before* submit — the
        engine's finite-input validation is the isolation under test."""
        if not self._fire(kind):
            return x
        x = np.array(x, copy=True)
        flat = x.reshape(-1)
        flat[int(self._hash01("pix", kind, self._total_fired)
                 * flat.size)] = np.nan
        return x

    @property
    def total_fired(self) -> int:
        with self._lock:
            return self._total_fired
