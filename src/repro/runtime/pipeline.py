"""The tile-pipeline executor: TDT -> schedule -> pack -> fused kernel.

``dcn_pipeline`` runs a full deformable convolution over a real
``(N, H, W, C)`` batch the way the paper's accelerator does (§IV-C/D):
the stage-1 offset conv runs dense (XLA), the resulting sampling
coordinates drive a per-image tile dependency table and Algorithm-1
schedule (host side, as the paper's scheduler is a dedicated hardware
block running ahead of the PE array), and each schedule entry dispatches
the fused BLI(+)conv Pallas kernel over a packed buffer holding exactly
the output tile's dependent input tiles.

Scheduling is data-dependent (it inspects the offsets), so the executor
is a host-driven loop rather than one jitted graph — the same structural
split as the hardware, where pre-scheduling runs concurrently with
execution. Gradients do not flow through this path; training uses the
XLA ``fused_deformable_conv2d`` (checkpoint) formulation.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deform import DeformableConvParams, conv2d, offsets_to_coords
from repro.core.scheduler import schedule_tiles, sequential_schedule
from repro.core.tiles import TileGrid, tdt_from_coords
from repro.kernels.dcn_fused import dcn_fused_tile
from repro.kernels.ops import round_up
from repro.runtime.cache import coords_digest, default_schedule_cache
from repro.runtime.packing import (build_neighbour_tables, pack_output_tile,
                                   plane_to_tiles, tiles_to_plane)
from repro.runtime.trace import ImageTrace, PipelineTrace, TileRecord


def resolve_interpret(flag: bool | None) -> bool:
    """None = auto-detect: Pallas interpret mode only off-accelerator, so
    GPU/TPU runs compile the kernels without a config change."""
    if flag is None:
        return jax.default_backend() == "cpu"
    return bool(flag)


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Executor knobs (everything except the layer's own parameters)."""

    tile: int | tuple[int, int] = 8      # output/input tile side(s)
    buffer_tiles: int | None = None      # M for Algorithm 1; None = all
    schedule: str = "alg1"               # "alg1" | "sequential"
    block_p: int = 128                   # kernel pixel-block size
    interpret: bool | None = None        # Pallas interpret; None = auto
    use_schedule_cache: bool = True      # LRU-cache TDT+Algorithm-1 builds

    @property
    def tile_hw(self) -> tuple[int, int]:
        t = self.tile
        th, tw = (t, t) if isinstance(t, int) else (int(t[0]), int(t[1]))
        if th < 1 or tw < 1:
            raise ValueError(f"tile sides must be >= 1, got {(th, tw)}")
        return th, tw


def _pipeline_single(
    x_i: jax.Array,           # (H, W, C_in)
    coords_i: jax.Array,      # (H, W, KK, 2)
    w2: jax.Array,            # (KK, C_in, C_out)
    b: jax.Array,             # (C_out,)
    kernel_size: int,
    cfg: PipelineConfig,
) -> tuple[jax.Array, ImageTrace]:
    h, w, c = x_i.shape
    th, tw = cfg.tile_hw
    grid = TileGrid(h, w, min(th, h), min(tw, w))
    tp = grid.th * grid.tw
    m = grid.num_tiles if cfg.buffer_tiles is None else cfg.buffer_tiles

    def build_schedule():
        B = np.asarray(tdt_from_coords(coords_i, grid, grid))
        if cfg.schedule == "alg1":
            return schedule_tiles(B, m)
        if cfg.schedule == "sequential":
            return sequential_schedule(B)
        raise ValueError(f"unknown schedule: {cfg.schedule!r}")

    if cfg.use_schedule_cache:
        key = (coords_digest(coords_i, grid), m, cfg.schedule)
        sched, cache_hit = default_schedule_cache().get_or_build(
            key, build_schedule)
    else:
        sched, cache_hit = build_schedule(), None

    x_tiles = plane_to_tiles(x_i, grid)               # (T, tp, C)
    nb = build_neighbour_tables(coords_i, grid)

    # Uniform packed-buffer size across the image's dispatches (single
    # kernel compilation): dependent-tile count padded to a power of two.
    k_max = max(len(d) for d in sched.iid)
    k_pad = 1 << (k_max - 1).bit_length()
    bp = min(cfg.block_p, tp)
    p_pad = tp if tp % bp == 0 else round_up(tp, cfg.block_p)

    tile_bytes = tp * c * x_i.dtype.itemsize
    trace = ImageTrace(grid=grid, tile_bytes=tile_bytes, buffer_tiles=m,
                       schedule=cfg.schedule, schedule_cache_hit=cache_hit)

    c_out = w2.shape[-1]
    y_tiles = [None] * grid.num_tiles
    for out_tile, deps in zip(sched.oid, sched.iid):
        idx, coeff = pack_output_tile(nb, grid, out_tile, deps, p_pad)
        x_packed = x_tiles[jnp.asarray(deps, jnp.int32)]  # (k, tp, C)
        if len(deps) < k_pad:
            x_packed = jnp.pad(
                x_packed, ((0, k_pad - len(deps)), (0, 0), (0, 0)))
        y_t = dcn_fused_tile(
            x_packed.reshape(k_pad * tp, c),
            jnp.asarray(idx), jnp.asarray(coeff), w2, b,
            kernel_size=kernel_size, block_p=cfg.block_p,
            interpret=resolve_interpret(cfg.interpret))
        y_tiles[out_tile] = y_t[:tp]
        trace.records.append(TileRecord(
            out_tile=out_tile,
            dep_tiles=tuple(deps),
            loaded_bytes=len(deps) * tile_bytes,
            buffer_bytes=k_pad * tp * c * x_i.dtype.itemsize))

    zero = jnp.zeros((tp, c_out), x_i.dtype)
    y = tiles_to_plane(jnp.stack([t if t is not None else zero
                                  for t in y_tiles]), grid, h, w)
    return y, trace


def dcn_pipeline(
    x: jax.Array,
    params: DeformableConvParams,
    *,
    kernel_size: int = 3,
    variant: str = "dcn2",
    max_displacement: float | None = None,
    tile: int | tuple[int, int] = 8,
    buffer_tiles: int | None = None,
    schedule: str = "alg1",
    block_p: int = 128,
    interpret: bool | None = None,
    return_trace: bool = False,
    config: PipelineConfig | None = None,
):
    """Scheduler-driven deformable conv over a batch: (N,H,W,C) -> (N,H,W,O).

    Per batch element: stage-1 offsets -> coords -> TDT -> Algorithm-1
    schedule -> packed-tile fused-kernel dispatches -> scatter. Numerically
    matches ``core.deform.deformable_conv2d`` (the XLA reference) to float
    tolerance; additionally returns a :class:`PipelineTrace` of the actual
    packed-tile traffic when ``return_trace`` is set.

    ``config`` overrides the individual executor keywords when given.
    """
    if isinstance(x, jax.core.Tracer):
        raise ValueError(
            "dcn_pipeline is a host-driven, forward-only executor: the "
            "Algorithm-1 schedule is data-dependent, so it cannot run "
            "under jit/grad/vmap. Trace with backend='xla' "
            "(fused_deformable_conv2d) for differentiable/jitted paths.")
    cfg = config or PipelineConfig(tile=tile, buffer_tiles=buffer_tiles,
                                   schedule=schedule, block_p=block_p,
                                   interpret=interpret)
    n = x.shape[0]
    kk = kernel_size * kernel_size
    c_out = params.w.shape[-1]

    offsets = conv2d(x, params.w_off, params.b_off)               # Eq. 1
    coords = offsets_to_coords(offsets.astype(jnp.float32),
                               kernel_size, variant, max_displacement)
    w2 = params.w.reshape(kk, x.shape[-1], c_out)

    trace = PipelineTrace()
    if n == 0:
        y = jnp.zeros(x.shape[:3] + (c_out,), x.dtype)
        return (y, trace) if return_trace else y
    outs = []
    for i in range(n):
        y_i, tr = _pipeline_single(x[i], coords[i], w2, params.b,
                                   kernel_size, cfg)
        outs.append(y_i)
        trace.images.append(tr)
    y = jnp.stack(outs)
    return (y, trace) if return_trace else y
