"""The tile-pipeline executor: TDT -> schedule -> pack -> fused kernel.

``dcn_pipeline`` runs a full deformable convolution over a real
``(N, H, W, C)`` batch the way the paper's accelerator does (§IV-C/D):
the stage-1 offset conv runs dense (XLA), the resulting sampling
coordinates drive a per-image tile dependency table and Algorithm-1
schedule (host side, as the paper's scheduler is a dedicated hardware
block running ahead of the PE array), and the schedule executes through
the fused BLI(+)conv Pallas kernel.

Two dispatch modes (``PipelineConfig.dispatch``):

  * ``"batched"`` (default) — the whole schedule is ONE ``pallas_call``:
    the scheduled-tile index is the leading grid dimension and the
    scalar-prefetched dep table drives the input-tile DMA order
    (``kernels.dcn_fused.dcn_fused_schedule``); outputs scatter back in
    one op. One kernel dispatch per image.
  * ``"per_tile"`` — the PR 1 loop: one packed-buffer kernel dispatch per
    schedule entry.

Scheduling is data-dependent (it inspects the offsets), so the executor
is a host-driven loop rather than one jitted graph — the same structural
split as the hardware, where pre-scheduling runs concurrently with
execution. With ``staging_depth > 1`` the prepass (TDT + schedule +
packing) of image i+1 runs on a worker thread under image i's device
execution. Gradients do not flow through this path; training uses the
XLA ``fused_deformable_conv2d`` (checkpoint) formulation.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutTimeout
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deform import DeformableConvParams, conv2d, offsets_to_coords
from repro.core.scheduler import (DeviceSchedule, TileSchedule, pow2_pad,
                                  schedule_arrays_device, schedule_tiles,
                                  sequential_schedule)
from repro.core.tiles import TileGrid, tdt_from_coords
from repro.kernels.dcn_fused import (dcn_fused_batch,
                                     dcn_fused_batch_sharded,
                                     dcn_fused_schedule, dcn_fused_tile)
from repro.kernels.dcn_schedule import tdt_from_coords_device
from repro.kernels.ops import round_up
from repro.obs import Tracer, default_registry, get_tracer, use_tracer
from repro.runtime.cache import coords_digest, default_schedule_cache
from repro.runtime.packing import (NeighbourTables, build_neighbour_tables,
                                   pack_batch_schedules, pack_output_tile,
                                   pack_plane_operands, pack_schedule_tiles,
                                   plane_to_tiles, tiles_to_plane)
from repro.runtime.shard import (ShardPlan, allgather_nbytes,
                                 plan_batch_shards, resolve_shard_mesh,
                                 shard_batch_schedules, stack_rows,
                                 unstack_rows)
from repro.runtime.trace import ImageTrace, PipelineTrace, TileRecord


def resolve_interpret(flag: bool | None) -> bool:
    """None = auto-detect: Pallas interpret mode only off-accelerator, so
    GPU/TPU runs compile the kernels without a config change."""
    if flag is None:
        return jax.default_backend() == "cpu"
    return bool(flag)


# Process-wide like core.scheduler.host_schedule_builds: callers that
# need a per-engine view keep a construction-time baseline and report
# their delta.
staging_watchdog_failovers = default_registry().counter(
    "staging.watchdog_failovers",
    help="staged prepasses that missed the watchdog deadline and were "
         "re-run synchronously on the driving thread")


def run_staged(n: int, prepass, execute, depth: int, overlap,
               tracer: Tracer | None = None,
               watchdog_s: float | None = None, faults=None) -> list:
    """The multi-image staging queue shared by both executors.

    ``prepass(i)`` builds image i's host-side artifacts, ``execute(i,
    art)`` dispatches its kernels. With ``depth > 1`` up to ``depth - 1``
    prepasses run ahead on a single worker thread while the main thread
    executes (jax dispatch is itself async, so the device stays busy
    under the host-side schedule build); ``overlap`` (an
    :class:`~repro.runtime.trace.OverlapSpans`) is re-derived from the
    ``prepass`` / ``prepass.wait`` spans this queue records through
    ``tracer`` (always measured; stored only when the tracer is
    enabled). Returns the per-image execute results.

    ``watchdog_s`` bounds each wait on the staging worker: a prepass
    that does not deliver within the deadline is treated as wedged — the
    queue fails over to synchronous prepass for the rest of the run
    (``staging.watchdog_failover`` instant marker + process counter),
    the stuck worker is abandoned (never joined), and batch-fused
    callers' sequential prepass state stays consistent because their
    epoch-guarded commit discards any late duplicate (see
    ``_run_graph_batch_fused``). ``faults`` is a test-only injector
    (``repro.testing.faults``) consulted for ``worker_stall`` sleeps.
    """
    tr = tracer if tracer is not None else get_tracer()

    def staged(i: int):
        if faults is not None:
            faults.stall("worker_stall")
        with tr.timed("prepass", unit=i) as sp:
            art = prepass(i)
        return art, sp

    outs = []
    if depth == 1 or n == 1:
        for i in range(n):
            # Serial mode: the execute loop blocks on the whole prepass,
            # so the wait span wraps it (host_overlap_frac == 0).
            with tr.timed("prepass.wait", unit=i) as wsp:
                art, sp = staged(i)
            overlap.add_span(sp)
            overlap.add_span(wsp)
            outs.append(execute(i, art))
        return outs
    pool = ThreadPoolExecutor(max_workers=1)
    failed_over = False
    try:
        futs: deque = deque()
        nxt = 0
        while nxt < n and len(futs) < depth - 1:
            futs.append(pool.submit(staged, nxt))
            nxt += 1
        for i in range(n):
            with tr.timed("prepass.wait", unit=i) as wsp:
                if failed_over or not futs:
                    art, sp = staged(i)
                else:
                    try:
                        art, sp = futs.popleft().result(
                            timeout=watchdog_s)
                    except _FutTimeout:
                        failed_over = True
                        staging_watchdog_failovers.bump()
                        tr.instant("staging.watchdog_failover", unit=i)
                        art, sp = staged(i)
            overlap.add_span(sp)
            overlap.add_span(wsp)
            if not failed_over and nxt < n:
                futs.append(pool.submit(staged, nxt))
                nxt += 1
            outs.append(execute(i, art))
    finally:
        # A wedged worker would hang the context-manager shutdown; after
        # a failover, abandon it (queued-but-unstarted work is
        # cancelled, the running thread exits on its own — injected
        # stalls are finite by contract).
        pool.shutdown(wait=not failed_over, cancel_futures=failed_over)
    return outs


def validate_dispatch_config(cfg) -> None:
    """Shared ``__post_init__`` checks of the executor configs: tile
    sides, dispatch mode, schedule backend and staging depth."""
    cfg.tile_hw                          # validates tile sides
    if cfg.dispatch not in ("batched", "per_tile", "batch_fused"):
        raise ValueError(f"unknown dispatch mode: {cfg.dispatch!r}")
    if cfg.schedule_backend not in ("host", "device"):
        raise ValueError(
            f"unknown schedule backend: {cfg.schedule_backend!r}")
    if cfg.staging_depth < 1:
        raise ValueError(
            f"staging_depth must be >= 1, got {cfg.staging_depth}")
    if cfg.watchdog_s is not None and cfg.watchdog_s <= 0:
        raise ValueError(
            f"watchdog_s must be > 0 (or None), got {cfg.watchdog_s}")
    dp = cfg.data_parallel
    if dp is not None and dp < 1:
        raise ValueError(f"data_parallel must be >= 1, got {dp}")
    if ((cfg.mesh is not None or (dp or 1) > 1)
            and cfg.dispatch != "batch_fused"):
        raise ValueError(
            "mesh=/data_parallel= sharding only applies to "
            f"dispatch='batch_fused', got dispatch={cfg.dispatch!r}")
    if cfg.autotune not in ("off", "offline", "cached-only"):
        raise ValueError(
            f"autotune must be 'off', 'offline' or 'cached-only', "
            f"got {cfg.autotune!r}")
    if cfg.autotune_budget < 1:
        raise ValueError(
            f"autotune_budget must be >= 1, got {cfg.autotune_budget}")


def clamp_tile_config(cfg, h: int, w: int):
    """Clamp a config's tile to an (h, w) input plane — the model and
    serving entry points accept any image size, while the raw executors
    reject tile > plane (a silent 1-tile grid otherwise). Works for both
    ``PipelineConfig`` and ``GraphConfig``."""
    th, tw = cfg.tile_hw
    if th <= h and tw <= w:
        return cfg
    return dataclasses.replace(cfg, tile=(min(th, h), min(tw, w)))


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Executor knobs (everything except the layer's own parameters)."""

    tile: int | tuple[int, int] = 8      # output/input tile side(s)
    buffer_tiles: int | None = None      # M for Algorithm 1; None = all
    schedule: str = "alg1"               # "alg1" | "sequential"
    block_p: int = 128                   # kernel pixel-block size
    interpret: bool | None = None        # Pallas interpret; None = auto
    use_schedule_cache: bool = True      # LRU-cache TDT+Algorithm-1 builds
    # "batched": the whole schedule as one pallas_call grid (per image).
    # "batch_fused": the concatenated schedules of ALL batch images as
    #   one pallas_call grid — one dispatch per layer segment per BATCH,
    #   and with schedule_backend="device" the schedule arrays feed the
    #   dispatch directly (no host TileSchedule on the hot path).
    # "per_tile": one kernel dispatch per schedule entry (PR 1).
    dispatch: str = "batched"
    # "host": TDT scatter + Algorithm-1 loop in host numpy/Python.
    # "device": both run as Pallas kernels (kernels.dcn_schedule) — the
    # paper's on-chip scheduler block; bit-exact vs the host path, and
    # the staging thread shrinks to packing only.
    schedule_backend: str = "host"
    # Images staged ahead: 1 = serial, 2 (default) = prepass image i+1 on
    # a worker thread while image i executes.
    staging_depth: int = 2
    # Staging-worker watchdog: None = wait forever (pre-resilience
    # behavior); a float bounds each wait on a staged prepass, after
    # which the run fails over to synchronous prepass.
    watchdog_s: float | None = None
    # Batch-dimension scale-out (batch_fused only): an explicit
    # jax.sharding.Mesh with a "data" axis, or data_parallel=D as the
    # convenience spelling (builds a (D, 1) host mesh at run time, so
    # device availability is checked at run, not config construction).
    # Each mesh device runs the concatenated schedules of its local
    # images; the only collective is the all-gather at the logits.
    mesh: Any = None
    data_parallel: int | None = None
    # Simulator-guided tile autotuning (repro.tuning): "off" = use the
    # configured tile; "offline" = search once per layer geometry for
    # the (tile_h, tile_w) with the least simulated DRAM traffic and
    # cache the winner; "cached-only" = use a cached winner, never
    # search. plan_cache_dir persists winners across processes.
    autotune: str = "off"
    plan_cache_dir: str | None = None
    autotune_budget: int = 128
    # Fault injector (repro.testing.faults.FaultInjector) — test/bench
    # only, excluded from config equality: two configs with the same
    # executor knobs are the same config.
    faults: Any = dataclasses.field(default=None, compare=False)

    def __post_init__(self):
        validate_dispatch_config(self)

    @property
    def tile_hw(self) -> tuple[int, int]:
        t = self.tile
        th, tw = (t, t) if isinstance(t, int) else (int(t[0]), int(t[1]))
        if th < 1 or tw < 1:
            raise ValueError(f"tile sides must be >= 1, got {(th, tw)}")
        return th, tw


@dataclasses.dataclass
class _ImageArtifacts:
    """Prepass products of one image: schedule + packed kernel operands."""

    sched: TileSchedule
    cache_hit: bool | None
    nb: NeighbourTables
    k_pad: int
    # TDT + schedule build wall time inside the prepass, and the portion
    # that ran through the device scheduling backend.
    schedule_s: float = 0.0
    schedule_device_s: float = 0.0
    # batched dispatch only: stacked kernel operands for the whole schedule
    dep_tbl: np.ndarray | None = None
    dep_cnt: np.ndarray | None = None
    idx: np.ndarray | None = None
    coeff: np.ndarray | None = None


def _pipeline_prepass(
    coords_i: jax.Array,      # (H, W, KK, 2)
    grid: TileGrid,
    m: int,
    p_pad: int,
    cfg: PipelineConfig,
    interp: bool,
    tracer: Tracer | None = None,
) -> _ImageArtifacts:
    """Host-side prepass of one image: TDT -> schedule (cached) ->
    neighbour tables -> (batched) group-level packed operands. With
    ``schedule_backend="device"`` the TDT scatter and the Algorithm-1
    selection run as Pallas kernels and the host only reassembles."""
    tr = tracer if tracer is not None else get_tracer()

    def build_schedule():
        with tr.span("prepass.tdt", backend=cfg.schedule_backend):
            if cfg.schedule_backend == "device":
                B = tdt_from_coords_device(coords_i, grid, grid,
                                           interpret=interp)
            else:
                B = tdt_from_coords(coords_i, grid, grid)
        if cfg.schedule == "alg1":
            return schedule_tiles(B, m, backend=cfg.schedule_backend,
                                  interpret=interp)
        if cfg.schedule == "sequential":
            return sequential_schedule(np.asarray(B))
        raise ValueError(f"unknown schedule: {cfg.schedule!r}")

    with tr.timed("prepass.schedule",
                  backend=cfg.schedule_backend) as ssp:
        if cfg.use_schedule_cache:
            # Tile dims are hashed inside coords_digest via the grid, but
            # stay an explicit key component too: two configs sharing
            # coords must never collide across (tile_h, tile_w).
            key = (coords_digest(coords_i, grid), grid.th, grid.tw, m,
                   cfg.schedule)
            if cfg.faults is not None:
                salt = cfg.faults.miss_salt()
                if salt is not None:
                    key = key + (salt,)
            sched, cache_hit = default_schedule_cache().get_or_build(
                key, build_schedule)
        else:
            sched, cache_hit = build_schedule(), None
        ssp.set(cached=cache_hit)
    schedule_s = ssp.dur

    with tr.span("pack", dispatch=cfg.dispatch):
        nb = build_neighbour_tables(coords_i, grid)
        # Uniform packed-buffer size across the image's dispatches (one
        # kernel compilation): dep-tile count padded to a power of two.
        oid, deps, counts = sched.dense()
        k_pad = deps.shape[1]
        art = _ImageArtifacts(
            sched=sched, cache_hit=cache_hit, nb=nb, k_pad=k_pad,
            schedule_s=schedule_s,
            schedule_device_s=(schedule_s
                               if cfg.schedule_backend == "device"
                               else 0.0))
        if cfg.dispatch == "batched":
            dep_lists = [d[:c] for d, c in zip(deps, counts)]
            (art.dep_tbl, art.dep_cnt, art.idx,
             art.coeff) = pack_schedule_tiles(
                nb, grid, oid, dep_lists, p_pad, k_pad)
    return art


def _pipeline_exec(
    x_i: jax.Array,           # (H, W, C_in)
    art: _ImageArtifacts,
    w2: jax.Array,            # (KK, C_in, C_out)
    b: jax.Array,             # (C_out,)
    kernel_size: int,
    cfg: PipelineConfig,
    grid: TileGrid,
    m: int,
    p_pad: int,
    interpret: bool,
) -> tuple[jax.Array, ImageTrace]:
    h, w, c = x_i.shape
    tp = grid.th * grid.tw
    sched, nb, k_pad = art.sched, art.nb, art.k_pad
    c_out = w2.shape[-1]

    tile_bytes = tp * c * x_i.dtype.itemsize
    trace = ImageTrace(grid=grid, tile_bytes=tile_bytes, buffer_tiles=m,
                       schedule=cfg.schedule,
                       schedule_cache_hit=art.cache_hit,
                       dispatch=cfg.dispatch,
                       schedule_backend=cfg.schedule_backend)

    x_tiles = plane_to_tiles(x_i, grid)               # (T, tp, C)
    buffer_bytes = k_pad * tp * c * x_i.dtype.itemsize

    if cfg.dispatch == "batched":
        y_sched = dcn_fused_schedule(
            x_tiles, jnp.asarray(art.dep_tbl), jnp.asarray(art.dep_cnt),
            jnp.asarray(art.idx), jnp.asarray(art.coeff), w2, b,
            kernel_size=kernel_size, block_p=cfg.block_p,
            interpret=interpret)[:, :tp]
        oid = np.asarray(sched.oid, np.int32)
        y_tiles = jnp.zeros((grid.num_tiles, tp, c_out), x_i.dtype)
        y_tiles = y_tiles.at[jnp.asarray(oid)].set(y_sched)
        trace.kernel_dispatches = 1
    else:
        tiles: list = [None] * grid.num_tiles
        for out_tile, deps in zip(sched.oid, sched.iid):
            idx, coeff = pack_output_tile(nb, grid, out_tile, deps, p_pad)
            x_packed = x_tiles[jnp.asarray(deps, jnp.int32)]  # (k, tp, C)
            if len(deps) < k_pad:
                x_packed = jnp.pad(
                    x_packed, ((0, k_pad - len(deps)), (0, 0), (0, 0)))
            y_t = dcn_fused_tile(
                x_packed.reshape(k_pad * tp, c),
                jnp.asarray(idx), jnp.asarray(coeff), w2, b,
                kernel_size=kernel_size, block_p=cfg.block_p,
                interpret=interpret)
            tiles[out_tile] = y_t[:tp]
            trace.kernel_dispatches += 1
        zero = jnp.zeros((tp, c_out), x_i.dtype)
        y_tiles = jnp.stack([t if t is not None else zero for t in tiles])

    for out_tile, deps in zip(sched.oid, sched.iid):
        trace.records.append(TileRecord(
            out_tile=out_tile,
            dep_tiles=tuple(deps),
            loaded_bytes=len(deps) * tile_bytes,
            buffer_bytes=buffer_bytes))

    y = tiles_to_plane(y_tiles, grid, h, w)
    return y, trace


# ---------------------------------------------------------------------------
# Batch-fused dispatch: ONE kernel call for the whole batch's schedules.
# ---------------------------------------------------------------------------


def build_dense_schedule(coords_i, grid: TileGrid, m: int, cfg, interp: bool,
                         cache) -> tuple[DeviceSchedule, bool | None]:
    """One image's schedule in dense dispatch form (cached).

    With ``schedule_backend="device"`` (and the default alg1 schedule)
    the TDT scatter, greedy selection, and the schedule->dispatch
    handoff all run on-device — the returned arrays are device arrays
    and NO host ``TileSchedule`` is built. The host backend (and the
    sequential ablation) builds the classic schedule and densifies it.
    """

    def build() -> DeviceSchedule:
        if cfg.schedule_backend == "device" and cfg.schedule == "alg1":
            B = tdt_from_coords_device(coords_i, grid, grid,
                                       interpret=interp)
            return schedule_arrays_device(B, m, interpret=interp)
        if cfg.schedule_backend == "device":
            B = np.asarray(tdt_from_coords_device(coords_i, grid, grid,
                                                  interpret=interp))
        else:
            B = np.asarray(tdt_from_coords(coords_i, grid, grid))
        if cfg.schedule == "alg1":
            sched = schedule_tiles(B, m)
        elif cfg.schedule == "sequential":
            sched = sequential_schedule(B)
        else:
            raise ValueError(f"unknown schedule: {cfg.schedule!r}")
        return DeviceSchedule.from_host(sched, grid.num_tiles)

    if cache is None:
        return build(), None
    # Same digest as the per-image paths plus a "dense" discriminator:
    # the cached artifact type differs from the TileSchedule entries.
    key = (coords_digest(coords_i, grid), grid.th, grid.tw, m,
           cfg.schedule, "dense")
    if cfg.faults is not None:
        salt = cfg.faults.miss_salt()
        if salt is not None:
            key = key + (salt,)
    return cache.get_or_build(key, build)


@dataclasses.dataclass
class _BatchArtifacts:
    """Prepass products of one whole batch (batch-fused dispatch)."""

    scheds: list[DeviceSchedule]
    cache_hits: list[bool | None]
    batch: object                 # packing.BatchDispatch (None if sharded)
    idx: jax.Array                # (N*T, p_pad, KK, 4) plane-global
    coeff: jax.Array              # (N*T, p_pad, KK, 4)
    schedule_s: float = 0.0
    schedule_device_s: float = 0.0
    shard: object = None          # shard.ShardedDispatch when sharded


def _pipeline_batch_prepass(
    coords: jax.Array,            # (N, H, W, KK, 2)
    grid: TileGrid,
    m: int,
    p_pad: int,
    cfg: PipelineConfig,
    interp: bool,
    tracer: Tracer | None = None,
    plan: ShardPlan | None = None,
) -> _BatchArtifacts:
    """Whole-batch prepass: per-image dense schedules (cached; partial
    batch hits skip scheduling for the hit images) concatenated into one
    batch grid, plus the plane-ordered packed operands — all jnp, so the
    device scheduling backend keeps the hot path host-free. With a
    shard ``plan`` the schedules concatenate PER SHARD instead (each
    shard keeps its own ragged padding)."""
    tr = tracer if tracer is not None else get_tracer()
    n = coords.shape[0]
    cache = default_schedule_cache() if cfg.use_schedule_cache else None
    with tr.timed("prepass.schedule", backend=cfg.schedule_backend,
                  batch=n) as ssp:
        scheds, hits = [], []
        for i in range(n):
            if cfg.faults is not None:
                cfg.faults.check("prepass", image=i)
            ds, hit = build_dense_schedule(coords[i], grid, m, cfg, interp,
                                           cache)
            scheds.append(ds)
            hits.append(hit)
        if plan is None:
            batch = pack_batch_schedules(scheds, grid.num_tiles,
                                         grid.num_tiles)
            shard = None
        else:
            batch = None
            shard = shard_batch_schedules(scheds, grid.num_tiles,
                                          grid.num_tiles, plan)
    schedule_s = ssp.dur
    if cache is not None:
        cache.note_batch_assembly(sum(bool(h) for h in hits),
                                  images=len(hits))

    with tr.span("pack", dispatch="batch_fused", batch=n):
        idx, coeff = jax.vmap(
            lambda c: pack_plane_operands(c, grid, p_pad))(coords)
    kk = coords.shape[3]
    idx = idx.reshape(n * grid.num_tiles, p_pad, kk, 4)
    coeff = coeff.reshape(n * grid.num_tiles, p_pad, kk, 4)
    device = cfg.schedule_backend == "device" and cfg.schedule == "alg1"
    return _BatchArtifacts(
        scheds=scheds, cache_hits=hits, batch=batch, idx=idx, coeff=coeff,
        schedule_s=schedule_s,
        schedule_device_s=schedule_s if device else 0.0, shard=shard)


def _pipeline_batch_exec(
    x: jax.Array,                 # (N, H, W, C_in)
    art: _BatchArtifacts,
    w2: jax.Array,
    b: jax.Array,
    kernel_size: int,
    cfg: PipelineConfig,
    grid: TileGrid,
    m: int,
    interp: bool,
    trace: PipelineTrace,
    return_trace: bool,
    mesh=None,
    plan: ShardPlan | None = None,
) -> jax.Array:
    n, h, w = x.shape[0], x.shape[1], x.shape[2]
    c = x.shape[3]
    tp = grid.th * grid.tw
    t = grid.num_tiles
    c_out = w2.shape[-1]
    if cfg.faults is not None:
        cfg.faults.check("dispatch", images=n)

    x_tiles = jax.vmap(lambda p: plane_to_tiles(p, grid))(x)  # (N, T, tp, C)
    if plan is None:
        y_rows = dcn_fused_batch(
            x_tiles.reshape(n * t, tp, c), art.batch.row_id,
            art.batch.dep_glb, art.batch.dep_cnt, art.idx, art.coeff,
            w2, b, t_in=t, kernel_size=kernel_size, block_p=cfg.block_p,
            interpret=interp)[:, :tp]
        # Scatter valid rows back to (image, tile) order; ragged-padding
        # rows land in a dump row that is dropped.
        target = jnp.where(art.batch.oid >= 0, art.batch.row_id, n * t)
        y_all = jnp.zeros((n * t + 1, tp, c_out), x.dtype)
        y_all = y_all.at[target].set(y_rows.astype(x.dtype))
        y_tiles = y_all[:-1].reshape(n, t, tp, c_out)
    else:
        sh = art.shard
        y_rows = dcn_fused_batch_sharded(
            stack_rows(x_tiles.reshape(n * t, tp, c), plan, t),
            sh.row_id, sh.dep_glb, sh.dep_cnt,
            stack_rows(art.idx, plan, t), stack_rows(art.coeff, plan, t),
            w2, b, mesh=mesh, t_in=t, kernel_size=kernel_size,
            block_p=cfg.block_p, interpret=interp)[:, :, :tp]
        # Per-shard scatter (row ids are shard-local) stays on each
        # device; the unstack of the result is the run's ONE all-gather.
        slab = plan.n_max * t
        target = jnp.where(sh.oid >= 0, sh.row_id, slab)
        y_all = jnp.zeros((plan.n_shards, slab + 1, tp, c_out), x.dtype)
        y_all = jax.vmap(lambda ya, tg, yy: ya.at[tg].set(yy))(
            y_all, target, y_rows.astype(x.dtype))
        y_flat = unstack_rows(y_all[:, :-1], plan, t)
        trace.allgather_bytes += allgather_nbytes(y_flat)
        trace.shards = plan.n_shards
        y_tiles = y_flat.reshape(n, t, tp, c_out)
    y = jax.vmap(lambda yt: tiles_to_plane(yt, grid, h, w))(y_tiles)

    trace.batch_dispatches += 1
    tile_bytes = tp * c * x.dtype.itemsize
    for i in range(n):
        im = ImageTrace(grid=grid, tile_bytes=tile_bytes, buffer_tiles=m,
                        schedule=cfg.schedule,
                        schedule_cache_hit=art.cache_hits[i],
                        dispatch="batch_fused",
                        schedule_backend=cfg.schedule_backend,
                        batch_rows=(i * t, (i + 1) * t))
        if return_trace:
            # Lazy host assembly — traces/cross-checks only, never the
            # hot path (asserted by the prepass-instrumentation test).
            # buffer_bytes uses the schedule's own padded dep count (as
            # the per-image batched path does), NOT DeviceSchedule.k_pad
            # — the device handoff pads that to pow2_pad(num_tiles).
            sched = art.scheds[i].to_host()
            k_pad = pow2_pad(max((len(d) for d in sched.iid), default=1))
            buffer_bytes = k_pad * tp * c * x.dtype.itemsize
            for out_tile, deps in zip(sched.oid, sched.iid):
                im.records.append(TileRecord(
                    out_tile=out_tile, dep_tiles=tuple(deps),
                    loaded_bytes=len(deps) * tile_bytes,
                    buffer_bytes=buffer_bytes))
        trace.images.append(im)
    return y


def dcn_pipeline(
    x: jax.Array,
    params: DeformableConvParams,
    *,
    kernel_size: int = 3,
    variant: str = "dcn2",
    max_displacement: float | None = None,
    tile: int | tuple[int, int] = 8,
    buffer_tiles: int | None = None,
    schedule: str = "alg1",
    block_p: int = 128,
    interpret: bool | None = None,
    return_trace: bool = False,
    config: PipelineConfig | None = None,
    tracer: Tracer | None = None,
):
    """Scheduler-driven deformable conv over a batch: (N,H,W,C) -> (N,H,W,O).

    Per batch element: stage-1 offsets -> coords -> TDT -> Algorithm-1
    schedule -> fused-kernel execution (one batched grid dispatch per
    image by default; per-tile dispatches with ``dispatch="per_tile"``)
    -> scatter. Numerically matches ``core.deform.deformable_conv2d``
    (the XLA reference) to float tolerance; additionally returns a
    :class:`PipelineTrace` of the actual packed-tile traffic when
    ``return_trace`` is set.

    ``config`` overrides the individual executor keywords when given.
    ``tracer`` routes the call's telemetry spans (prepass/pack/dispatch)
    into a specific :class:`~repro.obs.Tracer`; default is the current
    ``repro.obs.get_tracer()`` (a no-op unless enabled).
    """
    if isinstance(x, jax.core.Tracer):
        raise ValueError(
            "dcn_pipeline is a host-driven, forward-only executor: the "
            "Algorithm-1 schedule is data-dependent, so it cannot run "
            "under jit/grad/vmap. Trace with backend='xla' "
            "(fused_deformable_conv2d) for differentiable/jitted paths.")
    cfg = config or PipelineConfig(tile=tile, buffer_tiles=buffer_tiles,
                                   schedule=schedule, block_p=block_p,
                                   interpret=interpret)
    tr = tracer if tracer is not None else get_tracer()
    n, h, w = x.shape[0], x.shape[1], x.shape[2]
    th, tw = cfg.tile_hw
    if th > h or tw > w:
        raise ValueError(
            f"tile {th}x{tw} exceeds the {h}x{w} feature plane — a "
            f"degenerate 1-tile grid; choose tile sides <= the plane")
    kk = kernel_size * kernel_size
    c_out = params.w.shape[-1]

    offsets = conv2d(x, params.w_off, params.b_off)               # Eq. 1
    coords = offsets_to_coords(offsets.astype(jnp.float32),
                               kernel_size, variant, max_displacement)
    w2 = params.w.reshape(kk, x.shape[-1], c_out)

    trace = PipelineTrace()
    if n == 0:
        y = jnp.zeros(x.shape[:3] + (c_out,), x.dtype)
        return (y, trace) if return_trace else y

    if cfg.autotune != "off":
        # Single layer, nothing to cut: the search degenerates to the
        # tile shape with the least simulated DRAM (first image's
        # coords as the representative input; winner cached per layer
        # geometry, so later batches skip straight to it).
        from repro.tuning import resolve_tuned_tile
        tt = resolve_tuned_tile(
            coords[0], h, w, c_in=int(x.shape[-1]), c_out=int(c_out),
            kernel_size=kernel_size, autotune=cfg.autotune,
            dtype_bytes=x.dtype.itemsize, tile_hw=(th, tw),
            buffer_tiles=cfg.buffer_tiles, schedule=cfg.schedule,
            budget=cfg.autotune_budget,
            plan_cache_dir=cfg.plan_cache_dir, tracer=tr)
        if tt is not None:
            th, tw = tt
    grid = TileGrid(h, w, th, tw)
    tp = grid.th * grid.tw
    m = grid.num_tiles if cfg.buffer_tiles is None else cfg.buffer_tiles
    bp = min(cfg.block_p, tp)
    p_pad = tp if tp % bp == 0 else round_up(tp, cfg.block_p)
    interp = resolve_interpret(cfg.interpret)

    if cfg.dispatch == "batch_fused":
        # Batch-level prepass replaces the per-image staging loop: the
        # whole batch's schedules concatenate into ONE kernel dispatch
        # (per shard, when a mesh shards the batch axis).
        mesh = resolve_shard_mesh(cfg.mesh, cfg.data_parallel)
        plan = (plan_batch_shards(n, dict(mesh.shape)["data"])
                if mesh is not None else None)
        with tr.timed("prepass", batch=n) as psp:
            art = _pipeline_batch_prepass(coords, grid, m, p_pad, cfg,
                                          interp, tracer=tr, plan=plan)
        trace.overlap.add_span(psp)
        trace.overlap.prepass_wait_s += psp.dur
        trace.overlap.schedule_s += art.schedule_s
        trace.overlap.schedule_device_s += art.schedule_device_s
        with use_tracer(tr):
            y = _pipeline_batch_exec(x, art, w2, params.b, kernel_size,
                                     cfg, grid, m, interp, trace,
                                     return_trace, mesh=mesh, plan=plan)
        return (y, trace) if return_trace else y

    def prepass(i: int) -> _ImageArtifacts:
        if cfg.faults is not None:
            cfg.faults.check("prepass", image=i)
        return _pipeline_prepass(coords[i], grid, m, p_pad, cfg, interp,
                                 tracer=tr)

    def execute(i: int, art: _ImageArtifacts) -> jax.Array:
        if cfg.faults is not None:
            cfg.faults.check("dispatch", image=i)
        with use_tracer(tr):
            y_i, im_tr = _pipeline_exec(x[i], art, w2, params.b,
                                        kernel_size, cfg, grid, m, p_pad,
                                        interp)
        trace.overlap.schedule_s += art.schedule_s
        trace.overlap.schedule_device_s += art.schedule_device_s
        trace.images.append(im_tr)
        return y_i

    outs = run_staged(n, prepass, execute, cfg.staging_depth,
                      trace.overlap, tracer=tr,
                      watchdog_s=cfg.watchdog_s, faults=cfg.faults)
    y = jnp.stack(outs)
    return (y, trace) if return_trace else y
