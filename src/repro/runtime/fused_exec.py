"""Cross-layer fused network executor (paper §IV-D taken network-wide).

Executes a :class:`~repro.runtime.graph.NetGraph` so that inside each
:class:`~repro.runtime.graph.FusedGroup` the boundary feature planes
between layers NEVER materialize in DRAM:

  prepass   per group, run stage-1 offset convs densely (the paper's
            pre-scheduler runs ahead of the PE array) and build one TDT
            per layer — measured ``tdt_from_coords`` for DCN layers,
            analytic ``tdt_standard_conv`` halos for standard convs;
  schedule  chain the per-layer TDTs into one composite table
            (``compose_tdt``) and run ONE Algorithm-1 schedule per group
            over the *group-input* tiles;
  execute   walk the schedule; each group-output tile pulls its producer
            tiles recursively. Intermediate tiles live in a bounded
            per-layer :class:`TileBuffer` (FIFO eviction, recompute on
            miss — eviction costs FLOPs, never DRAM), conv tiles run as
            halo-windowed XLA convs, DCN tiles as the packed fused Pallas
            kernel (``kernels.dcn_fused``).

Pool/upsample segments between groups execute densely; their plane
traffic is counted as boundary bytes. The resulting
:class:`~repro.runtime.trace.NetworkTrace` must agree exactly with
``core.simulator.simulate_network`` — benchmarks/bench_graph.py asserts
the cross-check, tests/test_graph.py the numerics vs the XLA reference.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deform import conv2d, deformable_conv2d, offsets_to_coords
from repro.core.scheduler import schedule_tiles, sequential_schedule
from repro.core.tiles import (TileGrid, compose_tdt_chain, tdt_from_coords,
                              tdt_standard_conv)
from repro.kernels.dcn_fused import dcn_fused_tile
from repro.kernels.ops import round_up
from repro.runtime.cache import (ScheduleCache, chain_digest, conv_digest,
                                 coords_digest, default_schedule_cache)
from repro.runtime.graph import (DeformNode, FusedGroup, NetGraph, PoolNode,
                                 Segment, UpsampleNode, boundary_bytes,
                                 group_weight_bytes, partition_graph)
from repro.runtime.packing import (build_neighbour_tables, pack_output_tile,
                                   plane_to_tiles, tiles_to_plane)
from repro.runtime.pipeline import resolve_interpret
from repro.runtime.trace import (GroupTrace, LayerBufferStats, NetworkTrace,
                                 TileRecord)

ONCHIP_BUDGET_BYTES = (128 + 256) * 1024   # paper Table I: input + output buf


@dataclasses.dataclass(frozen=True)
class GraphConfig:
    """Network-graph executor knobs."""

    tile: int | tuple[int, int] = 8       # tile side(s), shared per group
    buffer_tiles: int | None = None       # M for the composite schedule
    # Intermediate tile-buffer capacity per layer plane. None = derive from
    # onchip_budget_bytes (budget split across the group's layers); an int
    # pins it, and undersizing only costs recomputes, never correctness.
    inter_buffer_tiles: int | None = None
    schedule: str = "alg1"                # "alg1" | "sequential"
    block_p: int = 128                    # kernel pixel-block size
    interpret: bool | None = None         # None = auto (CPU -> interpret)
    onchip_budget_bytes: int = ONCHIP_BUDGET_BYTES  # drives group planning
    use_schedule_cache: bool = True

    @property
    def tile_hw(self) -> tuple[int, int]:
        t = self.tile
        th, tw = (t, t) if isinstance(t, int) else (int(t[0]), int(t[1]))
        if th < 1 or tw < 1:
            raise ValueError(f"tile sides must be >= 1, got {(th, tw)}")
        return th, tw


class TileBuffer:
    """Bounded on-chip store for one intermediate plane's output tiles.

    FIFO eviction like the paper's input buffer; a miss on a previously
    produced tile means recompute (fusion forbids the DRAM round trip).
    """

    def __init__(self, capacity_tiles: int):
        if capacity_tiles < 1:
            raise ValueError("tile buffer capacity must be >= 1 tile")
        self.capacity = int(capacity_tiles)
        self._tiles: dict[int, Any] = {}
        self._queue: list[int] = []
        self._ever: set[int] = set()
        self.computes = 0
        self.recomputes = 0
        self.resident_bytes = 0
        self.max_resident_bytes = 0

    def get(self, tile: int):
        return self._tiles.get(tile)

    def put(self, tile: int, value, nbytes: int) -> None:
        self.computes += 1
        if tile in self._ever:
            self.recomputes += 1
        self._ever.add(tile)
        if tile not in self._tiles:
            self._queue.append(tile)
        self._tiles[tile] = value
        self.resident_bytes += nbytes
        while len(self._queue) > self.capacity:
            evicted = self._queue.pop(0)
            self._tiles.pop(evicted, None)
            self.resident_bytes -= nbytes  # uniform tile size per plane
        self.max_resident_bytes = max(self.max_resident_bytes,
                                      self.resident_bytes)


def apply_layer_dense(plane: jax.Array, node, p,
                      max_displacement: float | None = None) -> jax.Array:
    """XLA reference for one layer node on a (H, W, C) plane."""
    if isinstance(node, DeformNode):
        y = deformable_conv2d(plane[None], p, node.kernel_size, node.variant,
                              max_displacement)[0]
    else:
        y = conv2d(plane[None], p["w"], p["b"])[0]
    return jax.nn.relu(y) if node.relu else y


def apply_boundary_dense(plane: jax.Array, node: Segment) -> jax.Array:
    """Dense pool/upsample between groups (resolution boundary)."""
    if isinstance(node, PoolNode):
        k = node.window
        return jax.lax.reduce_window(plane[None], -jnp.inf, jax.lax.max,
                                     (1, k, k, 1), (1, k, k, 1), "VALID")[0]
    f = node.factor
    return jnp.repeat(jnp.repeat(plane, f, axis=0), f, axis=1)


def run_graph_dense(convs: list, graph: NetGraph, x: jax.Array,
                    max_displacement: float | None = None) -> jax.Array:
    """Dense XLA execution of the whole graph — the numerics oracle."""
    outs = []
    for i in range(x.shape[0]):
        plane = x[i]
        for node in graph.nodes:
            if isinstance(node, (PoolNode, UpsampleNode)):
                plane = apply_boundary_dense(plane, node)
            else:
                plane = apply_layer_dense(plane, node, convs[node.param_idx],
                                          max_displacement)
        outs.append(plane)
    return jnp.stack(outs)


def _inter_capacity(cfg: GraphConfig, group: FusedGroup, node,
                    tp: int, dtype_bytes: int) -> int:
    """Tile-buffer capacity for one layer plane: an even split of the
    on-chip budget across the group's layers, in that plane's tile size."""
    if cfg.inter_buffer_tiles is not None:
        return cfg.inter_buffer_tiles
    per_layer = cfg.onchip_budget_bytes // max(1, group.n_layers)
    return max(1, per_layer // (tp * node.c_out * dtype_bytes))


def _tile_valid_mask(grid: TileGrid, tile: int) -> np.ndarray:
    """(tp, 1) float mask: 1 inside the real H x W plane, 0 on padding."""
    tr, tc = divmod(tile, grid.cols)
    rr = np.arange(tr * grid.th, (tr + 1) * grid.th)
    cc = np.arange(tc * grid.tw, (tc + 1) * grid.tw)
    valid = (rr[:, None] < grid.h) & (cc[None, :] < grid.w)
    return valid.reshape(-1, 1).astype(np.float32)


def _assemble_halo(dep_arrays: list, deps: np.ndarray, grid: TileGrid,
                   out_tile: int, r: int, c: int) -> jax.Array:
    """Paste dependent tiles into the (th+2r, tw+2r, C) halo window of
    ``out_tile``. Positions no tile covers stay zero — identical to the
    SAME-conv zero padding because produced tiles are masked beyond the
    real plane."""
    th, tw = grid.th, grid.tw
    tr, tc = divmod(out_tile, grid.cols)
    r_lo, c_lo = tr * th - r, tc * tw - r
    win = jnp.zeros((th + 2 * r, tw + 2 * r, c), dep_arrays[0].dtype)
    for d, arr in zip(deps, dep_arrays):
        dr, dc = divmod(int(d), grid.cols)
        a0, a1 = max(dr * th, r_lo), min((dr + 1) * th, r_lo + th + 2 * r)
        b0, b1 = max(dc * tw, c_lo), min((dc + 1) * tw, c_lo + tw + 2 * r)
        if a1 <= a0 or b1 <= b0:
            continue
        patch = arr.reshape(th, tw, c)[a0 - dr * th:a1 - dr * th,
                                       b0 - dc * tw:b1 - dc * tw]
        win = win.at[a0 - r_lo:a1 - r_lo, b0 - c_lo:b1 - c_lo].set(patch)
    return win


def _group_schedule_artifacts(
    x_g: jax.Array,
    group: FusedGroup,
    convs: list,
    grid: TileGrid,
    m: int,
    cfg: GraphConfig,
    max_displacement: float | None,
    cache: ScheduleCache | None,
):
    """Prepass: per-layer TDTs + neighbour tables + composite schedule.

    Stage-1 offset convs run densely (the hardware pre-scheduler's role);
    only layers with a downstream DeformNode need their dense plane. The
    (TDTs, schedule) pair is cached under the quantized-coords chain
    digest when a cache is given.
    """
    needs_plane = [any(isinstance(n, DeformNode) for n in group.nodes[j + 1:])
                   for j in range(group.n_layers)]
    plane = x_g
    nbs: list = []
    digests: list[str] = []
    dcn_coords: list = []
    for j, node in enumerate(group.nodes):
        p = convs[node.param_idx]
        if isinstance(node, DeformNode):
            offsets = conv2d(plane[None], p.w_off, p.b_off)
            coords = offsets_to_coords(offsets.astype(jnp.float32),
                                       node.kernel_size, node.variant,
                                       max_displacement)[0]
            nbs.append(build_neighbour_tables(coords, grid))
            digests.append(coords_digest(coords, grid))
            dcn_coords.append(coords)
        else:
            nbs.append(None)
            digests.append(conv_digest(node.kernel_size, grid))
            dcn_coords.append(None)
        if needs_plane[j]:
            plane = apply_layer_dense(plane, node, p, max_displacement)

    def build():
        b_layers = []
        for node, coords in zip(group.nodes, dcn_coords):
            if coords is None:
                b_layers.append(tdt_standard_conv(grid, grid,
                                                  node.kernel_size))
            else:
                b_layers.append(np.asarray(tdt_from_coords(coords, grid,
                                                           grid)))
        comp = compose_tdt_chain(b_layers)
        if cfg.schedule == "alg1":
            sched = schedule_tiles(comp, m)
        elif cfg.schedule == "sequential":
            sched = sequential_schedule(comp)
        else:
            raise ValueError(f"unknown schedule: {cfg.schedule!r}")
        return b_layers, sched

    if cache is None:
        b_layers, sched = build()
        return b_layers, nbs, sched, None
    key = (chain_digest(digests, grid), m, cfg.schedule)
    (b_layers, sched), hit = cache.get_or_build(key, build)
    return b_layers, nbs, sched, hit


def _run_group(
    x_g: jax.Array,
    group: FusedGroup,
    convs: list,
    cfg: GraphConfig,
    interpret: bool,
    max_displacement: float | None,
    cache: ScheduleCache | None,
) -> tuple[jax.Array, GroupTrace]:
    h, w, c_in = x_g.shape
    th, tw = cfg.tile_hw
    grid = TileGrid(h, w, min(th, h), min(tw, w))
    tp = grid.th * grid.tw
    m = grid.num_tiles if cfg.buffer_tiles is None else cfg.buffer_tiles
    dtype_bytes = x_g.dtype.itemsize

    b_layers, nbs, sched, cache_hit = _group_schedule_artifacts(
        x_g, group, convs, grid, m, cfg, max_displacement, cache)

    # Per-DCN-layer packing geometry: uniform packed-buffer sizes so each
    # layer compiles its fused kernel once per group.
    bp = min(cfg.block_p, tp)
    p_pad = tp if tp % bp == 0 else round_up(tp, cfg.block_p)
    k_pad = [1 << (max(1, int(b.sum(axis=1).max())) - 1).bit_length()
             for b in b_layers]

    x_tiles = plane_to_tiles(x_g, grid)
    buffers = [TileBuffer(_inter_capacity(cfg, group, n, tp, dtype_bytes))
               for n in group.nodes]
    masks = [jnp.asarray(_tile_valid_mask(grid, t), x_g.dtype)
             for t in range(grid.num_tiles)]

    def produce(j: int, t: int) -> jax.Array:
        if j < 0:
            return x_tiles[t]
        cached = buffers[j].get(t)
        if cached is not None:
            return cached
        node = group.nodes[j]
        deps = np.flatnonzero(b_layers[j][t])
        dep_arrays = [produce(j - 1, int(d)) for d in deps]
        p = convs[node.param_idx]
        if isinstance(node, DeformNode):
            idx, coeff = pack_output_tile(nbs[j], grid, t, deps.tolist(),
                                          p_pad)
            x_packed = jnp.stack(dep_arrays)                  # (k, tp, C)
            if len(deps) < k_pad[j]:
                x_packed = jnp.pad(
                    x_packed, ((0, k_pad[j] - len(deps)), (0, 0), (0, 0)))
            kk = node.kernel_size ** 2
            w2 = p.w.reshape(kk, node.c_in, node.c_out)
            y = dcn_fused_tile(
                x_packed.reshape(k_pad[j] * tp, node.c_in),
                jnp.asarray(idx), jnp.asarray(coeff), w2, p.b,
                kernel_size=node.kernel_size, block_p=cfg.block_p,
                interpret=interpret)[:tp]
        else:
            r = (node.kernel_size - 1) // 2
            win = _assemble_halo(dep_arrays, deps, grid, t, r, node.c_in)
            y = conv2d(win[None], p["w"], p["b"], padding="VALID")[0]
            y = y.reshape(tp, node.c_out)
        if node.relu:
            y = jax.nn.relu(y)
        y = y * masks[t]    # zero padded-plane pixels: halo reads see zeros
        buffers[j].put(t, y, tp * node.c_out * dtype_bytes)
        return y

    tile_bytes = tp * c_in * dtype_bytes
    trace = GroupTrace(
        grid=grid, tile_bytes=tile_bytes, buffer_tiles=m,
        schedule=cfg.schedule, schedule_cache_hit=cache_hit,
        dtype_bytes=dtype_bytes, layer_channels=group.layer_channels,
        output_bytes=h * w * group.c_out * dtype_bytes,
        weight_bytes=group_weight_bytes(group, dtype_bytes),
        b_layers=list(b_layers))

    last = group.n_layers - 1
    y_tiles: list = [None] * grid.num_tiles
    for out_tile, loads in zip(sched.oid, sched.iid):
        y_tiles[out_tile] = produce(last, out_tile)
        trace.records.append(TileRecord(
            out_tile=out_tile,
            dep_tiles=tuple(loads),
            loaded_bytes=len(loads) * tile_bytes,
            buffer_bytes=len(loads) * tile_bytes))

    trace.layer_stats = [
        LayerBufferStats(kind=n.kind, tiles_computed=b.computes,
                         recomputes=b.recomputes,
                         max_resident_bytes=b.max_resident_bytes)
        for n, b in zip(group.nodes, buffers)]

    zero = jnp.zeros((tp, group.c_out), x_g.dtype)
    y = tiles_to_plane(jnp.stack([t if t is not None else zero
                                  for t in y_tiles]), grid, h, w)
    return y, trace


def run_graph(
    convs: list,
    graph: NetGraph,
    x: jax.Array,
    *,
    config: GraphConfig | None = None,
    max_displacement: float | None = None,
    return_trace: bool = False,
):
    """Execute a backbone graph over a batch: (N,H,W,C) -> (N,H',W',C').

    ``convs`` is the per-node parameter list (``params["convs"]`` of the
    DCN models): ``DeformableConvParams`` for DeformNodes, ``{"w", "b"}``
    dicts for ConvNodes. Numerically matches :func:`run_graph_dense` (the
    XLA reference) to float tolerance; with ``return_trace`` additionally
    returns the :class:`NetworkTrace` of the executed DRAM traffic.
    """
    if isinstance(x, jax.core.Tracer):
        raise ValueError(
            "run_graph is a host-driven, forward-only executor: the "
            "cross-layer schedule is data-dependent, so it cannot run "
            "under jit/grad/vmap. Use backend='xla' for those paths.")
    cfg = config or GraphConfig()
    interpret = resolve_interpret(cfg.interpret)
    cache = default_schedule_cache() if cfg.use_schedule_cache else None
    segments = partition_graph(graph, cfg.onchip_budget_bytes,
                               dtype_bytes=x.dtype.itemsize)

    trace = NetworkTrace()
    n = x.shape[0]
    if n == 0:
        h, w, c = graph.out_shape
        y = jnp.zeros((0, h, w, c), x.dtype)
        return (y, trace) if return_trace else y
    outs = []
    for i in range(n):
        plane = x[i]
        g = 0
        for seg in segments:
            if isinstance(seg, (PoolNode, UpsampleNode)):
                plane = apply_boundary_dense(plane, seg)
                trace.boundary_bytes += boundary_bytes(seg,
                                                       x.dtype.itemsize)
            else:
                plane, gt = _run_group(plane, seg, convs, cfg, interpret,
                                       max_displacement, cache)
                gt.image, gt.group = i, g
                g += 1
                trace.groups.append(gt)
        outs.append(plane)
    y = jnp.stack(outs)
    return (y, trace) if return_trace else y


def network_sim_specs(trace: NetworkTrace) -> list[dict]:
    """Rebuild ``core.simulator.simulate_network`` group specs from an
    executed trace — byte-identical TDT inputs, so the fused prediction
    must equal the executed FIFO replay exactly."""
    specs = []
    for gt in trace.groups:
        specs.append(dict(
            b_layers=gt.b_layers,
            grid=gt.grid,
            layer_channels=gt.layer_channels,
            weight_bytes=gt.weight_bytes,
            buffer_tiles=gt.buffer_tiles,
            dtype_bytes=gt.dtype_bytes,
            schedule=gt.schedule,
        ))
    return specs
