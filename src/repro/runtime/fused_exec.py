"""Cross-layer fused network executor (paper §IV-D taken network-wide).

Executes a :class:`~repro.runtime.graph.NetGraph` under the accelerator's
cross-layer dataflow: inside each
:class:`~repro.runtime.graph.FusedGroup`, boundary feature planes between
layers carry no *modeled* DRAM traffic — the
:class:`~repro.runtime.trace.NetworkTrace` prices exactly group-input
tile loads (under the FIFO buffer model), group outputs, weights and
pool/upsample boundary planes, matching
``core.simulator.simulate_network`` byte-for-byte:

  prepass   per image, run the stage-1 chain densely (the paper's
            pre-scheduler runs ahead of the PE array) and build one TDT
            per layer — measured ``tdt_from_coords`` for DCN layers,
            analytic ``tdt_standard_conv`` halos for standard convs —
            then chain them (``compose_tdt``) into ONE Algorithm-1
            schedule per fused group and pack the batched kernel
            operands. The prepass for image i+1 runs on a staging thread
            while image i executes on the device
            (``GraphConfig.staging_depth``).
  execute   two dispatch modes:
              * ``"batched"`` (default) — one batched kernel dispatch per
                (group, layer segment): the group's schedule becomes the
                leading grid dimension of a single ``pallas_call``
                (``kernels.dcn_fused.dcn_fused_schedule``), with the
                scalar-prefetched dep table driving the input-tile DMA
                sequence; standard-conv segments run as one halo conv
                over the assembled plane. Dispatches per group drop from
                O(num_tiles x layers) to n_layers. Interior planes are
                materialized as whole device arrays between segments
                (recorded honestly in ``LayerBufferStats``
                ``max_resident_bytes``) — the paper's bounded on-chip
                intermediate buffer is modeled by the per_tile mode.
              * ``"per_tile"`` — the PR 2 demand-driven loop: each
                group-output tile pulls its producer tiles recursively
                through a bounded recompute-on-evict :class:`TileBuffer`
                (eviction costs FLOPs, never modeled DRAM).

Both modes execute the same Algorithm-1 schedule, whose group-input load
order is what the trace records and the simulator prices — batching
preserves it as the grid order, so the cross-check stays exact.
benchmarks/bench_graph.py asserts it; tests/test_graph.py +
tests/test_batched_dispatch.py pin the numerics vs the XLA reference.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deform import conv2d, deformable_conv2d, offsets_to_coords
from repro.core.scheduler import (DeviceSchedule, TileSchedule, pow2_pad,
                                  schedule_arrays_device, schedule_tiles,
                                  sequential_schedule)
from repro.core.tiles import (TileGrid, compose_tdt_chain,
                              compose_tdt_chain_device, tdt_from_coords,
                              tdt_standard_conv)
from repro.kernels.dcn_fused import (dcn_fused_batch,
                                     dcn_fused_batch_sharded,
                                     dcn_fused_schedule, dcn_fused_tile)
from repro.kernels.dcn_schedule import (tdt_dispatch_arrays,
                                        tdt_from_coords_device)
from repro.kernels.ops import round_up
from repro.obs import Tracer, get_tracer, use_tracer
from repro.runtime.cache import (ScheduleCache, chain_digest, conv_digest,
                                 coords_digest, default_schedule_cache)
from repro.runtime.graph import (DeformNode, FusedGroup, NetGraph, PoolNode,
                                 Segment, UpsampleNode, boundary_bytes,
                                 group_weight_bytes,
                                 partition_graph_cached)
from repro.runtime.packing import (build_neighbour_tables,
                                   pack_batch_schedules, pack_output_tile,
                                   pack_plane_operands, pack_schedule_tiles,
                                   plane_to_tiles, tiles_to_plane)
from repro.runtime.pipeline import (resolve_interpret, run_staged,
                                    validate_dispatch_config)
from repro.runtime.shard import (ShardPlan, allgather_nbytes,
                                 plan_batch_shards, resolve_shard_mesh,
                                 shard_batch_schedules, stack_rows,
                                 unstack_rows)
from repro.runtime.trace import (GroupTrace, LayerBufferStats, NetworkTrace,
                                 TileRecord)

ONCHIP_BUDGET_BYTES = (128 + 256) * 1024   # paper Table I: input + output buf


@dataclasses.dataclass(frozen=True)
class GraphConfig:
    """Network-graph executor knobs."""

    tile: int | tuple[int, int] = 8       # tile side(s), shared per group
    buffer_tiles: int | None = None       # M for the composite schedule
    # Intermediate tile-buffer capacity per layer plane (per_tile dispatch).
    # None = derive from onchip_budget_bytes (budget split across the
    # group's layers); an int pins it, and undersizing only costs
    # recomputes, never correctness.
    inter_buffer_tiles: int | None = None
    schedule: str = "alg1"                # "alg1" | "sequential"
    block_p: int = 128                    # kernel pixel-block size
    interpret: bool | None = None         # None = auto (CPU -> interpret)
    onchip_budget_bytes: int = ONCHIP_BUDGET_BYTES  # drives group planning
    use_schedule_cache: bool = True
    # "batched": one pallas_call grid per (group, layer segment) PER IMAGE.
    # "batch_fused": the concatenated schedules of all batch images as one
    #   grid per layer segment — dispatches per segment drop from N to 1,
    #   and with schedule_backend="device" the schedule arrays flow into
    #   the dispatch operands with zero host round trip.
    # "per_tile": PR 2 demand-driven per-tile dispatch loop.
    dispatch: str = "batched"
    # "host": TDT scatter + Algorithm-1 loop in host numpy/Python.
    # "device": both as Pallas kernels (kernels.dcn_schedule), bit-exact
    # vs the host path — the staging thread shrinks to packing only.
    schedule_backend: str = "host"
    # Images staged ahead of execution: 1 = serial, 2 = prepass image i+1
    # on a worker thread while image i executes (the default), >2 queues
    # deeper (rarely helps: prepass is single-threaded host work).
    staging_depth: int = 2
    # Staging-worker watchdog deadline (seconds); None = wait forever.
    # A staged prepass that misses it triggers failover to synchronous
    # prepass for the rest of the run (see pipeline.run_staged).
    watchdog_s: float | None = None
    # Batch-dimension scale-out (batch_fused only): an explicit
    # jax.sharding.Mesh with a "data" axis, or data_parallel=D (builds a
    # (D, 1) host mesh at run time). Each mesh device runs the
    # concatenated schedules of its local images; the only collective is
    # the all-gather at the logits.
    mesh: Any = None
    data_parallel: int | None = None
    # Simulator-guided plan autotuning (repro.tuning): "off" = greedy
    # partition at the default tile; "offline" = search (once per plan
    # key, cached) for the best cuts + per-group tile shapes;
    # "cached-only" = use a cached plan if present, never search (for
    # replicas that must not pay search latency).
    autotune: str = "off"
    # Directory for the persistent TunedPlan store; None = in-memory
    # only (the plan still survives across engines in this process).
    plan_cache_dir: str | None = None
    # Max simulator evaluations the search may pay per plan.
    autotune_budget: int = 128
    # Fault injector (repro.testing.faults.FaultInjector) — test/bench
    # only, excluded from config equality.
    faults: Any = dataclasses.field(default=None, compare=False)

    def __post_init__(self):
        validate_dispatch_config(self)

    @property
    def tile_hw(self) -> tuple[int, int]:
        t = self.tile
        th, tw = (t, t) if isinstance(t, int) else (int(t[0]), int(t[1]))
        if th < 1 or tw < 1:
            raise ValueError(f"tile sides must be >= 1, got {(th, tw)}")
        return th, tw


class TileBuffer:
    """Bounded on-chip store for one intermediate plane's output tiles.

    FIFO eviction like the paper's input buffer; a miss on a previously
    produced tile means recompute (fusion forbids the DRAM round trip).
    Used by the ``per_tile`` dispatch mode.
    """

    def __init__(self, capacity_tiles: int):
        if capacity_tiles < 1:
            raise ValueError("tile buffer capacity must be >= 1 tile")
        self.capacity = int(capacity_tiles)
        self._tiles: dict[int, Any] = {}
        self._queue: list[int] = []
        self._ever: set[int] = set()
        self.computes = 0
        self.recomputes = 0
        self.resident_bytes = 0
        self.max_resident_bytes = 0

    def get(self, tile: int):
        return self._tiles.get(tile)

    def put(self, tile: int, value, nbytes: int) -> None:
        self.computes += 1
        if tile in self._ever:
            self.recomputes += 1
        self._ever.add(tile)
        if tile not in self._tiles:
            self._queue.append(tile)
        self._tiles[tile] = value
        self.resident_bytes += nbytes
        while len(self._queue) > self.capacity:
            evicted = self._queue.pop(0)
            self._tiles.pop(evicted, None)
            self.resident_bytes -= nbytes  # uniform tile size per plane
        self.max_resident_bytes = max(self.max_resident_bytes,
                                      self.resident_bytes)


def apply_layer_dense(plane: jax.Array, node, p,
                      max_displacement: float | None = None) -> jax.Array:
    """XLA reference for one layer node on a (H, W, C) plane."""
    if isinstance(node, DeformNode):
        y = deformable_conv2d(plane[None], p, node.kernel_size, node.variant,
                              max_displacement)[0]
    else:
        y = conv2d(plane[None], p["w"], p["b"])[0]
    return jax.nn.relu(y) if node.relu else y


def apply_boundary_dense(plane: jax.Array, node: Segment) -> jax.Array:
    """Dense pool/upsample between groups (resolution boundary)."""
    if isinstance(node, PoolNode):
        k = node.window
        return jax.lax.reduce_window(plane[None], -jnp.inf, jax.lax.max,
                                     (1, k, k, 1), (1, k, k, 1), "VALID")[0]
    f = node.factor
    return jnp.repeat(jnp.repeat(plane, f, axis=0), f, axis=1)


def run_graph_dense(convs: list, graph: NetGraph, x: jax.Array,
                    max_displacement: float | None = None) -> jax.Array:
    """Dense XLA execution of the whole graph — the numerics oracle."""
    outs = []
    for i in range(x.shape[0]):
        plane = x[i]
        for node in graph.nodes:
            if isinstance(node, (PoolNode, UpsampleNode)):
                plane = apply_boundary_dense(plane, node)
            else:
                plane = apply_layer_dense(plane, node, convs[node.param_idx],
                                          max_displacement)
        outs.append(plane)
    return jnp.stack(outs)


def _segment_grid(seg: FusedGroup, th: int, tw: int) -> TileGrid:
    """Tile grid for one fused group: the group's autotuned tile shape
    when the plan set one, the config default otherwise — either way
    clamped to the group's plane (interior groups sit at lower
    resolution than the input)."""
    if seg.tile_hw is not None:
        th, tw = seg.tile_hw
    return TileGrid(seg.h, seg.w, min(th, seg.h), min(tw, seg.w))


def _inter_capacity(cfg: GraphConfig, group: FusedGroup, node,
                    tp: int, dtype_bytes: int) -> int:
    """Tile-buffer capacity for one layer plane: an even split of the
    on-chip budget across the group's layers, in that plane's tile size."""
    if cfg.inter_buffer_tiles is not None:
        return cfg.inter_buffer_tiles
    per_layer = cfg.onchip_budget_bytes // max(1, group.n_layers)
    return max(1, per_layer // (tp * node.c_out * dtype_bytes))


def _tile_valid_mask(grid: TileGrid, tile: int) -> np.ndarray:
    """(tp, 1) float mask: 1 inside the real H x W plane, 0 on padding."""
    tr, tc = divmod(tile, grid.cols)
    rr = np.arange(tr * grid.th, (tr + 1) * grid.th)
    cc = np.arange(tc * grid.tw, (tc + 1) * grid.tw)
    valid = (rr[:, None] < grid.h) & (cc[None, :] < grid.w)
    return valid.reshape(-1, 1).astype(np.float32)


def _assemble_halo(dep_arrays: list, deps: np.ndarray, grid: TileGrid,
                   out_tile: int, r: int, c: int) -> jax.Array:
    """Paste dependent tiles into the (th+2r, tw+2r, C) halo window of
    ``out_tile``. Positions no tile covers stay zero — identical to the
    SAME-conv zero padding because produced tiles are masked beyond the
    real plane."""
    th, tw = grid.th, grid.tw
    tr, tc = divmod(out_tile, grid.cols)
    r_lo, c_lo = tr * th - r, tc * tw - r
    win = jnp.zeros((th + 2 * r, tw + 2 * r, c), dep_arrays[0].dtype)
    for d, arr in zip(deps, dep_arrays):
        dr, dc = divmod(int(d), grid.cols)
        a0, a1 = max(dr * th, r_lo), min((dr + 1) * th, r_lo + th + 2 * r)
        b0, b1 = max(dc * tw, c_lo), min((dc + 1) * tw, c_lo + tw + 2 * r)
        if a1 <= a0 or b1 <= b0:
            continue
        patch = arr.reshape(th, tw, c)[a0 - dr * th:a1 - dr * th,
                                       b0 - dc * tw:b1 - dc * tw]
        win = win.at[a0 - r_lo:a1 - r_lo, b0 - c_lo:b1 - c_lo].set(patch)
    return win


@dataclasses.dataclass
class _LayerDispatch:
    """One DCN layer's batched-grid operands, packed in the prepass."""

    out_order: np.ndarray                 # (T,) grid order of output tiles
    dep_tbl: np.ndarray                   # (T, k_pad) scalar-prefetch table
    dep_cnt: np.ndarray                   # (T,) true dep count per tile
    idx: np.ndarray                       # (T, p_pad, KK, 4)
    coeff: np.ndarray                     # (T, p_pad, KK, 4)


@dataclasses.dataclass
class _GroupArtifacts:
    """Prepass products for one fused group of one image."""

    grid: TileGrid
    m: int                                # schedule buffer capacity
    b_layers: list[np.ndarray]            # per-layer TDTs
    nbs: list                             # per-layer NeighbourTables | None
    sched: TileSchedule                   # composite Algorithm-1 schedule
    cache_hit: bool | None
    # TDT + schedule build wall time inside the prepass, and the portion
    # that ran through the device scheduling backend.
    schedule_s: float = 0.0
    schedule_device_s: float = 0.0
    # Batched dispatch only: per-layer packed operands (None entries for
    # conv layers). Packed on the staging thread so the per-image packing
    # cost overlaps the previous image's execution.
    packed: list[_LayerDispatch | None] | None = None


def _group_schedule_artifacts(
    x_g: jax.Array,
    group: FusedGroup,
    convs: list,
    grid: TileGrid,
    m: int,
    cfg: GraphConfig,
    max_displacement: float | None,
    cache: ScheduleCache | None,
    need_out_plane: bool,
    interp: bool = False,
    tracer: Tracer | None = None,
) -> tuple[_GroupArtifacts, jax.Array]:
    """Prepass for one group: per-layer TDTs + neighbour tables +
    composite schedule, plus the group's dense output plane when
    ``need_out_plane`` (a downstream group still holds a DeformNode whose
    offset conv consumes it — the stage-1 chain runs exactly as far ahead
    as the deformation reaches, no further).

    The (TDTs, schedule) pair is cached under the quantized-coords chain
    digest when a cache is given.
    """
    tr = tracer if tracer is not None else get_tracer()
    # Dense planes are consumed only by DeformNode offset convs; stop
    # advancing after the last consumer (monotone: deforms never reappear
    # past this point within the group when need_out_plane is False).
    needs_plane = [need_out_plane
                   or any(isinstance(nd, DeformNode)
                          for nd in group.nodes[j + 1:])
                   for j in range(group.n_layers)]
    plane = x_g
    nbs: list = []
    digests: list[str] = []
    dcn_coords: list = []
    for j, node in enumerate(group.nodes):
        p = convs[node.param_idx]
        if isinstance(node, DeformNode):
            offsets = conv2d(plane[None], p.w_off, p.b_off)
            coords = offsets_to_coords(offsets.astype(jnp.float32),
                                       node.kernel_size, node.variant,
                                       max_displacement)[0]
            nbs.append(build_neighbour_tables(coords, grid))
            digests.append(coords_digest(coords, grid))
            dcn_coords.append(coords)
        else:
            nbs.append(None)
            digests.append(conv_digest(node.kernel_size, grid))
            dcn_coords.append(None)
        if needs_plane[j]:
            plane = apply_layer_dense(plane, node, p, max_displacement)

    def build():
        device = cfg.schedule_backend == "device"
        b_layers = []
        with tr.span("prepass.tdt", backend=cfg.schedule_backend,
                     layers=group.n_layers):
            for node, coords in zip(group.nodes, dcn_coords):
                if coords is None:
                    # Standard-conv halos are static per grid — no offsets
                    # to decode, so the analytic host table stays.
                    b_layers.append(tdt_standard_conv(grid, grid,
                                                      node.kernel_size))
                elif device:
                    b_layers.append(np.asarray(tdt_from_coords_device(
                        coords, grid, grid, interpret=interp)))
                else:
                    b_layers.append(np.asarray(tdt_from_coords(coords,
                                                               grid,
                                                               grid)))
        comp = compose_tdt_chain(b_layers)
        if cfg.schedule == "alg1":
            sched = schedule_tiles(comp, m,
                                   backend=cfg.schedule_backend,
                                   interpret=interp)
        elif cfg.schedule == "sequential":
            sched = sequential_schedule(comp)
        else:
            raise ValueError(f"unknown schedule: {cfg.schedule!r}")
        return b_layers, sched

    with tr.timed("prepass.schedule",
                  backend=cfg.schedule_backend) as ssp:
        if cache is None:
            b_layers, sched = build()
            hit = None
        else:
            # Tile dims are hashed into every digest via the grid, but
            # stay an explicit key component too: same coords under a
            # different (tile_h, tile_w) must never collide.
            key = (chain_digest(digests, grid), grid.th, grid.tw, m,
                   cfg.schedule)
            if cfg.faults is not None:
                salt = cfg.faults.miss_salt()
                if salt is not None:
                    key = key + (salt,)
            (b_layers, sched), hit = cache.get_or_build(key, build)
        ssp.set(cached=hit)
    schedule_s = ssp.dur

    # Pack the batched-grid operands here, on the staging thread. The
    # schedule cache cannot cover this: idx follows the quantized coords
    # (the cache key) but the BLI coefficients keep the fractional parts.
    packed: list[_LayerDispatch | None] | None = None
    if cfg.dispatch == "batched":
        tp = grid.th * grid.tw
        bp = min(cfg.block_p, tp)
        p_pad = tp if tp % bp == 0 else round_up(tp, cfg.block_p)
        oid_arr = np.asarray(sched.oid, np.int32)
        last = group.n_layers - 1
        packed = []
        with tr.span("pack", dispatch="batched", layers=group.n_layers):
            for j, node in enumerate(group.nodes):
                if not isinstance(node, DeformNode):
                    packed.append(None)
                    continue
                # Grid order: the Algorithm-1 schedule for the group's
                # output layer; plane order for interior layers (their
                # tiles never touch DRAM, so order is free).
                out_order = (oid_arr if j == last
                             else np.arange(grid.num_tiles,
                                            dtype=np.int32))
                dep_lists = [np.flatnonzero(b_layers[j][t])
                             for t in out_order]
                k_pad = pow2_pad(max((len(d) for d in dep_lists),
                                     default=1))
                dep_tbl, dep_cnt, idx, coeff = pack_schedule_tiles(
                    nbs[j], grid, out_order, dep_lists, p_pad, k_pad)
                packed.append(_LayerDispatch(out_order, dep_tbl, dep_cnt,
                                             idx, coeff))

    art = _GroupArtifacts(
        grid=grid, m=m, b_layers=list(b_layers), nbs=nbs, sched=sched,
        cache_hit=hit, packed=packed, schedule_s=schedule_s,
        schedule_device_s=(schedule_s
                           if cfg.schedule_backend == "device" else 0.0))
    return art, plane


def _image_prepass(
    x_i: jax.Array,
    segments: list[Segment],
    convs: list,
    cfg: GraphConfig,
    max_displacement: float | None,
    cache: ScheduleCache | None,
    interp: bool = False,
    tracer: Tracer | None = None,
) -> list[_GroupArtifacts | None]:
    """Host-side prepass of one whole image: the dense stage-1 chain runs
    ahead through the segments as far as the last DeformNode's offset
    conv needs it, emitting per-group schedule artifacts. Runs on the
    staging thread so it overlaps device execution of the previous
    image."""
    th, tw = cfg.tile_hw
    # deform_after[s]: a segment AFTER s still contains a DeformNode, so
    # segment s must keep advancing the dense plane for its prepass.
    deform_after = [False] * len(segments)
    seen = False
    for s in range(len(segments) - 1, -1, -1):
        deform_after[s] = seen
        if isinstance(segments[s], FusedGroup) and any(
                isinstance(nd, DeformNode) for nd in segments[s].nodes):
            seen = True

    arts: list[_GroupArtifacts | None] = []
    plane = x_i
    for s, seg in enumerate(segments):
        if isinstance(seg, (PoolNode, UpsampleNode)):
            if deform_after[s]:
                plane = apply_boundary_dense(plane, seg)
            arts.append(None)
        else:
            grid = _segment_grid(seg, th, tw)
            m = (grid.num_tiles if cfg.buffer_tiles is None
                 else cfg.buffer_tiles)
            art, plane = _group_schedule_artifacts(
                plane, seg, convs, grid, m, cfg, max_displacement, cache,
                need_out_plane=deform_after[s], interp=interp,
                tracer=tracer)
            arts.append(art)
    return arts


def _exec_group_per_tile(
    x_tiles: jax.Array,
    group: FusedGroup,
    convs: list,
    cfg: GraphConfig,
    interpret: bool,
    art: _GroupArtifacts,
    masks: list,
    dtype_bytes: int,
) -> tuple[jax.Array, list[LayerBufferStats], int]:
    """PR 2 demand-driven loop: one kernel dispatch per produced tile,
    intermediates in bounded recompute-on-evict TileBuffers."""
    grid, b_layers, nbs, sched = art.grid, art.b_layers, art.nbs, art.sched
    tp = grid.th * grid.tw
    bp = min(cfg.block_p, tp)
    p_pad = tp if tp % bp == 0 else round_up(tp, cfg.block_p)
    k_pad = [pow2_pad(int(b.sum(axis=1).max())) for b in b_layers]
    buffers = [TileBuffer(_inter_capacity(cfg, group, n, tp, dtype_bytes))
               for n in group.nodes]

    def produce(j: int, t: int) -> jax.Array:
        if j < 0:
            return x_tiles[t]
        cached = buffers[j].get(t)
        if cached is not None:
            return cached
        node = group.nodes[j]
        deps = np.flatnonzero(b_layers[j][t])
        dep_arrays = [produce(j - 1, int(d)) for d in deps]
        p = convs[node.param_idx]
        if isinstance(node, DeformNode):
            idx, coeff = pack_output_tile(nbs[j], grid, t, deps.tolist(),
                                          p_pad)
            x_packed = jnp.stack(dep_arrays)                  # (k, tp, C)
            if len(deps) < k_pad[j]:
                x_packed = jnp.pad(
                    x_packed, ((0, k_pad[j] - len(deps)), (0, 0), (0, 0)))
            kk = node.kernel_size ** 2
            w2 = p.w.reshape(kk, node.c_in, node.c_out)
            y = dcn_fused_tile(
                x_packed.reshape(k_pad[j] * tp, node.c_in),
                jnp.asarray(idx), jnp.asarray(coeff), w2, p.b,
                kernel_size=node.kernel_size, block_p=cfg.block_p,
                interpret=interpret)[:tp]
        else:
            r = (node.kernel_size - 1) // 2
            win = _assemble_halo(dep_arrays, deps, grid, t, r, node.c_in)
            y = conv2d(win[None], p["w"], p["b"], padding="VALID")[0]
            y = y.reshape(tp, node.c_out)
        if node.relu:
            y = jax.nn.relu(y)
        y = y * masks[t]    # zero padded-plane pixels: halo reads see zeros
        buffers[j].put(t, y, tp * node.c_out * dtype_bytes)
        return y

    last = group.n_layers - 1
    y_tiles: list = [None] * grid.num_tiles
    for out_tile in sched.oid:
        y_tiles[out_tile] = produce(last, out_tile)
    zero = jnp.zeros((tp, group.c_out), x_tiles.dtype)
    out = jnp.stack([t if t is not None else zero for t in y_tiles])

    stats = [LayerBufferStats(kind=n.kind, tiles_computed=b.computes,
                              recomputes=b.recomputes,
                              max_resident_bytes=b.max_resident_bytes)
             for n, b in zip(group.nodes, buffers)]
    dispatches = sum(b.computes for b in buffers)
    return out, stats, dispatches


def _exec_group_batched(
    x_tiles: jax.Array,
    group: FusedGroup,
    convs: list,
    cfg: GraphConfig,
    interpret: bool,
    art: _GroupArtifacts,
    masks: list,
    dtype_bytes: int,
) -> tuple[jax.Array, list[LayerBufferStats], int]:
    """One batched dispatch per layer segment: DCN layers run the whole
    tile schedule as a single ``pallas_call`` grid (scalar-prefetched dep
    table -> scheduled DMA order, operands packed in the prepass), conv
    layers as one halo conv over the assembled plane; outputs scatter
    back to tile order in one op."""
    grid = art.grid
    h, w = grid.h, grid.w
    tp = grid.th * grid.tw
    num = grid.num_tiles
    masks_arr = jnp.stack(masks)                          # (T, tp, 1)
    last = group.n_layers - 1

    tiles = x_tiles
    stats: list[LayerBufferStats] = []
    dispatches = 0
    for j, node in enumerate(group.nodes):
        p = convs[node.param_idx]
        if isinstance(node, DeformNode):
            ld = art.packed[j]
            kk = node.kernel_size ** 2
            w2 = p.w.reshape(kk, node.c_in, node.c_out)
            y = dcn_fused_schedule(
                tiles, jnp.asarray(ld.dep_tbl), jnp.asarray(ld.dep_cnt),
                jnp.asarray(ld.idx), jnp.asarray(ld.coeff), w2, p.b,
                kernel_size=node.kernel_size, block_p=cfg.block_p,
                interpret=interpret)[:, :tp]
            if node.relu:
                y = jax.nn.relu(y)
            y = y * masks_arr[np.asarray(ld.out_order)]
            if j == last:
                # Scatter all scheduled outputs back to tile order at once.
                tiles = jnp.zeros((num, tp, node.c_out), y.dtype)
                tiles = tiles.at[jnp.asarray(ld.out_order)].set(y)
            else:
                tiles = y
            computed = len(ld.out_order)
        else:
            plane = tiles_to_plane(tiles, grid, h, w)
            yp = conv2d(plane[None], p["w"], p["b"])[0]
            if node.relu:
                yp = jax.nn.relu(yp)
            tiles = plane_to_tiles(yp, grid)
            computed = num
        dispatches += 1
        stats.append(LayerBufferStats(
            kind=node.kind, tiles_computed=computed, recomputes=0,
            max_resident_bytes=num * tp * node.c_out * dtype_bytes))
    return tiles, stats, dispatches


def _run_group(
    x_g: jax.Array,
    group: FusedGroup,
    convs: list,
    cfg: GraphConfig,
    interpret: bool,
    art: _GroupArtifacts,
) -> tuple[jax.Array, GroupTrace]:
    h, w, c_in = x_g.shape
    grid, sched = art.grid, art.sched
    tp = grid.th * grid.tw
    dtype_bytes = x_g.dtype.itemsize

    x_tiles = plane_to_tiles(x_g, grid)
    masks = [jnp.asarray(_tile_valid_mask(grid, t), x_g.dtype)
             for t in range(grid.num_tiles)]

    exec_fn = (_exec_group_batched if cfg.dispatch == "batched"
               else _exec_group_per_tile)
    y_tiles, layer_stats, dispatches = exec_fn(
        x_tiles, group, convs, cfg, interpret, art, masks, dtype_bytes)

    tile_bytes = tp * c_in * dtype_bytes
    trace = GroupTrace(
        grid=grid, tile_bytes=tile_bytes, buffer_tiles=art.m,
        schedule=cfg.schedule, schedule_cache_hit=art.cache_hit,
        schedule_backend=cfg.schedule_backend,
        dtype_bytes=dtype_bytes, layer_channels=group.layer_channels,
        output_bytes=h * w * group.c_out * dtype_bytes,
        weight_bytes=group_weight_bytes(group, dtype_bytes),
        b_layers=list(art.b_layers),
        kernel_dispatches=dispatches, dispatch=cfg.dispatch)
    trace.layer_stats = layer_stats
    for out_tile, loads in zip(sched.oid, sched.iid):
        trace.records.append(TileRecord(
            out_tile=out_tile,
            dep_tiles=tuple(loads),
            loaded_bytes=len(loads) * tile_bytes,
            buffer_bytes=len(loads) * tile_bytes))

    y = tiles_to_plane(y_tiles, grid, h, w)
    return y, trace


# ---------------------------------------------------------------------------
# Batch-fused dispatch: one kernel call per layer segment for the WHOLE batch.
# ---------------------------------------------------------------------------


def apply_boundary_batch(planes: jax.Array, node: Segment) -> jax.Array:
    """Batched :func:`apply_boundary_dense` — one op for all N images."""
    if isinstance(node, PoolNode):
        k = node.window
        return jax.lax.reduce_window(planes, -jnp.inf, jax.lax.max,
                                     (1, k, k, 1), (1, k, k, 1), "VALID")
    f = node.factor
    return jnp.repeat(jnp.repeat(planes, f, axis=1), f, axis=2)


def _advance_dense_batch(planes: jax.Array, node, p,
                         max_displacement: float | None) -> jax.Array:
    """Batched stage-1 chain advance (XLA, one dispatch for all images)."""
    if isinstance(node, DeformNode):
        y = deformable_conv2d(planes, p, node.kernel_size, node.variant,
                              max_displacement)
    else:
        y = conv2d(planes, p["w"], p["b"])
    return jax.nn.relu(y) if node.relu else y


@dataclasses.dataclass
class _ImageGroupSched:
    """One image's schedule bundle for one fused group, in dense
    dispatch form (the schedule-cache value for batch-fused mode)."""

    b_layers: list                        # per-layer TDTs (device or np)
    exec_scheds: list                     # per-layer DeviceSchedule | None:
    #   interior DCN layers dispatch in plane order over their own TDT
    #   rows; the LAST layer dispatches in the composite Algorithm-1
    #   order (its dep rows still come from its own TDT — the composite
    #   iid is the group-input load order the trace records).
    ds: DeviceSchedule                    # composite schedule (records)


@dataclasses.dataclass
class _BatchLayerOps:
    """One DCN layer's batch-fused operands (whole batch).

    Single-device: ``batch`` is a ``packing.BatchDispatch`` and
    idx/coeff are flat ``(N*T, p_pad, KK, 4)``. Sharded: ``shard`` is a
    ``shard.ShardedDispatch`` and idx/coeff carry a leading shard axis
    ``(D, n_max*T, p_pad, KK, 4)`` (shard-contiguous, zero-padded to the
    fullest shard).
    """

    batch: object                         # BatchDispatch | None
    idx: jax.Array
    coeff: jax.Array
    shard: object = None                  # ShardedDispatch | None


@dataclasses.dataclass
class _BatchGroupArtifacts:
    """Prepass products of one fused group for the WHOLE batch."""

    grid: TileGrid
    m: int
    bundles: list[_ImageGroupSched]
    cache_hits: list[bool | None]
    layer_ops: list[_BatchLayerOps | None]
    schedule_s: float = 0.0
    schedule_device_s: float = 0.0


def _group_batch_prepass(
    planes: jax.Array,                    # (N, H, W, C) dense chain state
    group: FusedGroup,
    convs: list,
    grid: TileGrid,
    m: int,
    cfg: GraphConfig,
    max_displacement: float | None,
    cache: ScheduleCache | None,
    need_out_plane: bool,
    interp: bool,
    tracer: Tracer | None = None,
    plan: ShardPlan | None = None,
) -> tuple[_BatchGroupArtifacts, jax.Array]:
    """Batch-level prepass for one group: the stage-1 chain runs batched
    (one XLA dispatch per layer for all images), per-image composite
    schedules are built in dense form (cached — partial batch hits skip
    scheduling for the hit images), and the per-layer batch operands are
    concatenated with per-image base offsets. With the device scheduling
    backend everything after the digest stays on-device. With a shard
    ``plan`` the per-layer operands concatenate PER SHARD (each shard
    keeps its own ragged padding) — per-image schedules themselves are
    built identically either way, so traces never depend on placement."""
    tr = tracer if tracer is not None else get_tracer()
    n = planes.shape[0]
    device = cfg.schedule_backend == "device" and cfg.schedule == "alg1"
    t_out = grid.num_tiles
    k_pad = pow2_pad(t_out)
    tp = grid.th * grid.tw
    bp = min(cfg.block_p, tp)
    p_pad = tp if tp % bp == 0 else round_up(tp, cfg.block_p)
    last = group.n_layers - 1

    needs_plane = [need_out_plane
                   or any(isinstance(nd, DeformNode)
                          for nd in group.nodes[j + 1:])
                   for j in range(group.n_layers)]
    plane = planes
    coords_layers: list = []
    for j, node in enumerate(group.nodes):
        p = convs[node.param_idx]
        if isinstance(node, DeformNode):
            offsets = conv2d(plane, p.w_off, p.b_off)
            coords_layers.append(offsets_to_coords(
                offsets.astype(jnp.float32), node.kernel_size,
                node.variant, max_displacement))
        else:
            coords_layers.append(None)
        if needs_plane[j]:
            plane = _advance_dense_batch(plane, node, p, max_displacement)

    def build_bundle(i: int) -> _ImageGroupSched:
        b_layers: list = []
        with tr.span("prepass.tdt", backend=cfg.schedule_backend,
                     image=i):
            for j, node in enumerate(group.nodes):
                if coords_layers[j] is None:
                    B = tdt_standard_conv(grid, grid, node.kernel_size)
                    b_layers.append(jnp.asarray(B) if device else B)
                elif device:
                    b_layers.append(tdt_from_coords_device(
                        coords_layers[j][i], grid, grid,
                        interpret=interp))
                else:
                    b_layers.append(np.asarray(tdt_from_coords(
                        coords_layers[j][i], grid, grid)))
        if device:
            comp = compose_tdt_chain_device(b_layers)
            ds = schedule_arrays_device(comp, m, k_pad=k_pad,
                                        interpret=interp)
        else:
            comp = compose_tdt_chain([np.asarray(b) for b in b_layers])
            if cfg.schedule == "alg1":
                sched = schedule_tiles(comp, m)
            elif cfg.schedule == "sequential":
                sched = sequential_schedule(comp)
            else:
                raise ValueError(f"unknown schedule: {cfg.schedule!r}")
            ds = DeviceSchedule.from_host(sched, t_out)
        exec_scheds: list = []
        for j, node in enumerate(group.nodes):
            if not isinstance(node, DeformNode):
                exec_scheds.append(None)
                continue
            dep_j, cnt_j = tdt_dispatch_arrays(jnp.asarray(b_layers[j]),
                                               k_pad)
            if j == last:
                oid = jnp.asarray(ds.oid).reshape(-1)
                sel = jnp.maximum(oid, 0)
                exec_scheds.append(DeviceSchedule(
                    oid, dep_j[sel],
                    jnp.where(oid >= 0, cnt_j[sel], 0),
                    jnp.zeros_like(oid)))
            else:
                ar = jnp.arange(t_out, dtype=jnp.int32)
                exec_scheds.append(DeviceSchedule(
                    ar, dep_j, cnt_j, jnp.zeros_like(ar)))
        return _ImageGroupSched(b_layers, exec_scheds, ds)

    bundles, hits = [], []
    with tr.timed("prepass.schedule", backend=cfg.schedule_backend,
                  batch=n) as ssp:
        for i in range(n):
            if cfg.faults is not None:
                cfg.faults.check("prepass", image=i)
            if cache is None:
                bundles.append(build_bundle(i))
                hits.append(None)
                continue
            digests = []
            for j, node in enumerate(group.nodes):
                if coords_layers[j] is None:
                    digests.append(conv_digest(node.kernel_size, grid))
                else:
                    digests.append(coords_digest(coords_layers[j][i],
                                                 grid))
            key = (chain_digest(digests, grid), grid.th, grid.tw, m,
                   cfg.schedule, "dense")
            if cfg.faults is not None:
                salt = cfg.faults.miss_salt()
                if salt is not None:
                    key = key + (salt,)
            bundle, hit = cache.get_or_build(key,
                                             lambda i=i: build_bundle(i))
            bundles.append(bundle)
            hits.append(hit)
        ssp.set(hits=sum(bool(h) for h in hits))
    schedule_s = ssp.dur
    if cache is not None:
        cache.note_batch_assembly(sum(bool(h) for h in hits),
                                  images=len(hits))

    layer_ops: list[_BatchLayerOps | None] = []
    with tr.span("pack", dispatch="batch_fused", batch=n,
                 layers=group.n_layers):
        for j, node in enumerate(group.nodes):
            if not isinstance(node, DeformNode):
                layer_ops.append(None)
                continue
            kk = node.kernel_size ** 2
            idx, coeff = jax.vmap(
                lambda c: pack_plane_operands(c, grid, p_pad)
            )(coords_layers[j])
            idx = idx.reshape(n * t_out, p_pad, kk, 4)
            coeff = coeff.reshape(n * t_out, p_pad, kk, 4)
            scheds = [bundles[i].exec_scheds[j] for i in range(n)]
            if plan is not None:
                layer_ops.append(_BatchLayerOps(
                    None,
                    stack_rows(idx, plan, t_out),
                    stack_rows(coeff, plan, t_out),
                    shard=shard_batch_schedules(scheds, t_out, t_out,
                                                plan)))
            else:
                layer_ops.append(_BatchLayerOps(
                    pack_batch_schedules(scheds, t_out, t_out),
                    idx, coeff))

    art = _BatchGroupArtifacts(
        grid=grid, m=m, bundles=bundles, cache_hits=hits,
        layer_ops=layer_ops, schedule_s=schedule_s,
        schedule_device_s=schedule_s if device else 0.0)
    return art, plane


def _exec_group_batch_fused(
    planes: jax.Array,                    # (N, H, W, C_in)
    group: FusedGroup,
    convs: list,
    cfg: GraphConfig,
    interpret: bool,
    art: _BatchGroupArtifacts,
    mesh=None,
    plan: ShardPlan | None = None,
) -> tuple[jax.Array, int]:
    """Execute one fused group for the whole batch: ONE dispatch per
    layer segment (the batch-fused kernel for DCN layers, one batched
    XLA conv for standard layers). With ``mesh``/``plan`` each DCN
    segment stacks its tile rows into per-shard slabs, dispatches the
    shard_map kernel, and unstacks the scattered result — everything
    else (conv segments, plane assembly) runs on the TRUE batch with
    exactly the single-device shapes, so sharded results are bit-equal
    to the unsharded run (XLA convs can change reduction order with
    batch size; never giving them a padded pseudo-batch avoids that)."""
    n = planes.shape[0]
    if cfg.faults is not None:
        cfg.faults.check("dispatch", images=plan.n if plan else n)
    grid = art.grid
    h, w = grid.h, grid.w
    tp = grid.th * grid.tw
    t = grid.num_tiles
    masks_arr = jnp.stack(
        [jnp.asarray(_tile_valid_mask(grid, ti), planes.dtype)
         for ti in range(t)])                               # (T, tp, 1)
    last = group.n_layers - 1

    flat = jax.vmap(
        lambda p: plane_to_tiles(p, grid))(planes).reshape(n * t, tp, -1)
    dispatches = 0
    for j, node in enumerate(group.nodes):
        p = convs[node.param_idx]
        if isinstance(node, DeformNode):
            ops = art.layer_ops[j]
            kk = node.kernel_size ** 2
            w2 = p.w.reshape(kk, node.c_in, node.c_out)
            if plan is not None:
                sh = ops.shard
                d = plan.n_shards
                slab = plan.n_max * t
                y = dcn_fused_batch_sharded(
                    stack_rows(flat, plan, t), sh.row_id, sh.dep_glb,
                    sh.dep_cnt, ops.idx, ops.coeff, w2, p.b, mesh=mesh,
                    t_in=t, kernel_size=node.kernel_size,
                    block_p=cfg.block_p, interpret=interpret)[:, :, :tp]
                if node.relu:
                    y = jax.nn.relu(y)
                y = y * masks_arr[jnp.maximum(sh.oid, 0)]
                # Scatter each shard's scheduled rows back to shard-
                # local (image, tile) order — padding rows (ragged
                # schedules or shard-size fill) land in a dropped per-
                # shard dump row — then unstack to true batch rows.
                target = jnp.where(sh.oid >= 0, sh.row_id, slab)
                y_all = jnp.zeros((d, slab + 1, tp, node.c_out), y.dtype)
                y_all = jax.vmap(lambda ya, tg, yy: ya.at[tg].set(yy))(
                    y_all, target, y)
                flat = unstack_rows(y_all[:, :-1], plan, t)
            else:
                y = dcn_fused_batch(
                    flat, ops.batch.row_id, ops.batch.dep_glb,
                    ops.batch.dep_cnt, ops.idx, ops.coeff, w2, p.b,
                    t_in=t, kernel_size=node.kernel_size,
                    block_p=cfg.block_p, interpret=interpret)[:, :tp]
                if node.relu:
                    y = jax.nn.relu(y)
                y = y * masks_arr[jnp.maximum(ops.batch.oid, 0)]
                if j == last:
                    # Scatter scheduled rows back to (image, tile) order;
                    # ragged-padding rows fall into a dropped dump row.
                    target = jnp.where(ops.batch.oid >= 0,
                                       ops.batch.row_id, n * t)
                    y_all = jnp.zeros((n * t + 1, tp, node.c_out),
                                      y.dtype)
                    flat = y_all.at[target].set(y)[:-1]
                else:
                    flat = y         # rows already in (img, tile) order
        else:
            pl_j = jax.vmap(lambda ti: tiles_to_plane(ti, grid, h, w))(
                flat.reshape(n, t, tp, node.c_in))
            yp = conv2d(pl_j, p["w"], p["b"])
            if node.relu:
                yp = jax.nn.relu(yp)
            flat = jax.vmap(
                lambda pj: plane_to_tiles(pj, grid))(yp).reshape(
                    n * t, tp, node.c_out)
        dispatches += 1
    out = jax.vmap(lambda ti: tiles_to_plane(ti, grid, h, w))(
        flat.reshape(n, t, tp, group.c_out))
    return out, dispatches


def _batch_fused_group_traces(
    group: FusedGroup,
    art: _BatchGroupArtifacts,
    cfg: GraphConfig,
    dtype_bytes: int,
    group_idx: int,
) -> list[GroupTrace]:
    """Per-image GroupTraces of one batch-fused group — lazy host
    assembly of the composite schedules, OFF the hot path."""
    grid = art.grid
    tp = grid.th * grid.tw
    t = grid.num_tiles
    tile_bytes = tp * group.c_in * dtype_bytes
    traces = []
    for i, bundle in enumerate(art.bundles):
        sched = bundle.ds.to_host()
        gt = GroupTrace(
            grid=grid, tile_bytes=tile_bytes, buffer_tiles=art.m,
            schedule=cfg.schedule, schedule_cache_hit=art.cache_hits[i],
            schedule_backend=cfg.schedule_backend,
            dispatch="batch_fused", batch_rows=(i * t, (i + 1) * t),
            dtype_bytes=dtype_bytes, layer_channels=group.layer_channels,
            output_bytes=grid.h * grid.w * group.c_out * dtype_bytes,
            weight_bytes=group_weight_bytes(group, dtype_bytes),
            b_layers=[np.asarray(b) for b in bundle.b_layers],
            kernel_dispatches=0)
        gt.image, gt.group = i, group_idx
        gt.layer_stats = [LayerBufferStats(
            kind=nd.kind,
            tiles_computed=(len(sched.oid) if j == group.n_layers - 1
                            and isinstance(nd, DeformNode) else t),
            recomputes=0,
            max_resident_bytes=t * tp * nd.c_out * dtype_bytes)
            for j, nd in enumerate(group.nodes)]
        for out_tile, loads in zip(sched.oid, sched.iid):
            gt.records.append(TileRecord(
                out_tile=out_tile, dep_tiles=tuple(loads),
                loaded_bytes=len(loads) * tile_bytes,
                buffer_bytes=len(loads) * tile_bytes))
        traces.append(gt)
    return traces


def _run_graph_batch_fused(
    convs: list,
    segments: list[Segment],
    x: jax.Array,
    cfg: GraphConfig,
    interpret: bool,
    cache: ScheduleCache | None,
    max_displacement: float | None,
    trace: NetworkTrace,
    return_trace: bool,
    tracer: Tracer | None = None,
    mesh=None,
    shard_sizes=None,
) -> jax.Array:
    """Batch-fused graph execution: the staging unit is a SEGMENT of the
    whole batch (not an image) — segment s+1's batch prepass overlaps
    segment s's execution on the staging thread.

    With a ``mesh`` every DCN segment dispatches through the shard_map
    kernel over per-shard row slabs (see ``_exec_group_batch_fused``);
    the prepass chain and all dense segments stay on the TRUE batch, so
    schedules, traces and numerics are identical to the single-device
    run. The modeled collective is the one logits all-gather."""
    tr = tracer if tracer is not None else get_tracer()
    n = x.shape[0]
    th, tw = cfg.tile_hw
    itemsize = x.dtype.itemsize
    plan = None
    if mesh is not None:
        d = dict(mesh.shape)["data"]
        plan = plan_batch_shards(n, d, shard_sizes)

    deform_after = [False] * len(segments)
    seen = False
    for s in range(len(segments) - 1, -1, -1):
        deform_after[s] = seen
        if isinstance(segments[s], FusedGroup) and any(
                isinstance(nd, DeformNode) for nd in segments[s].nodes):
            seen = True

    # The dense stage-1 chain state, advanced sequentially by the prepass
    # (run_staged's single worker preserves submission order). The epoch
    # guard exists for watchdog failover: after a stuck worker is
    # abandoned and the same segment re-runs synchronously, the worker
    # may still wake and finish — its read is rejected (epoch moved on)
    # or its commit is discarded, so the chain state can never regress
    # or double-advance.
    pre_lock = threading.Lock()
    pre_state = {"plane": x, "epoch": 0}

    def prepass(s: int):
        seg = segments[s]
        with pre_lock:
            if pre_state["epoch"] != s:
                return None        # stale duplicate from an abandoned worker
            plane_in = pre_state["plane"]
        if isinstance(seg, (PoolNode, UpsampleNode)):
            art = None
            plane = (apply_boundary_batch(plane_in, seg)
                     if deform_after[s] else plane_in)
        else:
            grid = _segment_grid(seg, th, tw)
            m = (grid.num_tiles if cfg.buffer_tiles is None
                 else cfg.buffer_tiles)
            art, plane = _group_batch_prepass(
                plane_in, seg, convs, grid, m, cfg, max_displacement,
                cache, need_out_plane=deform_after[s], interp=interpret,
                tracer=tr, plan=plan)
        with pre_lock:
            if pre_state["epoch"] == s:
                pre_state["plane"] = plane
                pre_state["epoch"] = s + 1
        return art

    exec_state = {"plane": x, "group": 0}
    pending: list[GroupTrace] = []

    def execute(s: int, art):
        seg = segments[s]
        if art is None:
            exec_state["plane"] = apply_boundary_batch(exec_state["plane"],
                                                       seg)
            trace.boundary_bytes += n * boundary_bytes(seg, itemsize)
            return None
        planes, dispatches = _exec_group_batch_fused(
            exec_state["plane"], seg, convs, cfg, interpret, art,
            mesh=mesh, plan=plan)
        exec_state["plane"] = planes
        trace.batch_dispatches += dispatches
        trace.overlap.schedule_s += art.schedule_s
        trace.overlap.schedule_device_s += art.schedule_device_s
        if return_trace:
            pending.extend(_batch_fused_group_traces(
                seg, art, cfg, itemsize, exec_state["group"]))
        exec_state["group"] += 1
        return None

    run_staged(len(segments), prepass, execute, cfg.staging_depth,
               trace.overlap, tracer=tr, watchdog_s=cfg.watchdog_s,
               faults=cfg.faults)
    # Keep trace.groups image-major like the per-image executors.
    pending.sort(key=lambda g: (g.image, g.group))
    trace.groups.extend(pending)
    out = exec_state["plane"]
    if plan is not None:
        # Modeled collective traffic: each replica keeps its local rows
        # until the logits, which cross once (the executor's per-layer
        # host gathers are simulation plumbing, not modeled DRAM).
        trace.shards = plan.n_shards
        trace.allgather_bytes += allgather_nbytes(out)
    return out


def run_graph(
    convs: list,
    graph: NetGraph,
    x: jax.Array,
    *,
    config: GraphConfig | None = None,
    max_displacement: float | None = None,
    return_trace: bool = False,
    schedule_cache: ScheduleCache | None = None,
    tracer: Tracer | None = None,
    shard_sizes=None,
    tuned_plan="auto",
):
    """Execute a backbone graph over a batch: (N,H,W,C) -> (N,H',W',C').

    ``convs`` is the per-node parameter list (``params["convs"]`` of the
    DCN models): ``DeformableConvParams`` for DeformNodes, ``{"w", "b"}``
    dicts for ConvNodes. Numerically matches :func:`run_graph_dense` (the
    XLA reference) to float tolerance; with ``return_trace`` additionally
    returns the :class:`NetworkTrace` of the executed DRAM traffic.

    With ``staging_depth > 1`` (the default) image i+1's host prepass
    runs on a worker thread while image i's kernels execute — the trace's
    ``host_overlap_frac`` reports how much host time was hidden.
    ``schedule_cache`` overrides the process-wide cache (serving engines
    pass their own). ``tracer`` routes span tracing (``prepass.*``,
    ``pack``, ``dispatch.*``) into an enabled :class:`~repro.obs.Tracer`;
    default is the current ``repro.obs.get_tracer()`` (a no-op unless
    enabled or overridden via ``use_tracer``).

    With ``config.mesh`` / ``config.data_parallel`` (batch_fused only)
    the batch dimension shards over the mesh's ``"data"`` axis;
    ``shard_sizes`` pins an explicit per-shard image count (the serving
    engine's replica placement — must sum to N, zeros allowed). Traces
    are placement-independent: per-image schedules and records are built
    exactly as on a single device.

    With ``config.autotune`` enabled the partition and per-group tile
    shapes come from the simulator-guided tuner (``repro.tuning``):
    ``tuned_plan="auto"`` resolves through the plan cache per the config
    knobs; pass a ``TunedPlan`` (or None for explicitly-greedy) to skip
    resolution — the serving engine resolves once at construction and
    replays the same plan on every step and replica. Executed traces
    stay exactly equal to the DRAM simulator under any tuned plan.
    """
    if isinstance(x, jax.core.Tracer):
        raise ValueError(
            "run_graph is a host-driven, forward-only executor: the "
            "cross-layer schedule is data-dependent, so it cannot run "
            "under jit/grad/vmap. Use backend='xla' for those paths.")
    cfg = config or GraphConfig()
    if tuple(x.shape[1:]) != (graph.in_h, graph.in_w, graph.in_c):
        raise ValueError(
            f"input {tuple(x.shape[1:])} does not match the graph's "
            f"({graph.in_h}, {graph.in_w}, {graph.in_c}) input plane — "
            f"rebuild the graph for this image size")
    th, tw = cfg.tile_hw
    if th > graph.in_h or tw > graph.in_w:
        raise ValueError(
            f"tile {th}x{tw} exceeds the {graph.in_h}x{graph.in_w} input "
            f"plane — a degenerate 1-tile grid; choose tile sides <= the "
            f"plane (interior groups at lower resolution are clamped "
            f"automatically)")
    interpret = resolve_interpret(cfg.interpret)
    tr = tracer if tracer is not None else get_tracer()
    if schedule_cache is not None:
        cache: ScheduleCache | None = schedule_cache
    else:
        cache = default_schedule_cache() if cfg.use_schedule_cache else None
    trace = NetworkTrace()
    n = x.shape[0]
    if n == 0:
        h, w, c = graph.out_shape
        y = jnp.zeros((0, h, w, c), x.dtype)
        return (y, trace) if return_trace else y

    # "auto": resolve per cfg.autotune (cache-through; "offline" may pay
    # a search on first use). Callers that already hold a plan — the
    # serving engine resolves once at construction — pass it (or None
    # for explicitly-greedy) so the hot path never re-resolves.
    if tuned_plan == "auto":
        tuned_plan = None
        if cfg.autotune != "off":
            from repro.tuning import resolve_tuned_plan
            tuned_plan = resolve_tuned_plan(
                convs, graph, autotune=cfg.autotune,
                onchip_budget_bytes=cfg.onchip_budget_bytes,
                dtype_bytes=x.dtype.itemsize, tile_hw=cfg.tile_hw,
                buffer_tiles=cfg.buffer_tiles, schedule=cfg.schedule,
                batch=n, budget=cfg.autotune_budget,
                plan_cache_dir=cfg.plan_cache_dir,
                max_displacement=max_displacement, tracer=tr)
    segments = partition_graph_cached(graph, cfg.onchip_budget_bytes,
                                      dtype_bytes=x.dtype.itemsize,
                                      autotune=cfg.autotune,
                                      tuned=tuned_plan)

    mesh = resolve_shard_mesh(cfg.mesh, cfg.data_parallel)
    if shard_sizes is not None and mesh is None:
        raise ValueError(
            "shard_sizes= requires a sharded config (mesh= or "
            "data_parallel= with a data axis > 1)")
    if cfg.dispatch == "batch_fused":
        with use_tracer(tr):
            y = _run_graph_batch_fused(convs, segments, x, cfg, interpret,
                                       cache, max_displacement, trace,
                                       return_trace, tracer=tr, mesh=mesh,
                                       shard_sizes=shard_sizes)
        return (y, trace) if return_trace else y

    def prepass(i: int):
        if cfg.faults is not None:
            cfg.faults.check("prepass", image=i)
        return _image_prepass(x[i], segments, convs, cfg, max_displacement,
                              cache, interp=interpret, tracer=tr)

    def execute_image(i: int, arts) -> jax.Array:
        if cfg.faults is not None:
            cfg.faults.check("dispatch", image=i)
        plane = x[i]
        g = 0
        for seg, art in zip(segments, arts):
            if art is None:
                plane = apply_boundary_dense(plane, seg)
                trace.boundary_bytes += boundary_bytes(seg,
                                                       x.dtype.itemsize)
            else:
                plane, gt = _run_group(plane, seg, convs, cfg, interpret,
                                       art)
                gt.image, gt.group = i, g
                g += 1
                trace.overlap.schedule_s += art.schedule_s
                trace.overlap.schedule_device_s += art.schedule_device_s
                trace.groups.append(gt)
        return plane

    with use_tracer(tr):
        outs = run_staged(n, prepass, execute_image, cfg.staging_depth,
                          trace.overlap, tracer=tr,
                          watchdog_s=cfg.watchdog_s, faults=cfg.faults)
    y = jnp.stack(outs)
    return (y, trace) if return_trace else y


def network_sim_specs(trace: NetworkTrace) -> list[dict]:
    """Rebuild ``core.simulator.simulate_network`` group specs from an
    executed trace — byte-identical TDT inputs, so the fused prediction
    must equal the executed FIFO replay exactly."""
    specs = []
    for gt in trace.groups:
        specs.append(dict(
            b_layers=gt.b_layers,
            grid=gt.grid,
            layer_channels=gt.layer_channels,
            weight_bytes=gt.weight_bytes,
            buffer_tiles=gt.buffer_tiles,
            dtype_bytes=gt.dtype_bytes,
            schedule=gt.schedule,
        ))
    return specs
