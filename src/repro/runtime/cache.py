"""LRU cache for host-side tile schedules, keyed on quantized coordinates.

Building the TDT (a jnp scatter) and running Algorithm 1 (a Python loop)
per image is the executor's host-side cost. Both depend on the sampling
coordinates only through their *clipped integer floors* — the quantity the
paper's boundary comparator (Fig. 9) decodes — so two inputs whose floors
agree produce byte-identical TDTs and schedules. The cache key is a digest
of that quantization (exact, not lossy: a floor flip changes the key), so
repeated inputs — benchmark loops, serving replays — skip the rebuild
entirely. Hit/miss counters surface on ``PipelineTrace``/``NetworkTrace``.
"""

from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from typing import Any, Callable, Hashable

import numpy as np

from repro.core.tiles import TileGrid


def coords_digest(coords: Any, grid: TileGrid) -> str:
    """Digest of the clipped floor quantization of sampling coordinates.

    ``coords`` is (..., 2) float (row, col). The TDT depends only on
    clip(floor(r), 0, h-1) / clip(floor(c), 0, w-1) (the +1 neighbours are
    determined by these), so the digest is an exact schedule key.
    """
    c = np.asarray(coords)
    r0 = np.clip(np.floor(c[..., 0]), 0, grid.h - 1).astype(np.int32)
    c0 = np.clip(np.floor(c[..., 1]), 0, grid.w - 1).astype(np.int32)
    h = hashlib.sha1()
    h.update(repr(tuple(grid)).encode())
    h.update(np.ascontiguousarray(r0).tobytes())
    h.update(np.ascontiguousarray(c0).tobytes())
    return h.hexdigest()


def conv_digest(kernel_size: int, grid: TileGrid) -> str:
    """Static key for a standard-conv layer's TDT (no data dependence)."""
    return f"conv:k{kernel_size}:{tuple(grid)}"


def chain_digest(layer_digests: list[str], grid: TileGrid) -> str:
    """Key for a cross-layer composite schedule: the group's layer chain."""
    h = hashlib.sha1()
    h.update(repr(tuple(grid)).encode())
    for d in layer_digests:
        h.update(d.encode())
    return h.hexdigest()


class ScheduleCache:
    """Bounded LRU mapping schedule keys -> prebuilt schedule artifacts.

    Thread-safe: the multi-image staging queue runs prepass (and therefore
    cache lookups) on a worker thread while the main thread dispatches.
    """

    def __init__(self, maxsize: int = 128):
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        self.maxsize = int(maxsize)
        self._entries: OrderedDict[Hashable, Any] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        # Batch-fused accounting: per-image lookups inside a batch
        # assembly (a partial batch hit = some images skip scheduling
        # while the misses are built and spliced into the batch grid).
        # ``image_lookups`` counts every per-image membership check so
        # the hit accounting stays a rate even when a serving engine
        # coalesces dynamically sized slot batches.
        self.image_hits = 0
        self.image_lookups = 0
        self.batch_assemblies = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: Hashable) -> Any | None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return entry

    def put(self, key: Hashable, value: Any) -> None:
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)

    def get_or_build(self, key: Hashable, build: Callable[[], Any]
                     ) -> tuple[Any, bool]:
        """Return (value, was_hit); builds and inserts on miss."""
        value = self.get(key)
        if value is not None:
            return value, True
        value = build()
        self.put(key, value)
        return value, False

    def note_batch_assembly(self, image_hits: int,
                            images: int = 0) -> None:
        """Record one batch-grid assembly: how many of its ``images``
        were served from the cache (partial batch hits)."""
        with self._lock:
            self.batch_assemblies += 1
            self.image_hits += int(image_hits)
            self.image_lookups += int(images)

    @property
    def image_hit_rate(self) -> float:
        """Per-image hit rate across batch assemblies (coalesced slot
        batches count each admitted image once)."""
        with self._lock:
            if not self.image_lookups:
                return 0.0
            return self.image_hits / self.image_lookups

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = 0
            self.misses = 0
            self.image_hits = 0
            self.image_lookups = 0
            self.batch_assemblies = 0

    def info(self) -> dict[str, int]:
        with self._lock:
            return {"size": len(self._entries), "maxsize": self.maxsize,
                    "hits": self.hits, "misses": self.misses,
                    "image_hits": self.image_hits,
                    "image_lookups": self.image_lookups,
                    "batch_assemblies": self.batch_assemblies}

    def publish(self, registry, prefix: str = "schedule_cache") -> None:
        """Mirror the cache counters into a
        :class:`repro.obs.MetricsRegistry` as gauges (plus the derived
        hit rates), so ``registry.snapshot()`` carries the cache state
        alongside the rest of the telemetry."""
        info = self.info()
        for k, v in info.items():
            registry.gauge(f"{prefix}.{k}").set(v)
        lookups = info["hits"] + info["misses"]
        registry.gauge(f"{prefix}.hit_rate").set(
            info["hits"] / lookups if lookups else 0.0)
        registry.gauge(f"{prefix}.image_hit_rate").set(
            info["image_hits"] / info["image_lookups"]
            if info["image_lookups"] else 0.0)


_DEFAULT_CACHE = ScheduleCache(maxsize=128)


def default_schedule_cache() -> ScheduleCache:
    """The process-wide cache the executors use unless given their own."""
    return _DEFAULT_CACHE
