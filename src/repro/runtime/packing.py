"""Host-side tile packing for the pipeline executor.

The fused Pallas kernel (``kernels.dcn_fused``) consumes a flat packed
input buffer ``x_packed (S, C)`` plus per-output-pixel ``(idx, coeff)``
tensors whose indices address *that buffer* — the software analogue of the
paper's on-chip input buffer and address converter (Eq. 4): global
``(row, col)`` sample coordinates are rewritten into buffer-local
addresses ``slot(tile) * tile_pixels + offset_in_tile``.

Shapes that do not divide by the tile size are handled by padding the
feature plane up to ``rows*th x cols*tw``: sampling coordinates are
clamped in-range upstream (``core.deform.offsets_to_coords``), so padded
pixels are never addressed, and padded *output* pixels are packed with
``coeff = 0`` and discarded on scatter.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deform import bli_coefficients
from repro.core.tiles import TileGrid


def plane_to_tiles(x: jax.Array, grid: TileGrid) -> jax.Array:
    """(H, W, C) -> (num_tiles, th*tw, C), zero-padded to the tile grid."""
    h, w, c = x.shape
    hp, wp = grid.rows * grid.th, grid.cols * grid.tw
    if (hp, wp) != (h, w):
        x = jnp.pad(x, ((0, hp - h), (0, wp - w), (0, 0)))
    x = x.reshape(grid.rows, grid.th, grid.cols, grid.tw, c)
    return x.transpose(0, 2, 1, 3, 4).reshape(grid.num_tiles,
                                              grid.th * grid.tw, c)


def tiles_to_plane(y_tiles: jax.Array, grid: TileGrid, h: int, w: int,
                   ) -> jax.Array:
    """(num_tiles, th*tw, C) -> (H, W, C): inverse of ``plane_to_tiles``."""
    c = y_tiles.shape[-1]
    y = y_tiles.reshape(grid.rows, grid.cols, grid.th, grid.tw, c)
    y = y.transpose(0, 2, 1, 3, 4).reshape(grid.rows * grid.th,
                                           grid.cols * grid.tw, c)
    return y[:h, :w]


class NeighbourTables(NamedTuple):
    """Per-pixel BLI neighbour data in host memory (one image).

    All arrays are (H, W, KK, 4) over the 4 integer-grid neighbours in the
    order (r0,c0) (r0,c1) (r1,c0) (r1,c1) — matching Eq. 5 / the kernels.
    """

    tile_id: np.ndarray   # int32 input-tile id of each neighbour
    offset: np.ndarray    # int32 raster offset of the neighbour in its tile
    coeff: np.ndarray     # float32 BLI coefficients (eta, theta, mu, gamma)


def build_neighbour_tables(coords: jax.Array, grid: TileGrid,
                           ) -> NeighbourTables:
    """coords (H, W, KK, 2) float -> host-side neighbour tables.

    Uses the exact clipping/coefficient rules of the XLA reference
    (``core.deform.bilinear_sample``) so the pipeline is bit-compatible
    with it up to matmul association order.
    """
    floor_rc, coeffs = bli_coefficients(coords)
    floor_rc = np.asarray(floor_rc)
    r0 = np.clip(floor_rc[..., 0], 0, grid.h - 1)
    c0 = np.clip(floor_rc[..., 1], 0, grid.w - 1)
    r1 = np.clip(r0 + 1, 0, grid.h - 1)
    c1 = np.clip(c0 + 1, 0, grid.w - 1)
    nb_r = np.stack([r0, r0, r1, r1], axis=-1)
    nb_c = np.stack([c0, c1, c0, c1], axis=-1)
    tile_id = (nb_r // grid.th) * grid.cols + (nb_c // grid.tw)
    offset = (nb_r % grid.th) * grid.tw + (nb_c % grid.tw)
    return NeighbourTables(tile_id.astype(np.int32),
                           offset.astype(np.int32),
                           np.asarray(coeffs, np.float32))


def pack_output_tile(
    nb: NeighbourTables,
    grid: TileGrid,
    out_tile: int,
    dep_tiles: list[int],
    p_pad: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Build the kernel's (idx, coeff) operands for one output tile.

    Rewrites each neighbour's global (tile_id, offset) into an address in
    the packed buffer that concatenates ``dep_tiles`` in load order:
    ``slot * tile_pixels + offset``. Output pixels beyond the real plane
    (tile overhangs the H x W extent) get ``coeff = 0`` so they contribute
    zeros that the scatter discards.

    Returns idx (p_pad, KK, 4) int32 and coeff (p_pad, KK, 4) float32.
    """
    th, tw, cols = grid.th, grid.tw, grid.cols
    tp = th * tw
    kk = nb.coeff.shape[2]

    slot = np.zeros(grid.num_tiles, np.int32)
    slot[np.asarray(dep_tiles, np.int64)] = np.arange(len(dep_tiles),
                                                      dtype=np.int32)

    tr, tc = divmod(out_tile, cols)
    rr = np.arange(tr * th, (tr + 1) * th)
    cc = np.arange(tc * tw, (tc + 1) * tw)
    valid = (rr[:, None] < grid.h) & (cc[None, :] < grid.w)    # (th, tw)
    rr_c = np.minimum(rr, grid.h - 1)
    cc_c = np.minimum(cc, grid.w - 1)

    t_ids = nb.tile_id[rr_c][:, cc_c]                          # (th,tw,KK,4)
    offs = nb.offset[rr_c][:, cc_c]
    cfs = nb.coeff[rr_c][:, cc_c] * valid[..., None, None]

    # TDT guarantee: every neighbour tile of a real pixel in ``out_tile``
    # is in ``dep_tiles``; padded pixels carry coeff 0 and may point at
    # slot 0 harmlessly.
    idx = slot[t_ids] * tp + offs
    idx = np.where(valid[..., None, None], idx, 0).astype(np.int32)

    idx = idx.reshape(tp, kk, 4)
    cfs = cfs.reshape(tp, kk, 4).astype(np.float32)
    if p_pad != tp:
        idx = np.pad(idx, ((0, p_pad - tp), (0, 0), (0, 0)))
        cfs = np.pad(cfs, ((0, p_pad - tp), (0, 0), (0, 0)))
    return idx, cfs


def pack_schedule_tiles(
    nb: NeighbourTables,
    grid: TileGrid,
    out_tiles,
    dep_lists,
    p_pad: int,
    k_pad: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Group-level packing: the batched grid kernel's operands for a whole
    schedule at once (``kernels.dcn_fused.dcn_fused_schedule``).

    ``out_tiles``/``dep_lists`` are the schedule: per scheduled output tile
    its dependent input tiles. Stacks :func:`pack_output_tile` over the
    schedule and emits the dep table + counts the kernel's scalar-prefetch
    machinery consumes:

      dep_tbl (T, k_pad) int32  — dep tile ids, zero-padded; padding slots
                                  are never addressed because packed
                                  addresses only reach slot < len(deps),
                                  and the kernel skips them via dep_cnt.
                                  An empty dep list zeroes the whole coeff
                                  row (its row contributes bias only —
                                  schedules never contain dep-less tiles).
      dep_cnt (T,)       int32  — true dep count per scheduled tile
      idx     (T, p_pad, KK, 4) int32
      coeff   (T, p_pad, KK, 4) float32
    """
    kk = nb.coeff.shape[2]
    t = len(out_tiles)
    dep_tbl = np.zeros((t, k_pad), np.int32)
    dep_cnt = np.zeros((t,), np.int32)
    idx = np.zeros((t, p_pad, kk, 4), np.int32)
    coeff = np.zeros((t, p_pad, kk, 4), np.float32)
    for n, (tile, deps) in enumerate(zip(out_tiles, dep_lists)):
        deps = [int(d) for d in deps]
        if len(deps) > k_pad:
            raise ValueError(f"{len(deps)} deps exceed k_pad={k_pad}")
        if not deps:
            continue          # all-zero coeff row: the dispatch contributes
                              # bias only (schedules never emit such tiles)
        i, c = pack_output_tile(nb, grid, int(tile), deps, p_pad)
        idx[n], coeff[n] = i, c
        dep_tbl[n, :len(deps)] = deps
        dep_cnt[n] = len(deps)
    return dep_tbl, dep_cnt, idx, coeff
