"""Host-side tile packing for the pipeline executor.

The fused Pallas kernel (``kernels.dcn_fused``) consumes a flat packed
input buffer ``x_packed (S, C)`` plus per-output-pixel ``(idx, coeff)``
tensors whose indices address *that buffer* — the software analogue of the
paper's on-chip input buffer and address converter (Eq. 4): global
``(row, col)`` sample coordinates are rewritten into buffer-local
addresses ``slot(tile) * tile_pixels + offset_in_tile``.

Shapes that do not divide by the tile size are handled by padding the
feature plane up to ``rows*th x cols*tw``: sampling coordinates are
clamped in-range upstream (``core.deform.offsets_to_coords``), so padded
pixels are never addressed, and padded *output* pixels are packed with
``coeff = 0`` and discarded on scatter.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deform import bli_coefficients
from repro.core.scheduler import DeviceSchedule
from repro.core.tiles import TileGrid
from repro.obs import get_tracer


def plane_to_tiles(x: jax.Array, grid: TileGrid) -> jax.Array:
    """(H, W, C) -> (num_tiles, th*tw, C), zero-padded to the tile grid."""
    h, w, c = x.shape
    hp, wp = grid.rows * grid.th, grid.cols * grid.tw
    if (hp, wp) != (h, w):
        x = jnp.pad(x, ((0, hp - h), (0, wp - w), (0, 0)))
    x = x.reshape(grid.rows, grid.th, grid.cols, grid.tw, c)
    return x.transpose(0, 2, 1, 3, 4).reshape(grid.num_tiles,
                                              grid.th * grid.tw, c)


def tiles_to_plane(y_tiles: jax.Array, grid: TileGrid, h: int, w: int,
                   ) -> jax.Array:
    """(num_tiles, th*tw, C) -> (H, W, C): inverse of ``plane_to_tiles``."""
    c = y_tiles.shape[-1]
    y = y_tiles.reshape(grid.rows, grid.cols, grid.th, grid.tw, c)
    y = y.transpose(0, 2, 1, 3, 4).reshape(grid.rows * grid.th,
                                           grid.cols * grid.tw, c)
    return y[:h, :w]


class NeighbourTables(NamedTuple):
    """Per-pixel BLI neighbour data in host memory (one image).

    All arrays are (H, W, KK, 4) over the 4 integer-grid neighbours in the
    order (r0,c0) (r0,c1) (r1,c0) (r1,c1) — matching Eq. 5 / the kernels.
    """

    tile_id: np.ndarray   # int32 input-tile id of each neighbour
    offset: np.ndarray    # int32 raster offset of the neighbour in its tile
    coeff: np.ndarray     # float32 BLI coefficients (eta, theta, mu, gamma)


def build_neighbour_tables(coords: jax.Array, grid: TileGrid,
                           ) -> NeighbourTables:
    """coords (H, W, KK, 2) float -> host-side neighbour tables.

    Uses the exact clipping/coefficient rules of the XLA reference
    (``core.deform.bilinear_sample``) so the pipeline is bit-compatible
    with it up to matmul association order.
    """
    floor_rc, coeffs = bli_coefficients(coords)
    floor_rc = np.asarray(floor_rc)
    r0 = np.clip(floor_rc[..., 0], 0, grid.h - 1)
    c0 = np.clip(floor_rc[..., 1], 0, grid.w - 1)
    r1 = np.clip(r0 + 1, 0, grid.h - 1)
    c1 = np.clip(c0 + 1, 0, grid.w - 1)
    nb_r = np.stack([r0, r0, r1, r1], axis=-1)
    nb_c = np.stack([c0, c1, c0, c1], axis=-1)
    tile_id = (nb_r // grid.th) * grid.cols + (nb_c // grid.tw)
    offset = (nb_r % grid.th) * grid.tw + (nb_c % grid.tw)
    return NeighbourTables(tile_id.astype(np.int32),
                           offset.astype(np.int32),
                           np.asarray(coeffs, np.float32))


def pack_output_tile(
    nb: NeighbourTables,
    grid: TileGrid,
    out_tile: int,
    dep_tiles: list[int],
    p_pad: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Build the kernel's (idx, coeff) operands for one output tile.

    Rewrites each neighbour's global (tile_id, offset) into an address in
    the packed buffer that concatenates ``dep_tiles`` in load order:
    ``slot * tile_pixels + offset``. Output pixels beyond the real plane
    (tile overhangs the H x W extent) get ``coeff = 0`` so they contribute
    zeros that the scatter discards.

    Returns idx (p_pad, KK, 4) int32 and coeff (p_pad, KK, 4) float32.
    """
    th, tw, cols = grid.th, grid.tw, grid.cols
    tp = th * tw
    kk = nb.coeff.shape[2]

    slot = np.zeros(grid.num_tiles, np.int32)
    slot[np.asarray(dep_tiles, np.int64)] = np.arange(len(dep_tiles),
                                                      dtype=np.int32)

    tr, tc = divmod(out_tile, cols)
    rr = np.arange(tr * th, (tr + 1) * th)
    cc = np.arange(tc * tw, (tc + 1) * tw)
    valid = (rr[:, None] < grid.h) & (cc[None, :] < grid.w)    # (th, tw)
    rr_c = np.minimum(rr, grid.h - 1)
    cc_c = np.minimum(cc, grid.w - 1)

    t_ids = nb.tile_id[rr_c][:, cc_c]                          # (th,tw,KK,4)
    offs = nb.offset[rr_c][:, cc_c]
    cfs = nb.coeff[rr_c][:, cc_c] * valid[..., None, None]

    # TDT guarantee: every neighbour tile of a real pixel in ``out_tile``
    # is in ``dep_tiles``; padded pixels carry coeff 0 and may point at
    # slot 0 harmlessly.
    idx = slot[t_ids] * tp + offs
    idx = np.where(valid[..., None, None], idx, 0).astype(np.int32)

    idx = idx.reshape(tp, kk, 4)
    cfs = cfs.reshape(tp, kk, 4).astype(np.float32)
    if p_pad != tp:
        idx = np.pad(idx, ((0, p_pad - tp), (0, 0), (0, 0)))
        cfs = np.pad(cfs, ((0, p_pad - tp), (0, 0), (0, 0)))
    return idx, cfs


def pack_schedule_tiles(
    nb: NeighbourTables,
    grid: TileGrid,
    out_tiles,
    dep_lists,
    p_pad: int,
    k_pad: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Group-level packing: the batched grid kernel's operands for a whole
    schedule at once (``kernels.dcn_fused.dcn_fused_schedule``).

    ``out_tiles``/``dep_lists`` are the schedule: per scheduled output tile
    its dependent input tiles. Stacks :func:`pack_output_tile` over the
    schedule and emits the dep table + counts the kernel's scalar-prefetch
    machinery consumes:

      dep_tbl (T, k_pad) int32  — dep tile ids, zero-padded; padding slots
                                  are never addressed because packed
                                  addresses only reach slot < len(deps),
                                  and the kernel skips them via dep_cnt.
                                  An empty dep list zeroes the whole coeff
                                  row (its row contributes bias only —
                                  schedules never contain dep-less tiles).
      dep_cnt (T,)       int32  — true dep count per scheduled tile
      idx     (T, p_pad, KK, 4) int32
      coeff   (T, p_pad, KK, 4) float32
    """
    kk = nb.coeff.shape[2]
    t = len(out_tiles)
    dep_tbl = np.zeros((t, k_pad), np.int32)
    dep_cnt = np.zeros((t,), np.int32)
    idx = np.zeros((t, p_pad, kk, 4), np.int32)
    coeff = np.zeros((t, p_pad, kk, 4), np.float32)
    with get_tracer().span("pack.schedule_tiles", tiles=t, k_pad=k_pad):
        for n, (tile, deps) in enumerate(zip(out_tiles, dep_lists)):
            deps = [int(d) for d in deps]
            if len(deps) > k_pad:
                raise ValueError(f"{len(deps)} deps exceed k_pad={k_pad}")
            if not deps:
                continue      # all-zero coeff row: the dispatch contributes
                              # bias only (schedules never emit such tiles)
            i, c = pack_output_tile(nb, grid, int(tile), deps, p_pad)
            idx[n], coeff[n] = i, c
            dep_tbl[n, :len(deps)] = deps
            dep_cnt[n] = len(deps)
    return dep_tbl, dep_cnt, idx, coeff


# ---------------------------------------------------------------------------
# Batch-fused packing: plane-ordered global-address operands + the
# batch-stacking path (concatenated per-image schedules).
# ---------------------------------------------------------------------------


def pack_plane_operands(coords: jax.Array, grid: TileGrid, p_pad: int,
                        ) -> tuple[jax.Array, jax.Array]:
    """(idx, coeff) kernel operands for EVERY output tile, in plane order,
    with PLANE-GLOBAL packed addresses ``tile_id * tile_pixels + offset``.

    Unlike :func:`pack_output_tile`, the addresses do not depend on any
    schedule's dep-slot assignment — the batch-fused kernel localises
    them against the scalar-prefetched dep id per slot. That makes the
    packing pure jnp on the sampling coordinates: with the device
    scheduling backend the whole prepass stays on-device (zero host
    round trip). Numerics match ``build_neighbour_tables`` +
    ``pack_output_tile`` exactly (same Eq. 4/5 formulas).

    coords: (H, W, KK, 2) -> idx/coeff (num_tiles, p_pad, KK, 4).
    """
    h, w, kk, _ = coords.shape
    th, tw, rows, cols = grid.th, grid.tw, grid.rows, grid.cols
    tp = th * tw

    floor_rc, coeffs = bli_coefficients(coords)
    r0 = jnp.clip(floor_rc[..., 0], 0, grid.h - 1)
    c0 = jnp.clip(floor_rc[..., 1], 0, grid.w - 1)
    r1 = jnp.clip(r0 + 1, 0, grid.h - 1)
    c1 = jnp.clip(c0 + 1, 0, grid.w - 1)
    nb_r = jnp.stack([r0, r0, r1, r1], axis=-1)            # (H, W, KK, 4)
    nb_c = jnp.stack([c0, c1, c0, c1], axis=-1)
    idx = ((nb_r // th) * cols + nb_c // tw) * tp \
        + (nb_r % th) * tw + nb_c % tw

    # Replicate-pad ragged edges; overhang output pixels carry coeff 0
    # (their contribution is discarded on scatter) and address 0.
    r_idx = jnp.minimum(jnp.arange(rows * th), h - 1)
    c_idx = jnp.minimum(jnp.arange(cols * tw), w - 1)
    valid = ((jnp.arange(rows * th) < h)[:, None]
             & (jnp.arange(cols * tw) < w)[None, :])
    idx_p = jnp.where(valid[..., None, None], idx[r_idx][:, c_idx], 0)
    cf_p = coeffs[r_idx][:, c_idx] * valid[..., None, None]

    def to_tiles(a):
        a = a.reshape(rows, th, cols, tw, kk, 4)
        a = a.transpose(0, 2, 1, 3, 4, 5).reshape(rows * cols, tp, kk, 4)
        if p_pad != tp:
            a = jnp.pad(a, ((0, 0), (0, p_pad - tp), (0, 0), (0, 0)))
        return a

    return (to_tiles(idx_p).astype(jnp.int32),
            to_tiles(cf_p).astype(jnp.float32))


class BatchDispatch(NamedTuple):
    """Concatenated per-image schedules as batch-fused kernel operands.

    One row per (image, schedule step) slot, images back to back with
    per-image base offsets already applied (``img * t_out`` for output
    rows, ``img * t_in`` for dep tiles). Ragged schedule lengths pad to
    the uniform per-image row count with ``oid = -1`` / ``dep_cnt = 0``
    slots whose dep entries repeat the image's last real dep (so the
    kernel's clamped index map elides their DMAs across the image
    boundary).
    """

    row_id: jax.Array    # (G,) int32 img*t_out + max(oid, 0)
    dep_glb: jax.Array   # (G, k_pad) int32 img*t_in + dep (load order)
    dep_cnt: jax.Array   # (G,) int32, 0 on padded slots
    oid: jax.Array       # (G,) int32 concatenated oids, -1 on padding
    img_id: jax.Array    # (G,) int32


def pack_batch_schedules(scheds: list[DeviceSchedule], t_in: int,
                         t_out: int) -> BatchDispatch:
    """Batch-stacking path: concatenate per-image dense schedules into
    one batch grid. Pure jnp over the ``DeviceSchedule`` arrays — device
    schedules stay on-device end-to-end; host-built schedules (numpy
    arrays) are uploaded as-is. All images must share the tile grid
    (same uniform row count per image)."""
    if not scheds:
        raise ValueError("empty batch")
    n_rows = scheds[0].n_rows
    if any(s.n_rows != n_rows for s in scheds):
        raise ValueError("per-image schedules disagree on row count — "
                         "images in a batch must share the tile grid")
    k_pad = max(s.k_pad for s in scheds)
    rows, deps, cnts, oids, imgs = [], [], [], [], []
    with get_tracer().span("pack.batch_schedules", batch=len(scheds),
                           rows=n_rows):
        for i, s in enumerate(scheds):
            oid_i = jnp.asarray(s.oid).reshape(-1)
            dep_i = jnp.asarray(s.dep_tbl)
            cnt_i = jnp.asarray(s.dep_cnt).reshape(-1)
            if dep_i.shape[1] < k_pad:
                dep_i = jnp.pad(dep_i,
                                ((0, 0), (0, k_pad - dep_i.shape[1])))
            valid = oid_i >= 0
            # Padded suffix rows repeat the image's last real dep so
            # their (skipped) grid steps issue no fresh DMA.
            last_row = jnp.maximum(jnp.sum(valid) - 1, 0)
            last_dep = dep_i[last_row,
                             jnp.maximum(cnt_i[last_row] - 1, 0)]
            dep_i = jnp.where(valid[:, None], dep_i, last_dep)
            rows.append(i * t_out + jnp.maximum(oid_i, 0))
            deps.append(i * t_in + dep_i)
            cnts.append(cnt_i)
            oids.append(oid_i)
            imgs.append(jnp.full((n_rows,), i, jnp.int32))
        return BatchDispatch(
            row_id=jnp.concatenate(rows).astype(jnp.int32),
            dep_glb=jnp.concatenate(deps).astype(jnp.int32),
            dep_cnt=jnp.concatenate(cnts).astype(jnp.int32),
            oid=jnp.concatenate(oids).astype(jnp.int32),
            img_id=jnp.concatenate(imgs))
