"""Batch-dimension sharding of the batch-fused dispatch (scale-out).

The paper scales its accelerator by replicating the tile pipeline
behind one scheduler; the executor analogue is sharding the batch axis
of ``dispatch="batch_fused"`` across a device mesh. This module holds
the host-side plumbing that stays identical for the pipeline and graph
executors:

* :class:`ShardPlan` — a contiguous partition of the batch over the
  mesh's ``"data"`` axis (serving passes explicit per-replica sizes so
  slot placement and shard placement agree).
* :func:`shard_batch_schedules` — per-shard ``pack_batch_schedules``:
  each shard keeps its OWN ragged padding (``k_pad`` / row count from
  its local images only), then pads to the cross-shard max with fully
  elided rows (``dep_cnt=0``, clamped-index DMA reuse) so a slow
  replica never inflates another replica's real work.
* :func:`stack_rows` / :func:`unstack_rows` — reshuffle flat per-image
  row blocks into the ``(D, n_max*rows, ...)`` shard-stacked layout the
  sharded kernel consumes, and back. ``unstack_rows`` on the logits is
  the ONE all-gather of the whole sharded run.

Scheduling, packing and traces are untouched: per-image schedules are
built exactly as in the single-device path, so executed traces stay
equal to the DRAM simulator regardless of placement.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.runtime.packing import pack_batch_schedules


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """A contiguous partition of ``n`` batch images over shards.

    ``spans[s] = (start, stop)`` is shard ``s``'s image range; spans
    cover ``range(n)`` in order, and may be empty (a replica with no
    occupied slots still participates in the SPMD dispatch with a fully
    padded grid).
    """

    n: int
    spans: tuple[tuple[int, int], ...]

    @property
    def n_shards(self) -> int:
        return len(self.spans)

    @property
    def sizes(self) -> tuple[int, ...]:
        return tuple(b - a for a, b in self.spans)

    @property
    def n_max(self) -> int:
        """Images on the fullest shard — the uniform SPMD slab size."""
        return max(self.sizes) if self.spans else 0


def plan_batch_shards(n: int, n_shards: int,
                      sizes: Sequence[int] | None = None) -> ShardPlan:
    """Partition ``n`` images contiguously over ``n_shards`` shards.

    Default is the near-even split (first ``n % n_shards`` shards get
    one extra image). ``sizes`` pins an explicit per-shard image count
    (the serving engine's replica-aware placement), which must sum to
    ``n``; zeros are allowed.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if sizes is None:
        base, extra = divmod(n, n_shards)
        sizes = [base + (1 if s < extra else 0) for s in range(n_shards)]
    else:
        sizes = [int(s) for s in sizes]
        if len(sizes) != n_shards:
            raise ValueError(f"sizes has {len(sizes)} entries for "
                             f"{n_shards} shards")
        if any(s < 0 for s in sizes):
            raise ValueError(f"negative shard size in {sizes}")
        if sum(sizes) != n:
            raise ValueError(f"shard sizes {sizes} sum to {sum(sizes)}, "
                             f"expected {n}")
    spans, at = [], 0
    for s in sizes:
        spans.append((at, at + s))
        at += s
    return ShardPlan(n=n, spans=tuple(spans))


def resolve_shard_mesh(mesh, data_parallel: int | None):
    """The effective mesh of a config's ``mesh=`` / ``data_parallel=``
    knobs, or None for the single-device path.

    An explicit ``mesh`` wins; ``data_parallel=D`` is the convenience
    spelling that builds a ``(D, 1)`` host mesh at run time (device
    availability is checked there, not at config construction, so
    configs stay picklable/buildable before jax initialises devices).
    """
    if mesh is None:
        if not data_parallel or int(data_parallel) <= 1:
            return None
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(data=int(data_parallel))
    if dict(mesh.shape).get("data", 1) <= 1:
        return None
    return mesh


class ShardedDispatch(NamedTuple):
    """Per-shard :class:`~repro.runtime.packing.BatchDispatch` arrays,
    stacked to the cross-shard max grid size. All ids are shard-LOCAL
    (row/dep bases restart at 0 per shard); ``oid`` is -1 on padding
    rows of either origin (ragged image schedules or shard-size
    padding)."""

    row_id: jax.Array    # (D, G_loc) int32
    dep_glb: jax.Array   # (D, G_loc, k_pad) int32
    dep_cnt: jax.Array   # (D, G_loc) int32, 0 on padded slots
    oid: jax.Array       # (D, G_loc) int32, -1 on padding


def shard_batch_schedules(scheds, t_in: int, t_out: int,
                          plan: ShardPlan) -> ShardedDispatch:
    """Concatenate each shard's image schedules independently, then pad
    to the uniform SPMD slab. The per-shard packs keep their own ragged
    ``k_pad``; cross-shard padding rows carry ``dep_cnt = 0`` and repeat
    the shard's last real dep (DMA elision), so uniformity costs no
    real work."""
    if len(scheds) != plan.n:
        raise ValueError(f"{len(scheds)} schedules for a plan of "
                         f"{plan.n} images")
    packs = [pack_batch_schedules(list(scheds[a:b]), t_in, t_out)
             if b > a else None
             for a, b in plan.spans]
    n_rows = scheds[0].n_rows if scheds else t_out
    g_max = plan.n_max * n_rows
    k_max = max((p.dep_glb.shape[1] for p in packs if p is not None),
                default=1)
    rows, deps, cnts, oids = [], [], [], []
    for p in packs:
        if p is None or p.row_id.shape[0] == 0:
            rows.append(jnp.zeros((g_max,), jnp.int32))
            deps.append(jnp.zeros((g_max, k_max), jnp.int32))
            cnts.append(jnp.zeros((g_max,), jnp.int32))
            oids.append(jnp.full((g_max,), -1, jnp.int32))
            continue
        g = p.row_id.shape[0]
        dep = p.dep_glb
        if dep.shape[1] < k_max:
            dep = jnp.pad(dep, ((0, 0), (0, k_max - dep.shape[1])),
                          mode="edge")
        if g < g_max:
            dep = jnp.pad(dep, ((0, g_max - g), (0, 0)), mode="edge")
        rows.append(jnp.pad(p.row_id, (0, g_max - g)))
        deps.append(dep)
        cnts.append(jnp.pad(p.dep_cnt, (0, g_max - g)))
        oids.append(jnp.pad(p.oid, (0, g_max - g), constant_values=-1))
    return ShardedDispatch(
        row_id=jnp.stack(rows).astype(jnp.int32),
        dep_glb=jnp.stack(deps).astype(jnp.int32),
        dep_cnt=jnp.stack(cnts).astype(jnp.int32),
        oid=jnp.stack(oids).astype(jnp.int32))


def stack_rows(flat: jax.Array, plan: ShardPlan, rows: int) -> jax.Array:
    """(n*rows, ...) image-major rows -> (D, n_max*rows, ...) shard
    slabs, zero-padding shards below ``n_max`` images. ``rows`` is the
    per-image row count (tiles per plane, or 1 for whole planes)."""
    slab = plan.n_max * rows
    parts = []
    for a, b in plan.spans:
        blk = flat[a * rows:b * rows]
        pad = slab - blk.shape[0]
        if pad:
            blk = jnp.pad(blk, ((0, pad),) + ((0, 0),) * (blk.ndim - 1))
        parts.append(blk)
    return jnp.stack(parts)


def unstack_rows(stacked: jax.Array, plan: ShardPlan,
                 rows: int) -> jax.Array:
    """Inverse of :func:`stack_rows`: drop shard padding and restore the
    flat image-major row order. On the final logits this is the run's
    single all-gather — every shard's slab crosses to the host/default
    device exactly once."""
    parts = [stacked[s, :(b - a) * rows]
             for s, (a, b) in enumerate(plan.spans) if b > a]
    if not parts:
        return stacked.reshape((0,) + stacked.shape[2:])
    return jnp.concatenate(parts)


def allgather_nbytes(arr: jax.Array) -> int:
    """Byte volume of gathering ``arr`` from its shards — the measured
    collective cost the scale-out bench reports."""
    return int(arr.size) * int(arr.dtype.itemsize)
