"""Tile-pipeline runtime — the paper's end-to-end execution path.

Connects the previously independent components into one runnable
accelerator model, per batch element and layer:

  stage 1   offset conv -> sampling coordinates      (core.deform)
  TDT       coords -> tile dependency table          (core.tiles)
  schedule  Algorithm 1 / sequential ordering        (core.scheduler)
  pack      halo/dependent input tiles + per-pixel
            (idx, coeff) tensors, padded for shapes
            not divisible by the tile size           (runtime.packing)
  execute   fused BLI(+)conv Pallas kernel per
            schedule entry, scattered back into the
            (N, H, W, C_out) output                  (kernels.dcn_fused)

The executor also emits a ``PipelineTrace`` whose packed-tile byte counts
can be compared against the DRAM-traffic simulator's predictions
(benchmarks/bench_scheduling.py, bench_fusion.py).
"""

from repro.runtime.packing import (
    NeighbourTables,
    build_neighbour_tables,
    pack_output_tile,
    plane_to_tiles,
)
from repro.runtime.pipeline import PipelineConfig, dcn_pipeline
from repro.runtime.trace import ImageTrace, PipelineTrace, TileRecord

__all__ = [
    "NeighbourTables",
    "build_neighbour_tables",
    "pack_output_tile",
    "plane_to_tiles",
    "PipelineConfig",
    "dcn_pipeline",
    "ImageTrace",
    "PipelineTrace",
    "TileRecord",
]
