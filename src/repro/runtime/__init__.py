"""Tile-pipeline + network-graph runtime — the paper's execution paths.

Per-layer pipeline (PR 1), per batch element and layer:

  stage 1   offset conv -> sampling coordinates      (core.deform)
  TDT       coords -> tile dependency table          (core.tiles)
  schedule  Algorithm 1 / sequential ordering        (core.scheduler)
  pack      halo/dependent input tiles + per-pixel
            (idx, coeff) tensors, padded for shapes
            not divisible by the tile size           (runtime.packing)
  execute   fused BLI(+)conv Pallas kernel per
            schedule entry, scattered back into the
            (N, H, W, C_out) output                  (kernels.dcn_fused)

Network-graph executor (§IV-D network-wide): a graph IR over the model
backbone (runtime.graph) is partitioned into cross-layer fused groups;
each group runs ONE Algorithm-1 schedule over a composite TDT chained
through its layers, with intermediate tiles confined to a bounded
on-chip tile buffer (runtime.fused_exec). Host-side schedules are
memoized in an LRU keyed on quantized coordinates (runtime.cache).

Executors emit traces (runtime.trace) whose byte counts are cross-checked
against the DRAM-traffic simulator in benchmarks/bench_scheduling.py,
bench_fusion.py and bench_graph.py.
"""

from repro.runtime.cache import ScheduleCache, default_schedule_cache
from repro.runtime.fused_exec import (
    GraphConfig,
    TileBuffer,
    run_graph,
    run_graph_dense,
)
from repro.runtime.graph import (
    ConvNode,
    DeformNode,
    FusedGroup,
    NetGraph,
    PoolNode,
    UpsampleNode,
    build_graph,
    partition_graph,
    partition_graph_cached,
    partition_graph_tuned,
)
from repro.runtime.packing import (
    BatchDispatch,
    NeighbourTables,
    build_neighbour_tables,
    pack_batch_schedules,
    pack_output_tile,
    pack_plane_operands,
    pack_schedule_tiles,
    plane_to_tiles,
)
from repro.runtime.pipeline import (
    PipelineConfig,
    clamp_tile_config,
    dcn_pipeline,
    resolve_interpret,
)
from repro.runtime.shard import (
    ShardPlan,
    plan_batch_shards,
    resolve_shard_mesh,
)
from repro.runtime.trace import (
    GroupTrace,
    ImageTrace,
    LatencyStats,
    LayerBufferStats,
    NetworkTrace,
    OverlapSpans,
    PipelineTrace,
    TileRecord,
)

__all__ = [
    "BatchDispatch",
    "NeighbourTables",
    "build_neighbour_tables",
    "pack_batch_schedules",
    "pack_output_tile",
    "pack_plane_operands",
    "pack_schedule_tiles",
    "plane_to_tiles",
    "PipelineConfig",
    "dcn_pipeline",
    "resolve_interpret",
    "ScheduleCache",
    "default_schedule_cache",
    "ShardPlan",
    "plan_batch_shards",
    "resolve_shard_mesh",
    "GraphConfig",
    "TileBuffer",
    "clamp_tile_config",
    "run_graph",
    "run_graph_dense",
    "ConvNode",
    "DeformNode",
    "FusedGroup",
    "NetGraph",
    "PoolNode",
    "UpsampleNode",
    "build_graph",
    "partition_graph",
    "partition_graph_cached",
    "partition_graph_tuned",
    "GroupTrace",
    "ImageTrace",
    "LatencyStats",
    "LayerBufferStats",
    "NetworkTrace",
    "OverlapSpans",
    "PipelineTrace",
    "TileRecord",
]
