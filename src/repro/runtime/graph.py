"""Network-graph IR for cross-layer tile fusion (paper §IV-D, Fig. 18).

The paper's fused-layer dataflow keeps the deformed-feature intermediate —
and, taken network-wide, whole boundary feature planes — out of DRAM. This
module is the *plan* side of that: a small IR over the backbone of a
``DcnNetConfig`` (``ConvNode`` / ``DeformNode`` / ``PoolNode`` /
``UpsampleNode``, built from ``stage_plan``) plus a partitioner that cuts
the chain into :class:`FusedGroup` segments using the §IV-D fusion planner
(``core.fusion.plan_fused_groups``).

Within a fused group every layer runs at the same spatial resolution
(stride-1 SAME convs), so tile grids coincide and per-layer tile
dependency tables chain by boolean composition (``core.tiles.compose_tdt``)
into one composite TDT the group is Algorithm-1-scheduled on. Pool and
upsample nodes change resolution and therefore always sit *between*
groups: their planes round-trip DRAM (counted as boundary bytes).

Execution lives in ``runtime.fused_exec``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import TYPE_CHECKING, Union

from repro.core.fusion import (GroupPlan, LayerShape, plan_fused_groups,
                               plan_network)
from repro.obs import get_tracer

if TYPE_CHECKING:  # avoid a cycle: models.dcn_models imports fused_exec
    from repro.models.dcn_models import DcnNetConfig


@dataclasses.dataclass(frozen=True)
class ConvNode:
    """Standard 3x3 stride-1 SAME conv (+ optional ReLU)."""

    param_idx: int            # index into the model's params["convs"]
    c_in: int
    c_out: int
    h: int                    # input (== output) spatial dims
    w: int
    kernel_size: int = 3
    relu: bool = True
    kind = "conv"


@dataclasses.dataclass(frozen=True)
class DeformNode:
    """Deformable conv (Eq. 1-3): offset conv -> BLI -> main conv."""

    param_idx: int
    c_in: int
    c_out: int
    h: int
    w: int
    kernel_size: int = 3
    variant: str = "dcn2"
    relu: bool = True
    kind = "deform"


@dataclasses.dataclass(frozen=True)
class PoolNode:
    """2x2 stride-2 VALID maxpool — a resolution boundary between groups."""

    h: int                    # input dims
    w: int
    channels: int
    window: int = 2
    kind = "pool"

    @property
    def out_h(self) -> int:
        return (self.h - self.window) // self.window + 1

    @property
    def out_w(self) -> int:
        return (self.w - self.window) // self.window + 1


@dataclasses.dataclass(frozen=True)
class UpsampleNode:
    """Nearest-neighbour 2x upsample (SegNet decoder unpool boundary)."""

    h: int
    w: int
    channels: int
    factor: int = 2
    kind = "upsample"

    @property
    def out_h(self) -> int:
        return self.h * self.factor

    @property
    def out_w(self) -> int:
        return self.w * self.factor


LayerNode = Union[ConvNode, DeformNode]
BoundaryNode = Union[PoolNode, UpsampleNode]
Node = Union[ConvNode, DeformNode, PoolNode, UpsampleNode]


@dataclasses.dataclass(frozen=True)
class NetGraph:
    """A linear backbone graph (VGG/SegNet-style chains have one path)."""

    nodes: tuple[Node, ...]
    in_h: int
    in_w: int
    in_c: int

    def __post_init__(self):
        h, w, c = self.in_h, self.in_w, self.in_c
        for n in self.nodes:
            if isinstance(n, (ConvNode, DeformNode)):
                if (n.h, n.w, n.c_in) != (h, w, c):
                    raise ValueError(
                        f"node {n} does not accept plane ({h},{w},{c})")
                c = n.c_out
            else:
                if (n.h, n.w, n.channels) != (h, w, c):
                    raise ValueError(
                        f"boundary {n} does not accept plane ({h},{w},{c})")
                h, w = n.out_h, n.out_w

    @property
    def out_shape(self) -> tuple[int, int, int]:
        h, w, c = self.in_h, self.in_w, self.in_c
        for n in self.nodes:
            if isinstance(n, (ConvNode, DeformNode)):
                c = n.c_out
            else:
                h, w = n.out_h, n.out_w
        return h, w, c


@dataclasses.dataclass(frozen=True)
class FusedGroup:
    """Consecutive same-resolution layers executed under ONE cross-layer
    Algorithm-1 schedule; interior planes live only in the tile buffer."""

    nodes: tuple[LayerNode, ...]
    plan: GroupPlan           # per-layer FusionPlans + modeled DRAM saving
    # Autotuned (tile_h, tile_w) override for this group's schedules and
    # dispatches; None -> the executor config's default tile applies.
    tile_hw: tuple[int, int] | None = None

    @property
    def h(self) -> int:
        return self.nodes[0].h

    @property
    def w(self) -> int:
        return self.nodes[0].w

    @property
    def c_in(self) -> int:
        return self.nodes[0].c_in

    @property
    def c_out(self) -> int:
        return self.nodes[-1].c_out

    @property
    def n_layers(self) -> int:
        return len(self.nodes)

    @property
    def layer_channels(self) -> list[tuple[int, int]]:
        return [(n.c_in, n.c_out) for n in self.nodes]


Segment = Union[FusedGroup, PoolNode, UpsampleNode]


def node_weight_bytes(node: LayerNode, dtype_bytes: int) -> int:
    """DRAM weight traffic of one layer (same formula as the simulator:
    main conv + offset conv for deformable layers)."""
    kk2 = node.kernel_size ** 2
    bytes_ = kk2 * node.c_in * node.c_out * dtype_bytes
    if isinstance(node, DeformNode):
        L = 2 if node.variant == "dcn1" else 2 * kk2
        bytes_ += kk2 * node.c_in * L * dtype_bytes
    return bytes_


def group_weight_bytes(group: FusedGroup, dtype_bytes: int) -> int:
    return sum(node_weight_bytes(n, dtype_bytes) for n in group.nodes)


def boundary_bytes(node: BoundaryNode, dtype_bytes: int) -> int:
    """Dense boundary op: read the input plane + write the output plane."""
    read = node.h * node.w * node.channels * dtype_bytes
    write = node.out_h * node.out_w * node.channels * dtype_bytes
    return read + write


def build_graph(cfg: "DcnNetConfig") -> NetGraph:
    """Build the backbone IR from ``DcnNetConfig.stage_plan`` — the exact
    node sequence ``models.dcn_models.dcn_net_apply`` executes (convs with
    ReLU, encoder pools, decoder unpool upsamples; heads excluded)."""
    # Imported lazily: dcn_models imports runtime.fused_exec -> this module.
    from repro.models.dcn_models import _VGG19_STAGES, _pool_positions

    decoder = cfg.name == "segnet"
    plan = cfg.stage_plan(decoder)
    pools = _pool_positions(cfg)
    n_enc = sum(n for _, n in _VGG19_STAGES)

    nodes: list[Node] = []
    h = w = cfg.img_size
    # Mirror the executed network exactly: encoder pools are skipped once
    # a plane side drops below 2, and a decoder upsample only pairs with a
    # pool that actually ran (shape parity for tiny inputs).
    applied_pools: set[int] = set()
    for i, (ci, co, deform) in enumerate(plan):
        if deform:
            nodes.append(DeformNode(i, ci, co, h, w, variant=cfg.variant))
        else:
            nodes.append(ConvNode(i, ci, co, h, w))
        if i < n_enc and i in pools and h >= 2 and w >= 2:
            nodes.append(PoolNode(h, w, co))
            h, w = nodes[-1].out_h, nodes[-1].out_w
            applied_pools.add(i)
        elif decoder and i >= n_enc and (2 * n_enc - 1 - i) in applied_pools:
            nodes.append(UpsampleNode(h, w, co))
            h, w = nodes[-1].out_h, nodes[-1].out_w
    return NetGraph(tuple(nodes), cfg.img_size, cfg.img_size,
                    cfg.in_channels)


def partition_graph(graph: NetGraph, onchip_budget_bytes: int,
                    dtype_bytes: int = 4) -> list[Segment]:
    """Cut the backbone into executable segments.

    Boundary nodes pass through as-is; each maximal run of layer nodes
    between boundaries is split into :class:`FusedGroup` segments by the
    §IV-D planner (STAGED layers become singleton groups).
    """
    segments: list[Segment] = []
    run: list[LayerNode] = []

    def flush() -> None:
        if not run:
            return
        shapes = [LayerShape(n.h, n.w, n.c_in, n.c_out, n.kernel_size,
                             dtype_bytes) for n in run]
        for gp in plan_fused_groups(shapes, onchip_budget_bytes):
            segments.append(FusedGroup(tuple(run[gp.start:gp.stop]), gp))
        run.clear()

    with get_tracer().span("prepass.partition",
                           nodes=len(graph.nodes)) as sp:
        for node in graph.nodes:
            if isinstance(node, (PoolNode, UpsampleNode)):
                flush()
                segments.append(node)
            else:
                run.append(node)
        flush()
        sp.set(segments=len(segments))
    return segments


def partition_graph_tuned(graph: NetGraph, tuned,
                          onchip_budget_bytes: int,
                          dtype_bytes: int = 4) -> list[Segment]:
    """Cut the backbone along an autotuned plan's explicit cut points.

    ``tuned`` is a ``repro.tuning.TunedPlan``: its groups name
    graph-node index spans ``[start, stop)`` plus the tile shape each
    group's schedules use (carried on ``FusedGroup.tile_hw``). The
    spans must exactly tile the layer-node indices without crossing a
    boundary node — anything else is a stale or foreign plan and
    raises instead of silently mis-executing.
    """
    layer_idx = [i for i, n in enumerate(graph.nodes)
                 if isinstance(n, (ConvNode, DeformNode))]
    covered = [i for g in tuned.groups for i in range(g.start, g.stop)]
    if covered != layer_idx:
        raise ValueError(
            "tuned plan does not tile this graph's layer nodes "
            f"(plan covers {covered[:8]}..., graph has "
            f"{layer_idx[:8]}...)")

    segments: list[Segment] = []
    groups = iter(tuned.groups)
    with get_tracer().span("prepass.partition", nodes=len(graph.nodes),
                           tuned=True) as sp:
        i = 0
        while i < len(graph.nodes):
            node = graph.nodes[i]
            if isinstance(node, (PoolNode, UpsampleNode)):
                segments.append(node)
                i += 1
                continue
            g = next(groups)
            run = graph.nodes[g.start:g.stop]
            shapes = [LayerShape(n.h, n.w, n.c_in, n.c_out,
                                 n.kernel_size, dtype_bytes)
                      for n in run]
            plans = tuple(plan_network(shapes, onchip_budget_bytes))
            saved = sum(2 * n.h * n.w * n.c_out * dtype_bytes
                        for n in run[:-1])
            gp = GroupPlan(0, len(run), plans, saved)
            segments.append(FusedGroup(tuple(run), gp,
                                       tile_hw=(g.tile_h, g.tile_w)))
            i = g.stop
        sp.set(segments=len(segments))
    return segments


@functools.lru_cache(maxsize=64)
def _partition_cached(graph: NetGraph, onchip_budget_bytes: int,
                      dtype_bytes: int, autotune: str,
                      tuned) -> tuple[Segment, ...]:
    if tuned is not None:
        return tuple(partition_graph_tuned(graph, tuned,
                                           onchip_budget_bytes,
                                           dtype_bytes))
    return tuple(partition_graph(graph, onchip_budget_bytes, dtype_bytes))


def partition_graph_cached(graph: NetGraph, onchip_budget_bytes: int,
                           dtype_bytes: int = 4, autotune: str = "off",
                           tuned=None) -> list[Segment]:
    """Memoized :func:`partition_graph` for serving hot paths.

    ``NetGraph`` is a frozen dataclass of frozen nodes and a
    ``TunedPlan`` is all-tuple, so the full key — graph, budget,
    dtype, autotune mode, tuned plan — is hashable and the planner
    sweep (greedy or tuned) runs once per distinct deployment instead
    of once per request step. Every input that can change the plan is
    part of the memo key: a tuned run can never be served a stale
    greedy partition (or vice versa), and two different tuned plans
    never collide. Segments are frozen; sharing them across calls is
    safe.
    """
    return list(_partition_cached(graph, int(onchip_budget_bytes),
                                  int(dtype_bytes), str(autotune),
                                  tuned))
