"""Execution traces of the tile-pipeline and network-graph executors.

The trace is the executor-side counterpart of the DRAM-traffic simulator
(``repro.core.simulator``): where the simulator *predicts* tile loads from
the TDT and a FIFO buffer model, the trace records what the executor
*actually packed and dispatched*. Replaying the recorded load sequence
through the same ``FifoBuffer`` must reproduce the simulator's scheduled
tile-load count exactly — benchmarks/bench_scheduling.py asserts this for
the per-layer pipeline, benchmarks/bench_graph.py for the cross-layer
fused groups (``GroupTrace`` / ``NetworkTrace``).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.scheduler import FifoBuffer
from repro.core.tiles import TileGrid
from repro.obs import Histogram


@dataclass(frozen=True)
class TileRecord:
    """One schedule entry as executed: output tile + what was packed."""

    out_tile: int
    dep_tiles: tuple[int, ...]   # input tiles packed, in load order
    loaded_bytes: int            # len(dep_tiles) * tile_bytes (no reuse)
    buffer_bytes: int            # padded on-chip packed buffer (S * C * b)


@dataclass
class ImageTrace:
    """Trace of one batch element through one deformable layer."""

    grid: TileGrid
    tile_bytes: int              # one input tile, in the executed dtype
    buffer_tiles: int            # M used for scheduling
    schedule: str                # "alg1" | "sequential"
    records: list[TileRecord] = field(default_factory=list)
    # None = schedule cache disabled for this image; True/False = hit/miss.
    schedule_cache_hit: bool | None = None
    # Kernel-dispatch accounting: host-issued compute dispatches (fused
    # Pallas calls + halo convs). Per-tile dispatch pays one per schedule
    # entry; batched grid dispatch pays one per layer segment; batch-fused
    # dispatches are shared by the whole batch and counted ONCE on the
    # enclosing PipelineTrace/NetworkTrace (``batch_dispatches``), so this
    # stays 0 for "batch_fused" images.
    kernel_dispatches: int = 0
    dispatch: str = "per_tile"   # "per_tile" | "batched" | "batch_fused"
    # Which scheduler built this image's TDT + Algorithm-1 order:
    # "host" = numpy reference loop, "device" = Pallas kernels.
    schedule_backend: str = "host"
    # batch-fused dispatch only: this image's (start, stop) row span in
    # the concatenated batch grid — its per-image slice of the single
    # fused dispatch. Grid order within the span is the image's own
    # schedule order, so ``records`` (and the simulator cross-check)
    # are unchanged vs per-image dispatch.
    batch_rows: tuple[int, int] | None = None

    @property
    def packed_tile_loads(self) -> int:
        """Input tiles packed with no cross-tile reuse (upper bound)."""
        return sum(len(r.dep_tiles) for r in self.records)

    @property
    def packed_bytes(self) -> int:
        return sum(r.loaded_bytes for r in self.records)

    @property
    def max_buffer_bytes(self) -> int:
        return max((r.buffer_bytes for r in self.records), default=0)

    def fifo_replay(self, buffer_tiles: int | None = None) -> FifoBuffer:
        """Replay the executed load sequence through the FIFO buffer model.

        With ``buffer_tiles`` equal to the simulator's capacity this yields
        exactly the simulator's tile-load count for the same schedule.
        """
        buf = FifoBuffer(self.buffer_tiles if buffer_tiles is None
                         else buffer_tiles)
        for r in self.records:
            for t in r.dep_tiles:
                buf.touch(t)
        return buf


class LatencyStats(Histogram):
    """Per-request latency accounting of a serving engine.

    Samples are submit->result wall seconds (queueing delay + every
    serving step the request waited through + its own service time), so
    the tail percentiles reflect what a client actually observes under
    the arrival process — the serving counterpart of the per-call
    ``OverlapSpans``. A thin seconds-suffixed veneer over the telemetry
    :class:`~repro.obs.Histogram`, so serving engines register it
    directly in their :class:`~repro.obs.MetricsRegistry`.

    Edge cases are well-defined rather than index arithmetic: an empty
    snapshot reports ``None`` mean/percentiles, a single sample reports
    that sample.
    """

    def __init__(self, samples_s=None):
        super().__init__(name="latency_s",
                         help="submit->result request latency (s)")
        for v in (samples_s or ()):
            self.observe(float(v))

    def add(self, latency_s: float) -> None:
        self.observe(float(latency_s))

    @property
    def samples_s(self) -> list[float]:
        return self.samples

    @property
    def mean_s(self) -> float | None:
        """Mean latency in seconds (None with no samples)."""
        return self.mean

    def percentile_s(self, q: float) -> float | None:
        """q-th percentile latency in seconds; None with no samples,
        the sample itself with exactly one."""
        return self.percentile(q)

    def summary(self) -> dict:
        """The stats block serving engines and benchmarks report."""
        return {
            "count": self.count,
            "mean_s": self.mean_s,
            "p50_s": self.percentile_s(50.0),
            "p95_s": self.percentile_s(95.0),
            "p99_s": self.percentile_s(99.0),
        }

    render = summary


@dataclass
class OverlapSpans:
    """Host-prepass vs device-execution overlap accounting of one executor
    call (the multi-image staging queue): how much of the host-side
    prepass (stage-1 offsets, TDT build, schedule, packing) was hidden
    under device execution of earlier images.

    No longer measured with bespoke timer bookkeeping: the executors
    record ``prepass`` / ``prepass.wait`` / ``prepass.schedule`` spans
    through the telemetry tracer (``repro.obs``) and this accounting is
    re-derived from those spans via :meth:`add_span` /
    :meth:`from_spans` — the trace fields are sums of span durations.
    """

    prepass_s: float = 0.0       # total host prepass wall time
    prepass_wait_s: float = 0.0  # prepass time the execute loop blocked on
    # Scheduling-stage split of the prepass: how much of it was the
    # TDT + Algorithm-1 build, and how much of *that* ran through the
    # on-device scheduler ("schedule_backend": "device") rather than the
    # host Python loop. With the device backend the staging thread
    # shrinks to packing only.
    schedule_s: float = 0.0          # TDT + schedule build wall time
    schedule_device_s: float = 0.0   # portion served by the device path

    # Span name -> accumulated field; "prepass.schedule" additionally
    # feeds schedule_device_s when its backend attr is "device".
    SPAN_FIELDS = {"prepass": "prepass_s",
                   "prepass.wait": "prepass_wait_s",
                   "prepass.schedule": "schedule_s"}

    def add_span(self, span) -> None:
        """Fold one tracer span (or measured ``timed`` handle) into the
        accounting; spans with unrelated names are ignored."""
        field_name = self.SPAN_FIELDS.get(span.name)
        if field_name is None:
            return
        setattr(self, field_name, getattr(self, field_name) + span.dur)
        if (span.name == "prepass.schedule"
                and span.attrs.get("backend") == "device"):
            self.schedule_device_s += span.dur

    @classmethod
    def from_spans(cls, spans) -> "OverlapSpans":
        """Re-derive the whole accounting from a span sequence."""
        o = cls()
        for s in spans:
            o.add_span(s)
        return o

    def merge(self, other: "OverlapSpans") -> None:
        """Accumulate another call's accounting (serving engines fold
        per-step traces into engine totals)."""
        self.prepass_s += other.prepass_s
        self.prepass_wait_s += other.prepass_wait_s
        self.schedule_s += other.schedule_s
        self.schedule_device_s += other.schedule_device_s

    @property
    def host_overlap_frac(self) -> float:
        """Fraction of prepass time hidden under execution (0 = serial)."""
        if self.prepass_s <= 0:
            return 0.0
        return max(0.0, 1.0 - self.prepass_wait_s / self.prepass_s)

    @property
    def schedule_device_frac(self) -> float:
        """Fraction of schedule-build time on the device backend."""
        if self.schedule_s <= 0:
            return 0.0
        return min(1.0, self.schedule_device_s / self.schedule_s)


@dataclass
class PipelineTrace:
    """Per-image traces of one ``dcn_pipeline`` call."""

    images: list[ImageTrace] = field(default_factory=list)
    overlap: OverlapSpans = field(default_factory=OverlapSpans)
    # Batch-fused dispatches: kernel calls shared by the WHOLE batch
    # (one per layer segment), counted here instead of per image.
    batch_dispatches: int = 0
    # Batch-axis scale-out: mesh shards the dispatch ran over, and the
    # measured byte volume of the single logits all-gather (0 when
    # single-device). Collective traffic, so NOT part of the per-image
    # DRAM model the simulator cross-checks.
    shards: int = 1
    allgather_bytes: int = 0

    @property
    def packed_bytes(self) -> int:
        return sum(im.packed_bytes for im in self.images)

    @property
    def kernel_dispatches(self) -> int:
        return (self.batch_dispatches
                + sum(im.kernel_dispatches for im in self.images))

    @property
    def dispatches_per_batch(self) -> int:
        """Host-issued dispatches of this call — for batch-fused mode the
        whole call is one batch, so this equals ``kernel_dispatches``."""
        return self.kernel_dispatches

    @property
    def host_overlap_frac(self) -> float:
        return self.overlap.host_overlap_frac

    @property
    def schedule_device_frac(self) -> float:
        return self.overlap.schedule_device_frac

    @property
    def packed_tile_loads(self) -> int:
        return sum(im.packed_tile_loads for im in self.images)

    @property
    def schedule_cache_hits(self) -> int:
        return sum(im.schedule_cache_hit is True for im in self.images)

    @property
    def schedule_cache_misses(self) -> int:
        return sum(im.schedule_cache_hit is False for im in self.images)

    def fifo_loads(self, buffer_tiles: int | None = None) -> int:
        return sum(im.fifo_replay(buffer_tiles).loads for im in self.images)


# ---------------------------------------------------------------------------
# Network-graph executor traces (cross-layer fused groups)
# ---------------------------------------------------------------------------


@dataclass
class LayerBufferStats:
    """On-chip accounting of one layer's output-tile buffer inside a fused
    group: intermediates never touch DRAM, so the only costs are the
    bounded resident footprint and recomputes after eviction."""

    kind: str                    # "conv" | "deform"
    tiles_computed: int = 0      # dispatches (first computes + recomputes)
    recomputes: int = 0          # tiles evicted then produced again
    max_resident_bytes: int = 0  # tile-buffer high-water mark


@dataclass
class GroupTrace(ImageTrace):
    """One fused group of one batch element as executed.

    ``records`` holds the group-level schedule: per composite-schedule
    entry, the *group-input* tiles in load order — ``fifo_replay`` of that
    sequence must equal the network simulator's fused prediction exactly.
    ``b_layers`` keeps the per-layer TDTs the schedule was built from so
    the simulator cross-check consumes byte-identical inputs.
    """

    image: int = 0
    group: int = 0
    dtype_bytes: int = 4
    layer_channels: list[tuple[int, int]] = field(default_factory=list)
    output_bytes: int = 0        # group output plane write
    weight_bytes: int = 0
    layer_stats: list[LayerBufferStats] = field(default_factory=list)
    b_layers: list[np.ndarray] = field(default_factory=list)

    @property
    def input_load_bytes(self) -> int:
        return self.fifo_replay().loads * self.tile_bytes

    @property
    def total_dram_bytes(self) -> int:
        # Interior planes contribute nothing: that is the fusion.
        return self.input_load_bytes + self.output_bytes + self.weight_bytes

    @property
    def total_recomputes(self) -> int:
        return sum(s.recomputes for s in self.layer_stats)

    @property
    def max_resident_bytes(self) -> int:
        return max((s.max_resident_bytes for s in self.layer_stats),
                   default=0)


@dataclass
class NetworkTrace:
    """Trace of one ``run_graph`` call: all groups of all batch elements,
    plus the dense boundary ops (pool/upsample) between groups."""

    groups: list[GroupTrace] = field(default_factory=list)
    boundary_bytes: int = 0      # pool/upsample plane read+write traffic
    overlap: OverlapSpans = field(default_factory=OverlapSpans)
    # Batch-fused dispatches: kernel calls shared by the WHOLE batch
    # (one per layer segment), counted here instead of per group trace.
    batch_dispatches: int = 0
    # Batch-axis scale-out: mesh shards the dispatch ran over, and the
    # measured byte volume of the single logits all-gather (0 when
    # single-device). Collective traffic, so NOT part of the per-image
    # DRAM model the simulator cross-checks.
    shards: int = 1
    allgather_bytes: int = 0

    @property
    def kernel_dispatches(self) -> int:
        return (self.batch_dispatches
                + sum(g.kernel_dispatches for g in self.groups))

    @property
    def dispatches_per_batch(self) -> int:
        """Host-issued dispatches of this call — for batch-fused mode the
        whole call is one batch, so this equals ``kernel_dispatches``."""
        return self.kernel_dispatches

    @property
    def host_overlap_frac(self) -> float:
        return self.overlap.host_overlap_frac

    @property
    def schedule_device_frac(self) -> float:
        return self.overlap.schedule_device_frac

    @property
    def input_load_bytes(self) -> int:
        return sum(g.input_load_bytes for g in self.groups)

    @property
    def output_write_bytes(self) -> int:
        return sum(g.output_bytes for g in self.groups)

    @property
    def weight_read_bytes(self) -> int:
        return sum(g.weight_bytes for g in self.groups)

    @property
    def total_dram_bytes(self) -> int:
        return (self.input_load_bytes + self.output_write_bytes
                + self.weight_read_bytes + self.boundary_bytes)

    @property
    def schedule_cache_hits(self) -> int:
        return sum(g.schedule_cache_hit is True for g in self.groups)

    @property
    def schedule_cache_misses(self) -> int:
        return sum(g.schedule_cache_hit is False for g in self.groups)

    @property
    def total_recomputes(self) -> int:
        return sum(g.total_recomputes for g in self.groups)
