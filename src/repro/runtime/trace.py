"""Execution traces of the tile-pipeline executor.

The trace is the executor-side counterpart of the DRAM-traffic simulator
(``repro.core.simulator``): where the simulator *predicts* tile loads from
the TDT and a FIFO buffer model, the trace records what the executor
*actually packed and dispatched*. Replaying the recorded load sequence
through the same ``FifoBuffer`` must reproduce the simulator's scheduled
tile-load count exactly — benchmarks/bench_scheduling.py asserts this.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.scheduler import FifoBuffer
from repro.core.tiles import TileGrid


@dataclass(frozen=True)
class TileRecord:
    """One schedule entry as executed: output tile + what was packed."""

    out_tile: int
    dep_tiles: tuple[int, ...]   # input tiles packed, in load order
    loaded_bytes: int            # len(dep_tiles) * tile_bytes (no reuse)
    buffer_bytes: int            # padded on-chip packed buffer (S * C * b)


@dataclass
class ImageTrace:
    """Trace of one batch element through one deformable layer."""

    grid: TileGrid
    tile_bytes: int              # one input tile, in the executed dtype
    buffer_tiles: int            # M used for scheduling
    schedule: str                # "alg1" | "sequential"
    records: list[TileRecord] = field(default_factory=list)

    @property
    def packed_tile_loads(self) -> int:
        """Input tiles packed with no cross-tile reuse (upper bound)."""
        return sum(len(r.dep_tiles) for r in self.records)

    @property
    def packed_bytes(self) -> int:
        return sum(r.loaded_bytes for r in self.records)

    @property
    def max_buffer_bytes(self) -> int:
        return max((r.buffer_bytes for r in self.records), default=0)

    def fifo_replay(self, buffer_tiles: int | None = None) -> FifoBuffer:
        """Replay the executed load sequence through the FIFO buffer model.

        With ``buffer_tiles`` equal to the simulator's capacity this yields
        exactly the simulator's tile-load count for the same schedule.
        """
        buf = FifoBuffer(self.buffer_tiles if buffer_tiles is None
                         else buffer_tiles)
        for r in self.records:
            for t in r.dep_tiles:
                buf.touch(t)
        return buf


@dataclass
class PipelineTrace:
    """Per-image traces of one ``dcn_pipeline`` call."""

    images: list[ImageTrace] = field(default_factory=list)

    @property
    def packed_bytes(self) -> int:
        return sum(im.packed_bytes for im in self.images)

    @property
    def packed_tile_loads(self) -> int:
        return sum(im.packed_tile_loads for im in self.images)

    def fifo_loads(self, buffer_tiles: int | None = None) -> int:
        return sum(im.fifo_replay(buffer_tiles).loads for im in self.images)
