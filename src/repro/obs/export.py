"""Exporters: Chrome-trace/Perfetto JSON out of a :class:`Tracer`.

The emitted document is the Trace Event Format both ``chrome://tracing``
and https://ui.perfetto.dev load directly: a ``traceEvents`` list of
complete ``"ph": "X"`` events (microsecond ``ts``/``dur``) plus
``"ph": "M"`` metadata naming the tracks. Track layout:

* pid 0 ("host threads") — one tid per OS thread that recorded spans,
  so the staging worker's prepass track sits under the main thread's
  execute track and the overlap is visible directly.
* pid 1 ("engine steps") — every ``serve.step`` span is duplicated onto
  a per-step track (tid = step id), annotated with the step's dispatch
  counts and DRAM bytes, so one artifact shows where each serving
  step's wall went.

``validate_chrome_trace`` is the schema check the benchmark gate and
the tests share.
"""

from __future__ import annotations

import json
import numbers

from repro.obs.tracer import Span, Tracer

_SERVE_STEP = "serve.step"


def _json_value(v):
    """Coerce an attr value to something JSON-serializable."""
    if v is None or isinstance(v, (bool, str)):
        return v
    if isinstance(v, numbers.Integral):
        return int(v)
    if isinstance(v, numbers.Real):
        return float(v)
    return repr(v)


def _args(span: Span) -> dict:
    return {k: _json_value(v) for k, v in span.attrs.items()
            if k != "instant"}


def chrome_trace_events(tracer_or_spans) -> list[dict]:
    """Render spans as Trace Event Format events (see module docstring)."""
    if isinstance(tracer_or_spans, Tracer):
        spans = tracer_or_spans.snapshot()
    else:
        spans = list(tracer_or_spans)
    events: list[dict] = [
        {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
         "args": {"name": "host threads"}},
        {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
         "args": {"name": "engine steps"}},
    ]
    if not spans:
        return events
    t0 = min(s.ts for s in spans)

    # Compact, deterministic thread ids in order of first appearance.
    tids: dict[int, int] = {}
    for s in sorted(spans, key=lambda s: s.ts):
        if s.tid not in tids:
            tids[s.tid] = len(tids)
            events.append({"name": "thread_name", "ph": "M", "pid": 0,
                           "tid": tids[s.tid],
                           "args": {"name": s.thread_name
                                    or f"thread-{s.tid}"}})

    for s in sorted(spans, key=lambda s: s.ts):
        ts_us = (s.ts - t0) * 1e6
        if s.attrs.get("instant"):
            events.append({"name": s.name, "ph": "i", "s": "t",
                           "ts": ts_us, "pid": 0, "tid": tids[s.tid],
                           "args": _args(s)})
            continue
        ev = {"name": s.name, "cat": s.name.split(".", 1)[0], "ph": "X",
              "ts": ts_us, "dur": s.dur * 1e6, "pid": 0,
              "tid": tids[s.tid], "args": _args(s)}
        events.append(ev)
        if s.name == _SERVE_STEP and "step" in s.attrs:
            step = int(s.attrs["step"])
            events.append({"name": "thread_name", "ph": "M", "pid": 1,
                           "tid": step,
                           "args": {"name": f"step {step}"}})
            events.append(dict(ev, pid=1, tid=step))
    return events


def chrome_trace(tracer_or_spans) -> dict:
    """Full Chrome-trace document (the JSON-object flavor)."""
    return {"traceEvents": chrome_trace_events(tracer_or_spans),
            "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, tracer_or_spans) -> dict:
    doc = chrome_trace(tracer_or_spans)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def validate_chrome_trace(doc) -> list[str]:
    """Schema-check a Chrome-trace document; [] means loadable.

    Checks the invariants ``chrome://tracing`` / Perfetto rely on:
    a ``traceEvents`` list whose complete events carry name/ph plus
    numeric non-negative ts/dur and integer pid/tid, and JSON
    serializability of the whole document.
    """
    problems: list[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document is not a dict with a 'traceEvents' key"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' is not a list"]
    try:
        json.dumps(doc)
    except (TypeError, ValueError) as e:
        problems.append(f"not JSON-serializable: {e}")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i} is not an object")
            continue
        if not isinstance(ev.get("name"), str):
            problems.append(f"event {i} missing string 'name'")
        ph = ev.get("ph")
        if ph not in ("X", "M", "i"):
            problems.append(f"event {i} has unsupported ph={ph!r}")
            continue
        if not isinstance(ev.get("pid"), int):
            problems.append(f"event {i} missing int 'pid'")
        if not isinstance(ev.get("tid"), int):
            problems.append(f"event {i} missing int 'tid'")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i} has invalid ts={ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i} has invalid dur={dur!r}")
    return problems


def write_json(path: str, obj) -> None:
    """Dump a metrics snapshot / serving timeline as indented JSON."""
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, default=_json_value)
