"""Span tracing: nested, thread-aware wall-time spans with a no-op
disabled path.

A :class:`Tracer` records :class:`Span` entries — named wall-time
intervals with per-thread nesting — via context managers:

    tr = Tracer(enabled=True)
    with tr.span("prepass.schedule", backend="device"):
        build()

Two entry points with different disabled-path contracts:

* ``span(name, **attrs)`` — export-only instrumentation. When the
  tracer is disabled it returns a shared no-op context manager: no
  allocation, no clock read, nothing recorded. Safe to sprinkle on hot
  paths (kernel dispatch wrappers).
* ``timed(name, **attrs)`` — structural accounting. The duration is
  ALWAYS measured (the returned object's ``.dur`` is valid after the
  ``with`` block) but the span is only *recorded* when the tracer is
  enabled. The executors' ``OverlapSpans`` bookkeeping is re-derived
  from these spans (``OverlapSpans.add_span``), so overlap counters
  stay exact whether or not tracing is on.

Thread model: each thread keeps its own span stack (parenting never
crosses threads — the staging worker's prepass spans are roots on its
own track), and the span list is lock-protected, so the multi-image
staging queue and concurrent serving submitters can all record into one
tracer. Export to Chrome-trace/Perfetto JSON lives in
``repro.obs.export``.

Zero-dep by design: stdlib only, importable from ``core``/``kernels``
without cycles.
"""

from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field


@dataclass
class Span:
    """One finished wall-time interval."""

    name: str
    ts: float                       # start, seconds on the perf_counter clock
    dur: float = 0.0                # seconds
    sid: int = 0                    # unique id within the tracer
    parent: int | None = None       # enclosing span's sid (same thread)
    tid: int = 0                    # OS thread ident
    thread_name: str = ""
    attrs: dict = field(default_factory=dict)


class _NullSpan:
    """Shared no-op context manager for the disabled ``span()`` path."""

    __slots__ = ()
    name = None
    dur = 0.0
    attrs: dict = {}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NULL_SPAN = _NullSpan()


class Stopwatch:
    """Measure-only context manager: ``.dur`` valid after the block.

    What ``Tracer.timed`` degrades to when tracing is disabled, and the
    shared timing helper for benchmarks that previously hand-rolled
    ``perf_counter`` pairs.
    """

    __slots__ = ("name", "attrs", "dur", "_t0")

    def __init__(self, name: str | None = None, attrs: dict | None = None):
        self.name = name
        self.attrs = attrs if attrs is not None else {}
        self.dur = 0.0
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.dur = time.perf_counter() - self._t0
        return False

    def set(self, **attrs):
        self.attrs.update(attrs)
        return self


class _LiveSpan:
    """Recording context manager: appends a Span to the tracer on exit."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: Tracer, name: str, attrs: dict):
        th = threading.current_thread()
        self._tracer = tracer
        self._span = Span(name=name, ts=0.0, tid=th.ident or 0,
                          thread_name=th.name, attrs=attrs)

    def __enter__(self):
        tr = self._tracer
        stack = tr._stack()
        sp = self._span
        sp.sid = tr._next_id()
        sp.parent = stack[-1] if stack else None
        stack.append(sp.sid)
        sp.ts = time.perf_counter()
        return self

    def __exit__(self, *exc):
        sp = self._span
        sp.dur = time.perf_counter() - sp.ts
        stack = self._tracer._stack()
        if stack and stack[-1] == sp.sid:
            stack.pop()
        self._tracer._record(sp)
        return False

    def set(self, **attrs):
        """Attach/overwrite attributes mid-span (e.g. results known only
        after the work ran)."""
        self._span.attrs.update(attrs)
        return self

    @property
    def dur(self) -> float:
        return self._span.dur

    @property
    def name(self) -> str:
        return self._span.name

    @property
    def attrs(self) -> dict:
        return self._span.attrs


class Tracer:
    """Collects spans; disabled by default (see module docstring)."""

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self.spans: list[Span] = []
        self._lock = threading.Lock()
        self._id = 0
        self._local = threading.local()

    # -- recording ----------------------------------------------------------

    def span(self, name: str, **attrs):
        """Export-only span: a true no-op when the tracer is disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _LiveSpan(self, name, attrs)

    def timed(self, name: str, **attrs):
        """Always-measured span: ``.dur`` is valid after the block even
        when disabled (recorded into ``spans`` only when enabled)."""
        if not self.enabled:
            return Stopwatch(name, attrs)
        return _LiveSpan(self, name, attrs)

    def instant(self, name: str, **attrs) -> None:
        """Zero-duration marker event (Chrome-trace ``ph: "i"``)."""
        if not self.enabled:
            return
        th = threading.current_thread()
        sp = Span(name=name, ts=time.perf_counter(), dur=0.0,
                  sid=self._next_id(), tid=th.ident or 0,
                  thread_name=th.name, attrs=attrs)
        sp.attrs["instant"] = True
        self._record(sp)

    # -- lifecycle ----------------------------------------------------------

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self.spans = []

    def __len__(self) -> int:
        with self._lock:
            return len(self.spans)

    def snapshot(self) -> list[Span]:
        """Copy of the recorded spans (safe to iterate while recording)."""
        with self._lock:
            return list(self.spans)

    def spans_since(self, mark: int) -> list[Span]:
        """Spans recorded after a previous ``len(tracer)`` mark."""
        with self._lock:
            return list(self.spans[mark:])

    # -- internals ----------------------------------------------------------

    def _stack(self) -> list[int]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _next_id(self) -> int:
        with self._lock:
            self._id += 1
            return self._id

    def _record(self, span: Span) -> None:
        with self._lock:
            self.spans.append(span)


# ---------------------------------------------------------------------------
# Global/current tracer: a process-wide default (disabled) plus a
# thread-local override so a serving engine can route the executors and
# kernel dispatch wrappers it drives into its own tracer.
# ---------------------------------------------------------------------------

_GLOBAL = Tracer(enabled=False)
_OVERRIDE = threading.local()


def global_tracer() -> Tracer:
    """The process-wide default tracer (disabled until enabled)."""
    return _GLOBAL


def get_tracer() -> Tracer:
    """The current tracer: the innermost ``use_tracer`` override on this
    thread, else the global default."""
    stack = getattr(_OVERRIDE, "stack", None)
    if stack:
        return stack[-1]
    return _GLOBAL


@contextmanager
def use_tracer(tracer: Tracer):
    """Route ``get_tracer()`` on THIS thread to ``tracer`` for the block
    (executors use it so kernel dispatch wrappers record into the same
    tracer as the surrounding call)."""
    stack = getattr(_OVERRIDE, "stack", None)
    if stack is None:
        stack = _OVERRIDE.stack = []
    stack.append(tracer)
    try:
        yield tracer
    finally:
        stack.pop()
