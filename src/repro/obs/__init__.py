"""Unified telemetry for the DCN serving stack (zero external deps).

Three layers, one package:

* ``obs.tracer`` — nested, thread-aware wall-time spans
  (``prepass.schedule``, ``dispatch.batch_fused``, ``serve.step``, …)
  with a true no-op disabled path; the executors' ``OverlapSpans``
  accounting is re-derived from these spans.
* ``obs.metrics`` — typed Counter/Gauge/Histogram objects behind a
  :class:`MetricsRegistry` whose ``snapshot()`` is the single
  machine-readable view of every serving/scheduling counter.
* ``obs.export`` — Chrome-trace/Perfetto JSON export of a recorded run
  (loads in ``chrome://tracing`` / ui.perfetto.dev) plus plain-JSON
  dumps of metrics snapshots and serving timelines.

Stdlib-only on purpose: ``core`` and ``kernels`` import it without
cycles, and tracing can thread through the whole hot path — kernels'
dispatch wrappers, both executors, packing, the scheduler backends and
the serving engine — at negligible cost when disabled.
"""

from repro.obs.export import (
    chrome_trace,
    chrome_trace_events,
    validate_chrome_trace,
    write_chrome_trace,
    write_json,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    percentile,
)
from repro.obs.tracer import (
    Span,
    Stopwatch,
    Tracer,
    get_tracer,
    global_tracer,
    use_tracer,
)

__all__ = [
    "Span",
    "Stopwatch",
    "Tracer",
    "get_tracer",
    "global_tracer",
    "use_tracer",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "percentile",
    "chrome_trace",
    "chrome_trace_events",
    "validate_chrome_trace",
    "write_chrome_trace",
    "write_json",
]
