"""Typed metrics: Counter / Gauge / Histogram behind one registry.

Unifies the counters that previously lived as ad-hoc attributes across
``runtime/cache.py``, ``core/scheduler.py`` and ``serving/engine.py``:
a :class:`MetricsRegistry` owns named metric objects and renders them
all through one ``snapshot() -> dict`` — the single source of truth the
serving engine's ``stats``, the benchmark gates and the CI artifacts
read.

Zero-dep (stdlib only) so ``core`` and ``kernels`` can import it
without cycles; percentiles are computed with the same linear
interpolation as ``numpy.percentile`` but without index arithmetic on
empty/singleton samples (None / the sample respectively).
"""

from __future__ import annotations

import math
import threading


def percentile(samples, q: float):
    """q-th percentile (linear interpolation, like numpy's default).

    Well-defined edge cases instead of index arithmetic: ``None`` with
    no samples, the sample itself with exactly one.
    """
    n = len(samples)
    if n == 0:
        return None
    s = sorted(samples)
    if n == 1:
        return float(s[0])
    pos = (n - 1) * (float(q) / 100.0)
    lo = int(math.floor(pos))
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return float(s[lo] * (1.0 - frac) + s[hi] * frac)


class Counter:
    """Monotonically increasing count."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    # Back-compat aliases for the pre-registry instrumentation counter
    # API (``core.scheduler.host_schedule_builds.bump()`` / ``.count``).
    def bump(self) -> None:
        self.inc()

    @property
    def value(self) -> int:
        with self._lock:
            return self._value

    @property
    def count(self) -> int:
        return self.value

    def reset(self) -> None:
        with self._lock:
            self._value = 0

    def render(self):
        return self.value


class Gauge:
    """Last-set value (depths, rates, config echoes)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v) -> None:
        with self._lock:
            self._value = v

    def add(self, dv) -> None:
        with self._lock:
            self._value += dv

    @property
    def value(self):
        with self._lock:
            return self._value

    def render(self):
        return self.value


class Histogram:
    """Sample distribution with percentile summaries.

    Keeps raw samples (serving runs are CI-sized; the latency population
    is what benchmarks archive anyway). ``summary()`` reports count /
    mean / p50 / p95 / p99 with the edge-case contract of
    :func:`percentile`.
    """

    kind = "histogram"

    def __init__(self, name: str = "", help: str = ""):
        self.name = name
        self.help = help
        self._samples: list[float] = []
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        with self._lock:
            self._samples.append(float(v))

    @property
    def samples(self) -> list[float]:
        with self._lock:
            return list(self._samples)

    @property
    def count(self) -> int:
        with self._lock:
            return len(self._samples)

    @property
    def mean(self):
        with self._lock:
            if not self._samples:
                return None
            return sum(self._samples) / len(self._samples)

    def percentile(self, q: float):
        return percentile(self.samples, q)

    def summary(self) -> dict:
        s = self.samples
        return {
            "count": len(s),
            "mean": (sum(s) / len(s)) if s else None,
            "p50": percentile(s, 50.0),
            "p95": percentile(s, 95.0),
            "p99": percentile(s, 99.0),
        }

    def render(self):
        return self.summary()


class MetricsRegistry:
    """Named metric objects + one ``snapshot()`` over all of them."""

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, cls, help: str):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(name, Counter, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(name, Gauge, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get_or_create(name, Histogram, help)

    def register(self, name: str, metric) -> None:
        """Adopt an externally constructed metric object (it must expose
        ``render()``); e.g. the serving engine's ``LatencyStats``."""
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None and existing is not metric:
                raise ValueError(f"metric {name!r} already registered")
            self._metrics[name] = metric

    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> dict:
        """{name: value} for counters/gauges, {name: summary dict} for
        histograms — one machine-readable view of every metric."""
        with self._lock:
            items = list(self._metrics.items())
        return {name: m.render() for name, m in items}


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """Process-wide registry (home of cross-cutting counters like
    ``host_schedule_builds``)."""
    return _DEFAULT
