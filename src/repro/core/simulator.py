"""Memory-traffic + DRAM-energy simulator for deformable convolution.

Reproduces the paper's evaluation methodology (§V):

  * DRAM traffic is counted in tile loads under a FIFO-replacement on-chip
    buffer (the paper's input buffer, Table I: 128 KB), for the three
    strategies of Fig. 14/16:
       - ``naive``      : "W/O bit vector"  — per-output-feature demand
                          loading; no tile-level dependency dedup.
       - ``bitvec``     : "W/ bit vector + W/O scheduling" — sequential
                          output tiles, per-tile deduplicated loads.
       - ``scheduled``  : "W/ bit vector + W/ scheduling" — Algorithm 1.
  * DRAM energy follows Micron's power-calculator methodology the paper
    cites (Table II): per-access energies for ACT/RD/WR/IO plus a
    background-power term over the execution time.
  * Fusion accounting (§IV-D, Fig. 18): without BLI(+)conv fusion the
    deformed-feature intermediate — K*K x the input feature map — is
    written to and read back from DRAM; with fusion it never leaves
    on-chip buffers.

All byte counts are exact functions of the schedule; the energy constants
are the paper's Table II. Execution-time modelling for the platform
comparison lives in ``benchmarks/bench_platforms.py``.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .scheduler import (FifoBuffer, TileSchedule, schedule_tiles,
                        sequential_schedule)
from .tiles import TileGrid, compose_tdt_chain

# ---------------------------------------------------------------------------
# DRAM energy model (paper Table II, Micron DDR3 power calculator)
# ---------------------------------------------------------------------------

# Power in mW at DDR3-1600 (800 MHz IO clock), per Table II.
P_ACT_MW = 63.7
P_RD_MW = 52.1
P_WR_MW = 52.1
P_READ_IO_MW = 32.7
P_WRITE_ODT_MW = 136.1
P_BG_MW = 67.7

# DDR3-1600 x16: peak 12.8 GB/s. Energy/byte = P / BW for the dynamic
# terms that scale with traffic; BG power integrates over wall time.
_DDR_BW_BYTES_PER_S = 12.8e9


@dataclass(frozen=True)
class DramEnergyModel:
    """Per-byte dynamic energies (pJ/B) + background power (W)."""

    read_pj_per_byte: float = ((P_ACT_MW + P_RD_MW + P_READ_IO_MW)
                               / 1e3 / _DDR_BW_BYTES_PER_S * 1e12)
    write_pj_per_byte: float = ((P_ACT_MW + P_WR_MW + P_WRITE_ODT_MW)
                                / 1e3 / _DDR_BW_BYTES_PER_S * 1e12)
    background_w: float = P_BG_MW / 1e3

    def energy_j(self, read_bytes: float, write_bytes: float,
                 exec_time_s: float) -> float:
        return (self.read_pj_per_byte * read_bytes * 1e-12
                + self.write_pj_per_byte * write_bytes * 1e-12
                + self.background_w * exec_time_s)


# ---------------------------------------------------------------------------
# Traffic simulation
# ---------------------------------------------------------------------------


@dataclass
class TrafficReport:
    strategy: str
    tile_loads: int            # input tiles fetched from DRAM
    reuse_hits: int            # on-chip tile reuse events
    input_read_bytes: int      # tile_loads * tile_bytes
    intermediate_bytes: int    # deformed-feature DRAM round trip (0 if fused)
    output_write_bytes: int
    weight_read_bytes: int

    @property
    def total_dram_bytes(self) -> int:
        return (self.input_read_bytes + self.intermediate_bytes
                + self.output_write_bytes + self.weight_read_bytes)


def _replay(schedule: TileSchedule, buffer_tiles: int) -> FifoBuffer:
    buf = FifoBuffer(buffer_tiles)
    for loads in schedule.iid:
        for t in loads:
            buf.touch(t)
    return buf


def simulate_naive(per_pixel_tiles: np.ndarray,
                   buffer_tiles: int) -> FifoBuffer:
    """'W/O bit vector': output features execute in raster order and demand
    their input tiles one by one — no output-tile-level dedup is possible
    because the overall dependency information is unknown.

    per_pixel_tiles: (H, W, KK, 4) int input-tile ids (from
    ``tiles.per_pixel_input_tiles``).
    """
    buf = FifoBuffer(buffer_tiles)
    flat = np.asarray(per_pixel_tiles).reshape(per_pixel_tiles.shape[0]
                                               * per_pixel_tiles.shape[1], -1)
    for px in flat:
        # within one output feature, the 4*KK accesses are served from the
        # currently-resident tiles (a single feature's working set).
        for t in dict.fromkeys(px.tolist()):
            buf.touch(t)
    return buf


def simulate_strategies(
    B: np.ndarray,
    per_pixel_tiles: np.ndarray,
    in_grid: TileGrid,
    channels: int,
    c_out: int,
    kernel_size: int,
    buffer_bytes: int,
    dtype_bytes: int = 1,
    fused: bool = True,
) -> dict[str, TrafficReport]:
    """Run all three strategies of paper Fig. 14/16 on one deformable conv.

    Returns a dict strategy -> TrafficReport. ``fused`` toggles the
    §IV-D BLI(+)conv fusion accounting for the deformed intermediate.
    """
    tile_bytes = in_grid.tile_bytes(channels, dtype_bytes)
    buffer_tiles = max(1, buffer_bytes // tile_bytes)
    h, w = in_grid.h, in_grid.w
    kk2 = kernel_size * kernel_size

    out_bytes = h * w * c_out * dtype_bytes
    weight_bytes = (kk2 * channels * c_out          # main conv
                    + kk2 * channels * 2 * kk2) * dtype_bytes  # offset conv
    inter_bytes = 0 if fused else 2 * h * w * kk2 * channels * dtype_bytes

    def report(name: str, buf: FifoBuffer) -> TrafficReport:
        return TrafficReport(
            strategy=name,
            tile_loads=buf.loads,
            reuse_hits=buf.hits,
            input_read_bytes=buf.loads * tile_bytes,
            intermediate_bytes=inter_bytes,
            output_write_bytes=out_bytes,
            weight_read_bytes=weight_bytes,
        )

    naive_buf = simulate_naive(per_pixel_tiles, buffer_tiles)
    bitvec_buf = _replay(sequential_schedule(B), buffer_tiles)
    sched_buf = _replay(schedule_tiles(B, buffer_tiles), buffer_tiles)

    return {
        "naive": report("naive", naive_buf),
        "bitvec": report("bitvec", bitvec_buf),
        "scheduled": report("scheduled", sched_buf),
    }


# ---------------------------------------------------------------------------
# Network-level traffic (cross-layer fusion, §IV-D taken network-wide)
# ---------------------------------------------------------------------------


@dataclass
class GroupTrafficReport:
    """Predicted DRAM traffic of one fused group (or its per-layer run)."""

    n_layers: int
    tile_loads: int            # input tiles fetched from DRAM
    reuse_hits: int
    input_read_bytes: int
    intermediate_bytes: int    # interior boundary-plane writes (0 if fused)
    output_write_bytes: int    # group output plane
    weight_read_bytes: int

    @property
    def total_dram_bytes(self) -> int:
        return (self.input_read_bytes + self.intermediate_bytes
                + self.output_write_bytes + self.weight_read_bytes)


@dataclass
class NetworkTrafficReport:
    """Whole-network traffic: per-group reports + dense boundary ops."""

    mode: str                  # "fused" | "layerwise"
    groups: list[GroupTrafficReport]
    boundary_bytes: int = 0    # pool/upsample plane read+write between groups

    @property
    def tile_loads(self) -> int:
        return sum(g.tile_loads for g in self.groups)

    @property
    def total_dram_bytes(self) -> int:
        return (sum(g.total_dram_bytes for g in self.groups)
                + self.boundary_bytes)


def _schedule_and_replay(B: np.ndarray, buffer_tiles: int,
                         schedule: str) -> FifoBuffer:
    if schedule == "alg1":
        sched = schedule_tiles(B, buffer_tiles)
    elif schedule == "sequential":
        sched = sequential_schedule(B)
    else:
        raise ValueError(f"unknown schedule: {schedule!r}")
    return _replay(sched, buffer_tiles)


def simulate_group(
    b_layers: list[np.ndarray],
    grid: TileGrid,
    layer_channels: list[tuple[int, int]],
    weight_bytes: int,
    buffer_tiles: int,
    dtype_bytes: int = 1,
    fused: bool = True,
    schedule: str = "alg1",
) -> GroupTrafficReport:
    """Predict one group's DRAM traffic from its per-layer TDTs.

    ``fused=True`` runs ONE Algorithm-1 schedule over the composite TDT
    (``compose_tdt`` chained over the group's layers): only group-input
    tiles are fetched and interior planes stay on-chip. ``fused=False``
    models the per-layer execution of the same layers: each layer is
    scheduled on its own TDT, and every interior boundary plane is written
    to DRAM (its read-back is the next layer's tile loads).
    """
    if len(b_layers) != len(layer_channels):
        raise ValueError("need one (c_in, c_out) pair per layer TDT")
    h, w = grid.h, grid.w
    if fused:
        comp = compose_tdt_chain(b_layers)
        buf = _schedule_and_replay(comp, buffer_tiles, schedule)
        loads, hits = buf.loads, buf.hits
        input_bytes = loads * grid.tile_bytes(layer_channels[0][0],
                                              dtype_bytes)
        inter_bytes = 0
    else:
        loads = hits = input_bytes = 0
        for b, (c_in, _) in zip(b_layers, layer_channels):
            buf = _schedule_and_replay(np.asarray(b, bool), buffer_tiles,
                                       schedule)
            loads += buf.loads
            hits += buf.hits
            input_bytes += buf.loads * grid.tile_bytes(c_in, dtype_bytes)
        inter_bytes = sum(h * w * c_out * dtype_bytes
                          for _, c_out in layer_channels[:-1])
    return GroupTrafficReport(
        n_layers=len(b_layers),
        tile_loads=loads,
        reuse_hits=hits,
        input_read_bytes=input_bytes,
        intermediate_bytes=inter_bytes,
        output_write_bytes=h * w * layer_channels[-1][1] * dtype_bytes,
        weight_read_bytes=weight_bytes,
    )


def simulate_network(group_specs: list[dict], boundary_bytes: int = 0,
                     fused: bool = True) -> NetworkTrafficReport:
    """Network-level §IV-D accounting over pre-built group specs.

    Each spec is a kwargs dict for :func:`simulate_group` (without
    ``fused``). The executor trace (``runtime.trace.NetworkTrace``) must
    match the ``fused=True`` prediction exactly — bench_graph asserts it.
    """
    reports = [simulate_group(fused=fused, **spec) for spec in group_specs]
    return NetworkTrafficReport(mode="fused" if fused else "layerwise",
                                groups=reports, boundary_bytes=boundary_bytes)


def dram_energy(report: TrafficReport, exec_time_s: float,
                model: DramEnergyModel | None = None) -> float:
    """Joules for one layer's DRAM traffic under the Table II model."""
    model = model or DramEnergyModel()
    reads = report.input_read_bytes + report.weight_read_bytes \
        + report.intermediate_bytes // 2
    writes = report.output_write_bytes + report.intermediate_bytes // 2
    return model.energy_j(reads, writes, exec_time_s)
