"""Stage-fusion planner — paper §IV-D (BLI (+) conv fusion).

Decides, per deformable-conv layer, whether the three processing stages
(offset conv -> BLI -> main conv) are executed

  * ``FUSED``    : stages 2+3 tiled together; the deformed-feature
                   intermediate (K*K x the input feature map) lives only in
                   on-chip memory (VMEM on TPU) — the Pallas kernel
                   ``repro.kernels.dcn_fused`` / the ``jax.checkpoint``
                   XLA path implement this dataflow; or
  * ``STAGED``   : each stage round-trips through DRAM/HBM — only chosen
                   when a fused tile cannot fit on-chip even at the minimum
                   tile size.

The planner mirrors the paper's observation that the index tensor is small
and always buffered on-chip, while the deformed features dominate and are
what fusion must keep on-chip.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from enum import Enum


class FusionMode(str, Enum):
    FUSED = "fused"
    STAGED = "staged"


@dataclass(frozen=True)
class LayerShape:
    h: int
    w: int
    c_in: int
    c_out: int
    kernel_size: int = 3
    dtype_bytes: int = 1  # paper: 8-bit fixed point; TPU path uses 2 (bf16)


@dataclass(frozen=True)
class FusionPlan:
    mode: FusionMode
    tile_pixels: int          # output pixels processed per fused tile
    vmem_bytes: int           # on-chip working set of one fused tile
    dram_bytes_saved: int     # intermediate round-trip avoided vs STAGED


def fused_tile_bytes(shape: LayerShape, tile_pixels: int,
                     halo: int = 2) -> int:
    """On-chip working set of one fused output tile.

    input halo tile + deformed patch matrix + conv weights + output tile.
    The deformed patch matrix is (tile_pixels, K*K*C_in) — the tensor the
    paper keeps on-chip. The halo region covers the clamped offset range;
    ``halo`` is in units of tile side lengths (offsets clamped to R force
    halo <= R, DESIGN.md §2).
    """
    kk2 = shape.kernel_size ** 2
    side = max(1, int(math.sqrt(tile_pixels)))
    in_side = side * (1 + halo)
    input_tile = in_side * in_side * shape.c_in * shape.dtype_bytes
    deformed = tile_pixels * kk2 * shape.c_in * shape.dtype_bytes
    weights = kk2 * shape.c_in * shape.c_out * shape.dtype_bytes
    output = tile_pixels * shape.c_out * shape.dtype_bytes
    coords = tile_pixels * kk2 * 2 * 4  # fp32 indices (index buffer)
    return input_tile + deformed + weights + output + coords


def plan_fusion(shape: LayerShape, onchip_budget_bytes: int,
                min_tile_pixels: int = 64) -> FusionPlan:
    """Pick the largest fused tile that fits the on-chip budget.

    Tries power-of-two tile sizes from the full plane downwards; falls back
    to STAGED only if even ``min_tile_pixels`` does not fit (e.g. enormous
    C_in*C_out weight working sets).
    """
    total_pixels = shape.h * shape.w
    kk2 = shape.kernel_size ** 2
    saved = 2 * total_pixels * kk2 * shape.c_in * shape.dtype_bytes

    t = 1 << (total_pixels - 1).bit_length()  # >= total_pixels, pow2
    while t >= min_tile_pixels:
        vmem = fused_tile_bytes(shape, min(t, total_pixels))
        if vmem <= onchip_budget_bytes:
            return FusionPlan(FusionMode.FUSED, min(t, total_pixels), vmem,
                              dram_bytes_saved=saved)
        t //= 2
    return FusionPlan(FusionMode.STAGED, min_tile_pixels,
                      fused_tile_bytes(shape, min_tile_pixels),
                      dram_bytes_saved=0)


def plan_network(shapes: list[LayerShape], onchip_budget_bytes: int
                 ) -> list[FusionPlan]:
    return [plan_fusion(s, onchip_budget_bytes) for s in shapes]
