"""Stage-fusion planner — paper §IV-D (BLI (+) conv fusion).

Decides, per deformable-conv layer, whether the three processing stages
(offset conv -> BLI -> main conv) are executed

  * ``FUSED``    : stages 2+3 tiled together; the deformed-feature
                   intermediate (K*K x the input feature map) lives only in
                   on-chip memory (VMEM on TPU) — the Pallas kernel
                   ``repro.kernels.dcn_fused`` / the ``jax.checkpoint``
                   XLA path implement this dataflow; or
  * ``STAGED``   : each stage round-trips through DRAM/HBM — only chosen
                   when a fused tile cannot fit on-chip even at the minimum
                   tile size.

The planner mirrors the paper's observation that the index tensor is small
and always buffered on-chip, while the deformed features dominate and are
what fusion must keep on-chip.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from enum import Enum


class FusionMode(str, Enum):
    FUSED = "fused"
    STAGED = "staged"


@dataclass(frozen=True)
class LayerShape:
    h: int
    w: int
    c_in: int
    c_out: int
    kernel_size: int = 3
    dtype_bytes: int = 1  # paper: 8-bit fixed point; TPU path uses 2 (bf16)


@dataclass(frozen=True)
class FusionPlan:
    mode: FusionMode
    tile_pixels: int          # output pixels processed per fused tile
    vmem_bytes: int           # on-chip working set of one fused tile
    dram_bytes_saved: int     # intermediate round-trip avoided vs STAGED


def fused_tile_bytes(shape: LayerShape, tile_pixels: int,
                     halo: int = 2) -> int:
    """On-chip working set of one fused output tile.

    input halo tile + deformed patch matrix + conv weights + output tile.
    The deformed patch matrix is (tile_pixels, K*K*C_in) — the tensor the
    paper keeps on-chip. The halo region covers the clamped offset range;
    ``halo`` is in units of tile side lengths (offsets clamped to R force
    halo <= R, DESIGN.md §2).
    """
    kk2 = shape.kernel_size ** 2
    side = max(1, int(math.sqrt(tile_pixels)))
    in_side = side * (1 + halo)
    input_tile = in_side * in_side * shape.c_in * shape.dtype_bytes
    deformed = tile_pixels * kk2 * shape.c_in * shape.dtype_bytes
    weights = kk2 * shape.c_in * shape.c_out * shape.dtype_bytes
    output = tile_pixels * shape.c_out * shape.dtype_bytes
    coords = tile_pixels * kk2 * 2 * 4  # fp32 indices (index buffer)
    return input_tile + deformed + weights + output + coords


def plan_fusion(shape: LayerShape, onchip_budget_bytes: int,
                min_tile_pixels: int = 64) -> FusionPlan:
    """Pick the largest fused tile that fits the on-chip budget.

    Tries power-of-two tile sizes from the full plane downwards; falls back
    to STAGED only if even ``min_tile_pixels`` does not fit (e.g. enormous
    C_in*C_out weight working sets).
    """
    total_pixels = shape.h * shape.w
    kk2 = shape.kernel_size ** 2
    saved = 2 * total_pixels * kk2 * shape.c_in * shape.dtype_bytes
    min_tile_pixels = min(min_tile_pixels, total_pixels)  # tiny planes fuse

    t = 1 << (total_pixels - 1).bit_length()  # >= total_pixels, pow2
    while t >= min_tile_pixels:
        vmem = fused_tile_bytes(shape, min(t, total_pixels))
        if vmem <= onchip_budget_bytes:
            return FusionPlan(FusionMode.FUSED, min(t, total_pixels), vmem,
                              dram_bytes_saved=saved)
        t //= 2
    return FusionPlan(FusionMode.STAGED, min_tile_pixels,
                      fused_tile_bytes(shape, min_tile_pixels),
                      dram_bytes_saved=0)


def plan_network(shapes: list[LayerShape], onchip_budget_bytes: int
                 ) -> list[FusionPlan]:
    return [plan_fusion(s, onchip_budget_bytes) for s in shapes]


@dataclass(frozen=True)
class GroupPlan:
    """One *cross-layer* fused group: consecutive layers whose boundary
    feature planes never round-trip through DRAM (§IV-D taken network-wide,
    Fig. 18). ``start``/``stop`` index the layer-shape chain half-open."""

    start: int
    stop: int
    plans: tuple[FusionPlan, ...]
    dram_bytes_saved: int     # interior boundary planes (write+read) elided

    @property
    def n_layers(self) -> int:
        return self.stop - self.start


def plan_fused_groups(shapes: list[LayerShape], onchip_budget_bytes: int,
                      ) -> list[GroupPlan]:
    """Partition a chain of same-resolution layers into fused groups.

    Layers whose per-layer plan is FUSED are merged into maximal runs; a
    STAGED layer (its fused tile cannot fit on-chip even at the minimum
    tile size) becomes a singleton group whose boundaries materialize.
    The interior boundary planes of a multi-layer group are the §IV-D
    saving, counted as one write plus one read of each interior plane.
    """
    plans = plan_network(shapes, onchip_budget_bytes)
    groups: list[GroupPlan] = []
    run_start: int | None = None

    def flush(stop: int) -> None:
        nonlocal run_start
        if run_start is None:
            return
        saved = sum(2 * shapes[j].h * shapes[j].w * shapes[j].c_out
                    * shapes[j].dtype_bytes
                    for j in range(run_start, stop - 1))
        groups.append(GroupPlan(run_start, stop,
                                tuple(plans[run_start:stop]), saved))
        run_start = None

    for i, p in enumerate(plans):
        if p.mode is FusionMode.STAGED:
            flush(i)
            groups.append(GroupPlan(i, i + 1, (p,), 0))
        elif run_start is None:
            run_start = i
    flush(len(plans))
    return groups
