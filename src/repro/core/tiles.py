"""Tile Dependency Table (TDT) — paper §IV-C, Fig. 9.

The input and output feature maps are divided into fixed tiles. For each
*output* tile we record, as a bit vector over *input* tiles, which input
tiles its deformable-convolution computation touches. The table is built
"at runtime" from the stage-1 sampling coordinates: every deformed sample
needs the 4 integer-grid neighbours of its (row, col) coordinate, and each
neighbour lands in exactly one input tile (the paper's boundary-comparator
+ decoder circuit, Fig. 9, is a hardware argmax over tile boundaries — here
it is an integer divide).

Two implementations:
  * ``tdt_from_coords``        — jittable jnp version (runtime tracking).
  * ``per_pixel_input_tiles``  — per-output-pixel tile ids, used by the
                                 naive-baseline traffic simulator
                                 (paper Fig. 16, "W/O bit vector").
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class TileGrid(NamedTuple):
    """A tiling of an (H, W) feature plane into th x tw tiles."""

    h: int
    w: int
    th: int
    tw: int

    @property
    def rows(self) -> int:
        return math.ceil(self.h / self.th)

    @property
    def cols(self) -> int:
        return math.ceil(self.w / self.tw)

    @property
    def num_tiles(self) -> int:
        return self.rows * self.cols

    def tile_of(self, r, c):
        """Tile id of integer pixel coordinates (vectorised)."""
        return (r // self.th) * self.cols + (c // self.tw)

    def tile_bytes(self, channels: int, dtype_bytes: int = 1) -> int:
        return self.th * self.tw * channels * dtype_bytes


def make_square_grid(h: int, w: int, tiles_per_side: int) -> TileGrid:
    """Paper-style "divided into n x n tiles" constructor (e.g. 5x5)."""
    return TileGrid(h, w, math.ceil(h / tiles_per_side),
                    math.ceil(w / tiles_per_side))


def _neighbour_tiles(coords: jax.Array, grid: TileGrid) -> jax.Array:
    """Input-tile id of each of the 4 BLI neighbours of every coordinate.

    coords (..., 2) float -> (..., 4) int32 tile ids.
    """
    r0 = jnp.clip(jnp.floor(coords[..., 0]).astype(jnp.int32), 0, grid.h - 1)
    c0 = jnp.clip(jnp.floor(coords[..., 1]).astype(jnp.int32), 0, grid.w - 1)
    r1 = jnp.clip(r0 + 1, 0, grid.h - 1)
    c1 = jnp.clip(c0 + 1, 0, grid.w - 1)
    return jnp.stack(
        [grid.tile_of(r0, c0), grid.tile_of(r0, c1),
         grid.tile_of(r1, c0), grid.tile_of(r1, c1)], axis=-1)


def tdt_from_coords(coords: jax.Array, in_grid: TileGrid,
                    out_grid: TileGrid) -> jax.Array:
    """Build the TDT from sampling coordinates (single image).

    coords: (H, W, KK, 2) absolute float sampling coordinates for each
            output position (output plane assumed same HxW as input, as in
            the paper's stride-1 deformable layers).
    returns B: (out_grid.num_tiles, in_grid.num_tiles) bool — B[o, i] is
            True iff output tile o depends on input tile i.
    """
    h, w, kk, _ = coords.shape
    rows = jnp.arange(h, dtype=jnp.int32)[:, None]
    cols = jnp.arange(w, dtype=jnp.int32)[None, :]
    out_tile = out_grid.tile_of(rows, cols)                    # (H, W)
    out_tile = jnp.broadcast_to(out_tile[..., None, None], (h, w, kk, 4))

    in_tile = _neighbour_tiles(coords, in_grid)                # (H, W, KK, 4)

    flat_out = out_tile.reshape(-1)
    flat_in = in_tile.reshape(-1)
    b = jnp.zeros((out_grid.num_tiles, in_grid.num_tiles), jnp.bool_)
    return b.at[flat_out, flat_in].set(True)


def per_pixel_input_tiles(coords: jax.Array, in_grid: TileGrid) -> jax.Array:
    """(H, W, KK, 4) int32 input-tile id per neighbour per tap per pixel."""
    return _neighbour_tiles(coords, in_grid)


def tdt_standard_conv(in_grid: TileGrid, out_grid: TileGrid,
                      kernel_size: int = 3) -> np.ndarray:
    """TDT of a *standard* convolution (regular sliding window) — the
    uniform-access baseline from the paper's §III characterisation."""
    r = (kernel_size - 1) // 2
    b = np.zeros((out_grid.num_tiles, in_grid.num_tiles), bool)
    for tr in range(out_grid.rows):
        for tc in range(out_grid.cols):
            o = tr * out_grid.cols + tc
            r_lo = max(tr * out_grid.th - r, 0)
            r_hi = min((tr + 1) * out_grid.th - 1 + r, in_grid.h - 1)
            c_lo = max(tc * out_grid.tw - r, 0)
            c_hi = min((tc + 1) * out_grid.tw - 1 + r, in_grid.w - 1)
            tiles_r = range(r_lo // in_grid.th, r_hi // in_grid.th + 1)
            tiles_c = range(c_lo // in_grid.tw, c_hi // in_grid.tw + 1)
            for ir in tiles_r:
                for ic in tiles_c:
                    b[o, ir * in_grid.cols + ic] = True
    return b


def compose_tdt(b_down: np.ndarray, b_up: np.ndarray) -> np.ndarray:
    """Chain two tile-dependency tables across a layer boundary.

    ``b_up`` describes the upstream layer (its output tiles are the
    downstream layer's input tiles); ``b_down`` describes the downstream
    layer. The composition maps downstream *output* tiles all the way to
    the upstream layer's *input* tiles:

        C[o, i] = OR_m  b_down[o, m] AND b_up[m, i]

    i.e. boolean matrix multiplication. Chaining a DCN layer's measured
    TDT through downstream standard-conv halos (``tdt_standard_conv``)
    yields the composite table a cross-layer fused group is scheduled on.
    """
    d = np.asarray(b_down, dtype=bool)
    u = np.asarray(b_up, dtype=bool)
    if d.shape[1] != u.shape[0]:
        raise ValueError(
            f"TDT shapes do not chain: down {d.shape} x up {u.shape}")
    # int32, not uint8: a pair sharing a multiple of 256 intermediate
    # tiles would wrap to 0 and silently drop the dependency.
    return (d.astype(np.int32) @ u.astype(np.int32)) > 0


def compose_tdt_chain(b_layers: list[np.ndarray]) -> np.ndarray:
    """Composite TDT of a layer chain (``b_layers`` in execution order):
    last-layer output tiles -> first-layer input tiles. The executor and
    the network simulator both schedule on exactly this table."""
    if not b_layers:
        raise ValueError("empty layer chain")
    comp = np.asarray(b_layers[-1], bool)
    for b in b_layers[-2::-1]:
        comp = compose_tdt(comp, b)
    return comp


def compose_tdt_chain_device(b_layers: list) -> jax.Array:
    """On-device :func:`compose_tdt_chain`: boolean matrix-chain product
    as jnp int32 matmuls, so a fused group's composite TDT can flow from
    the device TDT kernels straight into the device scheduler with no
    host round trip. Bit-exact vs the numpy chain (both are exact
    boolean algebra)."""
    if not b_layers:
        raise ValueError("empty layer chain")
    comp = jnp.asarray(b_layers[-1]).astype(jnp.int32)
    for b in b_layers[-2::-1]:
        up = jnp.asarray(b).astype(jnp.int32)
        if comp.shape[1] != up.shape[0]:
            raise ValueError(
                f"TDT shapes do not chain: down {comp.shape} x up "
                f"{up.shape}")
        comp = (comp @ up > 0).astype(jnp.int32)
    return comp > 0


def access_histogram(coords: jax.Array, h: int, w: int) -> jax.Array:
    """Per-input-feature utilisation counts (paper Fig. 3a).

    Counts how many (output position, tap, neighbour) accesses touch each
    input feature location.
    """
    r0 = jnp.clip(jnp.floor(coords[..., 0]).astype(jnp.int32), 0, h - 1)
    c0 = jnp.clip(jnp.floor(coords[..., 1]).astype(jnp.int32), 0, w - 1)
    r1 = jnp.clip(r0 + 1, 0, h - 1)
    c1 = jnp.clip(c0 + 1, 0, w - 1)
    idx = jnp.stack([r0 * w + c0, r0 * w + c1, r1 * w + c0, r1 * w + c1])
    counts = jnp.zeros((h * w,), jnp.int32)
    return counts.at[idx.reshape(-1)].add(1).reshape(h, w)


def tile_access_histogram(coords: jax.Array, in_grid: TileGrid) -> jax.Array:
    """Per-input-tile utilisation counts (paper Fig. 3b)."""
    tiles = _neighbour_tiles(coords, in_grid)
    counts = jnp.zeros((in_grid.num_tiles,), jnp.int32)
    return counts.at[tiles.reshape(-1)].add(1)
