"""Unified deformable-convolution model (paper §II-B, Eq. 1-3).

Implements the three-stage pipeline in pure JAX (this module is the
algorithmic reference; the Pallas kernels in ``repro.kernels`` accelerate
stages 2+3 and are validated against these functions):

  stage 1  offset convolution  -> non-integer sampling coordinates  (Eq. 1)
  stage 2  bilinear interpolation (BLI) at those coordinates        (Eq. 2)
  stage 3  standard convolution over the deformed features          (Eq. 3)

Two DCN variants from the paper (§II-A):
  * DCN-I  : one (alpha, beta) pair per *plane position*, shared by all
             K*K kernel taps and all channels.          offsets: (N,H,W,2)
  * DCN-II : one (alpha, beta) pair per *tap* per position (the original
             deformable convolution).                   offsets: (N,H,W,2*K*K)

Layout: NHWC. Coordinates are (row, col) = (beta, alpha) in float32.
Out-of-range coordinates are clamped to the valid feature extent — the
paper's address converter (Eq. 4) likewise assumes in-range buffer
addresses. An optional ``max_displacement`` clamps the *offset magnitude*;
this is what makes the distributed halo-exchange path (DESIGN.md §2) legal.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class DeformableConvParams(NamedTuple):
    """Parameters of one deformable convolution (stages 1+3)."""

    w_off: jax.Array  # (K, K, C_in, L)  offset-conv weights   (Eq. 1)
    b_off: jax.Array  # (L,)             offset-conv bias
    w: jax.Array      # (K, K, C_in, C_out) main conv weights  (Eq. 3)
    b: jax.Array      # (C_out,)         main conv bias


def offset_channels(kernel_size: int, variant: str) -> int:
    """Number of offset channels L produced by stage 1."""
    if variant == "dcn1":
        return 2
    if variant == "dcn2":
        return 2 * kernel_size * kernel_size
    raise ValueError(f"unknown DCN variant: {variant!r}")


def init_deformable_conv(
    key: jax.Array,
    c_in: int,
    c_out: int,
    kernel_size: int = 3,
    variant: str = "dcn2",
    dtype=jnp.float32,
) -> DeformableConvParams:
    k_off, k_w = jax.random.split(key)
    kk = kernel_size
    L = offset_channels(kk, variant)
    fan_in = kk * kk * c_in
    # Offset conv is initialised at zero (standard DCN practice: start from
    # the regular grid); main conv uses He init.
    w_off = jnp.zeros((kk, kk, c_in, L), dtype)
    b_off = jnp.zeros((L,), dtype)
    w = (jax.random.normal(k_w, (kk, kk, c_in, c_out), dtype)
         * jnp.sqrt(2.0 / fan_in).astype(dtype))
    b = jnp.zeros((c_out,), dtype)
    del k_off
    return DeformableConvParams(w_off, b_off, w, b)


def randomize_offset_conv(params: DeformableConvParams, key: jax.Array,
                          scale: float) -> DeformableConvParams:
    """Replace the (zero-initialised) offset-conv weights with Gaussian
    noise of the given scale — the canonical way tests and benchmarks
    create genuinely irregular sampling patterns."""
    w_off = jax.random.normal(key, params.w_off.shape,
                              params.w_off.dtype) * scale
    return params._replace(w_off=w_off.astype(params.w_off.dtype))


def conv2d(x: jax.Array, w: jax.Array, b: jax.Array | None = None,
           stride: int = 1, padding: str = "SAME") -> jax.Array:
    """Standard NHWC conv (stages 1 and 3 building block)."""
    y = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=(jnp.float32 if x.dtype == jnp.bfloat16
                                else None),
    )
    if b is not None:
        y = y + b.astype(y.dtype)
    return y.astype(x.dtype)


def base_tap_grid(kernel_size: int, dtype=jnp.float32) -> jax.Array:
    """Relative (row, col) positions of the K*K regular taps, centred."""
    r = (kernel_size - 1) / 2.0
    d = jnp.arange(kernel_size, dtype=dtype) - r
    taps = jnp.stack(jnp.meshgrid(d, d, indexing="ij"), axis=-1)  # (K,K,2)
    return taps.reshape(-1, 2)  # (K*K, 2)


def offsets_to_coords(
    offsets: jax.Array,
    kernel_size: int,
    variant: str,
    max_displacement: float | None = None,
) -> jax.Array:
    """Convert stage-1 offsets to absolute sampling coordinates.

    offsets: (N, H, W, L) with L = 2 (DCN-I) or 2*K*K (DCN-II).
    returns coords (N, H, W, K*K, 2) float, (row, col), clamped in-range.
    """
    n, h, w, L = offsets.shape
    kk2 = kernel_size * kernel_size
    assert L == offset_channels(kernel_size, variant), (L, variant)
    dtype = offsets.dtype

    rows = jnp.arange(h, dtype=dtype)[:, None, None]
    cols = jnp.arange(w, dtype=dtype)[None, :, None]
    centre = jnp.concatenate(
        [jnp.broadcast_to(rows, (h, w, 1)), jnp.broadcast_to(cols, (h, w, 1))],
        axis=-1,
    )  # (H, W, 2)
    taps = base_tap_grid(kernel_size, dtype)  # (KK, 2)

    if variant == "dcn1":
        off = offsets[..., None, :]                     # (N,H,W,1,2)
    else:
        off = offsets.reshape(n, h, w, kk2, 2)          # (N,H,W,KK,2)
    if max_displacement is not None:
        off = jnp.clip(off, -max_displacement, max_displacement)

    coords = centre[None, :, :, None, :] + taps[None, None, None, :, :] + off
    hi = jnp.array([h - 1, w - 1], dtype=dtype)
    return jnp.clip(coords, 0.0, hi)


def bli_coefficients(coords: jax.Array):
    """Paper Eq. 5: the four BLI coefficients eta, mu, theta, gamma.

    coords (..., 2) -> (floor_rc int32 (...,2), coeffs (..., 4)).
    Coefficient order matches neighbour order
    (r0,c0), (r0,c1), (r1,c0), (r1,c1)  =  (eta, theta, mu, gamma) with
    da = fractional col, db = fractional row.
    """
    floor_rc = jnp.floor(coords)
    frac = coords - floor_rc
    db = frac[..., 0]  # row fraction
    da = frac[..., 1]  # col fraction
    eta = (1.0 - da) * (1.0 - db)
    theta = da * (1.0 - db)
    mu = (1.0 - da) * db
    gamma = da * db
    coeffs = jnp.stack([eta, theta, mu, gamma], axis=-1)
    return floor_rc.astype(jnp.int32), coeffs


def bilinear_sample(x: jax.Array, coords: jax.Array) -> jax.Array:
    """Stage 2 (Eq. 2): sample deformed features with BLI.

    x:      (N, H, W, C)
    coords: (N, H, W, KK, 2) absolute (row, col), assumed in-range.
    -> deformed features (N, H, W, KK, C)
    """
    n, h, w, c = x.shape
    floor_rc, coeffs = bli_coefficients(coords)
    r0 = jnp.clip(floor_rc[..., 0], 0, h - 1)
    c0 = jnp.clip(floor_rc[..., 1], 0, w - 1)
    r1 = jnp.clip(r0 + 1, 0, h - 1)
    c1 = jnp.clip(c0 + 1, 0, w - 1)

    x_flat = x.reshape(n, h * w, c)

    def gather(ri, ci):
        idx = ri * w + ci  # (N,H,W,KK)
        flat = idx.reshape(n, -1)
        out = jnp.take_along_axis(x_flat, flat[..., None], axis=1)
        return out.reshape(idx.shape + (c,))

    coeffs = coeffs.astype(x.dtype)
    out = (gather(r0, c0) * coeffs[..., 0:1]
           + gather(r0, c1) * coeffs[..., 1:2]
           + gather(r1, c0) * coeffs[..., 2:3]
           + gather(r1, c1) * coeffs[..., 3:4])
    return out


def deformable_conv2d(
    x: jax.Array,
    params: DeformableConvParams,
    kernel_size: int = 3,
    variant: str = "dcn2",
    max_displacement: float | None = None,
    return_coords: bool = False,
):
    """Full deformable convolution, Eq. 1-3, XLA reference path.

    x (N,H,W,C_in) -> (N,H,W,C_out).
    """
    offsets = conv2d(x, params.w_off, params.b_off)          # Eq. 1
    coords = offsets_to_coords(
        offsets.astype(jnp.float32), kernel_size, variant, max_displacement)
    deformed = bilinear_sample(x, coords)                    # Eq. 2
    # Eq. 3: contraction over (tap, channel) == a 1x1 "im2col" matmul.
    kk2 = kernel_size * kernel_size
    w = params.w.reshape(kk2, x.shape[-1], params.w.shape[-1])
    y = jnp.einsum("nhwkc,kco->nhwo", deformed, w,
                   preferred_element_type=jnp.float32)
    y = (y + params.b).astype(x.dtype)
    if return_coords:
        return y, coords
    return y


def fused_deformable_conv2d(
    x: jax.Array,
    params: DeformableConvParams,
    kernel_size: int = 3,
    variant: str = "dcn2",
    max_displacement: float | None = None,
) -> jax.Array:
    """Stage-fused variant (paper §IV-D) on the XLA path.

    ``jax.checkpoint`` forbids saving the deformed-feature tensor — which is
    K*K times the input — so it is recomputed in the backward pass instead of
    round-tripping through HBM, mirroring the paper's BLI (+) conv fusion.
    The Pallas kernel (`repro.kernels.dcn_fused`) performs the same fusion
    explicitly in VMEM for the forward pass.
    """

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def stage23(x, params):
        offsets = conv2d(x, params.w_off, params.b_off)
        coords = offsets_to_coords(
            offsets.astype(jnp.float32), kernel_size, variant,
            max_displacement)
        deformed = bilinear_sample(x, coords)
        kk2 = kernel_size * kernel_size
        w = params.w.reshape(kk2, x.shape[-1], params.w.shape[-1])
        y = jnp.einsum("nhwkc,kco->nhwo", deformed, w,
                       preferred_element_type=jnp.float32)
        return (y + params.b).astype(x.dtype)

    return stage23(x, params)
