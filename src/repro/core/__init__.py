"""Core contribution of the paper, in JAX.

deform.py     Eq. 1-3 unified deformable-convolution model (DCN-I/II)
tiles.py      Tile Dependency Table (TDT), §IV-C Fig. 9
scheduler.py  Algorithm 1 runtime tile scheduler + FIFO buffer model
simulator.py  DRAM traffic + energy simulator (Table II model)
fusion.py     BLI (+) conv stage-fusion planner, §IV-D
"""

from repro.core.deform import (
    DeformableConvParams,
    bilinear_sample,
    bli_coefficients,
    conv2d,
    deformable_conv2d,
    fused_deformable_conv2d,
    init_deformable_conv,
    offsets_to_coords,
)
from repro.core.fusion import (
    FusionMode,
    FusionPlan,
    GroupPlan,
    LayerShape,
    plan_fused_groups,
    plan_fusion,
    plan_network,
)
from repro.core.scheduler import (
    FifoBuffer,
    TileSchedule,
    assemble_device_schedule,
    schedule_tiles,
    schedule_tiles_device,
    sequential_schedule,
)
from repro.core.simulator import (
    DramEnergyModel,
    GroupTrafficReport,
    NetworkTrafficReport,
    TrafficReport,
    dram_energy,
    simulate_group,
    simulate_network,
    simulate_strategies,
)
from repro.core.tiles import (
    TileGrid,
    access_histogram,
    compose_tdt,
    compose_tdt_chain,
    make_square_grid,
    per_pixel_input_tiles,
    tdt_from_coords,
    tdt_standard_conv,
    tile_access_histogram,
)
