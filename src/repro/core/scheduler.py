"""Runtime tile scheduler — paper §IV-C, Algorithm 1, Fig. 10.

Faithful implementation of the paper's bit-vector-based tile scheduling:

  * ``output_tile_scheduling``  — greedily pick the un-executed output tile
    whose input-tile dependency vector overlaps most with the current one
    (hardware: AND + non-zero-bit adder tree + pipelined max comparator).
  * ``input_tile_scheduling``   — order the dependent input tiles of the
    *next* output tile in three priority classes:
       1. already resident on-chip            (loadedVec)   — reuse first,
       2. everything else                     (seqLoadVec)  — middle,
       3. shared with the *current* tile but
          not resident                        (lastLoadVec) — loaded last so
          they stay resident for the upcoming reuse.
  * FIFO replacement for the on-chip input-tile buffer (paper: "An FIFO
    strategy is used for the input tile replacement for efficient hardware
    implementation").

Two backends. The default host backend is a numpy reference of the
paper's dedicated hardware block ("pre-scheduling" runs concurrently
with the PE array); ``backend="device"`` runs the same greedy selection
as a Pallas kernel (``kernels.dcn_schedule.greedy_schedule_arrays``) —
the step loop becomes the kernel grid, the resident-set bitmask lives in
VMEM, and the host only reassembles the emitted order — matching the
paper's on-chip scheduler architecture. Both backends are bit-exact:
they produce byte-identical ``TileSchedule``s on every input. On TPU the
schedule orders the Pallas grid / DMA sequence (see DESIGN.md §2).

The module also provides the two ablation baselines of paper Fig. 14-16:
``sequential_schedule`` (W/ bit vector + W/O scheduling) and the naive
per-pixel path lives in ``repro.core.simulator``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.obs import default_registry

# Instrumentation: counts host-side ``TileSchedule`` constructions.
#
# The batch-fused executors promise a zero-host-round-trip hot path with
# ``schedule_backend="device"`` — device schedule arrays flow straight
# into the dispatch operands, and the Python ``TileSchedule`` is only
# assembled lazily for traces. Tests pin that promise by snapshotting
# this counter around an executor call; it lives in the process-wide
# ``repro.obs`` registry so metrics snapshots carry it too.
host_schedule_builds = default_registry().counter(
    "host_schedule_builds",
    help="host-side TileSchedule constructions (0 on the device "
         "scheduling hot path)")


def pow2_pad(x: int) -> int:
    """Smallest power of two >= max(1, x) — the packed dep-slot padding
    policy shared by the schedule and both executors (uniform packed
    geometry -> one kernel compilation per layer)."""
    return 1 << (max(1, x) - 1).bit_length()


@dataclass
class TileSchedule:
    """Result of Algorithm 1.

    oid:  execution order of output tiles (len = #output tiles with deps).
    iid:  per scheduled output tile, the ordered list of its dependent
          input tiles (priority classes already applied).
    """

    oid: list[int]
    iid: list[list[int]]
    # Diagnostics filled by the scheduler:
    # Per transition: |B[curr] & B[next]|
    reuse_overlap: list[int] = field(default_factory=list)

    def dense(self, k_pad: int | None = None
              ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Schedule as dense arrays for batched grid dispatch.

        The batched executor feeds the schedule to ONE ``pallas_call``
        whose leading grid dimension is the scheduled-tile index, so it
        needs arrays, not Python lists:

          oid    (T,)        int32 — output tiles in execution order
          deps   (T, k_pad)  int32 — dependent input tiles in load order,
                                     rows zero-padded past their count
          counts (T,)        int32 — true dep count per scheduled tile

        ``k_pad`` defaults to the max dep count rounded up to a power of
        two (uniform packed-buffer geometry -> one kernel compilation).
        """
        t = len(self.oid)
        k_max = max((len(d) for d in self.iid), default=1)
        if k_pad is None:
            k_pad = pow2_pad(k_max)
        elif k_pad < k_max:
            raise ValueError(f"k_pad={k_pad} below max dep count {k_max}")
        oid = np.asarray(self.oid, np.int32).reshape(t)
        deps = np.zeros((t, k_pad), np.int32)
        counts = np.zeros((t,), np.int32)
        for n, d in enumerate(self.iid):
            deps[n, :len(d)] = d
            counts[n] = len(d)
        return oid, deps, counts


class FifoBuffer:
    """FIFO-replacement on-chip tile buffer model (capacity = M tiles)."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("buffer capacity must be >= 1 tile")
        self.capacity = int(capacity)
        self.queue: list[int] = []  # front = oldest
        self.resident: set[int] = set()
        self.loads = 0  # number of DRAM tile loads issued
        self.hits = 0   # number of on-chip reuse hits

    def touch(self, tile: int) -> bool:
        """Access ``tile``; load it if absent. Returns True on a hit."""
        if tile in self.resident:
            self.hits += 1
            return True
        self.loads += 1
        if len(self.queue) >= self.capacity:
            evicted = self.queue.pop(0)
            self.resident.discard(evicted)
        self.queue.append(tile)
        self.resident.add(tile)
        return False

    def occupancy_vector(self, n: int) -> np.ndarray:
        oc = np.zeros(n, dtype=bool)
        oc[list(self.resident)] = True
        return oc


def _ids_of(vec: np.ndarray) -> list[int]:
    return np.flatnonzero(vec).tolist()


def output_tile_scheduling(B: np.ndarray, os_mask: np.ndarray,
                           curr_id: int) -> int:
    """Algorithm 1, procedure output_tile_scheduling.

    Picks the un-executed output tile with the largest dependency overlap
    with ``curr_id``. Ties are broken by the lowest tile id (the paper's
    pipelined comparator keeps the first maximum).
    """
    overlap = (B & B[curr_id]).sum(axis=1)
    overlap[~os_mask] = -1
    return int(np.argmax(overlap))


def input_tile_scheduling(B: np.ndarray, curr_id: int, next_id: int,
                          oc: np.ndarray) -> list[int]:
    """Algorithm 1, procedure input_tile_scheduling (3 priority classes)."""
    loaded_vec = oc & B[next_id]
    last_load_vec = B[curr_id] & B[next_id] & ~loaded_vec
    seq_load_vec = B[next_id] & ~loaded_vec & ~last_load_vec
    return _ids_of(loaded_vec) + _ids_of(seq_load_vec) + _ids_of(last_load_vec)


def schedule_tiles(B, buffer_tiles: int, backend: str = "host",
                   *, interpret: bool | None = None) -> TileSchedule:
    """Full Algorithm 1: bit-vector based tile scheduling.

    B: (n_out, n_in) bool tile-dependency table (TDT). May be a device
       array (it stays on-device for ``backend="device"``).
    buffer_tiles: M, on-chip input-buffer capacity in tiles.
    backend: "host" — the numpy reference loop below; "device" — the
       Pallas greedy-selection kernel (bit-exact with the host loop; see
       :func:`schedule_tiles_device`). ``interpret`` only applies to the
       device backend (None = auto: interpret off-accelerator).

    Returns the output-tile execution order and the per-tile input-load
    order. The on-chip occupancy OC used for the priority classes is
    maintained with the same FIFO model the execution will use.
    """
    if backend == "device":
        return schedule_tiles_device(B, buffer_tiles, interpret=interpret)
    if backend != "host":
        raise ValueError(f"unknown schedule backend: {backend!r}")
    B = np.asarray(B, dtype=bool)
    n_out, n_in = B.shape
    os_mask = B.any(axis=1)  # output tiles that actually need inputs
    buf = FifoBuffer(buffer_tiles)

    # line 2: first output tile = the one requiring the most input tiles.
    first = int(np.argmax(np.where(os_mask, B.sum(axis=1), -1)))
    oid = [first]
    iid = [_ids_of(B[first])]
    overlaps: list[int] = []
    for t in iid[0]:
        buf.touch(t)
    os_mask[first] = False

    while os_mask.any():
        curr = oid[-1]
        nxt = output_tile_scheduling(B, os_mask, curr)
        oc = buf.occupancy_vector(n_in)
        order = input_tile_scheduling(B, curr, nxt, oc)
        oid.append(nxt)
        iid.append(order)
        overlaps.append(int((B[curr] & B[nxt]).sum()))
        for t in order:
            buf.touch(t)
        os_mask[nxt] = False

    host_schedule_builds.bump()
    return TileSchedule(oid=oid, iid=iid, reuse_overlap=overlaps)


def assemble_device_schedule(oid_seq: np.ndarray, klass: np.ndarray,
                             overlap: np.ndarray) -> TileSchedule:
    """Assemble a ``TileSchedule`` from the device greedy kernel's dense
    outputs (``kernels.dcn_schedule.greedy_schedule_arrays``).

    oid_seq: (n_out,) or (n_out, 1) int32 — scheduled tile per step, -1
             once every dependent tile is done (a contiguous suffix).
    klass:   (n_out, n_in) int32 — per-step input priority class
             (0 loadedVec / 1 seqLoadVec / 2 lastLoadVec / 3 non-dep);
             the load order is ids(0) asc ++ ids(1) asc ++ ids(2) asc,
             exactly ``input_tile_scheduling``'s three classes.
    overlap: (n_out,) or (n_out, 1) int32 — per-step reuse overlap.

    This residual host work is O(total deps) bookkeeping — the O(T^2 *
    n_in) selection ran on-device.
    """
    oid_seq = np.asarray(oid_seq).reshape(-1)
    klass = np.asarray(klass)
    overlap = np.asarray(overlap).reshape(-1)
    n_sched = int((oid_seq >= 0).sum())
    iid = []
    for t in range(n_sched):
        row = klass[t]
        iid.append(np.flatnonzero(row == 0).tolist()
                   + np.flatnonzero(row == 1).tolist()
                   + np.flatnonzero(row == 2).tolist())
    host_schedule_builds.bump()
    return TileSchedule(oid=oid_seq[:n_sched].tolist(), iid=iid,
                        reuse_overlap=overlap[1:n_sched].tolist())


def schedule_tiles_device(B, buffer_tiles: int,
                          *, interpret: bool | None = None) -> TileSchedule:
    """Algorithm 1 via the on-device greedy selection kernel.

    Bit-exact vs the host ``schedule_tiles`` loop on every TDT: same
    first-tile choice, same first-max tie-breaks, same three input
    priority classes under the same FIFO residency model (the kernel
    tracks it as per-tile load sequence numbers in VMEM).
    """
    # Imported lazily: the numpy host path must stay importable without
    # pulling the Pallas toolchain in.
    import jax

    from repro.kernels.dcn_schedule import greedy_schedule_arrays

    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    oid_seq, klass, ovl = greedy_schedule_arrays(
        jax.numpy.asarray(B), int(buffer_tiles), interpret=bool(interpret))
    return assemble_device_schedule(np.asarray(oid_seq), np.asarray(klass),
                                    np.asarray(ovl))


def sequential_schedule(B: np.ndarray) -> TileSchedule:
    """Ablation baseline: 'W/ bit vector + W/O scheduling' (paper Fig. 14).

    Output tiles execute in sequential id order; each loads its dependent
    input tiles (deduplicated via the TDT) in ascending id order.
    """
    B = np.asarray(B, dtype=bool)
    oid = [o for o in range(B.shape[0]) if B[o].any()]
    iid = [_ids_of(B[o]) for o in oid]
    host_schedule_builds.bump()
    return TileSchedule(oid=oid, iid=iid)


# ---------------------------------------------------------------------------
# Dense device-schedule handoff (batch-fused dispatch, zero host round-trip)
# ---------------------------------------------------------------------------


@dataclass
class DeviceSchedule:
    """Algorithm-1 schedule as dense dispatch-ready arrays.

    The batch-fused executors consume schedules in exactly the dense form
    the batched kernel's scalar-prefetch machinery needs, so with
    ``schedule_backend="device"`` the greedy kernel's outputs flow here
    as device arrays end-to-end — no host reassembly, no Python
    ``TileSchedule`` on the hot path. All arrays have ``n_out`` rows
    (one per possible scheduling step); the padded suffix past the real
    schedule length carries ``oid = -1`` / ``dep_cnt = 0`` and is what
    ragged batch concatenation elides.

      oid     (n_out,)        int32 — scheduled tile per step, -1 padding
      dep_tbl (n_out, k_pad)  int32 — dependent input tiles in LOAD order
                                      (the three Algorithm-1 priority
                                      classes), rows zero-padded
      dep_cnt (n_out,)        int32 — true dep count per step
      overlap (n_out,)        int32 — per-step reuse overlap diagnostic

    Arrays may live on device (jax) or host (numpy) — both backends emit
    bit-identical values. ``to_host()`` lazily assembles the classic
    ``TileSchedule`` for traces and simulator cross-checks.
    """

    oid: Any
    dep_tbl: Any
    dep_cnt: Any
    overlap: Any
    _host: TileSchedule | None = None

    @property
    def n_rows(self) -> int:
        return int(self.oid.shape[0])

    @property
    def k_pad(self) -> int:
        return int(self.dep_tbl.shape[1])

    def to_host(self) -> TileSchedule:
        """Assemble (and memoize) the host ``TileSchedule`` — OFF the hot
        path: traces and cross-checks only."""
        if self._host is None:
            oid = np.asarray(self.oid).reshape(-1)
            dep = np.asarray(self.dep_tbl)
            cnt = np.asarray(self.dep_cnt).reshape(-1)
            ovl = np.asarray(self.overlap).reshape(-1)
            n_sched = int((oid >= 0).sum())
            host_schedule_builds.bump()
            self._host = TileSchedule(
                oid=oid[:n_sched].tolist(),
                iid=[dep[t, :cnt[t]].tolist() for t in range(n_sched)],
                reuse_overlap=ovl[1:n_sched].tolist())
        return self._host

    @classmethod
    def from_host(cls, sched: TileSchedule, n_out: int,
                  k_pad: int | None = None) -> "DeviceSchedule":
        """Dense padded form of a host-built schedule (numpy arrays).

        Pads to ``n_out`` rows so batch concatenation sees the same
        uniform per-image row count as the device path.
        """
        t = len(sched.oid)
        if t > n_out:
            raise ValueError(f"schedule has {t} steps > n_out={n_out}")
        oid_d, deps_d, cnt_d = sched.dense(k_pad)
        oid = np.full((n_out,), -1, np.int32)
        oid[:t] = oid_d
        dep_tbl = np.zeros((n_out, deps_d.shape[1]), np.int32)
        dep_tbl[:t] = deps_d
        cnt = np.zeros((n_out,), np.int32)
        cnt[:t] = cnt_d
        ovl = np.zeros((n_out,), np.int32)
        ro = np.asarray(sched.reuse_overlap[:max(t - 1, 0)], np.int32)
        ovl[1:1 + ro.size] = ro   # sequential schedules carry no overlaps
        return cls(oid, dep_tbl, cnt, ovl, _host=sched)


def schedule_arrays_device(B, m: int, *, k_pad: int | None = None,
                           interpret: bool | None = None) -> DeviceSchedule:
    """Algorithm 1 on-device, emitted directly as dispatch arrays.

    Unlike :func:`schedule_tiles_device` the result never touches the
    host: ``greedy_schedule_arrays`` runs the selection, and the class
    rows are converted to load-ordered dep tables with a stable device
    argsort (``kernels.dcn_schedule.dispatch_arrays_from_klass``).
    ``k_pad`` defaults to ``pow2_pad(n_in)`` — static, so no host sync
    on the data-dependent max dep count.
    """
    import jax

    from repro.kernels.dcn_schedule import (dispatch_arrays_from_klass,
                                            greedy_schedule_arrays)

    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B = jax.numpy.asarray(B)
    n_in = B.shape[1]
    if k_pad is None:
        k_pad = pow2_pad(n_in)
    oid_seq, klass, ovl = greedy_schedule_arrays(
        B, int(m), interpret=bool(interpret))
    oid, dep_tbl, cnt = dispatch_arrays_from_klass(oid_seq, klass, k_pad)
    return DeviceSchedule(oid, dep_tbl, cnt, ovl.reshape(-1))
