"""Elastic scaling + failure handling policy (DESIGN.md §5).

At 1000+-node scale the failure model is: a pod/host drops mid-run
(preemption or hardware), or a straggler slows the synchronous step.
The framework's posture:

  * **Checkpoint/restart** — atomic keep-N checkpoints (repro.checkpoint)
    plus a preemption hook: on SIGTERM the trainer finishes the in-flight
    step, writes a checkpoint, and exits 42 (the launcher treats 42 as
    "clean preemption, reschedule").
  * **Elastic re-mesh** — checkpoints are topology-free (unsharded
    arrays), so a restart may target a *different* mesh. ``plan_remesh``
    picks the largest usable (data, model) grid for the surviving chip
    count; ``restore`` device_puts every leaf with the new shardings.
    Tested 8 -> 4 fake devices in tests/test_distributed.py.
  * **Straggler mitigation** — synchronous SPMD steps can't drop a slow
    worker mid-step, so mitigation is between steps: the trainer tracks a
    rolling step-time EWMA; when a step exceeds ``straggler_factor`` x
    EWMA more than ``patience`` times, it checkpoints and requests a
    re-mesh excluding the slow host (the launcher decides replacement).
    This is the standard TPU-pod policy: detect, drain, reshard.
"""

from __future__ import annotations

import dataclasses
import signal


@dataclasses.dataclass
class ElasticConfig:
    straggler_factor: float = 2.0
    patience: int = 3
    ewma_alpha: float = 0.1
    min_model_parallel: int = 1


class PreemptionGuard:
    """SIGTERM -> finish step, checkpoint, exit(42)."""

    def __init__(self):
        self.requested = False
        try:
            signal.signal(signal.SIGTERM, self._handler)
        except ValueError:  # not in main thread (tests)
            pass

    def _handler(self, signum, frame):
        self.requested = True


class StragglerDetector:
    def __init__(self, cfg: ElasticConfig):
        self.cfg = cfg
        self.ewma: float | None = None
        self.strikes = 0

    def observe(self, step_time_s: float) -> bool:
        """Returns True when a re-mesh is recommended."""
        if self.ewma is None:
            self.ewma = step_time_s
            return False
        slow = step_time_s > self.cfg.straggler_factor * self.ewma
        self.strikes = self.strikes + 1 if slow else 0
        self.ewma = ((1 - self.cfg.ewma_alpha) * self.ewma
                     + self.cfg.ewma_alpha * step_time_s)
        return self.strikes >= self.cfg.patience


def plan_remesh(n_chips: int, model_parallel: int,
                cfg: ElasticConfig | None = None) -> tuple[int, int]:
    """Largest (data, model) grid for the surviving chip count.

    Keeps model_parallel if it divides the chip count; otherwise halves it
    until it fits (param shards must still be gatherable, which the
    topology-free checkpoints guarantee).
    """
    cfg = cfg or ElasticConfig()
    mp = model_parallel
    while mp > cfg.min_model_parallel and n_chips % mp:
        mp //= 2
    mp = max(mp, cfg.min_model_parallel)
    data = max(n_chips // mp, 1)
    return data, mp
