"""jit-compiled train / prefill / decode steps with explicit shardings.

``build_step`` is the single entry point used by the trainer, the server
and the dry-run: it resolves the sharding rule table for (config, shape),
builds abstract inputs, and returns a jit'd function plus everything
needed to ``.lower().compile()`` it without allocating a single parameter
(ShapeDtypeStruct end to end).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import base as cfgbase
from repro.models import lm
from repro.models.params import abstract_params, param_axes, tree_specs
from repro.models.transformer import ModelConfig
from repro.optim import AdamWConfig, abstract_opt_state, adamw_update
from repro.launch.sharding import sharding_rules


@dataclasses.dataclass
class StepBundle:
    fn: Callable                  # jit'd
    args_abstract: tuple          # matching abstract args
    in_shardings: tuple
    out_shardings: Any
    rules: dict
    param_shardings: Any = None
    opt_shardings: Any = None


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _replicated_like(mesh, struct):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), struct)


def build_step(cfg: ModelConfig, shape: cfgbase.ShapeCell, mesh,
               opt_cfg: AdamWConfig | None = None,
               param_dtype=jnp.bfloat16,
               donate: bool = True,
               rules_override: dict | None = None) -> StepBundle:
    long_ctx = shape.name == "long_500k"
    rules = rules_override if rules_override is not None else \
        sharding_rules(cfg, kind=shape.kind, long_ctx=long_ctx)

    axes = param_axes(lambda mk: lm.init_lm(mk, cfg))
    params_ab = abstract_params(lambda mk: lm.init_lm(mk, cfg),
                                dtype=param_dtype)
    pspecs = tree_specs(axes, params_ab, rules, mesh)
    pshard = _ns(mesh, pspecs)

    in_ab = cfgbase.input_specs(cfg, shape)
    in_axes_tree = cfgbase.input_axes(cfg, shape)
    in_specs = tree_specs(in_axes_tree, in_ab, rules, mesh)
    in_shard = _ns(mesh, in_specs)

    batch_axes = rules.get("batch") or ()
    if isinstance(batch_axes, str):
        batch_axes = (batch_axes,)
    dp = tuple(a for a in batch_axes if a in mesh.shape)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    ctx = {"mesh": mesh, "act_pspec": P(dp_spec, None, None)}

    if shape.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        opt_ab = abstract_opt_state(params_ab, opt_cfg)
        ospecs = {"step": P(), "m": pspecs, "v": pspecs}
        oshard = _ns(mesh, ospecs)

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                return lm.lm_loss(p, cfg, batch, ctx)
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            new_p, new_o, om = adamw_update(params, grads, opt_state, opt_cfg)
            return new_p, new_o, {**metrics, **om}

        with mesh:
            out_struct = jax.eval_shape(train_step, params_ab, opt_ab,
                                        in_ab["batch"])
        out_shard = (pshard, oshard, _replicated_like(mesh, out_struct[2]))
        fn = jax.jit(train_step,
                     in_shardings=(pshard, oshard, in_shard["batch"]),
                     out_shardings=out_shard,
                     donate_argnums=(0, 1) if donate else ())
        return StepBundle(fn, (params_ab, opt_ab, in_ab["batch"]),
                          (pshard, oshard, in_shard["batch"]), out_shard,
                          rules, pshard, oshard)

    def _logits_shard():
        import math
        b = shape.global_batch
        dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1
        dp_ok = bool(dp) and b % dp_size == 0
        v_ok = cfg.vocab % mesh.shape.get("model", 1) == 0
        return NamedSharding(mesh, P(dp_spec if dp_ok else None, None,
                                     "model" if v_ok else None))

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return lm.lm_prefill(params, cfg, batch, ctx)

        logits_shard = _logits_shard()
        fn = jax.jit(prefill_step,
                     in_shardings=(pshard, in_shard["batch"]),
                     out_shardings=logits_shard)
        return StepBundle(fn, (params_ab, in_ab["batch"]),
                          (pshard, in_shard["batch"]), logits_shard,
                          rules, pshard)

    # decode
    def decode_step(params, cache, token, pos):
        return lm.lm_decode_step(params, cfg, cache, token, pos, ctx)

    out_shard = (_logits_shard(), in_shard["cache"])
    fn = jax.jit(decode_step,
                 in_shardings=(pshard, in_shard["cache"],
                               in_shard["token"], in_shard["pos"]),
                 out_shardings=out_shard,
                 donate_argnums=(1,) if donate else ())
    return StepBundle(fn, (params_ab, in_ab["cache"], in_ab["token"],
                           in_ab["pos"]),
                      (pshard, in_shard["cache"], in_shard["token"],
                       in_shard["pos"]),
                      out_shard, rules, pshard)
