"""Logical-axis -> mesh sharding rules (MaxText-style), per arch x shape.

Rules tables map the logical axes recorded at param-init time
(repro.models.params) to mesh axes. ``resolve_spec`` enforces
divisibility + one-mesh-axis-per-spec, so e.g. smollm's 15 heads simply
degrade to replication instead of failing to lower.

Policy (DESIGN.md §5):
  * activations: batch over ("pod","data"); TP over "model".
  * weights: TP dims (heads / mlp / vocab / expert) over "model"; for
    >=8B-param archs the d_model dim is additionally FSDP-sharded over
    ("pod","data") — GSPMD all-gathers one layer's weights just-in-time
    inside the scan (the scan structure bounds the transient).
  * decode caches: kv_seq over "model" (flash-decode-style split-S: every
    chip holds a slice of every sequence's cache and attention psums over
    "model"), except long_500k which spreads 512k tokens over
    ("data","model") = 256-way.
  * optimizer states m/v inherit the param specs verbatim.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import lm
from repro.models.params import (abstract_params, param_axes, param_bytes,
                                 tree_specs)
from repro.models.transformer import ModelConfig

FSDP_BYTES_THRESHOLD = 8e9  # params sizes above this get FSDP'd d_model


def sharding_rules(cfg: ModelConfig, *, kind: str = "train",
                   long_ctx: bool = False,
                   fsdp: bool | None = None) -> dict:
    if fsdp is None:
        ab = abstract_params(lambda mk: lm.init_lm(mk, cfg),
                             dtype=jax.numpy.bfloat16)
        fsdp = param_bytes(ab) > FSDP_BYTES_THRESHOLD
    dp = ("pod", "data")
    rules = {
        "batch": dp,
        "vocab": "model",
        "embed": dp if fsdp else None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "expert": "model",
        "embed_fsdp": dp,      # MoE expert weights always FSDP (they dominate)
        "mlp_fsdp": dp,
        "q_lora": None,
        "kv_lora": None,
        "layers": None,        # scan axis — never mesh-sharded
        "heads_inner": "model",
        "codebook": None,
        "kv_seq": (("data", "model") if long_ctx
                   else ("model" if kind == "decode" else None)),
    }
    return rules


def param_tree_specs(cfg: ModelConfig, mesh, rules, dtype=jax.numpy.bfloat16):
    axes = param_axes(lambda mk: lm.init_lm(mk, cfg))
    ab = abstract_params(lambda mk: lm.init_lm(mk, cfg), dtype=dtype)
    return tree_specs(axes, ab, rules, mesh), ab


def to_shardings(spec_tree, mesh):
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(cfg: ModelConfig, mesh, rules, input_axes_tree,
                input_specs_tree):
    return tree_specs(input_axes_tree, input_specs_tree, rules, mesh)
