"""Production mesh construction (DESIGN.md §5).

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis crosses the DCI; only DP gradient all-reduce (optionally int8-
compressed, repro.optim.compression) travels on it.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first jax init, and only
launch/dryrun.py forces 512 host devices).
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (fake) host devices exist — used by
    smoke/distributed tests (8 fake devices), the scale-out executor
    path (``data_parallel=``) and single-device runs.

    Validates the request against the live device count up front: the
    raw ``make_mesh`` reshape error ("cannot reshape array of size 1
    into shape (2, 1)") says nothing about WHY there aren't enough
    devices or how to get more on a CPU host.
    """
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got data={data}, "
                         f"model={model}")
    have = jax.device_count()
    if data * model > have:
        raise ValueError(
            f"make_host_mesh(data={data}, model={model}) needs "
            f"{data * model} devices but only {have} "
            f"{'is' if have == 1 else 'are'} available — launch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{data * model} (set before jax initialises) or shrink "
            f"the mesh")
    return make_mesh((data, model), ("data", "model"))


def mesh_chips(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
