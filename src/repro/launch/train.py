"""Training driver: data pipeline -> jit train step -> checkpoints.

Runs any --arch at any scale: on this CPU container it trains the smoke
configs for real (examples/train_lm.py uses it); on a pod it is the same
code path the dry-run lowers (launch.steps.build_step).

Fault tolerance: resumes from the newest complete checkpoint, writes
atomically every --ckpt-every steps (async), handles SIGTERM preemption,
and watches for stragglers (launch.elastic).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro import configs
from repro.configs.base import ShapeCell
from repro.data import DataConfig, PrefetchIterator, token_batch
from repro.launch.elastic import (ElasticConfig, PreemptionGuard,
                                  StragglerDetector)
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_step
from repro.models import lm
from repro.models.params import Maker
from repro.optim import AdamWConfig, init_opt_state


def train_loop(cfg, shape: ShapeCell, mesh, *, steps: int = 20,
               opt_cfg: AdamWConfig | None = None, ckpt_dir: str | None = None,
               ckpt_every: int = 10, seed: int = 0, log_every: int = 5,
               param_dtype=jnp.float32, verbose: bool = True):
    opt_cfg = opt_cfg or AdamWConfig(total_steps=steps)
    bundle = build_step(cfg, shape, mesh, opt_cfg=opt_cfg,
                        param_dtype=param_dtype, donate=False)

    params = lm.init_lm(Maker("init", jax.random.PRNGKey(seed), param_dtype),
                        cfg)
    opt_state = init_opt_state(params, opt_cfg)

    start_step = 0
    ckptr = None
    if ckpt_dir:
        ckptr = ckpt.AsyncCheckpointer(ckpt_dir)
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            state = ckpt.restore(ckpt_dir, latest,
                                 {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = latest
            if verbose:
                print(f"[train] resumed from step {latest}")

    dcfg = DataConfig(seed=seed, vocab=cfg.vocab, seq=shape.seq,
                      global_batch=shape.global_batch,
                      n_codebooks=cfg.n_codebooks,
                      cross_tokens=cfg.n_cross_tokens if cfg.d_cross else 0,
                      cross_dim=cfg.d_cross or 0)
    data = PrefetchIterator(lambda s: token_batch(dcfg, s),
                            start_step=start_step)
    guard = PreemptionGuard()
    straggler = StragglerDetector(ElasticConfig())

    losses = []
    with mesh:
        for _ in range(start_step, steps):
            step_id, batch = next(data)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.time()
            params, opt_state, metrics = bundle.fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            if verbose and (step_id % log_every == 0 or step_id == steps - 1):
                print(f"[train] step {step_id:5d} loss={loss:.4f} "
                      f"({dt*1e3:.0f} ms)", flush=True)
            if ckptr and (step_id + 1) % ckpt_every == 0:
                ckptr.save(step_id + 1, {"params": params, "opt": opt_state})
            if straggler.observe(dt) and verbose:
                print(f"[train] straggler detected at step {step_id}; "
                      "re-mesh recommended (launch.elastic)", flush=True)
            if guard.requested:
                if ckptr:
                    ckptr.save(step_id + 1, {"params": params,
                                             "opt": opt_state})
                    ckptr.wait()
                if verbose:
                    print("[train] preemption: checkpointed, exiting 42")
                raise SystemExit(42)
    data.close()
    if ckptr:
        ckptr.wait()
    return params, opt_state, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    shape = ShapeCell("cli_train", "train", args.seq, args.batch)
    mesh = make_host_mesh(args.data_parallel, args.model_parallel)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps)
    _, _, losses = train_loop(cfg, shape, mesh, steps=args.steps,
                              opt_cfg=opt, ckpt_dir=args.ckpt_dir)
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
