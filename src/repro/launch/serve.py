"""Serving driver: load/initialize a model, run the batched decode engine.

CPU-runnable with smoke configs (examples/serve_lm.py); the decode_32k /
long_500k dry-run cells lower the same lm_decode_step this engine calls.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.params import Maker
from repro.serving import DecodeEngine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, smoke=True)
    params = lm.init_lm(Maker("init", jax.random.PRNGKey(args.seed)), cfg)
    mesh = make_host_mesh(1, 1)
    engine = DecodeEngine(params, cfg, batch=args.batch,
                          max_len=args.max_len, mesh=mesh)

    rng = jax.random.PRNGKey(args.seed + 1)
    for rid in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = int(jax.random.randint(k, (), 2, 8))
        if cfg.n_codebooks > 1:
            prompt = jax.random.randint(k, (plen, cfg.n_codebooks), 0,
                                        cfg.vocab).tolist()
        else:
            prompt = jax.random.randint(k, (plen,), 0, cfg.vocab).tolist()
        engine.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))

    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, continuous batching over "
          f"{args.batch} slots)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt_len={len(r.prompt)} out={r.out[:8]}…")


if __name__ == "__main__":
    main()
