"""Structural analysis of compiled (post-SPMD) HLO text.

``jax.stages.Compiled.cost_analysis`` counts while-loop bodies ONCE, so a
pattern-scanned 61-layer model under-reports flops ~60x. This module
re-derives per-device roofline inputs directly from the optimized HLO:

  * builds the computation call graph (ENTRY -> fusions / while bodies),
  * extracts ``known_trip_count`` from each while's backend_config and
    propagates execution multipliers down the graph,
  * counts matmul flops exactly from dot shapes + contracting dims
    (2*M*N*K, with K looked up from the per-computation symbol table),
  * sums collective payload bytes per kind (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute),
  * sums an HBM-traffic proxy: operand+result bytes of every top-level
    fusion / dot / copy / DUS / gather / scatter / collective (on TPU each
    such op is one HBM round trip; elementwise interiors are fused).

Shapes in post-SPMD HLO are per-device, so every number here is per-chip.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# HBM round-trip proxies. Excluded on purpose: reshape/bitcast (free),
# broadcast/iota/transpose/slice (fused into consumers by the TPU
# backend; standalone only in CPU HLO).
_TRAFFIC_OPS = _COLLECTIVES + (
    "fusion", "dot", "convolution", "copy", "dynamic-update-slice",
    "dynamic-slice", "gather", "scatter", "sort", "reduce", "concatenate",
    "select-and-scatter")

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
                "f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OP_RE = re.compile(r"^([a-z][\w\-]*)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_FRAME_ID_RE = re.compile(r"stack_frame_id=(\d+)")

# ops originating in these functions are attention score/softmax chains
# that the Pallas flash kernel keeps in VMEM on real TPU (DESIGN.md §6b)
_ATTN_FUNCS = ("_sdpa", "_sdpa_chunked", "attention_ref", "mla_train",
               "sdpa_any", "_mlstm_chunk")


def parse_stack_tables(text: str) -> dict[int, str]:
    """HLO-header FileNames/FunctionNames/FileLocations/StackFrames tables
    -> {stack_frame_id: "fn_a;fn_b;..."} (frame + ancestors)."""
    sections: dict[str, dict[int, str]] = {"FunctionNames": {}}
    locs: dict[int, int] = {}     # file_location_id -> function_name_id
    frames: dict[int, tuple[int, int]] = {}  # frame -> (loc, parent)
    mode = None
    for line in text.splitlines():
        s = line.strip()
        if s in ("FileNames", "FunctionNames", "FileLocations",
                 "StackFrames"):
            mode = s
            continue
        if not s:
            if mode:
                mode = None
            continue
        if mode == "FunctionNames":
            m = re.match(r'(\d+)\s+"(.*)"', s)
            if m:
                sections["FunctionNames"][int(m.group(1))] = m.group(2)
        elif mode == "FileLocations":
            m = re.match(r"(\d+)\s+\{.*function_name_id=(\d+)", s)
            if m:
                locs[int(m.group(1))] = int(m.group(2))
        elif mode == "StackFrames":
            m = re.match(r"(\d+)\s+\{file_location_id=(\d+)"
                         r"(?:\s+parent_frame_id=(\d+))?", s)
            if m:
                frames[int(m.group(1))] = (int(m.group(2)),
                                           int(m.group(3) or 0))
        elif s.startswith("%") or s.startswith("ENTRY"):
            break  # computations begin; tables are done

    fnames = sections["FunctionNames"]
    out: dict[int, str] = {}
    for fid in frames:
        chain, cur, hops = [], fid, 0
        while cur and hops < 50:
            loc, parent = frames.get(cur, (0, 0))
            fn = fnames.get(locs.get(loc, -1))
            if fn:
                chain.append(fn)
            if parent == cur:
                break
            cur, hops = parent, hops + 1
        out[fid] = ";".join(chain)
    return out
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply)=%([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _split_def(line: str):
    """'%x = TYPE op(...)...' -> (name, type_str, op, rest) or None.

    TYPE may be a parenthesized tuple containing '/*index=N*/' comments —
    handled by paren balancing, not regex.
    """
    mn = _NAME_RE.match(line)
    if not mn:
        return None
    rest = line[mn.end():]
    if rest.startswith("("):  # tuple type: find matching close paren
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str = rest[:i + 1]
                    tail = rest[i + 1:].lstrip()
                    break
        else:
            return None
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        tail = rest[sp + 1:].lstrip()
    mo = _OP_RE.match(tail)
    if not mo:
        return None
    return mn.group(1), type_str, mo.group(1), tail[mo.end() - 1:]


def _type_dims(type_str: str):
    """'f32[16,128]{1,0}' -> ('f32', (16, 128)); tuples -> sum via list."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, shape in _type_dims(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class OpRecord:
    op: str
    out_type: str
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    frame_id: int = 0
    calls: list = dataclasses.field(default_factory=list)  # (name, trips)


@dataclasses.dataclass
class Computation:
    name: str
    ops: list


def _parse_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    symbols: dict[str, str] = {}
    entry_name = None
    for raw in text.splitlines():
        mc = _COMP_RE.match(raw)
        if mc:
            cur = Computation(mc.group(1), [])
            comps[cur.name] = cur
            symbols = {}
            if raw.startswith("ENTRY"):
                entry_name = cur.name
            continue
        if cur is None:
            continue
        if raw.startswith("}"):
            cur = None
            continue
        md = _split_def(raw)
        if not md:
            continue
        name, out_type, op, tail = md
        symbols[name] = out_type
        rec = OpRecord(op=op, out_type=out_type)
        mf = _FRAME_ID_RE.search(raw)
        if mf:
            rec.frame_id = int(mf.group(1))

        if op in ("while", "fusion", "call", "conditional", "reduce",
                  "sort", "scatter", "select-and-scatter",
                  "reduce-scatter", "all-reduce", "map"):
            trips = 1
            mt = _TRIP_RE.search(raw)
            if mt:
                trips = int(mt.group(1))
            for cm in _CALL_RE.finditer(raw):
                rec.calls.append((cm.group(1), trips if op == "while" else 1))

        if op == "dot":
            out_elems = 1
            for _, shape in _type_dims(out_type):
                for d in shape:
                    out_elems *= d
            k = 1
            ml = _LHS_CONTRACT_RE.search(raw)
            operands = _OPERAND_RE.findall(tail.split(")")[0])
            if ml and operands:
                lhs_type = symbols.get(operands[0])
                if lhs_type:
                    dims = _type_dims(lhs_type)
                    if dims:
                        shape = dims[0][1]
                        for ci in (int(c)
                                   for c in ml.group(1).split(",") if c):
                            if ci < len(shape):
                                k *= shape[ci]
            rec.flops = 2.0 * out_elems * k

        if op in _COLLECTIVES or (op + "-start") in _COLLECTIVES:
            rec.collective_bytes = float(_type_bytes(out_type))
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES:
            rec.collective_bytes = float(_type_bytes(out_type))
            rec.op = base
        if base in _TRAFFIC_OPS:
            # result + named-operand bytes (operands resolved when local)
            tb = _type_bytes(out_type)
            for on in _OPERAND_RE.findall(tail.split(")")[0]):
                t = symbols.get(on)
                if t:
                    tb += _type_bytes(t)
            rec.traffic_bytes = float(tb)
        cur.ops.append(rec)
    return comps, entry_name


def _is_score_shaped(type_str: str, score_dims: set[int]) -> bool:
    """True when any tensor in the type is an attention score matrix:
    the two trailing dims are both sequence/chunk lengths (e.g.
    (B,H,S,S) logits, (B,H,S,ck) chunked scores, (S,S) masks) and the
    tensor is large. Structural — survives fusion/CSE metadata hoisting.
    """
    for _, dims in _type_dims(type_str):
        if len(dims) < 2:
            continue
        n = 1
        for d in dims:
            n *= d
        if (dims[-1] in score_dims and dims[-2] in score_dims
                and n >= 1 << 20):
            return True
    return False


def analyze_hlo(text: str, score_dims: set[int] | None = None) -> dict:
    comps, entry = _parse_computations(text)
    if entry is None:
        return {"flops": 0.0, "traffic_bytes": 0.0, "attn_traffic_bytes": 0.0,
                "traffic_by_kind": {}, "collective_bytes": 0.0,
                "collectives": {}}
    score_dims = score_dims or set()

    # propagate execution multipliers through the call graph
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # iterate to fixed point (call graph is a DAG; few passes suffice)
    for _ in range(32):
        changed = False
        new_mult: dict[str, float] = defaultdict(float)
        new_mult[entry] = 1.0
        for cname, comp in comps.items():
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for rec in comp.ops:
                for callee, trips in rec.calls:
                    new_mult[callee] += m * trips
        for k, v in new_mult.items():
            if abs(mult.get(k, 0.0) - v) > 1e-6:
                changed = True
        mult = new_mult
        if not changed:
            break

    flops = 0.0
    traffic = 0.0
    attn_traffic = 0.0
    traffic_by_kind: dict[str, float] = defaultdict(float)
    coll_by_kind: dict[str, dict] = {k: {"count": 0.0, "bytes": 0.0}
                                     for k in _COLLECTIVES}
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for rec in comp.ops:
            flops += m * rec.flops
            traffic += m * rec.traffic_bytes
            if rec.traffic_bytes:
                traffic_by_kind[rec.op] += m * rec.traffic_bytes
                if score_dims and _is_score_shaped(rec.out_type,
                                                   score_dims):
                    attn_traffic += m * rec.traffic_bytes
            if rec.collective_bytes and rec.op in coll_by_kind:
                coll_by_kind[rec.op]["count"] += m
                coll_by_kind[rec.op]["bytes"] += m * rec.collective_bytes
    total_coll = sum(v["bytes"] for v in coll_by_kind.values())
    return {
        "flops": flops,
        "traffic_bytes": traffic,
        # traffic of attention score-shaped tensors (structural shape
        # classification) — VMEM-resident under the Pallas flash kernel
        # on real TPU; roofline.py reports the projected term.
        "attn_traffic_bytes": attn_traffic,
        "traffic_by_kind": dict(sorted(traffic_by_kind.items(),
                                       key=lambda kv: -kv[1])),
        "collective_bytes": total_coll,
        "collectives": coll_by_kind,
        "n_computations": len(comps),
    }
