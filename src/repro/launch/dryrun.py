import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape) cell on the production meshes and record the
compiled artifact's memory/cost/collective analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
      --shape train_4k --mesh pod1
  PYTHONPATH=src python -m repro.launch.dryrun --all   # full 40-cell grid

Artifacts land in benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json
and feed benchmarks/roofline.py (deliverable g). Existing artifacts are
skipped unless --force.
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax.numpy as jnp

from repro import configs
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.launch.steps import build_step
from repro.optim import AdamWConfig

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64|c64)"
                       r"\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "s64": 8, "c64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all tensor shapes in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective byte counts from post-SPMD optimized HLO.

    Result-shape bytes of every collective op are summed per kind. Shapes
    in the compiled module are PER-DEVICE (post-partitioning), so these are
    per-chip wire bytes (ring-algorithm factors ~(n-1)/n are ignored —
    consistent across all cells, so relative comparisons hold).
    """
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match "X = <type> all-gather(...)" forms, incl. -start variants
        m = re.search(r"=\s+(\S+)\s+([a-z\-]+)(-start)?\(", s)
        if not m:
            continue
        op = m.group(2)
        if op in _COLLECTIVES:
            out[op]["count"] += 1
            out[op]["bytes"] += _shape_bytes(m.group(1))
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


# --- §Perf hillclimb variants: config / sharding-rule overrides ---------
import dataclasses as _dc


def _v_remat_full(cfg, rules):
    return _dc.replace(cfg, remat="full"), rules


def _v_flashlike(cfg, rules):
    return _dc.replace(cfg, remat="full", attn_impl="chunked",
                       attn_chunk=2048), rules


def _v_fsdp(cfg, rules):
    """Drop TP, go 256-way FSDP+DP on the single-pod mesh: batch and the
    d_model weight dim both shard over ("data","model")."""
    rules = dict(rules)
    rules.update(batch=("data", "model"), embed=("data", "model"),
                 heads=None, kv_heads=None, mlp=None, vocab=None,
                 heads_inner=None)
    return cfg, rules


def _v_cap1(cfg, rules):
    moe = _dc.replace(cfg.moe, capacity_factor=1.0)
    return _dc.replace(cfg, moe=moe), rules


def _v_chunk512(cfg, rules):
    return _dc.replace(cfg, attn_chunk=512), rules


def _v_serve_tp(cfg, rules):
    """Decode layout: weights stationary. Expert FFNs switch to the F-
    sharded TP path (no per-step FSDP gathers); dense weights drop the
    d_model FSDP axis (TP over "model" alone suffices at decode)."""
    rules = dict(rules)
    rules["embed"] = None
    if cfg.moe is not None:
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, weight_mode="tp_f"))
    return cfg, rules


VARIANTS = {
    "remat_full": _v_remat_full,
    "flashlike": _v_flashlike,
    "fsdp": _v_fsdp,
    "cap1": _v_cap1,
    "chunk512": _v_chunk512,
    "serve_tp": _v_serve_tp,
}


def run_cell(arch: str, shape_name: str, mesh_name: str,
             force: bool = False, art_dir: str = ART_DIR,
             variant: str | None = None) -> dict:
    os.makedirs(art_dir, exist_ok=True)
    stem = f"{arch}__{shape_name}__{mesh_name}"
    if variant:
        stem += f"__{variant}"
    path = os.path.join(art_dir, stem + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = configs.get_config(arch)
    shape = configs.SHAPES[shape_name]
    ok, why = configs.cell_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "seq": shape.seq,
           "global_batch": shape.global_batch}
    if not ok:
        rec.update(status="skipped", reason=why)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    rules_override = None
    if variant:
        from repro.launch.sharding import sharding_rules
        rules = sharding_rules(cfg, kind=shape.kind,
                               long_ctx=(shape_name == "long_500k"))
        for v in variant.split(","):
            cfg, rules = VARIANTS[v](cfg, rules)
        rules_override = rules
        rec["variant"] = variant
    t0 = time.time()
    try:
        bundle = build_step(cfg, shape, mesh,
                            opt_cfg=AdamWConfig(state_dtype=jnp.bfloat16),
                            rules_override=rules_override)
        with mesh:
            lowered = bundle.fn.lower(*bundle.args_abstract)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        from repro.launch.hlo_analysis import analyze_hlo
        score_dims = {shape.seq, cfg.attn_chunk,
                      -(-shape.seq // cfg.attn_chunk) * cfg.attn_chunk}
        analysis = analyze_hlo(hlo, score_dims=score_dims)

        rec.update(
            status="ok",
            chips=mesh_chips(mesh),
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory={
                "argument_bytes": int(
                    getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "generated_code_bytes": int(
                    getattr(mem, "generated_code_size_in_bytes", 0)),
            },
            cost={k: float(v) for k, v in (cost or {}).items()
                  if isinstance(v, (int, float))},
            collectives=coll,
            analysis={
                "flops": analysis["flops"],
                "traffic_bytes": analysis["traffic_bytes"],
                "attn_traffic_bytes": analysis["attn_traffic_bytes"],
                "traffic_by_kind": analysis["traffic_by_kind"],
                "collective_bytes": analysis["collective_bytes"],
                "collectives": analysis["collectives"],
            },
            hlo_bytes=len(hlo),
        )
    except Exception as e:  # record the failure; the grid keeps going
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="comma-joined hillclimb overrides: "
                         + ",".join(VARIANTS))
    ap.add_argument("--out", default=ART_DIR)
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for a in configs.ARCHS:
            for s in configs.SHAPES:
                for m in ("pod1", "pod2"):
                    cells.append((a, s, m))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        cells.append((args.arch, args.shape, args.mesh))

    failures = 0
    for a, s, m in cells:
        rec = run_cell(a, s, m, force=args.force, art_dir=args.out,
                       variant=args.variant)
        status = rec["status"]
        extra = ""
        if status == "ok":
            ana = rec.get("analysis", {})
            extra = (f"compile={rec['compile_s']}s "
                     f"flops/dev={ana.get('flops', 0):.3g} "
                     f"coll/dev={ana.get('collective_bytes', 0):.3g}B")
            print(f"[dryrun] {a:24s} {s:12s} {m}: OK   {extra}", flush=True)
            mem = rec["memory"]
            print(f"         args={mem['argument_bytes']/1e9:.2f}GB "
                  f"out={mem['output_bytes']/1e9:.2f}GB "
                  f"temp={mem['temp_bytes']/1e9:.2f}GB per device",
                  flush=True)
        elif status == "skipped":
            print(f"[dryrun] {a:24s} {s:12s} {m}: SKIP {rec['reason'][:60]}",
                  flush=True)
        else:
            failures += 1
            print(f"[dryrun] {a:24s} {s:12s} {m}: FAIL {rec['error'][:120]}",
                  flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
