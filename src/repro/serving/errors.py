"""Typed serving failures: request-scoped errors + drain timeout.

The resilience contract of :class:`~repro.serving.engine.DcnServingEngine`
is that a failing request *completes with an error status* — its handle's
``error`` field is set, ``result()`` raises it, and the request appears
exactly once in the step/drain return — while step-mates finish normally.
These types make every failure mode distinguishable at the call site:

``RequestFailedError``
    The request's images could not be served (executor exception,
    injected fault, queue shedding). ``cause`` carries the original
    exception when there is one.
``DeadlineExceededError``
    The request's ``deadline_s`` passed before (admission) or during
    (mid-flight) serving. A subclass of ``RequestFailedError`` so generic
    handlers catch both.
``QueueFullError``
    Raised *at submit* under the ``reject`` backpressure policy (the
    request was never accepted — no handle exists), and used as the
    ``cause`` of shed victims under ``shed-oldest``.
``DrainTimeout``
    ``drain(max_steps)`` / ``run(max_steps)`` exhausted its step budget
    with requests still in flight. Carries the stuck rids and whatever
    finished before the timeout, so callers never silently lose
    requests.
"""

from __future__ import annotations


class RequestFailedError(RuntimeError):
    """A serving request resolved with an error status."""

    def __init__(self, rid: int, cause: BaseException | None = None,
                 message: str = ""):
        self.rid = rid
        self.cause = cause
        msg = message or f"request {rid} failed"
        if cause is not None:
            msg += f": {type(cause).__name__}: {cause}"
        super().__init__(msg)
        if cause is not None:
            self.__cause__ = cause


class DeadlineExceededError(RequestFailedError):
    """The request's deadline passed before its results were ready."""

    def __init__(self, rid: int, deadline: float | None = None):
        super().__init__(
            rid, message=f"request {rid} missed its deadline")
        self.deadline = deadline


class QueueFullError(RuntimeError):
    """The bounded submit queue is at capacity (policy ``reject``), or —
    as the ``cause`` of a shed request's ``RequestFailedError`` — the
    request was evicted to make room (policy ``shed-oldest``)."""


class DrainTimeout(RuntimeError):
    """``drain``/``run`` exhausted ``max_steps`` with work in flight."""

    def __init__(self, pending_rids, finished=None):
        self.pending_rids = list(pending_rids)
        self.finished = list(finished or [])
        super().__init__(
            "drain exhausted max_steps with requests still in flight: "
            f"rids {self.pending_rids}")
