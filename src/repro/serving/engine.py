"""Serving engines: LM decode batching + DCN graph-backend inference.

``DecodeEngine`` is the LM serving counterpart of launch/train.py: a
fixed pool of ``batch`` cache slots; requests are admitted into free
slots (continuous batching), step() decodes one token for every active
slot in a single jit'd call, finished slots (EOS or max_len) are
released and refilled. Per-slot positions make the batch ragged-safe:
each slot attends only to its own ``pos`` prefix.

Prefill here is incremental (the decode step consumed token by token) for
simplicity of cache layout; the ``prefill_32k`` dry-run cell lowers the
batched full-sequence prefill (lm.lm_prefill), which is the production
prefill path.

``DcnServingEngine`` serves DCN vision models through the network-graph
executor (``backend="graph"``) with a per-engine schedule cache: replayed
requests whose quantized sampling coordinates match a previous request
skip the host-side TDT + Algorithm-1 rebuild entirely, so steady-state
serving pays only the batched kernel dispatches. ``stats`` exposes the
cache hit rate and dispatch/overlap counters.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.transformer import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    temperature: float = 0.0
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig, *, batch: int, max_len: int,
                 mesh=None, cache_dtype=jnp.float32, eos_id: int | None = None,
                 rng_seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.mesh = mesh
        self.cache = lm.init_cache(None, cfg, batch, max_len, cache_dtype)
        self.slots: list[Request | None] = [None] * batch
        self.pos = np.zeros((batch,), np.int32)
        self.pending_tok = np.zeros(
            (batch, 1, cfg.n_codebooks) if cfg.n_codebooks > 1 else (batch, 1),
            np.int32)
        self.active = np.zeros((batch,), bool)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._key = jax.random.PRNGKey(rng_seed)
        ctx = {"mesh": mesh} if mesh is not None else {}
        self._step = jax.jit(
            lambda p, c, t, pos: lm.lm_decode_step(p, cfg, c, t, pos, ctx))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                self.pos[i] = 0
                self.pending_tok[i] = req.prompt[0]
                self.active[i] = True

    def _sample(self, logits, temperature):
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self._key, k = jax.random.split(self._key)
        return jax.random.categorical(k, logits / temperature, axis=-1)

    def step(self) -> int:
        """One decode step over all active slots. Returns #active."""
        self._admit()
        if not self.active.any():
            return 0
        tok = jnp.asarray(self.pending_tok)
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._step(self.params, self.cache, tok, pos)

        # (B,) or (B, cb)
        next_tok = np.asarray(self._sample(logits[:, 0], 0.0))
        for i in range(self.batch):
            req = self.slots[i]
            if req is None or not self.active[i]:
                continue
            self.pos[i] += 1
            in_prompt = self.pos[i] < len(req.prompt)
            if in_prompt:
                nxt = req.prompt[self.pos[i]]
            else:
                nxt = next_tok[i]
                req.out.append(int(np.asarray(nxt).reshape(-1)[0]))
            self.pending_tok[i] = nxt
            hit_eos = (self.eos_id is not None and not in_prompt
                       and int(np.asarray(nxt).reshape(-1)[0]) == self.eos_id)
            if (len(req.out) >= req.max_new or hit_eos
                    or self.pos[i] >= self.max_len - 1):
                req.done = True
                self.finished.append(req)
                self.slots[i] = None
                self.active[i] = False
        return int(self.active.sum())

    def run(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            active = self.step()
            if active == 0 and not self.queue:
                break
        return self.finished


# ---------------------------------------------------------------------------
# DCN graph-backend serving
# ---------------------------------------------------------------------------


class DcnServingEngine:
    """Inference service for the paper's DCN networks over the graph
    executor (cross-layer fused groups, batched tile-grid dispatch).

    Each request is an image batch; the engine owns a
    :class:`~repro.runtime.cache.ScheduleCache` so per-request coords
    digests are shared across requests — a replayed input (same quantized
    stage-1 sampling pattern) skips host scheduling and goes straight to
    the batched kernel dispatches. Typical serving traffic is bursts of
    near-duplicate frames (video, retries, canaries), which is exactly
    the cache's hit population.
    """

    def __init__(self, params, cfg, *, graph=None, cache_size: int = 256):
        # Local imports keep the LM serving path import-light.
        from repro.models.dcn_models import DcnNetConfig
        from repro.runtime import (GraphConfig, OverlapSpans, ScheduleCache,
                                   build_graph)

        if not isinstance(cfg, DcnNetConfig):
            raise ValueError(
                f"DcnServingEngine needs a DcnNetConfig, got {type(cfg)}")
        self.params = params
        self.cfg = cfg
        self.graph_cfg = graph or GraphConfig()
        self.net_graph = build_graph(cfg)
        self.cache = ScheduleCache(maxsize=cache_size)
        self.requests = 0
        self.images = 0
        self.kernel_dispatches = 0
        self.overlap = OverlapSpans()

    def infer(self, x: jax.Array) -> jax.Array:
        """Serve one request batch (N, H, W, C) -> logits."""
        from repro.models.dcn_models import _apply_head
        from repro.runtime import clamp_tile_config, run_graph

        gcfg = clamp_tile_config(self.graph_cfg, x.shape[1], x.shape[2])
        y, trace = run_graph(self.params["convs"], self.net_graph, x,
                             config=gcfg,
                             max_displacement=self.cfg.max_displacement,
                             return_trace=True, schedule_cache=self.cache)
        self.requests += 1
        self.images += int(x.shape[0])
        self.kernel_dispatches += trace.kernel_dispatches
        self.overlap.prepass_s += trace.overlap.prepass_s
        self.overlap.prepass_wait_s += trace.overlap.prepass_wait_s
        self.overlap.schedule_s += trace.overlap.schedule_s
        self.overlap.schedule_device_s += trace.overlap.schedule_device_s
        return _apply_head(self.params, self.cfg, y,
                           self.cfg.name == "segnet")

    @property
    def stats(self) -> dict[str, Any]:
        """Serving counters: schedule-cache hit/miss + dispatch/overlap.

        With ``graph=GraphConfig(dispatch="batch_fused")`` the cache is
        keyed per image but the dispatch grid is assembled per batch:
        ``image_hits``/``batch_assemblies`` split the hit accounting
        (partial batch hits skip scheduling only for the hit images),
        and ``dispatches_per_batch`` reports the average host-issued
        kernel dispatches per served request batch.
        """
        info = self.cache.info()
        total = info["hits"] + info["misses"]
        return {
            "requests": self.requests,
            "images": self.images,
            "schedule_cache_hits": info["hits"],
            "schedule_cache_misses": info["misses"],
            "schedule_cache_hit_rate": (info["hits"] / total
                                        if total else 0.0),
            "schedule_cache_size": info["size"],
            "image_hits": info["image_hits"],
            "batch_assemblies": info["batch_assemblies"],
            "kernel_dispatches": self.kernel_dispatches,
            "dispatches_per_batch": (self.kernel_dispatches / self.requests
                                     if self.requests else 0.0),
            "host_overlap_frac": self.overlap.host_overlap_frac,
            "schedule_backend": self.graph_cfg.schedule_backend,
            "dispatch": self.graph_cfg.dispatch,
            "schedule_s": self.overlap.schedule_s,
            "schedule_device_frac": self.overlap.schedule_device_frac,
        }
