"""Serving engines: LM decode batching + DCN graph-backend inference.

``DecodeEngine`` is the LM serving counterpart of launch/train.py: a
fixed pool of ``batch`` cache slots; requests are admitted into free
slots (continuous batching), step() decodes one token for every active
slot in a single jit'd call, finished slots (EOS or max_len) are
released and refilled. Per-slot positions make the batch ragged-safe:
each slot attends only to its own ``pos`` prefix.

Prefill here is incremental (the decode step consumed token by token) for
simplicity of cache layout; the ``prefill_32k`` dry-run cell lowers the
batched full-sequence prefill (lm.lm_prefill), which is the production
prefill path.

``DcnServingEngine`` serves DCN vision models through the network-graph
executor (``backend="graph"``) with a per-engine schedule cache: replayed
requests whose quantized sampling coordinates match a previous request
skip the host-side TDT + Algorithm-1 rebuild entirely, so steady-state
serving pays only the batched kernel dispatches. It is a continuous-
batching service in the same shape as ``DecodeEngine``: ``submit()``
enqueues image requests from any thread, ``step()`` admits queued images
into a fixed pool of slots and serves every occupied slot with ONE
``batch_fused`` ragged grid per layer segment — concurrent single-image
requests coalesce into one dispatch, and a large request's images can
split across steps. ``stats`` exposes the cache hit rate,
dispatch/overlap counters and submit->result latency percentiles.

Resilience (ISSUE 8): requests are *isolated* — input validation at
``submit()`` (shape, emptiness, finiteness), per-request deadlines
checked at admission and completion, a bounded queue with
``block``/``reject``/``shed-oldest`` backpressure, and per-step fault
containment: a failed ``batch_fused`` step retries once with the
offending slot evicted, then degrades to per-image ``batched`` dispatch
so one poisoned image can never take down its step-mates. A failing
request completes with ``DcnRequest.error`` set (``result()`` raises
the typed ``RequestFailedError``) and is returned exactly once; all
failure counters (``requests_failed``, ``deadline_expired``,
``queue_rejected``, ``step_retries``, ``degraded_steps``,
``watchdog_failovers``) surface through ``stats`` /
``metrics_snapshot()``.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.transformer import ModelConfig
from repro.obs import MetricsRegistry, Tracer, get_tracer
from repro.serving.errors import (DeadlineExceededError, DrainTimeout,
                                  QueueFullError, RequestFailedError)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    temperature: float = 0.0
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig, *, batch: int, max_len: int,
                 mesh=None, cache_dtype=jnp.float32, eos_id: int | None = None,
                 rng_seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.mesh = mesh
        self.cache = lm.init_cache(None, cfg, batch, max_len, cache_dtype)
        self.slots: list[Request | None] = [None] * batch
        self.pos = np.zeros((batch,), np.int32)
        self.pending_tok = np.zeros(
            (batch, 1, cfg.n_codebooks) if cfg.n_codebooks > 1 else (batch, 1),
            np.int32)
        self.active = np.zeros((batch,), bool)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        # submit() is documented thread-safe (continuous batching admits
        # from any producer thread); the lock covers every queue
        # mutation — a bare list append/pop pair can interleave under
        # concurrent submits.
        self._lock = threading.Lock()
        self._key = jax.random.PRNGKey(rng_seed)
        ctx = {"mesh": mesh} if mesh is not None else {}
        self._step = jax.jit(
            lambda p, c, t, pos: lm.lm_decode_step(p, cfg, c, t, pos, ctx))

    def submit(self, req: Request):
        if not req.prompt:
            raise ValueError(
                f"request {req.rid}: empty prompt — decoding needs at "
                "least one prompt token to seed the first step")
        with self._lock:
            self.queue.append(req)

    def _admit(self):
        with self._lock:
            for i in range(self.batch):
                if self.slots[i] is None and self.queue:
                    req = self.queue.pop(0)
                    self.slots[i] = req
                    self.pos[i] = 0
                    self.pending_tok[i] = req.prompt[0]
                    self.active[i] = True

    def _sample(self, logits, temperature):
        """Next-token sampling; ``temperature`` is a scalar or a per-slot
        (B,) vector — 0 means greedy argmax for that slot."""
        t = jnp.atleast_1d(jnp.asarray(temperature, jnp.float32))
        greedy = jnp.argmax(logits, axis=-1)
        if not bool((t > 0).any()):
            return greedy
        self._key, k = jax.random.split(self._key)
        safe = jnp.where(t > 0, t, 1.0)
        scaled = logits / safe.reshape(t.shape + (1,) * (logits.ndim - 1))
        sampled = jax.random.categorical(k, scaled, axis=-1)
        keep = (t > 0).reshape(t.shape + (1,) * (greedy.ndim - 1))
        return jnp.where(keep, sampled, greedy)

    def step(self) -> int:
        """One decode step over all active slots. Returns #active."""
        self._admit()
        if not self.active.any():
            return 0
        tok = jnp.asarray(self.pending_tok)
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._step(self.params, self.cache, tok, pos)

        # (B,) or (B, cb) — sampled at each slot's OWN request
        # temperature (inactive slots decode greedily into the void).
        temps = np.zeros((self.batch,), np.float32)
        for i, req in enumerate(self.slots):
            if req is not None and self.active[i]:
                temps[i] = req.temperature
        next_tok = np.asarray(self._sample(logits[:, 0], temps))
        for i in range(self.batch):
            req = self.slots[i]
            if req is None or not self.active[i]:
                continue
            self.pos[i] += 1
            in_prompt = self.pos[i] < len(req.prompt)
            if in_prompt:
                nxt = req.prompt[self.pos[i]]
            else:
                nxt = next_tok[i]
                req.out.append(int(np.asarray(nxt).reshape(-1)[0]))
            self.pending_tok[i] = nxt
            hit_eos = (self.eos_id is not None and not in_prompt
                       and int(np.asarray(nxt).reshape(-1)[0]) == self.eos_id)
            if (len(req.out) >= req.max_new or hit_eos
                    or self.pos[i] >= self.max_len - 1):
                req.done = True
                self.finished.append(req)
                self.slots[i] = None
                self.active[i] = False
        return int(self.active.sum())

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Decode until idle. Raises :class:`DrainTimeout` (stuck rids +
        what did finish) if ``max_steps`` is exhausted with requests
        still queued or mid-decode — silently returning would drop
        them."""
        for _ in range(max_steps):
            active = self.step()
            with self._lock:
                queued = bool(self.queue)
            if active == 0 and not queued:
                return self.finished
        with self._lock:
            stuck = ([r.rid for r in self.slots if r is not None]
                     + [r.rid for r in self.queue])
        if stuck:
            raise DrainTimeout(stuck, finished=self.finished)
        return self.finished


# ---------------------------------------------------------------------------
# DCN graph-backend serving
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DcnRequest:
    """One vision serving request: a small batch of images.

    ``out`` fills per image as serving steps complete the images' slots;
    the request finishes when its last image does. Latency is
    submit -> finish on the engine's clock (wall time by default, a
    virtual clock in open-loop benchmarks).

    A request always *resolves*: either ``done`` with outputs, or
    ``done`` with ``error`` set (executor fault, missed deadline, queue
    shedding) — ``result()`` then raises that typed error instead of
    returning garbage. ``deadline`` is absolute on the engine's clock
    (set from ``submit(..., deadline_s=...)``).
    """

    rid: int
    x: np.ndarray                # (n, H, W, C)
    submit_s: float
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    finish_s: float = 0.0
    error: Exception | None = None
    deadline: float | None = None

    @property
    def n_images(self) -> int:
        return int(self.x.shape[0])

    @property
    def failed(self) -> bool:
        return self.error is not None

    @property
    def latency_s(self) -> float:
        return (self.finish_s - self.submit_s) if self.done else 0.0

    def result(self) -> np.ndarray:
        """Stacked per-image outputs, in submit order. Raises the
        request's :class:`RequestFailedError` if it resolved with an
        error."""
        if self.error is not None:
            raise self.error
        if not self.done:
            raise RuntimeError(f"request {self.rid} is not finished")
        return np.stack([np.asarray(o) for o in self.out])


class DcnServingEngine:
    """Inference service for the paper's DCN networks over the graph
    executor (cross-layer fused groups, batched tile-grid dispatch).

    Each request is an image batch; the engine owns a
    :class:`~repro.runtime.cache.ScheduleCache` so per-request coords
    digests are shared across requests — a replayed input (same quantized
    stage-1 sampling pattern) skips host scheduling and goes straight to
    the batched kernel dispatches. Typical serving traffic is bursts of
    near-duplicate frames (video, retries, canaries), which is exactly
    the cache's hit population.

    Two serving modes:

    * ``infer(x)`` — serve one request synchronously, whole batch in one
      executor call (the serve-one-at-a-time baseline).
    * ``submit(x)`` / ``step()`` / ``drain()`` — continuous batching: a
      submit queue feeds a fixed pool of ``slots`` image slots; each
      ``step()`` admits queued images into free slots (mid-flight, so a
      request arriving between steps joins the next step's batch) and
      serves ALL occupied slots with one ``batch_fused`` ragged grid per
      layer segment. Every admitted image completes within its step
      (vision inference has no iterative decode), so slots free each
      step and admission is purely a queue->pool refill. ``submit`` is
      thread-safe; ``step``/``drain`` are driven by one serving loop.

    Scale-out: a ``graph=GraphConfig(..., data_parallel=D)`` (or an
    explicit ``mesh=``) partitions the slot pool contiguously over the
    D data replicas — admission targets the replica with the most free
    slots, and each step passes its per-replica occupancy to the
    executor as ``shard_sizes`` so shard placement is exactly slot
    placement. ``stats`` then reports ``replicas``/``per_replica``
    image, dispatch and DRAM counters plus the logits
    ``allgather_bytes``; per-image schedules and traces are placement-
    independent.
    """

    def __init__(self, params, cfg, *, graph=None, cache_size: int = 256,
                 slots: int = 4,
                 clock: Callable[[], float] | None = None,
                 tracer: Tracer | None = None,
                 max_queue: int | None = None,
                 queue_policy: str = "block",
                 faults=None):
        # Local imports keep the LM serving path import-light.
        from repro.core.scheduler import host_schedule_builds
        from repro.models.dcn_models import DcnNetConfig
        from repro.runtime import (GraphConfig, LatencyStats, OverlapSpans,
                                   ScheduleCache, build_graph,
                                   clamp_tile_config)
        from repro.runtime.pipeline import staging_watchdog_failovers

        if not isinstance(cfg, DcnNetConfig):
            raise ValueError(
                f"DcnServingEngine needs a DcnNetConfig, got {type(cfg)}")
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if queue_policy not in ("block", "reject", "shed-oldest"):
            raise ValueError(
                f"unknown queue_policy: {queue_policy!r} (expected "
                f"'block', 'reject' or 'shed-oldest')")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.params = params
        self.cfg = cfg
        self.graph_cfg = graph or GraphConfig()
        if faults is not None:
            # Convenience: thread a fault injector through without the
            # caller rebuilding the GraphConfig.
            self.graph_cfg = dataclasses.replace(self.graph_cfg,
                                                 faults=faults)
        self.net_graph = build_graph(cfg)
        self.cache = ScheduleCache(maxsize=cache_size)
        self.overlap = OverlapSpans()
        # Telemetry: the engine owns a MetricsRegistry (one snapshot()
        # for everything ``stats`` reports) and routes executor + kernel
        # spans into ``tracer`` (default: the current obs tracer — a
        # no-op unless enabled). ``host_schedule_builds`` is process-
        # wide, so the engine keeps a construction-time baseline and
        # reports its own delta.
        self.tracer = tracer if tracer is not None else get_tracer()
        self.metrics = MetricsRegistry()
        self._m_requests = self.metrics.counter(
            "serving.requests", help="requests submitted")
        self._m_images = self.metrics.counter(
            "serving.images", help="images served")
        self._m_dispatches = self.metrics.counter(
            "serving.kernel_dispatches",
            help="host-issued kernel dispatches")
        self._m_steps = self.metrics.counter(
            "serving.steps", help="continuous-batching serving steps")
        self._m_failed = self.metrics.counter(
            "serving.requests_failed",
            help="requests that resolved with an error status")
        self._m_deadline = self.metrics.counter(
            "serving.deadline_expired",
            help="requests failed on a missed deadline (admission or "
                 "completion)")
        self._m_rejected = self.metrics.counter(
            "serving.queue_rejected",
            help="submits refused by the bounded queue (policy "
                 "'reject', or a request wider than max_queue)")
        self._m_shed = self.metrics.counter(
            "serving.queue_shed",
            help="queued requests evicted by policy 'shed-oldest'")
        self._m_retries = self.metrics.counter(
            "serving.step_retries",
            help="batch_fused steps retried after an execution fault")
        self._m_degraded = self.metrics.counter(
            "serving.degraded_steps",
            help="steps degraded to per-image batched dispatch")
        self._host_builds = host_schedule_builds
        self._host_builds0 = host_schedule_builds.count
        self._watchdog = staging_watchdog_failovers
        self._watchdog0 = staging_watchdog_failovers.count
        # Per-step serving timeline (filled only when the tracer is
        # enabled): step id, coalesced width, dispatch/DRAM accounting
        # and the step's dispatch span walls — what bench_serving dumps.
        self.timeline: list[dict] = []
        # Continuous-batching state. The step config pins the coalesced
        # dispatch mode to batch_fused (the ragged batch grid handles
        # whatever mix of slot images a step happens to coalesce) and is
        # clamped once: serving images all share the config's plane.
        self.n_slots = int(slots)
        self.max_queue = None if max_queue is None else int(max_queue)
        self.queue_policy = queue_policy
        self._clock = clock if clock is not None else time.perf_counter
        self._lock = threading.Lock()
        # Backpressure: blocked submitters wait on this; step()'s
        # admission and any queue purge notify it.
        self._queue_room = threading.Condition(self._lock)
        self._queue: deque[tuple[DcnRequest, int]] = deque()
        self._slots: list[tuple[DcnRequest, int] | None] = (
            [None] * self.n_slots)
        self._rid = itertools.count()
        self.latency = LatencyStats()
        self.metrics.register("serving.latency_s", self.latency)
        self.last_trace = None
        self.last_step_faulted = False
        self._step_cfg = clamp_tile_config(
            dataclasses.replace(self.graph_cfg, dispatch="batch_fused"),
            cfg.img_size, cfg.img_size)
        # Degraded mode: per-image batched dispatch, serial staging — a
        # fault in one image's dispatch cannot touch another's. Sharding
        # is cleared too: "batched" rejects mesh=/data_parallel=, and a
        # degraded step must not depend on collective health anyway.
        self._degraded_cfg = dataclasses.replace(
            self._step_cfg, dispatch="batched", staging_depth=1,
            mesh=None, data_parallel=None)
        self._faults = self._step_cfg.faults
        # Scale-out: with a sharded step config (mesh=/data_parallel=)
        # the slot pool partitions contiguously over the mesh's data
        # replicas — admission targets the replica with the most free
        # slots, and each step passes its per-replica occupancy as
        # shard_sizes so shard placement equals slot placement.
        from repro.runtime.shard import (plan_batch_shards,
                                         resolve_shard_mesh)
        _mesh = resolve_shard_mesh(self._step_cfg.mesh,
                                   self._step_cfg.data_parallel)
        self.replicas = (dict(_mesh.shape)["data"]
                         if _mesh is not None else 1)
        if self.replicas > self.n_slots:
            raise ValueError(
                f"slots={self.n_slots} cannot cover {self.replicas} "
                f"data replicas — every replica needs at least one "
                f"slot (raise slots= or shrink the mesh)")
        self._slot_replica = [
            r for r, (a, b) in enumerate(
                plan_batch_shards(self.n_slots, self.replicas).spans)
            for _ in range(b - a)]
        self._m_replica = [
            {"images": self.metrics.counter(
                 f"serving.replica{r}.images",
                 help=f"images served on data replica {r}"),
             "dispatches": self.metrics.counter(
                 f"serving.replica{r}.dispatches",
                 help=f"kernel dispatches executed on replica {r}"),
             "dram_bytes": self.metrics.counter(
                 f"serving.replica{r}.dram_bytes",
                 help=f"modeled DRAM bytes of replica {r}'s images")}
            for r in range(self.replicas)]
        self._m_allgather = self.metrics.counter(
            "serving.allgather_bytes",
            help="logits all-gather traffic of sharded steps")
        # Plan autotuning (ISSUE 10): resolve the tuned plan ONCE at
        # construction — cache hit (memory or plan_cache_dir disk) is
        # free, "offline" miss pays the simulator search here rather
        # than on the first request. Every step, replica and the
        # degraded path replay this same plan (tuned_plan= below), so
        # the hot path never re-resolves.
        from repro.tuning import plan_cache_hits, resolve_tuned_plan
        self._plan_hits = plan_cache_hits
        self._plan_hits0 = plan_cache_hits.count
        self.tuned_plan = None
        self._autotune_search_s = 0.0
        if self._step_cfg.autotune != "off":
            sc = self._step_cfg
            hits_before = plan_cache_hits.count
            self.tuned_plan = resolve_tuned_plan(
                self.params["convs"], self.net_graph,
                autotune=sc.autotune,
                onchip_budget_bytes=sc.onchip_budget_bytes,
                dtype_bytes=4, tile_hw=sc.tile_hw,
                buffer_tiles=sc.buffer_tiles, schedule=sc.schedule,
                batch=self.n_slots, budget=sc.autotune_budget,
                plan_cache_dir=sc.plan_cache_dir,
                max_displacement=self.cfg.max_displacement,
                tracer=self.tracer)
            if (self.tuned_plan is not None
                    and plan_cache_hits.count == hits_before):
                # Fresh search (not a cache hit): surface its cost.
                self._autotune_search_s = self.tuned_plan.search_s

    # Counter-backed views keep the pre-registry attribute API
    # (``eng.requests`` etc.) readable while the registry is the single
    # writer.

    @property
    def requests(self) -> int:
        return self._m_requests.count

    @property
    def images(self) -> int:
        return self._m_images.count

    @property
    def kernel_dispatches(self) -> int:
        return self._m_dispatches.count

    @property
    def steps(self) -> int:
        return self._m_steps.count

    @property
    def host_schedule_builds(self) -> int:
        """Host-side ``TileSchedule`` builds since this engine was
        constructed (0 on the device scheduling hot path)."""
        return self._host_builds.count - self._host_builds0

    @property
    def requests_failed(self) -> int:
        return self._m_failed.count

    @property
    def watchdog_failovers(self) -> int:
        """Staging-watchdog failovers since this engine was constructed
        (the counter is process-wide, like ``host_schedule_builds``)."""
        return self._watchdog.count - self._watchdog0

    @property
    def plan_cache_hits(self) -> int:
        """Tuned-plan cache hits since this engine was constructed
        (process-wide counter, engine-relative delta — same pattern as
        ``host_schedule_builds``)."""
        return self._plan_hits.count - self._plan_hits0

    @property
    def tuned_groups(self) -> int:
        """Fused groups in the active tuned plan (0 = greedy plan)."""
        return len(self.tuned_plan.groups) if self.tuned_plan else 0

    def _absorb_trace(self, trace) -> None:
        """Fold one executor trace into the engine counters (caller must
        hold ``self._lock``)."""
        self._m_dispatches.inc(trace.kernel_dispatches)
        self._m_allgather.inc(getattr(trace, "allgather_bytes", 0))
        self.overlap.merge(trace.overlap)
        self.last_trace = trace

    def _fail_locked(self, req: DcnRequest, error: RequestFailedError,
                     now: float) -> bool:
        """Resolve ``req`` with an error (caller holds ``self._lock``).

        Purges its queued images and occupied slots so no later step
        serves a dead request, and wakes blocked submitters (the queue
        may have shrunk). Returns False if the request already resolved
        (exactly-once: the caller must not report it again)."""
        if req.done:
            return False
        req.error = error
        req.done = True
        req.finish_s = now
        self._m_failed.inc()
        if isinstance(error, DeadlineExceededError):
            self._m_deadline.inc()
        if any(e[0] is req for e in self._queue):
            self._queue = deque(e for e in self._queue
                                if e[0] is not req)
        for i, s in enumerate(self._slots):
            if s is not None and s[0] is req:
                self._slots[i] = None
        self._queue_room.notify_all()
        return True

    def infer(self, x: jax.Array) -> jax.Array:
        """Serve one request batch (N, H, W, C) -> logits."""
        from repro.models.dcn_models import _apply_head
        from repro.runtime import clamp_tile_config, run_graph

        gcfg = clamp_tile_config(self.graph_cfg, x.shape[1], x.shape[2])
        y, trace = run_graph(self.params["convs"], self.net_graph, x,
                             config=gcfg,
                             max_displacement=self.cfg.max_displacement,
                             return_trace=True, schedule_cache=self.cache,
                             tracer=self.tracer,
                             tuned_plan=self.tuned_plan)
        self._m_requests.inc()
        self._m_images.inc(int(x.shape[0]))
        with self._lock:
            self._absorb_trace(trace)
        return _apply_head(self.params, self.cfg, y,
                           self.cfg.name == "segnet")

    # -- continuous batching ------------------------------------------------

    def submit(self, x, *, deadline_s: float | None = None) -> DcnRequest:
        """Enqueue a request (thread-safe). ``x`` is one image (H, W, C)
        or a batch (n, H, W, C) matching the engine's configured plane.
        Returns the :class:`DcnRequest` handle; results appear on it
        once serving steps complete its images.

        ``deadline_s`` (relative, engine clock) fails the request with
        :class:`DeadlineExceededError` if it is still queued past the
        deadline (checked at admission) or its step completes past it
        (checked at completion).

        With ``max_queue`` set, a submit that would overfill the queue
        follows ``queue_policy``: ``block`` waits for admission to make
        room, ``reject`` raises :class:`QueueFullError` (no handle is
        created), ``shed-oldest`` evicts the request(s) owning the
        oldest queued images — their handles resolve immediately with a
        ``RequestFailedError`` caused by ``QueueFullError`` (shed
        requests never appear in ``step()``/``drain()`` returns; they
        resolve on the handle). A single
        request wider than ``max_queue`` is always rejected (no policy
        could ever fit it).
        """
        x = np.asarray(x)
        if x.ndim == 3:
            x = x[None]
        g = self.net_graph
        if x.ndim != 4 or x.shape[1:] != (g.in_h, g.in_w, g.in_c):
            raise ValueError(
                f"request images must be (n, {g.in_h}, {g.in_w}, "
                f"{g.in_c}); got {x.shape}")
        if x.shape[0] == 0:
            raise ValueError(
                "empty request: a serving request needs at least one "
                "image")
        if not bool(np.isfinite(x).all()):
            # NaN/Inf offsets would decode into garbage clipped-floor
            # coords and poison the schedule cache with a junk digest
            # entry shared across requests — reject at the front door.
            raise ValueError(
                "request images must be finite: NaN/Inf values poison "
                "the quantized-coords schedule-cache digest")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(
                f"deadline_s must be > 0, got {deadline_s}")
        n_img = int(x.shape[0])
        with self._queue_room:
            if self.max_queue is not None and n_img > self.max_queue:
                self._m_rejected.inc()
                raise QueueFullError(
                    f"request of {n_img} images exceeds max_queue="
                    f"{self.max_queue}")
            if self.max_queue is not None:
                if self.queue_policy == "reject":
                    if len(self._queue) + n_img > self.max_queue:
                        self._m_rejected.inc()
                        raise QueueFullError(
                            f"queue full ({len(self._queue)}/"
                            f"{self.max_queue} images queued)")
                elif self.queue_policy == "shed-oldest":
                    while len(self._queue) + n_img > self.max_queue:
                        victim = self._queue[0][0]
                        self._m_shed.inc()
                        self._fail_locked(
                            victim,
                            RequestFailedError(
                                victim.rid,
                                cause=QueueFullError(
                                    f"request {victim.rid} shed: queue "
                                    "full, policy shed-oldest")),
                            self._clock())
                else:  # block
                    while len(self._queue) + n_img > self.max_queue:
                        self._queue_room.wait()
            req = DcnRequest(rid=next(self._rid), x=x,
                             submit_s=self._clock(),
                             out=[None] * n_img)
            if deadline_s is not None:
                req.deadline = req.submit_s + deadline_s
            self._m_requests.inc()
            for j in range(n_img):
                self._queue.append((req, j))
        self.tracer.instant("serve.submit", rid=req.rid,
                            images=req.n_images)
        return req

    @property
    def queue_depth(self) -> int:
        """Images waiting for a slot (not yet admitted)."""
        with self._lock:
            return len(self._queue)

    def _run_batch(self, images: list[np.ndarray], step_cfg,
                   shard_sizes=None):
        """One executor call over a list of images -> (outputs, trace)."""
        from repro.models.dcn_models import _apply_head
        from repro.runtime import run_graph

        xb = jnp.asarray(np.stack(images))
        y, trace = run_graph(
            self.params["convs"], self.net_graph, xb, config=step_cfg,
            max_displacement=self.cfg.max_displacement,
            return_trace=True, schedule_cache=self.cache,
            tracer=self.tracer, shard_sizes=shard_sizes,
            tuned_plan=self.tuned_plan)
        out = np.asarray(_apply_head(self.params, self.cfg, y,
                                     self.cfg.name == "segnet"))
        return out, trace

    def _shard_sizes(self, repl: list[int] | None):
        """Per-replica image counts of one step's batch (None when the
        engine is unsharded). ``repl`` is slot-ordered, and slots map to
        replicas contiguously, so the batch is shard-contiguous by
        construction."""
        if repl is None or self.replicas <= 1:
            return None
        return [repl.count(r) for r in range(self.replicas)]

    def _execute_isolated(self, images: list[np.ndarray],
                          repl: list[int] | None = None):
        """Serve one step's images with request isolation.

        Returns ``(outs, traces, failures, degraded)``: ``outs`` maps
        batch position -> output array, ``failures`` maps batch
        position -> exception, ``traces`` is the executor traces to
        absorb, ``degraded`` marks a step that fell back to per-image
        batched dispatch. ``repl`` is the per-position replica id of a
        sharded engine (drives ``shard_sizes`` so shard placement
        follows slot placement, including across the evicted retry).

        Fault containment ladder: (1) the coalesced ``batch_fused`` run;
        (2) on an exception that names the offending image
        (``e.image``), retry ONCE with that slot evicted; (3) on an
        unattributed exception or a failed retry, degrade to per-image
        ``batched`` dispatch, capturing each image's exception
        individually — one poisoned image can then never fail its
        step-mates.
        """
        n = len(images)
        try:
            out, trace = self._run_batch(
                images, self._step_cfg,
                shard_sizes=self._shard_sizes(repl))
            return dict(enumerate(out)), [trace], {}, False
        except Exception as e:   # isolation boundary: any executor fault
            first = e
        self._m_retries.inc()
        self.tracer.instant("serve.step_retry",
                            error=type(first).__name__)
        failures: dict[int, Exception] = {}
        bad = getattr(first, "image", None)
        if isinstance(bad, int) and 0 <= bad < n:
            failures[bad] = first
            keep = [k for k in range(n) if k != bad]
            if not keep:
                return {}, [], failures, False
            try:
                out, trace = self._run_batch(
                    [images[k] for k in keep], self._step_cfg,
                    shard_sizes=self._shard_sizes(
                        [repl[k] for k in keep]
                        if repl is not None else None))
                return ({k: out[z] for z, k in enumerate(keep)},
                        [trace], failures, False)
            except Exception:    # retry faulted too -> degrade
                pass
        self._m_degraded.inc()
        self.tracer.instant("serve.step_degraded", width=n)
        outs: dict[int, np.ndarray] = {}
        traces: list = []
        for k in range(n):
            if k in failures:
                continue
            try:
                out, trace = self._run_batch([images[k]],
                                             self._degraded_cfg)
                outs[k] = out[0]
                traces.append(trace)
            except Exception as ek:
                failures[k] = ek
        return outs, traces, failures, True

    def _admission_order(self) -> list[int]:
        """Free slots in admission order (caller holds the lock).

        Unsharded engines refill lowest-slot-first. Sharded engines
        repeatedly target the replica with the MOST free slots (ties to
        the lowest replica): step batches stay balanced across
        replicas, so the SPMD slab — sized by the fullest replica —
        stays minimal."""
        free = [i for i in range(self.n_slots) if self._slots[i] is None]
        if self.replicas <= 1:
            return free
        by_r: list[list[int]] = [[] for _ in range(self.replicas)]
        for i in free:
            by_r[self._slot_replica[i]].append(i)
        order: list[int] = []
        while True:
            r = max(range(self.replicas), key=lambda q: len(by_r[q]))
            if not by_r[r]:
                return order
            order.append(by_r[r].pop(0))

    def _attribute_replicas(self, repl: list[int], traces,
                            failures) -> None:
        """Per-replica serving counters for one step (caller holds the
        lock). Images count by slot placement; every replica that
        served >= 1 image executed each of the step's SPMD kernel
        dispatches locally; per-image modeled DRAM comes from the
        executed trace's per-image groups (clean coalesced steps only —
        retried/degraded steps change batch positions mid-flight, so
        their DRAM stays in the engine-wide counters)."""
        dispatches = sum(t.kernel_dispatches for t in traces)
        for k, r in enumerate(repl):
            if k not in failures:
                self._m_replica[r]["images"].inc()
        for r in sorted(set(repl)):
            self._m_replica[r]["dispatches"].inc(dispatches)
        if len(traces) == 1 and not failures:
            per_img: dict[int, int] = {}
            for gt in traces[0].groups:
                per_img[gt.image] = (per_img.get(gt.image, 0)
                                     + gt.total_dram_bytes)
            for k, r in enumerate(repl):
                self._m_replica[r]["dram_bytes"].inc(per_img.get(k, 0))

    def step(self) -> list[DcnRequest]:
        """One continuous-batching serving step.

        Admission: free slots refill from the queue in submit order —
        a large request's images may split across steps, and images from
        different requests coalesce into the same step. Requests whose
        deadline already passed fail at admission without occupying a
        slot. Execution: one ``batch_fused`` ragged grid per layer
        segment over ALL occupied slots (the per-image schedules — and
        therefore the DRAM trace — are exactly the per-image
        simulator's; the batch only shares dispatches), with the
        retry/degrade fault containment of :meth:`_execute_isolated`.
        Returns the requests that resolved this step — finished OR
        failed, each exactly once.
        """
        tr = self.tracer
        faults = self._faults
        if faults is not None:
            begin = getattr(faults, "begin_step", None)
            if begin is not None:
                begin()
        finished: list[DcnRequest] = []
        with tr.span("serve.admit", queue_depth=self.queue_depth):
            with self._lock:
                now = self._clock()
                for i in self._admission_order():
                    while self._queue:
                        req, j = self._queue.popleft()
                        self._queue_room.notify_all()
                        if req.done:
                            continue   # failed/shed while queued
                        if req.deadline is not None and now > req.deadline:
                            if self._fail_locked(
                                    req,
                                    DeadlineExceededError(
                                        req.rid, deadline=req.deadline),
                                    now):
                                finished.append(req)
                            continue
                        self._slots[i] = (req, j)
                        break
                occupied = [(i, s[0], s[1])
                            for i, s in enumerate(self._slots)
                            if s is not None]
        if not occupied:
            return finished
        step_id = self._m_steps.count
        hits0 = self.cache.info()["image_hits"] if tr.enabled else 0
        mark = len(tr) if tr.enabled else 0
        images = [req.x[j] for _, req, j in occupied]
        # Slot-ordered, and the slot->replica map is contiguous, so the
        # step batch is shard-contiguous by construction.
        repl = [self._slot_replica[i] for i, _, _ in occupied]
        with tr.timed("serve.step", step=step_id,
                      width=len(occupied)) as ssp:
            outs, traces, failures, degraded = \
                self._execute_isolated(images, repl)
            dispatches = sum(t.kernel_dispatches for t in traces)
            dram = sum(t.total_dram_bytes for t in traces)
            ssp.set(dispatches=dispatches, dram_bytes=dram,
                    failures=len(failures), degraded=degraded)
        if tr.enabled:
            dispatch_spans = [s for s in tr.spans_since(mark)
                              if s.name.startswith("dispatch.")]
            self.timeline.append({
                "step": step_id,
                "width": len(occupied),
                "wall_s": ssp.dur,
                "dispatches": dispatches,
                "dram_bytes": dram,
                "failures": len(failures),
                "degraded": degraded,
                "image_hits": (self.cache.info()["image_hits"]
                               - hits0),
                "schedule_backend": self._step_cfg.schedule_backend,
                "dispatch_spans": [
                    {"name": s.name, "dur_s": s.dur, **s.attrs}
                    for s in dispatch_spans],
            })
        now = self._clock()
        with self._lock:
            self._m_steps.inc()
            self._m_images.inc(len(occupied))
            for t in traces:
                self._absorb_trace(t)
            self._attribute_replicas(repl, traces, failures)
            self.last_step_faulted = bool(failures)
            for k, (i, req, j) in enumerate(occupied):
                self._slots[i] = None
                if req.done:
                    continue   # a step-mate image already failed it
                if k in failures:
                    e = failures[k]
                    err = (e if isinstance(e, RequestFailedError)
                           else RequestFailedError(req.rid, cause=e))
                    if self._fail_locked(req, err, now):
                        finished.append(req)
                    continue
                if k in outs:
                    req.out[j] = outs[k]
                if req.deadline is not None and now > req.deadline:
                    # Mid-flight expiry: computed, but past the caller's
                    # deadline — the contract is the deadline, not the
                    # compute.
                    if self._fail_locked(
                            req,
                            DeadlineExceededError(req.rid,
                                                  deadline=req.deadline),
                            now):
                        finished.append(req)
                    continue
                if all(o is not None for o in req.out):
                    req.done = True
                    req.finish_s = now
                    self.latency.add(now - req.submit_s)
                    finished.append(req)
        return finished

    def drain(self, max_steps: int = 10_000) -> list[DcnRequest]:
        """Serve until queue and slots are empty. Returns every request
        that resolved during the drain (finished or failed), each
        exactly once. Raises :class:`DrainTimeout` — carrying the stuck
        rids and everything that did resolve — if ``max_steps`` is
        exhausted with work still in flight, instead of silently
        dropping it."""
        finished: list[DcnRequest] = []
        with self.tracer.span("serve.drain") as sp:
            for _ in range(max_steps):
                finished.extend(self.step())
                with self._lock:
                    idle = (not self._queue
                            and all(s is None for s in self._slots))
                if idle:
                    sp.set(finished=len(finished))
                    return finished
            with self._lock:
                stuck = sorted(
                    {req.rid for req, _ in self._queue}
                    | {s[0].rid for s in self._slots if s is not None})
            sp.set(finished=len(finished), stuck=len(stuck))
        if stuck:
            raise DrainTimeout(stuck, finished=finished)
        return finished

    @property
    def stats(self) -> dict[str, Any]:
        """Serving counters: schedule-cache hit/miss + dispatch/overlap.

        With ``graph=GraphConfig(dispatch="batch_fused")`` the cache is
        keyed per image but the dispatch grid is assembled per batch:
        ``image_hits``/``batch_assemblies`` split the hit accounting
        (partial batch hits skip scheduling only for the hit images),
        and ``dispatches_per_batch`` reports the average host-issued
        kernel dispatches per served request batch.

        The whole snapshot is taken under the engine lock (the cache
        keeps its own), so a concurrent submitter can never tear the
        view: counters and queue depth are read at one instant.
        """
        with self._lock:
            info = self.cache.info()
            total = info["hits"] + info["misses"]
            return {
                "requests": self.requests,
                "images": self.images,
                "schedule_cache_hits": info["hits"],
                "schedule_cache_misses": info["misses"],
                "schedule_cache_hit_rate": (info["hits"] / total
                                            if total else 0.0),
                "schedule_cache_size": info["size"],
                "image_hits": info["image_hits"],
                "image_lookups": info["image_lookups"],
                "image_hit_rate": (info["image_hits"]
                                   / info["image_lookups"]
                                   if info["image_lookups"] else 0.0),
                "batch_assemblies": info["batch_assemblies"],
                "kernel_dispatches": self.kernel_dispatches,
                "dispatches_per_batch": (self.kernel_dispatches
                                         / self.requests
                                         if self.requests else 0.0),
                "host_overlap_frac": self.overlap.host_overlap_frac,
                "schedule_backend": self.graph_cfg.schedule_backend,
                "dispatch": self.graph_cfg.dispatch,
                "schedule_s": self.overlap.schedule_s,
                "schedule_device_frac": self.overlap.schedule_device_frac,
                "slots": self.n_slots,
                "replicas": self.replicas,
                "per_replica": [
                    {"images": c["images"].count,
                     "dispatches": c["dispatches"].count,
                     "dram_bytes": c["dram_bytes"].count}
                    for c in self._m_replica],
                "allgather_bytes": self._m_allgather.count,
                "queue_depth": len(self._queue),
                "steps": self.steps,
                "host_schedule_builds": self.host_schedule_builds,
                "latency": self.latency.summary(),
                "max_queue": self.max_queue,
                "queue_policy": self.queue_policy,
                "requests_failed": self._m_failed.count,
                "deadline_expired": self._m_deadline.count,
                "queue_rejected": self._m_rejected.count,
                "queue_shed": self._m_shed.count,
                "step_retries": self._m_retries.count,
                "degraded_steps": self._m_degraded.count,
                "watchdog_failovers": self.watchdog_failovers,
                "autotune": self._step_cfg.autotune,
                "plan_cache_hits": self.plan_cache_hits,
                "autotune_search_s": self._autotune_search_s,
                "tuned_groups": self.tuned_groups,
            }

    def metrics_snapshot(self) -> dict[str, Any]:
        """One machine-readable view of every engine metric: the
        registry counters/histograms plus gauges synced at call time
        (cache state + hit rates, queue/slot depths, overlap fractions,
        the engine-relative ``host_schedule_builds`` delta). Every value
        ``stats`` reports — and every counter the benchmark gates —
        appears here under a stable name."""
        m = self.metrics
        with self._lock:
            self.cache.publish(m, prefix="schedule_cache")
            m.gauge("serving.queue_depth").set(len(self._queue))
            m.gauge("serving.slots").set(self.n_slots)
            m.gauge("serving.host_schedule_builds").set(
                self.host_schedule_builds)
            m.gauge("serving.watchdog_failovers").set(
                self.watchdog_failovers)
            req = self._m_requests.count
            m.gauge("serving.dispatches_per_batch").set(
                self._m_dispatches.count / req if req else 0.0)
            m.gauge("serving.host_overlap_frac").set(
                self.overlap.host_overlap_frac)
            m.gauge("serving.schedule_s").set(self.overlap.schedule_s)
            m.gauge("serving.schedule_device_frac").set(
                self.overlap.schedule_device_frac)
            m.gauge("serving.plan_cache_hits").set(self.plan_cache_hits)
            m.gauge("serving.autotune_search_s").set(
                self._autotune_search_s)
            m.gauge("serving.tuned_groups").set(self.tuned_groups)
        return m.snapshot()
