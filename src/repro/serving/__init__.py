from repro.serving.engine import DecodeEngine, Request
