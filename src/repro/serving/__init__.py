from repro.serving.engine import (DcnRequest, DcnServingEngine, DecodeEngine,
                                  Request)
