from repro.serving.engine import (DcnRequest, DcnServingEngine, DecodeEngine,
                                  Request)
from repro.serving.errors import (DeadlineExceededError, DrainTimeout,
                                  QueueFullError, RequestFailedError)

__all__ = [
    "DcnRequest",
    "DcnServingEngine",
    "DecodeEngine",
    "Request",
    "DeadlineExceededError",
    "DrainTimeout",
    "QueueFullError",
    "RequestFailedError",
]
