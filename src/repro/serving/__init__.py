from repro.serving.engine import DcnServingEngine, DecodeEngine, Request
