"""smollm-360m [dense] — 32L, d_model 960, 15H (GQA kv=5), d_ff 2560,
vocab 49152 [hf:HuggingFaceTB/SmolLM family]. Llama-arch small; tied
embeddings. 15 heads do not divide the 16-way model axis — the sharding
resolver degrades head sharding to replication (params.resolve_spec), and
this config is served data-parallel-only by design.
"""

from repro.models.transformer import BlockSpec, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        d_model=960, n_heads=15, n_kv_heads=5, head_dim=64,
        d_ff=2560, vocab=49152,
        pattern=(BlockSpec(),), n_repeats=32,
        tie_embeddings=True, remat="dots")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="smollm-smoke",
        d_model=60, n_heads=3, n_kv_heads=1, head_dim=20,
        d_ff=128, vocab=128,
        pattern=(BlockSpec(),), n_repeats=2,
        tie_embeddings=True)
