"""xlstm-1.3b [ssm] — 48 blocks, d_model 2048, 4 heads, vocab 50304.

sLSTM + mLSTM blocks at 1:7 (one sLSTM per 8-block group), per
[arXiv:2405.04517]. No separate FFN (d_ff = 0): the mLSTM block carries a
2x up-projection internally. Sub-quadratic: O(1) recurrent decode state ->
runs the long_500k cell.
"""

from repro.models.transformer import BlockSpec, ModelConfig
from repro.models.xlstm import XlstmConfig

_PATTERN = tuple([BlockSpec(kind="mlstm", mlp="none")] * 7
                 + [BlockSpec(kind="slstm", mlp="none")])


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b",
        d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
        d_ff=0, vocab=50304,
        pattern=_PATTERN, n_repeats=6,
        xlstm_cfg=XlstmConfig(d_model=2048, n_heads=4, proj_factor=2.0,
                              chunk_size=64),
        tie_embeddings=True, remat="dots", sub_quadratic=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke",
        d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=0, vocab=128,
        pattern=_PATTERN, n_repeats=1,
        xlstm_cfg=XlstmConfig(d_model=64, n_heads=2, proj_factor=2.0,
                              chunk_size=8),
        tie_embeddings=True, sub_quadratic=True)
