"""gemma2-27b [dense] — 46L, d_model 4608, 32H (GQA kv=16), d_ff 36864,
vocab 256000 [arXiv:2408.00118].

Local(4096-window)/global alternating attention, attn-logit softcap 50,
final-logit softcap 30, sandwich (pre+post) norms, GeGLU, sqrt(d) input
embedding scaling. head_dim = d_model/n_heads = 144 per the assigned spec.
"""

from repro.models.transformer import BlockSpec, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b",
        d_model=4608, n_heads=32, n_kv_heads=16, head_dim=144,
        d_ff=36864, vocab=256000,
        pattern=(BlockSpec(window=4096), BlockSpec()), n_repeats=23,
        mlp_kind="geglu", sandwich_norm=True, emb_scale=True,
        attn_softcap=50.0, final_softcap=30.0,
        tie_embeddings=True, remat="dots")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke",
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab=128,
        pattern=(BlockSpec(window=8), BlockSpec()), n_repeats=1,
        mlp_kind="geglu", sandwich_norm=True, emb_scale=True,
        attn_softcap=50.0, final_softcap=30.0, tie_embeddings=True)
