"""llama-3.2-vision-11b [vlm] — 40L, d_model 4096, 32H (GQA kv=8),
d_ff 14336, vocab 128256 [hf:meta-llama/Llama-3.2-11B-Vision].

Cross-attention image layers every 5th layer (position 3 of each period-5
group, matching the published layer ids 3, 8, 13, ...). The modality
frontend is a STUB per the assignment: input_specs provides precomputed
patch embeddings (B, 1601, d_cross) and the backbone consumes them via
cross-attention. DESIGN.md §4 notes where the paper's deformable-sampling
technique lands in a real vision tower.
"""

from repro.models.transformer import BlockSpec, ModelConfig

_PATTERN = (BlockSpec(), BlockSpec(), BlockSpec(),
            BlockSpec(cross=True), BlockSpec())


def full() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=128256,
        pattern=_PATTERN, n_repeats=8,
        rope_theta=500000.0,
        d_cross=4096, n_cross_tokens=1601,
        remat="dots")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-smoke",
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=128,
        pattern=_PATTERN, n_repeats=1,
        d_cross=32, n_cross_tokens=17)
