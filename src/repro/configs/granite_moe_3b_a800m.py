"""granite-moe-3b-a800m [moe] — 32L, d_model 1536, 24H (GQA kv=8),
40 experts top-8 with d_ff 512, vocab 49155
[hf:ibm-granite/granite-3.0-*-base family].

Tied embeddings and logit scaling per the Granite-3.0 recipe. 40 experts
are padded to 48 for the 16-way EP axis (router masks the padding —
repro.models.moe).
"""

from repro.models.moe import MoeConfig
from repro.models.transformer import BlockSpec, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
        d_ff=512, vocab=49155,
        pattern=(BlockSpec(mlp="moe"),), n_repeats=32,
        moe=MoeConfig(d_model=1536, d_ff=512, n_experts=40, top_k=8, ep=16),
        tie_embeddings=True, logits_scale=6.0, remat="dots")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke",
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab=128,
        pattern=(BlockSpec(mlp="moe"),), n_repeats=2,
        moe=MoeConfig(d_model=64, d_ff=32, n_experts=5, top_k=2),
        tie_embeddings=True, logits_scale=6.0)
