"""musicgen-medium [audio] — 48L, d_model 1536, 24H (MHA), d_ff 6144,
vocab 2048 per codebook [arXiv:2306.05284].

Decoder-only over EnCodec tokens: 4 parallel codebooks with summed input
embeddings and 4 output heads (the delay-pattern interleaving is a data-
pipeline concern; the frontend is a stub providing token frames).
LayerNorm + GELU per the published config.
"""

from repro.models.transformer import BlockSpec, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
        d_ff=6144, vocab=2048,
        pattern=(BlockSpec(),), n_repeats=48,
        norm="layer", mlp_kind="gelu",
        n_codebooks=4, remat="dots")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke",
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=64,
        pattern=(BlockSpec(),), n_repeats=2,
        norm="layer", mlp_kind="gelu", n_codebooks=4)
