"""qwen3-1.7b [dense] — 28L, d_model 2048, 16H (GQA kv=8), d_ff 6144,
vocab 151936 [hf:Qwen/Qwen3 family]. qk-norm on every attention layer."""

from repro.models.transformer import BlockSpec, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b",
        d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=6144, vocab=151936,
        pattern=(BlockSpec(),), n_repeats=28,
        qk_norm=True, rope_theta=1e6, tie_embeddings=True, remat="dots")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke",
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=128,
        pattern=(BlockSpec(),), n_repeats=2,
        qk_norm=True, tie_embeddings=True)
