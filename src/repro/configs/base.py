"""Config registry plumbing + the four assigned input-shape cells.

Each arch file exports ``full()`` (the exact published config) and
``smoke()`` (a reduced same-family config for CPU tests). ``input_specs``
builds ShapeDtypeStruct stand-ins for every model input of a (config,
shape) cell — the dry-run lowers against these, so nothing is allocated.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.params import LogicalAxes
from repro.models.transformer import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

SMOKE_SHAPES = {
    "train": ShapeCell("smoke_train", "train", 32, 2),
    "decode": ShapeCell("smoke_decode", "decode", 32, 2),
}


def cell_supported(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """long_500k needs sub-quadratic decode (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-softmax attention: 512k-token decode is "
                       "quadratic-history; skipped per DESIGN.md §4")
    return True, ""


def _token_shape(cfg: ModelConfig, batch: int, seq: int):
    if cfg.n_codebooks > 1:
        return (batch, seq, cfg.n_codebooks)
    return (batch, seq)


def input_specs(cfg: ModelConfig, shape: ShapeCell,
                cache_dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every input of this cell."""
    b, s = shape.global_batch, shape.seq
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct(_token_shape(cfg, b, s + 1),
                                                i32)}
        if cfg.d_cross:
            specs["cross_states"] = jax.ShapeDtypeStruct(
                (b, cfg.n_cross_tokens, cfg.d_cross), jnp.bfloat16)
        return {"batch": specs}
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct(_token_shape(cfg, b, s), i32)}
        if cfg.d_cross:
            specs["cross_states"] = jax.ShapeDtypeStruct(
                (b, cfg.n_cross_tokens, cfg.d_cross), jnp.bfloat16)
        return {"batch": specs}
    # decode: one new token against a seq-long cache
    mk = lambda shp, axes: jax.ShapeDtypeStruct(shp, cache_dtype)
    cache = lm.init_cache(mk, cfg, b, s, cache_dtype)
    # state caches are fp32 in the concrete impl; keep dtype consistent
    return {
        "token": jax.ShapeDtypeStruct(_token_shape(cfg, b, 1), i32),
        "pos": jax.ShapeDtypeStruct((b,), i32),
        "cache": cache,
    }


def input_axes(cfg: ModelConfig, shape: ShapeCell) -> dict:
    """LogicalAxes mirror of input_specs (for in_shardings)."""
    if shape.kind in ("train", "prefill"):
        tok_ax = LogicalAxes(("batch", None, None)
                             if cfg.n_codebooks > 1 else ("batch", None))
        specs = {"tokens": tok_ax}
        if cfg.d_cross:
            specs["cross_states"] = LogicalAxes(("batch", None, None))
        return {"batch": specs}
    mk = lambda shp, axes: LogicalAxes(axes)
    cache = lm.init_cache(mk, cfg, shape.global_batch, shape.seq)
    return {
        "token": LogicalAxes(("batch", None, None)
                             if cfg.n_codebooks > 1 else ("batch", None)),
        "pos": LogicalAxes(("batch",)),
        "cache": cache,
    }
