"""jamba-v0.1-52b [hybrid] — 32L, d_model 4096, 32H (GQA kv=8),
d_ff 14336, vocab 65536, MoE 16 experts top-2 [arXiv:2403.19887].

Mamba:attention at 7:1 (attention at position 4 of each period-8 group),
MoE on every second layer (odd positions). Sub-quadratic decode: 28 mamba
layers carry O(1) state; only 4 attention layers keep a KV cache, whose
kv_seq axis shards over "data" for the long_500k cell (launch.sharding).
"""

from repro.models.moe import MoeConfig
from repro.models.ssm import MambaConfig
from repro.models.transformer import BlockSpec, ModelConfig


def _pattern():
    out = []
    for i in range(8):
        kind = "attn" if i == 4 else "mamba"
        mlp = "moe" if i % 2 == 1 else "dense"
        out.append(BlockSpec(kind=kind, mlp=mlp))
    return tuple(out)


def full() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=65536,
        pattern=_pattern(), n_repeats=4,
        moe=MoeConfig(d_model=4096, d_ff=14336, n_experts=16, top_k=2,
                      ep=16),
        mamba=MambaConfig(d_model=4096, expand=2, d_state=16, d_conv=4,
                          chunk_size=256),
        remat="dots", sub_quadratic=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke",
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=128,
        pattern=_pattern(), n_repeats=1,
        moe=MoeConfig(d_model=64, d_ff=32, n_experts=4, top_k=2),
        mamba=MambaConfig(d_model=64, chunk_size=8),
        sub_quadratic=True)
