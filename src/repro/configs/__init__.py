"""Config registry: ``--arch <id>`` resolution for the 10 assigned
architectures plus the paper's own VGG19/SegNet deformable networks."""

from __future__ import annotations

import importlib

from repro.configs.base import (SHAPES, SMOKE_SHAPES, ShapeCell,
                                cell_supported, input_axes, input_specs)
from repro.models.dcn_models import DcnNetConfig

_ARCH_MODULES = {
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "smollm-360m": "repro.configs.smollm_360m",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
}

ARCHS = tuple(_ARCH_MODULES)


def get_config(arch: str, smoke: bool = False):
    """Resolve an --arch id to its ModelConfig."""
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.smoke() if smoke else mod.full()


# The paper's own networks (Table III), selectable like any other arch.
def get_dcn_config(name: str, n_deform: int, variant: str = "dcn2",
                   smoke: bool = False) -> DcnNetConfig:
    if smoke:
        return DcnNetConfig(name=name, n_deform=n_deform, variant=variant,
                            img_size=32, width_mult=0.125, num_classes=10)
    return DcnNetConfig(name=name, n_deform=n_deform, variant=variant,
                        img_size=224, num_classes=1000)


__all__ = ["ARCHS", "SHAPES", "SMOKE_SHAPES", "ShapeCell", "cell_supported",
           "get_config", "get_dcn_config", "input_axes", "input_specs"]
