"""deepseek-v3-671b [moe] — 61L, d_model 7168, 128 heads, vocab 129280
[arXiv:2412.19437].

MLA (q_lora 1536 / kv_lora 512, 128-d nope + 64-d rope per head), 3 dense
prefix layers (d_ff 18432) + 58 MoE layers with 1 shared + 256 routed
experts (d_ff 2048), top-8 sigmoid aux-loss-free router with
routed_scaling_factor 2.5, and depth-1 MTP. bf16 params; expert weights
EP-sharded over "model" and FSDP over ("pod","data") (repro.models.moe).
"""

from repro.models.layers import MlaConfig
from repro.models.moe import MoeConfig
from repro.models.transformer import BlockSpec, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
        d_ff=18432, vocab=129280,
        prefix=(BlockSpec(kind="mla"),) * 3,
        pattern=(BlockSpec(kind="mla", mlp="moe"),), n_repeats=58,
        mla=MlaConfig(d_model=7168, n_heads=128, q_lora_rank=1536,
                      kv_lora_rank=512, d_nope=128, d_rope=64, d_v=128),
        moe=MoeConfig(d_model=7168, d_ff=2048, n_experts=256, top_k=8,
                      n_shared=1, router="sigmoid", routed_scale=2.5,
                      ep=16),
        mtp=True, rope_theta=10000.0, remat="dots")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke",
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=128,
        prefix=(BlockSpec(kind="mla"),),
        pattern=(BlockSpec(kind="mla", mlp="moe"),), n_repeats=2,
        mla=MlaConfig(d_model=64, n_heads=4, q_lora_rank=32,
                      kv_lora_rank=16, d_nope=16, d_rope=8, d_v=16),
        moe=MoeConfig(d_model=64, d_ff=32, n_experts=8, top_k=2,
                      n_shared=1, router="sigmoid"),
        mtp=True)
