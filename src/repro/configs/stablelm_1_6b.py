"""stablelm-1.6b [dense] — 24L, d_model 2048, 32H (MHA kv=32), d_ff 5632,
vocab 100352 [hf:stabilityai/stablelm-2-1_6b].

LayerNorm + 25% partial rotary embeddings per the StableLM-2 recipe.
"""

from repro.models.transformer import BlockSpec, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b",
        d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=5632, vocab=100352,
        pattern=(BlockSpec(),), n_repeats=24,
        norm="layer", rope_fraction=0.25, remat="dots")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke",
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=128,
        pattern=(BlockSpec(),), n_repeats=2,
        norm="layer", rope_fraction=0.25)
