"""Simulator-guided autotuner for partition cuts & per-group tiles.

The DRAM simulator (:mod:`repro.core.simulator`) is cross-checked to be
*exactly* equal to executed traces, which makes it a free, trustworthy
cost model for offline design-space exploration — the same move Ahn et
al. (2006.05238) build their accelerator around. This module searches
over

* **cut points**: where to split each run of conv/deform layers into
  fused groups (fusing deeper grows the composite-TDT halo; cutting
  pays an interior boundary plane), and
* **per-group tile shapes** ``(tile_h, tile_w)``: the paper's Fig. 17
  lever — finer tiles dedup halo loads, coarser tiles amortize
  per-tile overheads,

scoring every candidate with :func:`simulate_group` on a deterministic
representative input, seeded by the greedy :func:`plan_fused_groups`
plan and refined by coordinate descent (tile passes + merge/split cut
moves) under a configurable simulator-evaluation budget. Only strict
improvements are accepted, so the tuned plan never scores worse than
the greedy seed — the invariant the smoke gate and the hypothesis
property test both check.

Scoring mirrors the executor exactly: same grid clamping
(``min(tile, plane)``), same FIFO depth rule (``num_tiles`` when
``buffer_tiles`` is None), same TDT construction from the same offset
convs — so "simulated bytes under plan P" is precisely what
``run_graph`` will report when executing plan P on the same input.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deform import conv2d, offsets_to_coords
from repro.core.fusion import LayerShape, fused_tile_bytes, \
    plan_fused_groups
from repro.core.simulator import simulate_group
from repro.core.tiles import TileGrid, tdt_from_coords, \
    tdt_standard_conv
from repro.obs import get_tracer
from repro.runtime.graph import DeformNode, PoolNode, UpsampleNode, \
    node_weight_bytes
from repro.tuning.plan_cache import PlanCache, TunedGroup, TunedPlan, \
    default_plan_cache, plan_key

AUTOTUNE_MODES = ("off", "offline", "cached-only")

# Candidate tile sides: powers of two (clamped to the plane) plus the
# config default. Grids past _MAX_TILES tiles are skipped — Algorithm-1
# scheduling is superlinear in tile count and such grids never win on
# CI-sized planes anyway.
_TILE_SIDES = (1, 2, 4, 8, 16, 32)
_MAX_TILES = 1024


def representative_input(graph, seed: int = 0,
                         dtype=jnp.float32) -> jax.Array:
    """Deterministic input the tuner scores on. Plans must be a pure
    function of the cache key, so the tuner never peeks at live
    traffic — a seeded normal image stands in for it (offset convs are
    the real net's; only the image pixels are synthetic)."""
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(
        key, (1, graph.in_h, graph.in_w, graph.in_c), dtype)


def collect_layer_coords(convs, graph, x: jax.Array | None = None,
                         max_displacement: float | None = None) -> list:
    """Per-node sampling coordinates of one input image.

    Advances the dense XLA chain through the whole graph once and
    records each DeformNode's ``(H, W, KK, 2)`` coords (``None`` for
    standard convs and boundary nodes). Coords are tiling-independent,
    so one dense pass serves every candidate grid the search tries.
    """
    # Lazy import: fused_exec imports the tuner (run_graph resolves
    # plans), so the dense helpers are pulled in at call time.
    from repro.runtime.fused_exec import apply_boundary_dense, \
        apply_layer_dense

    if x is None:
        x = representative_input(graph)
    plane = x[0]
    out: list = []
    for node in graph.nodes:
        if isinstance(node, (PoolNode, UpsampleNode)):
            out.append(None)
            plane = apply_boundary_dense(plane, node)
            continue
        p = convs[node.param_idx]
        if isinstance(node, DeformNode):
            offsets = conv2d(plane[None], p.w_off, p.b_off)
            coords = offsets_to_coords(offsets.astype(jnp.float32),
                                       node.kernel_size, node.variant,
                                       max_displacement)[0]
            out.append(coords)
        else:
            out.append(None)
        plane = apply_layer_dense(plane, node, p, max_displacement)
    return out


def tile_candidates(h: int, w: int,
                    tile_hw: tuple[int, int]) -> list[tuple[int, int]]:
    """Candidate ``(tile_h, tile_w)`` shapes for an ``h x w`` plane:
    power-of-two sides clamped to the plane, plus the config default,
    minus grids with more than ``_MAX_TILES`` tiles."""
    hs = sorted({min(s, h) for s in _TILE_SIDES} | {min(tile_hw[0], h)})
    ws = sorted({min(s, w) for s in _TILE_SIDES} | {min(tile_hw[1], w)})
    out = []
    for th in hs:
        for tw in ws:
            if TileGrid(h, w, th, tw).num_tiles <= _MAX_TILES:
                out.append((th, tw))
    return out


class _GroupScorer:
    """Memoized simulated-DRAM scorer over one run of layer nodes.

    ``score(start, stop, th, tw)`` is the exact simulated DRAM bytes of
    ``nodes[start:stop]`` executed as ONE fused group at tile
    ``(th, tw)`` — input halo loads via FIFO replay of the composite
    TDT, the group's weight bytes, and the output plane write. TDTs
    are cached per (node, grid) and scores per (span, tile), so the
    coordinate descent only pays the simulator for genuinely new
    candidates; ``evals`` counts those paid evaluations against the
    search budget.
    """

    def __init__(self, nodes, coords, *, buffer_tiles, dtype_bytes,
                 schedule, tracer):
        self.nodes = list(nodes)
        self.coords = list(coords)
        self.h = self.nodes[0].h
        self.w = self.nodes[0].w
        self.buffer_tiles = buffer_tiles
        self.dtype_bytes = int(dtype_bytes)
        self.schedule = schedule
        self.tracer = tracer
        self.evals = 0
        self._tdts: dict = {}
        self._scores: dict = {}

    def grid(self, th: int, tw: int) -> TileGrid:
        return TileGrid(self.h, self.w,
                        min(th, self.h), min(tw, self.w))

    def _tdt(self, pos: int, grid: TileGrid) -> np.ndarray:
        key = (pos, grid.th, grid.tw)
        b = self._tdts.get(key)
        if b is None:
            c = self.coords[pos]
            if c is None:
                b = tdt_standard_conv(grid, grid,
                                      self.nodes[pos].kernel_size)
            else:
                b = np.asarray(tdt_from_coords(c, grid, grid))
            self._tdts[key] = b
        return b

    def feasible(self, start: int, stop: int, th: int, tw: int,
                 onchip_budget_bytes: int) -> bool:
        """Every layer's working set at this tile must fit the on-chip
        budget — the same TileBuffer bound ``plan_fusion`` enforces."""
        tp = min(th, self.h) * min(tw, self.w)
        for n in self.nodes[start:stop]:
            shape = LayerShape(n.h, n.w, n.c_in, n.c_out,
                               n.kernel_size, self.dtype_bytes)
            if fused_tile_bytes(shape, tp) > onchip_budget_bytes:
                return False
        return True

    def score(self, start: int, stop: int, th: int, tw: int) -> int:
        key = (start, stop, min(th, self.h), min(tw, self.w))
        cached = self._scores.get(key)
        if cached is not None:
            return cached
        grid = self.grid(th, tw)
        m = (grid.num_tiles if self.buffer_tiles is None
             else self.buffer_tiles)
        b_layers = [self._tdt(p, grid) for p in range(start, stop)]
        channels = [(n.c_in, n.c_out)
                    for n in self.nodes[start:stop]]
        weight = sum(node_weight_bytes(n, self.dtype_bytes)
                     for n in self.nodes[start:stop])
        with self.tracer.span("tuning.score", start=start, stop=stop,
                              tile_h=grid.th, tile_w=grid.tw):
            rep = simulate_group(b_layers, grid, channels, weight, m,
                                 dtype_bytes=self.dtype_bytes,
                                 fused=True, schedule=self.schedule)
        self.evals += 1
        bytes_ = int(rep.total_dram_bytes)
        self._scores[key] = bytes_
        return bytes_


def _tune_run(scorer: _GroupScorer, seed_groups, *, candidates,
              onchip_budget_bytes, budget, evals_before: int):
    """Coordinate descent over one run of layers.

    ``seed_groups`` is a list of ``(start, stop, th, tw)`` (run-local
    indices) from the greedy plan at the default tile. Moves: per-group
    tile swap, merge of adjacent groups, split at an interior point —
    each accepted only on a strict simulated-DRAM improvement, so the
    result can never score worse than the seed. The budget counts paid
    simulator evaluations across the whole plan (memo hits are free).
    """
    groups = list(seed_groups)

    def left() -> int:
        return budget - (evals_before + scorer.evals)

    for _ in range(8):                      # descent passes
        improved = False

        # Tile pass: best feasible candidate tile per group.
        for i, (a, b, th, tw) in enumerate(groups):
            if left() <= 0:
                break
            cur = scorer.score(a, b, th, tw)
            best = (cur, th, tw)
            for cth, ctw in candidates:
                if left() <= 0:
                    break
                if (cth, ctw) == (th, tw):
                    continue
                if not scorer.feasible(a, b, cth, ctw,
                                       onchip_budget_bytes):
                    continue
                c = scorer.score(a, b, cth, ctw)
                if c < best[0]:
                    best = (c, cth, ctw)
            if best[1:] != (th, tw):
                groups[i] = (a, b, best[1], best[2])
                improved = True

        # Merge pass: fuse adjacent groups when the composite halo is
        # cheaper than paying the interior boundary plane.
        # One merge step pays at most 4 evals (two merge candidates +
        # the two current-group scores when unmemoized), so require
        # that much headroom — the budget is a hard cap, not a hint.
        i = 0
        while i < len(groups) - 1 and left() >= 4:
            a, b, th1, tw1 = groups[i]
            b2, c, th2, tw2 = groups[i + 1]
            merged = None
            for th, tw in {(th1, tw1), (th2, tw2)}:
                if not scorer.feasible(a, c, th, tw,
                                       onchip_budget_bytes):
                    continue
                s = scorer.score(a, c, th, tw)
                if merged is None or s < merged[0]:
                    merged = (s, th, tw)
            if merged is not None and merged[0] < (
                    scorer.score(a, b, th1, tw1)
                    + scorer.score(b2, c, th2, tw2)):
                groups[i:i + 2] = [(a, c, merged[1], merged[2])]
                improved = True
            else:
                i += 1

        # Split pass: cut a group when two shallower halos beat one
        # deep composite halo (halves inherit the parent tile; the
        # next tile pass re-optimizes them independently).
        # A split step pays the whole-group score (<= 1 eval) plus 2
        # evals per cut point tried.
        i = 0
        while i < len(groups) and left() >= 3:
            a, b, th, tw = groups[i]
            whole = scorer.score(a, b, th, tw)
            cut = None
            for mid in range(a + 1, b):
                if left() <= 1:
                    break
                s = (scorer.score(a, mid, th, tw)
                     + scorer.score(mid, b, th, tw))
                if s < whole and (cut is None or s < cut[0]):
                    cut = (s, mid)
            if cut is not None:
                groups[i:i + 1] = [(a, cut[1], th, tw),
                                   (cut[1], b, th, tw)]
                improved = True
            i += 1

        if not improved or left() <= 0:
            break
    return groups


def autotune_plan(convs, graph, *, onchip_budget_bytes,
                  dtype_bytes: int = 4,
                  tile_hw: tuple[int, int] = (8, 8),
                  buffer_tiles: int | None = None,
                  schedule: str = "alg1", batch: int = 1,
                  budget: int = 128,
                  max_displacement: float | None = None,
                  x: jax.Array | None = None, tracer=None,
                  key: tuple | None = None) -> TunedPlan:
    """Search for the best partition + per-group tile plan of ``graph``.

    Returns a :class:`TunedPlan` whose ``dram_bytes`` is guaranteed
    ``<= greedy_dram_bytes`` (the greedy seed is a candidate and only
    strict improvements replace it). Per-image score; ``batch`` only
    rides in the cache key (every image of a batch replays the same
    plan, so the per-image argmin is the batch argmin).
    """
    tr = tracer if tracer is not None else get_tracer()
    if key is None:
        key = plan_key(graph, batch=batch,
                       onchip_budget_bytes=onchip_budget_bytes,
                       dtype_bytes=dtype_bytes, tile_hw=tile_hw,
                       buffer_tiles=buffer_tiles, schedule=schedule,
                       max_displacement=max_displacement)
    with tr.timed("tuning.search", nodes=len(graph.nodes),
                  budget=budget) as sp:
        coords = collect_layer_coords(convs, graph, x=x,
                                      max_displacement=max_displacement)
        tuned_groups: list[TunedGroup] = []
        tuned_total = 0
        greedy_total = 0
        evals = 0
        i, n = 0, len(graph.nodes)
        while i < n:
            node = graph.nodes[i]
            if isinstance(node, (PoolNode, UpsampleNode)):
                i += 1
                continue
            j = i
            while j < n and not isinstance(graph.nodes[j],
                                           (PoolNode, UpsampleNode)):
                j += 1
            run = graph.nodes[i:j]
            scorer = _GroupScorer(run, coords[i:j],
                                  buffer_tiles=buffer_tiles,
                                  dtype_bytes=dtype_bytes,
                                  schedule=schedule, tracer=tr)
            th0 = min(tile_hw[0], scorer.h)
            tw0 = min(tile_hw[1], scorer.w)
            shapes = [LayerShape(nd.h, nd.w, nd.c_in, nd.c_out,
                                 nd.kernel_size, dtype_bytes)
                      for nd in run]
            seed = [(gp.start, gp.stop, th0, tw0) for gp in
                    plan_fused_groups(shapes, onchip_budget_bytes)]
            greedy_total += sum(scorer.score(*g) for g in seed)
            cands = tile_candidates(scorer.h, scorer.w, tile_hw)
            tuned = _tune_run(scorer, seed, candidates=cands,
                              onchip_budget_bytes=onchip_budget_bytes,
                              budget=budget, evals_before=evals)
            tuned_total += sum(scorer.score(*g) for g in tuned)
            tuned_groups.extend(
                TunedGroup(i + a, i + b, th, tw)
                for a, b, th, tw in tuned)
            evals += scorer.evals
            i = j
        sp.set(candidates=evals, dram_bytes=tuned_total,
               greedy_dram_bytes=greedy_total)
    return TunedPlan(key=key, groups=tuple(tuned_groups),
                     dram_bytes=int(tuned_total),
                     greedy_dram_bytes=int(greedy_total),
                     candidates=int(evals),
                     search_s=float(sp.dur))


def resolve_tuned_plan(convs, graph, *, autotune: str,
                       onchip_budget_bytes, dtype_bytes: int = 4,
                       tile_hw: tuple[int, int] = (8, 8),
                       buffer_tiles: int | None = None,
                       schedule: str = "alg1", batch: int = 1,
                       budget: int = 128,
                       plan_cache_dir: str | None = None,
                       max_displacement: float | None = None,
                       plan_cache: PlanCache | None = None,
                       tracer=None) -> TunedPlan | None:
    """Cache-through plan resolution — the one entry point executors
    and the serving engine use.

    ``off`` → None (greedy planning, no lookup). ``cached-only`` →
    the cached plan or None (never searches: serving replicas that
    must not pay search latency). ``offline`` → cached plan, or run
    the search and persist the winner.
    """
    if autotune not in AUTOTUNE_MODES:
        raise ValueError(f"unknown autotune mode: {autotune!r}")
    if autotune == "off":
        return None
    cache = plan_cache if plan_cache is not None \
        else default_plan_cache(plan_cache_dir)
    key = plan_key(graph, batch=batch,
                   onchip_budget_bytes=onchip_budget_bytes,
                   dtype_bytes=dtype_bytes, tile_hw=tile_hw,
                   buffer_tiles=buffer_tiles, schedule=schedule,
                   max_displacement=max_displacement)
    plan = cache.get(key)
    if plan is not None:
        return plan
    if autotune == "cached-only":
        return None
    plan = autotune_plan(convs, graph,
                         onchip_budget_bytes=onchip_budget_bytes,
                         dtype_bytes=dtype_bytes, tile_hw=tile_hw,
                         buffer_tiles=buffer_tiles, schedule=schedule,
                         batch=batch, budget=budget,
                         max_displacement=max_displacement,
                         tracer=tracer, key=key)
    cache.put(key, plan)
    return plan


def resolve_tuned_tile(coords, h: int, w: int, *, c_in: int,
                       c_out: int, kernel_size: int, autotune: str,
                       dtype_bytes: int,
                       tile_hw: tuple[int, int],
                       buffer_tiles: int | None, schedule: str,
                       budget: int = 128,
                       plan_cache_dir: str | None = None,
                       plan_cache: PlanCache | None = None,
                       tracer=None) -> tuple[int, int] | None:
    """Single-layer tile-shape tuning for ``dcn_pipeline``.

    The pipeline has one deformable layer and no partition to cut, so
    the search degenerates to picking the tile shape with the least
    simulated input traffic. Keyed on the layer geometry (not the
    coords): the first resolution's coords act as the representative
    input and the winner is cached for every later call — same
    philosophy as the graph path, where plans deliberately generalize
    across inputs with the same key.
    """
    if autotune not in AUTOTUNE_MODES:
        raise ValueError(f"unknown autotune mode: {autotune!r}")
    if autotune == "off":
        return None
    cache = plan_cache if plan_cache is not None \
        else default_plan_cache(plan_cache_dir)
    key = ("layer", int(h), int(w), int(c_in), int(c_out),
           int(kernel_size), int(dtype_bytes),
           int(tile_hw[0]), int(tile_hw[1]),
           None if buffer_tiles is None else int(buffer_tiles),
           str(schedule))
    plan = cache.get(key)
    if plan is not None:
        g = plan.groups[0]
        return (g.tile_h, g.tile_w)
    if autotune == "cached-only":
        return None
    tr = tracer if tracer is not None else get_tracer()
    with tr.timed("tuning.search", nodes=1, budget=budget) as sp:
        best = None
        evals = 0
        th0, tw0 = min(tile_hw[0], h), min(tile_hw[1], w)
        cands = [(th0, tw0)] + [
            c for c in tile_candidates(h, w, tile_hw)
            if c != (th0, tw0)]
        for th, tw in cands:
            if evals >= budget and best is not None:
                break
            grid = TileGrid(h, w, min(th, h), min(tw, w))
            m = (grid.num_tiles if buffer_tiles is None
                 else buffer_tiles)
            b = np.asarray(tdt_from_coords(coords, grid, grid))
            with tr.span("tuning.score", tile_h=grid.th,
                         tile_w=grid.tw):
                rep = simulate_group([b], grid, [(c_in, c_out)], 0, m,
                                     dtype_bytes=dtype_bytes,
                                     fused=True, schedule=schedule)
            evals += 1
            s = int(rep.total_dram_bytes)
            if best is None or s < best[0]:
                best = (s, grid.th, grid.tw)
        sp.set(candidates=evals, dram_bytes=best[0])
    plan = TunedPlan(key=key,
                     groups=(TunedGroup(0, 1, best[1], best[2]),),
                     dram_bytes=best[0], greedy_dram_bytes=best[0],
                     candidates=evals, search_s=float(sp.dur))
    cache.put(key, plan)
    return (best[1], best[2])
