"""Simulator-guided autotuning: partition cuts, per-group tile shapes,
and a persistent plan cache (ISSUE 10).

``resolve_tuned_plan`` is the one entry point the executors and the
serving engine use; everything else is the search machinery and the
cache it writes through.
"""

from repro.tuning.autotune import (
    AUTOTUNE_MODES,
    autotune_plan,
    collect_layer_coords,
    representative_input,
    resolve_tuned_plan,
    resolve_tuned_tile,
    tile_candidates,
)
from repro.tuning.plan_cache import (
    PlanCache,
    TunedGroup,
    TunedPlan,
    default_plan_cache,
    net_digest,
    plan_cache_hits,
    plan_cache_misses,
    plan_key,
)

__all__ = [
    "AUTOTUNE_MODES",
    "PlanCache",
    "TunedGroup",
    "TunedPlan",
    "autotune_plan",
    "collect_layer_coords",
    "default_plan_cache",
    "net_digest",
    "plan_cache_hits",
    "plan_cache_misses",
    "plan_key",
    "representative_input",
    "resolve_tuned_plan",
    "resolve_tuned_tile",
    "tile_candidates",
]
