"""Persistent cache of autotuned partition / tile-shape plans.

The autotuner (:mod:`repro.tuning.autotune`) searches over group cut
points and per-group ``(tile_h, tile_w)`` shapes, scoring every
candidate with the exact DRAM simulator. The search is pure offline
work, so its result — a :class:`TunedPlan` — is cached per
``(net digest, img_size, batch, onchip_budget, …)``: in memory as an
LRU (sibling of :class:`repro.runtime.cache.ScheduleCache`) and
optionally on disk (the ``plan_cache_dir=`` knob), so a serving
process pays the search once and every later engine, replica or
restart reuses the winning plan.

Disk format: one JSON file per key, named by the sha1 of the key's
repr, written atomically (tmp + ``os.replace``). Corrupt, truncated or
version-skewed files are treated as cache misses — the caller falls
back to a fresh search and rewrites the entry; a bad file can never
poison a run.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import threading
from collections import OrderedDict

from repro.obs import default_registry

PLAN_FORMAT_VERSION = 1

# Process-wide counters (PR 7 registry): the serving engine reports the
# delta from its construction-time baseline, mirroring how
# ``host_schedule_builds`` / ``staging.watchdog_failovers`` are exposed.
plan_cache_hits = default_registry().counter(
    "plan_cache.hits",
    help="tuned-plan cache hits (memory or disk) this process")
plan_cache_misses = default_registry().counter(
    "plan_cache.misses",
    help="tuned-plan cache misses (searches paid) this process")


@dataclasses.dataclass(frozen=True)
class TunedGroup:
    """One fused group of a tuned plan: the graph-node index span
    ``[start, stop)`` it fuses, plus the tile shape its schedules and
    dispatches use (overriding the config default for this group)."""

    start: int
    stop: int
    tile_h: int
    tile_w: int


@dataclasses.dataclass(frozen=True)
class TunedPlan:
    """Winning plan for one cache key.

    All-tuple fields keep the plan hashable, so it can ride inside the
    ``partition_graph_cached`` lru memo key — the stale-plan fix: two
    runs differing only in their tuned plan must never share memoized
    segments. ``dram_bytes`` / ``greedy_dram_bytes`` are the simulated
    layer-segment totals on the tuner's representative input (boundary
    planes and total weight bytes are partition-invariant, so the
    comparison is exact for ranking).
    """

    key: tuple
    groups: tuple[TunedGroup, ...]
    dram_bytes: int
    greedy_dram_bytes: int
    candidates: int
    search_s: float

    def to_json(self) -> dict:
        return {
            "version": PLAN_FORMAT_VERSION,
            "key": list(self.key),
            "groups": [[g.start, g.stop, g.tile_h, g.tile_w]
                       for g in self.groups],
            "dram_bytes": int(self.dram_bytes),
            "greedy_dram_bytes": int(self.greedy_dram_bytes),
            "candidates": int(self.candidates),
            "search_s": float(self.search_s),
        }

    @classmethod
    def from_json(cls, obj: dict) -> "TunedPlan":
        if not isinstance(obj, dict):
            raise ValueError("plan entry is not an object")
        if obj.get("version") != PLAN_FORMAT_VERSION:
            raise ValueError("plan format version mismatch")
        groups = tuple(TunedGroup(int(a), int(b), int(th), int(tw))
                       for a, b, th, tw in obj["groups"])
        return cls(key=_freeze(obj["key"]), groups=groups,
                   dram_bytes=int(obj["dram_bytes"]),
                   greedy_dram_bytes=int(obj["greedy_dram_bytes"]),
                   candidates=int(obj["candidates"]),
                   search_s=float(obj["search_s"]))


def _freeze(v):
    """JSON round-trips tuples as lists; re-freeze them for hashing."""
    return tuple(_freeze(x) for x in v) if isinstance(v, list) else v


def net_digest(graph) -> str:
    """Structural digest of a :class:`NetGraph`: the nodes are frozen
    dataclasses, so ``repr`` covers channels, kernel sizes, variants,
    relu flags and the input plane — anything that changes the graph
    changes the digest."""
    return hashlib.sha1(repr(graph).encode()).hexdigest()


def plan_key(graph, *, batch, onchip_budget_bytes, dtype_bytes,
             tile_hw, buffer_tiles, schedule,
             max_displacement=None) -> tuple:
    """Cache key: everything that can change the winning plan.

    Supersets the contract key ``(net digest, img_size, batch,
    onchip_budget)`` with the remaining scoring inputs — dtype width,
    the default tile the seed plan uses, the FIFO depth override and
    the schedule flavour. A flat tuple of JSON primitives, so it
    survives the disk round-trip exactly.
    """
    return (net_digest(graph), int(graph.in_h), int(graph.in_w),
            int(batch), int(onchip_budget_bytes), int(dtype_bytes),
            int(tile_hw[0]), int(tile_hw[1]),
            None if buffer_tiles is None else int(buffer_tiles),
            str(schedule),
            None if max_displacement is None
            else float(max_displacement))


class PlanCache:
    """Thread-safe LRU of ``key -> TunedPlan`` with optional disk
    persistence (one JSON file per key under ``cache_dir``)."""

    def __init__(self, maxsize: int = 64,
                 cache_dir: str | None = None):
        self.maxsize = int(maxsize)
        self.cache_dir = cache_dir
        self._lock = threading.Lock()
        self._mem: OrderedDict[tuple, TunedPlan] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.disk_hits = 0
        if cache_dir:
            os.makedirs(cache_dir, exist_ok=True)

    def _path(self, key: tuple) -> str:
        name = hashlib.sha1(repr(key).encode()).hexdigest()
        return os.path.join(self.cache_dir, f"plan-{name}.json")

    def get(self, key: tuple) -> TunedPlan | None:
        with self._lock:
            plan = self._mem.get(key)
            if plan is not None:
                self._mem.move_to_end(key)
                self.hits += 1
                plan_cache_hits.inc()
                return plan
        plan = self._load(key)
        with self._lock:
            if plan is not None:
                self._remember(key, plan)
                self.hits += 1
                self.disk_hits += 1
                plan_cache_hits.inc()
            else:
                self.misses += 1
                plan_cache_misses.inc()
        return plan

    def _load(self, key: tuple) -> TunedPlan | None:
        """Disk lookup. Any malformed entry — unreadable, bad JSON,
        version skew, key mismatch, nonsense groups — is a miss (the
        caller re-searches and rewrites), never an exception."""
        if not self.cache_dir:
            return None
        try:
            with open(self._path(key), "r", encoding="utf-8") as f:
                plan = TunedPlan.from_json(json.load(f))
            if plan.key != key:
                raise ValueError("stored key mismatch")
            if any(g.stop <= g.start or g.tile_h < 1 or g.tile_w < 1
                   for g in plan.groups):
                raise ValueError("malformed groups")
            return plan
        except (OSError, ValueError, KeyError, TypeError):
            return None

    def _remember(self, key: tuple, plan: TunedPlan) -> None:
        self._mem[key] = plan
        self._mem.move_to_end(key)
        while len(self._mem) > self.maxsize:
            self._mem.popitem(last=False)

    def put(self, key: tuple, plan: TunedPlan) -> None:
        with self._lock:
            self._remember(key, plan)
        if not self.cache_dir:
            return
        fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(plan.to_json(), f)
            os.replace(tmp, self._path(key))
        except OSError:
            # Best-effort persistence: a read-only or full disk must not
            # fail the run — the plan still lives in the memory LRU.
            try:
                os.unlink(tmp)
            except OSError:
                pass

    def clear(self) -> None:
        with self._lock:
            self._mem.clear()

    def info(self) -> dict:
        with self._lock:
            return {"size": len(self._mem), "maxsize": self.maxsize,
                    "hits": self.hits, "misses": self.misses,
                    "disk_hits": self.disk_hits,
                    "dir": self.cache_dir}

    def publish(self, registry, prefix: str = "plan_cache") -> None:
        """Mirror cache state into a :class:`MetricsRegistry` as gauges
        (per-instance view; the process-wide counters above aggregate
        across every cache)."""
        info = self.info()
        for k in ("size", "hits", "misses", "disk_hits"):
            registry.gauge(f"{prefix}.{k}").set(info[k])


_DEFAULT_PLAN_CACHE = PlanCache(maxsize=64)
_DIR_CACHES: dict[str, PlanCache] = {}
_DIR_LOCK = threading.Lock()


def default_plan_cache(cache_dir: str | None = None) -> PlanCache:
    """Process-wide plan cache. One shared instance per ``cache_dir``
    (so every engine / run over the same directory shares the memory
    layer); a single memory-only instance when no directory is set."""
    if cache_dir is None:
        return _DEFAULT_PLAN_CACHE
    path = os.path.abspath(cache_dir)
    with _DIR_LOCK:
        pc = _DIR_CACHES.get(path)
        if pc is None:
            pc = _DIR_CACHES[path] = PlanCache(maxsize=64,
                                               cache_dir=path)
        return pc
