"""Mixture-of-Experts with expert parallelism over the "model" mesh axis.

Design notes (DESIGN.md §4/§5): the paper's TDT insight — turn irregular,
input-dependent gathers into *bounded, schedulable tile traffic* — maps to
MoE token->expert dispatch. We deliberately do NOT use the GShard dense
one-hot dispatch einsum: at DeepSeek scale (E=256) its T*E*C*D MAC cost is
~600x the expert FFN itself. Instead dispatch is gather/scatter into
static *capacity slots* (the "tiles"):

  * tokens are replicated across the "model" axis (the usual TP activation
    layout after attention);
  * each model rank owns E/ep experts; it selects its own (token, k) pairs
    with a cumsum-position capacity assignment (static shapes), scatters
    them into (E_loc, C, D) slot buffers, runs the expert FFN as one
    batched einsum, gathers results back, and the ranks' partial outputs
    are combined with a single psum — no all-to-all at all;
  * expert weights are additionally FSDP-sharded over ("pod","data") and
    all-gathered just-in-time per layer (the scan-over-layers structure
    bounds the transient to one layer's experts).

The block runs under ``jax.shard_map`` (fully manual) when a mesh is
present, and as plain single-device JAX otherwise (the oracle path used by
tests).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import pvary, shard_map
from repro.models.params import Maker


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    d_model: int
    d_ff: int                 # per-expert intermediate width
    n_experts: int            # logical expert count (pre-padding)
    top_k: int
    n_shared: int = 0         # shared-expert multiplier (deepseek: 1)
    router: str = "softmax"   # "softmax" | "sigmoid" (deepseek aux-free)
    capacity_factor: float = 1.25
    ep: int = 1               # expert-parallel degree (model-axis size)
    routed_scale: float = 1.0  # deepseek routed_scaling_factor
    # "fsdp": expert weights sharded (E/model, D/dp) and all-gathered
    #         just-in-time (training layout: bytes ~ params/step).
    # "tp_f": weights stationary, F additionally sharded over dp, tokens
    #         replicated, one psum over (dp, model) (decode layout:
    #         bytes ~ activations/step). §Perf "serve_tp" hillclimb.
    weight_mode: str = "fsdp"

    @property
    def n_experts_padded(self) -> int:
        return math.ceil(self.n_experts / self.ep) * self.ep

    @property
    def e_loc(self) -> int:
        return self.n_experts_padded // self.ep


def init_moe(mk: Maker, cfg: MoeConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts_padded
    p = {
        "router": mk((d, e), ("embed", None), init="fan_in"),
        "w_gate": mk((e, d, f), ("expert", "embed_fsdp", "mlp"),
                     init="fan_in"),
        "w_up": mk((e, d, f), ("expert", "embed_fsdp", "mlp"), init="fan_in"),
        "w_down": mk((e, f, d), ("expert", "mlp_fsdp", "embed"),
                     init="fan_in"),
    }
    if cfg.router == "sigmoid":
        p["e_bias"] = mk((e,), (None,), init="zeros")  # aux-loss-free bias
    if cfg.n_shared:
        fs = f * cfg.n_shared
        p["shared"] = {
            "w_gate": mk((d, fs), ("embed", "mlp"), init="fan_in"),
            "w_up": mk((d, fs), ("embed", "mlp"), init="fan_in"),
            "w_down": mk((fs, d), ("mlp", "embed"), init="fan_in"),
        }
    return p


def _route(p, cfg: MoeConfig, x_flat):
    """-> gates (T, K) f32, expert ids (T, K) i32, aux loss scalar."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    e = cfg.n_experts_padded
    if cfg.n_experts < e:  # mask padded experts off
        pad_mask = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(pad_mask[None], -1e30, logits)

    if cfg.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + p["e_bias"].astype(jnp.float32)[None]
        if cfg.n_experts < e:
            sel = jnp.where(jnp.arange(e)[None] >= cfg.n_experts, -1e30, sel)
        _, eids = jax.lax.top_k(sel, cfg.top_k)
        picked = jnp.take_along_axis(scores, eids, axis=-1)
        gates = picked / jnp.maximum(picked.sum(-1, keepdims=True), 1e-9)
        gates = gates * cfg.routed_scale
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gates, eids = jax.lax.top_k(probs, cfg.top_k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux (a metric for sigmoid/aux-free).
    t = x_flat.shape[0]
    counts = jnp.zeros((e,), jnp.float32).at[eids.reshape(-1)].add(1.0)
    frac = counts / (t * cfg.top_k)
    imp = probs.mean(0)
    aux = cfg.n_experts * jnp.sum(frac * imp)
    return gates, eids, aux


def _expert_ffn(x_slots, w_gate, w_up, w_down):
    """(E_loc, C, D) -> (E_loc, C, D), SwiGLU per expert."""
    dt = x_slots.dtype
    g = jnp.einsum("ecd,edf->ecf", x_slots, w_gate.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", x_slots, w_up.astype(dt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down.astype(dt))


def _moe_core(p, cfg: MoeConfig, x, *, rank, wgather, psum):
    """The per-rank math. x: (B_loc, S, D). rank: this device's EP index."""
    b, s, d = x.shape
    t = b * s
    x_flat = x.reshape(t, d)
    gates, eids, aux = _route(p, cfg, x_flat)

    e_loc = cfg.e_loc
    cap = max(8, int(t * cfg.top_k / cfg.n_experts_padded
                     * cfg.capacity_factor))
    lo = rank * e_loc

    w_gate = wgather(p["w_gate"], 1)   # (E_loc, D, F) after FSDP gather
    w_up = wgather(p["w_up"], 1)
    w_down = wgather(p["w_down"], 1)

    n_slots = e_loc * cap
    x_slots = jnp.zeros((n_slots + 1, d), x.dtype)   # last row = drop bin
    slot_of = []
    keep_of = []
    # Per-k dispatch keeps transients at (T, D) instead of (T*K, D).
    occupancy = jnp.zeros((e_loc,), jnp.int32)
    for k in range(cfg.top_k):
        le = eids[:, k] - lo                                   # (T,)
        local = (le >= 0) & (le < e_loc)
        le_c = jnp.clip(le, 0, e_loc - 1)
        onehot = (le_c[:, None] == jnp.arange(e_loc)[None]) & local[:, None]
        pos = jnp.cumsum(onehot, axis=0) - 1                   # (T, E_loc)
        pos_k = jnp.take_along_axis(pos, le_c[:, None], axis=1)[:, 0]
        pos_k = pos_k + occupancy[le_c]
        occupancy = occupancy + onehot.sum(0, dtype=jnp.int32)
        keep = local & (pos_k < cap)
        slot = jnp.where(keep, le_c * cap + pos_k, n_slots)
        x_slots = x_slots.at[slot].add(jnp.where(keep[:, None], x_flat, 0))
        slot_of.append(slot)
        keep_of.append(keep)

    y_slots = _expert_ffn(x_slots[:n_slots].reshape(e_loc, cap, d),
                          w_gate, w_up, w_down)
    y_slots = jnp.concatenate(
        [y_slots.reshape(n_slots, d), jnp.zeros((1, d), y_slots.dtype)], 0)

    y = jnp.zeros((t, d), jnp.float32)
    for k in range(cfg.top_k):
        contrib = y_slots[slot_of[k]].astype(jnp.float32)
        w = jnp.where(keep_of[k], gates[:, k], 0.0)
        y = y + contrib * w[:, None]
    y = psum(y)
    out = y.astype(x.dtype).reshape(b, s, d)

    if cfg.n_shared:
        sh = p["shared"]
        g = jnp.einsum("bsd,df->bsf", x, sh["w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, sh["w_up"].astype(x.dtype))
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                               sh["w_down"].astype(x.dtype))
    return out, aux


def moe_apply(p, cfg: MoeConfig, x, *, mesh: jax.sharding.Mesh | None = None,
              dp_axes: tuple[str, ...] = ("pod", "data"),
              ep_axis: str = "model"):
    """MoE forward. With a mesh: fully-manual shard_map EP/FSDP; without:
    single-device oracle path (rank 0 owns all experts; requires ep == 1).
    """
    if mesh is None:
        assert cfg.ep == 1, "local path requires ep=1"
        return _moe_core(p, cfg, x, rank=0, wgather=lambda w, ax: w,
                         psum=lambda y: y)

    dp = tuple(a for a in dp_axes if a in mesh.shape)
    # Small batches (e.g. long_500k decode with B=1) can't shard over dp:
    # drop axes until the batch divides (tokens then replicate over the
    # dropped axes — unavoidable and cheap at that batch size).
    while dp and x.shape[0] % math.prod(mesh.shape[a] for a in dp):
        dp = dp[:-1]
    tp_f = cfg.weight_mode == "tp_f"
    if tp_f:
        # weights stationary: tokens replicate (tiny at decode), F shards
        # over dp, one psum combines F-partials and expert-partials.
        batch_spec = P(None, None, None)
        wspec = {
            "router": P(None, None),
            "w_gate": P(ep_axis, None, dp), "w_up": P(ep_axis, None, dp),
            "w_down": P(ep_axis, dp, None),
        }
    else:
        batch_spec = P(dp if len(dp) > 1 else (dp[0] if dp else None),
                       None, None)
        wspec = {
            "router": P(None, None),
            "w_gate": P(ep_axis, dp, None), "w_up": P(ep_axis, dp, None),
            "w_down": P(ep_axis, dp, None),
        }
    if "e_bias" in p:
        wspec["e_bias"] = P(None)
    if "shared" in p:
        wspec["shared"] = {"w_gate": P(None, ep_axis),
                           "w_up": P(None, ep_axis),
                           "w_down": P(ep_axis, None)}

    all_axes = dp + (ep_axis,)

    def body(p_loc, x_loc):
        rank = jax.lax.axis_index(ep_axis)

        if tp_f:
            def wgather(w, ax):
                return w  # stationary: F-sharded partials, no movement

            def psum(y):
                return jax.lax.psum(y, dp + (ep_axis,)) if dp \
                    else jax.lax.psum(y, ep_axis)
        else:
            def wgather(w, ax):
                return jax.lax.all_gather(w, dp, axis=ax, tiled=True) \
                    if dp else w

            def psum(y):
                return jax.lax.psum(y, ep_axis)

        if "shared" in p_loc:  # shared expert runs TP over ep_axis
            routed, aux = _moe_core(
                {k: v for k, v in p_loc.items() if k != "shared"},
                dataclasses.replace(cfg, n_shared=0), x_loc,
                rank=rank, wgather=wgather, psum=lambda y: y)
            sh = p_loc["shared"]
            g = jnp.einsum("bsd,df->bsf", x_loc,
                           sh["w_gate"].astype(x_loc.dtype))
            u = jnp.einsum("bsd,df->bsf", x_loc,
                           sh["w_up"].astype(x_loc.dtype))
            shared = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                                sh["w_down"].astype(x_loc.dtype)) \
                .astype(jnp.float32)
            if tp_f:
                # shared partials vary over ep only; routed vary over dp+ep
                out = (psum(routed.astype(jnp.float32))
                       + jax.lax.psum(shared, ep_axis))
            else:
                out = psum(routed.astype(jnp.float32) + shared)
            out = out.astype(x_loc.dtype)
            aux = pvary(aux, (dp + (ep_axis,)) if tp_f
                                else (ep_axis,))
            return out, jax.lax.pmean(aux, all_axes)

        out, aux = _moe_core(p_loc, cfg, x_loc, rank=rank,
                             wgather=wgather, psum=psum)
        aux = pvary(aux, (dp + (ep_axis,)) if tp_f
                            else (ep_axis,))
        return out, jax.lax.pmean(aux, all_axes)

    return shard_map(
        body, mesh=mesh,
        in_specs=(wspec, batch_spec),
        out_specs=(batch_spec, P()),
    )(p, x)
