"""The paper's benchmark networks (Table III): VGG19 and SegNet with the
last-k convolution layers replaced by deformable convolutions.

Configurations follow §V-A: {VGG19, SegNet} x {-3, -8, -F} x {DCN-I, II}.
Replacement proceeds from the output layer toward the input layer ("we
have deformable convolution placed from the output layer to input layer
... to minimize the deformable convolution induced computation").

The forward pass selects an execution ``backend`` per deformable layer:

  * ``"xla"``      — reference path (repro.core.deform); differentiable.
  * ``"pallas"``   — whole-plane fused Pallas kernels (repro.kernels).
  * ``"pipeline"`` — the scheduler-driven tile-pipeline executor
                     (repro.runtime): TDT -> Algorithm-1 schedule ->
                     packed-tile fused-kernel dispatches. Forward only.
  * ``"graph"``    — the network-graph executor with cross-layer tile
                     fusion (repro.runtime.fused_exec): the backbone is
                     partitioned into fused groups whose boundary planes
                     never round-trip DRAM. Forward only.

The legacy ``use_pallas`` flag maps to ``backend="pallas"``.
``layer_shapes`` feeds the traffic simulator / fusion planner benchmarks.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.deform import (conv2d, deformable_conv2d,
                               fused_deformable_conv2d,
                               init_deformable_conv)
from repro.core.fusion import LayerShape
from repro.kernels.ops import deformable_conv2d_pallas
from repro.runtime.fused_exec import GraphConfig, run_graph
from repro.runtime.graph import build_graph
from repro.runtime.pipeline import (PipelineConfig, clamp_tile_config,
                                    dcn_pipeline)

# (channels, n_convs) per VGG19 stage; maxpool after each stage.
_VGG19_STAGES = ((64, 2), (128, 2), (256, 4), (512, 4), (512, 4))


@dataclasses.dataclass(frozen=True)
class DcnNetConfig:
    name: str                    # "vgg19" | "segnet"
    n_deform: int                # 3 | 8 | -1 (=F, all)
    variant: str = "dcn2"        # dcn1 | dcn2  (paper DCN-I / DCN-II)
    num_classes: int = 100
    in_channels: int = 3
    img_size: int = 64           # paper uses ImageNet 224; smoke uses 32/64
    width_mult: float = 1.0      # smoke-reduction knob
    max_displacement: float | None = None

    def stage_plan(self, decoder: bool = False):
        """[(c_in, c_out, deformable?)] conv list + pool markers."""
        stages = [(max(8, int(c * self.width_mult)), n)
                  for c, n in _VGG19_STAGES]
        convs: list[tuple[int, int]] = []
        c_prev = self.in_channels
        for c, n in stages:
            for _ in range(n):
                convs.append((c_prev, c))
                c_prev = c
        if decoder:  # SegNet decoder mirrors the encoder
            dec = []
            rev = list(reversed(convs))
            for i, (ci, co) in enumerate(rev):
                dec.append((co, ci if i < len(rev) - 1 else rev[-1][1]))
            convs = convs + dec
        n_def = len(convs) if self.n_deform < 0 else min(self.n_deform,
                                                         len(convs))
        flags = [i >= len(convs) - n_def for i in range(len(convs))]
        return [(ci, co, f) for (ci, co), f in zip(convs, flags)]


def init_dcn_net(key: jax.Array, cfg: DcnNetConfig, dtype=jnp.float32):
    decoder = cfg.name == "segnet"
    plan = cfg.stage_plan(decoder)
    params: dict[str, Any] = {"convs": []}
    for i, (ci, co, deform) in enumerate(plan):
        k = jax.random.fold_in(key, i)
        if deform:
            params["convs"].append(init_deformable_conv(
                k, ci, co, 3, cfg.variant, dtype))
        else:
            fan = 9 * ci
            params["convs"].append({
                "w": jax.random.normal(k, (3, 3, ci, co), dtype)
                * jnp.sqrt(2.0 / fan).astype(dtype),
                "b": jnp.zeros((co,), dtype),
            })
    if not decoder:
        k = jax.random.fold_in(key, 10_000)
        c_last = plan[-1][1]
        params["fc"] = {
            "w": jax.random.normal(k, (c_last, cfg.num_classes), dtype) * 0.02,
            "b": jnp.zeros((cfg.num_classes,), dtype),
        }
    else:
        k = jax.random.fold_in(key, 10_000)
        c_last = plan[-1][1]
        params["seg_head"] = {
            "w": jax.random.normal(k, (1, 1, c_last, cfg.num_classes), dtype)
            * 0.02,
            "b": jnp.zeros((cfg.num_classes,), dtype),
        }
    return params


def _pool_positions(cfg: DcnNetConfig) -> set[int]:
    """Conv indices after which a 2x2 maxpool (encoder) happens."""
    pos, i = set(), 0
    for _, n in _VGG19_STAGES:
        i += n
        pos.add(i - 1)
    return pos


def dcn_net_apply(params, cfg: DcnNetConfig, x, *, use_pallas: bool = False,
                  fused: bool = True, backend: str | None = None,
                  pipeline: PipelineConfig | None = None,
                  graph: GraphConfig | None = None):
    """x: (N, H, W, C). Returns logits (N, classes) for vgg19 or per-pixel
    logits (N, H', W', classes) for segnet.

    backend: "xla" (default), "pallas", "pipeline" (the tile-pipeline
    executor, configured by ``pipeline``), or "graph" (the cross-layer
    fused network executor, configured by ``graph``); overrides
    ``use_pallas``.
    """
    if backend is None:
        backend = "pallas" if use_pallas else "xla"
    if backend not in ("xla", "pallas", "pipeline", "graph"):
        raise ValueError(f"unknown backend: {backend!r}")
    decoder = cfg.name == "segnet"

    if backend == "graph":
        net_graph = build_graph(cfg)
        gcfg = clamp_tile_config(graph or GraphConfig(), x.shape[1],
                                 x.shape[2])
        x = run_graph(params["convs"], net_graph, x, config=gcfg,
                      max_displacement=cfg.max_displacement)
        return _apply_head(params, cfg, x, decoder)

    plan = cfg.stage_plan(decoder)
    pools = _pool_positions(cfg)
    n_enc = sum(n for _, n in _VGG19_STAGES)

    def run_conv(p, x, deform):
        if deform:
            if backend == "pipeline":
                pcfg = pipeline or PipelineConfig(
                    tile=max(2, min(8, x.shape[1] // 2, x.shape[2] // 2)))
                # The requested tile is an upper bound: deep-stage planes
                # shrink below it, so clamp per layer (the raw executor
                # rejects tile > plane).
                pcfg = clamp_tile_config(pcfg, x.shape[1], x.shape[2])
                return dcn_pipeline(x, p, variant=cfg.variant,
                                    max_displacement=cfg.max_displacement,
                                    config=pcfg)
            if backend == "pallas":
                return deformable_conv2d_pallas(
                    x, p, variant=cfg.variant,
                    max_displacement=cfg.max_displacement)
            fn = fused_deformable_conv2d if fused else deformable_conv2d
            return fn(x, p, variant=cfg.variant,
                      max_displacement=cfg.max_displacement)
        return conv2d(x, p["w"], p["b"])

    # Encoder pools are skipped once a plane side drops below 2; each
    # decoder upsample must mirror a pool that actually ran, or tiny
    # inputs inflate (img_size=8 used to yield 32x32 segnet logits).
    applied_pools: set[int] = set()
    for i, (ci, co, deform) in enumerate(plan):
        x = jax.nn.relu(run_conv(params["convs"][i], x, deform))
        if i < n_enc and i in pools and x.shape[1] >= 2 and x.shape[2] >= 2:
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
            applied_pools.add(i)
        elif decoder and i >= n_enc and (2 * n_enc - 1 - i) in applied_pools:
            n, h, w, c = x.shape  # unpool by nearest-neighbour upsample
            x = jnp.repeat(jnp.repeat(x, 2, axis=1), 2, axis=2)

    return _apply_head(params, cfg, x, decoder)


def _apply_head(params, cfg: DcnNetConfig, x, decoder: bool):
    if not decoder:
        x = x.mean(axis=(1, 2))
        return x @ params["fc"]["w"] + params["fc"]["b"]
    return conv2d(x, params["seg_head"]["w"], params["seg_head"]["b"])


def layer_shapes(cfg: DcnNetConfig) -> list[LayerShape]:
    """Deformable-layer shapes for the traffic/energy benchmarks, with the
    paper's 8-bit feature size (dtype_bytes=1)."""
    decoder = cfg.name == "segnet"
    plan = cfg.stage_plan(decoder)
    pools = _pool_positions(cfg)
    n_enc = sum(n for _, n in _VGG19_STAGES)
    hw = cfg.img_size
    applied_pools: set[int] = set()
    out = []
    for i, (ci, co, deform) in enumerate(plan):
        if deform:
            out.append(LayerShape(h=hw, w=hw, c_in=ci, c_out=co,
                                  kernel_size=3, dtype_bytes=1))
        if i < n_enc and i in pools and hw >= 2:
            hw = hw // 2
            applied_pools.add(i)
        elif decoder and i >= n_enc and (2 * n_enc - 1 - i) in applied_pools:
            hw *= 2
    return out
