"""Transformer building blocks shared by the assigned architectures.

Norms (RMS/LayerNorm), rotary embeddings (full/partial, NTK theta),
GQA attention with qk-norm / sliding window / logit softcap / cross-attn,
DeepSeek MLA (training path + absorbed latent decode path), and dense MLPs
(SwiGLU / GeGLU / GELU).

All forward functions are pure: ``fn(params, cfg, x, ...)``. Attention has
three entry points:
  * ``attention_train``   — full-sequence causal (XLA einsum path; the
                            Pallas flash kernel is selected by cfg.use_flash
                            on TPU runtimes),
  * ``attention_decode``  — single-step with a KV cache,
  * same pair for MLA.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.params import Maker


# ---------------------------------------------------------------------------
# Config fragments
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0       # stablelm: 0.25 partial rotary
    qk_norm: bool = False            # qwen3
    window: int | None = None        # gemma2 local layers
    attn_softcap: float | None = None  # gemma2
    cross: bool = False              # llama-3.2-vision cross-attn layers
    d_cross: int | None = None       # encoder width for cross-attn
    qk_scale: float | None = None
    impl: str = "ref"                # "ref" | "chunked" (online softmax)
    chunk: int = 2048                # KV chunk for the chunked impl

    @property
    def q_dim(self):
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self):
        return self.n_kv_heads * self.head_dim


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    d_model: int
    d_ff: int
    kind: str = "swiglu"  # swiglu | geglu | gelu


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(mk: Maker, d: int):
    return {"scale": mk((d,), (None,), init="zeros")}  # (1+scale) convention


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


def init_layernorm(mk: Maker, d: int):
    return {"scale": mk((d,), (None,), init="ones"),
            "bias": mk((d,), (None,), init="zeros")}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def make_norm(kind: str):
    if kind == "rms":
        return init_rmsnorm, rmsnorm
    if kind == "layer":
        return init_layernorm, layernorm
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, fraction: float, theta: float):
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, jnp.float32) / rot))
    return inv, rot


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float,
               fraction: float = 1.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    inv, rot = rope_frequencies(d, fraction, theta)
    if rot == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv  # (B,S,rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(mk: Maker, cfg: AttnConfig):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    d_kv_in = cfg.d_cross if (cfg.cross and cfg.d_cross) else d
    p = {
        "wq": mk((d, hq, hd), ("embed", "heads", "head_dim"), init="fan_in"),
        "wk": mk((d_kv_in, hkv, hd), ("embed", "kv_heads", "head_dim"),
                 init="fan_in"),
        "wv": mk((d_kv_in, hkv, hd), ("embed", "kv_heads", "head_dim"),
                 init="fan_in"),
        "wo": mk((hq, hd, d), ("heads", "head_dim", "embed"), init="fan_in"),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(mk, hd)
        p["k_norm"] = init_rmsnorm(mk, hd)
    return p


def _qkv(p, cfg: AttnConfig, x, kv_src, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"].astype(kv_src.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"].astype(kv_src.dtype))
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if not cfg.cross:
        q = apply_rope(q, positions, theta=cfg.rope_theta,
                       fraction=cfg.rope_fraction)
        k = apply_rope(k, positions, theta=cfg.rope_theta,
                       fraction=cfg.rope_fraction)
    return q, k, v


def _sdpa(q, k, v, cfg: AttnConfig, *, causal: bool, q_offset=None,
          kv_valid_len=None):
    """Grouped softmax attention, fp32 logits.

    q: (B,Sq,Hq,D); k/v: (B,Skv,Hkv,D). q_offset: (B,) absolute position of
    q[0] (decode); kv_valid_len: (B,) #valid cache entries.
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = cfg.qk_scale if cfg.qk_scale is not None else d ** -0.5
    qg = q.reshape(b, sq, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if cfg.attn_softcap is not None:
        logits = jnp.tanh(logits / cfg.attn_softcap) * cfg.attn_softcap

    ki = jnp.arange(skv)[None, None, :]
    if q_offset is None:
        qi = jnp.arange(sq)[None, :, None] + (skv - sq)
    else:
        qi = jnp.arange(sq)[None, :, None] + q_offset[:, None, None]
    mask = jnp.ones((b, sq, skv), bool)
    if causal:
        mask &= qi >= ki
    if cfg.window is not None:
        mask &= qi - ki < cfg.window
    if kv_valid_len is not None:
        mask &= ki < kv_valid_len[:, None, None]
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def _sdpa_chunked(q, k, v, cfg: AttnConfig, *, causal: bool):
    """Online-softmax attention over KV chunks — the XLA-level equivalent
    of the Pallas flash kernel (kernels/flash_attention.py): the (Sq, Skv)
    score matrix never exists; the live working set is (Sq, chunk).

    Numerically identical to ``_sdpa`` (same fp32 accumulation; tested to
    2e-4). This is the "flashlike" hillclimb lever in EXPERIMENTS.md §Perf.
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = cfg.qk_scale if cfg.qk_scale is not None else d ** -0.5
    ck = min(cfg.chunk, skv)
    skv_pad = -(-skv // ck) * ck
    if skv_pad != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0)))
    nc = skv_pad // ck

    qg = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, g, d)
    kc = k.reshape(b, nc, ck, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, ck, hkv, d).transpose(1, 0, 2, 3, 4)
    qi = jnp.arange(sq)[:, None] + (skv - sq)          # (sq, 1)

    def body(carry, inp):
        m, l, acc = carry
        kj, vj, j = inp
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                            kj.astype(jnp.float32))    # (b,hkv,g,sq,ck)
        if cfg.attn_softcap is not None:
            logits = jnp.tanh(logits / cfg.attn_softcap) * cfg.attn_softcap
        ki = j * ck + jnp.arange(ck)[None, :]
        mask = ki < skv
        if causal:
            mask &= qi >= ki
        if cfg.window is not None:
            mask &= qi - ki < cfg.window
        logits = jnp.where(mask, logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vj.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                  (kc, vc, jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d).astype(q.dtype)


def sdpa_any(q, k, v, cfg: AttnConfig, *, causal: bool):
    if cfg.impl == "chunked":
        return _sdpa_chunked(q, k, v, cfg, causal=causal)
    return _sdpa(q, k, v, cfg, causal=causal)


def attention_train(p, cfg: AttnConfig, x, *, positions=None, kv_src=None,
                    use_flash: bool = False, flash_interpret: bool = True):
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    kv_src = x if kv_src is None else kv_src
    q, k, v = _qkv(p, cfg, x, kv_src, positions)
    causal = not cfg.cross
    if use_flash:
        from repro.kernels.flash_attention import flash_attention
        out = flash_attention(q, k, v, causal=causal, window=cfg.window,
                              softcap=cfg.attn_softcap, scale=cfg.qk_scale,
                              interpret=flash_interpret)
    else:
        out = sdpa_any(q, k, v, cfg, causal=causal)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))


def init_kv_cache(mk_or_none, cfg: AttnConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    axes_k = ("batch", "kv_seq", "kv_heads", "head_dim")
    if mk_or_none is not None:
        return {"k": mk_or_none(shape, axes_k),
                "v": mk_or_none(shape, axes_k)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_decode(p, cfg: AttnConfig, x, cache, pos):
    """x: (B, 1, D); cache {"k","v"}: (B, Smax, Hkv, D); pos: (B,) int32.

    Returns (out (B,1,D), new_cache). Cross-attn layers use a static cache
    (precomputed encoder KV) and do not update it. On TPU runtimes the
    inner attention is served by the split-KV Pallas kernel
    (repro.kernels.flash_decode, same ragged-length masking semantics —
    validated against this path in tests/test_kernels.py); the XLA einsum
    here is the dry-run/CPU form.
    """
    b = x.shape[0]
    positions = pos[:, None]
    if cfg.cross:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        if cfg.qk_norm:
            q = rmsnorm(p["q_norm"], q)
        out = _sdpa(q, cache["k"].astype(x.dtype), cache["v"].astype(x.dtype),
                    cfg, causal=False, q_offset=pos)
        return (jnp.einsum("bshk,hkd->bsd", out,
                           p["wo"].astype(out.dtype)), cache)

    q, k_new, v_new = _qkv(p, cfg, x, x, positions)
    k = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u.astype(c.dtype), (i, 0, 0)))(cache["k"], k_new, pos)
    v = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u.astype(c.dtype), (i, 0, 0)))(cache["v"], v_new, pos)
    out = _sdpa(q, k.astype(x.dtype), v.astype(x.dtype), cfg, causal=True,
                q_offset=pos, kv_valid_len=pos + 1)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))
    return out, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# DeepSeek MLA (multi-head latent attention)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MlaConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128
    rope_theta: float = 10000.0

    @property
    def qk_dim(self):
        return self.d_nope + self.d_rope


def init_mla(mk: Maker, cfg: MlaConfig):
    d, h = cfg.d_model, cfg.n_heads
    return {
        "wq_a": mk((d, cfg.q_lora_rank), ("embed", "q_lora"), init="fan_in"),
        "q_a_norm": init_rmsnorm(mk, cfg.q_lora_rank),
        "wq_b": mk((cfg.q_lora_rank, h, cfg.qk_dim),
                   ("q_lora", "heads", "head_dim"), init="fan_in"),
        "wkv_a": mk((d, cfg.kv_lora_rank + cfg.d_rope), ("embed", "kv_lora"),
                    init="fan_in"),
        "kv_a_norm": init_rmsnorm(mk, cfg.kv_lora_rank),
        "wk_b": mk((cfg.kv_lora_rank, h, cfg.d_nope),
                   ("kv_lora", "heads", "head_dim"), init="fan_in"),
        "wv_b": mk((cfg.kv_lora_rank, h, cfg.d_v),
                   ("kv_lora", "heads", "head_dim"), init="fan_in"),
        "wo": mk((h, cfg.d_v, d), ("heads", "head_dim", "embed"),
                 init="fan_in"),
    }


def _mla_qkr(p, cfg: MlaConfig, x, positions):
    """Queries + latent + rope-key shared by train/decode."""
    q_a = rmsnorm(p["q_a_norm"],
                  jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(x.dtype)))
    q = jnp.einsum("bsr,rhk->bshk", q_a, p["wq_b"].astype(x.dtype))
    q_nope, q_rope = q[..., :cfg.d_nope], q[..., cfg.d_nope:]
    q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(x.dtype))
    c_kv = rmsnorm(p["kv_a_norm"], kv_a[..., :cfg.kv_lora_rank])
    k_rope = kv_a[..., cfg.kv_lora_rank:][:, :, None, :]  # shared head
    k_rope = apply_rope(k_rope, positions, theta=cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope[:, :, 0, :]


def mla_train(p, cfg: MlaConfig, x, *, positions=None, impl: str = "ref",
              chunk: int = 2048):
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"].astype(x.dtype))

    # q·k = q_nope·k_nope + q_rope·k_rope  ==  concat(q)·concat(k) with the
    # shared rope key broadcast per head -> reuse the standard SDPA paths
    # (incl. the chunked/flash-like one).
    h = cfg.n_heads
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, h, cfg.d_rope)).astype(k_nope.dtype)],
        axis=-1)
    acfg = AttnConfig(d_model=cfg.d_model, n_heads=h, n_kv_heads=h,
                      head_dim=cfg.qk_dim, qk_scale=cfg.qk_dim ** -0.5,
                      impl=impl, chunk=chunk)
    # v has d_v dims (may differ from qk_dim): pad v to qk_dim then slice.
    if cfg.d_v != cfg.qk_dim:
        v_in = jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                           (0, cfg.qk_dim - cfg.d_v)))
    else:
        v_in = v
    out = sdpa_any(q_full, k_full, v_in, acfg, causal=True)[..., :cfg.d_v]
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(x.dtype))


def init_mla_cache(mk_or_none, cfg: MlaConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    """The MLA decode cache stores only the latent + shared rope key —
    (kv_lora_rank + d_rope) per token instead of 2*H*D (the paper-point of
    MLA; 576 vs 32768 floats/token for deepseek-v3)."""
    shape = (batch, max_len, cfg.kv_lora_rank + cfg.d_rope)
    if mk_or_none is not None:
        return {"ckv": mk_or_none(shape, ("batch", "kv_seq", None))}
    return {"ckv": jnp.zeros(shape, dtype)}


def mla_decode(p, cfg: MlaConfig, x, cache, pos):
    """Absorbed-matmul latent decode: attention runs in the 512-dim latent
    space; W_uk is folded into the query and W_uv into the output."""
    b = x.shape[0]
    positions = pos[:, None]
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkr(p, cfg, x, positions)

    entry = jnp.concatenate([c_kv_new, k_rope_new], axis=-1)  # (B,1,R+dr)
    ckv = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u.astype(c.dtype), (i, 0)))(cache["ckv"], entry, pos)
    c_lat = ckv[..., :cfg.kv_lora_rank].astype(jnp.float32)   # (B,S,R)
    k_rope = ckv[..., cfg.kv_lora_rank:].astype(jnp.float32)  # (B,S,dr)

    # absorb W_uk: q_lat (B,1,H,R)
    q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope.astype(jnp.float32),
                       p["wk_b"].astype(jnp.float32))
    scale = cfg.qk_dim ** -0.5
    logits = (jnp.einsum("bqhr,bkr->bhqk", q_lat, c_lat)
              + jnp.einsum("bqhn,bkn->bhqk", q_rope.astype(jnp.float32),
                           k_rope)) * scale
    ki = jnp.arange(ckv.shape[1])[None, None, None, :]
    logits = jnp.where(ki <= pos[:, None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhqk,bkr->bqhr", probs, c_lat)        # (B,1,H,R)
    out = jnp.einsum("bqhr,rhv->bqhv", o_lat,
                     p["wv_b"].astype(jnp.float32))           # absorb W_uv
    out = out.astype(x.dtype)
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(x.dtype)), \
        {"ckv": ckv}


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def init_mlp(mk: Maker, cfg: MlpConfig):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.kind in ("swiglu", "geglu"):
        return {
            "w_gate": mk((d, f), ("embed", "mlp"), init="fan_in"),
            "w_up": mk((d, f), ("embed", "mlp"), init="fan_in"),
            "w_down": mk((f, d), ("mlp", "embed"), init="fan_in"),
        }
    return {
        "w_up": mk((d, f), ("embed", "mlp"), init="fan_in"),
        "w_down": mk((f, d), ("mlp", "embed"), init="fan_in"),
    }


def mlp(p, cfg: MlpConfig, x):
    if cfg.kind in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
        act = jax.nn.silu(g) if cfg.kind == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x,
                                   p["w_up"].astype(x.dtype)))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
