"""Mamba (S6 selective-scan) block — the SSM layers of jamba-v0.1.

TPU adaptation note (DESIGN.md §2): the CUDA selective-scan kernel fuses
the state expansion (B,S,I,N) so it never hits HBM. In XLA we bound the
same working set by **chunking**: an outer ``lax.scan`` over sequence
chunks carries the (B,I,N) state; inside a chunk the recurrence runs as an
associative scan over ``chunk_size`` steps, and ``jax.checkpoint`` drops
the intra-chunk expansion on the backward pass. Working set per chunk:
B*chunk*I*N instead of B*S*I*N (16x smaller at S=4096, chunk=256).

Decode is the O(1) single-step recurrence over the carried (conv window,
ssm state) cache — this is what makes the ``long_500k`` shape runnable for
jamba (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.params import Maker


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    expand: int = 2
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int | None = None
    chunk_size: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def rank(self) -> int:
        return self.dt_rank if self.dt_rank else -(-self.d_model // 16)


def init_mamba(mk: Maker, cfg: MambaConfig):
    d, i, n, r = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.rank
    return {
        "in_proj": mk((d, 2 * i), ("embed", "mlp"), init="fan_in"),
        "conv_w": mk((cfg.d_conv, i), (None, "mlp"), init="fan_in", scale=1.0),
        "conv_b": mk((i,), ("mlp",), init="zeros"),
        "x_proj": mk((i, r + 2 * n), ("mlp", None), init="fan_in"),
        "dt_w": mk((r, i), (None, "mlp"), init="fan_in"),
        "dt_b": mk((i,), ("mlp",), init="ones"),
        "a_log": mk((i, n), ("mlp", None), init="ones"),
        "d_skip": mk((i,), ("mlp",), init="ones"),
        "out_proj": mk((i, d), ("mlp", "embed"), init="fan_in"),
    }


def _ssm_inputs(p, cfg: MambaConfig, u):
    """u: (B, W, I) conv'd+silu'd inputs -> (dA, dBu, C) per chunk."""
    xdb = jnp.einsum("bwi,ir->bwr", u, p["x_proj"].astype(u.dtype))
    r, n = cfg.rank, cfg.d_state
    dt = jax.nn.softplus(
        jnp.einsum("bwr,ri->bwi", xdb[..., :r], p["dt_w"].astype(u.dtype))
        .astype(jnp.float32) + p["dt_b"].astype(jnp.float32))
    b_in = xdb[..., r:r + n].astype(jnp.float32)          # (B,W,N)
    c_out = xdb[..., r + n:].astype(jnp.float32)          # (B,W,N)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))          # (I,N)
    da = jnp.exp(dt[..., None] * a)                       # (B,W,I,N)
    dbu = (dt * u.astype(jnp.float32))[..., None] * b_in[..., None, :]
    return da, dbu, c_out


def _chunk_scan(carry_h, da, dbu):
    """Associative scan of h' = da*h + dbu within one chunk.

    carry_h: (B,I,N); da/dbu: (B,W,I,N). Returns (h_last, all_h)."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2

    da_all, h_all = jax.lax.associative_scan(combine, (da, dbu), axis=1)
    h_all = h_all + da_all * carry_h[:, None]
    return h_all[:, -1], h_all


def _causal_conv(p, cfg: MambaConfig, x, conv_state=None):
    """Depthwise causal conv1d, kernel d_conv. x: (B,S,I)."""
    k = cfg.d_conv
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    w = p["conv_w"].astype(x.dtype)
    out = sum(xp[:, j:j + x.shape[1]] * w[j] for j in range(k))
    out = out + p["conv_b"].astype(x.dtype)
    return jax.nn.silu(out), xp[:, -(k - 1):]


def mamba_train(p, cfg: MambaConfig, x):
    """x: (B,S,D) -> (B,S,D)."""
    b, s, d = x.shape
    i = cfg.d_inner
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    u, z = xz[..., :i], xz[..., i:]
    u, _ = _causal_conv(p, cfg, u)

    w = min(cfg.chunk_size, s)
    s_pad = -(-s // w) * w
    if s_pad != s:  # pad tail; padded steps only affect sliced-off outputs
        u = jnp.pad(u, ((0, 0), (0, s_pad - s), (0, 0)))
    u_c = u.reshape(b, s_pad // w, w, i).swapaxes(0, 1)    # (NC,B,W,I)

    @jax.checkpoint
    def step(h, u_chunk):
        da, dbu, c_out = _ssm_inputs(p, cfg, u_chunk)
        h_last, h_all = _chunk_scan(h, da, dbu)
        y = jnp.einsum("bwin,bwn->bwi", h_all, c_out)
        return h_last, y.astype(x.dtype)

    h0 = jnp.zeros((b, i, cfg.d_state), jnp.float32)
    _, y_c = jax.lax.scan(step, h0, u_c)
    y = y_c.swapaxes(0, 1).reshape(b, s_pad, i)[:, :s]
    u = u[:, :s]
    y = y + u * p["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x.dtype))


def init_mamba_cache(mk_or_none, cfg: MambaConfig, batch: int,
                     dtype=jnp.bfloat16):
    i, n, k = cfg.d_inner, cfg.d_state, cfg.d_conv
    if mk_or_none is not None:
        return {"conv": mk_or_none((batch, k - 1, i), ("batch", None, "mlp")),
                "ssm": mk_or_none((batch, i, n), ("batch", "mlp", None))}
    return {"conv": jnp.zeros((batch, k - 1, i), dtype),
            "ssm": jnp.zeros((batch, i, n), dtype)}


def mamba_decode(p, cfg: MambaConfig, x, cache):
    """Single-token step. x: (B,1,D); cache {conv (B,K-1,I), ssm (B,I,N)}."""
    b = x.shape[0]
    i = cfg.d_inner
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    u, z = xz[..., :i], xz[..., i:]
    u, conv_new = _causal_conv(p, cfg, u, conv_state=cache["conv"])

    da, dbu, c_out = _ssm_inputs(p, cfg, u)                # W=1
    h = cache["ssm"].astype(jnp.float32) * da[:, 0] + dbu[:, 0]
    y = jnp.einsum("bin,bn->bi", h, c_out[:, 0])[:, None].astype(x.dtype)
    y = y + u * p["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x.dtype))
    return out, {"conv": conv_new.astype(cache["conv"].dtype),
                 "ssm": h.astype(cache["ssm"].dtype)}
