"""Block assembly + pattern-scan layer stacking.

A model is ``prefix`` blocks (non-repeating, e.g. deepseek-v3's 3 dense
layers) followed by ``n_repeats`` copies of a ``pattern`` super-block
(e.g. jamba's period-8 [7 mamba + 1 attn, alternating MoE], gemma-2's
period-2 [local, global]). Pattern layers are stacked into leading-dim
pytrees and executed with ``lax.scan`` -> compile time is O(pattern), not
O(n_layers), at 61-layer scale (DESIGN.md §7).

Each block kind exposes a train forward and a (decode, cache) pair; the
cache pytree mirrors the param pytree structure so the scan can carry
both together.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import ssm, xlstm
from repro.models.layers import (AttnConfig, MlaConfig, MlpConfig,
                                 attention_decode, attention_train,
                                 init_attention, init_kv_cache, init_mla,
                                 init_mla_cache, init_mlp, make_norm,
                                 mla_decode, mla_train, mlp)
from repro.models.moe import MoeConfig, init_moe, moe_apply
from repro.models.params import Maker, stacked


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    kind: str = "attn"          # attn | mla | mamba | mlstm | slstm
    mlp: str = "dense"          # dense | moe | none
    window: int | None = None   # sliding-window attention
    cross: bool = False         # cross-attention (kv from encoder states)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: tuple[BlockSpec, ...]
    n_repeats: int
    prefix: tuple[BlockSpec, ...] = ()
    norm: str = "rms"                    # rms | layer
    mlp_kind: str = "swiglu"
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0
    qk_norm: bool = False
    qk_scale: float | None = None
    attn_softcap: float | None = None
    final_softcap: float | None = None
    sandwich_norm: bool = False          # gemma-2 post-norms
    emb_scale: bool = False              # gemma: x *= sqrt(d)
    logits_scale: float | None = None    # granite
    moe: MoeConfig | None = None
    mla: MlaConfig | None = None
    mamba: ssm.MambaConfig | None = None
    xlstm_cfg: xlstm.XlstmConfig | None = None
    n_codebooks: int = 1                 # musicgen: 4
    d_cross: int | None = None           # llama-vision encoder width
    n_cross_tokens: int = 0
    mtp: bool = False                    # deepseek multi-token prediction
    mtp_weight: float = 0.3
    aux_weight: float = 0.01
    tie_embeddings: bool = False
    remat: str = "none"                  # none | full | dots
    scan_layers: bool = True
    sub_quadratic: bool = False          # long_500k-capable decode
    use_flash: bool = False              # Pallas flash attn on TPU runtimes
    attn_impl: str = "ref"               # "ref" | "chunked" (online softmax)
    attn_chunk: int = 2048

    @property
    def n_layers(self) -> int:
        return len(self.prefix) + self.n_repeats * len(self.pattern)

    def attn_cfg(self, spec: BlockSpec) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
            rope_theta=self.rope_theta, rope_fraction=self.rope_fraction,
            qk_norm=self.qk_norm, window=spec.window,
            attn_softcap=self.attn_softcap, cross=spec.cross,
            d_cross=self.d_cross, qk_scale=self.qk_scale,
            impl=self.attn_impl, chunk=self.attn_chunk)


# ---------------------------------------------------------------------------
# One block
# ---------------------------------------------------------------------------

def init_block(mk: Maker, cfg: ModelConfig, spec: BlockSpec):
    init_norm, _ = make_norm(cfg.norm)
    p: dict[str, Any] = {"norm1": init_norm(mk, cfg.d_model)}
    if spec.kind == "attn":
        p["attn"] = init_attention(mk, cfg.attn_cfg(spec))
    elif spec.kind == "mla":
        p["attn"] = init_mla(mk, cfg.mla)
    elif spec.kind == "mamba":
        p["mix"] = ssm.init_mamba(mk, cfg.mamba)
    elif spec.kind == "mlstm":
        p["mix"] = xlstm.init_mlstm(mk, cfg.xlstm_cfg)
    elif spec.kind == "slstm":
        p["mix"] = xlstm.init_slstm(mk, cfg.xlstm_cfg)
    else:
        raise ValueError(spec.kind)
    if cfg.sandwich_norm:
        p["post1"] = init_norm(mk, cfg.d_model)
    if spec.mlp == "dense":
        p["norm2"] = init_norm(mk, cfg.d_model)
        p["mlp"] = init_mlp(mk, MlpConfig(cfg.d_model, cfg.d_ff, cfg.mlp_kind))
        if cfg.sandwich_norm:
            p["post2"] = init_norm(mk, cfg.d_model)
    elif spec.mlp == "moe":
        p["norm2"] = init_norm(mk, cfg.d_model)
        p["moe"] = init_moe(mk, cfg.moe)
        if cfg.sandwich_norm:
            p["post2"] = init_norm(mk, cfg.d_model)
    return p


def _mix_train(p, cfg: ModelConfig, spec: BlockSpec, h, ctx):
    if spec.kind == "attn":
        kv_src = ctx.get("cross_states") if spec.cross else None
        return attention_train(p["attn"], cfg.attn_cfg(spec), h,
                               kv_src=kv_src, use_flash=cfg.use_flash)
    if spec.kind == "mla":
        return mla_train(p["attn"], cfg.mla, h, impl=cfg.attn_impl,
                         chunk=cfg.attn_chunk)
    if spec.kind == "mamba":
        return ssm.mamba_train(p["mix"], cfg.mamba, h)
    if spec.kind == "mlstm":
        return xlstm.mlstm_train(p["mix"], cfg.xlstm_cfg, h)
    if spec.kind == "slstm":
        return xlstm.slstm_train(p["mix"], cfg.xlstm_cfg, h)
    raise ValueError(spec.kind)


def maybe_constrain(x, ctx):
    """Apply the activation sharding constraint from ctx (GSPMD hint)."""
    spec = ctx.get("act_pspec")
    if spec is not None and len(spec) <= x.ndim:
        return jax.lax.with_sharding_constraint(x, spec)
    return x


def block_train(p, cfg: ModelConfig, spec: BlockSpec, x, ctx):
    """-> (x, aux). ctx: {"cross_states": ..., "mesh": ...}."""
    _, norm = make_norm(cfg.norm)
    h = norm(p["norm1"], x)
    y = _mix_train(p, cfg, spec, h, ctx)
    if cfg.sandwich_norm:
        y = norm(p["post1"], y)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if spec.mlp == "dense":
        h = norm(p["norm2"], x)
        y = mlp(p["mlp"], MlpConfig(cfg.d_model, cfg.d_ff, cfg.mlp_kind), h)
        if cfg.sandwich_norm:
            y = norm(p["post2"], y)
        x = x + y
    elif spec.mlp == "moe":
        h = norm(p["norm2"], x)
        y, aux = moe_apply(p["moe"], cfg.moe, h, mesh=ctx.get("mesh"))
        if cfg.sandwich_norm:
            y = norm(p["post2"], y)
        x = x + y
    return maybe_constrain(x, ctx), aux


def block_decode(p, cfg: ModelConfig, spec: BlockSpec, x, cache, pos, ctx):
    """Single-token step. -> (x, new_cache)."""
    _, norm = make_norm(cfg.norm)
    h = norm(p["norm1"], x)
    if spec.kind == "attn":
        y, new_mix = attention_decode(p["attn"], cfg.attn_cfg(spec), h,
                                      cache["mix"], pos)
    elif spec.kind == "mla":
        y, new_mix = mla_decode(p["attn"], cfg.mla, h, cache["mix"], pos)
    elif spec.kind == "mamba":
        y, new_mix = ssm.mamba_decode(p["mix"], cfg.mamba, h, cache["mix"])
    elif spec.kind == "mlstm":
        y, new_mix = xlstm.mlstm_decode(p["mix"], cfg.xlstm_cfg, h,
                                        cache["mix"])
    elif spec.kind == "slstm":
        y, new_mix = xlstm.slstm_decode(p["mix"], cfg.xlstm_cfg, h,
                                        cache["mix"])
    else:
        raise ValueError(spec.kind)
    if cfg.sandwich_norm:
        y = norm(p["post1"], y)
    x = x + y
    if spec.mlp == "dense":
        h = norm(p["norm2"], x)
        y = mlp(p["mlp"], MlpConfig(cfg.d_model, cfg.d_ff, cfg.mlp_kind), h)
        if cfg.sandwich_norm:
            y = norm(p["post2"], y)
        x = x + y
    elif spec.mlp == "moe":
        h = norm(p["norm2"], x)
        y, _ = moe_apply(p["moe"], cfg.moe, h, mesh=ctx.get("mesh"))
        if cfg.sandwich_norm:
            y = norm(p["post2"], y)
        x = x + y
    return x, {"mix": new_mix}


def init_block_cache(mk_or_none, cfg: ModelConfig, spec: BlockSpec,
                     batch: int, max_len: int, dtype=jnp.bfloat16):
    if spec.kind == "attn":
        if spec.cross:
            n = max(cfg.n_cross_tokens, 1)
            mix = init_kv_cache(mk_or_none, cfg.attn_cfg(spec), batch, n,
                                dtype)
        else:
            mix = init_kv_cache(mk_or_none, cfg.attn_cfg(spec), batch,
                                max_len, dtype)
    elif spec.kind == "mla":
        mix = init_mla_cache(mk_or_none, cfg.mla, batch, max_len, dtype)
    elif spec.kind == "mamba":
        mix = ssm.init_mamba_cache(mk_or_none, cfg.mamba, batch, dtype)
    elif spec.kind == "mlstm":
        mix = xlstm.init_mlstm_cache(mk_or_none, cfg.xlstm_cfg, batch)
    elif spec.kind == "slstm":
        mix = xlstm.init_slstm_cache(mk_or_none, cfg.xlstm_cfg, batch)
    else:
        raise ValueError(spec.kind)
    return {"mix": mix}


# ---------------------------------------------------------------------------
# Layer stack
# ---------------------------------------------------------------------------

def init_layers(mk: Maker, cfg: ModelConfig):
    p: dict[str, Any] = {}
    if cfg.prefix:
        p["prefix"] = [init_block(mk, cfg, s) for s in cfg.prefix]
    if cfg.n_repeats:
        p["stack"] = {
            f"b{j}": stacked(cfg.n_repeats,
                             lambda m, _s=s: init_block(m, cfg, _s), mk)
            for j, s in enumerate(cfg.pattern)
        }
    return p


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "full":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def apply_layers_train(p, cfg: ModelConfig, x, ctx):
    aux = jnp.zeros((), jnp.float32)
    for i, spec in enumerate(cfg.prefix):
        fn = _remat(cfg, functools.partial(block_train, cfg=cfg, spec=spec,
                                           ctx=ctx))
        x, a = fn(p["prefix"][i], x=x)
        aux = aux + a

    if not cfg.n_repeats:
        return x, aux

    def superblock(x, layer_p):
        a_tot = jnp.zeros((), jnp.float32)
        for j, spec in enumerate(cfg.pattern):
            x, a = block_train(layer_p[f"b{j}"], cfg, spec, x, ctx)
            a_tot = a_tot + a
        return x, a_tot

    if cfg.scan_layers:
        def body(carry, layer_p):
            return _remat(cfg, superblock)(carry, layer_p)
        x, auxs = jax.lax.scan(body, x, p["stack"])
        aux = aux + auxs.sum()
    else:
        for r in range(cfg.n_repeats):
            layer_p = jax.tree.map(lambda t: t[r], p["stack"])
            x, a = _remat(cfg, superblock)(x, layer_p)
            aux = aux + a
    return x, aux


def apply_layers_decode(p, cfg: ModelConfig, x, cache, pos, ctx):
    new_prefix = []
    for i, spec in enumerate(cfg.prefix):
        x, c = block_decode(p["prefix"][i], cfg, spec, x,
                            cache["prefix"][i], pos, ctx)
        new_prefix.append(c)

    new_cache: dict[str, Any] = {}
    if new_prefix:
        new_cache["prefix"] = new_prefix
    if cfg.n_repeats:
        def body(carry, xs):
            x = carry
            layer_p, layer_c = xs
            new_c = {}
            for j, spec in enumerate(cfg.pattern):
                x, c = block_decode(layer_p[f"b{j}"], cfg, spec, x,
                                    layer_c[f"b{j}"], pos, ctx)
                new_c[f"b{j}"] = c
            return x, new_c

        if cfg.scan_layers:
            x, stack_cache = jax.lax.scan(body, x,
                                          (p["stack"], cache["stack"]))
        else:
            outs = []
            for r in range(cfg.n_repeats):
                layer = jax.tree.map(lambda t: t[r],
                                     (p["stack"], cache["stack"]))
                x, c = body(x, layer)
                outs.append(c)
            stack_cache = jax.tree.map(lambda *ts: jnp.stack(ts), *outs)
        new_cache["stack"] = stack_cache
    return x, new_cache


def init_layer_caches(mk_or_none, cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    cache: dict[str, Any] = {}
    if cfg.prefix:
        cache["prefix"] = [
            init_block_cache(mk_or_none, cfg, s, batch, max_len, dtype)
            for s in cfg.prefix]
    if cfg.n_repeats:
        if mk_or_none is not None:
            def mk_stacked(shape, axes):
                return mk_or_none((cfg.n_repeats,) + shape, ("layers",) + axes)
            cache["stack"] = {
                f"b{j}": init_block_cache(mk_stacked, cfg, s, batch, max_len,
                                          dtype)
                for j, s in enumerate(cfg.pattern)}
        else:
            cache["stack"] = {
                f"b{j}": jax.tree.map(
                    lambda t: jnp.broadcast_to(t, (cfg.n_repeats,) + t.shape)
                    .copy(),
                    init_block_cache(None, cfg, s, batch, max_len, dtype))
                for j, s in enumerate(cfg.pattern)}
    return cache
