"""Full language model: embeddings, layer stack, heads, loss, decode step.

Covers all assigned-arch needs: multi-codebook audio tokens (musicgen),
cross-attention image conditioning from a stub frontend (llama-3.2-vision),
MTP auxiliary prediction (deepseek-v3), tied embeddings, final-logit
softcap (gemma-2) and logit scaling (granite).

Entry points:
  init_lm(mk, cfg)                       params in any Maker mode
  lm_loss(params, cfg, batch, ctx)       -> (loss, metrics)
  lm_decode_step(params, cfg, cache, token, pos, ctx) -> (logits, cache)
  init_cache / build_cross_cache         decode-cache management
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import make_norm
from repro.models.params import Maker
from repro.models.transformer import (ModelConfig, apply_layers_decode,
                                      apply_layers_train, block_train,
                                      init_block, init_layer_caches,
                                      init_layers)


def init_lm(mk: Maker, cfg: ModelConfig):
    init_norm, _ = make_norm(cfg.norm)
    p: dict[str, Any] = {
        "embed": mk((cfg.n_codebooks, cfg.vocab, cfg.d_model),
                    (None, "vocab", "embed"), init="normal", scale=0.02),
        "layers": init_layers(mk, cfg),
        "final_norm": init_norm(mk, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = mk((cfg.n_codebooks, cfg.d_model, cfg.vocab),
                       (None, "embed", "vocab"), init="fan_in")
    if cfg.mtp:
        from repro.models.transformer import BlockSpec
        mtp_spec = BlockSpec(kind="mla" if cfg.mla else "attn", mlp="dense")
        p["mtp"] = {
            "proj": mk((2 * cfg.d_model, cfg.d_model), ("embed", None),
                       init="fan_in"),
            "norm_h": init_norm(mk, cfg.d_model),
            "norm_e": init_norm(mk, cfg.d_model),
            "block": init_block(mk, cfg, mtp_spec),
        }
    return p


def _embed(p, cfg: ModelConfig, tokens):
    """tokens: (B, S) int32 or (B, S, n_cb) -> (B, S, D)."""
    table = p["embed"]
    if cfg.n_codebooks == 1:
        if tokens.ndim == 3:
            tokens = tokens[..., 0]
        x = table[0][tokens]
    else:
        x = sum(table[c][tokens[..., c]] for c in range(cfg.n_codebooks))
    if cfg.emb_scale:
        x = x * math.sqrt(cfg.d_model)
    return x


def _logits(p, cfg: ModelConfig, x):
    """x: (..., D) -> (..., n_cb, V) fp32."""
    if cfg.tie_embeddings:
        w = p["embed"].swapaxes(1, 2)            # (n_cb, D, V)
    else:
        w = p["head"]
    logits = jnp.einsum("...d,cdv->...cv", x, w.astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if cfg.logits_scale is not None:
        logits = logits / cfg.logits_scale
    if cfg.final_softcap is not None:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits


def _xent(logits, labels):
    """logits (..., V) fp32, labels (...) int32 -> mean CE."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def lm_loss(p, cfg: ModelConfig, batch, ctx=None):
    """batch: {"tokens": (B, S+1[, n_cb]) int32, optional "cross_states"}.

    -> (loss, metrics dict). Next-token CE averaged over all positions
    (+ codebooks), plus MoE aux and MTP losses per config.
    """
    ctx = dict(ctx or {})
    tokens = batch["tokens"]
    if "cross_states" in batch:
        ctx["cross_states"] = batch["cross_states"]
    inputs = tokens[:, :-1]
    labels = tokens[:, 1:]

    from repro.models.transformer import maybe_constrain
    x = maybe_constrain(_embed(p, cfg, inputs), ctx)
    x, aux = apply_layers_train(p["layers"], cfg, x, ctx)
    _, norm = make_norm(cfg.norm)
    h_final = norm(p["final_norm"], x)
    logits = _logits(p, cfg, h_final)                 # (B, S, n_cb, V)

    if cfg.n_codebooks == 1:
        lab = labels if labels.ndim == 2 else labels[..., 0]
        loss = _xent(logits[..., 0, :], lab)
    else:
        loss = _xent(logits, labels)                  # labels (B,S,n_cb)

    metrics = {"ce": loss, "aux": aux}
    if cfg.moe is not None:
        loss = loss + cfg.aux_weight * aux

    if cfg.mtp:
        # Depth-1 MTP (deepseek-v3): combine the trunk state at position i
        # with the embedding of token i+1 to predict token i+2.
        mtp = p["mtp"]
        h_in = norm(mtp["norm_h"], x[:, :-1])                 # (B, S-1, D)
        e_in = norm(mtp["norm_e"], _embed(p, cfg, inputs[:, 1:]))
        h = jnp.einsum("bsd,dk->bsk",
                       jnp.concatenate([h_in, e_in], -1),
                       mtp["proj"].astype(x.dtype))
        from repro.models.transformer import BlockSpec
        mtp_spec = BlockSpec(kind="mla" if cfg.mla else "attn", mlp="dense")
        h, _ = block_train(mtp["block"], cfg, mtp_spec, h, ctx)
        mtp_logits = _logits(p, cfg, norm(p["final_norm"], h))
        lab2 = labels[:, 1:] if labels.ndim == 2 else labels[:, 1:, 0]
        mtp_loss = _xent(mtp_logits[..., 0, :], lab2)
        metrics["mtp"] = mtp_loss
        loss = loss + cfg.mtp_weight * mtp_loss

    metrics["loss"] = loss
    return loss, metrics


def lm_prefill(p, cfg: ModelConfig, batch, ctx=None):
    """Inference prefill: forward the full prompt, return last-position
    logits (B, n_cb, V). (Cache materialization is the decode engine's
    job; prefill compute — the dominant cost — is what this cell lowers.)
    """
    ctx = dict(ctx or {})
    tokens = batch["tokens"]
    if "cross_states" in batch:
        ctx["cross_states"] = batch["cross_states"]
    from repro.models.transformer import maybe_constrain
    x = maybe_constrain(_embed(p, cfg, tokens), ctx)
    x, _ = apply_layers_train(p["layers"], cfg, x, ctx)
    _, norm = make_norm(cfg.norm)
    x = norm(p["final_norm"], x)
    return _logits(p, cfg, x[:, -1])


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(mk_or_none, cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    return init_layer_caches(mk_or_none, cfg, batch, max_len, dtype)


def build_cross_cache(p, cfg: ModelConfig, cache, cross_states):
    """Precompute cross-attention KV from encoder states into the cache
    (done once per request; cross layers never update their cache)."""
    def fill(layer_p, layer_c, spec):
        if not spec.cross:
            return layer_c
        ap = layer_p["attn"]
        k = jnp.einsum("bsd,dhk->bshk", cross_states,
                       ap["wk"].astype(cross_states.dtype))
        v = jnp.einsum("bsd,dhk->bshk", cross_states,
                       ap["wv"].astype(cross_states.dtype))
        return {"mix": {"k": k.astype(layer_c["mix"]["k"].dtype),
                        "v": v.astype(layer_c["mix"]["v"].dtype)}}

    new = dict(cache)
    layers = p["layers"]
    if cfg.prefix:
        new["prefix"] = [fill(layers["prefix"][i], cache["prefix"][i], s)
                         for i, s in enumerate(cfg.prefix)]
    if cfg.n_repeats:
        stack = {}
        for j, spec in enumerate(cfg.pattern):
            if spec.cross:
                stack[f"b{j}"] = jax.vmap(
                    lambda lp, lc, _s=spec: fill(lp, lc, _s))(
                        layers["stack"][f"b{j}"], cache["stack"][f"b{j}"])
            else:
                stack[f"b{j}"] = cache["stack"][f"b{j}"]
        new["stack"] = stack
    return new


def lm_decode_step(p, cfg: ModelConfig, cache, token, pos, ctx=None):
    """One decode step.

    token: (B, 1) or (B, 1, n_cb) int32; pos: (B,) int32 current position.
    -> (logits (B, n_cb, V) fp32, new_cache)
    """
    ctx = dict(ctx or {})
    x = _embed(p, cfg, token)
    x, new_cache = apply_layers_decode(p["layers"], cfg, x, cache, pos, ctx)
    _, norm = make_norm(cfg.norm)
    x = norm(p["final_norm"], x)
    logits = _logits(p, cfg, x[:, -1])                # (B, n_cb, V)
    return logits, new_cache
