"""Model zoo: transformer blocks, MoE, SSM, xLSTM, LM assembly, and the
paper's VGG19/SegNet deformable-conv networks."""
