"""Parameter construction with logical sharding axes (MaxText-style).

Every model init function is written once against a ``Maker`` and can be
instantiated in four modes:

  * ``init``     — real arrays (PRNG-seeded),
  * ``abstract`` — jax.ShapeDtypeStruct stand-ins (dry-run: no allocation),
  * ``axes``     — ``LogicalAxes`` leaves naming each dim's logical axis,
  * ``shapes``   — plain tuples (debugging / memory accounting).

Logical axes are resolved to mesh PartitionSpecs by ``resolve_spec`` using
a per-config rules table (see repro.launch.sharding). Resolution checks
divisibility and drops non-divisible or conflicting mesh axes, so a config
written for the 512-chip mesh still shards (degraded) on 1 CPU device.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class LogicalAxes:
    """Names the logical sharding axis of each tensor dimension."""

    axes: tuple[str | None, ...]

    def __iter__(self):
        return iter(self.axes)

    def __len__(self):
        return len(self.axes)


class Maker:
    """Single-writer parameter factory. See module docstring."""

    def __init__(self, mode: str, key: jax.Array | None = None,
                 dtype=jnp.float32):
        assert mode in ("init", "abstract", "axes", "shapes"), mode
        self.mode = mode
        self.key = key
        self.dtype = dtype
        self._n = 0

    def _next_key(self) -> jax.Array:
        k = jax.random.fold_in(self.key, self._n)
        self._n += 1
        return k

    def __call__(self, shape: tuple[int, ...], axes: tuple[str | None, ...],
                 init: str = "normal", scale: float | None = None):
        assert len(shape) == len(axes), (shape, axes)
        if self.mode == "axes":
            return LogicalAxes(axes)
        if self.mode == "shapes":
            return tuple(shape)
        if self.mode == "abstract":
            return jax.ShapeDtypeStruct(shape, self.dtype)
        key = self._next_key()
        if init == "zeros":
            return jnp.zeros(shape, self.dtype)
        if init == "ones":
            return jnp.ones(shape, self.dtype)
        if init == "normal":
            s = scale if scale is not None else 0.02
            return jax.random.normal(key, shape, self.dtype) * s
        if init == "fan_in":
            fan = math.prod(shape[:-1])
            s = scale if scale is not None else 1.0
            return (jax.random.normal(key, shape, self.dtype)
                    * (s / math.sqrt(max(fan, 1))))
        raise ValueError(f"unknown init {init!r}")


def init_params(fn: Callable, key: jax.Array, dtype=jnp.float32):
    return fn(Maker("init", key, dtype))


def abstract_params(fn: Callable, dtype=jnp.float32):
    return fn(Maker("abstract", dtype=dtype))


def param_axes(fn: Callable):
    return fn(Maker("axes"))


def stacked(n: int, fn: Callable, mk: Maker):
    """Build ``n`` stacked copies of ``fn``'s params (for lax.scan layers).

    The stacking dimension carries the logical axis "layers" (never mesh-
    sharded; it is the scan axis).
    """
    if mk.mode == "axes":
        inner = fn(Maker("axes"))
        return jax.tree.map(
            lambda a: LogicalAxes(("layers",) + a.axes), inner,
            is_leaf=lambda x: isinstance(x, LogicalAxes))
    if mk.mode == "shapes":
        inner = fn(Maker("shapes"))
        return jax.tree.map(lambda s: (n,) + s, inner,
                            is_leaf=lambda x: isinstance(x, tuple))
    if mk.mode == "abstract":
        inner = fn(Maker("abstract", dtype=mk.dtype))
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), inner)
    keys = jax.random.split(mk._next_key(), n)
    return jax.vmap(lambda k: fn(Maker("init", k, mk.dtype)))(keys)


# ---------------------------------------------------------------------------
# Logical-axis -> mesh resolution
# ---------------------------------------------------------------------------

def resolve_spec(axes: LogicalAxes, shape: tuple[int, ...],
                 rules: dict[str, str | tuple[str, ...] | None],
                 mesh: jax.sharding.Mesh) -> P:
    """LogicalAxes -> PartitionSpec under ``rules`` with divisibility and
    mesh-axis-conflict checks (conflicting/non-dividing axes -> replicated,
    as GSPMD requires each mesh axis to appear at most once)."""
    used: set[str] = set()
    out: list = []
    for dim, name in zip(shape, axes.axes):
        target = rules.get(name) if name else None
        if target is None:
            out.append(None)
            continue
        tgt = (target,) if isinstance(target, str) else tuple(target)
        tgt = tuple(t for t in tgt if t in mesh.shape and t not in used)
        size = math.prod(mesh.shape[t] for t in tgt) if tgt else 1
        if not tgt or dim % size != 0:
            out.append(None)
            continue
        used.update(tgt)
        out.append(tgt[0] if len(tgt) == 1 else tgt)
    return P(*out)


def tree_specs(axes_tree, abstract_tree, rules, mesh):
    """Zip an axes tree with an abstract-shape tree -> PartitionSpec tree."""
    return jax.tree.map(
        lambda a, s: resolve_spec(a, s.shape, rules, mesh),
        axes_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, LogicalAxes))


def tree_shardings(axes_tree, abstract_tree, rules, mesh):
    specs = tree_specs(axes_tree, abstract_tree, rules, mesh)
    return jax.tree.map(
        lambda sp: jax.sharding.NamedSharding(mesh, sp), specs,
        is_leaf=lambda x: isinstance(x, P))


def param_bytes(abstract_tree) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(abstract_tree))


def param_count(abstract_tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abstract_tree))
