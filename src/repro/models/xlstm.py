"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM.

mLSTM runs in an exact **chunkwise-parallel** form (the TPU-friendly
dataflow): within a chunk the update is a masked attention-like matmul on
the MXU; across chunks a ``lax.scan`` carries the (C, n, m) state. The
stabilizer m_t = max(logf_t + m_{t-1}, logi_t) unrolls to
A_t + max(m_0, max_s(logi_s - A_s)) with A = cumsum(logf), so the chunked
form reproduces the recurrence bit-for-bit in fp32 (tested against the
step-by-step reference in tests/test_models.py).

sLSTM has a true hidden-to-hidden recurrence (R z_{t-1}) and is inherently
sequential: a ``lax.scan`` over time with per-head block-diagonal R.
Simplifications vs the paper noted in DESIGN.md: no causal-conv feature
path on the sLSTM gates.

Both expose O(1)-state decode steps — this is what makes ``long_500k``
runnable for xlstm-1.3b (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm, init_rmsnorm
from repro.models.params import Maker


@dataclasses.dataclass(frozen=True)
class XlstmConfig:
    d_model: int
    n_heads: int = 4
    proj_factor: float = 2.0      # mLSTM up-projection
    slstm_proj_factor: float = 4.0 / 3.0
    chunk_size: int = 64

    @property
    def d_inner(self) -> int:
        return int(self.d_model * self.proj_factor)

    @property
    def d_head(self) -> int:
        return self.d_inner // self.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(mk: Maker, cfg: XlstmConfig):
    d, i, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    dh = i // h
    return {
        "up": mk((d, 2 * i), ("embed", "mlp"), init="fan_in"),
        # per-head block-diagonal q/k/v (as in xLSTM-1.3B)
        "wq": mk((h, dh, dh), ("heads", None, None), init="fan_in"),
        "wk": mk((h, dh, dh), ("heads", None, None), init="fan_in"),
        "wv": mk((h, dh, dh), ("heads", None, None), init="fan_in"),
        "w_if": mk((i, 2 * h), ("mlp", None), init="fan_in"),
        "b_if": mk((2 * h,), (None,), init="zeros"),
        "norm": init_rmsnorm(mk, i),
        "down": mk((i, d), ("mlp", "embed"), init="fan_in"),
    }


def _mlstm_qkvif(p, cfg: XlstmConfig, u):
    """u: (B,W,I) -> q,k,v (B,H,W,Dh), logi/logf (B,H,W) fp32."""
    b, w, i = u.shape
    h, dh = cfg.n_heads, cfg.d_head
    uh = u.reshape(b, w, h, dh).transpose(0, 2, 1, 3)       # (B,H,W,Dh)
    q = jnp.einsum("bhwd,hde->bhwe", uh, p["wq"].astype(u.dtype))
    k = jnp.einsum("bhwd,hde->bhwe", uh, p["wk"].astype(u.dtype)) * dh ** -0.5
    v = jnp.einsum("bhwd,hde->bhwe", uh, p["wv"].astype(u.dtype))
    gates = (jnp.einsum("bwi,ig->bwg", u, p["w_if"].astype(u.dtype))
             .astype(jnp.float32) + p["b_if"].astype(jnp.float32))
    logi = gates[..., :h].transpose(0, 2, 1)
    logf = jax.nn.log_sigmoid(gates[..., h:]).transpose(0, 2, 1)
    return q, k, v, logi, logf


def _mlstm_chunk(carry, q, k, v, logi, logf):
    """One chunk. carry: C (B,H,Dk,Dv), n (B,H,Dk), m (B,H)."""
    c0, n0, m0 = carry
    bsz, h, w, dh = q.shape
    a = jnp.cumsum(logf, axis=2)                           # (B,H,W)
    g = jax.lax.cummax(logi - a, axis=2)
    m = a + jnp.maximum(m0[..., None], g)                  # (B,H,W)

    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # intra-chunk decay matrix
    dmat = (a[..., :, None] - a[..., None, :]
            + logi[..., None, :] - m[..., :, None])
    tri = jnp.tril(jnp.ones((w, w), bool))
    dmat = jnp.where(tri, jnp.exp(dmat), 0.0)              # (B,H,W,W)

    scores = jnp.einsum("bhtd,bhsd->bhts", qf, kf) * dmat
    h_intra = jnp.einsum("bhts,bhsv->bhtv", scores, vf)
    bscale = jnp.exp(a + m0[..., None] - m)                # (B,H,W)
    h_inter = bscale[..., None] * jnp.einsum("bhtd,bhdv->bhtv", qf, c0)
    n_t = (bscale[..., None] * n0[:, :, None]
           + jnp.einsum("bhts,bhsd->bhtd", dmat, kf))      # (B,H,W,Dk)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhtd,bhtd->bht", qf, n_t)),
                      jnp.exp(-m))
    h_out = (h_intra + h_inter) / den[..., None]           # (B,H,W,Dv)

    # end-of-chunk state
    a_w = a[..., -1:]                                      # (B,H,1)
    m_next = (a_w + jnp.maximum(m0[..., None], g[..., -1:]))[..., 0]
    wlast = jnp.exp(a_w - a + logi - m_next[..., None])    # (B,H,W)
    cscale = jnp.exp(a_w[..., 0] + m0 - m_next)            # (B,H)
    c_next = (cscale[..., None, None] * c0
              + jnp.einsum("bhs,bhsd,bhsv->bhdv", wlast, kf, vf))
    n_next = cscale[..., None] * n0 + jnp.einsum("bhs,bhsd->bhd", wlast, kf)
    return (c_next, n_next, m_next), h_out


def mlstm_train(p, cfg: XlstmConfig, x):
    """x: (B,S,D) -> (B,S,D)."""
    b, s, d = x.shape
    i, h, dh = cfg.d_inner, cfg.n_heads, cfg.d_head
    uz = jnp.einsum("bsd,de->bse", x, p["up"].astype(x.dtype))
    u, z = uz[..., :i], uz[..., i:]
    q, k, v, logi, logf = _mlstm_qkvif(p, cfg, u)

    w = min(cfg.chunk_size, s)
    s_pad = -(-s // w) * w
    if s_pad != s:  # pad tail; padded steps only affect sliced-off outputs
        pad = ((0, 0), (0, 0), (0, s_pad - s))
        q, k, v = (jnp.pad(t, pad + ((0, 0),)) for t in (q, k, v))
        logi, logf = (jnp.pad(t, pad) for t in (logi, logf))
    nc = s_pad // w

    def chop(t):  # (B,H,S,...) -> (NC,B,H,W,...)
        return t.reshape(t.shape[:2] + (nc, w) + t.shape[3:]).swapaxes(0, 2) \
                .swapaxes(1, 2)

    @jax.checkpoint
    def step(carry, xs):
        return _mlstm_chunk(carry, *xs)

    carry0 = (jnp.zeros((b, h, dh, dh), jnp.float32),
              jnp.zeros((b, h, dh), jnp.float32),
              jnp.full((b, h), -1e30, jnp.float32))
    _, h_c = jax.lax.scan(step, carry0,
                          (chop(q), chop(k), chop(v), chop(logi), chop(logf)))
    # h_c: (NC,B,H,W,Dv) -> (B, NC*W=S_pad, H*Dv=I) -> slice to S
    h_all = (h_c.transpose(1, 0, 3, 2, 4).reshape(b, s_pad, i)[:, :s]
             .astype(x.dtype))
    h_all = rmsnorm(p["norm"], h_all)
    y = h_all * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", y, p["down"].astype(x.dtype))


def init_mlstm_cache(mk_or_none, cfg: XlstmConfig, batch: int):
    h, dh = cfg.n_heads, cfg.d_head
    if mk_or_none is not None:
        return {"c": mk_or_none((batch, h, dh, dh),
                                ("batch", "heads", None, None)),
                "n": mk_or_none((batch, h, dh), ("batch", "heads", None)),
                "m": mk_or_none((batch, h), ("batch", "heads"))}
    return {"c": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32),
            "m": jnp.full((batch, h), -1e30, jnp.float32)}


def mlstm_decode(p, cfg: XlstmConfig, x, cache):
    """Single step. x: (B,1,D)."""
    b = x.shape[0]
    i = cfg.d_inner
    uz = jnp.einsum("bsd,de->bse", x, p["up"].astype(x.dtype))
    u, z = uz[..., :i], uz[..., i:]
    q, k, v, logi, logf = _mlstm_qkvif(p, cfg, u)          # W = 1
    qf, kf, vf = (t[:, :, 0].astype(jnp.float32) for t in (q, k, v))
    logi, logf = logi[..., 0], logf[..., 0]

    m0 = cache["m"]
    m = jnp.maximum(logf + m0, logi)
    fg = jnp.exp(logf + m0 - m)
    ig = jnp.exp(logi - m)
    c = fg[..., None, None] * cache["c"] + ig[..., None, None] \
        * kf[..., :, None] * vf[..., None, :]
    n = fg[..., None] * cache["n"] + ig[..., None] * kf
    num = jnp.einsum("bhd,bhdv->bhv", qf, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), jnp.exp(-m))
    h_out = (num / den[..., None]).reshape(b, 1, i).astype(x.dtype)
    h_out = rmsnorm(p["norm"], h_out)
    y = h_out * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["down"].astype(x.dtype))
    return out, {"c": c, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(mk: Maker, cfg: XlstmConfig):
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    f = int(d * cfg.slstm_proj_factor)
    return {
        "w": mk((d, 4 * d), ("embed", "mlp"), init="fan_in"),   # z,i,f,o
        "r": mk((h, dh, 4 * dh), ("heads", None, None), init="fan_in"),
        "b": mk((4 * d,), (None,), init="zeros"),
        "norm": init_rmsnorm(mk, d),
        "up_gate": mk((d, f), ("embed", "mlp"), init="fan_in"),
        "up": mk((d, f), ("embed", "mlp"), init="fan_in"),
        "down": mk((f, d), ("mlp", "embed"), init="fan_in"),
    }


def _slstm_cell(p, cfg: XlstmConfig, wx, state):
    """wx: (B, 4D) input projection for this step."""
    c, n, hid, m = state
    b, d = hid.shape
    h, dh = cfg.n_heads, d // cfg.n_heads
    # gate layout per head: [z, i, f, o] each dh wide
    rh = jnp.einsum("bhx,hxy->bhy", hid.reshape(b, h, dh).astype(jnp.float32),
                    p["r"].astype(jnp.float32))             # (B,H,4*dh)
    rh4 = rh.reshape(b, h, 4, dh)
    wx4 = wx.astype(jnp.float32).reshape(b, h, 4, dh)
    pre = wx4 + rh4 + p["b"].astype(jnp.float32).reshape(1, h, 4, dh)
    z = jnp.tanh(pre[:, :, 0])
    logi = pre[:, :, 1]
    logf = jax.nn.log_sigmoid(pre[:, :, 2])
    o = jax.nn.sigmoid(pre[:, :, 3])
    mh = m.reshape(b, h, dh)
    m_new = jnp.maximum(logf + mh, logi)
    ig = jnp.exp(logi - m_new)
    fg = jnp.exp(logf + mh - m_new)
    ch = fg * c.reshape(b, h, dh) + ig * z
    nh = fg * n.reshape(b, h, dh) + ig
    hid_new = o * ch / jnp.maximum(nh, 1e-6)
    return (ch.reshape(b, d), nh.reshape(b, d),
            hid_new.reshape(b, d), m_new.reshape(b, d))


def slstm_train(p, cfg: XlstmConfig, x):
    b, s, d = x.shape
    wx = jnp.einsum("bsd,de->bse", x, p["w"].astype(x.dtype))
    # reorder (z,i,f,o per-d) -> per-head layout
    wx = wx.reshape(b, s, 4, cfg.n_heads, d // cfg.n_heads) \
        .transpose(0, 1, 3, 2, 4).reshape(b, s, 4 * d)

    def step(state, wx_t):
        new = _slstm_cell(p, cfg, wx_t, state)
        return new, new[2]

    zeros = jnp.zeros((b, d), jnp.float32)
    state0 = (zeros, zeros, zeros, jnp.full((b, d), -1e30, jnp.float32))
    _, h_all = jax.lax.scan(step, state0, wx.swapaxes(0, 1))
    h_all = h_all.swapaxes(0, 1).astype(x.dtype)           # (B,S,D)
    h_all = rmsnorm(p["norm"], h_all)
    g = jnp.einsum("bsd,df->bsf", h_all, p["up_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", h_all, p["up"].astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                      p["down"].astype(x.dtype))


def init_slstm_cache(mk_or_none, cfg: XlstmConfig, batch: int):
    d = cfg.d_model
    if mk_or_none is not None:
        ax = ("batch", None)
        return {k: mk_or_none((batch, d), ax) for k in ("c", "n", "h", "m")}
    zeros = jnp.zeros((batch, d), jnp.float32)
    return {"c": zeros, "n": zeros, "h": zeros,
            "m": jnp.full((batch, d), -1e30, jnp.float32)}


def slstm_decode(p, cfg: XlstmConfig, x, cache):
    b, _, d = x.shape
    wx = jnp.einsum("bsd,de->bse", x, p["w"].astype(x.dtype))[:, 0]
    wx = wx.reshape(b, 4, cfg.n_heads, d // cfg.n_heads) \
        .transpose(0, 2, 1, 3).reshape(b, 4 * d)
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, hid, m = _slstm_cell(p, cfg, wx, state)
    h_out = rmsnorm(p["norm"], hid[:, None].astype(x.dtype))
    g = jnp.einsum("bsd,df->bsf", h_out, p["up_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", h_out, p["up"].astype(x.dtype))
    out = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                     p["down"].astype(x.dtype))
    return out, {"c": c, "n": n, "h": hid, "m": m}
