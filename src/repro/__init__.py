"""repro — a JAX reproduction+extension of "Energy-Efficient Accelerator
Design for Deformable Convolution Networks" (Xu et al., 2021), built as a
multi-pod training/serving framework. See DESIGN.md."""

__version__ = "1.0.0"
