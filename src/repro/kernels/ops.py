"""jit'd public wrappers around the Pallas kernels.

These handle layout (coords -> flat 4-neighbour indices + Eq.5
coefficients), padding to MXU-aligned block multiples, and batching
(vmap adds the batch grid dimension to the pallas_call), so callers see
plain NHWC tensors. Oracles in ``repro.kernels.ref``; XLA fallbacks in
``repro.core.deform``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.deform import (DeformableConvParams, bli_coefficients,
                               conv2d, offsets_to_coords)
from repro.kernels.dcn_bli import bli_tile_matmul
from repro.kernels.dcn_fused import dcn_fused_tile


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def coords_to_idx_coeff(coords: jax.Array, h: int, w: int):
    """(..., 2) float coords -> flat 4-neighbour idx + coeffs (..., 4).

    Neighbour order (r0,c0) (r0,c1) (r1,c0) (r1,c1) matches Eq. 5
    (eta, theta, mu, gamma) as produced by ``bli_coefficients``.
    """
    floor_rc, coeffs = bli_coefficients(coords)
    r0 = jnp.clip(floor_rc[..., 0], 0, h - 1)
    c0 = jnp.clip(floor_rc[..., 1], 0, w - 1)
    r1 = jnp.clip(r0 + 1, 0, h - 1)
    c1 = jnp.clip(c0 + 1, 0, w - 1)
    idx = jnp.stack([r0 * w + c0, r0 * w + c1, r1 * w + c0, r1 * w + c1],
                    axis=-1).astype(jnp.int32)
    return idx, coeffs.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bli_pallas(x: jax.Array, coords: jax.Array, *,
               interpret: bool = True) -> jax.Array:
    """Stage 2 (Eq. 2) via the MXU 4-hot matmul kernel.

    x: (N, H, W, C); coords: (N, H, W, KK, 2) -> (N, H, W, KK, C).
    """
    n, h, w, c = x.shape
    kk = coords.shape[3]
    idx, coeff = coords_to_idx_coeff(coords, h, w)

    p = h * w * kk
    p_pad = round_up(p, 128)
    c_pad = round_up(c, 128)

    x_flat = x.reshape(n, h * w, c)
    if c_pad != c:
        x_flat = jnp.pad(x_flat, ((0, 0), (0, 0), (0, c_pad - c)))
    idx_f = idx.reshape(n, p, 4)
    coeff_f = coeff.reshape(n, p, 4)
    if p_pad != p:
        idx_f = jnp.pad(idx_f, ((0, 0), (0, p_pad - p), (0, 0)))
        coeff_f = jnp.pad(coeff_f, ((0, 0), (0, p_pad - p), (0, 0)))

    fn = functools.partial(bli_tile_matmul, interpret=interpret)
    out = jax.vmap(fn)(x_flat, idx_f, coeff_f)          # (N, P_pad, C_pad)
    return out[:, :p, :c].reshape(n, h, w, kk, c)


@functools.partial(jax.jit,
                   static_argnames=("kernel_size", "variant",
                                    "max_displacement", "interpret"))
def deformable_conv2d_pallas(
    x: jax.Array,
    params: DeformableConvParams,
    *,
    kernel_size: int = 3,
    variant: str = "dcn2",
    max_displacement: float | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Full deformable conv: XLA stage-1 conv + fused Pallas stages 2+3.

    The fused kernel is invoked per (scheduled) tile on hardware; on the
    validation path the whole plane is one tile (S = H*W), which exercises
    the identical kernel dataflow.
    """
    n, h, w, c = x.shape
    o = params.w.shape[-1]
    kk = kernel_size * kernel_size

    offsets = conv2d(x, params.w_off, params.b_off)                  # Eq. 1
    coords = offsets_to_coords(offsets.astype(jnp.float32),
                               kernel_size, variant, max_displacement)
    idx, coeff = coords_to_idx_coeff(coords, h, w)       # (N,H,W,KK,4)

    p = h * w
    p_pad = round_up(p, 128)
    idx_f = idx.reshape(n, p, kk, 4)
    coeff_f = coeff.reshape(n, p, kk, 4)
    if p_pad != p:
        idx_f = jnp.pad(idx_f, ((0, 0), (0, p_pad - p), (0, 0), (0, 0)))
        coeff_f = jnp.pad(coeff_f, ((0, 0), (0, p_pad - p), (0, 0), (0, 0)))

    x_flat = x.reshape(n, p, c)
    w2 = params.w.reshape(kk, c, o)

    fn = functools.partial(dcn_fused_tile, kernel_size=kernel_size,
                           interpret=interpret)
    out = jax.vmap(fn, in_axes=(0, 0, 0, None, None))(
        x_flat, idx_f, coeff_f, w2, params.b)            # (N,P_pad,O)
    return out[:, :p].reshape(n, h, w, o)
