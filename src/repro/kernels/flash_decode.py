"""Pallas TPU flash-decode kernel: split-KV attention for the decode path.

Single-query attention (one new token per sequence) against a long KV
cache is bandwidth-bound and, with the cache's kv_seq axis sharded, each
chip reduces over its KV slice. This kernel parallelizes the reduction
over KV *blocks* (FlashDecoding-style): grid (B*Hq, Skv/bk) with the
running (m, l, acc) in VMEM scratch, exactly the flash dataflow with
Sq == 1. Position masking (``lengths``) makes ragged batches safe — each
sequence attends only to its own prefix, matching
``repro.models.layers.attention_decode`` (the oracle wrapper in ref form).

GQA is handled in the BlockSpec index_map (q head -> kv head), as in
kernels/flash_attention.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, window: int | None,
                   softcap: float | None, bk: int):
    jk = pl.program_id(1)
    nkv = pl.num_programs(1)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale            # (1, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (1, bk)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    length = len_ref[0]                                  # valid prefix len
    ki = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    mask = ki < length
    if window is not None:
        mask &= ki > length - 1 - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(jk == nkv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "scale", "block_k",
                              "interpret"))
def flash_decode(
    q: jax.Array,        # (B, Hq, D) — the single new token's queries
    k_cache: jax.Array,  # (B, S, Hkv, D)
    v_cache: jax.Array,  # (B, S, Hkv, D)
    lengths: jax.Array,  # (B,) int32 — valid prefix length per sequence
    *,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """-> (B, Hq, D) attention output for the new token."""
    b, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    bk = min(block_k, s)
    s_pad = -(-s // bk) * bk

    qf = q.reshape(b * hq, 1, d)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    if s_pad != s:
        kf = jnp.pad(kf, ((0, 0), (0, s_pad - s), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, s_pad - s), (0, 0)))
    lens = jnp.repeat(lengths.astype(jnp.int32), hq).reshape(b * hq, 1)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, window=window,
                          softcap=softcap, bk=bk),
        grid=(b * hq, s_pad // bk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda h, j: (h, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j, g=g: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j, g=g: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda h, j: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qf, kf, vf)
    return out.reshape(b, hq, d)
