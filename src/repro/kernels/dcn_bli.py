"""Pallas TPU kernel: BLI as an interpolation-matrix matmul on the MXU.

Paper §IV-B maps each bilinear interpolation to a 4-wide dot product on a
cluster of 4 PEs (Fig. 5/6), with a parity-banked input buffer so the four
neighbours are fetched in one cycle (Fig. 7). The TPU re-derivation
(DESIGN.md §2): the MXU's idiomatic "gather" is a one-hot matmul, so we
generalize one-hot to **4-hot**: per output row, an interpolation matrix
row with the four BLI coefficients (eta, theta, mu, gamma — Eq. 5) at the
four neighbour columns. The whole tile then becomes

    out (P, C) = W_bli (P, S) @ x_tile (S, C)

one dense matmul that runs at MXU rate, replacing P*C serial gathers. The
4-hot matrix is *built inside the kernel* from iota comparisons (it never
exists in HBM), so HBM traffic is exactly: x_tile + idx + coeff + out.

VMEM blocking: grid (P/bp, C/bc); per step the kernel holds
  W_bli block (bp, S) fp32 + x block (S, bc) + out (bp, bc).
S (halo-tile pixels) is the contraction dim and stays resident; choose the
tile grid so S*max(bc)*dtype fits VMEM (the fusion planner does this).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bli_kernel(idx_ref, coeff_ref, x_ref, o_ref, *, s_pixels: int):
    """One (bp, bc) output block.

    idx_ref:   (bp, 4) int32 — flat neighbour indices into [0, S)
    coeff_ref: (bp, 4) f32   — eta, theta, mu, gamma
    x_ref:     (S, bc)       — halo tile (flattened pixels) x channel block
    o_ref:     (bp, bc)
    """
    idx = idx_ref[...]
    coeff = coeff_ref[...].astype(jnp.float32)
    cols = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], s_pixels), 1)

    # 4-hot interpolation matrix, built in VREGs from comparisons.
    w_bli = jnp.zeros((idx.shape[0], s_pixels), jnp.float32)
    for j in range(4):
        onehot = (cols == idx[:, j:j + 1]).astype(jnp.float32)
        w_bli = w_bli + onehot * coeff[:, j:j + 1]

    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.dot(
        w_bli, x, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_p", "block_c", "interpret"))
def bli_tile_matmul(
    x_tile: jax.Array,       # (S, C) flattened halo tile
    idx: jax.Array,          # (P, 4) int32 flat neighbour indices
    coeff: jax.Array,        # (P, 4) float BLI coefficients
    *,
    block_p: int = 128,
    block_c: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Deformed features (P, C) = 4-hot(idx, coeff) @ x_tile."""
    s, c = x_tile.shape
    p = idx.shape[0]
    bp = min(block_p, p)
    bc = min(block_c, c)
    if p % bp or c % bc:
        raise ValueError(
            f"P={p} and C={c} must tile by ({bp},{bc}); pad upstream")

    return pl.pallas_call(
        functools.partial(_bli_kernel, s_pixels=s),
        grid=(p // bp, c // bc),
        in_specs=[
            pl.BlockSpec((bp, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((s, bc), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bp, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, c), x_tile.dtype),
        interpret=interpret,
    )(idx, coeff, x_tile)


# ---------------------------------------------------------------------------
# Parity-plane gather variant (Fig. 7 adaptation): a VPU-style kernel that
# uses the 4-bank decomposition directly. Kept for comparison/benchmarks;
# the matmul variant above is the production path (see EXPERIMENTS.md).
# ---------------------------------------------------------------------------

def parity_planes(x: jax.Array
                  ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Split (H, W, C) into 4 parity planes (the paper's 4 buffer banks).

    Plane (pr, pc) holds x[pr::2, pc::2]. The four BLI neighbours of any
    coordinate land in four *different* planes iff floor(r), floor(c) have
    the right parity — generally they land in 4 distinct (plane, offset)
    slots, which is exactly the conflict-free property of Fig. 7.
    """
    return x[0::2, 0::2], x[0::2, 1::2], x[1::2, 0::2], x[1::2, 1::2]


def bli_gather_reference(x_tile: jax.Array, idx: jax.Array,
                         coeff: jax.Array) -> jax.Array:
    """XLA gather formulation over the same (S, C) tile — the baseline the
    matmul kernel is hillclimbed against in benchmarks/bench_kernels.py."""
    coeff = coeff.astype(jnp.float32)
    out = jnp.zeros((idx.shape[0], x_tile.shape[1]), jnp.float32)
    for j in range(4):
        out = out + x_tile[idx[:, j]].astype(jnp.float32) * coeff[:, j:j + 1]
    return out.astype(x_tile.dtype)
