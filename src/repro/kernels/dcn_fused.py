"""Pallas TPU kernel: fused BLI (+) main-conv for one output tile (§IV-D).

The deformed-feature tensor is K*K x the size of the input feature map —
the paper's fusion keeps it on-chip. Here the fused kernel materializes the
deformed patch matrix (bp, KK*C_in) **only in VMEM/VREGs** and immediately
contracts it with the main-conv weights:

    deformed (bp*KK, C)  = 4-hot(idx, coeff) (bp*KK, S) @ x_tile (S, C)
    out      (bp, O)     = reshape(deformed, (bp, KK*C)) @ w (KK*C, O) + b

Two chained MXU matmuls per block; HBM traffic is x_tile + indices +
weights + out — the deformed intermediate never leaves the core. This is
the TPU-native form of the paper's Fig. 18 fusion.

Two entry points: ``dcn_fused_tile`` computes ONE output tile per call
(the per-tile dispatch loop), ``dcn_fused_schedule`` runs a whole
Algorithm-1 tile schedule as a single ``pallas_call`` grid — the
scheduled-tile index is the leading grid dimension and a
scalar-prefetched dep table drives the input-tile DMA sequence, so the
scheduled tiles stream back-to-back through the core with no per-tile
host dispatch (the paper's §IV-C execution model).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec

from repro.compat import shard_map
from repro.obs import get_tracer


def _fused_kernel(idx_ref, coeff_ref, x_ref, w_ref, b_ref, o_ref,
                  *, s_pixels: int, kk: int):
    """One bp-pixel output block, full C_out.

    idx_ref:   (bp*KK, 4) int32
    coeff_ref: (bp*KK, 4) f32
    x_ref:     (S, C)
    w_ref:     (KK*C, O)
    b_ref:     (1, O)
    o_ref:     (bp, O)
    """
    idx = idx_ref[...]
    coeff = coeff_ref[...].astype(jnp.float32)
    rows = idx.shape[0]                      # bp * KK
    bp = rows // kk

    cols = jax.lax.broadcasted_iota(jnp.int32, (rows, s_pixels), 1)
    w_bli = jnp.zeros((rows, s_pixels), jnp.float32)
    for j in range(4):
        onehot = (cols == idx[:, j:j + 1]).astype(jnp.float32)
        w_bli = w_bli + onehot * coeff[:, j:j + 1]

    x = x_ref[...].astype(jnp.float32)       # (S, C)
    deformed = jnp.dot(w_bli, x, preferred_element_type=jnp.float32)
    patches = deformed.reshape(bp, kk * x.shape[1])
    w = w_ref[...].astype(jnp.float32)       # (KK*C, O)
    acc = jnp.dot(patches, w, preferred_element_type=jnp.float32)
    o_ref[...] = (acc + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("kernel_size", "block_p", "interpret"))
def _dcn_fused_tile_jit(
    x_tile: jax.Array,   # (S, C_in) flattened halo tile
    idx: jax.Array,      # (P, KK, 4) int32 flat neighbour indices
    coeff: jax.Array,    # (P, KK, 4) float BLI coefficients
    w: jax.Array,        # (KK, C_in, C_out) main conv weights
    b: jax.Array,        # (C_out,)
    *,
    kernel_size: int = 3,
    block_p: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused Eq.2+3 on one tile -> (P, C_out)."""
    s, c = x_tile.shape
    p, kk, _ = idx.shape
    o = w.shape[-1]
    assert kk == kernel_size * kernel_size, (kk, kernel_size)
    bp = min(block_p, p)
    if p % bp:
        raise ValueError(f"P={p} must tile by {bp}; pad upstream")

    idx2 = idx.reshape(p * kk, 4)
    coeff2 = coeff.reshape(p * kk, 4)
    w2 = w.reshape(kk * c, o)
    b2 = b.reshape(1, o)

    return pl.pallas_call(
        functools.partial(_fused_kernel, s_pixels=s, kk=kk),
        grid=(p // bp,),
        in_specs=[
            pl.BlockSpec((bp * kk, 4), lambda i: (i, 0)),
            pl.BlockSpec((bp * kk, 4), lambda i: (i, 0)),
            pl.BlockSpec((s, c), lambda i: (0, 0)),
            pl.BlockSpec((kk * c, o), lambda i: (0, 0)),
            pl.BlockSpec((1, o), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bp, o), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, o), x_tile.dtype),
        interpret=interpret,
    )(idx2, coeff2, x_tile, w2, b2)


# ---------------------------------------------------------------------------
# Batched schedule-grid dispatch: ONE pallas_call for a whole tile schedule.
# ---------------------------------------------------------------------------


def _sched_kernel(dep_ref, cnt_ref, idx_ref, coeff_ref, x_ref, w_ref, b_ref,
                  o_ref, acc_ref, *, tp: int, kk: int, k_pad: int):
    """One (scheduled tile, pixel block, dep slot) grid step.

    dep_ref:   (T, k_pad) int32 scalar-prefetch dep table — consumed by the
               x BlockSpec index map, not read here.
    cnt_ref:   (T,) int32 scalar-prefetch true dep count per tile; slots
               beyond it are padding and skip the matmul entirely (the x
               index map clamps to the last real dep, so consecutive
               padding slots keep the same block and the DMA is elided).
    idx_ref:   (1, bp*KK, 4) int32 packed-buffer addresses of the tile
    coeff_ref: (1, bp*KK, 4) f32
    x_ref:     (1, tp, C) — input tile ``dep[t, k]``, DMA'd by the grid
    w_ref:     (KK*C, O)
    b_ref:     (1, O)
    o_ref:     (1, bp, O) — written on the last dep slot
    acc_ref:   (bp*KK, C) f32 VMEM scratch — the deformed patch block

    The BLI contraction is decomposed over dep slots: slot k owns packed
    addresses [k*tp, (k+1)*tp), so its partial 4-hot matmul sees only the
    one input tile the grid just fetched. The deformed patch matrix never
    leaves VMEM (same §IV-D fusion as the per-tile kernel).
    """
    del dep_ref
    ti = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < cnt_ref[ti])
    def _accumulate():
        idx = idx_ref[0]
        coeff = coeff_ref[0].astype(jnp.float32)
        rows = idx.shape[0]                  # bp * KK
        local = idx - k * tp                 # in [0, tp) iff owned by slot k
        cols = jax.lax.broadcasted_iota(jnp.int32, (rows, tp), 1)
        w_bli = jnp.zeros((rows, tp), jnp.float32)
        for j in range(4):
            onehot = (cols == local[:, j:j + 1]).astype(jnp.float32)
            w_bli = w_bli + onehot * coeff[:, j:j + 1]
        x = x_ref[0].astype(jnp.float32)     # (tp, C)
        acc_ref[...] += jnp.dot(w_bli, x,
                                preferred_element_type=jnp.float32)

    @pl.when(k == k_pad - 1)
    def _flush():
        rows, c = acc_ref.shape
        bp = rows // kk
        patches = acc_ref[...].reshape(bp, kk * c)
        w = w_ref[...].astype(jnp.float32)
        acc = jnp.dot(patches, w, preferred_element_type=jnp.float32)
        o_ref[0] = (acc + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("kernel_size", "block_p", "interpret"))
def _dcn_fused_schedule_jit(
    x_tiles: jax.Array,   # (T_in, tp, C_in) every input tile of the plane
    dep_tbl: jax.Array,   # (T, k_pad) int32 dep table in schedule order
    dep_cnt: jax.Array,   # (T,) int32 true dep count per scheduled tile
    idx: jax.Array,       # (T, P, KK, 4) int32 packed-buffer addresses
    coeff: jax.Array,     # (T, P, KK, 4) float BLI coefficients
    w: jax.Array,         # (KK, C_in, C_out) main conv weights
    b: jax.Array,         # (C_out,)
    *,
    kernel_size: int = 3,
    block_p: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused Eq.2+3 over a whole tile schedule -> (T, P, C_out).

    The batched form of :func:`dcn_fused_tile`: instead of one host
    dispatch per scheduled output tile, the schedule IS the leading grid
    dimension of a single ``pallas_call``. The scalar-prefetched dep table
    drives the input-tile BlockSpec, so the grid's DMA sequence streams
    exactly the Algorithm-1 scheduled tile loads through the PE array —
    the paper's back-to-back tile execution, with zero per-tile Python
    overhead. Row ``t`` of the result is the output of scheduled tile
    ``t`` (the caller scatters rows by its schedule order).
    """
    t_in, tp, c = x_tiles.shape
    t, p, kk, _ = idx.shape
    k_pad = dep_tbl.shape[1]
    o = w.shape[-1]
    assert kk == kernel_size * kernel_size, (kk, kernel_size)
    bp = min(block_p, p)
    if p % bp:
        raise ValueError(f"P={p} must tile by {bp}; pad upstream")
    if t == 0:          # empty schedule: nothing to dispatch
        return jnp.zeros((0, p, o), x_tiles.dtype)

    idx2 = idx.reshape(t, p * kk, 4)
    coeff2 = coeff.reshape(t, p * kk, 4)
    w2 = w.reshape(kk * c, o)
    b2 = b.reshape(1, o)

    def x_index(ti, j, k, dep, cnt):
        # Clamp padding slots to the last real dep: the block index then
        # repeats across consecutive padding steps, so no DMA is issued
        # for them (the kernel's pl.when skips their compute).
        return (dep[ti, jnp.minimum(k, jnp.maximum(cnt[ti] - 1, 0))], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(t, p // bp, k_pad),
        in_specs=[
            pl.BlockSpec((1, bp * kk, 4),
                         lambda ti, j, k, dep, cnt: (ti, j, 0)),
            pl.BlockSpec((1, bp * kk, 4),
                         lambda ti, j, k, dep, cnt: (ti, j, 0)),
            pl.BlockSpec((1, tp, c), x_index),
            pl.BlockSpec((kk * c, o), lambda ti, j, k, dep, cnt: (0, 0)),
            pl.BlockSpec((1, o), lambda ti, j, k, dep, cnt: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bp, o),
                               lambda ti, j, k, dep, cnt: (ti, j, 0)),
        scratch_shapes=[pltpu.VMEM((bp * kk, c), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_sched_kernel, tp=tp, kk=kk, k_pad=k_pad),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, p, o), x_tiles.dtype),
        interpret=interpret,
    )(dep_tbl, dep_cnt, idx2, coeff2, x_tiles, w2, b2)


# ---------------------------------------------------------------------------
# Batch-fused dispatch: ONE pallas_call for the schedules of a whole batch.
# ---------------------------------------------------------------------------


def _batch_kernel(row_ref, dep_ref, cnt_ref, idx_ref, coeff_ref, x_ref,
                  w_ref, b_ref, o_ref, acc_ref,
                  *, tp: int, kk: int, k_pad: int, t_in: int):
    """One (batch-grid row, pixel block, dep slot) step.

    row_ref:   (G,) int32 scalar prefetch — per grid row, the flat
               ``img * T_out + out_tile`` row of the idx/coeff operands
               (clamped on padded rows; consumed by the BlockSpecs).
    dep_ref:   (G, k_pad) int32 scalar prefetch — GLOBAL dep tile ids
               ``img * T_in + dep``; rows beyond an image's schedule
               length are pre-filled with the image's last real dep so
               the clamped x index map repeats the block and the DMA is
               elided across image boundaries.
    cnt_ref:   (G,) int32 true dep count; 0 marks a ragged-padding row,
               whose compute is skipped entirely.
    idx_ref:   (1, bp*KK, 4) int32 plane-global packed addresses
               ``tile_id * tp + offset`` (schedule-independent: packed
               once per image in plane order).
    x_ref:     (1, tp, C) — input tile ``dep[g, k]`` of image ``img``.
    acc_ref:   (bp*KK, C) f32 VMEM scratch.

    Same §IV-D fusion as ``_sched_kernel``; the only difference is the
    addressing: idx is global to the image's tile array, so slot k's
    partial matmul localises it against the dep tile the grid fetched
    (``idx - dep * tp``) instead of assuming slot-contiguous packing.
    """
    g = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < cnt_ref[g])
    def _accumulate():
        idx = idx_ref[0]
        coeff = coeff_ref[0].astype(jnp.float32)
        rows = idx.shape[0]                  # bp * KK
        dep_local = dep_ref[g, k] % t_in     # image-local dep tile id
        local = idx - dep_local * tp         # in [0, tp) iff in this tile
        cols = jax.lax.broadcasted_iota(jnp.int32, (rows, tp), 1)
        w_bli = jnp.zeros((rows, tp), jnp.float32)
        for j in range(4):
            onehot = (cols == local[:, j:j + 1]).astype(jnp.float32)
            w_bli = w_bli + onehot * coeff[:, j:j + 1]
        x = x_ref[0].astype(jnp.float32)     # (tp, C)
        acc_ref[...] += jnp.dot(w_bli, x,
                                preferred_element_type=jnp.float32)

    @pl.when(k == k_pad - 1)
    def _flush():
        rows, c = acc_ref.shape
        bp = rows // kk
        patches = acc_ref[...].reshape(bp, kk * c)
        w = w_ref[...].astype(jnp.float32)
        acc = jnp.dot(patches, w, preferred_element_type=jnp.float32)
        o_ref[0] = (acc + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("t_in", "kernel_size", "block_p",
                                    "interpret"))
def _dcn_fused_batch_jit(
    x_tiles: jax.Array,   # (N*T_in, tp, C_in) every image's input tiles
    row_id: jax.Array,    # (G,) int32 img*T_out + out_tile (clamped)
    dep_glb: jax.Array,   # (G, k_pad) int32 img*T_in + dep, load order
    dep_cnt: jax.Array,   # (G,) int32 true dep count (0 = padded row)
    idx: jax.Array,       # (N*T_out, P, KK, 4) int32 plane-global addrs
    coeff: jax.Array,     # (N*T_out, P, KK, 4) float BLI coefficients
    w: jax.Array,         # (KK, C_in, C_out) shared main conv weights
    b: jax.Array,         # (C_out,)
    *,
    t_in: int,
    kernel_size: int = 3,
    block_p: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused Eq.2+3 over the concatenated schedules of a WHOLE BATCH ->
    (G, P, C_out), one row per batch-grid slot.

    The batch-fused form of :func:`dcn_fused_schedule`: all N images'
    Algorithm-1 schedules are concatenated (ragged-padded per image)
    into one leading grid dimension, so a layer segment costs ONE kernel
    dispatch per batch instead of one per image. Weights are shared
    across the grid; the per-image tile arrays are addressed through the
    scalar-prefetched global ids (``img * T_in + dep``), and ragged
    padding rows (``dep_cnt == 0``) skip compute with their DMAs elided
    by the clamped index map. The caller scatters valid rows back by
    ``row_id``.
    """
    nt_in, tp, c = x_tiles.shape
    g_rows, p, kk, _ = idx.shape
    k_pad = dep_glb.shape[1]
    o = w.shape[-1]
    assert kk == kernel_size * kernel_size, (kk, kernel_size)
    bp = min(block_p, p)
    if p % bp:
        raise ValueError(f"P={p} must tile by {bp}; pad upstream")
    if nt_in % t_in:
        raise ValueError(f"x_tiles rows {nt_in} not a multiple of "
                         f"t_in={t_in}")
    g = row_id.shape[0]
    if g == 0:          # empty batch grid: nothing to dispatch
        return jnp.zeros((0, p, o), x_tiles.dtype)

    idx2 = idx.reshape(g_rows, p * kk, 4)
    coeff2 = coeff.reshape(g_rows, p * kk, 4)
    w2 = w.reshape(kk * c, o)
    b2 = b.reshape(1, o)

    def x_index(gi, j, k, row, dep, cnt):
        # Clamp padding slots to the last real dep (pre-filled across
        # whole padded rows): consecutive padding steps repeat the block
        # index, so no DMA is issued for them.
        return (dep[gi, jnp.minimum(k, jnp.maximum(cnt[gi] - 1, 0))], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(g, p // bp, k_pad),
        in_specs=[
            pl.BlockSpec((1, bp * kk, 4),
                         lambda gi, j, k, row, dep, cnt: (row[gi], j, 0)),
            pl.BlockSpec((1, bp * kk, 4),
                         lambda gi, j, k, row, dep, cnt: (row[gi], j, 0)),
            pl.BlockSpec((1, tp, c), x_index),
            pl.BlockSpec((kk * c, o),
                         lambda gi, j, k, row, dep, cnt: (0, 0)),
            pl.BlockSpec((1, o), lambda gi, j, k, row, dep, cnt: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bp, o),
                               lambda gi, j, k, row, dep, cnt: (gi, j, 0)),
        scratch_shapes=[pltpu.VMEM((bp * kk, c), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_batch_kernel, tp=tp, kk=kk, k_pad=k_pad,
                          t_in=t_in),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, p, o), x_tiles.dtype),
        interpret=interpret,
    )(row_id, dep_glb, dep_cnt, idx2, coeff2, x_tiles, w2, b2)


# ---------------------------------------------------------------------------
# Sharded batch-fused dispatch: the batch grid above, SPMD over a device
# mesh's "data" axis. Each device runs the concatenated Algorithm-1
# schedules of its LOCAL images only — the paper's replicated-lane
# scaling unit — with zero collective contact inside the kernel (the
# executor all-gathers once, at the logits).
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("mesh", "axis", "t_in", "kernel_size",
                                    "block_p", "interpret"))
def _dcn_fused_batch_sharded_jit(
    x_tiles: jax.Array,   # (D, N_loc*T_in, tp, C_in) per-shard tiles
    row_id: jax.Array,    # (D, G_loc) int32 shard-LOCAL idx/coeff rows
    dep_glb: jax.Array,   # (D, G_loc, k_pad) int32 shard-LOCAL dep ids
    dep_cnt: jax.Array,   # (D, G_loc) int32 true dep count (0 = padded)
    idx: jax.Array,       # (D, N_loc*T_out, P, KK, 4) int32
    coeff: jax.Array,     # (D, N_loc*T_out, P, KK, 4) float
    w: jax.Array,         # (KK, C_in, C_out) replicated weights
    b: jax.Array,         # (C_out,) replicated bias
    *,
    mesh,
    axis: str,
    t_in: int,
    kernel_size: int,
    block_p: int,
    interpret: bool,
) -> jax.Array:
    """Per-device :func:`_dcn_fused_batch_jit` over mesh axis ``axis`` ->
    (D, G_loc, P, C_out), shard ``s`` computed entirely on device ``s``.

    Every operand except the weights carries a leading shard axis of
    size ``D == mesh.shape[axis]``; ``shard_map`` hands each device its
    own slab (leading dim 1), which runs the ordinary batch-fused grid
    over its local images. G_loc / k_pad are the max over shards, but
    the dep tables are packed PER SHARD: a shard with shorter schedules
    keeps its own ragged padding (``dep_cnt == 0`` rows skip compute and
    their DMAs are elided by the clamped index map), so one slow replica
    never inflates another's real work.
    """
    spec = PartitionSpec(axis)

    def body(xt, row, dep, cnt, ix, cf, wl, bl):
        y = _dcn_fused_batch_jit(xt[0], row[0], dep[0], cnt[0], ix[0],
                                 cf[0], wl, bl, t_in=t_in,
                                 kernel_size=kernel_size,
                                 block_p=block_p, interpret=interpret)
        return y[None]

    f = shard_map(body, mesh=mesh,
                  in_specs=(spec,) * 6 + (PartitionSpec(),
                                          PartitionSpec()),
                  out_specs=spec)
    return f(x_tiles, row_id, dep_glb, dep_cnt, idx, coeff, w, b)


# ---------------------------------------------------------------------------
# Public dispatch wrappers: the jitted kernels above, plus a telemetry
# span per host dispatch. Spans cannot live INSIDE the jitted functions
# (they would fire once at trace time, not per call), so each entry
# point is a thin host wrapper that opens ``dispatch.<mode>`` on the
# current ``repro.obs`` tracer. Disabled tracer = one extra attribute
# check per dispatch; calls from inside jit/vmap traces (``x`` is a JAX
# tracer) skip the span entirely.
# ---------------------------------------------------------------------------


def _span_dispatch(name: str, x, **attrs):
    tr = get_tracer()
    if not tr.enabled or isinstance(x, jax.core.Tracer):
        return None
    return tr.span(name, **attrs)


def dcn_fused_tile(x_tile, idx, coeff, w, b, *, kernel_size: int = 3,
                   block_p: int = 128, interpret: bool = False):
    """Fused Eq.2+3 on one tile -> (P, C_out) (see module docstring)."""
    sp = _span_dispatch("dispatch.per_tile", x_tile,
                        pixels=int(idx.shape[0]), c_out=int(w.shape[-1]))
    if sp is None:
        return _dcn_fused_tile_jit(x_tile, idx, coeff, w, b,
                                   kernel_size=kernel_size,
                                   block_p=block_p, interpret=interpret)
    with sp:
        return _dcn_fused_tile_jit(x_tile, idx, coeff, w, b,
                                   kernel_size=kernel_size,
                                   block_p=block_p, interpret=interpret)


def dcn_fused_schedule(x_tiles, dep_tbl, dep_cnt, idx, coeff, w, b, *,
                       kernel_size: int = 3, block_p: int = 128,
                       interpret: bool = False):
    """Fused Eq.2+3 over a whole tile schedule -> (T, P, C_out)."""
    sp = _span_dispatch("dispatch.batched", x_tiles,
                        tiles=int(idx.shape[0]),
                        c_out=int(w.shape[-1]))
    if sp is None:
        return _dcn_fused_schedule_jit(x_tiles, dep_tbl, dep_cnt, idx,
                                       coeff, w, b,
                                       kernel_size=kernel_size,
                                       block_p=block_p,
                                       interpret=interpret)
    with sp:
        return _dcn_fused_schedule_jit(x_tiles, dep_tbl, dep_cnt, idx,
                                       coeff, w, b,
                                       kernel_size=kernel_size,
                                       block_p=block_p,
                                       interpret=interpret)


def dcn_fused_batch(x_tiles, row_id, dep_glb, dep_cnt, idx, coeff, w, b,
                    *, t_in: int, kernel_size: int = 3,
                    block_p: int = 128, interpret: bool = False):
    """Fused Eq.2+3 over a whole batch's schedules -> (G, P, C_out)."""
    sp = _span_dispatch("dispatch.batch_fused", x_tiles,
                        grid_rows=int(row_id.shape[0]),
                        c_out=int(w.shape[-1]))
    if sp is None:
        return _dcn_fused_batch_jit(x_tiles, row_id, dep_glb, dep_cnt,
                                    idx, coeff, w, b, t_in=t_in,
                                    kernel_size=kernel_size,
                                    block_p=block_p, interpret=interpret)
    with sp:
        return _dcn_fused_batch_jit(x_tiles, row_id, dep_glb, dep_cnt,
                                    idx, coeff, w, b, t_in=t_in,
                                    kernel_size=kernel_size,
                                    block_p=block_p, interpret=interpret)


def dcn_fused_batch_sharded(x_tiles, row_id, dep_glb, dep_cnt, idx, coeff,
                            w, b, *, mesh, axis: str = "data", t_in: int,
                            kernel_size: int = 3, block_p: int = 128,
                            interpret: bool = False):
    """Fused Eq.2+3 over per-device shards of a batch's schedules ->
    (D, G_loc, P, C_out); shard ``s`` runs on mesh device ``s``."""
    d = mesh.shape[axis]
    for name, arr in (("x_tiles", x_tiles), ("row_id", row_id),
                      ("dep_glb", dep_glb), ("dep_cnt", dep_cnt),
                      ("idx", idx), ("coeff", coeff)):
        if arr.shape[0] != d:
            raise ValueError(
                f"{name} leading dim {arr.shape[0]} != mesh "
                f"{axis!r} axis size {d}")
    sp = _span_dispatch("dispatch.batch_fused_sharded", x_tiles,
                        shards=int(d),
                        grid_rows=int(row_id.shape[0] * row_id.shape[1]),
                        c_out=int(w.shape[-1]))
    if sp is None:
        return _dcn_fused_batch_sharded_jit(
            x_tiles, row_id, dep_glb, dep_cnt, idx, coeff, w, b,
            mesh=mesh, axis=axis, t_in=t_in, kernel_size=kernel_size,
            block_p=block_p, interpret=interpret)
    with sp:
        return _dcn_fused_batch_sharded_jit(
            x_tiles, row_id, dep_glb, dep_cnt, idx, coeff, w, b,
            mesh=mesh, axis=axis, t_in=t_in, kernel_size=kernel_size,
            block_p=block_p, interpret=interpret)
