"""Pallas TPU kernel: fused BLI (+) main-conv for one output tile (§IV-D).

The deformed-feature tensor is K*K x the size of the input feature map —
the paper's fusion keeps it on-chip. Here the fused kernel materializes the
deformed patch matrix (bp, KK*C_in) **only in VMEM/VREGs** and immediately
contracts it with the main-conv weights:

    deformed (bp*KK, C)  = 4-hot(idx, coeff) (bp*KK, S) @ x_tile (S, C)
    out      (bp, O)     = reshape(deformed, (bp, KK*C)) @ w (KK*C, O) + b

Two chained MXU matmuls per block; HBM traffic is x_tile + indices +
weights + out — the deformed intermediate never leaves the core. This is
the TPU-native form of the paper's Fig. 18 fusion.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(idx_ref, coeff_ref, x_ref, w_ref, b_ref, o_ref,
                  *, s_pixels: int, kk: int):
    """One bp-pixel output block, full C_out.

    idx_ref:   (bp*KK, 4) int32
    coeff_ref: (bp*KK, 4) f32
    x_ref:     (S, C)
    w_ref:     (KK*C, O)
    b_ref:     (1, O)
    o_ref:     (bp, O)
    """
    idx = idx_ref[...]
    coeff = coeff_ref[...].astype(jnp.float32)
    rows = idx.shape[0]                      # bp * KK
    bp = rows // kk

    cols = jax.lax.broadcasted_iota(jnp.int32, (rows, s_pixels), 1)
    w_bli = jnp.zeros((rows, s_pixels), jnp.float32)
    for j in range(4):
        onehot = (cols == idx[:, j:j + 1]).astype(jnp.float32)
        w_bli = w_bli + onehot * coeff[:, j:j + 1]

    x = x_ref[...].astype(jnp.float32)       # (S, C)
    deformed = jnp.dot(w_bli, x, preferred_element_type=jnp.float32)
    patches = deformed.reshape(bp, kk * x.shape[1])
    w = w_ref[...].astype(jnp.float32)       # (KK*C, O)
    acc = jnp.dot(patches, w, preferred_element_type=jnp.float32)
    o_ref[...] = (acc + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("kernel_size", "block_p", "interpret"))
def dcn_fused_tile(
    x_tile: jax.Array,   # (S, C_in) flattened halo tile
    idx: jax.Array,      # (P, KK, 4) int32 flat neighbour indices
    coeff: jax.Array,    # (P, KK, 4) float BLI coefficients
    w: jax.Array,        # (KK, C_in, C_out) main conv weights
    b: jax.Array,        # (C_out,)
    *,
    kernel_size: int = 3,
    block_p: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused Eq.2+3 on one tile -> (P, C_out)."""
    s, c = x_tile.shape
    p, kk, _ = idx.shape
    o = w.shape[-1]
    assert kk == kernel_size * kernel_size, (kk, kernel_size)
    bp = min(block_p, p)
    if p % bp:
        raise ValueError(f"P={p} must tile by {bp}; pad upstream")

    idx2 = idx.reshape(p * kk, 4)
    coeff2 = coeff.reshape(p * kk, 4)
    w2 = w.reshape(kk * c, o)
    b2 = b.reshape(1, o)

    return pl.pallas_call(
        functools.partial(_fused_kernel, s_pixels=s, kk=kk),
        grid=(p // bp,),
        in_specs=[
            pl.BlockSpec((bp * kk, 4), lambda i: (i, 0)),
            pl.BlockSpec((bp * kk, 4), lambda i: (i, 0)),
            pl.BlockSpec((s, c), lambda i: (0, 0)),
            pl.BlockSpec((kk * c, o), lambda i: (0, 0)),
            pl.BlockSpec((1, o), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bp, o), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, o), x_tile.dtype),
        interpret=interpret,
    )(idx2, coeff2, x_tile, w2, b2)
