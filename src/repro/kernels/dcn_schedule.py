"""Pallas TPU kernels: on-device tile scheduling (paper §IV-B/C).

On the paper's ASIC the tile scheduler is a dedicated hardware block next
to the NNA: it builds the Tile Dependency Table from the stage-1 offsets
(Fig. 9's boundary comparator + decoder) and runs Algorithm 1's greedy
max-overlap selection (AND + non-zero-bit adder tree + pipelined max
comparator) concurrently with the PE array. Until now our runtime
emulated that block on the host (``core.tiles.tdt_from_coords`` +
``core.scheduler.schedule_tiles``); this module moves both steps into
Pallas kernels so scheduling runs on-device like the paper's hardware:

  * :func:`tdt_from_coords_device` — the TDT scatter. One grid step per
    *output* tile: its pixel block's sampling coordinates are floored,
    clipped and decoded to input-tile ids (the boundary-comparator
    circuit as an integer divide), then reduced into one row of the TDT
    with a masked segment reduction (``max`` over a one-hot lane
    compare) instead of the host ``.at[].set`` scatter.
  * :func:`greedy_schedule_arrays` — Algorithm 1. The grid dimension IS
    the scheduling step; VMEM scratch carries the executed-tile bitmask
    and the FIFO residency state (per-input-tile last-load sequence
    numbers) across steps, SMEM carries the current tile id and the
    global load counter. Each step computes every candidate's overlap
    with the current tile as one vector AND + popcount (the paper's
    adder tree), argmaxes (the pipelined comparator, first-max ties like
    the host), classifies the next tile's inputs into Algorithm 1's
    three priority classes, and advances the FIFO state exactly as the
    host :class:`~repro.core.scheduler.FifoBuffer` would.

Both kernels are bit-exact against the host reference —
``core.scheduler.schedule_tiles(..., backend="device")`` consumes them
and must produce byte-identical ``TileSchedule``s
(tests/test_device_schedule.py pins this on every oracle config).

The FIFO state trick: with load-only insertion and FIFO eviction, a tile
is resident iff its last-load sequence number is among the ``m`` most
recent loads, i.e. ``seq[t] > loads_total - m``. Within one scheduling
step the loaded-class tiles are touched first and are all hits (they
were resident when the step began), and the seq/last-class tiles are all
loads (they were not), so the per-step update is a pure vector rank
assignment — no per-touch loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Sentinel "never loaded" sequence number: always evicted under
# ``seq > loads_total - m`` for any reachable loads_total/m.
_NEVER_LOADED = -(1 << 30)


# ---------------------------------------------------------------------------
# TDT scatter kernel: sampling coordinates -> tile dependency table rows.
# ---------------------------------------------------------------------------


def _tdt_kernel(rc_ref, o_ref, *, h: int, w: int, th: int, tw: int,
                cols: int, n_in: int):
    """One output tile's TDT row from its pixel block's coordinates.

    rc_ref: (1, 2, tpkk) f32 — row 0 the sample row coords, row 1 the
            column coords, flattened over (tile pixel, kernel tap).
    o_ref:  (1, n_in) int32 — the tile's dependency row (0/1).

    Fig. 9's circuit: each coordinate's 4 BLI neighbours are clipped to
    the plane, decoded to an input-tile id, and OR-reduced over the
    block into the row — a masked segment reduction replacing the host
    scatter.
    """
    rc = rc_ref[0]                                         # (2, tpkk)
    r = rc[0:1, :]
    c = rc[1:2, :]
    r0 = jnp.clip(jnp.floor(r).astype(jnp.int32), 0, h - 1)
    c0 = jnp.clip(jnp.floor(c).astype(jnp.int32), 0, w - 1)
    r1 = jnp.clip(r0 + 1, 0, h - 1)
    c1 = jnp.clip(c0 + 1, 0, w - 1)

    tpkk = rc.shape[1]
    lane = jax.lax.broadcasted_iota(jnp.int32, (tpkk, n_in), 1)
    row = jnp.zeros((1, n_in), jnp.int32)
    for rr, cc in ((r0, c0), (r0, c1), (r1, c0), (r1, c1)):
        tid = (rr // th) * cols + cc // tw                 # (1, tpkk)
        hit = (lane == tid.reshape(tpkk, 1)).astype(jnp.int32)
        row = jnp.maximum(row, jnp.max(hit, axis=0, keepdims=True))
    o_ref[...] = row


@functools.partial(jax.jit,
                   static_argnames=("in_grid", "out_grid", "interpret"))
def tdt_from_coords_device(coords: jax.Array, in_grid, out_grid,
                           interpret: bool = False) -> jax.Array:
    """Build the TDT on-device (bit-exact vs ``core.tiles.tdt_from_coords``).

    coords: (H, W, KK, 2) absolute float sampling coordinates (the
            stage-1 offset planes after ``offsets_to_coords``).
    returns B: (out_grid.num_tiles, in_grid.num_tiles) bool.

    Ragged edge tiles are handled by replicate-padding the coordinate
    gather: a padded slot repeats the plane's last row/column pixel,
    which lives in the same edge tile, so its neighbour marks are
    already present and the table is unchanged.
    """
    h, w, kk, _ = coords.shape
    th, tw = out_grid.th, out_grid.tw
    rows, cols = out_grid.rows, out_grid.cols
    t_out = out_grid.num_tiles
    tp = th * tw
    tpkk = tp * kk
    n_in = in_grid.num_tiles

    r_idx = jnp.minimum(jnp.arange(rows * th, dtype=jnp.int32), h - 1)
    c_idx = jnp.minimum(jnp.arange(cols * tw, dtype=jnp.int32), w - 1)
    ct = coords.astype(jnp.float32)[r_idx][:, c_idx]
    ct = (ct.reshape(rows, th, cols, tw, kk, 2)
          .transpose(0, 2, 1, 3, 4, 5)
          .reshape(t_out, tpkk, 2))
    rc = ct.transpose(0, 2, 1)                             # (T, 2, tpkk)

    out = pl.pallas_call(
        functools.partial(_tdt_kernel, h=in_grid.h, w=in_grid.w,
                          th=in_grid.th, tw=in_grid.tw, cols=in_grid.cols,
                          n_in=n_in),
        grid=(t_out,),
        in_specs=[pl.BlockSpec((1, 2, tpkk), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, n_in), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t_out, n_in), jnp.int32),
        interpret=interpret,
    )(rc)
    return out > 0


# ---------------------------------------------------------------------------
# Greedy max-overlap selection kernel: Algorithm 1 on-device.
# ---------------------------------------------------------------------------


def _greedy_kernel(b_ref, oid_ref, klass_ref, ovl_ref,
                   exec_ref, seq_ref, sm_ref, *, m: int):
    """One Algorithm-1 scheduling step (the grid dimension is the step).

    b_ref:     (n_out, n_in) int32 0/1 TDT — full block every step.
    oid_ref:   (1, 1)     int32 — tile scheduled this step (-1 = done).
    klass_ref: (1, n_in)  int32 — input priority class per input tile:
               0 = loadedVec, 1 = seqLoadVec, 2 = lastLoadVec, 3 = not a
               dependency. The host reconstructs the load order as
               ids(0) asc ++ ids(1) asc ++ ids(2) asc.
    ovl_ref:   (1, 1)     int32 — |B[curr] & B[next]| reuse overlap.
    exec_ref:  VMEM (n_out, 1) int32 scratch — executed-tile bitmask.
    seq_ref:   VMEM (1, n_in) int32 scratch — FIFO last-load seq numbers.
    sm_ref:    SMEM (2,) int32 scratch — [loads_total, curr tile id].
    """
    i = pl.program_id(0)
    n_out, n_in = b_ref.shape

    @pl.when(i == 0)
    def _init():
        exec_ref[...] = jnp.zeros_like(exec_ref)
        seq_ref[...] = jnp.full_like(seq_ref, _NEVER_LOADED)
        sm_ref[0] = 0
        sm_ref[1] = 0

    b = b_ref[...]
    executed = exec_ref[...]                               # (n_out, 1)
    seqs = seq_ref[...]                                    # (1, n_in)
    loads_total = sm_ref[0]
    curr = sm_ref[1]
    is_first = i == 0

    # Candidate scores: dependency count on the first step (Algorithm 1
    # line 2), overlap with the current tile (AND + adder tree) after.
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (n_out, n_in), 0)
    currdep = jnp.sum(
        jnp.where((row_iota == curr) & jnp.logical_not(is_first), b, 0),
        axis=0, keepdims=True)                             # (1, n_in)
    overlap = jnp.sum(b * currdep, axis=1, keepdims=True)  # (n_out, 1)
    dep_cnt = jnp.sum(b, axis=1, keepdims=True)
    score = jnp.where(is_first, dep_cnt, overlap)
    valid = (dep_cnt > 0) & (executed == 0)
    masked = jnp.where(valid, score, -1)
    # First maximum wins ties — the paper's pipelined comparator and the
    # host np.argmax agree on this.
    nxt = jnp.argmax(masked).astype(jnp.int32)
    # The host schedules its argmax pick unconditionally on the first
    # step (even a dependency-free tile 0 when the TDT is empty); later
    # steps only run while un-executed dependent tiles remain.
    take = is_first | jnp.any(valid)

    nxtdep = jnp.sum(jnp.where(row_iota == nxt, b, 0),
                     axis=0, keepdims=True) > 0            # (1, n_in)
    resident = seqs > (loads_total - m)
    loaded = resident & nxtdep
    lastv = (currdep > 0) & nxtdep & ~loaded
    seqv = nxtdep & ~loaded & ~lastv

    # FIFO advance: seq-class loads first (ascending id), then
    # last-class; rank within each class via an inclusive triangular
    # prefix sum (exact in f32 for any realistic tile count).
    tri = (jax.lax.broadcasted_iota(jnp.int32, (n_in, n_in), 0)
           <= jax.lax.broadcasted_iota(jnp.int32, (n_in, n_in), 1)
           ).astype(jnp.float32)
    seqf = seqv.astype(jnp.float32)
    lastf = lastv.astype(jnp.float32)
    rank_seq = jnp.dot(seqf, tri,
                       preferred_element_type=jnp.float32)
    rank_last = jnp.dot(lastf, tri,
                        preferred_element_type=jnp.float32)
    n_seq = jnp.sum(seqf).astype(jnp.int32)
    n_last = jnp.sum(lastf).astype(jnp.int32)
    new_seqs = jnp.where(
        seqv, loads_total + rank_seq.astype(jnp.int32),
        jnp.where(lastv, loads_total + n_seq + rank_last.astype(jnp.int32),
                  seqs))

    klass = jnp.where(loaded, 0,
                      jnp.where(seqv, 1, jnp.where(lastv, 2, 3)))
    oid_ref[...] = jnp.where(take, nxt, -1).reshape(1, 1)
    klass_ref[...] = jnp.where(take, klass, 3).astype(jnp.int32)
    ovl_ref[...] = jnp.where(
        take, jnp.sum(((currdep > 0) & nxtdep).astype(jnp.int32)),
        0).reshape(1, 1)

    @pl.when(take)
    def _advance():
        onehot = (jax.lax.broadcasted_iota(jnp.int32, (n_out, 1), 0)
                  == nxt).astype(jnp.int32)
        exec_ref[...] = executed + onehot
        seq_ref[...] = new_seqs
        sm_ref[0] = loads_total + n_seq + n_last
        sm_ref[1] = nxt


@functools.partial(jax.jit, static_argnames=("k_pad",))
def dispatch_arrays_from_klass(
    oid_seq: jax.Array,   # (n_out, 1) or (n_out,) int32, -1 padded suffix
    klass: jax.Array,     # (n_out, n_in) int32 priority classes (0/1/2/3)
    k_pad: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Device-side schedule -> dispatch handoff (zero host round-trip).

    Converts the greedy kernel's per-step class rows into the dense
    operands the batched dispatch consumes, entirely as jnp ops on
    device — the host never rebuilds a ``TileSchedule`` on this path:

      oid     (n_out,)       int32 — scheduled tile per step (-1 padding)
      dep_tbl (n_out, k_pad) int32 — dependent input tiles in LOAD order:
              class 0 (loaded) ids asc ++ class 1 (seq) asc ++ class 2
              (last) asc — exactly ``input_tile_scheduling``'s order,
              recovered with one stable argsort over the class row.
      dep_cnt (n_out,)       int32 — true dep count (0 on padded steps).

    ``k_pad`` must be >= n_in or any schedule's max dep count; the
    static choice ``pow2_pad(n_in)`` needs no host sync.
    """
    oid = oid_seq.reshape(-1).astype(jnp.int32)
    n_out, n_in = klass.shape
    # Stable sort on the class alone: ids ascend within each class.
    order = jnp.argsort(klass.astype(jnp.int32), axis=1)   # (n_out, n_in)
    cnt = jnp.sum(klass < 3, axis=1).astype(jnp.int32)
    if k_pad < n_in:
        order = order[:, :k_pad]  # only valid if max cnt <= k_pad
    elif k_pad > n_in:
        order = jnp.pad(order, ((0, 0), (0, k_pad - n_in)))
    # Zero out padding slots so rows match the host dense() convention.
    slot = jax.lax.broadcasted_iota(jnp.int32, (n_out, k_pad), 1)
    dep_tbl = jnp.where(slot < cnt[:, None], order, 0).astype(jnp.int32)
    return oid, dep_tbl, cnt


def tdt_dispatch_arrays(b: jax.Array, k_pad: int
                        ) -> tuple[jax.Array, jax.Array]:
    """Dense dispatch rows straight from a TDT (no scheduling): per output
    tile its dependent input tiles in ascending id order + counts. Used
    for interior fused-group layers, whose grid order is plane order.
    All jnp — stays on device for the batch-fused handoff."""
    bi = b.astype(jnp.int32)
    n_out, n_in = bi.shape
    order = jnp.argsort(1 - bi, axis=1)                    # deps first, asc
    cnt = jnp.sum(bi, axis=1).astype(jnp.int32)
    if k_pad < n_in:
        order = order[:, :k_pad]
    elif k_pad > n_in:
        order = jnp.pad(order, ((0, 0), (0, k_pad - n_in)))
    slot = jax.lax.broadcasted_iota(jnp.int32, (n_out, k_pad), 1)
    return jnp.where(slot < cnt[:, None], order, 0).astype(jnp.int32), cnt


@functools.partial(jax.jit, static_argnames=("m", "interpret"))
def greedy_schedule_arrays(
    b: jax.Array,        # (n_out, n_in) bool/int TDT
    m: int,              # FIFO input-buffer capacity in tiles
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Run Algorithm 1 on-device over a tile dependency table.

    Returns dense device arrays the host assembles into a
    ``TileSchedule`` (``core.scheduler.assemble_device_schedule``):

      oid_seq (n_out, 1)    int32 — scheduled tile per step, -1 padding
                                    once every dependent tile is done
                                    (padding is a contiguous suffix).
      klass   (n_out, n_in) int32 — per step, each input tile's priority
                                    class (0 loaded / 1 seq / 2 last /
                                    3 not a dependency).
      ovl     (n_out, 1)    int32 — per step, reuse overlap with the
                                    previously scheduled tile.
    """
    b = b.astype(jnp.int32)
    n_out, n_in = b.shape
    if m < 1:
        raise ValueError("buffer capacity must be >= 1 tile")
    return pl.pallas_call(
        functools.partial(_greedy_kernel, m=m),
        grid=(n_out,),
        in_specs=[pl.BlockSpec((n_out, n_in), lambda i: (0, 0))],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, n_in), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_out, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_out, n_in), jnp.int32),
            jax.ShapeDtypeStruct((n_out, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_out, 1), jnp.int32),
            pltpu.VMEM((1, n_in), jnp.int32),
            pltpu.SMEM((2,), jnp.int32),
        ],
        interpret=interpret,
    )(b)
