"""Pallas TPU flash attention (blockwise-softmax, VMEM-resident running
state) — the serving/prefill hot-spot of the LM architectures.

Features needed by the assigned archs: causal masking, GQA (q-head ->
kv-head mapping done in the BlockSpec index_map, so KV is never
materialized per q-head), sliding-window (Gemma-2 local layers), logit
soft-capping (Gemma-2). Oracle: ``repro.kernels.ref.attention_ref``.

Grid: (batch*q_heads, Sq/bq, Skv/bk) with the KV dimension innermost;
running max / denominator / accumulator live in VMEM scratch across the
KV steps (the canonical TPU flash dataflow — outputs are written once, on
the last KV step).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  softcap: float | None, sq: int, skv: int,
                  bq: int, bk: int):
    jk = pl.program_id(2)
    nkv = pl.num_programs(2)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)                    # (bk, d)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    qi = (pl.program_id(1) * bq
          + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
          + (skv - sq))                        # absolute key-time of q
    ki = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = ki < skv                                      # kv padding
    if causal:
        mask &= qi >= ki
    if window is not None:
        mask &= qi - ki < window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]                                  # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(jk == nkv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale",
                     "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,  # (B, Skv, Hkv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    bq = min(block_q, sq)
    bk = min(block_k, skv)
    sq_pad = -(-sq // bq) * bq
    skv_pad = -(-skv // bk) * bk

    # (B*H, S, D) layout; KV heads are NOT repeated — the index_map below
    # routes q-head bh to kv-head bh // g.
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    if sq_pad != sq:
        qf = jnp.pad(qf, ((0, 0), (0, sq_pad - sq), (0, 0)))
    if skv_pad != skv:
        kf = jnp.pad(kf, ((0, 0), (0, skv_pad - skv), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, skv_pad - skv), (0, 0)))

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            softcap=softcap, sq=sq, skv=skv, bq=bq, bk=bk),
        grid=(b * hq, sq_pad // bq, skv_pad // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=g: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=g: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)

    out = out[:, :sq].reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
    return out
