"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the numerical ground truth the kernels are tested against
(tests/test_kernels.py sweeps shapes/dtypes with assert_allclose).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.deform import bli_coefficients


# ---------------------------------------------------------------------------
# BLI (paper Eq. 2) on a flat tile: the oracle for kernels/dcn_bli.py
# ---------------------------------------------------------------------------

def bli_tile_ref(x_tile: jax.Array, coords: jax.Array) -> jax.Array:
    """Bilinear interpolation over a (S_h, S_w, C) tile.

    x_tile: (S_h, S_w, C) input features (the halo tile).
    coords: (P, 2) float coordinates local to the tile, in
            [0, S_h-1] x [0, S_w-1].
    -> (P, C) deformed features.
    """
    sh, sw, c = x_tile.shape
    floor_rc, coeffs = bli_coefficients(coords)
    r0 = jnp.clip(floor_rc[..., 0], 0, sh - 1)
    c0 = jnp.clip(floor_rc[..., 1], 0, sw - 1)
    r1 = jnp.clip(r0 + 1, 0, sh - 1)
    c1 = jnp.clip(c0 + 1, 0, sw - 1)
    flat = x_tile.reshape(sh * sw, c)
    coeffs = coeffs.astype(x_tile.dtype)
    return (flat[r0 * sw + c0] * coeffs[..., 0:1]
            + flat[r0 * sw + c1] * coeffs[..., 1:2]
            + flat[r1 * sw + c0] * coeffs[..., 2:3]
            + flat[r1 * sw + c1] * coeffs[..., 3:4])


def dcn_fused_tile_ref(x_tile: jax.Array, coords: jax.Array,
                       w: jax.Array, b: jax.Array) -> jax.Array:
    """Fused BLI + main conv (paper Eq. 2+3) on one tile — the oracle for
    kernels/dcn_fused.py.

    x_tile: (S_h, S_w, C_in)
    coords: (P, KK, 2) local coordinates per output pixel per tap
    w:      (KK, C_in, C_out) main conv weights
    b:      (C_out,)
    -> (P, C_out)
    """
    p, kk, _ = coords.shape
    deformed = bli_tile_ref(x_tile, coords.reshape(p * kk, 2))
    deformed = deformed.reshape(p, kk, x_tile.shape[-1])
    y = jnp.einsum("pkc,kco->po", deformed, w,
                   preferred_element_type=jnp.float32)
    return (y + b).astype(x_tile.dtype)


# ---------------------------------------------------------------------------
# Flash attention oracle (serving/prefill path of the LM archs)
# ---------------------------------------------------------------------------

def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True,
                  window: int | None = None,
                  softcap: float | None = None,
                  scale: float | None = None) -> jax.Array:
    """Reference softmax attention.

    q: (B, Sq, Hq, D), k/v: (B, Skv, Hkv, D) with Hq % Hkv == 0 (GQA).
    window: sliding-window size (Gemma-2 local layers).
    softcap: logit soft-capping (Gemma-2).
    -> (B, Sq, Hq, D)
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, sq, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    qi = jnp.arange(sq)[:, None] + (skv - sq)
    ki = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= qi >= ki
    if window is not None:
        mask &= qi - ki < window
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# MoE one-hot dispatch oracle (the C3 "gather as matmul" generalization)
# ---------------------------------------------------------------------------

def moe_dispatch_ref(x: jax.Array, dispatch: jax.Array) -> jax.Array:
    """dispatch: (T, E, Cap) one-hot; x: (T, D) -> (E, Cap, D)."""
    return jnp.einsum("tec,td->ecd", dispatch.astype(jnp.float32),
                      x.astype(jnp.float32)).astype(x.dtype)
