"""int8 error-feedback gradient compression for the DP all-reduce.

A distributed-optimization trick for the 1000+-node posture (DESIGN.md
§5): the data-parallel gradient all-reduce is the dominant inter-pod
collective for the dense archs; quantizing the payload to int8 with
per-tensor scales cuts the "pod"-axis (DCI) bytes 4x vs fp32 / 2x vs bf16.
Error feedback (residual carried between steps) keeps convergence —
1-bit-Adam-style. Implemented as an explicit ``shard_map`` over the DP
axes with psum on the decoded values; selectable via TrainConfig.

The same machinery doubles as the quantization path of the paper's 8-bit
PE evaluation (Table I): ``quantize``/``dequantize`` are the reference
int8 fixed-point ops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import axis_size


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """fp -> (int8 values, fp32 scale). Symmetric per-tensor."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum_tree(grads, err, axis_names):
    """Per-leaf: quantize(grad + err) -> psum(int32) -> dequantize; the
    quantization residual feeds back into ``err`` for the next step.

    Must run inside shard_map with ``axis_names`` manual axes.
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        # All shards must quantize against the SAME scale or the int sum is
        # biased: agree on pmax(local_scale) first (one scalar all-reduce).
        local_scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        scale = jax.lax.pmax(local_scale, axis_names)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        # int8 payload on the wire; accumulate in int32 to avoid overflow.
        summed = jax.lax.psum(q.astype(jnp.int32), axis_names)
        n = 1
        for a in (axis_names if isinstance(axis_names, tuple)
                  else (axis_names,)):
            n *= axis_size(a)
        decoded = summed.astype(jnp.float32) * scale / n
        new_err = gf - dequantize(q, scale)
        return decoded.astype(g.dtype), new_err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
