from repro.optim.adamw import (AdamWConfig, abstract_opt_state, adamw_update,
                               clip_by_global_norm, cosine_lr, global_norm,
                               init_opt_state)
from repro.optim.compression import (compressed_psum_tree, dequantize,
                                     init_error_state, quantize)
