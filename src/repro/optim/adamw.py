"""AdamW with pytree states, cosine schedule, global-norm clipping.

Self-contained (no optax dependency). Optimizer state dtype is selectable:
fp32 (default) or bf16 with stochastic-free simple cast (the deepseek-v3
HBM-fit option recorded in DESIGN.md §7). State sharding mirrors param
sharding (launch.sharding reuses the param PartitionSpecs for m/v).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    state_dtype: Any = jnp.float32


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(math.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def abstract_opt_state(params_abstract, cfg: AdamWConfig):
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, cfg.state_dtype)
    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": jax.tree.map(sds, params_abstract),
        "v": jax.tree.map(sds, params_abstract),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale)
                        .astype(g.dtype), grads), norm


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """-> (new_params, new_state, metrics)."""
    step = state["step"] + 1
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
        mhat = mf / bc1
        vhat = vf / bc2
        step_vec = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        if p.ndim >= 2:  # decoupled decay on matrices only
            pf = pf * (1.0 - lr * cfg.weight_decay)
        pf = pf - lr * step_vec
        return (pf.astype(p.dtype), mf.astype(cfg.state_dtype),
                vf.astype(cfg.state_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"step": step, "m": new_m, "v": new_v}, metrics
