from repro.checkpoint.store import (AsyncCheckpointer, completed_steps,
                                    latest_step, restore, save)
