"""Fault-tolerant checkpointing: atomic, keep-N, async, re-mesh resume.

Layout: <dir>/step_<n>/ holding one .npy per flattened pytree leaf plus a
manifest.json with the treedef keypaths and shapes. Writes go to
``step_<n>.tmp`` and are atomically renamed only after an fsync'd
manifest — a killed writer can never corrupt the latest checkpoint
(restore always picks the newest *complete* step).

Elastic re-mesh: arrays are written unsharded (gathered), so a restore may
target ANY mesh — ``restore`` device_puts each leaf with the sharding
computed for the new topology. This is the resume-on-fewer/more-nodes path
(tested 8 -> 4 fake devices in tests/test_distributed.py).

Async: ``AsyncCheckpointer`` snapshots to host (device_get) synchronously
— cheap — and does the disk I/O on a background thread so the train loop
only blocks for the copy, not the write (the usual multi-pod pattern).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def flatten_with_names(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_keystr(p), v) for p, v in flat]


def save(ckpt_dir: str, step: int, tree, keep: int | None = 3) -> str:
    """Atomic checkpoint write. Returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(flatten_with_names(tree)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"name": name, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    if keep is not None:
        for old in sorted(completed_steps(ckpt_dir))[:-keep]:
            shutil.rmtree(os.path.join(ckpt_dir, f"step_{old:08d}"),
                          ignore_errors=True)
    return final


def completed_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                out.append(int(d[len("step_"):]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = completed_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like_tree,
            shardings=None):
    """Restore into the structure of ``like_tree``.

    shardings: optional matching pytree of NamedSharding for the TARGET
    mesh (elastic re-mesh: may differ from the mesh that wrote it).
    """
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = jax.tree_util.tree_flatten(like_tree)
    assert len(flat_like) == len(manifest["leaves"]), \
        (len(flat_like), len(manifest["leaves"]))
    flat_sh = (treedef.flatten_up_to(shardings) if shardings is not None
               else [None] * len(flat_like))
    leaves = []
    for rec, like, sh in zip(manifest["leaves"], flat_like, flat_sh):
        arr = np.load(os.path.join(final, rec["file"]))
        assert list(arr.shape) == list(like.shape), (rec["name"], arr.shape,
                                                     like.shape)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return treedef.unflatten(leaves)


class AsyncCheckpointer:
    """Host-snapshot now, write later. One in-flight write at a time."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree):
        self.wait()
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)),
                                 tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
