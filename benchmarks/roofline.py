"""Deliverable (g): three-term roofline per (arch x shape) from the
compiled dry-run artifacts.

  compute    = HLO_FLOPs_per_chip    / peak_FLOP/s      (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_chip    / HBM_bw           (819 GB/s)
  collective = coll_bytes_per_chip   / link_bw          (50 GB/s ICI)

Sources: flops/traffic/collective bytes come from the loop-aware HLO
analysis (repro.launch.hlo_analysis) — ``compiled.cost_analysis`` counts
while-loop bodies once and would under-report scanned models ~60x; the
structural analysis multiplies by known_trip_count. All shapes in the
post-SPMD module are per-device, so the chips term cancels.

MODEL_FLOPS = 6*N*D (train), 2*N*D (prefill), 2*N_active*B (decode step),
with N_active excluding non-selected experts. The ratio
MODEL_FLOPS / (HLO_FLOPs * chips) exposes remat/capacity/dispatch waste.
"""

from __future__ import annotations

import json
import os

from repro import configs
from repro.models import lm
from repro.models.params import abstract_params, param_count

PEAK_FLOPS = 197e12     # TPU v5e bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
LINK_BW = 50e9          # bytes/s per ICI link

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def _n_params(cfg) -> tuple[int, int]:
    """(N_total, N_active) excluding unselected experts."""
    n = param_count(abstract_params(lambda mk: lm.init_lm(mk, cfg)))
    if cfg.moe is None:
        return n, n
    per_expert = 3 * cfg.moe.d_model * cfg.moe.d_ff
    n_moe_layers = (len([s for s in cfg.pattern if s.mlp == "moe"])
                    * cfg.n_repeats
                    + len([s for s in cfg.prefix if s.mlp == "moe"]))
    inactive = (cfg.moe.n_experts_padded - cfg.moe.top_k) * per_expert \
        * n_moe_layers
    return n, n - inactive


def model_flops(cfg, shape) -> float:
    n, n_active = _n_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq
        return 2.0 * n_active * tokens
    # decode: one token per sequence; attention reads add ~2*B*S*kv flops
    return 2.0 * n_active * shape.global_batch


def load_cell(arch: str, shape: str, mesh: str) -> dict | None:
    path = os.path.join(ART_DIR, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _flash_attn_bytes(cfg, shape, chips) -> float:
    """Analytic per-chip HBM bytes of attention under the Pallas flash
    kernel: Q,K,V read + O written, x3 for the backward (dQ,dK,dV + one
    recompute read), bf16. Replaces the XLA score-chain traffic."""
    n_attn = (sum(1 for s in cfg.prefix if s.kind in ("attn", "mla"))
              + cfg.n_repeats * sum(1 for s in cfg.pattern
                                    if s.kind in ("attn", "mla")))
    if shape.kind == "decode":
        tokens = shape.global_batch
    else:
        tokens = shape.global_batch * shape.seq
    if cfg.mla is not None:
        per_tok = cfg.mla.n_heads * (cfg.mla.qk_dim * 2 + cfg.mla.d_v * 2)
    else:
        per_tok = (cfg.n_heads + 2 * cfg.n_kv_heads + cfg.n_heads) \
            * cfg.head_dim
    factor = 3.0 if shape.kind == "train" else 1.0
    return factor * tokens * per_tok * 2 * n_attn / chips


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok" or "analysis" not in rec:
        return None
    a = rec["analysis"]
    cfg = configs.get_config(rec["arch"])
    shape = configs.SHAPES[rec["shape"]]
    chips = rec["chips"]
    t_c = a["flops"] / PEAK_FLOPS
    t_m = a["traffic_bytes"] / HBM_BW
    t_x = a["collective_bytes"] / LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops(cfg, shape)
    ratio = mf / max(a["flops"] * chips, 1)
    bound = max(t_c, t_m, t_x)
    # roofline fraction: useful-compute time over the bottleneck time
    frac = (mf / chips / PEAK_FLOPS) / max(bound, 1e-30)
    # TPU projection: attention score-chain traffic (stack-frame
    # attributed) is VMEM-resident under the flash kernel.
    attn = a.get("attn_traffic_bytes", 0.0)
    t_m_proj = (a["traffic_bytes"] - attn
                + _flash_attn_bytes(cfg, shape, chips)) / HBM_BW
    frac_proj = (mf / chips / PEAK_FLOPS) \
        / max(t_c, t_m_proj, t_x, 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "memory_proj_s": t_m_proj, "roofline_frac_proj": frac_proj,
        "dominant": dom, "model_flops": mf, "hlo_flops_chip": a["flops"],
        "useful_ratio": ratio, "roofline_frac": frac,
        "temp_gb": rec["memory"]["temp_bytes"] / 1e9,
        "compile_s": rec["compile_s"],
    }


def run(csv=print, mesh: str = "pod1"):
    rows = []
    for arch in configs.ARCHS:
        for shape in configs.SHAPES:
            rec = load_cell(arch, shape, mesh)
            if rec is None:
                continue
            if rec.get("status") == "skipped":
                csv(f"roofline,{arch},{shape},{mesh},SKIP,"
                    f"{rec['reason'][:50]}")
                continue
            row = roofline_row(rec)
            if row is None:
                csv(f"roofline,{arch},{shape},{mesh},ERROR")
                continue
            rows.append(row)
            csv(f"roofline,{arch},{shape},{mesh},"
                f"compute={row['compute_s']*1e3:.2f}ms,"
                f"memory={row['memory_s']*1e3:.2f}ms,"
                f"collective={row['collective_s']*1e3:.2f}ms,"
                f"dominant={row['dominant']},"
                f"useful_ratio={row['useful_ratio']:.2f},"
                f"roofline_frac={row['roofline_frac']:.3f},"
                f"tpu_proj_frac={row['roofline_frac_proj']:.3f}")
    if rows:
        worst = sorted(rows, key=lambda r: r["roofline_frac"])[:3]
        csv("roofline_summary,worst_cells="
            + ";".join(f"{r['arch']}/{r['shape']}({r['roofline_frac']:.3f})"
                       for r in worst))
    return rows


if __name__ == "__main__":
    run()
