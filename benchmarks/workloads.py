"""Shared workload accounting for the paper benchmarks.

Per-network FLOP/byte inventories for VGG19/SegNet x {-3,-8,-F} x
{DCN-I, DCN-II} (paper Table III), plus real tile-dependency tables built
by running the actual stage-1 offset conv of our DCN models on synthetic
images — the TDTs that drive the scheduling/tile-size/fusion benchmarks
are measured, not modeled.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deform import (conv2d, init_deformable_conv,
                               offsets_to_coords, randomize_offset_conv)
from repro.core.tiles import (make_square_grid, per_pixel_input_tiles,
                              tdt_from_coords)
from repro.data import DataConfig, image_batch
from repro.models.dcn_models import DcnNetConfig

NETWORKS = [("vgg19", 3), ("vgg19", 8), ("vgg19", -1),
            ("segnet", 3), ("segnet", 8), ("segnet", -1)]
VARIANTS = ["dcn1", "dcn2"]


def net_label(name: str, n_deform: int) -> str:
    return f"{name}-{'F' if n_deform < 0 else n_deform}"


@dataclasses.dataclass
class Workload:
    """FLOPs (int8 MAC*2) for one network forward pass, img 224."""
    conv_flops: float          # standard conv layers
    offset_flops: float        # stage-1 offset convs
    bli_flops: float           # stage-2 interpolation
    deform_conv_flops: float   # stage-3 convs over deformed features
    deform_bytes: float        # feature bytes touched by irregular sampling
    total_bytes: float

    @property
    def deform_flops(self):
        return self.offset_flops + self.bli_flops + self.deform_conv_flops

    @property
    def total_flops(self):
        return self.conv_flops + self.deform_flops


def build_workload(name: str, n_deform: int, variant: str,
                   img: int = 224) -> Workload:
    cfg = DcnNetConfig(name=name, n_deform=n_deform, variant=variant,
                       img_size=img)
    plan = cfg.stage_plan(decoder=(name == "segnet"))
    pools = set()
    from repro.models.dcn_models import _pool_positions, _VGG19_STAGES
    pools = _pool_positions(cfg)
    n_enc = sum(n for _, n in _VGG19_STAGES)

    hw = img
    conv_f = off_f = bli_f = dconv_f = 0.0
    dbytes = tbytes = 0.0
    kk = 9
    applied_pools = set()
    for i, (ci, co, deform) in enumerate(plan):
        layer_f = 2.0 * hw * hw * kk * ci * co
        tbytes += hw * hw * (ci + co)
        if deform:
            L = 2 if variant == "dcn1" else 2 * kk
            off_f += 2.0 * hw * hw * kk * ci * L
            taps = 1 if variant == "dcn1" else kk
            # DCN-I samples one deformed plane shared by taps; DCN-II
            # produces kk deformed features per position (paper §II-A).
            bli_f += 2.0 * hw * hw * taps * 4 * ci
            dconv_f += layer_f
            dbytes += hw * hw * taps * 4 * ci
        else:
            conv_f += layer_f
        if i < n_enc and i in pools and hw >= 2:
            hw = hw // 2
            applied_pools.add(i)
        elif (name == "segnet" and i >= n_enc
              and (2 * n_enc - 1 - i) in applied_pools):
            hw *= 2
    return Workload(conv_f, off_f, bli_f, dconv_f, dbytes, tbytes)


def executor_case(h: int, w: int, c: int, c_out: int, seed: int = 0,
                  offset_scale: float = 4.0):
    """Random deformable layer + input batch for the executor
    cross-checks (bench_scheduling / bench_fusion): non-zero offset conv
    so the sampling pattern is genuinely irregular."""
    key = jax.random.PRNGKey(seed)
    params = randomize_offset_conv(init_deformable_conv(key, c, c_out),
                                   jax.random.fold_in(key, 1),
                                   offset_scale / c)
    x = jax.random.normal(jax.random.fold_in(key, 2), (1, h, w, c))
    return params, x


@functools.lru_cache(maxsize=8)
def measured_coords(h: int = 56, w: int = 56, c: int = 256,
                    seed: int = 0, offset_scale: float = 6.0):
    """Sampling coordinates of a REAL stage-1 offset conv on a synthetic
    image (the paper's §III methodology, VGG16 conv3-scale layer).
    Coords are tiling-independent, so one run serves every grid the
    tile-shape sweeps try."""
    key = jax.random.PRNGKey(seed)
    params = randomize_offset_conv(init_deformable_conv(key, c, c),
                                   jax.random.fold_in(key, 1),
                                   offset_scale / c)
    img = image_batch(DataConfig(seed=seed, global_batch=1), 0, img=h,
                      channels=3)["images"]
    x = jnp.tile(jnp.asarray(img), (1, 1, 1, c // 3 + 1))[..., :c]
    offsets = conv2d(x, params.w_off, params.b_off)
    return offsets_to_coords(offsets.astype(jnp.float32), 3, "dcn2")[0]


@functools.lru_cache(maxsize=32)
def measured_tdt(h: int = 56, w: int = 56, c: int = 256,
                 tiles_per_side: int = 5, seed: int = 0,
                 offset_scale: float = 6.0):
    """TDT of :func:`measured_coords` under a square grid. Returns
    (B, per_pixel_tiles, grid)."""
    coords = measured_coords(h, w, c, seed, offset_scale)
    grid = make_square_grid(h, w, tiles_per_side)
    B = np.asarray(tdt_from_coords(coords, grid, grid))
    pp = np.asarray(per_pixel_input_tiles(coords, grid))
    return B, pp, grid
