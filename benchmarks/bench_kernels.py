"""Kernel-level microbenchmarks + correctness gates.

CPU wall-times validate STRUCTURE (the matmul formulation beats the
gather formulation even on CPU because XLA vectorizes the contraction);
TPU performance claims come from the roofline analysis, not these timings.
Every timing row is preceded by an allclose gate vs the jnp oracle.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core.deform import deformable_conv2d, init_deformable_conv
from repro.kernels import ref
from repro.kernels.dcn_bli import bli_gather_reference, bli_tile_matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import coords_to_idx_coeff, deformable_conv2d_pallas
from repro.obs import Stopwatch


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    with Stopwatch() as sw:
        for _ in range(iters):
            jax.block_until_ready(fn(*args))
    return sw.dur / iters * 1e6


def run(csv=print):
    key = jax.random.PRNGKey(0)
    # --- BLI formulations on one 32x32x256 tile
    sh = sw = 32
    c, p = 256, 1024
    x_tile = jax.random.normal(key, (sh * sw, c))
    coords = jax.random.uniform(jax.random.fold_in(key, 1), (p, 2),
                                maxval=30.99)
    idx, coeff = coords_to_idx_coeff(coords, sh, sw)
    want = ref.bli_tile_ref(x_tile.reshape(sh, sw, c), coords)

    gather = jax.jit(bli_gather_reference)
    np.testing.assert_allclose(gather(x_tile, idx, coeff), want,
                               rtol=1e-5, atol=1e-5)
    t_gather = _time(gather, x_tile, idx, coeff)
    csv(f"kernel,bli_gather_xla,{t_gather:.0f},us_per_tile_allclose_ok")

    t_matmul = _time(lambda x, i, cf: bli_tile_matmul(x, i, cf,
                                                      interpret=True),
                     x_tile, idx, coeff)
    out = bli_tile_matmul(x_tile, idx, coeff, interpret=True)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)
    csv(f"kernel,bli_matmul_pallas_interpret,{t_matmul:.0f},"
        "us_per_tile_allclose_ok(interpret-mode timing, structural only)")

    # --- full deformable conv: XLA vs fused-Pallas paths
    params = init_deformable_conv(jax.random.fold_in(key, 2), 64, 64)
    params = params._replace(w_off=jax.random.normal(
        jax.random.fold_in(key, 3), params.w_off.shape) * 0.2)
    x = jax.random.normal(jax.random.fold_in(key, 4), (1, 32, 32, 64))
    y_ref = deformable_conv2d(x, params)
    y_pal = deformable_conv2d_pallas(x, params)
    np.testing.assert_allclose(y_pal, y_ref, rtol=2e-4, atol=2e-4)
    t_xla = _time(jax.jit(lambda x: deformable_conv2d(x, params)), x)
    csv(f"kernel,deform_conv_xla,{t_xla:.0f},us_per_img_allclose_ok")

    # --- flash attention vs reference
    ks = jax.random.split(jax.random.fold_in(key, 5), 3)
    q = jax.random.normal(ks[0], (1, 256, 8, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    out = flash_attention(q, k, v, interpret=True)
    np.testing.assert_allclose(out, ref.attention_ref(q, k, v),
                               rtol=2e-4, atol=2e-4)
    t_ref = _time(jax.jit(lambda q, k, v: ref.attention_ref(q, k, v)),
                  q, k, v)
    csv(f"kernel,attention_xla_ref,{t_ref:.0f},us_allclose_ok")
    csv("kernel,flash_attention_pallas,validated,interpret=True vs oracle")


if __name__ == "__main__":
    run()
