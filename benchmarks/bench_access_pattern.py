"""Paper Fig. 3 (§III Observation): memory-access characterization of
deformable convolution.

(a) per-input-feature utilization: standard conv touches every feature
    ~K*K times uniformly; deformable conv's distribution is heavy-tailed
    (paper: ~15% of features used >12 times carrying ~25% of accesses,
    >22% used <6 times).
(b) per-input-tile utilization under a 5x5 tiling: notable variation
    (the headroom the TDT + scheduler exploit).

Computed from the measured offsets of a real stage-1 conv
(benchmarks.workloads.measured_tdt methodology).
"""

from __future__ import annotations

import numpy as np

from repro.core.deform import conv2d, init_deformable_conv, offsets_to_coords
from repro.core.tiles import make_square_grid, tile_access_histogram

import jax
import jax.numpy as jnp

from repro.data import DataConfig, image_batch


def run(csv=print):
    h = w = 56
    c = 64
    key = jax.random.PRNGKey(0)
    params = init_deformable_conv(key, c, c)
    params = params._replace(w_off=jax.random.normal(
        jax.random.fold_in(key, 1), params.w_off.shape) * (6.0 / c))
    img = image_batch(DataConfig(seed=0, global_batch=1), 0, img=h,
                      channels=3)["images"]
    x = jnp.tile(jnp.asarray(img), (1, 1, 1, c // 3 + 1))[..., :c]
    offsets = conv2d(x, params.w_off, params.b_off)
    coords = offsets_to_coords(offsets.astype(jnp.float32), 3, "dcn2")[0]

    # Paper semantics: a standard 3x3 conv "utilizes each input feature
    # around 9 times" -> count each deformed sample once, at its nearest
    # integer feature (the 4-neighbour BLI count is exactly 4x this).
    cr = np.clip(np.round(np.asarray(coords[..., 0])).astype(int), 0, h - 1)
    cc = np.clip(np.round(np.asarray(coords[..., 1])).astype(int), 0, w - 1)
    hist = np.bincount((cr * w + cc).reshape(-1), minlength=h * w)
    total = hist.sum()
    gt12 = hist > 12
    lt6 = hist < 6
    csv(f"fig3a_features,mean_accesses={hist.mean():.1f},paper_std_conv=9")
    csv(f"fig3a_features,frac_used_gt12={100*gt12.mean():.0f}%,"
        f"their_access_share={100*hist[gt12].sum()/total:.0f}%,"
        f"paper=15%/25%")
    csv(f"fig3a_features,frac_used_lt6={100*lt6.mean():.0f}%,paper=22%")

    grid = make_square_grid(h, w, 5)
    th = np.asarray(tile_access_histogram(coords, grid)).astype(float)
    # notable cv -> scheduling headroom
    csv(f"fig3b_tiles,min={th.min():.0f},max={th.max():.0f},"
        f"cv={th.std()/th.mean():.2f}")
    assert th.max() / max(th.min(), 1) > 1.2, \
        "tile utilization should vary (paper Fig. 3b)"
    return hist, th


if __name__ == "__main__":
    run()
