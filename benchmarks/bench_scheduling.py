"""Paper Figs. 14-16: tile scheduling ablation.

Three implementations over MEASURED tile-dependency tables (real stage-1
offset conv on synthetic images, benchmarks.workloads.measured_tdt):
  naive      = "W/O bit vector"                (per-feature demand loads)
  bitvec     = "W/ bit vector + W/O scheduling"
  scheduled  = "W/ bit vector + W/ scheduling" (Algorithm 1)

Reports per-network relative performance (Fig. 14), energy (Fig. 15) and
memory accesses (Fig. 16); the paper's headline — scheduling removes
~40.7% of memory accesses on */-F vs bit-vector-only — is printed against
ours.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.deform import conv2d, offsets_to_coords
from repro.core.simulator import dram_energy, simulate_strategies
from repro.core.tiles import TileGrid, per_pixel_input_tiles, tdt_from_coords
from repro.runtime import dcn_pipeline

from benchmarks.workloads import (NETWORKS, executor_case, measured_tdt,
                                  net_label)

BUF_BYTES = 128 * 1024  # paper Table I input buffer


def _deform_intensity(n_deform: int) -> float:
    """Fraction of layers that are deformable scales how much of the
    network the scheduling can touch (Fig. 14's -3/-8/-F trend)."""
    return {3: 0.12, 8: 0.45, -1: 1.0}[n_deform]


def run(csv=print, tdt_kwargs: dict | None = None, channels: int = 256,
        c_out: int = 256, buffer_bytes: int = BUF_BYTES):
    """``tdt_kwargs`` forwards to ``measured_tdt`` (smoke runs shrink it)."""
    B, pp, grid = measured_tdt(**(tdt_kwargs or {}))
    reports = simulate_strategies(B, pp, grid, channels=channels, c_out=c_out,
                                  kernel_size=3, buffer_bytes=buffer_bytes)
    base_loads = {k: r.tile_loads for k, r in reports.items()}
    csv(f"fig16_layer,naive_loads={base_loads['naive']},"
        f"bitvec_loads={base_loads['bitvec']},"
        f"scheduled_loads={base_loads['scheduled']}")

    sched_vs_bitvec = 1 - base_loads["scheduled"] / base_loads["bitvec"]
    csv(f"fig16_summary,sched_access_reduction_vs_bitvec="
        f"{100*sched_vs_bitvec:.1f}%,paper=40.7%")

    for name, nd in NETWORKS:
        w = _deform_intensity(nd)
        # deformable fraction of runtime benefits; the rest is unchanged
        def blended(strategy):
            rel = base_loads[strategy] / base_loads["naive"]
            return (1 - w) + w * rel
        perf = {k: 1.0 / blended(k) for k in base_loads}
        csv(f"fig14_perf,{net_label(name, nd)},"
            f"naive=1.00,bitvec={perf['bitvec']:.2f},"
            f"scheduled={perf['scheduled']:.2f}")
        e = {k: dram_energy(reports[k], exec_time_s=blended(k) * 1e-3)
             for k in reports}
        csv(f"fig15_energy,{net_label(name, nd)},"
            f"bitvec_rel={e['bitvec']/e['naive']:.2f},"
            f"scheduled_rel={e['scheduled']/e['naive']:.2f}")
    return reports


def run_executor(csv=print, h: int = 24, w: int = 24, c: int = 16,
                 c_out: int = 16, tile: int = 8, buffer_tiles: int = 4,
                 seed: int = 0):
    """Simulator-vs-executor cross-check on one real deformable layer.

    Runs the tile-pipeline executor (repro.runtime) on a real batch and
    compares its *actual* packed-tile traffic against the traffic
    simulator's predictions for the same coordinates/grid/buffer:

      * FIFO-replayed executed loads  == simulator "scheduled" tile loads
        (exact: same TDT, same Algorithm-1 schedule, same FIFO model);
      * no-reuse packed tile count    == the TDT's total dependency count,
        an upper bound the "bitvec" strategy improves on.
    """
    params, x = executor_case(h, w, c, c_out, seed)
    _, trace = dcn_pipeline(x, params, tile=tile, buffer_tiles=buffer_tiles,
                            return_trace=True)

    offsets = conv2d(x, params.w_off, params.b_off)
    coords = offsets_to_coords(offsets.astype(jnp.float32), 3, "dcn2")[0]
    grid = TileGrid(h, w, tile, tile)
    B = np.asarray(tdt_from_coords(coords, grid, grid))
    pp = np.asarray(per_pixel_input_tiles(coords, grid))
    dtype_bytes = x.dtype.itemsize
    tile_bytes = grid.tile_bytes(c, dtype_bytes)
    reports = simulate_strategies(B, pp, grid, channels=c, c_out=c_out,
                                  kernel_size=3,
                                  buffer_bytes=buffer_tiles * tile_bytes,
                                  dtype_bytes=dtype_bytes)

    sim = reports["scheduled"]
    exec_fifo = trace.fifo_loads()
    csv(f"executor_xcheck,sim_scheduled_loads={sim.tile_loads},"
        f"exec_fifo_loads={exec_fifo},"
        f"match={'yes' if sim.tile_loads == exec_fifo else 'NO'}")
    csv(f"executor_xcheck,sim_scheduled_bytes={sim.input_read_bytes},"
        f"exec_fifo_bytes={exec_fifo * tile_bytes},"
        f"exec_packed_bytes_no_reuse={trace.packed_bytes},"
        f"tdt_dep_count={int(B.sum())},"
        f"exec_packed_tiles={trace.packed_tile_loads}")
    return reports, trace


if __name__ == "__main__":
    run()
    run_executor()
