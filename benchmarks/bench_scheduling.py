"""Paper Figs. 14-16: tile scheduling ablation.

Three implementations over MEASURED tile-dependency tables (real stage-1
offset conv on synthetic images, benchmarks.workloads.measured_tdt):
  naive      = "W/O bit vector"                (per-feature demand loads)
  bitvec     = "W/ bit vector + W/O scheduling"
  scheduled  = "W/ bit vector + W/ scheduling" (Algorithm 1)

Reports per-network relative performance (Fig. 14), energy (Fig. 15) and
memory accesses (Fig. 16); the paper's headline — scheduling removes
~40.7% of memory accesses on */-F vs bit-vector-only — is printed against
ours.
"""

from __future__ import annotations

import numpy as np

from repro.core.simulator import dram_energy, simulate_strategies

from benchmarks.workloads import NETWORKS, measured_tdt, net_label

BUF_BYTES = 128 * 1024  # paper Table I input buffer


def _deform_intensity(n_deform: int) -> float:
    """Fraction of layers that are deformable scales how much of the
    network the scheduling can touch (Fig. 14's -3/-8/-F trend)."""
    return {3: 0.12, 8: 0.45, -1: 1.0}[n_deform]


def run(csv=print):
    B, pp, grid = measured_tdt()
    reports = simulate_strategies(B, pp, grid, channels=256, c_out=256,
                                  kernel_size=3, buffer_bytes=BUF_BYTES)
    base_loads = {k: r.tile_loads for k, r in reports.items()}
    csv(f"fig16_layer,naive_loads={base_loads['naive']},"
        f"bitvec_loads={base_loads['bitvec']},"
        f"scheduled_loads={base_loads['scheduled']}")

    sched_vs_bitvec = 1 - base_loads["scheduled"] / base_loads["bitvec"]
    csv(f"fig16_summary,sched_access_reduction_vs_bitvec="
        f"{100*sched_vs_bitvec:.1f}%,paper=40.7%")

    for name, nd in NETWORKS:
        w = _deform_intensity(nd)
        # deformable fraction of runtime benefits; the rest is unchanged
        def blended(strategy):
            rel = base_loads[strategy] / base_loads["naive"]
            return (1 - w) + w * rel
        perf = {k: 1.0 / blended(k) for k in base_loads}
        csv(f"fig14_perf,{net_label(name, nd)},"
            f"naive=1.00,bitvec={perf['bitvec']:.2f},"
            f"scheduled={perf['scheduled']:.2f}")
        e = {k: dram_energy(reports[k], exec_time_s=blended(k) * 1e-3)
             for k in reports}
        csv(f"fig15_energy,{net_label(name, nd)},"
            f"bitvec_rel={e['bitvec']/e['naive']:.2f},"
            f"scheduled_rel={e['scheduled']/e['naive']:.2f}")
    return reports


if __name__ == "__main__":
    run()
