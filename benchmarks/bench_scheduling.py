"""Paper Figs. 14-16: tile scheduling ablation.

Three implementations over MEASURED tile-dependency tables (real stage-1
offset conv on synthetic images, benchmarks.workloads.measured_tdt):
  naive      = "W/O bit vector"                (per-feature demand loads)
  bitvec     = "W/ bit vector + W/O scheduling"
  scheduled  = "W/ bit vector + W/ scheduling" (Algorithm 1)

Reports per-network relative performance (Fig. 14), energy (Fig. 15) and
memory accesses (Fig. 16); the paper's headline — scheduling removes
~40.7% of memory accesses on */-F vs bit-vector-only — is printed against
ours.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deform import conv2d, offsets_to_coords
from repro.obs import Stopwatch
from repro.core.scheduler import assemble_device_schedule, schedule_tiles
from repro.core.simulator import dram_energy, simulate_strategies
from repro.core.tiles import TileGrid, per_pixel_input_tiles, tdt_from_coords
from repro.kernels.dcn_schedule import (greedy_schedule_arrays,
                                        tdt_from_coords_device)
from repro.runtime import PipelineConfig, dcn_pipeline, resolve_interpret

from benchmarks.workloads import (NETWORKS, executor_case, measured_tdt,
                                  net_label)

BUF_BYTES = 128 * 1024  # paper Table I input buffer


def _deform_intensity(n_deform: int) -> float:
    """Fraction of layers that are deformable scales how much of the
    network the scheduling can touch (Fig. 14's -3/-8/-F trend)."""
    return {3: 0.12, 8: 0.45, -1: 1.0}[n_deform]


def run(csv=print, tdt_kwargs: dict | None = None, channels: int = 256,
        c_out: int = 256, buffer_bytes: int = BUF_BYTES):
    """``tdt_kwargs`` forwards to ``measured_tdt`` (smoke runs shrink it)."""
    B, pp, grid = measured_tdt(**(tdt_kwargs or {}))
    reports = simulate_strategies(B, pp, grid, channels=channels, c_out=c_out,
                                  kernel_size=3, buffer_bytes=buffer_bytes)
    base_loads = {k: r.tile_loads for k, r in reports.items()}
    csv(f"fig16_layer,naive_loads={base_loads['naive']},"
        f"bitvec_loads={base_loads['bitvec']},"
        f"scheduled_loads={base_loads['scheduled']}")

    sched_vs_bitvec = 1 - base_loads["scheduled"] / base_loads["bitvec"]
    csv(f"fig16_summary,sched_access_reduction_vs_bitvec="
        f"{100*sched_vs_bitvec:.1f}%,paper=40.7%")

    for name, nd in NETWORKS:
        w = _deform_intensity(nd)
        # deformable fraction of runtime benefits; the rest is unchanged
        def blended(strategy):
            rel = base_loads[strategy] / base_loads["naive"]
            return (1 - w) + w * rel
        perf = {k: 1.0 / blended(k) for k in base_loads}
        csv(f"fig14_perf,{net_label(name, nd)},"
            f"naive=1.00,bitvec={perf['bitvec']:.2f},"
            f"scheduled={perf['scheduled']:.2f}")
        e = {k: dram_energy(reports[k], exec_time_s=blended(k) * 1e-3)
             for k in reports}
        csv(f"fig15_energy,{net_label(name, nd)},"
            f"bitvec_rel={e['bitvec']/e['naive']:.2f},"
            f"scheduled_rel={e['scheduled']/e['naive']:.2f}")
    return reports


def run_executor(csv=print, h: int = 24, w: int = 24, c: int = 16,
                 c_out: int = 16, tile: int = 8, buffer_tiles: int = 4,
                 seed: int = 0):
    """Simulator-vs-executor cross-check on one real deformable layer.

    Runs the tile-pipeline executor (repro.runtime) on a real batch and
    compares its *actual* packed-tile traffic against the traffic
    simulator's predictions for the same coordinates/grid/buffer:

      * FIFO-replayed executed loads  == simulator "scheduled" tile loads
        (exact: same TDT, same Algorithm-1 schedule, same FIFO model);
      * no-reuse packed tile count    == the TDT's total dependency count,
        an upper bound the "bitvec" strategy improves on.
    """
    params, x = executor_case(h, w, c, c_out, seed)
    _, trace = dcn_pipeline(x, params, tile=tile, buffer_tiles=buffer_tiles,
                            return_trace=True)

    offsets = conv2d(x, params.w_off, params.b_off)
    coords = offsets_to_coords(offsets.astype(jnp.float32), 3, "dcn2")[0]
    grid = TileGrid(h, w, tile, tile)
    B = np.asarray(tdt_from_coords(coords, grid, grid))
    pp = np.asarray(per_pixel_input_tiles(coords, grid))
    dtype_bytes = x.dtype.itemsize
    tile_bytes = grid.tile_bytes(c, dtype_bytes)
    reports = simulate_strategies(B, pp, grid, channels=c, c_out=c_out,
                                  kernel_size=3,
                                  buffer_bytes=buffer_tiles * tile_bytes,
                                  dtype_bytes=dtype_bytes)

    sim = reports["scheduled"]
    exec_fifo = trace.fifo_loads()
    csv(f"executor_xcheck,sim_scheduled_loads={sim.tile_loads},"
        f"exec_fifo_loads={exec_fifo},"
        f"match={'yes' if sim.tile_loads == exec_fifo else 'NO'}")
    csv(f"executor_xcheck,sim_scheduled_bytes={sim.input_read_bytes},"
        f"exec_fifo_bytes={exec_fifo * tile_bytes},"
        f"exec_packed_bytes_no_reuse={trace.packed_bytes},"
        f"tdt_dep_count={int(B.sum())},"
        f"exec_packed_tiles={trace.packed_tile_loads}")
    return reports, trace


def run_backends(csv=print, h: int = 24, w: int = 24, c: int = 8,
                 c_out: int = 8, tile: int = 8, buffer_tiles: int = 4,
                 repeats: int = 3, seed: int = 0):
    """Host-vs-device scheduling backends on one real deformable layer.

    Times the per-image schedule build both ways and checks the device
    path emits bit-identical ``TileSchedule``s:

      * host backend — the full TDT scatter + Algorithm-1 greedy loop in
        host numpy/Python (the staging thread's scheduling cost today);
      * device backend — the Pallas kernels do the scatter + selection;
        the host residue is reassembling the emitted order
        (``device_host_s``), the kernel wall time is reported separately
        (``device_kernel_s``; on a CPU CI worker that is interpret-mode
        emulation, a gross upper bound on real-accelerator time).

    The ISSUE-4 acceptance gate is ``host_prepass_reduced``: the
    host-side scheduling work per image must be strictly smaller with
    ``schedule_backend="device"``. Also reports the end-to-end executor
    prepass + ``host_overlap_frac`` shift for both backends.
    """
    params, x = executor_case(h, w, c, c_out, seed)
    n = int(x.shape[0])
    offsets = conv2d(x, params.w_off, params.b_off)
    coords = offsets_to_coords(offsets.astype(jnp.float32), 3, "dcn2")
    grid = TileGrid(h, w, tile, tile)
    m = buffer_tiles
    interp = resolve_interpret(None)

    def host_build(i):
        B = np.asarray(tdt_from_coords(coords[i], grid, grid))
        return schedule_tiles(B, m)

    def device_kernels(i):
        B = tdt_from_coords_device(coords[i], grid, grid, interpret=interp)
        o, k, v = greedy_schedule_arrays(B, m, interpret=interp)
        return np.asarray(o), np.asarray(k), np.asarray(v)

    def best(fn):
        times = []
        for _ in range(repeats):
            with Stopwatch() as sw:
                fn()
            times.append(sw.dur)
        return min(times) / n

    host_scheds = [host_build(i) for i in range(n)]     # also warms jit
    arrays = [device_kernels(i) for i in range(n)]
    dev_scheds = [assemble_device_schedule(*a) for a in arrays]
    match = all(hs == ds for hs, ds in zip(host_scheds, dev_scheds))

    host_s = best(lambda: [host_build(i) for i in range(n)])
    dev_kernel_s = best(lambda: [device_kernels(i) for i in range(n)])
    dev_host_s = best(
        lambda: [assemble_device_schedule(*a) for a in arrays])
    reduced = dev_host_s < host_s
    csv(f"sched_backend,host_sched_s_per_img={host_s:.6f},"
        f"device_host_s_per_img={dev_host_s:.6f},"
        f"device_kernel_s_per_img={dev_kernel_s:.6f},"
        f"interpret={'yes' if interp else 'no'},"
        f"match={'yes' if match else 'NO'},"
        f"host_prepass_reduced={'yes' if reduced else 'NO'}")

    for backend in ("host", "device"):
        cfg = PipelineConfig(tile=tile, buffer_tiles=m,
                             use_schedule_cache=False,
                             schedule_backend=backend)
        dcn_pipeline(x, params, config=cfg)              # warm
        with Stopwatch() as sw:
            y, tr = dcn_pipeline(x, params, config=cfg, return_trace=True)
            jax.block_until_ready(y)
        csv(f"sched_backend_e2e,backend={backend},"
            f"prepass_s_per_img={tr.overlap.prepass_s / n:.6f},"
            f"sched_s_per_img={tr.overlap.schedule_s / n:.6f},"
            f"host_overlap_frac={tr.host_overlap_frac:.3f},"
            f"schedule_device_frac={tr.schedule_device_frac:.3f},"
            f"wall_s={sw.dur:.4f}")
    return dict(host_sched_s_per_img=host_s,
                device_host_s_per_img=dev_host_s,
                device_kernel_s_per_img=dev_kernel_s,
                match=match, host_prepass_reduced=reduced)


def run_batch_fused(csv=print, h: int = 16, w: int = 16, c: int = 8,
                    c_out: int = 8, tile: int = 8, buffer_tiles: int = 4,
                    batch: int = 4, repeats: int = 3, seed: int = 0):
    """ISSUE 5 acceptance: whole-batch fused dispatch vs per-image
    batched dispatch on one real deformable layer.

    Measures, for both scheduling backends:

      * ``dispatches_per_batch`` — host-issued kernel dispatches for the
        whole batch (batch-fused must be 1 for a single layer, vs
        ``batch`` for per-image batched dispatch);
      * ``host_prepass_residue_s`` — host wall time of the batch prepass.
        With ``schedule_backend="device"`` this is the zero-round-trip
        residue (digesting + async kernel launches: no host TDT, no
        Algorithm-1 loop, no ``TileSchedule`` reassembly);
      * batch-fused vs per-image batched wall-clock.

    Also checks the two dispatch modes agree numerically (match gate).
    """
    params, _ = executor_case(h, w, c, c_out, seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 7), (batch, h, w, c))

    def best(cfg):
        dcn_pipeline(x, params, config=cfg)                  # warm compile
        wall = float("inf")
        for _ in range(repeats):
            with Stopwatch() as sw:
                y, tr = dcn_pipeline(x, params, config=cfg,
                                     return_trace=True)
                jax.block_until_ready(y)
            wall = min(wall, sw.dur)
        return y, tr, wall

    out = {}
    for backend in ("host", "device"):
        y_b, tr_b, wall_b = best(PipelineConfig(
            tile=tile, buffer_tiles=buffer_tiles, dispatch="batched",
            schedule_backend=backend, use_schedule_cache=False))
        y_f, tr_f, wall_f = best(PipelineConfig(
            tile=tile, buffer_tiles=buffer_tiles, dispatch="batch_fused",
            schedule_backend=backend, use_schedule_cache=False))
        err = float(jnp.max(jnp.abs(y_f.astype(jnp.float32)
                                    - y_b.astype(jnp.float32))))
        match = err < 1e-5
        residue = tr_f.overlap.prepass_s
        csv(f"batch_fused,backend={backend},batch={batch},"
            f"dispatches_per_batch={tr_f.dispatches_per_batch},"
            f"batched_dispatches={tr_b.kernel_dispatches},"
            f"host_prepass_residue_s={residue:.6f},"
            f"batch_fused_wall_s={wall_f:.4f},"
            f"batched_wall_s={wall_b:.4f},"
            f"match={'yes' if match else 'NO'}")
        out[backend] = dict(dispatches_per_batch=tr_f.dispatches_per_batch,
                            batched_dispatches=tr_b.kernel_dispatches,
                            host_prepass_residue_s=residue,
                            batch_fused_wall_s=wall_f,
                            batched_wall_s=wall_b, match=match)
    return out


if __name__ == "__main__":
    run()
    run_executor()
    run_backends()
    run_batch_fused()
