"""Open-loop serving benchmark: continuous batching vs one-at-a-time.

Drives ``DcnServingEngine`` with a synthetic open-loop arrival process
(requests arrive on their own schedule, independent of completions — the
serving regime where queueing actually happens) and compares:

  * **sequential** — the serve-one-at-a-time baseline: each request is
    one blocking ``infer`` call in arrival order;
  * **batched** — continuous batching: requests land in the submit
    queue, each ``step()`` coalesces up to ``slots`` queued images into
    ONE ``batch_fused`` ragged grid per layer segment.

Time is a virtual clock that advances at real rate while the engine
computes and fast-forwards across idle gaps, so the reported
requests/sec and submit->result latency percentiles are honest for the
arrival process while the whole run stays CI-sized. The arrival rate is
calibrated to ~1.5x the sequential service rate: the baseline saturates
and queues, which is exactly the load continuous batching exists for.
"""

from __future__ import annotations

import os
import sys
import time

import jax.numpy as jnp
import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:   # allow `python benchmarks/bench_serving.py`
    sys.path.insert(0, _ROOT)

from benchmarks.bench_graph import _case
from repro.obs import (Stopwatch, Tracer, chrome_trace,
                       validate_chrome_trace, write_json)
from repro.runtime import GraphConfig
from repro.serving import DcnServingEngine


class _VirtualClock:
    """Virtual wall clock: flows at real rate (so compute is measured),
    plus explicit jumps across idle waits for the next arrival."""

    def __init__(self):
        self.offset = 0.0
        self.anchor = time.perf_counter()

    def __call__(self) -> float:
        return self.offset + (time.perf_counter() - self.anchor)

    def jump_to(self, t: float) -> None:
        now = self()
        if t > now:
            self.offset += t - now


def _request_stream(n: int, img: int, seed: int, dup_frac: float = 0.4):
    """Single-image requests; a ``dup_frac`` share are replayed frames
    (the schedule cache's serving hit population)."""
    rng = np.random.default_rng(seed)
    xs: list[np.ndarray] = []
    for _ in range(n):
        if xs and rng.random() < dup_frac:
            xs.append(xs[int(rng.integers(len(xs)))])
        else:
            xs.append(rng.normal(size=(img, img, 3)).astype(np.float32))
    return xs


def _simulate_sequential(params, cfg, tile, xs, arrivals):
    eng = DcnServingEngine(params, cfg, graph=GraphConfig(tile=tile))
    vc = _VirtualClock()
    lat = []
    for x, a in zip(xs, arrivals):
        vc.jump_to(a)                 # can't start before the arrival
        eng.infer(jnp.asarray(x[None]))
        lat.append(vc() - a)
    return np.asarray(lat), len(xs) / (vc() - arrivals[0])


def _simulate_batched(params, cfg, tile, slots, xs, arrivals,
                      tracer=None):
    vc = _VirtualClock()
    eng = DcnServingEngine(params, cfg, graph=GraphConfig(tile=tile),
                           slots=slots, clock=vc, tracer=tracer)
    n, i, finished = len(xs), 0, []
    step_wall = 0.0                   # real compute wall inside step()
    while len(finished) < n:
        now = vc()
        while i < n and arrivals[i] <= now:
            req = eng.submit(xs[i])
            # An arrival during the previous step is submitted after it;
            # backdate submit_s so its latency includes that wait.
            req.submit_s = arrivals[i]
            i += 1
        if eng.queue_depth == 0:
            vc.jump_to(arrivals[i])   # idle: fast-forward to next arrival
            continue
        with Stopwatch() as sw:
            finished.extend(eng.step())
        step_wall += sw.dur
    lat = np.asarray([r.latency_s for r in finished])
    return lat, n / (vc() - arrivals[0]), eng, step_wall


def run(csv=print, img: int = 13, n_deform: int = 2,
        width_mult: float = 0.125, tile: int = 4, slots: int = 8,
        n_requests: int = 16, load_factor: float = 3.0, seed: int = 0,
        trace_out: str | None = None, timeline_out: str | None = None,
        metrics_out: str | None = None):
    """Open-loop arrivals through both serving modes; csv one line of
    throughput + latency percentiles per mode plus the speedup verdict.

    The batched run executes under an enabled :class:`repro.obs.Tracer`:
    ``serving_trace`` reports the exported Chrome-trace event count, the
    schema verdict and the ratio of ``serve.step`` span wall to the
    measured step wall; ``serving_metrics`` cross-checks the engine's
    ``metrics_snapshot()`` against ``stats``. ``trace_out`` /
    ``timeline_out`` / ``metrics_out`` dump the Perfetto-loadable trace
    JSON, the per-step serving timeline and the metrics snapshot.
    """
    cfg, params, _ = _case(img, n_deform, width_mult, seed)
    xs = _request_stream(n_requests, img, seed + 1)

    # Warm up compile caches for EVERY coalesced batch width 1..slots
    # (each width is a distinct fused-grid shape and would otherwise be
    # billed a jit compile mid-measurement) plus the single-image
    # baseline shape.
    warm = DcnServingEngine(params, cfg, graph=GraphConfig(tile=tile),
                            slots=slots)
    for k in range(1, slots + 1):
        for x in xs[:k]:
            warm.submit(x)
        warm.step()
    warm.drain()
    warm.infer(jnp.asarray(xs[0][None]))

    # Calibrate the arrival rate to ``load_factor`` x the sequential
    # service rate — past saturation, so the baseline queues.
    with Stopwatch() as sw:
        warm.infer(jnp.asarray(xs[0][None]))
    rate = load_factor / max(sw.dur, 1e-9)
    rng = np.random.default_rng(seed + 2)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))

    seq_lat, seq_rps = _simulate_sequential(params, cfg, tile, xs, arrivals)
    tracer = Tracer(enabled=True)
    bat_lat, bat_rps, eng, step_wall = _simulate_batched(
        params, cfg, tile, slots, xs, arrivals, tracer=tracer)
    assert eng.stats["latency"]["count"] == n_requests

    def pct(a, q):
        return float(np.percentile(a, q))

    speedup = bat_rps / seq_rps
    beats = bat_rps > seq_rps
    csv(f"serving_bench,slots={slots},n_requests={n_requests},"
        f"rate_rps={rate:.3f},seq_rps={seq_rps:.3f},"
        f"batched_rps={bat_rps:.3f},speedup={speedup:.2f},"
        f"batched_beats_sequential={'yes' if beats else 'NO'}")
    csv(f"serving_latency,mode=sequential,p50_s={pct(seq_lat, 50):.4f},"
        f"p95_s={pct(seq_lat, 95):.4f},p99_s={pct(seq_lat, 99):.4f},"
        f"mean_s={float(seq_lat.mean()):.4f}")
    s = eng.stats
    csv(f"serving_latency,mode=batched,p50_s={pct(bat_lat, 50):.4f},"
        f"p95_s={pct(bat_lat, 95):.4f},p99_s={pct(bat_lat, 99):.4f},"
        f"mean_s={float(bat_lat.mean()):.4f}")
    csv(f"serving_engine,steps={s['steps']},images={s['images']},"
        f"kernel_dispatches={s['kernel_dispatches']},"
        f"image_hit_rate={s['image_hit_rate']:.3f},"
        f"queue_depth_end={s['queue_depth']}")

    # Telemetry: export the batched run's trace, schema-check it, and
    # reconcile the serve.step span wall against the measured step wall
    # (the two clocks bracket the same region, so the ratio pins span
    # accounting to reality).
    doc = chrome_trace(tracer)
    problems = validate_chrome_trace(doc)
    span_wall = sum(sp.dur for sp in tracer.snapshot()
                    if sp.name == "serve.step")
    span_wall_frac = span_wall / step_wall if step_wall else 0.0
    csv(f"serving_trace,events={len(doc['traceEvents'])},"
        f"spans={len(tracer)},span_wall_frac={span_wall_frac:.3f},"
        f"schema_ok={'yes' if not problems else 'NO'}")

    snap = eng.metrics_snapshot()
    lat = snap["serving.latency_s"]
    metrics_match = (
        snap["serving.kernel_dispatches"] == s["kernel_dispatches"]
        and snap["serving.images"] == s["images"]
        and snap["serving.steps"] == s["steps"]
        and snap["schedule_cache.hits"] == s["schedule_cache_hits"]
        and snap["schedule_cache.misses"] == s["schedule_cache_misses"]
        and abs(snap["schedule_cache.image_hit_rate"]
                - s["image_hit_rate"]) < 1e-12
        and snap["serving.host_schedule_builds"]
            == s["host_schedule_builds"]
        and snap["serving.plan_cache_hits"] == s["plan_cache_hits"]
        and snap["serving.tuned_groups"] == s["tuned_groups"]
        and abs(snap["serving.autotune_search_s"]
                - s["autotune_search_s"]) < 1e-12
        and lat["count"] == s["latency"]["count"])
    dps = (s["kernel_dispatches"] / s["steps"]) if s["steps"] else 0.0
    csv(f"serving_metrics,metrics={len(snap)},"
        f"dispatches_per_step={dps:.3f},"
        f"image_hit_rate={snap['schedule_cache.image_hit_rate']:.3f},"
        f"host_schedule_builds={snap['serving.host_schedule_builds']},"
        f"timeline_steps={len(eng.timeline)},"
        f"metrics_match_stats={'yes' if metrics_match else 'NO'}")

    if trace_out:
        write_json(trace_out, doc)
    if timeline_out:
        write_json(timeline_out, eng.timeline)
    if metrics_out:
        write_json(metrics_out, snap)
    return seq_rps, bat_rps, eng


if __name__ == "__main__":
    run()
