"""Paper Fig. 18 *executed*: cross-layer fused groups vs per-layer DRAM.

Runs a real VGG19-style DCN backbone through the network-graph executor
(``repro.runtime.fused_exec``) and cross-checks the executed trace against
the network-level traffic simulator (``repro.core.simulator``) with the
same FIFO-replay discipline as bench_scheduling:

  * per fused group, the executed group-input load sequence replayed
    through the FIFO buffer model must equal the simulator's fused
    prediction EXACTLY (same composite TDT, same Algorithm-1 schedule,
    same buffer model) — byte counts included;
  * the fused network DRAM total must be strictly below the per-layer
    (PR 1-style) execution of the same network — the Fig. 18 delta,
    reported per group and in aggregate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deform import DeformableConvParams, randomize_offset_conv
from repro.obs import Stopwatch
from repro.core.simulator import simulate_network
from repro.models.dcn_models import DcnNetConfig, dcn_net_apply, init_dcn_net
from repro.runtime.fused_exec import (GraphConfig, network_sim_specs,
                                      run_graph, run_graph_dense)
from repro.runtime.graph import (FusedGroup, build_graph, group_weight_bytes,
                                 partition_graph)


def _case(img: int, n_deform: int, width_mult: float, seed: int,
          offset_scale: float = 2.0):
    cfg = DcnNetConfig(name="vgg19", n_deform=n_deform, img_size=img,
                       width_mult=width_mult, num_classes=4)
    key = jax.random.PRNGKey(seed)
    params = init_dcn_net(key, cfg)
    # Non-zero offset convs so the sampling pattern is genuinely irregular.
    convs = []
    for i, p in enumerate(params["convs"]):
        if isinstance(p, DeformableConvParams):
            p = randomize_offset_conv(p, jax.random.fold_in(key, 100 + i),
                                      offset_scale / p.w.shape[2])
        convs.append(p)
    params["convs"] = convs
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, img, img, 3))
    return cfg, params, x


def run(csv=print, img: int = 13, n_deform: int = 2,
        width_mult: float = 0.125, tile: int = 4,
        buffer_tiles: int | None = None, seed: int = 0):
    """Executor-vs-simulator cross-check + fused-vs-layerwise Fig. 18 delta."""
    cfg, params, x = _case(img, n_deform, width_mult, seed)
    gcfg = GraphConfig(tile=tile, buffer_tiles=buffer_tiles)

    graph = build_graph(cfg)
    y, trace = run_graph(params["convs"], graph, x, config=gcfg,
                         return_trace=True)
    y_ref = run_graph_dense(params["convs"], graph, x)
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                - y_ref.astype(jnp.float32))))
    csv(f"graph_oracle,max_abs_err_vs_xla={err:.2e},"
        f"ok={'yes' if err < 1e-4 else 'NO'}")

    specs = network_sim_specs(trace)
    sim_fused = simulate_network(specs, boundary_bytes=trace.boundary_bytes,
                                 fused=True)
    sim_layer = simulate_network(specs, boundary_bytes=trace.boundary_bytes,
                                 fused=False)

    # Independent byte accounting straight from the graph IR, so a trace
    # bookkeeping bug cannot satisfy its own cross-check.
    itemsize = x.dtype.itemsize
    ir_groups = [s for s in partition_graph(graph, gcfg.onchip_budget_bytes,
                                            itemsize)
                 if isinstance(s, FusedGroup)]

    exact = True
    for gt, rep, seg in zip(trace.groups, sim_fused.groups, ir_groups):
        exec_loads = gt.fifo_replay().loads
        match = (exec_loads == rep.tile_loads
                 and gt.input_load_bytes == rep.input_read_bytes
                 and rep.output_write_bytes
                 == seg.h * seg.w * seg.c_out * itemsize
                 and rep.weight_read_bytes
                 == group_weight_bytes(seg, itemsize))
        exact &= match
        csv(f"graph_xcheck,group={gt.group},n_layers={rep.n_layers},"
            f"exec_fifo_loads={exec_loads},sim_loads={rep.tile_loads},"
            f"match={'yes' if match else 'NO'}")
    total_exact = (exact
                   and trace.total_dram_bytes == sim_fused.total_dram_bytes)
    csv(f"graph_xcheck_total,exec_dram_bytes={trace.total_dram_bytes},"
        f"sim_fused_bytes={sim_fused.total_dram_bytes},"
        f"exact={'yes' if total_exact else 'NO'}")

    for g_f, g_l in zip(sim_fused.groups, sim_layer.groups):
        if g_f.n_layers > 1:
            csv(f"fig18_group,n_layers={g_f.n_layers},"
                f"fused_bytes={g_f.total_dram_bytes},"
                f"layerwise_bytes={g_l.total_dram_bytes},"
                f"saved={g_l.total_dram_bytes - g_f.total_dram_bytes}")
    red = 1 - sim_fused.total_dram_bytes / sim_layer.total_dram_bytes
    below = sim_fused.total_dram_bytes < sim_layer.total_dram_bytes
    csv(f"fig18_network,fused_dram_bytes={sim_fused.total_dram_bytes},"
        f"layerwise_dram_bytes={sim_layer.total_dram_bytes},"
        f"reduction={100*red:.1f}%,"
        f"strictly_below={'yes' if below else 'NO'}")
    max_res = max((g.max_resident_bytes for g in trace.groups), default=0)
    csv(f"graph_buffers,recomputes={trace.total_recomputes},"
        f"max_resident_bytes={max_res},"
        f"schedule_cache_hits={trace.schedule_cache_hits},"
        f"misses={trace.schedule_cache_misses}")
    return trace, sim_fused, sim_layer


def run_dispatch(csv=print, img: int = 13, n_deform: int = 2,
                 width_mult: float = 0.125, tile: int = 4, batch: int = 2,
                 repeats: int = 3, seed: int = 0):
    """ISSUE 3 + ISSUE 5 acceptance: per-tile loop vs per-image batched
    grid vs whole-batch fused dispatch.

    Same network, same schedules (cache disabled for fair host-cost
    accounting); reports kernel-dispatch counts, end-to-end wall-clock
    (best of ``repeats`` after a compile warmup) and the host-prepass
    overlap fraction. The batched dispatch count must stay at or below
    one per layer segment per group PER IMAGE; the batch-fused count
    must be exactly one per layer segment PER BATCH.
    """
    cfg, params, x = _case(img, n_deform, width_mult, seed)
    x = jnp.concatenate([x] * batch) if batch > 1 else x
    graph = build_graph(cfg)
    y_ref = run_graph_dense(params["convs"], graph, x)
    n_segments = sum(len(s.nodes) for s in
                     partition_graph(graph,
                                     GraphConfig().onchip_budget_bytes,
                                     x.dtype.itemsize)
                     if isinstance(s, FusedGroup))

    variants = {
        "per_tile": GraphConfig(tile=tile, dispatch="per_tile",
                                staging_depth=1, use_schedule_cache=False),
        "batched": GraphConfig(tile=tile, dispatch="batched",
                               staging_depth=2, use_schedule_cache=False),
        "batch_fused": GraphConfig(tile=tile, dispatch="batch_fused",
                                   staging_depth=2,
                                   use_schedule_cache=False),
    }
    results = {}
    for name, gcfg in variants.items():
        y, trace = run_graph(params["convs"], graph, x, config=gcfg,
                             return_trace=True)  # warmup: compiles kernels
        err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                    - y_ref.astype(jnp.float32))))
        best = float("inf")
        for _ in range(repeats):
            with Stopwatch() as sw:
                y, trace = run_graph(params["convs"], graph, x,
                                     config=gcfg, return_trace=True)
                jax.block_until_ready(y)
            best = min(best, sw.dur)
        results[name] = (best, trace, err)
        csv(f"dispatch_mode,mode={name},wall_ms={1e3 * best:.1f},"
            f"dispatches={trace.kernel_dispatches},"
            f"host_overlap_frac={trace.host_overlap_frac:.3f},"
            f"max_abs_err_vs_xla={err:.2e},"
            f"ok={'yes' if err < 1e-4 else 'NO'}")

    t_p, tr_p, _ = results["per_tile"]
    t_b, tr_b, _ = results["batched"]
    t_f, tr_f, _ = results["batch_fused"]
    seg_bound = all(g.kernel_dispatches <= len(g.layer_stats)
                    for g in tr_b.groups)
    csv(f"dispatch_bench,per_tile_ms={1e3 * t_p:.1f},"
        f"batched_ms={1e3 * t_b:.1f},speedup={t_p / t_b:.2f}x,"
        f"per_tile_dispatches={tr_p.kernel_dispatches},"
        f"batched_dispatches={tr_b.kernel_dispatches},"
        f"host_overlap_frac={tr_b.host_overlap_frac:.3f},"
        f"dispatches_le_segments={'yes' if seg_bound else 'NO'},"
        f"improved={'yes' if t_b < t_p else 'NO'}")
    # ISSUE 5 gate: one dispatch per layer segment for the WHOLE batch.
    one_per_seg = tr_f.dispatches_per_batch == n_segments
    csv(f"batch_fused_bench,batch={batch},n_segments={n_segments},"
        f"dispatches_per_batch={tr_f.dispatches_per_batch},"
        f"batched_dispatches={tr_b.kernel_dispatches},"
        f"batch_fused_ms={1e3 * t_f:.1f},batched_ms={1e3 * t_b:.1f},"
        f"speedup_vs_batched={t_b / t_f:.2f}x,"
        f"one_dispatch_per_segment={'yes' if one_per_seg else 'NO'},"
        f"improved={'yes' if t_f < t_b else 'NO'}")
    return results


def run_model_backend(csv=print, img: int = 16, n_deform: int = 2,
                      width_mult: float = 0.125, tile: int = 4,
                      seed: int = 0):
    """backend="graph" through the model entry point vs the XLA backend."""
    cfg, params, x = _case(img, n_deform, width_mult, seed)
    y_graph = dcn_net_apply(params, cfg, x, backend="graph",
                            graph=GraphConfig(tile=tile))
    y_xla = dcn_net_apply(params, cfg, x, backend="xla", fused=False)
    err = float(np.max(np.abs(np.asarray(y_graph) - np.asarray(y_xla))))
    csv(f"graph_model_backend,max_abs_err={err:.2e},"
        f"ok={'yes' if err < 5e-3 else 'NO'}")
    return err


if __name__ == "__main__":
    run()
    run_dispatch()
    run_model_backend()
