"""Chaos benchmark: serving resilience under deterministic fault injection.

Drives ``DcnServingEngine`` through a seeded fault campaign
(``repro.testing.faults``) and verifies the resilience contract the unit
tests pin piecewise, end to end and under load:

  * **exactly-once** — every submitted request resolves exactly once:
    nothing lost, nothing duplicated, every failure a typed
    ``RequestFailedError`` on the handle;
  * **bounded blast radius** — healthy requests (those no fault touched)
    keep p99 latency within 1.5x of a fault-free run of the same
    workload;
  * **isolation** — a tagged fault in a coalesced step fails only the
    offending request while its step-mates complete reference-exact;
  * **honest accounting** — on every non-faulted step the executed
    trace still equals the DRAM simulator exactly (resilience machinery
    must not perturb the model);
  * **liveness** — nothing deadlocks: every drain completes within its
    step budget, including under backpressure shedding and deadline
    expiry.

The chaos phase runs the injector in ``"step"`` mode at ``fault_rate``
(default 0.1): each step arms each fault kind independently, so the
faulted-step fraction stays ~``1-(1-rate)^kinds`` and the healthy
population is large enough for the p99 gate to mean something. All
draws are pure functions of the seed — reruns reproduce the exact same
failure pattern.
"""

from __future__ import annotations

import os
import sys

import jax.numpy as jnp
import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:  # allow `python benchmarks/bench_resilience.py`
    sys.path.insert(0, _ROOT)

from benchmarks.bench_graph import _case
from repro.core.simulator import simulate_network
from repro.runtime import GraphConfig
from repro.runtime.fused_exec import network_sim_specs
from repro.serving import (DcnServingEngine, DrainTimeout,
                           RequestFailedError)
from repro.testing import ALL_FAULT_KINDS, FaultInjector


def _trace_matches(tr) -> bool:
    """Executed trace == DRAM simulator, exactly (the ISSUE 3-6 cross-
    check, reasserted on non-faulted steps of the chaos run)."""
    sim = simulate_network(network_sim_specs(tr),
                           boundary_bytes=tr.boundary_bytes, fused=True)
    if tr.total_dram_bytes != sim.total_dram_bytes:
        return False
    return all(gt.fifo_replay().loads == rep.tile_loads
               for gt, rep in zip(tr.groups, sim.groups))


def _closed_loop(eng, xs, inj=None, trace_check=False):
    """Serve ``xs`` one request at a time; returns accounting dict.

    With ``inj``, each image first passes through ``corrupt`` (the
    nan_image fault) — a rejected submit counts as resolved at the
    front door, which is the isolation under test.
    """
    acc = dict(submitted=0, nan_rejected=0, resolved_rids=[],
               healthy_lat=[], failed=[], deadlocked=False,
               trace_checked=0, trace_exact=0)
    for x in xs:
        # "Healthy" = neither a fault nor a watchdog failover touched
        # this request: the p99 gate measures blast radius onto
        # untouched traffic, so stalled/retried/failed-over requests
        # don't dilute it (a failover can also fire spuriously on a
        # transient scheduling hiccup — environmental noise, excluded
        # symmetrically in both phases).
        f0 = inj.total_fired if inj is not None else 0
        if inj is not None:
            x = inj.corrupt(x)
        try:
            r = eng.submit(x)
        except ValueError:
            acc["nan_rejected"] += 1
            continue
        acc["submitted"] += 1
        s0 = eng.stats
        try:
            done = eng.drain(max_steps=50)
        except DrainTimeout as e:
            acc["deadlocked"] = True
            done = e.finished
        acc["resolved_rids"].extend(q.rid for q in done)
        s1 = eng.stats
        if trace_check:
            clean = (not eng.last_step_faulted
                     and s1["degraded_steps"] == s0["degraded_steps"])
            if clean and eng.last_trace is not None:
                acc["trace_checked"] += 1
                acc["trace_exact"] += int(_trace_matches(eng.last_trace))
        if r.failed:
            acc["failed"].append(r)
        elif r.done:
            untouched = ((inj is None or inj.total_fired == f0)
                         and s1["watchdog_failovers"]
                         == s0["watchdog_failovers"])
            if untouched:
                acc["healthy_lat"].append(r.latency_s)
    return acc


def run(csv=print, img: int = 13, n_deform: int = 2,
        width_mult: float = 0.125, tile: int = 4, slots: int = 4,
        n_requests: int = 24, fault_rate: float = 0.1, seed: int = 0,
        stall_s: float = 0.6, watchdog_s: float = 0.25):
    """Fault-free baseline + seeded chaos run + isolation/backpressure
    scenarios; csv three records smoke.py gates on."""
    cfg, params, _ = _case(img, n_deform, width_mult, seed)
    rng = np.random.default_rng(seed + 1)
    xs = [rng.normal(size=(img, img, 3)).astype(np.float32)
          for _ in range(n_requests)]

    def engine(**kw):
        kw.setdefault("graph", GraphConfig(tile=tile,
                                           watchdog_s=watchdog_s))
        kw.setdefault("slots", slots)
        return DcnServingEngine(params, cfg, **kw)

    # Warm every compile path the chaos run can reach: fused widths the
    # coalesced/retry steps use, and the degraded per-image batched path
    # (forced via one untagged fault — the jit cache is process-global,
    # so this compile never lands mid-measurement).
    warm = engine()
    for w in (1, slots - 1, slots):
        for k in range(w):
            warm.submit(xs[k % len(xs)])
        warm.drain()
    force = FaultInjector(kinds=("dispatch",), rate=1.0, max_fires=1,
                          tag_image=False, seed=seed)
    warm_deg = engine(faults=force)
    warm_deg.submit(xs[0])
    warm_deg.drain()

    # -- phase 1: fault-free baseline (same workload, own engine/cache)
    base = _closed_loop(engine(), xs)
    p99_base = float(np.percentile(base["healthy_lat"], 99))

    # -- phase 2: chaos — all fault kinds, step-scoped arming
    inj = FaultInjector(kinds=ALL_FAULT_KINDS, rate=fault_rate,
                        seed=seed + 2, stall_s=stall_s, mode="step")
    eng = engine(faults=inj)
    chaos = _closed_loop(eng, xs, inj=inj, trace_check=True)
    p99_faulted = (float(np.percentile(chaos["healthy_lat"], 99))
                   if chaos["healthy_lat"] else float("nan"))
    p99_ratio = p99_faulted / p99_base if p99_base else float("inf")
    # Snapshot NOW: watchdog_failovers is a process-wide delta and the
    # scenario engines below would otherwise leak into it.
    s = eng.stats

    # -- phase 3: isolation — one tagged fault in a coalesced step
    inj_iso = FaultInjector(kinds=("dispatch",), rate=1.0, max_fires=1,
                            seed=seed + 3)
    eng_iso = engine(faults=inj_iso)
    iso_reqs = [eng_iso.submit(x) for x in xs[:slots]]
    iso_done = eng_iso.drain()
    iso_failed = [r for r in iso_reqs if r.failed]
    ref = np.asarray(engine().infer(jnp.asarray(np.stack(xs[:slots]))))
    iso_ok = (len(iso_done) == slots and len(iso_failed) == 1
              and isinstance(iso_failed[0].error, RequestFailedError)
              and all(np.allclose(r.result()[0], ref[i],
                                  rtol=2e-4, atol=2e-4)
                      for i, r in enumerate(iso_reqs) if not r.failed))

    # -- phase 4: backpressure shedding + deadline expiry, no deadlock
    eng_bp = engine(slots=1, max_queue=4, queue_policy="shed-oldest")
    bp_reqs = [eng_bp.submit(x) for x in xs[:6]]          # sheds 2
    bp_done = eng_bp.drain()
    rd = eng_bp.submit(xs[6], deadline_s=1e-6)            # expires queued
    bp_done += eng_bp.drain()
    shed = [r for r in bp_reqs if r.failed]
    bp_rids = [r.rid for r in bp_done] + [r.rid for r in shed]
    bp_ok = (sorted(bp_rids + []) == sorted(r.rid for r in bp_reqs + [rd])
             and rd.failed
             and eng_bp.stats["queue_shed"] == len(shed)
             and all(isinstance(r.error, RequestFailedError)
                     for r in shed + [rd]))

    # -- accounting and gates data
    lost = (chaos["submitted"] - len(chaos["resolved_rids"])
            + base["submitted"] - len(base["resolved_rids"]))
    duplicated = (len(chaos["resolved_rids"])
                  - len(set(chaos["resolved_rids"])))
    typed_ok = all(isinstance(r.error, RequestFailedError)
                   for r in chaos["failed"])
    deadlocked = base["deadlocked"] or chaos["deadlocked"]
    trace_exact = chaos["trace_exact"] == chaos["trace_checked"]

    csv(f"resilience_bench,n_requests={n_requests},"
        f"submitted={chaos['submitted']},"
        f"nan_rejected={chaos['nan_rejected']},"
        f"requests_lost={lost},duplicated={duplicated},"
        f"typed_errors={'yes' if typed_ok else 'NO'},"
        f"healthy={len(chaos['healthy_lat'])},"
        f"p99_base_s={p99_base:.4f},p99_faulted_s={p99_faulted:.4f},"
        f"healthy_p99_ratio={p99_ratio:.3f},"
        f"deadlocked={'YES' if deadlocked else 'no'}")
    csv(f"resilience_faults,rate={fault_rate},"
        f"total_fired={inj.total_fired},"
        f"prepass={inj.fired.get('prepass', 0)},"
        f"dispatch={inj.fired.get('dispatch', 0)},"
        f"worker_stall={inj.fired.get('worker_stall', 0)},"
        f"cache_miss={inj.fired.get('cache_miss', 0)},"
        f"nan_image={inj.fired.get('nan_image', 0)},"
        f"step_retries={s['step_retries']},"
        f"degraded_steps={s['degraded_steps']},"
        f"watchdog_failovers={s['watchdog_failovers']}")
    csv(f"resilience_engine,steps={s['steps']},"
        f"requests_failed={s['requests_failed']},"
        f"trace_checked={chaos['trace_checked']},"
        f"trace_exact={'yes' if trace_exact else 'NO'},"
        f"isolation_ok={'yes' if iso_ok else 'NO'},"
        f"queue_shed={eng_bp.stats['queue_shed']},"
        f"deadline_expired={eng_bp.stats['deadline_expired']},"
        f"backpressure_ok={'yes' if bp_ok else 'NO'}")
    return eng, chaos


if __name__ == "__main__":
    run()
