"""ISSUE 10 executed: simulator-guided plan autotuning, end to end.

Per (net, img_size) case the SAME representative input runs through the
graph executor twice — greedy plan vs ``autotune="offline"`` (the
winning plan persisted under a plan-cache directory) — and the bench
checks what the smoke gate enforces:

  * tuned executed DRAM <= greedy executed DRAM on every case
    (``tuned_never_loses_to_greedy``), with at least one case showing a
    strict >5% reduction;
  * the tuned trace stays EXACTLY equal to the DRAM simulator — the
    tuner's predicted win is verified on executed traffic, not trusted;
  * tuned numerics match the greedy run (same math, different tiling);
  * a FRESH ``PlanCache`` over the same directory serves the plan from
    disk (``plan_cache_hit_on_second_run``) — serving pays the search
    once per deployment, not once per process.

The FIFO depth is bounded (``buffer_tiles``) — the paper's actual
hardware model and the regime where Fig. 17's tile-shape sensitivity is
real: an unbounded FIFO loads every input tile exactly once, so tile
shape barely matters there.
"""

from __future__ import annotations

import tempfile

import jax
import numpy as np

from repro.core.deform import DeformableConvParams, randomize_offset_conv
from repro.core.simulator import simulate_network
from repro.models.dcn_models import DcnNetConfig, init_dcn_net
from repro.runtime.fused_exec import (GraphConfig, network_sim_specs,
                                      run_graph)
from repro.runtime.graph import build_graph
from repro.tuning import (PlanCache, representative_input,
                          resolve_tuned_plan)

from benchmarks.workloads import net_label

# (img, n_deform, width_mult, offset_scale, tile, buffer_tiles): small
# planes keep CI wall-time in seconds; the narrow width_mult case makes
# input-halo traffic dominate weights, where tuning wins big.
CASES = (
    (13, 2, 0.125, 4.0, 4, 6),
    (24, 2, 0.125, 6.0, 4, 6),
    (24, 2, 0.0625, 6.0, 4, 6),
)


def _case(img: int, n_deform: int, width_mult: float,
          offset_scale: float, seed: int = 0):
    cfg = DcnNetConfig(name="vgg19", n_deform=n_deform, img_size=img,
                       width_mult=width_mult, num_classes=4)
    key = jax.random.PRNGKey(seed)
    params = init_dcn_net(key, cfg)
    convs = []
    for i, p in enumerate(params["convs"]):
        if isinstance(p, DeformableConvParams):
            p = randomize_offset_conv(p, jax.random.fold_in(key, 100 + i),
                                      offset_scale)
        convs.append(p)
    return cfg, convs


def run(csv=print, cases=CASES, budget: int = 300,
        cache_dir: str | None = None):
    outdir = cache_dir or tempfile.mkdtemp(prefix="plan-cache-")
    ratios = []
    g_total = t_total = 0
    search_s_total = 0.0
    all_exact = all_num = True
    probe = None
    for img, nd, wm, scale, tile, bt in cases:
        cfg, convs = _case(img, nd, wm, scale)
        graph = build_graph(cfg)
        x = representative_input(graph)
        g_cfg = GraphConfig(tile=tile, buffer_tiles=bt)
        t_cfg = GraphConfig(tile=tile, buffer_tiles=bt,
                            autotune="offline", autotune_budget=budget,
                            plan_cache_dir=outdir)
        y_g, tr_g = run_graph(convs, graph, x, config=g_cfg,
                              return_trace=True)
        y_t, tr_t = run_graph(convs, graph, x, config=t_cfg,
                              return_trace=True)
        sim = simulate_network(network_sim_specs(tr_t),
                               boundary_bytes=tr_t.boundary_bytes,
                               fused=True)
        exact = tr_t.total_dram_bytes == sim.total_dram_bytes
        err = float(np.max(np.abs(np.asarray(y_t, np.float32)
                                  - np.asarray(y_g, np.float32))))
        num_ok = err < 1e-4
        gb, tb = tr_g.total_dram_bytes, tr_t.total_dram_bytes
        ratio = tb / gb if gb else 1.0
        # Introspect the persisted plan (cached-only -> pure hit).
        plan = resolve_tuned_plan(
            convs, graph, autotune="cached-only",
            onchip_budget_bytes=t_cfg.onchip_budget_bytes,
            dtype_bytes=x.dtype.itemsize, tile_hw=t_cfg.tile_hw,
            buffer_tiles=bt, schedule=t_cfg.schedule, batch=1,
            plan_cache_dir=outdir)
        probe = (convs, graph, x, t_cfg, bt, plan)
        ratios.append(ratio)
        g_total += gb
        t_total += tb
        search_s_total += plan.search_s if plan else 0.0
        all_exact = all_exact and exact
        all_num = all_num and num_ok
        csv(f"autotune_case,net={net_label('vgg19', nd)},img={img},"
            f"width_mult={wm},tile={tile},buffer_tiles={bt},"
            f"greedy_dram_bytes={gb},tuned_dram_bytes={tb},"
            f"ratio={ratio:.4f},"
            f"tuned_groups={len(plan.groups) if plan else 0},"
            f"search_evals={plan.candidates if plan else 0},"
            f"never_loses={'yes' if ratio <= 1.0 else 'NO'},"
            f"trace_exact={'yes' if exact else 'NO'},"
            f"numerics_ok={'yes' if num_ok else 'NO'}")

    # Disk round-trip: a FRESH cache over the same directory (bypassing
    # the shared in-memory layer) must serve the last case's plan.
    convs, graph, x, t_cfg, bt, plan = probe
    fresh = PlanCache(cache_dir=outdir)
    again = resolve_tuned_plan(
        convs, graph, autotune="cached-only",
        onchip_budget_bytes=t_cfg.onchip_budget_bytes,
        dtype_bytes=x.dtype.itemsize, tile_hw=t_cfg.tile_hw,
        buffer_tiles=bt, schedule=t_cfg.schedule, batch=1,
        plan_cache=fresh)
    hit2 = again is not None and again == plan
    csv(f"autotune_summary,cases={len(ratios)},"
        f"max_ratio={max(ratios):.4f},min_ratio={min(ratios):.4f},"
        f"greedy_total_bytes={g_total},tuned_total_bytes={t_total},"
        f"search_s_total={search_s_total:.2f},"
        f"plan_cache_hit_on_second_run={'yes' if hit2 else 'NO'},"
        f"all_trace_exact={'yes' if all_exact else 'NO'},"
        f"all_numerics_ok={'yes' if all_num else 'NO'}")
    return {"ratios": ratios, "greedy_total": g_total,
            "tuned_total": t_total, "hit_on_second_run": hit2,
            "all_exact": all_exact}


if __name__ == "__main__":
    run()
