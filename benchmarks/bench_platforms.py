"""Paper Figs. 11-12: DCN performance + energy on ARM / ARM+TPU / GPU /
DCNA, normalized to ARM.

Analytical platform models parameterized ONLY by public spec numbers (per
§V-A of the paper) applied to the measured per-network FLOP inventories
(benchmarks.workloads). DCNA's irregular-access efficiency comes from OUR
tile-scheduling simulator, not a fitted constant. The paper's headline
ratios are printed next to ours for comparison.

Platform constants (public):
  ARM Cortex-A7 @900MHz, 4-wide int8 NEON       ~3.6 GOPS dense conv
     irregular per-element gather+MAC path      ~0.15 GOPS (paper: GPP
     "extremely slow due to lack of parallel computing capability")
  TPU-like NNA (Table I): 16x32 PEs @800MHz     409.6 GOPS peak, int8
  Jetson TX2 GPU: 256 CUDA cores @1.3GHz fp16   665 GFLOPS peak,
     deformable ops run at gather efficiency    ~15% of peak
  Powers: ARM 1.3W avg / 0.3W idle (paper), TX2 GPU ~10W board,
     NNA ~0.9W @40nm (DianNao-class), DRAM per Table II.
"""

from __future__ import annotations

import dataclasses

from repro.core.scheduler import FifoBuffer, schedule_tiles
from repro.core.simulator import DramEnergyModel

from benchmarks.workloads import (NETWORKS, VARIANTS, build_workload,
                                  measured_tdt, net_label)

# --- platform constants (public spec numbers; see module docstring) ----
ARM_DENSE = 3.6e9
ARM_IRREG = 0.22e9
NNA_PEAK = 409.6e9          # 16*32 PEs * 2 ops * 800 MHz
NNA_EFF = 0.75              # dense conv utilization on the 2-D array
GPU_PEAK = 665e9
GPU_EFF = 0.45              # dense conv
GPU_IRREG_EFF = 0.10        # deformable ops (gather-bound)
P_ARM, P_ARM_IDLE = 1.3, 0.3
P_GPU = 10.0
P_NNA = 0.9
_DRAM = DramEnergyModel()


@dataclasses.dataclass
class PlatformResult:
    time_s: float
    energy_j: float


def _dcna_irregular_efficiency() -> float:
    """Fraction of peak the DCNA sustains on BLI sampling, from the
    measured TDT + Algorithm-1 schedule: loads-per-reuse under the paper's
    128KB input buffer determine how often the PE array stalls."""
    B, pp, grid = measured_tdt()
    tile_bytes = grid.tile_bytes(256, 1)
    buf_tiles = max(1, 128 * 1024 // tile_bytes)
    sched = schedule_tiles(B, buf_tiles)
    buf = FifoBuffer(buf_tiles)
    for loads in sched.iid:
        for t in loads:
            buf.touch(t)
    total_touches = buf.loads + buf.hits
    # every on-chip hit is full-rate; each load overlaps ~50% with compute
    return (buf.hits + 0.5 * buf.loads) / max(total_touches, 1)


def evaluate(name: str, n_deform: int, variant: str) -> dict:
    w = build_workload(name, n_deform, variant)
    eff = _dcna_irregular_efficiency()

    # --- execution-time models ---
    # DCN-I samples ONE deformed plane per position (indices shared across
    # taps): its stage-3 conv slides regularly over that plane and runs at
    # dense rate. DCN-II's stage-3 reads kk scattered samples per output
    # (paper §II-A: "more computation and random accesses").
    arm_dconv_rate = 1.25 * ARM_IRREG if variant == "dcn1" else ARM_IRREG
    arm = (w.conv_flops / ARM_DENSE
           + w.offset_flops / ARM_DENSE
           + w.bli_flops / ARM_IRREG
           + w.deform_conv_flops / arm_dconv_rate)
    arm_tpu = (max(w.conv_flops, 1) / (NNA_PEAK * NNA_EFF)
               + w.offset_flops / (NNA_PEAK * NNA_EFF)
               + w.bli_flops / ARM_IRREG
               + w.deform_conv_flops / arm_dconv_rate
               + 2 * w.deform_bytes / 12.8e9)  # ARM<->NNA feature shuttling
    gpu_dconv_eff = 2 * GPU_IRREG_EFF if variant == "dcn1" else GPU_IRREG_EFF
    gpu = ((w.conv_flops + w.offset_flops) / (GPU_PEAK * GPU_EFF)
           + w.bli_flops / (GPU_PEAK * GPU_IRREG_EFF)
           + w.deform_conv_flops / (GPU_PEAK * gpu_dconv_eff))
    dcna = ((w.conv_flops + w.offset_flops + w.deform_conv_flops)
            / (NNA_PEAK * NNA_EFF)
            + w.bli_flops / (NNA_PEAK * eff))

    # --- energy models (compute power * time + DRAM traffic) ---
    def dram_j(bytes_, t):
        return _DRAM.energy_j(bytes_ * 0.6, bytes_ * 0.4, t)

    e_arm = P_ARM * arm + dram_j(w.total_bytes + 4 * w.deform_bytes, arm)
    e_arm_tpu = (P_ARM * ((w.bli_flops + w.deform_conv_flops) / ARM_IRREG)
                 + P_ARM_IDLE * (arm_tpu)
                 + P_NNA * (w.conv_flops / (NNA_PEAK * NNA_EFF))
                 + dram_j(w.total_bytes + 6 * w.deform_bytes, arm_tpu))
    e_gpu = P_GPU * gpu + dram_j(w.total_bytes + 2 * w.deform_bytes, gpu)
    e_dcna = P_NNA * dcna + dram_j(w.total_bytes + w.deform_bytes, dcna)

    return {
        "net": net_label(name, n_deform), "variant": variant,
        "ARM": PlatformResult(arm, e_arm),
        "ARM+TPU": PlatformResult(arm_tpu, e_arm_tpu),
        "GPU": PlatformResult(gpu, e_gpu),
        "DCNA": PlatformResult(dcna, e_dcna),
    }


def run(csv=print):
    rows = []
    for variant in VARIANTS:
        for name, nd in NETWORKS:
            r = evaluate(name, nd, variant)
            rows.append(r)
            arm, dcna, gpu, at = (r["ARM"], r["DCNA"], r["GPU"], r["ARM+TPU"])
            csv(f"fig11_perf,{r['net']},{variant},"
                f"speedup_vs_arm={arm.time_s / dcna.time_s:.1f},"
                f"speedup_vs_armtpu={at.time_s / dcna.time_s:.1f},"
                f"speedup_vs_gpu={gpu.time_s / dcna.time_s:.2f}")
            csv(f"fig12_energy,{r['net']},{variant},"
                f"reduction_vs_arm={arm.energy_j / dcna.energy_j:.0f},"
                f"reduction_vs_gpu={gpu.energy_j / dcna.energy_j:.1f}")

    # headline averages vs paper claims
    import numpy as np
    for variant, paper_perf in (("dcn1", 515.0), ("dcn2", 621.0)):
        sel = [r for r in rows if r["variant"] == variant]
        ours = np.mean([r["ARM"].time_s / r["DCNA"].time_s for r in sel])
        csv(f"fig11_summary,{variant},mean_speedup_vs_arm={ours:.0f},"
            f"paper={paper_perf:.0f}")
    sel = rows
    gpu_speed = np.mean([r["GPU"].time_s / r["DCNA"].time_s for r in sel])
    gpu_energy = np.mean([r["GPU"].energy_j / r["DCNA"].energy_j for r in sel])
    arm_energy = np.mean([r["ARM"].energy_j / r["DCNA"].energy_j for r in sel])
    at_speed = [r["ARM+TPU"].time_s / r["DCNA"].time_s for r in sel]
    csv(f"fig11_summary,gpu,mean_speedup_vs_gpu={gpu_speed:.2f},paper=2.21")
    csv(f"fig12_summary,gpu,mean_energy_reduction={gpu_energy:.1f},paper=9")
    csv(f"fig12_summary,arm,mean_energy_reduction={arm_energy:.0f},paper=612")
    csv(f"fig11_summary,armtpu,speedup_range={min(at_speed):.0f}-"
        f"{max(at_speed):.0f},paper=45-546")
    return rows


if __name__ == "__main__":
    run()
