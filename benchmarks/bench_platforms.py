"""Paper Figs. 11-12: DCN performance + energy on ARM / ARM+TPU / GPU /
DCNA, normalized to ARM.

Analytical platform models parameterized ONLY by public spec numbers (per
§V-A of the paper) applied to the measured per-network FLOP inventories
(benchmarks.workloads). DCNA's irregular-access efficiency comes from OUR
tile-scheduling simulator, not a fitted constant. The paper's headline
ratios are printed next to ours for comparison.

Platform constants (public):
  ARM Cortex-A7 @900MHz, 4-wide int8 NEON       ~3.6 GOPS dense conv
     irregular per-element gather+MAC path      ~0.15 GOPS (paper: GPP
     "extremely slow due to lack of parallel computing capability")
  TPU-like NNA (Table I): 16x32 PEs @800MHz     409.6 GOPS peak, int8
  Jetson TX2 GPU: 256 CUDA cores @1.3GHz fp16   665 GFLOPS peak,
     deformable ops run at gather efficiency    ~15% of peak
  Powers: ARM 1.3W avg / 0.3W idle (paper), TX2 GPU ~10W board,
     NNA ~0.9W @40nm (DianNao-class), DRAM per Table II.
"""

from __future__ import annotations

import dataclasses

from repro.core.scheduler import FifoBuffer, schedule_tiles
from repro.core.simulator import DramEnergyModel

from benchmarks.workloads import (NETWORKS, VARIANTS, build_workload,
                                  measured_tdt, net_label)

# --- platform constants (public spec numbers; see module docstring) ----
ARM_DENSE = 3.6e9
ARM_IRREG = 0.22e9
NNA_PEAK = 409.6e9          # 16*32 PEs * 2 ops * 800 MHz
NNA_EFF = 0.75              # dense conv utilization on the 2-D array
GPU_PEAK = 665e9
GPU_EFF = 0.45              # dense conv
GPU_IRREG_EFF = 0.10        # deformable ops (gather-bound)
P_ARM, P_ARM_IDLE = 1.3, 0.3
P_GPU = 10.0
P_NNA = 0.9
_DRAM = DramEnergyModel()


@dataclasses.dataclass
class PlatformResult:
    time_s: float
    energy_j: float


def _dcna_irregular_efficiency() -> float:
    """Fraction of peak the DCNA sustains on BLI sampling, from the
    measured TDT + Algorithm-1 schedule: loads-per-reuse under the paper's
    128KB input buffer determine how often the PE array stalls."""
    B, pp, grid = measured_tdt()
    tile_bytes = grid.tile_bytes(256, 1)
    buf_tiles = max(1, 128 * 1024 // tile_bytes)
    sched = schedule_tiles(B, buf_tiles)
    buf = FifoBuffer(buf_tiles)
    for loads in sched.iid:
        for t in loads:
            buf.touch(t)
    total_touches = buf.loads + buf.hits
    # every on-chip hit is full-rate; each load overlaps ~50% with compute
    return (buf.hits + 0.5 * buf.loads) / max(total_touches, 1)


def evaluate(name: str, n_deform: int, variant: str) -> dict:
    w = build_workload(name, n_deform, variant)
    eff = _dcna_irregular_efficiency()

    # --- execution-time models ---
    # DCN-I samples ONE deformed plane per position (indices shared across
    # taps): its stage-3 conv slides regularly over that plane and runs at
    # dense rate. DCN-II's stage-3 reads kk scattered samples per output
    # (paper §II-A: "more computation and random accesses").
    arm_dconv_rate = 1.25 * ARM_IRREG if variant == "dcn1" else ARM_IRREG
    arm = (w.conv_flops / ARM_DENSE
           + w.offset_flops / ARM_DENSE
           + w.bli_flops / ARM_IRREG
           + w.deform_conv_flops / arm_dconv_rate)
    arm_tpu = (max(w.conv_flops, 1) / (NNA_PEAK * NNA_EFF)
               + w.offset_flops / (NNA_PEAK * NNA_EFF)
               + w.bli_flops / ARM_IRREG
               + w.deform_conv_flops / arm_dconv_rate
               + 2 * w.deform_bytes / 12.8e9)  # ARM<->NNA feature shuttling
    gpu_dconv_eff = 2 * GPU_IRREG_EFF if variant == "dcn1" else GPU_IRREG_EFF
    gpu = ((w.conv_flops + w.offset_flops) / (GPU_PEAK * GPU_EFF)
           + w.bli_flops / (GPU_PEAK * GPU_IRREG_EFF)
           + w.deform_conv_flops / (GPU_PEAK * gpu_dconv_eff))
    dcna = ((w.conv_flops + w.offset_flops + w.deform_conv_flops)
            / (NNA_PEAK * NNA_EFF)
            + w.bli_flops / (NNA_PEAK * eff))

    # --- energy models (compute power * time + DRAM traffic) ---
    def dram_j(bytes_, t):
        return _DRAM.energy_j(bytes_ * 0.6, bytes_ * 0.4, t)

    e_arm = P_ARM * arm + dram_j(w.total_bytes + 4 * w.deform_bytes, arm)
    e_arm_tpu = (P_ARM * ((w.bli_flops + w.deform_conv_flops) / ARM_IRREG)
                 + P_ARM_IDLE * (arm_tpu)
                 + P_NNA * (w.conv_flops / (NNA_PEAK * NNA_EFF))
                 + dram_j(w.total_bytes + 6 * w.deform_bytes, arm_tpu))
    e_gpu = P_GPU * gpu + dram_j(w.total_bytes + 2 * w.deform_bytes, gpu)
    e_dcna = P_NNA * dcna + dram_j(w.total_bytes + w.deform_bytes, dcna)

    return {
        "net": net_label(name, n_deform), "variant": variant,
        "ARM": PlatformResult(arm, e_arm),
        "ARM+TPU": PlatformResult(arm_tpu, e_arm_tpu),
        "GPU": PlatformResult(gpu, e_gpu),
        "DCNA": PlatformResult(dcna, e_dcna),
    }


def run(csv=print):
    rows = []
    for variant in VARIANTS:
        for name, nd in NETWORKS:
            r = evaluate(name, nd, variant)
            rows.append(r)
            arm, dcna, gpu, at = (r["ARM"], r["DCNA"], r["GPU"], r["ARM+TPU"])
            csv(f"fig11_perf,{r['net']},{variant},"
                f"speedup_vs_arm={arm.time_s / dcna.time_s:.1f},"
                f"speedup_vs_armtpu={at.time_s / dcna.time_s:.1f},"
                f"speedup_vs_gpu={gpu.time_s / dcna.time_s:.2f}")
            csv(f"fig12_energy,{r['net']},{variant},"
                f"reduction_vs_arm={arm.energy_j / dcna.energy_j:.0f},"
                f"reduction_vs_gpu={gpu.energy_j / dcna.energy_j:.1f}")

    # headline averages vs paper claims
    import numpy as np
    for variant, paper_perf in (("dcn1", 515.0), ("dcn2", 621.0)):
        sel = [r for r in rows if r["variant"] == variant]
        ours = np.mean([r["ARM"].time_s / r["DCNA"].time_s for r in sel])
        csv(f"fig11_summary,{variant},mean_speedup_vs_arm={ours:.0f},"
            f"paper={paper_perf:.0f}")
    sel = rows
    gpu_speed = np.mean([r["GPU"].time_s / r["DCNA"].time_s for r in sel])
    gpu_energy = np.mean([r["GPU"].energy_j / r["DCNA"].energy_j for r in sel])
    arm_energy = np.mean([r["ARM"].energy_j / r["DCNA"].energy_j for r in sel])
    at_speed = [r["ARM+TPU"].time_s / r["DCNA"].time_s for r in sel]
    csv(f"fig11_summary,gpu,mean_speedup_vs_gpu={gpu_speed:.2f},paper=2.21")
    csv(f"fig12_summary,gpu,mean_energy_reduction={gpu_energy:.1f},paper=9")
    csv(f"fig12_summary,arm,mean_energy_reduction={arm_energy:.0f},paper=612")
    csv(f"fig11_summary,armtpu,speedup_range={min(at_speed):.0f}-"
        f"{max(at_speed):.0f},paper=45-546")
    return rows


# --- multi-device scale-out sweep (ISSUE 9) ---------------------------
#
# Unlike the analytic platform models above, the scale-out sweep runs
# the REAL sharded serving engine: one subprocess per device count under
# XLA_FLAGS=--xla_force_host_platform_device_count=D serves the smoke
# graph with dispatch="batch_fused", data_parallel=D, and reports the
# machine-measured per-replica counters (images, SPMD dispatches,
# modeled DRAM bytes) plus the logits all-gather byte volume. Scale-out
# throughput is then the accelerator-model view of those measured
# counters: per-step time = the SLOWEST replica's DRAM+dispatch time
# plus the all-gather — forced host devices share the CI worker's
# cores, so wall-clock rps is reported but never gated.

DISPATCH_OVERHEAD_S = 2e-6   # per SPMD kernel launch on the NNA
LINK_BW = 12.8e9             # DRAM/interconnect bandwidth (Table I)


def _scaleout_worker(devices: int, n_requests: int, img: int,
                     n_deform: int, width_mult: float, tile: int,
                     slots: int) -> None:
    """Subprocess body: serve ``n_requests`` on a ``devices``-replica
    engine and print the measured counters as one JSON line."""
    import json
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.deform import (DeformableConvParams,
                                   randomize_offset_conv)
    from repro.models.dcn_models import DcnNetConfig, init_dcn_net
    from repro.runtime import GraphConfig
    from repro.serving import DcnServingEngine

    assert jax.device_count() >= devices, (jax.device_count(), devices)
    cfg = DcnNetConfig(name="vgg19", n_deform=n_deform, img_size=img,
                       width_mult=width_mult, num_classes=4)
    key = jax.random.PRNGKey(2)
    params = init_dcn_net(key, cfg)
    params["convs"] = [
        randomize_offset_conv(p, jax.random.fold_in(key, 100 + i),
                              2.0 / p.w.shape[2])
        if isinstance(p, DeformableConvParams) else p
        for i, p in enumerate(params["convs"])]
    graph = GraphConfig(tile=tile, dispatch="batch_fused",
                        data_parallel=devices if devices > 1 else None)
    eng = DcnServingEngine(params, cfg, graph=graph, slots=slots)
    rng = np.random.default_rng(0)
    xs = rng.normal(size=(n_requests, img, img, 3)).astype(np.float32)
    eng.infer(jnp.asarray(xs[:1]))               # warm compile + caches
    base = eng.stats
    base_pr = [dict(p) for p in base["per_replica"]]
    base_ag = base["allgather_bytes"]
    base_steps = base["steps"]
    t0 = time.perf_counter()
    reqs = [eng.submit(x) for x in xs]
    eng.drain()
    wall = time.perf_counter() - t0
    assert all(r.done and not r.failed for r in reqs)
    s = eng.stats
    print(json.dumps({
        "devices": devices,
        "replicas": s["replicas"],
        "requests": n_requests,
        "wall_s": wall,
        "steps": s["steps"] - base_steps,
        "per_replica": [{k: p[k] - b[k] for k in p}
                        for p, b in zip(s["per_replica"], base_pr)],
        "allgather_bytes": s["allgather_bytes"] - base_ag,
    }))


def _modeled_time_s(res: dict) -> float:
    """Accelerator-model serving time of one sweep point: replicas run
    their local images' DRAM traffic and SPMD launches concurrently, so
    the step critical path is the slowest replica, plus the one logits
    all-gather."""
    worst = max(p["dram_bytes"] / LINK_BW
                + p["dispatches"] * DISPATCH_OVERHEAD_S
                for p in res["per_replica"])
    return worst + res["allgather_bytes"] / LINK_BW


def run_scaleout(csv=print, device_counts=(1, 2, 4), n_requests=12,
                 img=16, n_deform=2, width_mult=0.125, tile=4, slots=4,
                 timeout_s=560):
    """Forced-host-device scale-out sweep -> ``scaleout*`` records.

    Each device count runs in its own subprocess (XLA_FLAGS must be set
    before jax initialises); the parent emits one ``scaleout`` record
    per point, per-device ``scaleout_device`` throughput records, and a
    ``scaleout_summary`` with the modeled speedup the smoke gate checks
    (>= 2.5x at 4 devices)."""
    import json
    import os
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    results = []
    for d in device_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                            f"{d}")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(root, "src"), root])
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_platforms",
             "--scaleout-worker", str(d), "--requests",
             str(n_requests), "--img", str(img), "--n-deform",
             str(n_deform), "--width-mult", str(width_mult), "--tile",
             str(tile), "--slots", str(slots)],
            env=env, cwd=root, capture_output=True, text=True,
            timeout=timeout_s)
        if proc.returncode != 0:
            raise RuntimeError(
                f"scaleout worker (devices={d}) failed:\n"
                f"{proc.stdout}\n{proc.stderr}")
        res = json.loads(proc.stdout.strip().splitlines()[-1])
        res["modeled_time_s"] = _modeled_time_s(res)
        res["modeled_rps"] = n_requests / res["modeled_time_s"]
        res["measured_rps"] = n_requests / res["wall_s"]
        results.append(res)
        csv(f"scaleout,devices={d},requests={n_requests},"
            f"steps={res['steps']},"
            f"measured_rps={res['measured_rps']:.2f},"
            f"wall_s={res['wall_s']:.3f},"
            f"modeled_rps={res['modeled_rps']:.1f},"
            f"allgather_bytes={res['allgather_bytes']}")
        for r, p in enumerate(res["per_replica"]):
            csv(f"scaleout_device,devices={d},replica={r},"
                f"images={p['images']},dispatches={p['dispatches']},"
                f"dram_bytes={p['dram_bytes']},"
                f"throughput_rps={p['images'] / res['wall_s']:.2f}")
    base = results[0]
    peak = results[-1]
    modeled = peak["modeled_rps"] / base["modeled_rps"]
    measured = peak["measured_rps"] / base["measured_rps"]
    csv(f"scaleout_summary,devices_max={peak['devices']},"
        f"modeled_speedup={modeled:.2f},"
        f"measured_speedup={measured:.2f},"
        f"near_linear={'yes' if modeled >= 2.5 else 'no'},"
        f"cpu_count={os.cpu_count()}")
    return results


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scaleout", action="store_true",
                    help="run the multi-device scale-out sweep")
    ap.add_argument("--scaleout-worker", type=int, default=None,
                    metavar="DEVICES", help=argparse.SUPPRESS)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--img", type=int, default=16)
    ap.add_argument("--n-deform", type=int, default=2)
    ap.add_argument("--width-mult", type=float, default=0.125)
    ap.add_argument("--tile", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args(argv)
    if args.scaleout_worker:
        _scaleout_worker(args.scaleout_worker, args.requests, args.img,
                         args.n_deform, args.width_mult, args.tile,
                         args.slots)
    elif args.scaleout:
        run_scaleout(n_requests=args.requests, img=args.img,
                     n_deform=args.n_deform,
                     width_mult=args.width_mult, tile=args.tile,
                     slots=args.slots)
    else:
        run()


if __name__ == "__main__":
    main()
