"""Benchmark smoke driver: tiny configs -> ``BENCH_*.json`` artifacts.

Runs bench_scheduling, bench_fusion, bench_graph and bench_serving on
configurations small enough for a CPU CI worker (a couple of minutes
total) and writes one JSON file per benchmark so the CI can archive the
perf trajectory:

  PYTHONPATH=src python benchmarks/smoke.py --out bench-artifacts

Each file carries the emitted csv lines verbatim plus parsed key=value
fields, so downstream tooling can diff runs without re-parsing logs.
BENCH_graph.json additionally carries top-level ``dispatch_count`` /
``per_tile_dispatch_count`` / ``host_overlap_frac`` fields, and
BENCH_scheduling.json carries the host-vs-device scheduling-backend
numbers (``sched_host_s_per_img`` etc.). The run exits nonzero (failing
the CI bench-smoke job) if:

  * the batched dispatch count regresses to or above the per-tile
    baseline (ISSUE 3 gate);
  * the device scheduling backend is not bit-exact vs the host, or does
    not strictly reduce host scheduling time per image (ISSUE 4 gate);
  * batch-fused dispatch (batch=4) does not hit exactly ONE kernel
    dispatch per layer segment, or disagrees numerically with per-image
    batched dispatch on either scheduling backend (ISSUE 5 gate);
  * continuous-batching serving (slot pool >= 4) does not beat the
    serve-one-at-a-time baseline by >= 1.5x requests/sec on the
    open-loop arrival benchmark (ISSUE 6 gate — BENCH_serving.json
    carries the p50/p95/p99 latencies of both modes);
  * the serving telemetry is broken (ISSUE 7 gate): the exported
    Chrome-trace JSON fails schema validation, the ``serve.step`` span
    wall diverges more than 10% from the measured step wall, or the
    engine's ``metrics_snapshot()`` disagrees with ``stats`` — the
    trace / timeline / metrics snapshot are written as
    ``TELEMETRY_serving_*.json`` next to the bench artifacts;
  * the resilience chaos bench (ISSUE 8 gate) loses or duplicates a
    request, fails a request with an untyped error, deadlocks, lets
    healthy-request p99 exceed 1.5x the fault-free baseline, breaks
    the executor-trace == DRAM-simulator cross-check on a non-faulted
    step, or fails the isolation / backpressure scenario checks;
  * the multi-device scale-out sweep (ISSUE 9 gate) does not reach a
    near-linear >= 2.5x modeled requests/sec at 4 forced host devices
    over the single-device baseline — the speedup is the accelerator
    model applied to the MEASURED per-replica counters (DRAM bytes,
    SPMD dispatches, all-gather bytes) of the real sharded serving
    engine, so an unbalanced replica placement or a chatty collective
    fails the gate even though forced host devices share the CI
    worker's cores (wall-clock rps is reported, never gated);
  * the autotuner bench (ISSUE 10 gate) lets a tuned plan lose to the
    greedy baseline on ANY swept (net, img_size) case, shows no case
    with a >5% executed-DRAM reduction, breaks the executed-trace ==
    DRAM-simulator equality under a tuned plan, diverges numerically
    from the greedy run, or fails to serve the persisted plan from a
    FRESH plan cache over the same directory (second-run disk hit);
  * ``--compare BASELINE_DIR`` is given (previous main-branch
    ``BENCH_*.json`` artifacts) and scheduled DRAM tile loads or a
    dispatch count (batched per-image, batch-fused at batch>1, or
    serving dispatches/step) regress more than 10% against the
    baseline, or serving requests/sec or the serving schedule-cache
    image hit rate drops more than 10% below it (direction-aware:
    rps and hit rate are higher-is-better), or the chaos bench loses
    a request (fails on >0) or its healthy p99 ratio climbs high, or
    the tuned total DRAM bytes / tuned-vs-greedy max ratio (floor 1.0)
    / best rectangular-tile DRAM bytes regress against the baseline.

``--suite {all,core,resilience,scaleout,autotune}`` selects which
benches run: ``core`` is the perf suite above, ``resilience`` only the
chaos bench (its own CI leg), ``scaleout`` only the multi-device sweep
(the ``multidevice`` CI leg; the sweep spawns its own forced-device
subprocesses, so any host can run it), ``autotune`` the tile-shape
sweep + simulator-guided autotuner bench (its own CI leg), ``all``
(default) everything. Gates and ``--compare`` checks apply only to
suites that ran.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:          # allow `python benchmarks/smoke.py`
    sys.path.insert(0, _ROOT)

from benchmarks import (bench_autotune, bench_fusion, bench_graph,
                        bench_platforms, bench_resilience,
                        bench_scheduling, bench_serving,
                        bench_tile_size)

TINY_TDT = dict(h=16, w=16, c=16, tiles_per_side=4)


def _parse_fields(line: str) -> dict:
    fields = {}
    for part in line.split(","):
        if "=" in part:
            k, v = part.split("=", 1)
            fields[k] = v
    return fields


def _collect(name: str, steps) -> dict:
    lines: list[str] = []
    for fn, kwargs in steps:
        fn(csv=lines.append, **kwargs)
    for ln in lines:
        print(ln)
    return {
        "bench": name,
        "config": "smoke-tiny",
        "lines": lines,
        "records": [dict(label=ln.split(",", 1)[0], **_parse_fields(ln))
                    for ln in lines],
    }


def _record(payload: dict, label: str) -> dict | None:
    return next((r for r in payload["records"] if r["label"] == label),
                None)


def _compare_baseline(baseline_dir: str, suites: dict) -> int:
    """CI bench-regression gate: scheduled DRAM tile loads and the
    batched dispatch count must stay within 10% of the previous
    main-branch artifacts. A missing baseline (first run, expired
    artifact) is a warning, not a failure."""
    rc = 0
    # direction "lower": regression is new > base*1.10 (counts, loads);
    # direction "higher": regression is new < base*0.90 (requests/sec).
    # An optional 5th element is an absolute floor on the limit — used
    # for inherently noisy ratios so run-to-run jitter below the floor
    # can never flake the gate (requests_lost has no floor: baseline is
    # 0, so ANY lost request is limit-exceeding, i.e. fails on >0).
    checks = [
        ("BENCH_scheduling.json", "scheduled DRAM tile loads",
         lambda p: int(_record(p, "fig16_layer")["scheduled_loads"]),
         "lower"),
        ("BENCH_graph.json", "batched dispatch count",
         lambda p: int(p["dispatch_count"]), "lower"),
        ("BENCH_graph.json", "batch-fused dispatch count (batch>1)",
         lambda p: int(p["batch_fused_dispatch_count"]), "lower"),
        ("BENCH_serving.json", "serving requests/sec (batched)",
         lambda p: float(p["serving_batched_rps"]), "higher"),
        ("BENCH_serving.json", "serving dispatches per step",
         lambda p: float(p["serving_dispatches_per_step"]), "lower"),
        ("BENCH_serving.json", "serving image hit rate",
         lambda p: float(p["serving_image_hit_rate"]), "higher"),
        ("BENCH_resilience.json", "resilience requests lost",
         lambda p: int(p["resilience_requests_lost"]), "lower"),
        ("BENCH_resilience.json", "resilience healthy p99 ratio",
         lambda p: float(p["resilience_p99_ratio"]), "lower", 1.5),
        ("BENCH_platforms.json", "scale-out modeled speedup",
         lambda p: float(p["scaleout_modeled_speedup"]), "higher"),
        ("BENCH_platforms.json", "scale-out all-gather bytes",
         lambda p: int(p["scaleout_allgather_bytes"]), "lower"),
        ("BENCH_autotune.json", "tuned total DRAM bytes",
         lambda p: int(p["autotune_tuned_total_bytes"]), "lower"),
        # ratio can only flake by run-to-run search jitter; the floor
        # keeps anything <= 1.0 (never losing) from ever failing.
        ("BENCH_autotune.json", "tuned-vs-greedy max DRAM ratio",
         lambda p: float(p["autotune_max_ratio"]), "lower", 1.0),
        ("BENCH_tiles.json", "best rectangular-tile DRAM bytes",
         lambda p: int(p["tiles_best_dram_bytes"]), "lower"),
    ]
    for fname, what, extract, direction, *floor in checks:
        if fname not in suites:
            continue          # suite not run (--suite core/resilience)
        path = os.path.join(baseline_dir, fname)
        if not os.path.exists(path):
            print(f"WARNING: no baseline {path}; skipping {what} check")
            continue
        try:
            with open(path) as f:
                base = extract(json.load(f))
        except (KeyError, TypeError, ValueError) as e:
            print(f"WARNING: unreadable baseline {path} ({e}); skipping")
            continue
        try:
            new = extract(suites[fname])
        except (KeyError, TypeError, ValueError) as e:
            # Current payload incomplete (e.g. an earlier gate already
            # flagged a missing record): fail the gate, keep going so
            # the artifacts still get written.
            print(f"ERROR: current {fname} missing comparison field "
                  f"({e})")
            rc = 1
            continue
        if direction == "higher":
            limit = base * 0.90
            regressed = new < limit
        else:
            limit = base * 1.10
            if floor:
                limit = max(limit, floor[0])
            regressed = new > limit
        verdict = "REGRESSED" if regressed else "ok"
        print(f"bench-regression: {what} new={new} baseline={base} "
              f"(limit {limit:.1f}) -> {verdict}")
        if regressed:
            rc = 1
    return rc


def _gate_graph(suites: dict) -> int:
    """ISSUE 3 + 5 gates: the batched grid dispatch must stay strictly
    below the per-tile baseline, and at batch=4 the whole-batch fused
    path must issue exactly ONE kernel dispatch per layer segment,
    strictly below the per-image batched count."""
    if "BENCH_graph.json" not in suites:
        return 0
    rc = 0
    graph_payload = suites["BENCH_graph.json"]
    bench = _record(graph_payload, "dispatch_bench")
    if bench is None:
        print("ERROR: dispatch_bench record missing from bench_graph")
        rc = 1
    else:
        per_tile = int(bench["per_tile_dispatches"])
        batched = int(bench["batched_dispatches"])
        graph_payload["dispatch_count"] = batched
        graph_payload["per_tile_dispatch_count"] = per_tile
        graph_payload["host_overlap_frac"] = float(
            bench["host_overlap_frac"])
        if batched >= per_tile:
            print(f"ERROR: dispatch_count regressed: batched={batched} "
                  f">= per_tile baseline={per_tile}")
            rc = 1
        if bench["dispatches_le_segments"] != "yes":
            print("ERROR: batched dispatches exceed layer-segment bound")
            rc = 1

    bf = _record(graph_payload, "batch_fused_bench")
    if bf is None:
        print("ERROR: batch_fused_bench record missing from bench_graph")
        rc = 1
    else:
        bf_dispatches = int(bf["dispatches_per_batch"])
        graph_payload["batch_fused_dispatch_count"] = bf_dispatches
        graph_payload["batch_fused_dispatches_per_batch"] = bf_dispatches
        graph_payload["batch_fused_batch"] = int(bf["batch"])
        graph_payload["n_layer_segments"] = int(bf["n_segments"])
        if bf["one_dispatch_per_segment"] != "yes":
            print(f"ERROR: batch-fused dispatches ({bf_dispatches}) != "
                  f"one per layer segment ({bf['n_segments']}) at "
                  f"batch={bf['batch']}")
            rc = 1
        if bf_dispatches >= int(bf["batched_dispatches"]):
            print(f"ERROR: batch-fused dispatch count regressed: "
                  f"{bf_dispatches} >= per-image batched "
                  f"{bf['batched_dispatches']}")
            rc = 1
    return rc


def _gate_scheduling(suites: dict) -> int:
    """ISSUE 4 gate: the device scheduler must be bit-exact vs the host
    and strictly reduce the host-side scheduling time per image; the
    pipeline batch-fused records must match batched numerics at one
    dispatch per batch."""
    if "BENCH_scheduling.json" not in suites:
        return 0
    rc = 0
    sched_payload = suites["BENCH_scheduling.json"]
    backend = _record(sched_payload, "sched_backend")
    if backend is None:
        print("ERROR: sched_backend record missing from bench_scheduling")
        rc = 1
    else:
        sched_payload["sched_host_s_per_img"] = float(
            backend["host_sched_s_per_img"])
        sched_payload["sched_device_host_s_per_img"] = float(
            backend["device_host_s_per_img"])
        sched_payload["sched_device_kernel_s_per_img"] = float(
            backend["device_kernel_s_per_img"])
        sched_payload["sched_backend_match"] = backend["match"]
        sched_payload["sched_host_prepass_reduced"] = (
            backend["host_prepass_reduced"])
        if backend["match"] != "yes":
            print("ERROR: device schedule backend is not bit-exact vs host")
            rc = 1
        if backend["host_prepass_reduced"] != "yes":
            print("ERROR: schedule_backend='device' did not reduce host "
                  "scheduling time per image")
            rc = 1

    bf_sched = [r for r in sched_payload["records"]
                if r["label"] == "batch_fused"]
    if not bf_sched:
        print("ERROR: batch_fused records missing from bench_scheduling")
        rc = 1
    for r in bf_sched:
        sched_payload[f"batch_fused_{r['backend']}_dispatches"] = int(
            r["dispatches_per_batch"])
        sched_payload[f"batch_fused_{r['backend']}_residue_s"] = float(
            r["host_prepass_residue_s"])
        if r["match"] != "yes":
            print(f"ERROR: batch-fused != batched numerics "
                  f"(backend={r['backend']})")
            rc = 1
        if int(r["dispatches_per_batch"]) >= int(r["batched_dispatches"]):
            print(f"ERROR: pipeline batch-fused dispatches "
                  f"({r['dispatches_per_batch']}) not below per-image "
                  f"batched ({r['batched_dispatches']})")
            rc = 1
    return rc


def _gate_serving(suites: dict) -> int:
    """ISSUE 6 + 7 gates: continuous-batching serving must beat the
    sequential baseline >= 1.5x at slot pool >= 4, and the telemetry
    (Chrome trace schema, serve.step span wall, metrics snapshot vs
    stats) must hold together."""
    if "BENCH_serving.json" not in suites:
        return 0
    rc = 0
    serving_payload = suites["BENCH_serving.json"]
    sv = _record(serving_payload, "serving_bench")
    if sv is None:
        print("ERROR: serving_bench record missing from bench_serving")
        rc = 1
    else:
        speedup = float(sv["speedup"])
        serving_payload["serving_slots"] = int(sv["slots"])
        serving_payload["serving_speedup"] = speedup
        serving_payload["serving_batched_rps"] = float(sv["batched_rps"])
        serving_payload["serving_sequential_rps"] = float(sv["seq_rps"])
        for r in serving_payload["records"]:
            if r["label"] == "serving_latency":
                for q in ("p50_s", "p95_s", "p99_s"):
                    serving_payload[f"serving_{r['mode']}_{q}"] = float(
                        r[q])
        if sv["batched_beats_sequential"] != "yes":
            print("ERROR: batched serving does not beat sequential infer")
            rc = 1
        if int(sv["slots"]) >= 4 and speedup < 1.5:
            print(f"ERROR: serving speedup {speedup:.2f}x < 1.5x at "
                  f"slot pool {sv['slots']}")
            rc = 1

    tr_rec = _record(serving_payload, "serving_trace")
    if tr_rec is None:
        print("ERROR: serving_trace record missing from bench_serving")
        rc = 1
    else:
        frac = float(tr_rec["span_wall_frac"])
        serving_payload["serving_trace_events"] = int(tr_rec["events"])
        serving_payload["serving_span_wall_frac"] = frac
        if tr_rec["schema_ok"] != "yes":
            print("ERROR: serving Chrome-trace export failed schema "
                  "validation")
            rc = 1
        if not 0.90 <= frac <= 1.10:
            print(f"ERROR: serve.step span wall diverges from measured "
                  f"step wall: span_wall_frac={frac:.3f} outside "
                  f"[0.90, 1.10]")
            rc = 1
    mt_rec = _record(serving_payload, "serving_metrics")
    if mt_rec is None:
        print("ERROR: serving_metrics record missing from bench_serving")
        rc = 1
    else:
        serving_payload["serving_dispatches_per_step"] = float(
            mt_rec["dispatches_per_step"])
        serving_payload["serving_image_hit_rate"] = float(
            mt_rec["image_hit_rate"])
        serving_payload["serving_timeline_steps"] = int(
            mt_rec["timeline_steps"])
        if mt_rec["metrics_match_stats"] != "yes":
            print("ERROR: engine metrics_snapshot() disagrees with "
                  "engine stats")
            rc = 1
    return rc


def _gate_resilience(suites: dict) -> int:
    """ISSUE 8 gate: under the seeded chaos campaign the engine must
    lose/duplicate zero requests, fail every faulted request with a
    typed error, never deadlock, keep healthy-request p99 <= 1.5x the
    fault-free baseline, keep the executor-trace == DRAM-simulator
    cross-check exact on non-faulted steps, and pass the isolation and
    backpressure scenario checks."""
    if "BENCH_resilience.json" not in suites:
        return 0
    rc = 0
    payload = suites["BENCH_resilience.json"]
    rb = _record(payload, "resilience_bench")
    if rb is None:
        print("ERROR: resilience_bench record missing from "
              "bench_resilience")
        rc = 1
    else:
        lost = int(rb["requests_lost"])
        duplicated = int(rb["duplicated"])
        ratio = float(rb["healthy_p99_ratio"])
        payload["resilience_requests_lost"] = lost
        payload["resilience_duplicated"] = duplicated
        payload["resilience_p99_ratio"] = ratio
        payload["resilience_p99_base_s"] = float(rb["p99_base_s"])
        payload["resilience_p99_faulted_s"] = float(rb["p99_faulted_s"])
        if lost > 0:
            print(f"ERROR: chaos bench lost {lost} request(s)")
            rc = 1
        if duplicated > 0:
            print(f"ERROR: chaos bench resolved {duplicated} request(s) "
                  f"more than once")
            rc = 1
        if rb["typed_errors"] != "yes":
            print("ERROR: a faulted request failed with an untyped error "
                  "(not RequestFailedError)")
            rc = 1
        if rb["deadlocked"] != "no":
            print("ERROR: chaos bench deadlocked (drain exhausted its "
                  "step budget)")
            rc = 1
        if ratio > 1.5:
            print(f"ERROR: healthy-request p99 ratio {ratio:.3f} > 1.5x "
                  f"fault-free baseline")
            rc = 1
    rf = _record(payload, "resilience_faults")
    if rf is None:
        print("ERROR: resilience_faults record missing from "
              "bench_resilience")
        rc = 1
    else:
        payload["resilience_faults_fired"] = int(rf["total_fired"])
        payload["resilience_watchdog_failovers"] = int(
            rf["watchdog_failovers"])
        if int(rf["total_fired"]) == 0:
            print("ERROR: chaos campaign fired zero faults — the bench "
                  "gated nothing")
            rc = 1
    re_rec = _record(payload, "resilience_engine")
    if re_rec is None:
        print("ERROR: resilience_engine record missing from "
              "bench_resilience")
        rc = 1
    else:
        payload["resilience_trace_checked"] = int(re_rec["trace_checked"])
        if re_rec["trace_exact"] != "yes":
            print("ERROR: executor trace != DRAM simulator on a "
                  "non-faulted chaos step")
            rc = 1
        if int(re_rec["trace_checked"]) == 0:
            print("ERROR: chaos run cross-checked zero traces")
            rc = 1
        if re_rec["isolation_ok"] != "yes":
            print("ERROR: tagged fault was not isolated to the offending "
                  "request (step-mates lost or inexact)")
            rc = 1
        if re_rec["backpressure_ok"] != "yes":
            print("ERROR: backpressure/deadline scenario failed "
                  "(shed/expired requests not accounted exactly once)")
            rc = 1
    return rc


def _gate_scaleout(suites: dict) -> int:
    """ISSUE 9 gate: the sharded serving engine must scale near-
    linearly — >= 2.5x modeled requests/sec at 4 forced host devices
    over single-device, with the speedup computed from the MEASURED
    per-replica counters (slowest-replica DRAM + dispatch time plus the
    logits all-gather), so unbalanced replica placement or collective
    bloat fails here even on a one-core worker."""
    if "BENCH_platforms.json" not in suites:
        return 0
    rc = 0
    payload = suites["BENCH_platforms.json"]
    summary = _record(payload, "scaleout_summary")
    if summary is None:
        print("ERROR: scaleout_summary record missing from "
              "bench_platforms")
        return 1
    modeled = float(summary["modeled_speedup"])
    devices_max = int(summary["devices_max"])
    payload["scaleout_devices_max"] = devices_max
    payload["scaleout_modeled_speedup"] = modeled
    payload["scaleout_measured_speedup"] = float(
        summary["measured_speedup"])
    points = [r for r in payload["records"] if r["label"] == "scaleout"]
    peak = next((r for r in points
                 if int(r["devices"]) == devices_max), None)
    payload["scaleout_allgather_bytes"] = (
        int(peak["allgather_bytes"]) if peak else 0)
    imgs = [int(r["images"]) for r in payload["records"]
            if (r["label"] == "scaleout_device"
                and int(r["devices"]) == devices_max)]
    if devices_max >= 4 and modeled < 2.5:
        print(f"ERROR: scale-out modeled speedup {modeled:.2f}x < 2.5x "
              f"at {devices_max} devices")
        rc = 1
    if imgs and max(imgs) - min(imgs) > 1:
        print(f"ERROR: replica placement unbalanced at "
              f"{devices_max} devices: per-replica images {imgs}")
        rc = 1
    if summary["near_linear"] != ("yes" if modeled >= 2.5 else "no"):
        print("ERROR: scaleout_summary near_linear flag disagrees with "
              "its own modeled_speedup")
        rc = 1
    return rc


def _gate_autotune(suites: dict) -> int:
    """ISSUE 10 gate: simulator-guided tuned plans must never lose to
    the greedy baseline on executed DRAM traffic for any swept
    (net, img_size) case, at least one case must show a >5% reduction,
    tuned executed traces must stay EXACTLY equal to the DRAM
    simulator, tuned numerics must match greedy, and the persisted plan
    must hit from a FRESH plan cache on the second run."""
    rc = 0
    if "BENCH_autotune.json" in suites:
        payload = suites["BENCH_autotune.json"]
        summary = _record(payload, "autotune_summary")
        if summary is None:
            print("ERROR: autotune_summary record missing from "
                  "bench_autotune")
            rc = 1
        else:
            max_ratio = float(summary["max_ratio"])
            min_ratio = float(summary["min_ratio"])
            payload["autotune_max_ratio"] = max_ratio
            payload["autotune_min_ratio"] = min_ratio
            payload["autotune_tuned_total_bytes"] = int(
                summary["tuned_total_bytes"])
            payload["autotune_greedy_total_bytes"] = int(
                summary["greedy_total_bytes"])
            payload["autotune_search_s_total"] = float(
                summary["search_s_total"])
            if max_ratio > 1.0:
                print(f"ERROR: tuned plan LOSES to greedy on a swept "
                      f"case: max tuned/greedy DRAM ratio "
                      f"{max_ratio:.4f} > 1.0")
                rc = 1
            if min_ratio >= 0.95:
                print(f"ERROR: no swept case shows a >5% tuned DRAM "
                      f"reduction (best ratio {min_ratio:.4f})")
                rc = 1
            if summary["plan_cache_hit_on_second_run"] != "yes":
                print("ERROR: persisted plan missed from a fresh plan "
                      "cache on the second run")
                rc = 1
            if summary["all_trace_exact"] != "yes":
                print("ERROR: tuned executed trace != DRAM simulator")
                rc = 1
            if summary["all_numerics_ok"] != "yes":
                print("ERROR: tuned run diverges numerically from the "
                      "greedy run")
                rc = 1
    if "BENCH_tiles.json" in suites:
        payload = suites["BENCH_tiles.json"]
        best = _record(payload, "rect_best")
        if best is None:
            print("ERROR: rect_best record missing from bench_tile_size")
            rc = 1
        else:
            payload["tiles_best_dram_bytes"] = int(best["dram_bytes"])
            payload["tiles_best_tile"] = (f"{best['tile_h']}x"
                                          f"{best['tile_w']}")
            payload["tiles_spread"] = float(best["spread"])
    return rc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=".", help="output directory")
    ap.add_argument("--compare", default=None, metavar="BASELINE_DIR",
                    help="directory of previous-main BENCH_*.json "
                         "artifacts; fail on >10%% regression of "
                         "scheduled loads / dispatch count")
    ap.add_argument("--suite", default="all",
                    choices=("all", "core", "resilience", "scaleout",
                             "autotune"),
                    help="which bench suites to run (default: all)")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    suites = {}
    if args.suite in ("all", "core"):
        suites = {
            "BENCH_scheduling.json": _collect("scheduling", [
                (bench_scheduling.run, dict(tdt_kwargs=TINY_TDT,
                                            channels=16, c_out=16,
                                            buffer_bytes=4096)),
                (bench_scheduling.run_executor, dict(h=16, w=16, c=8,
                                                     c_out=8, tile=8,
                                                     buffer_tiles=2)),
                (bench_scheduling.run_backends, dict(h=16, w=16, c=8,
                                                     c_out=8, tile=8,
                                                     buffer_tiles=2,
                                                     repeats=3)),
                (bench_scheduling.run_batch_fused, dict(h=16, w=16, c=8,
                                                        c_out=8, tile=8,
                                                        buffer_tiles=2,
                                                        batch=4,
                                                        repeats=2)),
            ]),
            "BENCH_fusion.json": _collect("fusion", [
                (bench_fusion.run, dict(tdt_kwargs=TINY_TDT, channels=16,
                                        c_out=16)),
                (bench_fusion.run_executor, dict(h=16, w=16, c=8, c_out=8,
                                                 tile=8)),
            ]),
            "BENCH_graph.json": _collect("graph", [
                (bench_graph.run, dict(img=13, n_deform=2,
                                       width_mult=0.125, tile=4)),
                (bench_graph.run_dispatch, dict(img=13, n_deform=2,
                                                width_mult=0.125, tile=4,
                                                batch=4, repeats=2)),
                (bench_graph.run_model_backend, dict(img=16, n_deform=2,
                                                     width_mult=0.125,
                                                     tile=4)),
            ]),
            "BENCH_serving.json": _collect("serving", [
                (bench_serving.run, dict(
                    img=13, n_deform=2, width_mult=0.125, tile=4, slots=8,
                    n_requests=16,
                    trace_out=os.path.join(
                        args.out, "TELEMETRY_serving_trace.json"),
                    timeline_out=os.path.join(
                        args.out, "TELEMETRY_serving_timeline.json"),
                    metrics_out=os.path.join(
                        args.out, "TELEMETRY_serving_metrics.json"))),
            ]),
        }
    if args.suite in ("all", "resilience"):
        suites["BENCH_resilience.json"] = _collect("resilience", [
            (bench_resilience.run, dict(img=13, n_deform=2,
                                        width_mult=0.125, tile=4,
                                        slots=4, n_requests=24,
                                        fault_rate=0.1, seed=0)),
        ])
    if args.suite in ("all", "scaleout"):
        suites["BENCH_platforms.json"] = _collect("platforms", [
            (bench_platforms.run, {}),
            (bench_platforms.run_scaleout, dict(
                device_counts=(1, 2, 4), n_requests=12, img=16,
                n_deform=2, width_mult=0.125, tile=4, slots=4)),
        ])
    if args.suite in ("all", "autotune"):
        suites["BENCH_tiles.json"] = _collect("tiles", [
            (bench_tile_size.run, dict(h=16, w=16, c=16,
                                       tiles_per_side=(2, 4, 8),
                                       buffer_bytes=4096)),
            # rect config picked so the best shape is an INTERIOR point
            # (8x8, spread ~2x) — the sweep demonstrates a real search
            # space, not a degenerate whole-plane winner.
            (bench_tile_size.run_rect, dict(h=24, w=24, c=24,
                                            sides=(2, 4, 8, 16),
                                            buffer_bytes=2048)),
        ])
        suites["BENCH_autotune.json"] = _collect("autotune", [
            (bench_autotune.run, dict(
                cache_dir=os.path.join(args.out, "plan-cache"))),
        ])

    # Gates apply only to suites that ran (--suite). The CI bench-smoke
    # job fails on the nonzero exit.
    rc = 0
    rc = max(rc, _gate_graph(suites))
    rc = max(rc, _gate_scheduling(suites))
    rc = max(rc, _gate_serving(suites))
    rc = max(rc, _gate_resilience(suites))
    rc = max(rc, _gate_scaleout(suites))
    rc = max(rc, _gate_autotune(suites))

    if args.compare:
        rc = max(rc, _compare_baseline(args.compare, suites))

    meta = {"python": platform.python_version(),
            "platform": platform.platform()}
    for fname, payload in suites.items():
        payload["meta"] = meta
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {path}")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
