"""Benchmark smoke driver: tiny configs -> ``BENCH_*.json`` artifacts.

Runs bench_scheduling, bench_fusion and bench_graph on configurations
small enough for a CPU CI worker (a couple of minutes total) and writes
one JSON file per benchmark so the CI can archive the perf trajectory:

  PYTHONPATH=src python benchmarks/smoke.py --out bench-artifacts

Each file carries the emitted csv lines verbatim plus parsed key=value
fields, so downstream tooling can diff runs without re-parsing logs.
BENCH_graph.json additionally carries top-level ``dispatch_count`` /
``per_tile_dispatch_count`` / ``host_overlap_frac`` fields, and the run
exits nonzero (failing the CI bench-smoke job) if the batched dispatch
count regresses to or above the per-tile baseline.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:          # allow `python benchmarks/smoke.py`
    sys.path.insert(0, _ROOT)

from benchmarks import bench_fusion, bench_graph, bench_scheduling  # noqa: E402

TINY_TDT = dict(h=16, w=16, c=16, tiles_per_side=4)


def _parse_fields(line: str) -> dict:
    fields = {}
    for part in line.split(","):
        if "=" in part:
            k, v = part.split("=", 1)
            fields[k] = v
    return fields


def _collect(name: str, steps) -> dict:
    lines: list[str] = []
    for fn, kwargs in steps:
        fn(csv=lines.append, **kwargs)
    for ln in lines:
        print(ln)
    return {
        "bench": name,
        "config": "smoke-tiny",
        "lines": lines,
        "records": [dict(label=ln.split(",", 1)[0], **_parse_fields(ln))
                    for ln in lines],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=".", help="output directory")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    suites = {
        "BENCH_scheduling.json": _collect("scheduling", [
            (bench_scheduling.run, dict(tdt_kwargs=TINY_TDT, channels=16,
                                        c_out=16, buffer_bytes=4096)),
            (bench_scheduling.run_executor, dict(h=16, w=16, c=8, c_out=8,
                                                 tile=8, buffer_tiles=2)),
        ]),
        "BENCH_fusion.json": _collect("fusion", [
            (bench_fusion.run, dict(tdt_kwargs=TINY_TDT, channels=16,
                                    c_out=16)),
            (bench_fusion.run_executor, dict(h=16, w=16, c=8, c_out=8,
                                             tile=8)),
        ]),
        "BENCH_graph.json": _collect("graph", [
            (bench_graph.run, dict(img=13, n_deform=2, width_mult=0.125,
                                   tile=4)),
            (bench_graph.run_dispatch, dict(img=13, n_deform=2,
                                            width_mult=0.125, tile=4,
                                            batch=2, repeats=2)),
            (bench_graph.run_model_backend, dict(img=16, n_deform=2,
                                                 width_mult=0.125, tile=4)),
        ]),
    }

    # Dispatch-count regression gate: the batched grid dispatch must stay
    # strictly below the per-tile baseline (ISSUE 3 acceptance). The CI
    # bench-smoke job fails on the nonzero exit.
    rc = 0
    graph_payload = suites["BENCH_graph.json"]
    bench = next((r for r in graph_payload["records"]
                  if r["label"] == "dispatch_bench"), None)
    if bench is None:
        print("ERROR: dispatch_bench record missing from bench_graph")
        rc = 1
    else:
        per_tile = int(bench["per_tile_dispatches"])
        batched = int(bench["batched_dispatches"])
        graph_payload["dispatch_count"] = batched
        graph_payload["per_tile_dispatch_count"] = per_tile
        graph_payload["host_overlap_frac"] = float(
            bench["host_overlap_frac"])
        if batched >= per_tile:
            print(f"ERROR: dispatch_count regressed: batched={batched} "
                  f">= per_tile baseline={per_tile}")
            rc = 1
        if bench["dispatches_le_segments"] != "yes":
            print("ERROR: batched dispatches exceed layer-segment bound")
            rc = 1

    meta = {"python": platform.python_version(),
            "platform": platform.platform()}
    for fname, payload in suites.items():
        payload["meta"] = meta
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {path}")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
