"""Benchmark smoke driver: tiny configs -> ``BENCH_*.json`` artifacts.

Runs bench_scheduling, bench_fusion and bench_graph on configurations
small enough for a CPU CI worker (a couple of minutes total) and writes
one JSON file per benchmark so the CI can archive the perf trajectory:

  PYTHONPATH=src python benchmarks/smoke.py --out bench-artifacts

Each file carries the emitted csv lines verbatim plus parsed key=value
fields, so downstream tooling can diff runs without re-parsing logs.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:          # allow `python benchmarks/smoke.py`
    sys.path.insert(0, _ROOT)

from benchmarks import bench_fusion, bench_graph, bench_scheduling  # noqa: E402

TINY_TDT = dict(h=16, w=16, c=16, tiles_per_side=4)


def _parse_fields(line: str) -> dict:
    fields = {}
    for part in line.split(","):
        if "=" in part:
            k, v = part.split("=", 1)
            fields[k] = v
    return fields


def _collect(name: str, steps) -> dict:
    lines: list[str] = []
    for fn, kwargs in steps:
        fn(csv=lines.append, **kwargs)
    for ln in lines:
        print(ln)
    return {
        "bench": name,
        "config": "smoke-tiny",
        "lines": lines,
        "records": [dict(label=ln.split(",", 1)[0], **_parse_fields(ln))
                    for ln in lines],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=".", help="output directory")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    suites = {
        "BENCH_scheduling.json": _collect("scheduling", [
            (bench_scheduling.run, dict(tdt_kwargs=TINY_TDT, channels=16,
                                        c_out=16, buffer_bytes=4096)),
            (bench_scheduling.run_executor, dict(h=16, w=16, c=8, c_out=8,
                                                 tile=8, buffer_tiles=2)),
        ]),
        "BENCH_fusion.json": _collect("fusion", [
            (bench_fusion.run, dict(tdt_kwargs=TINY_TDT, channels=16,
                                    c_out=16)),
            (bench_fusion.run_executor, dict(h=16, w=16, c=8, c_out=8,
                                             tile=8)),
        ]),
        "BENCH_graph.json": _collect("graph", [
            (bench_graph.run, dict(img=13, n_deform=2, width_mult=0.125,
                                   tile=4)),
            (bench_graph.run_model_backend, dict(img=16, n_deform=2,
                                                 width_mult=0.125, tile=4)),
        ]),
    }

    meta = {"python": platform.python_version(),
            "platform": platform.platform()}
    for fname, payload in suites.items():
        payload["meta"] = meta
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
