"""Benchmark driver: one harness per paper table/figure + roofline.

``PYTHONPATH=src python -m benchmarks.run`` prints name,value CSV rows for
  Figs 11-12  platform performance/energy comparison (bench_platforms)
  Figs 14-16  tile-scheduling ablation               (bench_scheduling)
  Fig  17     tile-size sweep                        (bench_tile_size)
  Fig  18     BLI(+)conv fusion                      (bench_fusion)
  kernels     microbench + allclose gates            (bench_kernels)
  roofline    3-term per (arch x shape) table        (roofline; reads
              benchmarks/artifacts/dryrun — run launch.dryrun first)
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_access_pattern, bench_fusion,
                            bench_kernels, bench_platforms,
                            bench_scheduling, bench_tile_size, roofline)

    sections = [
        ("access_pattern(fig3)", bench_access_pattern.run),
        ("platforms(fig11-12)", bench_platforms.run),
        ("scheduling(fig14-16)", bench_scheduling.run),
        ("tile_size(fig17)", bench_tile_size.run),
        ("fusion(fig18)", bench_fusion.run),
        ("kernels", bench_kernels.run),
        ("roofline", roofline.run),
    ]
    failures = 0
    for name, fn in sections:
        print(f"### {name}")
        t0 = time.time()
        try:
            fn()
            print(f"### {name} done in {time.time()-t0:.1f}s\n")
        except Exception as e:  # keep the suite running
            failures += 1
            print(f"### {name} FAILED: {type(e).__name__}: {e}\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
