"""Paper Fig. 17: DRAM energy vs tile size (VGG19/SegNet-F).

Smaller tiles -> finer dependency tracking -> fewer wasted bytes per load;
the paper finds the smallest tile size wins. We sweep the same 5x5..2x2
range over measured TDTs and report normalized DRAM energy.
"""

from __future__ import annotations

from repro.core.simulator import dram_energy, simulate_strategies

from benchmarks.workloads import measured_tdt

BUF_BYTES = 128 * 1024


def run(csv=print):
    results = {}
    for tiles_per_side in (2, 3, 4, 5, 7, 8):
        B, pp, grid = measured_tdt(tiles_per_side=tiles_per_side)
        rep = simulate_strategies(B, pp, grid, channels=256, c_out=256,
                                  kernel_size=3,
                                  buffer_bytes=BUF_BYTES)["scheduled"]
        e = dram_energy(rep, exec_time_s=1e-3)
        results[tiles_per_side] = (rep.total_dram_bytes, e)
    e_max = max(e for _, e in results.values())
    for tps, (bytes_, e) in sorted(results.items()):
        side = 56 // tps
        csv(f"fig17_tile_size,tile={side}x{side},dram_bytes={bytes_},"
            f"energy_rel={e/e_max:.3f}")
    # paper: smallest tile size -> least DRAM energy
    sizes = sorted(results)
    assert results[sizes[-1]][1] <= results[sizes[0]][1], \
        "finer tiles should not cost more DRAM energy"
    return results


if __name__ == "__main__":
    run()
