"""Paper Fig. 17: DRAM energy vs tile size — square AND rectangular.

Smaller tiles -> finer dependency tracking -> fewer wasted bytes per
load; the paper finds the smallest tile size wins. ``run`` sweeps the
paper's square 2x2..8x8 range over measured TDTs; ``run_rect`` extends
the sweep to rectangular ``(tile_h, tile_w)`` shapes — the exact design
space the autotuner (``repro.tuning``) searches per fused group. The
FIFO capacity is derived from a fixed byte budget, so every shape
competes on iso on-chip hardware. Both emit machine-readable records
through ``smoke.py`` (``BENCH_tiles.json``) so ``--compare`` tracks
tile-sensitivity regressions.
"""

from __future__ import annotations

from repro.core.simulator import dram_energy, simulate_strategies
from repro.core.tiles import TileGrid, per_pixel_input_tiles, \
    tdt_from_coords

from benchmarks.workloads import measured_coords, measured_tdt

BUF_BYTES = 128 * 1024


def run(csv=print, h: int = 56, w: int = 56, c: int = 256,
        tiles_per_side=(2, 3, 4, 5, 7, 8), seed: int = 0,
        offset_scale: float = 6.0, buffer_bytes: int = BUF_BYTES):
    """Square Fig. 17 sweep (paper reproduction + monotonicity check)."""
    results = {}
    for tps in tiles_per_side:
        B, pp, grid = measured_tdt(h, w, c, tps, seed, offset_scale)
        rep = simulate_strategies(B, pp, grid, channels=c, c_out=c,
                                  kernel_size=3,
                                  buffer_bytes=buffer_bytes)["scheduled"]
        e = dram_energy(rep, exec_time_s=1e-3)
        results[tps] = (rep.total_dram_bytes, e)
    e_max = max(e for _, e in results.values())
    for tps, (bytes_, e) in sorted(results.items()):
        side = h // tps
        csv(f"fig17_tile_size,tile={side}x{side},dram_bytes={bytes_},"
            f"energy_rel={e / e_max:.3f}")
    # paper: smallest tile size -> least DRAM energy
    sizes = sorted(results)
    assert results[sizes[-1]][1] <= results[sizes[0]][1], \
        "finer tiles should not cost more DRAM energy"
    return results


def run_rect(csv=print, h: int = 56, w: int = 56, c: int = 256,
             sides=(2, 4, 8, 16), seed: int = 0,
             offset_scale: float = 6.0,
             buffer_bytes: int = BUF_BYTES):
    """Rectangular ``(tile_h, tile_w)`` sweep over the same measured
    coords: one TDT per grid, scheduled DRAM bytes per shape, plus the
    best shape (what the autotuner should find for this layer)."""
    coords = measured_coords(h, w, c, seed, offset_scale)
    results = {}
    for th in sides:
        for tw in sides:
            if th > h or tw > w:
                continue
            grid = TileGrid(h, w, th, tw)
            B = tdt_from_coords(coords, grid, grid)
            pp = per_pixel_input_tiles(coords, grid)
            rep = simulate_strategies(
                B, pp, grid, channels=c, c_out=c, kernel_size=3,
                buffer_bytes=buffer_bytes)["scheduled"]
            results[(th, tw)] = rep.total_dram_bytes
    for (th, tw), bytes_ in sorted(results.items()):
        csv(f"fig17_rect,tile_h={th},tile_w={tw},dram_bytes={bytes_}")
    (bth, btw), best = min(results.items(), key=lambda kv: kv[1])
    worst = max(results.values())
    csv(f"rect_best,tile_h={bth},tile_w={btw},dram_bytes={best},"
        f"worst_dram_bytes={worst},"
        f"spread={worst / best if best else 0.0:.3f}")
    return results


if __name__ == "__main__":
    run()
    run_rect()
