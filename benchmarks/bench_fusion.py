"""Paper Fig. 18: BLI (+) conv fusion effect on energy.

Fusion keeps the deformed-feature intermediate (K*K x input) on-chip.
We compute DRAM traffic with/without fusion over the measured TDTs for
each network config and report the energy reduction; the paper's headline
— >20% on */-F with DCN-II — is printed against ours. The fusion planner
(repro.core.fusion) additionally reports the per-layer VMEM working sets
that make the fusion legal on the paper's 128KB+256KB buffers.
"""

from __future__ import annotations

from repro.core.fusion import LayerShape, fused_tile_bytes, plan_fusion
from repro.core.simulator import dram_energy, simulate_strategies
from repro.models.dcn_models import DcnNetConfig, layer_shapes
from repro.runtime import dcn_pipeline

from benchmarks.workloads import (NETWORKS, executor_case, measured_tdt,
                                  net_label)

BUF_BYTES = 128 * 1024
ONCHIP_BUDGET = (128 + 256) * 1024  # input + output buffers, Table I


def run(csv=print, tdt_kwargs: dict | None = None, channels: int = 256,
        c_out: int = 256):
    """``tdt_kwargs`` forwards to ``measured_tdt`` (smoke runs shrink it)."""
    B, pp, grid = measured_tdt(**(tdt_kwargs or {}))
    for name, nd in NETWORKS:
        kw = dict(in_grid=grid, channels=channels, c_out=c_out, kernel_size=3,
                  buffer_bytes=BUF_BYTES)
        fused = simulate_strategies(B, pp, fused=True, **kw)["scheduled"]
        staged = simulate_strategies(B, pp, fused=False, **kw)["scheduled"]
        w = {3: 0.12, 8: 0.45, -1: 1.0}[nd]
        e_f = dram_energy(fused, 1e-3)
        e_s = dram_energy(staged, 1e-3)
        # blend: only the deformable fraction of the network fuses
        red = w * (1 - e_f / e_s)
        csv(f"fig18_fusion,{net_label(name, nd)},"
            f"energy_reduction={100*red:.1f}%"
            + (",paper=>20%" if nd < 0 else ""))

    # fusion-planner legality on the paper's buffer budget
    cfg = DcnNetConfig(name="vgg19", n_deform=-1, img_size=224)
    plans = [plan_fusion(s, ONCHIP_BUDGET) for s in layer_shapes(cfg)]
    n_fused = sum(p.mode.value == "fused" for p in plans)
    csv(f"fig18_planner,vgg19-F,layers_fused={n_fused}/{len(plans)},"
        f"max_vmem_bytes={max(p.vmem_bytes for p in plans)}")
    return plans


def run_executor(csv=print, h: int = 16, w: int = 16, c: int = 16,
                 c_out: int = 16, tile: int = 8, seed: int = 0):
    """Measured vs modeled fused working set.

    The fusion planner models the VMEM footprint of one fused tile
    (``fused_tile_bytes``); the executor's trace records the packed input
    buffer it actually shipped to the kernel. The measured packed-input
    bytes are checked against the planner's *input-halo component* (the
    term that models exactly that buffer) — a packing blow-up trips the
    check even though the full fused envelope would hide it — and the
    total envelope is reported alongside.
    """
    params, x = executor_case(h, w, c, c_out, seed)
    _, trace = dcn_pipeline(x, params, tile=tile, return_trace=True)

    dtype_bytes = x.dtype.itemsize
    shape = LayerShape(h=h, w=w, c_in=c, c_out=c_out, kernel_size=3,
                       dtype_bytes=dtype_bytes)
    modeled_total = fused_tile_bytes(shape, tile * tile)
    # The planner's input-halo term (fusion.fused_tile_bytes, halo=2):
    # the component that models the packed input buffer specifically.
    modeled_input = (3 * tile) ** 2 * c * dtype_bytes
    measured = trace.images[0].max_buffer_bytes
    csv(f"fusion_xcheck,measured_packed_input_bytes={measured},"
        f"modeled_input_halo_bytes={modeled_input},"
        f"modeled_fused_tile_bytes={modeled_total},"
        f"within_input_halo={'yes' if measured <= modeled_input else 'NO'}")
    return measured, modeled_input, modeled_total


if __name__ == "__main__":
    run()
    run_executor()
