"""Render the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run artifacts (single source of truth). §Perf prose is hand-written in
EXPERIMENTS.md; this script prints markdown to splice in.

  PYTHONPATH=src python -m benchmarks.render_experiments
"""

from __future__ import annotations

import json
import os

from repro import configs
from benchmarks.roofline import ART_DIR, load_cell, model_flops, roofline_row


def fmt_bytes(b):
    return f"{b/1e9:.2f}"


def dryrun_table():
    print("| arch | shape | mesh | compile s | args GB/dev | temp GB/dev "
          "| HLO flops/dev | coll GB/dev |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in configs.ARCHS:
        for shape in configs.SHAPES:
            for mesh in ("pod1", "pod2"):
                r = load_cell(arch, shape, mesh)
                if r is None:
                    continue
                if r["status"] == "skipped":
                    if mesh == "pod1":
                        print(f"| {arch} | {shape} | both | — | — | — | "
                              f"skip: sub-quadratic required | — |")
                    continue
                a = r.get("analysis", {})
                print(f"| {arch} | {shape} | {mesh} | {r['compile_s']} | "
                      f"{fmt_bytes(r['memory']['argument_bytes'])} | "
                      f"{fmt_bytes(r['memory']['temp_bytes'])} | "
                      f"{a.get('flops', 0):.3g} | "
                      f"{a.get('collective_bytes', 0)/1e9:.2f} |")


def roofline_table(mesh="pod1"):
    print("| arch | shape | compute ms | memory ms | collective ms | "
          "dominant | MODEL_FLOPS | useful ratio | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in configs.ARCHS:
        for shape in configs.SHAPES:
            r = load_cell(arch, shape, mesh)
            if r is None or r.get("status") != "ok":
                continue
            row = roofline_row(r)
            if row is None:
                continue
            print(f"| {arch} | {shape} | {row['compute_s']*1e3:.1f} | "
                  f"{row['memory_s']*1e3:.0f} | "
                  f"{row['collective_s']*1e3:.1f} | {row['dominant']} | "
                  f"{row['model_flops']:.3g} | {row['useful_ratio']:.2f} | "
                  f"{row['roofline_frac']:.4f} |")


def variant_table(arch, shape, mesh, variants):
    print(f"| variant | flops/dev | traffic GB/dev | coll GB/dev | "
          f"temp GB/dev | dominant term s | TPU-proj bound s | "
          f"proj roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    rows = [("baseline", load_cell(arch, shape, mesh))]
    for v in variants:
        path = os.path.join(ART_DIR, f"{arch}__{shape}__{mesh}__{v}.json")
        if os.path.exists(path):
            with open(path) as f:
                rows.append((v, json.load(f)))
    for name, r in rows:
        if r is None or r.get("status") != "ok":
            print(f"| {name} | FAILED | | | | | | |")
            continue
        a = r["analysis"]
        t_c = a["flops"] / 197e12
        t_m = a["traffic_bytes"] / 819e9
        t_x = a["collective_bytes"] / 50e9
        row = roofline_row(r)
        proj = max(t_c, row["memory_proj_s"], t_x) if row else 0
        pf = row["roofline_frac_proj"] if row else 0
        print(f"| {name} | {a['flops']:.3g} | "
              f"{a['traffic_bytes']/1e9:.1f} | "
              f"{a['collective_bytes']/1e9:.2f} | "
              f"{r['memory']['temp_bytes']/1e9:.1f} | "
              f"{max(t_c, t_m, t_x):.2f} | {proj:.2f} | {pf:.4f} |")


if __name__ == "__main__":
    print("## §Dry-run\n")
    dryrun_table()
    print("\n## §Roofline (single-pod 16x16 = 256 chips)\n")
    roofline_table()
    print("\n## §Perf variants\n")
    for arch, shape, variants in [
        ("musicgen-medium", "train_4k",
         ["fsdp", "flashlike", "fsdp,flashlike"]),
        ("deepseek-v3-671b", "train_4k",
         ["remat_full", "flashlike", "flashlike,cap1", "remat_full,cap1"]),
        ("jamba-v0.1-52b", "decode_32k", ["serve_tp"]),
    ]:
        print(f"\n### {arch} / {shape}\n")
        variant_table(arch, shape, "pod1", variants)
