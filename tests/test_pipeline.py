"""Oracle tests for the tile-pipeline executor (repro.runtime).

The executor — stage-1 offsets -> TDT -> Algorithm-1 schedule -> packed
tiles -> fused Pallas kernel (interpret mode on CPU) -> scatter — must be
numerically indistinguishable from the XLA reference
``core.deform.deformable_conv2d`` on real batches, including shapes that
do not divide by the tile size, and its execution trace must agree with
the DRAM-traffic simulator run on the same coordinates.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.deform import (conv2d, deformable_conv2d,
                               init_deformable_conv, offsets_to_coords,
                               randomize_offset_conv)
from repro.core.simulator import simulate_strategies
from repro.core.tiles import TileGrid, per_pixel_input_tiles, tdt_from_coords
from repro.models.dcn_models import DcnNetConfig, dcn_net_apply, init_dcn_net
from repro.runtime import PipelineConfig, dcn_pipeline


def _layer(key, c_in, c_out, variant="dcn2", offset_scale=0.5,
           dtype=jnp.float32):
    """Deformable-conv params with a *non-zero* offset conv (real
    deformation, unlike the zero init) in the requested dtype."""
    params = init_deformable_conv(key, c_in, c_out, 3, variant, dtype)
    return randomize_offset_conv(params, jax.random.fold_in(key, 1),
                                 offset_scale)


class TestPipelineOracle:
    @pytest.mark.parametrize("h,w,tile,variant,dtype", [
        (16, 16, 8, "dcn2", jnp.float32),    # divisible, 2x2 grid
        (16, 16, 4, "dcn2", jnp.float32),    # smaller tiles, 4x4 grid
        (13, 13, 8, "dcn1", jnp.float32),    # non-divisible (edge tiles)
        (13, 13, 8, "dcn2", jnp.float32),    # non-divisible, dcn2
        (12, 10, 4, "dcn2", jnp.float32),    # rectangular plane
        (16, 16, 8, "dcn1", jnp.float32),    # dcn1 variant
        (16, 16, 16, "dcn2", jnp.float32),   # single tile == whole plane
        (16, 16, 8, "dcn2", jnp.bfloat16),   # bf16 features
        (13, 13, 8, "dcn2", jnp.bfloat16),   # bf16 + non-divisible
    ])
    def test_matches_xla_reference(self, h, w, tile, variant, dtype):
        key = jax.random.PRNGKey(h * 31 + w * 7 + tile)
        c_in, c_out = 6, 10
        params = _layer(key, c_in, c_out, variant, dtype=dtype)
        x = jax.random.normal(jax.random.fold_in(key, 2), (2, h, w, c_in),
                              dtype)
        y_ref = deformable_conv2d(x, params, variant=variant)
        y_pipe = dcn_pipeline(x, params, variant=variant, tile=tile,
                              interpret=True)
        assert y_pipe.shape == y_ref.shape == (2, h, w, c_out)
        assert y_pipe.dtype == x.dtype
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(y_pipe, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   rtol=tol, atol=tol)

    def test_sequential_schedule_same_result(self):
        """Tile execution order must not change the numerics."""
        key = jax.random.PRNGKey(42)
        params = _layer(key, 4, 8)
        x = jax.random.normal(jax.random.fold_in(key, 2), (1, 13, 13, 4))
        y_alg1 = dcn_pipeline(x, params, tile=4, schedule="alg1")
        y_seq = dcn_pipeline(x, params, tile=4, schedule="sequential")
        np.testing.assert_allclose(np.asarray(y_alg1), np.asarray(y_seq),
                                   rtol=1e-6, atol=1e-6)

    def test_buffer_capacity_does_not_change_numerics(self):
        """M only reorders loads; results are capacity-independent."""
        key = jax.random.PRNGKey(7)
        params = _layer(key, 4, 6)
        x = jax.random.normal(jax.random.fold_in(key, 2), (1, 16, 16, 4))
        outs = [dcn_pipeline(x, params, tile=4, buffer_tiles=m)
                for m in (1, 3, 16)]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                       rtol=1e-6, atol=1e-6)

    def test_zero_buffer_capacity_raises(self):
        """buffer_tiles=0 must raise, not silently mean 'unlimited'."""
        key = jax.random.PRNGKey(13)
        params = _layer(key, 4, 4)
        x = jax.random.normal(jax.random.fold_in(key, 2), (1, 8, 8, 4))
        with pytest.raises(ValueError, match="capacity"):
            dcn_pipeline(x, params, tile=4, buffer_tiles=0)

    def test_max_displacement_respected(self):
        key = jax.random.PRNGKey(11)
        params = _layer(key, 4, 4, offset_scale=3.0)
        x = jax.random.normal(jax.random.fold_in(key, 2), (1, 12, 12, 4))
        y_ref = deformable_conv2d(x, params, max_displacement=1.5)
        y_pipe = dcn_pipeline(x, params, max_displacement=1.5, tile=4)
        np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)


class TestPipelineTrace:
    def _run(self, h=16, w=16, tile=8, m=2, seed=0):
        key = jax.random.PRNGKey(seed)
        params = _layer(key, 4, 4, offset_scale=1.0)
        x = jax.random.normal(jax.random.fold_in(key, 2), (1, h, w, 4))
        y, trace = dcn_pipeline(x, params, tile=tile, buffer_tiles=m,
                                return_trace=True)
        offsets = conv2d(x, params.w_off, params.b_off)
        coords = offsets_to_coords(offsets.astype(jnp.float32), 3, "dcn2")
        return y, trace, coords[0], TileGrid(h, w, tile, tile)

    def test_schedule_covers_every_output_tile(self):
        _, trace, _, grid = self._run()
        im = trace.images[0]
        executed = sorted(r.out_tile for r in im.records)
        assert executed == list(range(grid.num_tiles))

    def test_fifo_replay_matches_simulator(self):
        """The executed load sequence, replayed through the FIFO model,
        reproduces the simulator's 'scheduled' tile-load count exactly."""
        m = 2
        _, trace, coords, grid = self._run(m=m)
        B = np.asarray(tdt_from_coords(coords, grid, grid))
        pp = np.asarray(per_pixel_input_tiles(coords, grid))
        tile_bytes = grid.tile_bytes(4, 4)
        rep = simulate_strategies(B, pp, grid, channels=4, c_out=4,
                                  kernel_size=3,
                                  buffer_bytes=m * tile_bytes,
                                  dtype_bytes=4)
        assert trace.fifo_loads() == rep["scheduled"].tile_loads
        assert trace.packed_bytes == trace.packed_tile_loads * tile_bytes

    def test_packed_deps_match_tdt(self):
        """Each dispatch packs exactly the TDT row of its output tile."""
        _, trace, coords, grid = self._run(h=13, w=13, tile=8)
        B = np.asarray(tdt_from_coords(coords, grid, grid))
        for r in trace.images[0].records:
            assert (sorted(r.dep_tiles)
                    == np.flatnonzero(B[r.out_tile]).tolist())


class TestPipelineModelBackend:
    def test_pipeline_backend_matches_xla(self):
        cfg = DcnNetConfig(name="vgg19", n_deform=2, img_size=16,
                           width_mult=0.125, num_classes=4)
        p = init_dcn_net(jax.random.PRNGKey(2), cfg)
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 16, 3))
        y_xla = dcn_net_apply(p, cfg, x, backend="xla", fused=False)
        y_pipe = dcn_net_apply(p, cfg, x, backend="pipeline",
                               pipeline=PipelineConfig(tile=2))
        np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_xla),
                                   rtol=5e-3, atol=5e-3)

    def test_unknown_backend_raises(self):
        cfg = DcnNetConfig(name="vgg19", n_deform=1, img_size=16,
                           width_mult=0.125, num_classes=4)
        p = init_dcn_net(jax.random.PRNGKey(0), cfg)
        x = jnp.zeros((1, 16, 16, 3))
        with pytest.raises(ValueError, match="backend"):
            dcn_net_apply(p, cfg, x, backend="tpu-v9")
