"""Launch machinery on the host: HLO analysis, step builder, rules."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.base import ShapeCell, cell_supported, input_specs
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_host_mesh, mesh_chips
from repro.launch.sharding import sharding_rules
from repro.launch.steps import build_step
from repro.models.params import LogicalAxes, resolve_spec
from repro.optim import AdamWConfig


class TestHloAnalysis:
    def test_scan_trip_count_multiplies(self):
        mesh = make_host_mesh(1, 1)
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=7)
            return y

        comp = jax.jit(f).lower(x, w).compile()
        a = analyze_hlo(comp.as_text())
        assert a["flops"] == pytest.approx(7 * 2 * 8 * 64 * 64, rel=0.01)

    def test_collectives_counted(self):
        # verified behaviourally in the dry-run artifacts; here: no
        # collectives on a single device
        comp = jax.jit(lambda x: x * 2).lower(
            jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
        a = analyze_hlo(comp.as_text())
        assert a["collective_bytes"] == 0


class TestShardingRules:
    def test_fsdp_auto_detection(self):
        big = sharding_rules(configs.get_config("gemma2-27b"))
        small = sharding_rules(configs.get_config("qwen3-1.7b"))
        assert big["embed"] == ("pod", "data")
        assert small["embed"] is None

    def test_decode_kv_rule(self):
        r = sharding_rules(configs.get_config("qwen3-1.7b"), kind="decode")
        assert r["kv_seq"] == "model"
        r = sharding_rules(configs.get_config("jamba-v0.1-52b"),
                           kind="decode", long_ctx=True)
        assert r["kv_seq"] == ("data", "model")

    @staticmethod
    def _mesh22():
        # resolve_spec only reads mesh.shape; a stub avoids needing 4
        # real devices in the main pytest process.
        import types
        return types.SimpleNamespace(shape={"data": 2, "model": 2})

    def test_resolver_drops_nondivisible(self):
        mesh = self._mesh22()
        spec = resolve_spec(LogicalAxes(("heads",)), (15,),
                            {"heads": "model"}, mesh)
        assert spec == P(None)
        spec = resolve_spec(LogicalAxes(("heads",)), (16,),
                            {"heads": "model"}, mesh)
        assert spec == P("model")

    def test_resolver_no_axis_reuse(self):
        mesh = self._mesh22()
        spec = resolve_spec(LogicalAxes(("embed", "mlp")), (8, 8),
                            {"embed": "model", "mlp": "model"}, mesh)
        assert spec == P("model", None)


class TestBuildStep:
    def test_train_lowers_on_host_mesh(self):
        cfg = configs.get_config("qwen3-1.7b", smoke=True)
        shape = ShapeCell("t", "train", 16, 4)
        mesh = make_host_mesh(1, 1)
        b = build_step(cfg, shape, mesh, opt_cfg=AdamWConfig(),
                       param_dtype=jnp.float32)
        with mesh:
            compiled = b.fn.lower(*b.args_abstract).compile()
        assert compiled.cost_analysis() is not None

    def test_decode_lowers_on_host_mesh(self):
        cfg = configs.get_config("xlstm-1.3b", smoke=True)
        shape = ShapeCell("d", "decode", 32, 2)
        mesh = make_host_mesh(1, 1)
        b = build_step(cfg, shape, mesh, param_dtype=jnp.float32)
        with mesh:
            compiled = b.fn.lower(*b.args_abstract).compile()
        assert compiled is not None

    def test_input_specs_cover_all_cells(self):
        for arch in configs.ARCHS:
            cfg = configs.get_config(arch)
            for shape in configs.SHAPES.values():
                ok, _ = cell_supported(cfg, shape)
                if not ok:
                    continue
                specs = input_specs(cfg, shape)
                assert jax.tree.leaves(specs), (arch, shape.name)

    def test_long_500k_only_subquadratic(self):
        shape = configs.SHAPES["long_500k"]
        supported = [a for a in configs.ARCHS
                     if cell_supported(configs.get_config(a), shape)[0]]
        assert sorted(supported) == ["jamba-v0.1-52b", "xlstm-1.3b"]

    def test_mesh_chips(self):
        assert mesh_chips(make_host_mesh(1, 1)) == 1


class TestHostMeshValidation:
    def test_too_many_devices_is_a_clear_error(self):
        """Over-asking must name the fix (XLA_FLAGS recipe), not
        surface as an opaque reshape failure."""
        have = jax.device_count()
        with pytest.raises(ValueError, match="xla_force_host_platform"):
            make_host_mesh(have + 1, 1)
        with pytest.raises(ValueError,
                           match=rf"needs {2 * (have + 3)} devices"):
            make_host_mesh(have + 3, 2)

    def test_degenerate_axes_rejected(self):
        with pytest.raises(ValueError, match="axes must be >= 1"):
            make_host_mesh(0, 1)
        with pytest.raises(ValueError, match="axes must be >= 1"):
            make_host_mesh(1, -2)

    def test_full_device_count_is_valid(self):
        mesh = make_host_mesh(jax.device_count(), 1)
        assert dict(mesh.shape)["data"] == jax.device_count()
