"""Paper benchmark networks + fusion planner + workload accounting."""

import jax
import numpy as np
import pytest

from repro.configs import get_dcn_config
from repro.models.dcn_models import (DcnNetConfig, dcn_net_apply,
                                     init_dcn_net, layer_shapes)


class TestDcnNets:
    @pytest.mark.parametrize("name,nd,variant", [
        ("vgg19", 3, "dcn2"), ("vgg19", 8, "dcn1"), ("vgg19", -1, "dcn2"),
        ("segnet", 3, "dcn2"), ("segnet", -1, "dcn1"),
    ])
    def test_forward_shapes_and_finite(self, name, nd, variant):
        cfg = DcnNetConfig(name=name, n_deform=nd, variant=variant,
                           img_size=32, width_mult=0.125, num_classes=7)
        p = init_dcn_net(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
        y = dcn_net_apply(p, cfg, x)
        if name == "vgg19":
            assert y.shape == (2, 7)
        else:
            assert y.shape == (2, 32, 32, 7)
        assert np.isfinite(np.asarray(y)).all()

    def test_pallas_path_matches_xla(self):
        cfg = DcnNetConfig(name="vgg19", n_deform=3, img_size=16,
                           width_mult=0.125, num_classes=4)
        p = init_dcn_net(jax.random.PRNGKey(2), cfg)
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 16, 3))
        y_xla = dcn_net_apply(p, cfg, x, use_pallas=False)
        y_pal = dcn_net_apply(p, cfg, x, use_pallas=True)
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_xla),
                                   rtol=5e-3, atol=5e-3)

    def test_replacement_from_output_side(self):
        """Paper: deformable layers replace convs from the output layer
        toward the input layer."""
        cfg = DcnNetConfig(name="vgg19", n_deform=3)
        plan = cfg.stage_plan()
        flags = [f for _, _, f in plan]
        assert flags[-3:] == [True] * 3
        assert not any(flags[:-3])

    def test_layer_shapes_count(self):
        assert len(layer_shapes(get_dcn_config("vgg19", 8, smoke=True))) == 8
        assert len(
            layer_shapes(get_dcn_config("segnet", -1, smoke=True))) == 32

    def test_gradients_flow_through_offsets(self):
        """The offset conv (stage 1) must receive gradients — the whole
        point of learnable deformation."""
        cfg = DcnNetConfig(name="vgg19", n_deform=3, img_size=32,
                           width_mult=0.125, num_classes=4)
        p = init_dcn_net(jax.random.PRNGKey(4), cfg)
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 32, 32, 3))
        g = jax.grad(lambda pp: dcn_net_apply(pp, cfg, x).sum())(p)
        w_off_grads = [np.abs(np.asarray(g["convs"][i].w_off)).sum()
                       for i in range(len(g["convs"]))
                       if hasattr(g["convs"][i], "w_off")]
        assert len(w_off_grads) == 3
        assert all(v > 0 for v in w_off_grads)
