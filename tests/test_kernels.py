"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True
executes the kernel body on CPU; BlockSpec tiling identical to TPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.deform import deformable_conv2d, init_deformable_conv
from repro.kernels import ref
from repro.kernels.dcn_bli import bli_gather_reference, bli_tile_matmul
from repro.kernels.dcn_fused import dcn_fused_tile
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.ops import (bli_pallas, coords_to_idx_coeff,
                               deformable_conv2d_pallas)


def _tile_case(key, sh, sw, c, p, kk=None, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    x_tile = jax.random.normal(k1, (sh, sw, c), dtype)
    shape = (p, 2) if kk is None else (p, kk, 2)
    coords = jax.random.uniform(
        k2, shape, jnp.float32,
        maxval=jnp.array([sh - 1.001, sw - 1.001]))
    return x_tile, coords


class TestBliKernel:
    @pytest.mark.parametrize("sh,sw,c,p", [
        (8, 8, 128, 128), (16, 16, 128, 256), (16, 8, 256, 128),
        (32, 32, 128, 512),
    ])
    def test_matches_oracle(self, sh, sw, c, p):
        x_tile, coords = _tile_case(jax.random.PRNGKey(p + c), sh, sw, c, p)
        idx, coeff = coords_to_idx_coeff(coords, sh, sw)
        out = bli_tile_matmul(x_tile.reshape(sh * sw, c), idx, coeff,
                              interpret=True)
        want = ref.bli_tile_ref(x_tile, coords)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                           (jnp.bfloat16, 2e-2)])
    def test_dtypes(self, dtype, tol):
        x_tile, coords = _tile_case(jax.random.PRNGKey(0), 16, 16, 128, 128,
                                    dtype=dtype)
        idx, coeff = coords_to_idx_coeff(coords, 16, 16)
        out = bli_tile_matmul(x_tile.reshape(256, 128), idx, coeff,
                              interpret=True)
        want = ref.bli_tile_ref(x_tile, coords)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    def test_matches_gather_formulation(self):
        x_tile, coords = _tile_case(jax.random.PRNGKey(5), 16, 16, 128, 128)
        idx, coeff = coords_to_idx_coeff(coords, 16, 16)
        a = bli_tile_matmul(x_tile.reshape(256, 128), idx, coeff,
                            interpret=True)
        b = bli_gather_reference(x_tile.reshape(256, 128), idx, coeff)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_full_layer_wrapper(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 12, 16))
        coords = jax.random.uniform(
            jax.random.PRNGKey(2), (2, 12, 12, 9, 2), jnp.float32,
            maxval=10.99)
        out = bli_pallas(x, coords)
        want = jax.vmap(ref.bli_tile_ref)(
            x, coords.reshape(2, -1, 2)).reshape(out.shape)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


class TestFusedKernel:
    @pytest.mark.parametrize("c,o,p", [(128, 64, 128), (128, 128, 256),
                                       (64, 32, 128)])
    def test_matches_oracle(self, c, o, p):
        x_tile, coords = _tile_case(jax.random.PRNGKey(c + o), 16, 16, c, p,
                                    kk=9)
        idx, coeff = coords_to_idx_coeff(coords, 16, 16)
        w = jax.random.normal(jax.random.PRNGKey(1), (9, c, o)) * 0.05
        b = jax.random.normal(jax.random.PRNGKey(2), (o,)) * 0.1
        out = dcn_fused_tile(x_tile.reshape(256, c), idx, coeff, w, b,
                             interpret=True)
        want = ref.dcn_fused_tile_ref(x_tile, coords, w, b)
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)

    def test_end_to_end_vs_xla_path(self):
        """Pallas fused layer == XLA reference deformable conv."""
        params = init_deformable_conv(jax.random.PRNGKey(3), 16, 24)
        params = params._replace(
            w_off=jax.random.normal(jax.random.PRNGKey(4),
                                    params.w_off.shape) * 0.3)
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 12, 12, 16))
        np.testing.assert_allclose(
            deformable_conv2d_pallas(x, params),
            deformable_conv2d(x, params), rtol=2e-4, atol=2e-4)

    def test_dcn1_variant(self):
        params = init_deformable_conv(jax.random.PRNGKey(6), 8, 8,
                                      variant="dcn1")
        params = params._replace(
            w_off=jax.random.normal(jax.random.PRNGKey(7),
                                    params.w_off.shape) * 0.5)
        x = jax.random.normal(jax.random.PRNGKey(8), (1, 8, 8, 8))
        np.testing.assert_allclose(
            deformable_conv2d_pallas(x, params, variant="dcn1"),
            deformable_conv2d(x, params, variant="dcn1"),
            rtol=2e-4, atol=2e-4)


class TestFlashAttention:
    @pytest.mark.parametrize("sq,skv,hq,hkv,d", [
        (64, 64, 4, 4, 32),    # MHA
        (64, 128, 8, 2, 32),   # GQA + longer kv
        (37, 100, 4, 2, 64),   # ragged (padding path)
        (1, 128, 4, 2, 32),    # decode-like
    ])
    def test_causal(self, sq, skv, hq, hkv, d):
        ks = jax.random.split(jax.random.PRNGKey(sq + skv), 3)
        q = jax.random.normal(ks[0], (2, sq, hq, d))
        k = jax.random.normal(ks[1], (2, skv, hkv, d))
        v = jax.random.normal(ks[2], (2, skv, hkv, d))
        out = flash_attention(q, k, v, interpret=True, block_q=32, block_k=32)
        want = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("kwargs", [
        {"window": 16}, {"softcap": 20.0}, {"causal": False},
        {"window": 16, "softcap": 30.0},
    ])
    def test_variants(self, kwargs):
        ks = jax.random.split(jax.random.PRNGKey(9), 3)
        q = jax.random.normal(ks[0], (1, 64, 4, 32))
        k = jax.random.normal(ks[1], (1, 64, 2, 32))
        v = jax.random.normal(ks[2], (1, 64, 2, 32))
        out = flash_attention(q, k, v, interpret=True, block_q=16,
                              block_k=16, **kwargs)
        want = ref.attention_ref(q, k, v, **kwargs)
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        ks = jax.random.split(jax.random.PRNGKey(10), 3)
        q = jax.random.normal(ks[0], (1, 64, 4, 32), jnp.bfloat16)
        k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.bfloat16)
        v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.bfloat16)
        out = flash_attention(q, k, v, interpret=True, block_q=32, block_k=32)
        want = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=3e-2, atol=3e-2)


class TestFlashDecode:
    def _oracle(self, q, kc, vc, lengths, window=None, softcap=None):
        outs = []
        for i in range(q.shape[0]):
            L = int(lengths[i])
            lo = max(0, L - window) if window is not None else 0
            o = ref.attention_ref(q[i][None, None], kc[i][None, lo:L],
                                  vc[i][None, lo:L], causal=False,
                                  softcap=softcap)
            outs.append(o[0, 0])
        return jnp.stack(outs)

    @pytest.mark.parametrize("kwargs", [
        {}, {"softcap": 25.0}, {"window": 32},
    ])
    def test_ragged_lengths(self, kwargs):
        b, s, hq, hkv, d = 3, 160, 8, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(11), 3)
        q = jax.random.normal(ks[0], (b, hq, d))
        kc = jax.random.normal(ks[1], (b, s, hkv, d))
        vc = jax.random.normal(ks[2], (b, s, hkv, d))
        lengths = jnp.array([40, 160, 97], jnp.int32)
        got = flash_decode(q, kc, vc, lengths, interpret=True, block_k=64,
                           **kwargs)
        want = self._oracle(q, kc, vc, lengths, **kwargs)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_split_k_invariance(self):
        """Result must not depend on the KV block size (the split-KV
        reduction is exact, not approximate)."""
        b, s, hq, hkv, d = 2, 128, 4, 4, 32
        ks = jax.random.split(jax.random.PRNGKey(12), 3)
        q = jax.random.normal(ks[0], (b, hq, d))
        kc = jax.random.normal(ks[1], (b, s, hkv, d))
        vc = jax.random.normal(ks[2], (b, s, hkv, d))
        lengths = jnp.array([128, 77], jnp.int32)
        outs = [flash_decode(q, kc, vc, lengths, interpret=True, block_k=bk)
                for bk in (32, 64, 128)]
        for o in outs[1:]:
            np.testing.assert_allclose(outs[0], o, rtol=1e-5, atol=1e-5)
