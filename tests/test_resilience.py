"""Fault-tolerant serving: isolation, deadlines, backpressure, watchdog.

Covers the ISSUE 8 resilience layer end to end with the deterministic
fault injector (``repro.testing.faults``):

* request isolation — a tagged executor fault fails exactly the
  offending request (typed ``RequestFailedError``) while its step-mates
  complete with reference-exact results; untagged faults degrade the
  step to per-image dispatch instead of failing the batch.
* deadlines — expiry at admission (never served) vs mid-flight
  (computed, still failed: the contract is the deadline).
* backpressure — the bounded queue under all three policies, including
  the oversized-request pre-reject that keeps ``block`` deadlock-free.
* watchdog — a stalled staging worker fails over to synchronous prepass
  with correct results.
* exactly-once — every submitted request resolves exactly once under a
  seeded fault storm; ``DrainTimeout`` instead of silent drops when a
  drain budget is exhausted.

Every injected fault is a pure function of ``(seed, kind, index)`` —
reruns reproduce bit-identical failure patterns.
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.deform import DeformableConvParams, randomize_offset_conv
from repro.models import lm
from repro.models.dcn_models import DcnNetConfig, init_dcn_net
from repro.models.params import Maker
from repro.runtime import GraphConfig
from repro.serving import (DcnServingEngine, DeadlineExceededError,
                           DecodeEngine, DrainTimeout, QueueFullError,
                           Request, RequestFailedError)
from repro.testing import ALL_FAULT_KINDS, FaultError, FaultInjector, FaultPlan


def _dcn_case(seed=2):
    cfg = DcnNetConfig(name="vgg19", n_deform=2, img_size=16,
                       width_mult=0.125, num_classes=4)
    key = jax.random.PRNGKey(seed)
    params = init_dcn_net(key, cfg)
    params["convs"] = [
        randomize_offset_conv(p, jax.random.fold_in(key, 100 + i),
                              2.0 / p.w.shape[2])
        if isinstance(p, DeformableConvParams) else p
        for i, p in enumerate(params["convs"])]
    return cfg, params


@pytest.fixture(scope="module")
def dcn_setup():
    return _dcn_case()


def _engine(dcn_setup, **kw):
    cfg, params = dcn_setup
    kw.setdefault("graph", GraphConfig(tile=4))
    return DcnServingEngine(params, cfg, **kw)


def _images(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, 16, 16, 3)).astype(np.float32)


def _reference(dcn_setup, xs):
    cfg, params = dcn_setup
    ref = DcnServingEngine(params, cfg, graph=GraphConfig(tile=4))
    return np.asarray(ref.infer(jnp.asarray(xs)))


class TestRequestIsolation:
    def test_tagged_dispatch_fault_isolates_one_request(self, dcn_setup):
        """A dispatch fault naming its image fails exactly that request;
        the evict-and-retry step serves the step-mates with results
        equal to a fault-free engine."""
        inj = FaultInjector(kinds=("dispatch",), rate=1.0, max_fires=1,
                            seed=3)
        eng = _engine(dcn_setup, slots=4, faults=inj)
        xs = _images(3, seed=1)
        reqs = [eng.submit(xs[i]) for i in range(3)]
        done = eng.drain()
        assert sorted(r.rid for r in done) == [r.rid for r in reqs]
        assert inj.fired["dispatch"] == 1
        failed = [r for r in reqs if r.failed]
        assert len(failed) == 1
        with pytest.raises(RequestFailedError) as ei:
            failed[0].result()
        assert isinstance(ei.value.__cause__, FaultError)
        ref = _reference(dcn_setup, xs)
        for i, r in enumerate(reqs):
            if not r.failed:
                np.testing.assert_allclose(r.result()[0], ref[i],
                                           rtol=2e-4, atol=2e-4)
        s = eng.stats
        assert s["step_retries"] == 1
        assert s["degraded_steps"] == 0
        assert s["requests_failed"] == 1

    def test_tagged_prepass_fault_isolates_one_request(self, dcn_setup):
        inj = FaultInjector(kinds=("prepass",), rate=1.0, max_fires=1,
                            seed=5)
        eng = _engine(dcn_setup, slots=4, faults=inj)
        xs = _images(3, seed=2)
        reqs = [eng.submit(xs[i]) for i in range(3)]
        eng.drain()
        assert sum(r.failed for r in reqs) == 1
        assert eng.stats["step_retries"] == 1
        ref = _reference(dcn_setup, xs)
        for i, r in enumerate(reqs):
            if not r.failed:
                np.testing.assert_allclose(r.result()[0], ref[i],
                                           rtol=2e-4, atol=2e-4)

    def test_untagged_transient_fault_degrades_step(self, dcn_setup):
        """A fault that cannot name its image degrades the step to
        per-image batched dispatch — every request still completes
        correctly (the fault was transient)."""
        inj = FaultInjector(kinds=("dispatch",), rate=1.0, max_fires=1,
                            tag_image=False, seed=7)
        eng = _engine(dcn_setup, slots=4, faults=inj)
        xs = _images(3, seed=3)
        reqs = [eng.submit(xs[i]) for i in range(3)]
        eng.drain()
        assert all(r.done and not r.failed for r in reqs)
        s = eng.stats
        assert s["degraded_steps"] == 1
        assert s["requests_failed"] == 0
        ref = _reference(dcn_setup, xs)
        got = np.concatenate([r.result() for r in reqs])
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)

    def test_persistent_untagged_fault_fails_all_typed(self, dcn_setup):
        """Every dispatch faulting (untagged, unlimited): the degraded
        per-image runs capture each image's exception — all requests
        resolve with typed errors, nothing deadlocks or goes missing."""
        inj = FaultInjector(kinds=("dispatch",), rate=1.0,
                            tag_image=False, seed=9)
        eng = _engine(dcn_setup, slots=4, faults=inj)
        reqs = [eng.submit(_images(1, seed=20 + i)) for i in range(3)]
        done = eng.drain(max_steps=50)
        assert sorted(r.rid for r in done) == [r.rid for r in reqs]
        for r in reqs:
            assert r.failed and isinstance(r.error, RequestFailedError)
            assert isinstance(r.error.__cause__, FaultError)
        s = eng.stats
        assert s["requests_failed"] == 3
        assert s["degraded_steps"] >= 1

    def test_cache_miss_storm_correct_but_cold(self, dcn_setup):
        """A cache_miss storm (every key salted) forces rebuilds: image
        hits stay 0 where a replay would normally hit, and results stay
        correct — the cache is an optimization, never a correctness
        dependency."""
        inj = FaultInjector(kinds=("cache_miss",), rate=1.0, seed=11)
        eng = _engine(dcn_setup, slots=1, faults=inj)
        x = _images(1, seed=4)
        r1 = eng.submit(x)
        r2 = eng.submit(x)                   # replay: would hit when healthy
        eng.drain()
        assert inj.fired["cache_miss"] > 0
        assert eng.stats["image_hits"] == 0
        ref = _reference(dcn_setup, x)
        np.testing.assert_allclose(r1.result(), ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(r2.result(), ref, rtol=2e-4, atol=2e-4)

    def test_exactly_once_under_fault_storm(self, dcn_setup):
        """Seeded multi-kind storm: every request resolves exactly once
        — failed requests carry typed errors, survivors match the
        fault-free reference."""
        inj = FaultInjector(kinds=("prepass", "dispatch"), rate=0.3,
                            seed=13)
        eng = _engine(dcn_setup, slots=4, faults=inj)
        xs = _images(8, seed=5)
        reqs = [eng.submit(xs[i]) for i in range(8)]
        done = eng.drain(max_steps=100)
        rids = [r.rid for r in done]
        assert sorted(rids) == [r.rid for r in reqs]
        assert len(rids) == len(set(rids))
        assert eng.drain() == []             # nothing resolves twice
        assert inj.total_fired > 0           # the storm actually fired
        ref = _reference(dcn_setup, xs)
        for i, r in enumerate(reqs):
            assert r.done
            if r.failed:
                assert isinstance(r.error, RequestFailedError)
            else:
                np.testing.assert_allclose(r.result()[0], ref[i],
                                           rtol=2e-4, atol=2e-4)
        s = eng.stats
        assert s["requests_failed"] == sum(r.failed for r in reqs)

    def test_failure_counters_in_metrics_snapshot(self, dcn_setup):
        """Every failure counter ``stats`` reports appears in
        ``metrics_snapshot()`` under its registry name."""
        inj = FaultInjector(kinds=("dispatch",), rate=1.0, max_fires=1,
                            seed=3)
        eng = _engine(dcn_setup, slots=2, faults=inj)
        for i in range(2):
            eng.submit(_images(1, seed=30 + i))
        eng.drain()
        snap = eng.metrics_snapshot()
        assert snap["serving.requests_failed"] == 1
        for name in ("serving.deadline_expired", "serving.queue_rejected",
                     "serving.queue_shed", "serving.step_retries",
                     "serving.degraded_steps",
                     "serving.watchdog_failovers"):
            assert name in snap


class TestDeadlines:
    def test_expiry_at_admission_never_served(self, dcn_setup):
        """A request whose deadline passes while queued fails at
        admission without ever occupying a slot or burning compute."""
        now = [0.0]
        eng = _engine(dcn_setup, slots=1, clock=lambda: now[0])
        r1 = eng.submit(_images(1, seed=40))
        r2 = eng.submit(_images(1, seed=41), deadline_s=0.5)
        now[0] = 1.0
        first = eng.step()                   # serves r1
        assert [r.rid for r in first] == [r1.rid]
        second = eng.step()                  # r2 expires at admission
        assert [r.rid for r in second] == [r2.rid]
        assert r2.failed and isinstance(r2.error, DeadlineExceededError)
        with pytest.raises(DeadlineExceededError):
            r2.result()
        s = eng.stats
        assert s["deadline_expired"] == 1 and s["requests_failed"] == 1
        assert s["images"] == 1              # r2 was never executed
        assert s["steps"] == 1               # the expiry step ran no grid
        assert s["latency"]["count"] == 1    # failures never enter latency

    def test_expiry_mid_flight_after_compute(self, dcn_setup):
        """Admitted in time, completed past the deadline: the image was
        computed but the request still fails — the contract is the
        deadline, not the compute."""
        ticks = [0.0, 0.0, 1.0]              # submit, admission, completion
        clock = lambda: ticks.pop(0) if ticks else 1.0  # noqa: E731
        eng = _engine(dcn_setup, slots=1, clock=clock)
        r = eng.submit(_images(1, seed=42), deadline_s=0.5)
        done = eng.step()
        assert [q.rid for q in done] == [r.rid]
        assert r.failed and isinstance(r.error, DeadlineExceededError)
        s = eng.stats
        assert s["deadline_expired"] == 1
        assert s["images"] == 1              # it WAS served, then expired
        assert s["latency"]["count"] == 0

    def test_deadline_validation(self, dcn_setup):
        eng = _engine(dcn_setup)
        with pytest.raises(ValueError, match="deadline_s"):
            eng.submit(_images(1), deadline_s=0.0)
        with pytest.raises(ValueError, match="deadline_s"):
            eng.submit(_images(1), deadline_s=-1.0)


class TestBackpressure:
    def test_reject_policy_raises_queue_full(self, dcn_setup):
        eng = _engine(dcn_setup, slots=1, max_queue=2,
                      queue_policy="reject")
        r1 = eng.submit(_images(1, seed=50))
        r2 = eng.submit(_images(1, seed=51))
        with pytest.raises(QueueFullError):
            eng.submit(_images(1, seed=52))
        assert eng.stats["queue_rejected"] == 1
        done = eng.drain()
        assert {r.rid for r in done} == {r1.rid, r2.rid}
        assert all(not r.failed for r in (r1, r2))

    def test_shed_oldest_resolves_victim_on_handle(self, dcn_setup):
        """Policy shed-oldest evicts the oldest queued request; its
        handle resolves immediately with a RequestFailedError caused by
        QueueFullError, and it never appears in step/drain returns."""
        eng = _engine(dcn_setup, slots=1, max_queue=2,
                      queue_policy="shed-oldest")
        r1 = eng.submit(_images(1, seed=53))
        r2 = eng.submit(_images(1, seed=54))
        r3 = eng.submit(_images(1, seed=55))  # sheds r1
        assert r1.done and r1.failed
        assert isinstance(r1.error, RequestFailedError)
        assert isinstance(r1.error.__cause__, QueueFullError)
        done = eng.drain()
        assert {r.rid for r in done} == {r2.rid, r3.rid}
        s = eng.stats
        assert s["queue_shed"] == 1 and s["requests_failed"] == 1

    def test_block_policy_waits_for_room(self, dcn_setup):
        """A blocked submitter is released by step()'s admission and the
        late request completes — no deadlock, nothing dropped."""
        eng = _engine(dcn_setup, slots=1, max_queue=1,
                      queue_policy="block")
        r1 = eng.submit(_images(1, seed=56))
        late: list = []

        def client():
            late.append(eng.submit(_images(1, seed=57)))

        t = threading.Thread(target=client)
        t.start()
        done: list = []
        for _ in range(50):
            done.extend(eng.step())
            if not t.is_alive() and len(done) == 2:
                break
        t.join(timeout=10)
        assert not t.is_alive()
        done.extend(eng.drain())
        assert {r.rid for r in done} == {r1.rid, late[0].rid}
        assert all(not r.failed for r in (r1, late[0]))

    def test_oversized_request_always_rejected(self, dcn_setup):
        """Wider than max_queue can never fit — rejected up front even
        under policy block (waiting would deadlock forever)."""
        eng = _engine(dcn_setup, slots=1, max_queue=2,
                      queue_policy="block")
        with pytest.raises(QueueFullError, match="exceeds max_queue"):
            eng.submit(_images(3, seed=58))
        assert eng.stats["queue_rejected"] == 1
        assert eng.queue_depth == 0

    def test_queue_config_validation(self, dcn_setup):
        with pytest.raises(ValueError, match="queue_policy"):
            _engine(dcn_setup, queue_policy="drop-newest")
        with pytest.raises(ValueError, match="max_queue"):
            _engine(dcn_setup, max_queue=0)


class TestWatchdog:
    def test_stalled_worker_fails_over_with_correct_results(self,
                                                            dcn_setup):
        """A staging worker stalled past watchdog_s is abandoned; the
        run fails over to synchronous prepass and still produces
        reference-exact results."""
        inj = FaultInjector(kinds=("worker_stall",), rate=1.0,
                            max_fires=1, stall_s=0.4, seed=15)
        eng = _engine(dcn_setup, slots=4,
                      graph=GraphConfig(tile=4, watchdog_s=0.05),
                      faults=inj)
        xs = _images(3, seed=6)
        reqs = [eng.submit(xs[i]) for i in range(3)]
        eng.drain()
        assert inj.fired["worker_stall"] == 1
        assert all(r.done and not r.failed for r in reqs)
        assert eng.stats["watchdog_failovers"] >= 1
        ref = _reference(dcn_setup, xs)
        got = np.concatenate([r.result() for r in reqs])
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)

    def test_watchdog_config_validation(self):
        with pytest.raises(ValueError, match="watchdog_s"):
            GraphConfig(watchdog_s=0.0)
        with pytest.raises(ValueError, match="watchdog_s"):
            GraphConfig(watchdog_s=-1.0)


class TestDrainTimeout:
    def test_dcn_drain_raises_with_stuck_rids(self, dcn_setup):
        eng = _engine(dcn_setup, slots=1)
        reqs = [eng.submit(_images(1, seed=60 + i)) for i in range(3)]
        with pytest.raises(DrainTimeout) as ei:
            eng.drain(max_steps=1)
        assert sorted(ei.value.pending_rids) == [reqs[1].rid, reqs[2].rid]
        assert [r.rid for r in ei.value.finished] == [reqs[0].rid]
        # the stuck work is still there, not dropped: a real drain finishes
        done = eng.drain()
        assert {r.rid for r in done} == {reqs[1].rid, reqs[2].rid}


class TestInputValidation:
    def test_nan_rejected_before_cache(self, dcn_setup):
        """A NaN image is rejected at submit() before its garbage coords
        digest can poison the schedule cache — later clean requests are
        unaffected."""
        eng = _engine(dcn_setup, slots=1)
        bad = _images(1, seed=70)
        bad[0, 3, 3, 1] = np.nan
        with pytest.raises(ValueError, match="finite"):
            eng.submit(bad)
        assert eng.cache.info()["size"] == 0
        assert eng.queue_depth == 0
        x = _images(1, seed=71)
        r = eng.submit(x)
        eng.drain()
        np.testing.assert_allclose(r.result(), _reference(dcn_setup, x),
                                   rtol=2e-4, atol=2e-4)

    def test_inf_rejected(self, dcn_setup):
        eng = _engine(dcn_setup)
        bad = _images(1, seed=72)
        bad[0, 0, 0, 0] = np.inf
        with pytest.raises(ValueError, match="finite"):
            eng.submit(bad)

    def test_corrupted_injector_image_caught_at_submit(self, dcn_setup):
        """The nan_image fault corrupts pre-submit; the engine's front
        door is the isolation under test."""
        inj = FaultInjector(kinds=("nan_image",), rate=1.0, seed=17)
        eng = _engine(dcn_setup)
        x = inj.corrupt(_images(1, seed=73))
        assert inj.fired["nan_image"] == 1
        with pytest.raises(ValueError, match="finite"):
            eng.submit(x)


class TestDecodeEngineResilience:
    @pytest.fixture(scope="class")
    def lm_setup(self):
        cfg = configs.get_config("smollm-360m", smoke=True)
        params = lm.init_lm(Maker("init", jax.random.PRNGKey(40)), cfg)
        return cfg, params

    def test_concurrent_submit_is_thread_safe(self, lm_setup):
        """Regression: the submit queue was a bare list; racing
        submitters could interleave with _admit's pop. Every request
        must decode exactly once."""
        cfg, params = lm_setup
        eng = DecodeEngine(params, cfg, batch=2, max_len=16)
        reqs: list = []
        lock = threading.Lock()

        def client(seed):
            for k in range(2):
                r = Request(seed * 10 + k, [3, 5], max_new=2)
                eng.submit(r)
                with lock:
                    reqs.append(r)

        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(3)]
        for t in threads:
            t.start()
        while any(t.is_alive() for t in threads):
            eng.step()
        for t in threads:
            t.join()
        done = eng.run()
        assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
        assert len(done) == len(set(r.rid for r in done)) == 6
        assert all(r.done and len(r.out) == 2 for r in reqs)

    def test_run_raises_drain_timeout(self, lm_setup):
        cfg, params = lm_setup
        eng = DecodeEngine(params, cfg, batch=1, max_len=64)
        eng.submit(Request(0, [3, 5], max_new=16))
        eng.submit(Request(1, [3, 5], max_new=16))
        with pytest.raises(DrainTimeout) as ei:
            eng.run(max_steps=2)
        assert set(ei.value.pending_rids) == {0, 1}
        done = eng.run()                     # the work was not dropped
        assert sorted(r.rid for r in done) == [0, 1]


class TestFaultInjector:
    def test_deterministic_across_instances(self):
        pat = []
        for _ in range(2):
            inj = FaultInjector(kinds=("dispatch",), rate=0.4, seed=21)
            fires = []
            for _ in range(30):
                try:
                    inj.check("dispatch", images=4)
                    fires.append(None)
                except FaultError as e:
                    fires.append(e.image)
            pat.append(fires)
        assert pat[0] == pat[1]
        assert any(f is not None for f in pat[0])
        assert any(f is None for f in pat[0])

    def test_rate_zero_never_fires_rate_one_always(self):
        quiet = FaultInjector(kinds=ALL_FAULT_KINDS, rate=0.0, seed=1)
        for _ in range(20):
            quiet.check("dispatch", images=2)
            quiet.check("prepass", image=0)
            assert quiet.miss_salt() is None
        assert quiet.total_fired == 0
        loud = FaultInjector(kinds=("prepass",), rate=1.0, seed=1)
        for i in range(5):
            with pytest.raises(FaultError):
                loud.check("prepass", image=i)
        assert loud.fired["prepass"] == 5

    def test_max_fires_caps_total(self):
        inj = FaultInjector(kinds=("dispatch",), rate=1.0, max_fires=2,
                            seed=2)
        hits = 0
        for _ in range(10):
            try:
                inj.check("dispatch", images=3)
            except FaultError:
                hits += 1
        assert hits == 2 and inj.total_fired == 2

    def test_corrupt_poisons_copy_only(self):
        inj = FaultInjector(kinds=("nan_image",), rate=1.0, seed=4)
        x = np.ones((2, 4, 4, 3), np.float32)
        y = inj.corrupt(x)
        assert y is not x
        assert np.isfinite(x).all()
        assert int(np.isnan(y).sum()) == 1
        off = FaultInjector(kinds=("nan_image",), rate=0.0, seed=4)
        assert off.corrupt(x) is x

    def test_miss_salts_are_unique(self):
        inj = FaultInjector(kinds=("cache_miss",), rate=1.0, seed=6)
        salts = [inj.miss_salt() for _ in range(5)]
        assert all(s is not None for s in salts)
        assert len(set(salts)) == 5

    def test_step_mode_bounds_fires_per_step(self):
        """In step mode an armed kind fires on exactly one consultation
        per step, however many sites consult it."""
        inj = FaultInjector(kinds=("dispatch",), rate=1.0, mode="step",
                            seed=8)
        for _ in range(3):
            inj.begin_step()
            fires = 0
            for _ in range(6):
                try:
                    inj.check("dispatch", images=2)
                except FaultError:
                    fires += 1
            assert fires == 1

    def test_plan_validation(self):
        with pytest.raises(ValueError, match="rate"):
            FaultPlan(rate=1.5)
        with pytest.raises(ValueError, match="mode"):
            FaultPlan(mode="chaos")
        with pytest.raises(ValueError, match="kinds"):
            FaultPlan(kinds=("prepass", "gremlin"))
        with pytest.raises(ValueError, match="not both"):
            FaultInjector(FaultPlan(), rate=0.5)
