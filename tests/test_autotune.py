"""Simulator-guided autotuner (ISSUE 10): search, plan cache, executor
integration, serving stats.

The tuner's contract is checked from every side: tuned plans must never
lose to the greedy baseline (by construction — the greedy seed is
scored first and only strict improvements are accepted), executed
traces under a tuned plan must stay EXACTLY equal to the DRAM
simulator, numerics must match the dense reference, the persisted plan
cache must round-trip through disk and degrade cleanly on corruption,
the partition memo must not conflate greedy and tuned plans for the
same (graph, budget), and the serving engine must surface the
autotuning counters in both ``stats`` and ``metrics_snapshot()``.
"""

import json

import jax
import numpy as np
import pytest

from repro.core.deform import init_deformable_conv, randomize_offset_conv
from repro.core.simulator import simulate_network
from repro.models.dcn_models import DcnNetConfig, init_dcn_net
from repro.runtime import (ConvNode, DeformNode, GraphConfig, NetGraph,
                           PoolNode, build_graph, run_graph,
                           run_graph_dense)
from repro.runtime.fused_exec import network_sim_specs
from repro.runtime.graph import partition_graph_cached
from repro.runtime.pipeline import PipelineConfig
from repro.serving import DcnServingEngine
from repro.tuning import (PlanCache, TunedGroup, TunedPlan,
                          autotune_plan, plan_cache_hits,
                          representative_input, resolve_tuned_plan)


def _conv_p(key, c_in, c_out, scale=0.2):
    return {"w": jax.random.normal(key, (3, 3, c_in, c_out)) * scale,
            "b": jax.random.normal(jax.random.fold_in(key, 1),
                                   (c_out,)) * 0.1}


def _deform_p(key, c_in, c_out, offset_scale=0.5):
    p = init_deformable_conv(key, c_in, c_out, 3, "dcn2")
    return randomize_offset_conv(p, jax.random.fold_in(key, 1),
                                 offset_scale)


def _chain_case(h=13, w=13, seed=0, offset_scale=0.5):
    """conv -> DCN -> conv -> pool -> conv: one fusible run, a boundary,
    a trailing run; h=13 does not divide the default tile."""
    key = jax.random.PRNGKey(seed)
    convs = [
        _conv_p(jax.random.fold_in(key, 0), 3, 6),
        _deform_p(jax.random.fold_in(key, 1), 6, 6, offset_scale),
        _conv_p(jax.random.fold_in(key, 2), 6, 8),
        _conv_p(jax.random.fold_in(key, 3), 8, 8),
    ]
    ph, pw = (h - 2) // 2 + 1, (w - 2) // 2 + 1
    nodes = (ConvNode(0, 3, 6, h, w), DeformNode(1, 6, 6, h, w),
             ConvNode(2, 6, 8, h, w), PoolNode(h, w, 8),
             ConvNode(3, 8, 8, ph, pw))
    graph = NetGraph(nodes, h, w, 3)
    return convs, graph


BUDGET = 512 * 1024


class TestAutotunePlan:
    def test_tuned_never_loses_to_greedy(self):
        convs, graph = _chain_case()
        for bt in (None, 4):
            plan = autotune_plan(convs, graph,
                                 onchip_budget_bytes=BUDGET,
                                 tile_hw=(4, 4), buffer_tiles=bt,
                                 budget=96)
            assert plan.dram_bytes <= plan.greedy_dram_bytes
            assert plan.candidates <= 96
            # the plan tiles every layer node exactly once, in order
            covered = [i for g in plan.groups
                       for i in range(g.start, g.stop)]
            layer_idx = [i for i, n in enumerate(graph.nodes)
                         if isinstance(n, (ConvNode, DeformNode))]
            assert covered == layer_idx

    def test_offline_trace_exact_and_numerics(self, tmp_path):
        """Executed trace under a tuned plan == DRAM simulator, and the
        tuned run matches the dense XLA reference."""
        convs, graph = _chain_case()
        x = representative_input(graph)
        cfg = GraphConfig(tile=4, buffer_tiles=4, autotune="offline",
                          autotune_budget=96,
                          plan_cache_dir=str(tmp_path))
        y, trace = run_graph(convs, graph, x, config=cfg,
                             return_trace=True)
        sim = simulate_network(network_sim_specs(trace),
                               boundary_bytes=trace.boundary_bytes,
                               fused=True)
        assert trace.total_dram_bytes == sim.total_dram_bytes
        y_ref = run_graph_dense(convs, graph, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_tuned_executed_dram_le_greedy(self, tmp_path):
        convs, graph = _chain_case()
        x = representative_input(graph)
        base = dict(tile=4, buffer_tiles=4)
        _, tr_g = run_graph(convs, graph, x,
                            config=GraphConfig(**base),
                            return_trace=True)
        _, tr_t = run_graph(convs, graph, x,
                            config=GraphConfig(
                                **base, autotune="offline",
                                autotune_budget=96,
                                plan_cache_dir=str(tmp_path)),
                            return_trace=True)
        assert tr_t.total_dram_bytes <= tr_g.total_dram_bytes

    def test_property_tuned_le_greedy_random_nets(self):
        """Hypothesis sweep: tuned <= greedy on random chains, budgets
        and FIFO depths (the tuner's by-construction guarantee)."""
        pytest.importorskip(
            "hypothesis",
            reason="hypothesis not installed; property test optional")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=12, deadline=None)
        @given(seed=st.integers(0, 1000), h=st.integers(6, 14),
               deform=st.booleans(),
               bt=st.sampled_from([None, 2, 4]),
               budget=st.integers(8, 64),
               onchip_kb=st.sampled_from([64, 256, 1024]))
        def prop(seed, h, deform, bt, budget, onchip_kb):
            key = jax.random.PRNGKey(seed)
            convs = [_conv_p(jax.random.fold_in(key, 0), 3, 4)]
            nodes = [ConvNode(0, 3, 4, h, h)]
            if deform:
                convs.append(_deform_p(jax.random.fold_in(key, 1),
                                       4, 4))
                nodes.append(DeformNode(1, 4, 4, h, h))
            convs.append(_conv_p(jax.random.fold_in(key, 2), 4, 4))
            nodes.append(ConvNode(len(convs) - 1, 4, 4, h, h))
            graph = NetGraph(tuple(nodes), h, h, 3)
            plan = autotune_plan(
                convs, graph, onchip_budget_bytes=onchip_kb * 1024,
                tile_hw=(4, 4), buffer_tiles=bt, budget=budget)
            assert plan.dram_bytes <= plan.greedy_dram_bytes
            assert plan.candidates <= budget

        prop()


class TestPlanCache:
    def _resolve(self, convs, graph, mode, tmp_path, **kw):
        return resolve_tuned_plan(
            convs, graph, autotune=mode, onchip_budget_bytes=BUDGET,
            tile_hw=(4, 4), buffer_tiles=4, budget=64,
            plan_cache_dir=str(tmp_path), **kw)

    def test_round_trip_disk(self, tmp_path):
        """offline search -> persisted file -> a FRESH cache over the
        same dir serves the identical plan without searching."""
        convs, graph = _chain_case()
        plan = self._resolve(convs, graph, "offline", tmp_path)
        assert plan is not None
        files = list(tmp_path.glob("plan-*.json"))
        assert len(files) == 1
        fresh = PlanCache(cache_dir=str(tmp_path))
        hits0 = plan_cache_hits.count
        again = resolve_tuned_plan(
            convs, graph, autotune="cached-only",
            onchip_budget_bytes=BUDGET, tile_hw=(4, 4),
            buffer_tiles=4, budget=64, plan_cache=fresh)
        assert again == plan
        assert plan_cache_hits.count == hits0 + 1

    def test_corrupt_file_is_a_clean_miss(self, tmp_path):
        """A corrupted cache file must read as a miss (cached-only ->
        None) and offline must recover by re-searching + rewriting."""
        convs, graph = _chain_case()
        plan = self._resolve(convs, graph, "offline", tmp_path)
        (f,) = tmp_path.glob("plan-*.json")
        f.write_text("{not json")
        fresh = PlanCache(cache_dir=str(tmp_path))
        miss = resolve_tuned_plan(
            convs, graph, autotune="cached-only",
            onchip_budget_bytes=BUDGET, tile_hw=(4, 4),
            buffer_tiles=4, budget=64, plan_cache=fresh)
        assert miss is None
        redo = resolve_tuned_plan(
            convs, graph, autotune="offline",
            onchip_budget_bytes=BUDGET, tile_hw=(4, 4),
            buffer_tiles=4, budget=64, plan_cache=fresh)
        # deterministic search: same plan modulo the re-search wall time
        assert (redo.key, redo.groups, redo.dram_bytes) == \
            (plan.key, plan.groups, plan.dram_bytes)
        assert json.loads(f.read_text())["key"]  # file rewritten

    def test_wrong_key_in_file_is_a_miss(self, tmp_path):
        """A file whose embedded key disagrees with its filename's key
        (e.g. a digest collision or a hand-edited file) is rejected."""
        convs, graph = _chain_case()
        plan = self._resolve(convs, graph, "offline", tmp_path)
        (f,) = tmp_path.glob("plan-*.json")
        doc = json.loads(f.read_text())
        doc["key"][0] = "0" * 40  # forge the digest
        f.write_text(json.dumps(doc))
        fresh = PlanCache(cache_dir=str(tmp_path))
        assert fresh.get(plan.key) is None

    def test_cached_only_never_searches(self, tmp_path):
        convs, graph = _chain_case()
        out = self._resolve(convs, graph, "cached-only", tmp_path)
        assert out is None
        assert list(tmp_path.glob("plan-*.json")) == []

    def test_plan_json_round_trip(self):
        plan = TunedPlan(
            key=("d" * 40, 8, 8, 1, BUDGET, 4, 4, 4, None, "alg1",
                 None),
            groups=(TunedGroup(0, 2, 4, 8),), dram_bytes=123,
            greedy_dram_bytes=456, candidates=7, search_s=0.5)
        assert TunedPlan.from_json(plan.to_json()) == plan


class TestPartitionMemoKeying:
    def test_memo_not_conflated(self):
        """Satellite 1 regression: the partition memo must key on the
        autotune mode + tuned plan — a tuned partition for the same
        (graph, budget) must not shadow the greedy one or vice versa."""
        convs, graph = _chain_case()
        greedy = partition_graph_cached(graph, BUDGET)
        plan = autotune_plan(convs, graph, onchip_budget_bytes=BUDGET,
                             tile_hw=(4, 4), buffer_tiles=4, budget=64)
        tuned = partition_graph_cached(graph, BUDGET,
                                       autotune="offline", tuned=plan)
        tile_hws = [s.tile_hw for s in tuned
                    if hasattr(s, "tile_hw")]
        assert tile_hws and all(t is not None for t in tile_hws)
        assert all(s.tile_hw is None for s in greedy
                   if hasattr(s, "tile_hw"))
        # greedy again: same memo entry (shared segment objects), and
        # NOT the tuned partition
        greedy2 = partition_graph_cached(graph, BUDGET)
        assert greedy2 == greedy and greedy2 != tuned
        assert all(a is b for a, b in zip(greedy2, greedy))
        tuned2 = partition_graph_cached(graph, BUDGET,
                                        autotune="offline", tuned=plan)
        assert all(a is b for a, b in zip(tuned2, tuned))


class TestConfigValidation:
    @pytest.mark.parametrize("cls", [GraphConfig, PipelineConfig])
    def test_invalid_mode_rejected(self, cls):
        with pytest.raises(ValueError, match="autotune"):
            cls(autotune="aggressive")
        with pytest.raises(ValueError, match="autotune_budget"):
            cls(autotune="offline", autotune_budget=0)
        cls(autotune="cached-only")  # valid modes construct fine


class TestServingAutotune:
    def _case(self, img=16, seed=2):
        cfg = DcnNetConfig(name="vgg19", n_deform=2, img_size=img,
                           width_mult=0.125, num_classes=4)
        params = init_dcn_net(jax.random.PRNGKey(seed), cfg)
        return cfg, params

    def test_stats_and_metrics_surface_autotune(self, tmp_path):
        """Satellite 6: plan_cache_hits / autotune_search_s /
        tuned_groups appear in stats AND metrics_snapshot and agree;
        a second engine over the same cache dir hits the cache and
        reports zero search time."""
        cfg, params = self._case()
        g = GraphConfig(tile=4, buffer_tiles=4, autotune="offline",
                        autotune_budget=64,
                        plan_cache_dir=str(tmp_path))
        eng = DcnServingEngine(params, cfg, graph=g, slots=2)
        s = eng.stats
        assert s["autotune"] == "offline"
        assert s["tuned_groups"] == eng.tuned_groups > 0
        assert s["autotune_search_s"] == eng.tuned_plan.search_s
        snap = eng.metrics_snapshot()
        for k in ("plan_cache_hits", "tuned_groups",
                  "autotune_search_s"):
            assert snap[f"serving.{k}"] == s[k]

        eng2 = DcnServingEngine(params, cfg, graph=g, slots=2)
        s2 = eng2.stats
        assert eng2.tuned_plan == eng.tuned_plan
        assert s2["plan_cache_hits"] >= 1
        assert s2["autotune_search_s"] == 0.0

        x = np.random.default_rng(0).normal(
            size=(16, 16, 3)).astype(np.float32)
        eng2.submit(x)
        (done,) = eng2.drain()
        ref = DcnServingEngine(params, cfg,
                               graph=GraphConfig(tile=4,
                                                 buffer_tiles=4),
                               slots=2)
        ref.submit(x)
        (done_ref,) = ref.drain()
        np.testing.assert_allclose(np.asarray(done.result()),
                                   np.asarray(done_ref.result()),
                                   rtol=1e-4, atol=1e-4)

    def test_autotune_off_engine_untouched(self):
        cfg, params = self._case()
        eng = DcnServingEngine(params, cfg,
                               graph=GraphConfig(tile=4), slots=2)
        s = eng.stats
        assert s["autotune"] == "off"
        assert s["tuned_groups"] == 0
        assert s["plan_cache_hits"] == 0
        assert eng.tuned_plan is None
