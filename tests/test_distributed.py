"""Distributed behaviour on 8 fake host devices.

These run in SUBPROCESSES with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps its single CPU device (per the dry-run isolation rule).
Each scenario script asserts internally and exits 0.
"""

import os
import subprocess
import sys
import textwrap

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    script = textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, (
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


class TestShardMapMoe:
    def test_sharded_equals_local(self):
        _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.launch.mesh import make_host_mesh
            from repro.models.moe import MoeConfig, init_moe, moe_apply
            from repro.models.params import Maker
            mesh = make_host_mesh(4, 2)
            cfg_l = MoeConfig(d_model=16, d_ff=32, n_experts=8, top_k=2,
                              capacity_factor=8.0)
            cfg_s = MoeConfig(d_model=16, d_ff=32, n_experts=8, top_k=2,
                              capacity_factor=8.0, ep=2)
            p = init_moe(Maker("init", jax.random.PRNGKey(0)), cfg_l)
            x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 16))
            out_local, aux_l = moe_apply(p, cfg_l, x)
            out_shard, aux_s = jax.jit(
                lambda p, x: moe_apply(p, cfg_s, x, mesh=mesh))(p, x)
            np.testing.assert_allclose(np.asarray(out_shard),
                                       np.asarray(out_local),
                                       rtol=2e-4, atol=2e-4)
            # aux is a per-shard metric pmean'd across shards; it equals the
            # local value only approximately (nonlinear in the partition).
            np.testing.assert_allclose(float(aux_s), float(aux_l), rtol=0.25)
            print("moe sharded == local OK")
        """)


class TestDistributedTraining:
    def test_train_step_on_mesh_matches_single_device(self):
        _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro import configs
            from repro.configs.base import ShapeCell
            from repro.launch.mesh import make_host_mesh
            from repro.launch.steps import build_step
            from repro.models import lm
            from repro.models.params import Maker
            from repro.optim import AdamWConfig, init_opt_state

            cfg = configs.get_config("qwen3-1.7b", smoke=True)
            shape = ShapeCell("t", "train", 16, 8)
            opt = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=10)
            params = lm.init_lm(Maker("init", jax.random.PRNGKey(0)), cfg)
            batch = {"tokens": jax.random.randint(
                jax.random.PRNGKey(1), (8, 17), 0, cfg.vocab)}

            results = []
            for (d, m) in [(1, 1), (4, 2)]:
                mesh = make_host_mesh(d, m)
                b = build_step(cfg, shape, mesh, opt_cfg=opt,
                               param_dtype=jnp.float32, donate=False)
                opt_state = init_opt_state(params, opt)
                with mesh:
                    new_p, _, metrics = b.fn(params, opt_state, batch)
                results.append((float(metrics["loss"]),
                                jax.tree.leaves(new_p)[0]))
            assert abs(results[0][0] - results[1][0]) < 1e-4, results
            np.testing.assert_allclose(np.asarray(results[0][1]),
                                       np.asarray(results[1][1]),
                                       rtol=1e-4, atol=1e-4)
            print("mesh train == single-device train OK")
        """)

    def test_decode_step_on_mesh(self):
        _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro import configs
            from repro.configs.base import ShapeCell
            from repro.launch.mesh import make_host_mesh
            from repro.launch.steps import build_step
            from repro.models import lm
            from repro.models.params import Maker

            cfg = configs.get_config("gemma2-27b", smoke=True)
            mesh = make_host_mesh(4, 2)
            shape = ShapeCell("d", "decode", 32, 8)
            b = build_step(cfg, shape, mesh, param_dtype=jnp.float32,
                           donate=False)
            params = lm.init_lm(Maker("init", jax.random.PRNGKey(0),
                                      jnp.float32), cfg)
            cache = lm.init_cache(None, cfg, 8, 32, dtype=jnp.bfloat16)
            tok = jax.random.randint(jax.random.PRNGKey(1), (8, 1), 0,
                                     cfg.vocab)
            pos = jnp.zeros((8,), jnp.int32)
            with mesh:
                logits, new_cache = b.fn(params, cache, tok, pos)
            assert np.isfinite(np.asarray(logits)).all()
            print("mesh decode OK")
        """)


class TestElasticRemesh:
    def test_checkpoint_8_to_4_devices(self, tmp_path):
        _run(f"""
            import jax, jax.numpy as jnp, numpy as np
            from repro import checkpoint as ckpt
            from repro import configs
            from repro.configs.base import ShapeCell
            from repro.launch.mesh import make_host_mesh
            from repro.launch.steps import build_step
            from repro.launch.sharding import sharding_rules
            from repro.models import lm
            from repro.models.params import (Maker, abstract_params,
                                             param_axes, tree_shardings)
            from repro.optim import AdamWConfig, init_opt_state

            cfg = configs.get_config("smollm-360m", smoke=True)
            shape = ShapeCell("t", "train", 16, 8)
            opt = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=4)
            params = lm.init_lm(Maker("init", jax.random.PRNGKey(0)), cfg)
            opt_state = init_opt_state(params, opt)
            batch = {{"tokens": jax.random.randint(
                jax.random.PRNGKey(1), (8, 17), 0, cfg.vocab)}}

            # train 2 steps on an 8-device mesh, checkpoint
            mesh8 = make_host_mesh(8, 1)
            b8 = build_step(cfg, shape, mesh8, opt_cfg=opt, donate=False,
                            param_dtype=jnp.float32)
            with mesh8:
                for _ in range(2):
                    params, opt_state, m = b8.fn(params, opt_state, batch)
            ckpt.save(r"{tmp_path}", 2, {{"params": params, "opt": opt_state}})

            # "pod failure": resume on HALF the devices (4-device mesh)
            mesh4 = make_host_mesh(4, 1)
            rules = sharding_rules(cfg, kind="train")
            axes = param_axes(lambda mk: lm.init_lm(mk, cfg))
            ab = abstract_params(lambda mk: lm.init_lm(mk, cfg),
                                 dtype=jnp.float32)
            pshard = tree_shardings(axes, ab, rules, mesh4)
            from jax.sharding import NamedSharding, PartitionSpec as P
            oshard = {{"step": NamedSharding(mesh4, P()),
                       "m": pshard, "v": pshard}}
            state = ckpt.restore(r"{tmp_path}", 2,
                                 {{"params": params, "opt": opt_state}},
                                 shardings={{"params": pshard,
                                             "opt": oshard}})
            b4 = build_step(cfg, shape, mesh4, opt_cfg=opt, donate=False,
                            param_dtype=jnp.float32)
            with mesh4:
                p2, o2, m2 = b4.fn(state["params"], state["opt"], batch)
            assert np.isfinite(float(m2["loss"]))
            print("elastic 8->4 resume OK, loss", float(m2["loss"]))
        """)


class TestGradientCompression:
    def test_compressed_psum_close_to_exact(self):
        _run("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.compat import shard_map
            from repro.launch.mesh import make_host_mesh
            from repro.optim import compressed_psum_tree, init_error_state

            mesh = make_host_mesh(8, 1)
            g_global = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

            def body(g):
                grads = {"w": g[0]}
                err = {"w": jnp.zeros_like(g[0])}
                summed, new_err = compressed_psum_tree(grads, err, ("data",))
                return summed["w"], new_err["w"][None]

            out, err = jax.jit(shard_map(
                body, mesh=mesh, in_specs=P("data", None),
                out_specs=(P(), P("data", None))))(g_global)
            want = g_global.mean(0)  # decoded psum is the DP mean
            np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                       atol=0.05)
            print("int8 compressed psum OK, max err",
                  float(jnp.abs(out - want).max()))
        """)
