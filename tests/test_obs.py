"""Telemetry layer (`repro.obs`): tracer, metrics, Chrome-trace export.

Unit-level contracts (disabled-path no-op, nested/threaded span
parenting, percentile edge cases, registry typing) plus the integration
acceptance of ISSUE 7: a real executor/serving run records the expected
span names, exports schema-valid Perfetto JSON, and the engine's
``metrics_snapshot()`` reproduces every counter the benchmark gates.
"""

import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scheduler
from repro.core.deform import DeformableConvParams, randomize_offset_conv
from repro.models.dcn_models import DcnNetConfig, init_dcn_net
from repro.obs import (Histogram, MetricsRegistry, Span, Stopwatch,
                       Tracer, chrome_trace, default_registry, get_tracer,
                       global_tracer, percentile, use_tracer,
                       validate_chrome_trace, write_chrome_trace)
from repro.runtime import GraphConfig, build_graph
from repro.runtime.fused_exec import run_graph
from repro.runtime.trace import OverlapSpans
from repro.serving import DcnServingEngine


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

class TestTracer:
    def test_nested_span_parenting(self):
        tr = Tracer(enabled=True)
        with tr.span("outer", k=1):
            with tr.span("inner"):
                pass
            with tr.span("inner2"):
                pass
        spans = {s.name: s for s in tr.snapshot()}
        assert set(spans) == {"outer", "inner", "inner2"}
        outer = spans["outer"]
        assert outer.parent is None and outer.attrs == {"k": 1}
        assert spans["inner"].parent == outer.sid
        assert spans["inner2"].parent == outer.sid
        # children finish (and record) before the enclosing span
        assert spans["inner"].dur <= outer.dur

    def test_threaded_spans_are_roots_on_own_track(self):
        tr = Tracer(enabled=True)

        def worker():
            with tr.span("worker.prepass"):
                pass

        with tr.span("main.execute"):
            t = threading.Thread(target=worker, name="stager")
            t.start()
            t.join()
        spans = {s.name: s for s in tr.snapshot()}
        w, m = spans["worker.prepass"], spans["main.execute"]
        # parenting never crosses threads: the worker span is a root on
        # its own thread track even though it ran inside main.execute.
        assert w.parent is None
        assert w.tid != m.tid
        assert w.thread_name == "stager"

    def test_disabled_tracer_records_nothing(self):
        tr = Tracer(enabled=False)
        with tr.span("a", k=1) as sp:
            sp.set(more=2)
        tr.instant("marker")
        with tr.timed("b") as sw:
            pass
        assert len(tr) == 0
        assert tr.snapshot() == []
        # span() hands back one shared null singleton: no allocation
        assert tr.span("x") is tr.span("y")
        # ...but timed() still measured
        assert isinstance(sw, Stopwatch) and sw.dur >= 0.0

    def test_disabled_span_overhead_bounded(self):
        """ISSUE 7 acceptance: the disabled path must be a near-free
        no-op. 200k disabled spans in well under a second (~µs each)
        is a generous ceiling that still catches an accidental clock
        read or allocation per call."""
        tr = Tracer(enabled=False)
        t0 = time.perf_counter()
        for _ in range(200_000):
            with tr.span("hot"):
                pass
        wall = time.perf_counter() - t0
        assert len(tr) == 0
        assert wall < 1.0

    def test_timed_measures_duration_when_disabled(self):
        tr = Tracer(enabled=False)
        with tr.timed("prepass", unit=3) as sw:
            time.sleep(0.002)
        assert sw.dur >= 0.002
        assert sw.name == "prepass" and sw.attrs == {"unit": 3}
        assert len(tr) == 0        # measured, not recorded

    def test_use_tracer_is_thread_local(self):
        tr = Tracer(enabled=True)
        assert get_tracer() is global_tracer()
        seen = {}

        def worker():
            seen["worker"] = get_tracer()

        with use_tracer(tr):
            assert get_tracer() is tr
            t = threading.Thread(target=worker)
            t.start()
            t.join()
            inner = Tracer(enabled=True)
            with use_tracer(inner):
                assert get_tracer() is inner
            assert get_tracer() is tr
        assert get_tracer() is global_tracer()
        # the override never leaks onto other threads
        assert seen["worker"] is global_tracer()

    def test_spans_since_and_clear(self):
        tr = Tracer(enabled=True)
        with tr.span("a"):
            pass
        mark = len(tr)
        with tr.span("b"):
            pass
        assert [s.name for s in tr.spans_since(mark)] == ["b"]
        tr.clear()
        assert len(tr) == 0

    def test_concurrent_recording_is_complete(self):
        tr = Tracer(enabled=True)
        n_threads, per_thread = 8, 50

        def worker(t):
            for k in range(per_thread):
                with tr.span(f"w{t}", k=k):
                    pass

        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        spans = tr.snapshot()
        assert len(spans) == n_threads * per_thread
        assert len({s.sid for s in spans}) == len(spans)


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

class TestPercentile:
    def test_empty_is_none(self):
        assert percentile([], 50) is None

    def test_singleton_is_the_sample(self):
        for q in (0, 50, 99, 100):
            assert percentile([0.7], q) == 0.7

    def test_matches_numpy_linear_interpolation(self):
        rng = np.random.default_rng(0)
        xs = rng.normal(size=37).tolist()
        for q in (0, 10, 50, 90, 95, 99, 100):
            assert percentile(xs, q) == pytest.approx(
                float(np.percentile(xs, q)), abs=1e-12)


class TestMetricsRegistry:
    def test_counter_gauge_histogram(self):
        reg = MetricsRegistry()
        c = reg.counter("c", help="a counter")
        c.inc()
        c.inc(3)
        c.bump()                       # pre-registry alias
        assert c.value == c.count == 5
        g = reg.gauge("g")
        g.set(2.5)
        g.add(0.5)
        assert g.value == 3.0
        h = reg.histogram("h")
        for v in (1.0, 2.0, 3.0):
            h.observe(v)
        assert h.count == 3 and h.mean == 2.0
        snap = reg.snapshot()
        assert snap["c"] == 5 and snap["g"] == 3.0
        assert snap["h"]["count"] == 3 and snap["h"]["p50"] == 2.0

    def test_get_or_create_identity_and_kind_conflict(self):
        reg = MetricsRegistry()
        assert reg.counter("x") is reg.counter("x")
        with pytest.raises(TypeError, match="already registered"):
            reg.gauge("x")

    def test_register_external_metric(self):
        reg = MetricsRegistry()
        h = Histogram("lat")
        reg.register("lat", h)
        reg.register("lat", h)        # same object: idempotent
        with pytest.raises(ValueError, match="already registered"):
            reg.register("lat", Histogram("other"))
        assert reg.get("lat") is h
        assert "lat" in reg.names()

    def test_empty_histogram_summary_is_none(self):
        s = Histogram("h").summary()
        assert s == {"count": 0, "mean": None, "p50": None, "p95": None,
                     "p99": None}


# ---------------------------------------------------------------------------
# OverlapSpans re-derivation
# ---------------------------------------------------------------------------

class TestOverlapSpans:
    def _span(self, name, dur, **attrs):
        return Span(name=name, ts=0.0, dur=dur, attrs=attrs)

    def test_from_spans_and_device_split(self):
        o = OverlapSpans.from_spans([
            self._span("prepass", 0.5),
            self._span("prepass.wait", 0.2),
            self._span("prepass.schedule", 0.3, backend="host"),
            self._span("prepass.schedule", 0.1, backend="device"),
            self._span("dispatch.batched", 9.0),   # unrelated: ignored
        ])
        assert o.prepass_s == pytest.approx(0.5)
        assert o.prepass_wait_s == pytest.approx(0.2)
        assert o.schedule_s == pytest.approx(0.4)
        assert o.schedule_device_s == pytest.approx(0.1)

    def test_merge_accumulates(self):
        a = OverlapSpans.from_spans([self._span("prepass", 1.0)])
        b = OverlapSpans.from_spans(
            [self._span("prepass.schedule", 0.25, backend="device")])
        a.merge(b)
        assert a.prepass_s == pytest.approx(1.0)
        assert a.schedule_s == pytest.approx(0.25)
        assert a.schedule_device_s == pytest.approx(0.25)

    def test_add_span_accepts_stopwatch(self):
        """timed() degrades to Stopwatch when tracing is off; the
        overlap accounting must keep working on it."""
        o = OverlapSpans()
        with Stopwatch("prepass") as sw:
            time.sleep(0.001)
        o.add_span(sw)
        assert o.prepass_s == pytest.approx(sw.dur)


# ---------------------------------------------------------------------------
# Chrome-trace export
# ---------------------------------------------------------------------------

class TestChromeTraceExport:
    def _trace(self):
        tr = Tracer(enabled=True)
        with tr.timed("serve.step", step=0, width=2) as sp:
            with tr.span("dispatch.batch_fused", grid_rows=8):
                pass
            sp.set(dispatches=4, dram_bytes=1024)
        tr.instant("serve.submit", rid=1)

        def worker():
            with tr.span("prepass", unit=0):
                pass

        t = threading.Thread(target=worker, name="stager")
        t.start()
        t.join()
        return tr

    def test_schema_valid_and_track_layout(self):
        tr = self._trace()
        doc = chrome_trace(tr)
        assert validate_chrome_trace(doc) == []
        evs = doc["traceEvents"]
        meta = [e for e in evs if e["ph"] == "M"]
        names = {(e["pid"], e["name"], e["args"]["name"]) for e in meta}
        assert (0, "process_name", "host threads") in names
        assert (1, "process_name", "engine steps") in names
        assert (1, "thread_name", "step 0") in names
        assert any(n == (0, "thread_name", "stager") for n in names)
        # serve.step is duplicated onto the per-step track (pid 1)
        steps = [e for e in evs
                 if e["ph"] == "X" and e["name"] == "serve.step"]
        assert sorted(e["pid"] for e in steps) == [0, 1]
        assert all(e["args"]["dispatches"] == 4 for e in steps)
        # complete events: µs timebase relative to the earliest span
        xs = [e for e in evs if e["ph"] == "X"]
        assert min(e["ts"] for e in xs) == 0.0
        assert all(e["dur"] >= 0 for e in xs)
        # compact thread ids in first-appearance order
        tids = {e["tid"] for e in xs if e["pid"] == 0}
        assert tids == set(range(len(tids)))
        inst = [e for e in evs if e["ph"] == "i"]
        assert len(inst) == 1 and inst[0]["name"] == "serve.submit"

    def test_write_chrome_trace_roundtrip(self, tmp_path):
        path = tmp_path / "trace.json"
        doc = write_chrome_trace(str(path), self._trace())
        with open(path) as f:
            loaded = json.load(f)
        assert loaded == json.loads(json.dumps(doc))
        assert validate_chrome_trace(loaded) == []

    def test_validate_rejects_malformed(self):
        assert validate_chrome_trace([]) != []
        assert validate_chrome_trace({"no": "events"}) != []
        bad = {"traceEvents": [
            {"name": "x", "ph": "X", "ts": -1.0, "dur": 1.0,
             "pid": 0, "tid": 0},
            {"name": "y", "ph": "Z", "pid": 0, "tid": 0},
            {"ph": "X", "ts": 0.0, "dur": "oops", "pid": 0, "tid": "a"},
        ]}
        problems = validate_chrome_trace(bad)
        assert len(problems) >= 3

    def test_empty_tracer_still_valid(self):
        doc = chrome_trace(Tracer(enabled=True))
        assert validate_chrome_trace(doc) == []


# ---------------------------------------------------------------------------
# Integration: executor + serving runs through the telemetry layer
# ---------------------------------------------------------------------------

def _dcn_case(n_deform=2, img=16, seed=2, offset_scale=2.0):
    cfg = DcnNetConfig(name="vgg19", n_deform=n_deform, img_size=img,
                       width_mult=0.125, num_classes=4)
    key = jax.random.PRNGKey(seed)
    params = init_dcn_net(key, cfg)
    params["convs"] = [
        randomize_offset_conv(p, jax.random.fold_in(key, 100 + i),
                              offset_scale / p.w.shape[2])
        if isinstance(p, DeformableConvParams) else p
        for i, p in enumerate(params["convs"])]
    return cfg, params


@pytest.fixture(scope="module")
def dcn_setup():
    return _dcn_case()


class TestExecutorTelemetry:
    def test_run_graph_records_expected_spans(self, dcn_setup):
        cfg, params = dcn_setup
        graph = build_graph(cfg)
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(2, 16, 16, 3)).astype(np.float32))
        tr = Tracer(enabled=True)
        y, trace = run_graph(params["convs"], graph, x,
                             config=GraphConfig(tile=4,
                                                use_schedule_cache=False),
                             return_trace=True, tracer=tr)
        jax.block_until_ready(y)
        names = {s.name for s in tr.snapshot()}
        assert {"prepass", "prepass.wait", "prepass.tdt",
                "prepass.schedule", "pack"} <= names
        assert any(n.startswith("dispatch.") for n in names)
        # the trace's overlap accounting is re-derived from these spans
        derived = OverlapSpans.from_spans(tr.snapshot())
        assert trace.overlap.prepass_s == pytest.approx(
            derived.prepass_s)
        assert trace.overlap.schedule_s == pytest.approx(
            derived.schedule_s)
        # ...and the whole run exports as loadable Perfetto JSON
        assert validate_chrome_trace(chrome_trace(tr)) == []

    def test_disabled_tracer_keeps_overlap_exact(self, dcn_setup):
        """With tracing off the executors still measure overlap via
        Stopwatch degradation: zero spans, non-zero accounting."""
        cfg, params = dcn_setup
        graph = build_graph(cfg)
        x = jnp.asarray(np.random.default_rng(1).normal(
            size=(1, 16, 16, 3)).astype(np.float32))
        tr = Tracer(enabled=False)
        _, trace = run_graph(params["convs"], graph, x,
                             config=GraphConfig(tile=4),
                             return_trace=True, tracer=tr)
        assert len(tr) == 0
        assert trace.overlap.prepass_s > 0.0

    def test_registry_counts_host_schedule_builds(self, dcn_setup):
        """The smoke-gated counter lives in the default registry and
        stays flat on the device-scheduling hot path."""
        cfg, params = dcn_setup
        graph = build_graph(cfg)
        x = jnp.asarray(np.random.default_rng(2).normal(
            size=(1, 16, 16, 3)).astype(np.float32))
        reg = default_registry()
        assert reg.get("host_schedule_builds") is \
            scheduler.host_schedule_builds
        gcfg = GraphConfig(tile=4, dispatch="batch_fused",
                           schedule_backend="device",
                           use_schedule_cache=False)
        run_graph(params["convs"], graph, x, config=gcfg)  # warm compile
        c0 = reg.snapshot()["host_schedule_builds"]
        y = run_graph(params["convs"], graph, x, config=gcfg)
        jax.block_until_ready(y)
        assert reg.snapshot()["host_schedule_builds"] == c0


class TestServingTelemetry:
    def _images(self, n, seed=0):
        rng = np.random.default_rng(seed)
        return rng.normal(size=(n, 16, 16, 3)).astype(np.float32)

    def _run(self, dcn_setup, tracer):
        cfg, params = dcn_setup
        eng = DcnServingEngine(params, cfg, graph=GraphConfig(tile=4),
                               slots=4, tracer=tracer)
        for i in range(3):
            eng.submit(self._images(1, seed=i))
        eng.submit(self._images(1, seed=0))     # replay: cache hit
        eng.step()
        eng.drain()
        return eng

    def test_serving_spans_timeline_and_export(self, dcn_setup):
        tr = Tracer(enabled=True)
        eng = self._run(dcn_setup, tr)
        names = {s.name for s in tr.snapshot()}
        assert {"serve.submit", "serve.admit", "serve.step",
                "serve.drain"} <= names
        steps = [s for s in tr.snapshot() if s.name == "serve.step"]
        assert steps and all("dispatches" in s.attrs
                             and "dram_bytes" in s.attrs for s in steps)
        # per-step timeline mirrors the spans
        assert len(eng.timeline) == len(steps) == eng.steps
        for entry in eng.timeline:
            assert {"step", "width", "wall_s", "dispatches",
                    "dram_bytes", "image_hits", "schedule_backend",
                    "dispatch_spans"} <= set(entry)
            assert entry["dispatches"] > 0 and entry["wall_s"] > 0
            for dsp in entry["dispatch_spans"]:
                assert dsp["name"].startswith("dispatch.")
                assert dsp["dur_s"] >= 0.0
        doc = chrome_trace(tr)
        assert validate_chrome_trace(doc) == []
        # every serving step shows up on the engine-steps process
        pid1 = [e for e in doc["traceEvents"]
                if e.get("pid") == 1 and e.get("ph") == "X"]
        assert len(pid1) == len(steps)

    def test_metrics_snapshot_reproduces_stats(self, dcn_setup):
        """ISSUE 7 acceptance: every counter the smoke gates read off
        ``stats`` is reproduced by ``metrics_snapshot()``."""
        eng = self._run(dcn_setup, Tracer(enabled=True))
        s = eng.stats
        snap = eng.metrics_snapshot()
        assert snap["serving.requests"] == s["requests"]
        assert snap["serving.images"] == s["images"]
        assert snap["serving.steps"] == s["steps"]
        assert snap["serving.kernel_dispatches"] == s["kernel_dispatches"]
        assert snap["schedule_cache.hits"] == s["schedule_cache_hits"]
        assert snap["schedule_cache.misses"] == s["schedule_cache_misses"]
        assert snap["schedule_cache.image_hit_rate"] == pytest.approx(
            s["image_hit_rate"])
        assert snap["serving.host_schedule_builds"] == \
            s["host_schedule_builds"]
        assert snap["serving.dispatches_per_batch"] == pytest.approx(
            s["dispatches_per_batch"])
        assert snap["serving.queue_depth"] == s["queue_depth"] == 0
        assert snap["serving.latency_s"]["count"] == \
            s["latency"]["count"] == s["requests"]

    def test_disabled_tracer_serving_stays_quiet(self, dcn_setup):
        tr = Tracer(enabled=False)
        eng = self._run(dcn_setup, tr)
        assert len(tr) == 0
        assert eng.timeline == []
        assert eng.stats["requests"] == 4
