"""Serving-engine behaviour: continuous batching + bugfix regressions.

The continuous-batching ``DcnServingEngine`` (submit queue -> slot pool
-> one ``batch_fused`` ragged grid per step) must produce the same
results as serve-one-at-a-time ``infer``, return every request exactly
once, admit mid-flight, and keep its coalesced traces exactly equal to
the DRAM simulator. The DecodeEngine regressions cover per-request
temperature and empty-prompt rejection.
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.deform import DeformableConvParams, randomize_offset_conv
from repro.core.simulator import simulate_network
from repro.models import lm
from repro.models.dcn_models import DcnNetConfig, init_dcn_net
from repro.models.params import Maker
from repro.runtime import GraphConfig, LatencyStats, build_graph
from repro.runtime.fused_exec import network_sim_specs
from repro.runtime.graph import partition_graph, partition_graph_cached
from repro.serving import DcnServingEngine, DecodeEngine, Request


def _dcn_case(n_deform=2, img=16, seed=2, offset_scale=2.0):
    """Tiny VGG19-style DCN with randomized offset convs so the sampling
    pattern (and therefore the schedule-cache keys) depends on input."""
    cfg = DcnNetConfig(name="vgg19", n_deform=n_deform, img_size=img,
                       width_mult=0.125, num_classes=4)
    key = jax.random.PRNGKey(seed)
    params = init_dcn_net(key, cfg)
    params["convs"] = [
        randomize_offset_conv(p, jax.random.fold_in(key, 100 + i),
                              offset_scale / p.w.shape[2])
        if isinstance(p, DeformableConvParams) else p
        for i, p in enumerate(params["convs"])]
    return cfg, params


@pytest.fixture(scope="module")
def dcn_setup():
    return _dcn_case()


def _engine(dcn_setup, **kw):
    cfg, params = dcn_setup
    kw.setdefault("graph", GraphConfig(tile=4))
    return DcnServingEngine(params, cfg, **kw)


def _images(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, 16, 16, 3)).astype(np.float32)


class TestContinuousBatching:
    def test_coalesced_results_match_infer(self, dcn_setup):
        """Concurrent small requests coalesced into one fused grid give
        bitwise the same per-image math as a lone batch_fused infer."""
        cfg, params = dcn_setup
        eng = _engine(dcn_setup, slots=4)
        xs = _images(3, seed=1)
        reqs = [eng.submit(xs[i]) for i in range(3)]
        done = eng.drain()
        assert sorted(r.rid for r in done) == [r.rid for r in reqs]

        ref_eng = DcnServingEngine(
            params, cfg, graph=GraphConfig(tile=4, dispatch="batch_fused"))
        ref = np.asarray(ref_eng.infer(jnp.asarray(xs)))
        got = np.concatenate([r.result() for r in reqs])
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)
        # the three single-image requests shared each step's dispatches
        assert eng.steps == 1
        assert eng.stats["latency"]["count"] == 3

    def test_pool_of_one_serves_sequentially(self, dcn_setup):
        eng = _engine(dcn_setup, slots=1)
        reqs = [eng.submit(_images(1, seed=s)) for s in range(3)]
        done = eng.drain()
        assert [r.rid for r in done] == [r.rid for r in reqs]
        assert eng.steps == 3
        assert all(r.done and r.result().shape == (1, 4) for r in reqs)

    def test_more_requests_than_slots(self, dcn_setup):
        """A 6-image request on a 4-slot pool splits across steps; the
        queue drains in submit order and nothing is lost."""
        eng = _engine(dcn_setup, slots=4)
        big = eng.submit(_images(6, seed=3))
        small = eng.submit(_images(1, seed=4))
        assert eng.queue_depth == 7
        done = eng.drain()
        assert {r.rid for r in done} == {big.rid, small.rid}
        assert eng.steps == 2 and eng.queue_depth == 0
        assert big.result().shape == (6, 4)

    def test_mid_flight_admission(self, dcn_setup):
        """A request submitted between steps joins the next step's
        coalesced batch alongside the in-flight request's remainder."""
        eng = _engine(dcn_setup, slots=4)
        big = eng.submit(_images(6, seed=5))
        first = eng.step()
        assert first == [] and not big.done       # 4 of 6 images served
        late = eng.submit(_images(1, seed=6))
        second = eng.step()
        # the step served big's remaining 2 images + late's 1 together
        assert {r.rid for r in second} == {big.rid, late.rid}
        assert eng.steps == 2 and eng.images == 7

    def test_cache_hit_request_coalesced_with_miss(self):
        """A replayed image (full schedule-cache hit) coalesced in the
        same step as a fresh image: the hit skips scheduling, the pair
        still shares one fused dispatch, and both results are right.

        Needs deform layers on planes > 1x1 (n_deform=6 reaches the
        2x2 stage), where the quantized coords digest actually depends
        on the input — at 1x1 every image quantizes identically and
        nothing can miss after warmup.
        """
        cfg, params = _dcn_case(n_deform=6, seed=5, offset_scale=4.0)
        eng = DcnServingEngine(params, cfg, graph=GraphConfig(tile=4),
                               slots=4)
        x_seen = _images(1, seed=7)
        eng.submit(x_seen)
        eng.drain()                               # warm the cache
        before = eng.cache.info()

        x_new = _images(1, seed=8)
        r_hit = eng.submit(x_seen)
        r_miss = eng.submit(x_new)
        done = eng.step()
        assert {r.rid for r in done} == {r_hit.rid, r_miss.rid}
        after = eng.cache.info()
        gained = after["image_hits"] - before["image_hits"]
        looked = after["image_lookups"] - before["image_lookups"]
        assert gained >= 1                        # the replay hit
        assert looked > gained                    # the fresh image missed
        ref_eng = DcnServingEngine(params, cfg, graph=GraphConfig(tile=4))
        ref = np.asarray(ref_eng.infer(jnp.asarray(x_seen)))
        np.testing.assert_allclose(r_hit.result(), ref,
                                   rtol=2e-4, atol=2e-4)

    def test_drain_returns_each_request_exactly_once(self, dcn_setup):
        eng = _engine(dcn_setup, slots=2)
        reqs = [eng.submit(_images(n, seed=10 + n)) for n in (1, 3, 1, 2)]
        done = eng.drain()
        rids = [r.rid for r in done]
        assert sorted(rids) == sorted(r.rid for r in reqs)
        assert len(rids) == len(set(rids))
        assert eng.drain() == []                  # nothing served twice
        assert eng.stats["latency"]["count"] == len(reqs)

    def test_latency_monotone_with_queueing(self, dcn_setup):
        """Submit->result latency includes queue wait: on a pool of 1,
        the second of two same-instant submissions waits through the
        first's step and observes strictly larger latency."""
        now = [0.0]
        eng = _engine(dcn_setup, slots=1, clock=lambda: now[0])
        first = eng.submit(_images(1, seed=20))
        second = eng.submit(_images(1, seed=21))
        now[0] = 1.0
        eng.step()                                # serves first
        now[0] = 2.0
        eng.step()                                # serves second
        assert first.done and second.done
        assert first.latency_s == 1.0
        assert second.latency_s == 2.0

    def test_concurrent_submit_is_thread_safe(self, dcn_setup):
        """Many submitter threads racing the serving loop: every image
        is served exactly once and the shared counters stay exact."""
        eng = _engine(dcn_setup, slots=4)
        n_threads, per_thread = 4, 3
        reqs: list = []
        lock = threading.Lock()

        def client(seed):
            for k in range(per_thread):
                r = eng.submit(_images(1, seed=100 * seed + k))
                with lock:
                    reqs.append(r)

        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        done: list = []
        while any(t.is_alive() for t in threads):
            done.extend(eng.step())
        for t in threads:
            t.join()
        done.extend(eng.drain())

        total = n_threads * per_thread
        assert len(reqs) == total
        assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
        s = eng.stats
        assert s["requests"] == total and s["images"] == total
        assert s["latency"]["count"] == total
        assert all(r.done for r in reqs)

    def test_stats_snapshot_consistent_under_concurrency(self, dcn_setup):
        """``stats`` is one atomic snapshot taken under the engine lock:
        readers racing submitters and the serving loop never observe a
        torn view (e.g. a request counted but its queue slot missing, or
        more finished latencies than admitted requests)."""
        eng = _engine(dcn_setup, slots=4)
        n_threads, per_thread = 3, 3
        stop = threading.Event()
        torn: list[str] = []

        def client(seed):
            for k in range(per_thread):
                eng.submit(_images(1, seed=500 * seed + k))

        def reader():
            while not stop.is_set():
                s = eng.stats
                # in_flight = admitted - finished; both legs come from
                # the same locked snapshot, so it can never go negative
                # or exceed the admitted total.
                in_flight = s["requests"] - s["latency"]["count"]
                if not 0 <= in_flight <= n_threads * per_thread:
                    torn.append(f"in_flight={in_flight}")
                if s["images"] > s["requests"]:
                    torn.append(f"images={s['images']}>{s['requests']}")
                if s["queue_depth"] < 0:
                    torn.append(f"queue_depth={s['queue_depth']}")

        submitters = [threading.Thread(target=client, args=(t,))
                      for t in range(n_threads)]
        readers = [threading.Thread(target=reader) for _ in range(2)]
        for t in submitters + readers:
            t.start()
        done: list = []
        while any(t.is_alive() for t in submitters):
            done.extend(eng.step())
        for t in submitters:
            t.join()
        done.extend(eng.drain())
        stop.set()
        for t in readers:
            t.join()

        assert torn == []
        total = n_threads * per_thread
        assert len(done) == total
        s = eng.stats
        assert s["requests"] == total
        assert s["latency"]["count"] == total
        assert s["queue_depth"] == 0

    def test_step_trace_equals_dram_simulator(self, dcn_setup):
        """The coalesced serving step's executed trace must equal the
        network DRAM simulator exactly, per image — coalescing shares
        dispatches, never schedules."""
        eng = _engine(dcn_setup, slots=4)
        for i in range(3):
            eng.submit(_images(1, seed=30 + i))
        eng.step()
        tr = eng.last_trace
        assert tr is not None and len(tr.groups) > 0
        sim = simulate_network(network_sim_specs(tr),
                               boundary_bytes=tr.boundary_bytes,
                               fused=True)
        for gt, rep in zip(tr.groups, sim.groups):
            assert gt.fifo_replay().loads == rep.tile_loads
            assert gt.input_load_bytes == rep.input_read_bytes
        assert tr.total_dram_bytes == sim.total_dram_bytes

    def test_submit_validation(self, dcn_setup):
        eng = _engine(dcn_setup)
        with pytest.raises(ValueError, match="empty request"):
            eng.submit(np.zeros((0, 16, 16, 3), np.float32))
        with pytest.raises(ValueError, match="request images"):
            eng.submit(np.zeros((1, 8, 8, 3), np.float32))
        with pytest.raises(ValueError, match="slots"):
            _engine(dcn_setup, slots=0)
        with pytest.raises(RuntimeError, match="not finished"):
            eng.submit(_images(1)).result()

    def test_infer_counters_locked_and_compatible(self, dcn_setup):
        """infer() keeps its serve-one-at-a-time stats semantics (and
        its counter updates now run under the engine lock)."""
        eng = _engine(dcn_setup)
        x = jnp.asarray(_images(2, seed=40))
        eng.infer(x)
        eng.infer(x)
        s = eng.stats
        assert s["requests"] == 2 and s["images"] == 4
        assert s["dispatches_per_batch"] == s["kernel_dispatches"] / 2


class TestDecodeEngineRegressions:
    @pytest.fixture(scope="class")
    def lm_setup(self):
        cfg = configs.get_config("smollm-360m", smoke=True)
        params = lm.init_lm(Maker("init", jax.random.PRNGKey(40)), cfg)
        return cfg, params

    def test_empty_prompt_rejected_at_submit(self, lm_setup):
        cfg, params = lm_setup
        eng = DecodeEngine(params, cfg, batch=2, max_len=16)
        with pytest.raises(ValueError, match="empty prompt"):
            eng.submit(Request(0, []))
        assert eng.queue == []                    # nothing half-admitted

    def test_temperature_zero_stays_argmax(self, lm_setup):
        """temp=0 must be deterministic greedy regardless of rng seed."""
        cfg, params = lm_setup
        outs = []
        for seed in (0, 1):
            eng = DecodeEngine(params, cfg, batch=2, max_len=32,
                               rng_seed=seed)
            eng.submit(Request(0, [3, 5], max_new=4, temperature=0.0))
            outs.append(eng.run()[0].out)
        assert outs[0] == outs[1]

    def test_high_temperature_actually_samples(self, lm_setup):
        """Regression: step() used to hardcode temperature 0, so every
        request decoded greedily. High temp must vary across rng seeds."""
        cfg, params = lm_setup
        seen = set()
        for seed in range(4):
            eng = DecodeEngine(params, cfg, batch=2, max_len=64,
                               rng_seed=seed)
            eng.submit(Request(0, [3, 5], max_new=12, temperature=5.0))
            seen.add(tuple(eng.run()[0].out))
        assert len(seen) > 1

    def test_mixed_temperatures_per_slot(self, lm_setup):
        """A hot request sharing the batch must not perturb a greedy
        one: sampling is per-slot, not per-batch."""
        cfg, params = lm_setup
        eng0 = DecodeEngine(params, cfg, batch=2, max_len=32)
        eng0.submit(Request(0, [3, 5], max_new=4, temperature=0.0))
        greedy = eng0.run()[0].out

        eng = DecodeEngine(params, cfg, batch=2, max_len=64, rng_seed=7)
        eng.submit(Request(0, [3, 5], max_new=4, temperature=0.0))
        eng.submit(Request(1, [3, 5], max_new=4, temperature=5.0))
        res = {r.rid: r.out for r in eng.run()}
        assert res[0] == greedy


class TestLatencyStats:
    def test_percentiles_and_summary(self):
        ls = LatencyStats()
        # Empty stats have NO percentiles: None, not a fabricated 0.0
        # that would read as a real (excellent) latency downstream.
        assert ls.summary() == {"count": 0, "mean_s": None, "p50_s": None,
                                "p95_s": None, "p99_s": None}
        assert ls.mean_s is None
        assert ls.percentile_s(99) is None
        for v in range(1, 101):
            ls.add(v / 100.0)
        s = ls.summary()
        assert s["count"] == 100
        assert abs(s["mean_s"] - 0.505) < 1e-9
        assert s["p50_s"] <= s["p95_s"] <= s["p99_s"] <= 1.0
        assert abs(ls.percentile_s(50) - 0.505) < 0.02

    def test_single_sample_is_every_percentile(self):
        ls = LatencyStats()
        ls.add(0.25)
        for q in (0, 1, 50, 95, 99, 100):
            assert ls.percentile_s(q) == 0.25
        s = ls.summary()
        assert s == {"count": 1, "mean_s": 0.25, "p50_s": 0.25,
                     "p95_s": 0.25, "p99_s": 0.25}


class TestPartitionMemo:
    def test_cached_partition_matches_and_memoizes(self, dcn_setup):
        cfg, _ = dcn_setup
        graph = build_graph(cfg)
        budget = GraphConfig().onchip_budget_bytes
        ref = partition_graph(graph, budget, dtype_bytes=4)
        got = partition_graph_cached(graph, budget, dtype_bytes=4)
        assert got == ref
        again = partition_graph_cached(graph, budget, dtype_bytes=4)
        # frozen segments are shared, not rebuilt
        assert all(a is b for a, b in zip(got, again))
