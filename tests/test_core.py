"""Core paper machinery: deformable conv Eq.1-3, TDT, Algorithm 1,
traffic simulator, fusion planner."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DramEnergyModel, FifoBuffer, LayerShape,
                        access_histogram, bilinear_sample, bli_coefficients,
                        deformable_conv2d, dram_energy,
                        fused_deformable_conv2d, init_deformable_conv,
                        make_square_grid, offsets_to_coords,
                        per_pixel_input_tiles, plan_fusion, schedule_tiles,
                        sequential_schedule, simulate_strategies,
                        tdt_from_coords)
from repro.core.deform import conv2d
from repro.core.fusion import FusionMode


def _rand_coords(key, h, w, kk, max_r=None):
    hi = jnp.array([h - 1.001, w - 1.001])
    return jax.random.uniform(key, (h, w, kk, 2)) * hi


class TestDeformableConv:
    def test_bli_coefficients_sum_to_one(self):
        coords = jax.random.uniform(jax.random.PRNGKey(0), (50, 2)) * 10
        _, coeffs = bli_coefficients(coords)
        np.testing.assert_allclose(coeffs.sum(-1), 1.0, rtol=1e-6)

    def test_bli_integer_coords_exact(self):
        """At integer coordinates BLI returns the feature exactly."""
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 3))
        rr, cc = jnp.meshgrid(jnp.arange(8.0), jnp.arange(8.0), indexing="ij")
        coords = jnp.stack([rr, cc], -1)[None, :, :, None, :]
        out = bilinear_sample(x, coords)
        np.testing.assert_allclose(out[:, :, :, 0], x, atol=1e-6)

    def test_zero_offsets_equal_standard_conv(self):
        """With zero offsets the deformable conv IS the standard conv."""
        key = jax.random.PRNGKey(2)
        params = init_deformable_conv(key, 8, 16)  # w_off zero-init
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 10, 10, 8))
        y_def = deformable_conv2d(x, params)
        y_std = conv2d(x, params.w, params.b)
        # Border differs (clamped sampling vs zero pad); compare interior.
        np.testing.assert_allclose(y_def[:, 2:-2, 2:-2], y_std[:, 2:-2, 2:-2],
                                   rtol=1e-4, atol=1e-4)

    def test_dcn1_vs_dcn2_offset_channels(self):
        p1 = init_deformable_conv(jax.random.PRNGKey(0), 4, 4, variant="dcn1")
        p2 = init_deformable_conv(jax.random.PRNGKey(0), 4, 4, variant="dcn2")
        assert p1.w_off.shape[-1] == 2
        assert p2.w_off.shape[-1] == 18

    def test_fused_matches_unfused(self):
        key = jax.random.PRNGKey(4)
        params = init_deformable_conv(key, 6, 12)
        params = params._replace(
            w_off=jax.random.normal(key, params.w_off.shape) * 0.4)
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 9, 9, 6))
        np.testing.assert_allclose(fused_deformable_conv2d(x, params),
                                   deformable_conv2d(x, params),
                                   rtol=1e-5, atol=1e-5)

    def test_fused_grads_match(self):
        key = jax.random.PRNGKey(6)
        params = init_deformable_conv(key, 4, 4)
        params = params._replace(
            w_off=jax.random.normal(key, params.w_off.shape) * 0.3)
        x = jax.random.normal(jax.random.PRNGKey(7), (1, 8, 8, 4))
        g1 = jax.grad(lambda p: deformable_conv2d(x, p).sum())(params)
        g2 = jax.grad(lambda p: fused_deformable_conv2d(x, p).sum())(params)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_fused_grads_match_wrt_input_and_dcn1(self):
        """Checkpoint-path gradients agree with the reference for d/dx as
        well as d/dparams, across variants and with offset clamping."""
        key = jax.random.PRNGKey(8)
        params = init_deformable_conv(key, 4, 4, variant="dcn1")
        params = params._replace(
            w_off=jax.random.normal(key, params.w_off.shape) * 0.5)
        x = jax.random.normal(jax.random.PRNGKey(9), (1, 8, 8, 4))

        def loss(fn, x, p):
            return (fn(x, p, variant="dcn1", max_displacement=2.0) ** 2).sum()

        gx1, gp1 = jax.grad(lambda x, p: loss(deformable_conv2d, x, p),
                            argnums=(0, 1))(x, params)
        gx2, gp2 = jax.grad(lambda x, p: loss(fused_deformable_conv2d, x, p),
                            argnums=(0, 1))(x, params)
        np.testing.assert_allclose(gx1, gx2, rtol=1e-4, atol=1e-4)
        for a, b in zip(jax.tree.leaves(gp1), jax.tree.leaves(gp2)):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_max_displacement_clamps(self):
        offsets = jnp.full((1, 4, 4, 18), 100.0)
        coords = offsets_to_coords(offsets, 3, "dcn2", max_displacement=2.0)
        centre_plus = jnp.max(coords[..., 0])
        assert centre_plus <= 3 + 1 + 2.0  # centre + tap + clamp


class TestTDT:
    def test_tdt_covers_neighbours(self):
        h = w = 20
        grid = make_square_grid(h, w, 5)
        coords = _rand_coords(jax.random.PRNGKey(0), h, w, 9)
        B = np.asarray(tdt_from_coords(coords, grid, grid))
        assert B.shape == (25, 25)
        assert B.any(axis=1).all()  # every output tile has deps
        # dependency implied by per-pixel tiles
        pp = np.asarray(per_pixel_input_tiles(coords, grid))
        for o in range(25):
            r0, c0 = (o // 5) * 4, (o % 5) * 4
            needed = np.unique(pp[r0:r0 + 4, c0:c0 + 4])
            assert B[o, needed].all()

    def test_access_histogram_totals(self):
        h = w = 10
        coords = _rand_coords(jax.random.PRNGKey(1), h, w, 9)
        hist = access_histogram(coords, h, w)
        assert int(hist.sum()) == h * w * 9 * 4


class TestScheduler:
    def _tdt(self, n=25, density=0.25, seed=0):
        rng = np.random.default_rng(seed)
        B = rng.random((n, n)) < density
        B[np.arange(n), np.arange(n)] = True
        return B

    def test_schedule_covers_all_tiles(self):
        B = self._tdt()
        s = schedule_tiles(B, 4)
        assert sorted(s.oid) == list(range(25))
        for o, loads in zip(s.oid, s.iid):
            assert set(loads) == set(np.flatnonzero(B[o]))

    def test_first_tile_has_most_deps(self):
        B = self._tdt(seed=3)
        s = schedule_tiles(B, 4)
        assert B[s.oid[0]].sum() == B.sum(axis=1).max()

    def test_fifo_buffer(self):
        buf = FifoBuffer(2)
        assert not buf.touch(1) and not buf.touch(2)
        assert buf.touch(1)           # hit
        assert not buf.touch(3)       # evicts 1 (FIFO: 1 oldest)
        assert not buf.touch(1)       # 1 was evicted -> miss
        assert buf.loads == 4 and buf.hits == 1

    def test_schedule_is_permutation_on_random_coord_fields(self):
        """Every schedule is a permutation of the output tiles that have
        dependencies — on measured TDTs, not just synthetic matrices."""
        h = w = 24
        grid = make_square_grid(h, w, 4)
        for seed in range(4):
            coords = _rand_coords(jax.random.PRNGKey(100 + seed), h, w, 9)
            B = np.asarray(tdt_from_coords(coords, grid, grid))
            for m in (1, 4, grid.num_tiles):
                s = schedule_tiles(B, m)
                dep_rows = np.flatnonzero(B.any(axis=1)).tolist()
                assert sorted(s.oid) == dep_rows
                assert len(s.oid) == len(set(s.oid))  # no repeats
                for o, loads in zip(s.oid, s.iid):
                    assert sorted(loads) == np.flatnonzero(B[o]).tolist()

    def test_fifo_occupancy_matches_independent_model(self):
        """Replaying real schedules: every hit/miss decision and the
        resident set match an independent deque FIFO model, and occupancy
        never exceeds M (the paper's input buffer is a hard capacity)."""
        from collections import deque
        h = w = 20
        grid = make_square_grid(h, w, 5)
        coords = _rand_coords(jax.random.PRNGKey(9), h, w, 9)
        B = np.asarray(tdt_from_coords(coords, grid, grid))
        for m in (1, 2, 5):
            buf = FifoBuffer(m)
            model = deque(maxlen=m)  # append on full evicts the oldest
            for loads in schedule_tiles(B, m).iid:
                for t in loads:
                    assert buf.touch(t) == (t in model)
                    if t not in model:
                        model.append(t)
                    assert len(buf.resident) <= m
                    assert set(buf.queue) == buf.resident == set(model)

    def test_traffic_ordering_on_random_coord_fields(self):
        """Paper Fig. 16 invariant, scheduled <= bitvec <= naive, holds on
        random coordinate fields across seeds and buffer sizes."""
        h = w = 24
        grid = make_square_grid(h, w, 4)
        for seed in range(4):
            coords = _rand_coords(jax.random.PRNGKey(200 + seed), h, w, 9)
            B = np.asarray(tdt_from_coords(coords, grid, grid))
            pp = np.asarray(per_pixel_input_tiles(coords, grid))
            for buf_tiles in (2, 4, 8):
                rep = simulate_strategies(
                    B, pp, grid, channels=8, c_out=8, kernel_size=3,
                    buffer_bytes=buf_tiles * grid.tile_bytes(8))
                assert (rep["scheduled"].tile_loads
                        <= rep["bitvec"].tile_loads
                        <= rep["naive"].tile_loads)

    def test_scheduled_never_worse_than_sequential(self):
        for seed in range(5):
            B = self._tdt(seed=seed, density=0.3)
            from repro.core.scheduler import FifoBuffer as FB
            for m in (3, 6, 12):
                seq = sequential_schedule(B)
                sch = schedule_tiles(B, m)
                def replay(s):
                    buf = FB(m)
                    for loads in s.iid:
                        for t in loads:
                            buf.touch(t)
                    return buf.loads
                assert replay(sch) <= replay(seq) * 1.05  # allow tie+noise


class TestSimulator:
    def test_strategy_ordering_matches_paper(self):
        """Fig. 14/16: naive >= bitvec >= scheduled in DRAM tile loads."""
        h = w = 40
        grid = make_square_grid(h, w, 5)
        coords = _rand_coords(jax.random.PRNGKey(2), h, w, 9)
        B = np.asarray(tdt_from_coords(coords, grid, grid))
        pp = np.asarray(per_pixel_input_tiles(coords, grid))
        rep = simulate_strategies(B, pp, grid, channels=64, c_out=64,
                                  kernel_size=3, buffer_bytes=32 * 1024)
        assert rep["naive"].tile_loads >= rep["bitvec"].tile_loads
        assert rep["bitvec"].tile_loads >= rep["scheduled"].tile_loads

    def test_fusion_removes_intermediate(self):
        h = w = 20
        grid = make_square_grid(h, w, 5)
        coords = _rand_coords(jax.random.PRNGKey(3), h, w, 9)
        B = np.asarray(tdt_from_coords(coords, grid, grid))
        pp = np.asarray(per_pixel_input_tiles(coords, grid))
        kw = dict(in_grid=grid, channels=16, c_out=16, kernel_size=3,
                  buffer_bytes=8192)
        fused = simulate_strategies(B, pp, fused=True, **kw)["scheduled"]
        staged = simulate_strategies(B, pp, fused=False, **kw)["scheduled"]
        assert fused.intermediate_bytes == 0
        assert staged.intermediate_bytes == 2 * h * w * 9 * 16
        assert staged.total_dram_bytes > fused.total_dram_bytes

    def test_energy_monotone_in_traffic(self):
        h = w = 20
        grid = make_square_grid(h, w, 5)
        coords = _rand_coords(jax.random.PRNGKey(4), h, w, 9)
        B = np.asarray(tdt_from_coords(coords, grid, grid))
        pp = np.asarray(per_pixel_input_tiles(coords, grid))
        rep = simulate_strategies(B, pp, grid, 16, 16, 3, 8192)
        e = {k: dram_energy(r, exec_time_s=1e-3) for k, r in rep.items()}
        assert e["naive"] >= e["scheduled"]

    def test_dram_model_positive(self):
        m = DramEnergyModel()
        assert m.read_pj_per_byte > 0 and m.write_pj_per_byte > 0
        assert m.energy_j(1e6, 1e6, 1e-3) > 0


class TestFusionPlanner:
    def test_small_layer_fuses(self):
        plan = plan_fusion(LayerShape(h=28, w=28, c_in=64, c_out=64),
                           onchip_budget_bytes=16 * 2 ** 20)
        assert plan.mode == FusionMode.FUSED
        assert plan.dram_bytes_saved > 0

    def test_huge_layer_stages(self):
        plan = plan_fusion(LayerShape(h=512, w=512, c_in=2048, c_out=2048),
                           onchip_budget_bytes=64 * 1024)
        assert plan.mode == FusionMode.STAGED

    def test_vmem_fits_budget_when_fused(self):
        budget = 8 * 2 ** 20
        plan = plan_fusion(LayerShape(h=56, w=56, c_in=128, c_out=128),
                           onchip_budget_bytes=budget)
        if plan.mode == FusionMode.FUSED:
            assert plan.vmem_bytes <= budget
