"""Scale-out parity: sharded batch_fused == single-device == oracle.

Host-side shard plumbing (ShardPlan, per-shard packing, stack/unstack)
and config validation run everywhere. Device parity scenarios run in
SUBPROCESSES with XLA_FLAGS=--xla_force_host_platform_device_count=4
(the main pytest process keeps its single CPU device, per the dry-run
isolation rule) — except on the CI ``multidevice`` leg, where the whole
pytest process has 4 forced devices and the in-process class runs too.

The invariants (ISSUE 9):
* sharded ``batch_fused`` output == single-device ``batch_fused``
  BIT-exact == XLA oracle to float tolerance — across ragged batches,
  batch sizes not divisible by the device count, empty shards, and an
  empty-schedule image inside one shard;
* per-image traces are placement-independent and stay EXACTLY equal to
  the network DRAM simulator;
* serving replica placement keeps the exactly-once contract under the
  PR 8 chaos harness.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import GraphConfig, PipelineConfig, plan_batch_shards
from repro.runtime.shard import (allgather_nbytes, shard_batch_schedules,
                                 stack_rows, unstack_rows)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, timeout: int = 560, devices: int = 4):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{devices}")
    # src for the package, the repo root so scripts can reuse the
    # test-suite case builders (tests.test_graph etc.).
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), _ROOT])
    script = textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, (
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


# ---------------------------------------------------------------------------
# Host-side shard plumbing (no devices needed)
# ---------------------------------------------------------------------------


class TestShardPlan:
    def test_near_even_default(self):
        p = plan_batch_shards(10, 4)
        assert p.sizes == (3, 3, 2, 2)
        assert p.spans == ((0, 3), (3, 6), (6, 8), (8, 10))
        assert p.n_max == 3

    def test_explicit_sizes_with_empty_shard(self):
        p = plan_batch_shards(5, 4, sizes=[3, 0, 2, 0])
        assert p.sizes == (3, 0, 2, 0)
        assert p.spans[1] == (3, 3)
        assert p.n_max == 3

    def test_fewer_images_than_shards(self):
        p = plan_batch_shards(2, 4)
        assert p.sizes == (1, 1, 0, 0)

    def test_validation(self):
        with pytest.raises(ValueError, match="sum to"):
            plan_batch_shards(5, 2, sizes=[2, 2])
        with pytest.raises(ValueError, match="entries"):
            plan_batch_shards(4, 2, sizes=[2, 1, 1])
        with pytest.raises(ValueError, match="negative"):
            plan_batch_shards(2, 2, sizes=[3, -1])
        with pytest.raises(ValueError, match="n_shards"):
            plan_batch_shards(4, 0)

    def test_stack_unstack_round_trip(self):
        rng = np.random.default_rng(3)
        flat = jnp.asarray(rng.normal(size=(5 * 4, 6, 2)))
        for sizes in (None, [3, 0, 2, 0], [1, 1, 1, 2]):
            p = plan_batch_shards(5, 4, sizes=sizes)
            stacked = stack_rows(flat, p, 4)
            assert stacked.shape == (4, p.n_max * 4, 6, 2)
            back = unstack_rows(stacked, p, 4)
            np.testing.assert_array_equal(np.asarray(back),
                                          np.asarray(flat))

    def test_allgather_nbytes(self):
        a = jnp.zeros((3, 4), jnp.float32)
        assert allgather_nbytes(a) == 48


class TestShardPack:
    def _scheds(self, t, n):
        from repro.core.scheduler import DeviceSchedule, schedule_tiles
        from repro.core.tiles import TileGrid, tdt_from_coords
        grid = TileGrid(8, 8, 4, 4)
        key = jax.random.PRNGKey(0)
        out = []
        for i in range(n):
            c = jnp.clip(jax.random.uniform(
                jax.random.fold_in(key, i), (8, 8, 9, 2)) * 7.0, 0.0,
                None)
            B = np.asarray(tdt_from_coords(c, grid, grid))
            out.append(DeviceSchedule.from_host(schedule_tiles(B, t), t))
        return out

    def test_per_shard_ragged_padding(self):
        """Each shard keeps its own k_pad; cross-shard pad rows are
        fully elided (cnt 0, oid -1)."""
        t = 4
        scheds = self._scheds(t, 5)
        plan = plan_batch_shards(5, 4)
        sh = shard_batch_schedules(scheds, t, t, plan)
        g_max = plan.n_max * scheds[0].n_rows
        assert sh.row_id.shape == (4, g_max)
        assert sh.dep_glb.shape[:2] == (4, g_max)
        oid = np.asarray(sh.oid)
        cnt = np.asarray(sh.dep_cnt)
        # shards with one image: the trailing slab rows are padding
        rows1 = scheds[0].n_rows
        for s in (1, 2, 3):
            assert (oid[s, rows1:] == -1).all()
            assert (cnt[s, rows1:] == 0).all()

    def test_empty_schedule_image_in_one_shard(self):
        """The empty-TDT quirk schedule (one step, zero deps) packs
        into its shard without disturbing neighbours."""
        from repro.core.scheduler import DeviceSchedule, schedule_tiles
        t = 4
        empty = schedule_tiles(np.zeros((t, t), bool), t)
        assert empty.oid == [0] and empty.iid == [[]]
        scheds = self._scheds(t, 3)
        scheds[1] = DeviceSchedule.from_host(empty, t)
        plan = plan_batch_shards(3, 2)        # shard 0: imgs 0,1
        sh = shard_batch_schedules(scheds, t, t, plan)
        oid = np.asarray(sh.oid)
        cnt = np.asarray(sh.dep_cnt)
        rows = scheds[0].n_rows
        # image 1 (second on shard 0): 1 real zero-dep row, rest padded
        img1 = slice(rows, 2 * rows)
        assert (oid[0, img1] >= 0).sum() == 1
        assert (cnt[0, img1] == 0).all()

    def test_empty_shard_is_fully_elided(self):
        t = 4
        scheds = self._scheds(t, 2)
        plan = plan_batch_shards(2, 3, sizes=[1, 0, 1])
        sh = shard_batch_schedules(scheds, t, t, plan)
        assert (np.asarray(sh.oid)[1] == -1).all()
        assert (np.asarray(sh.dep_cnt)[1] == 0).all()

    def test_plan_mismatch_rejected(self):
        scheds = self._scheds(4, 2)
        with pytest.raises(ValueError, match="plan"):
            shard_batch_schedules(scheds, 4, 4, plan_batch_shards(3, 2))


class TestShardConfigValidation:
    def test_sharding_requires_batch_fused(self):
        with pytest.raises(ValueError, match="batch_fused"):
            GraphConfig(dispatch="batched", data_parallel=2)
        with pytest.raises(ValueError, match="batch_fused"):
            PipelineConfig(dispatch="per_tile", data_parallel=2)

    def test_data_parallel_bounds(self):
        with pytest.raises(ValueError, match="data_parallel"):
            GraphConfig(dispatch="batch_fused", data_parallel=0)
        # data_parallel=1 is the single-device no-op, any dispatch
        GraphConfig(dispatch="batched", data_parallel=1)

    def test_shard_sizes_requires_sharded_config(self):
        from tests.test_graph import _acceptance_case
        from repro.runtime import run_graph
        convs, graph, x = _acceptance_case()
        with pytest.raises(ValueError, match="shard_sizes"):
            run_graph(convs, graph, x,
                      config=GraphConfig(tile=4,
                                         dispatch="batch_fused"),
                      shard_sizes=[1, 1])

    def test_oversubscribed_host_mesh_is_clear(self):
        """data_parallel beyond the live device count surfaces the
        make_host_mesh recipe, not a reshape error."""
        from tests.test_graph import _acceptance_case
        from repro.runtime import run_graph
        convs, graph, x = _acceptance_case()
        big = jax.device_count() + 1
        with pytest.raises(ValueError,
                           match="xla_force_host_platform"):
            run_graph(convs, graph, x,
                      config=GraphConfig(tile=4, dispatch="batch_fused",
                                         data_parallel=big))


# ---------------------------------------------------------------------------
# Device parity (subprocesses, 4 forced host devices)
# ---------------------------------------------------------------------------


class TestShardedParity:
    def test_pipeline_and_graph_sharded_bit_exact(self):
        """Sharded == single-device bit-exact, both == XLA oracle —
        pipeline and graph executors, ragged batch of 5 over 4 devices
        (not divisible), explicit shard_sizes with empty shards."""
        _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core.deform import (deformable_conv2d,
                                           init_deformable_conv,
                                           randomize_offset_conv)
            from repro.runtime import (GraphConfig, PipelineConfig,
                                       dcn_pipeline, run_graph,
                                       run_graph_dense)
            from tests.test_graph import _acceptance_case
            assert jax.device_count() == 4

            key = jax.random.PRNGKey(7)
            params = randomize_offset_conv(
                init_deformable_conv(key, 5, 7, 3, "dcn2"),
                jax.random.fold_in(key, 1), 0.7)
            x = jax.random.normal(jax.random.fold_in(key, 2),
                                  (5, 13, 13, 5))
            y_ref = deformable_conv2d(x, params)
            y0 = dcn_pipeline(x, params, config=PipelineConfig(
                tile=4, dispatch="batch_fused",
                use_schedule_cache=False))
            for dp in (2, 4):
                y = dcn_pipeline(x, params, config=PipelineConfig(
                    tile=4, dispatch="batch_fused", data_parallel=dp,
                    use_schedule_cache=False))
                assert np.array_equal(np.asarray(y), np.asarray(y0)), dp
            np.testing.assert_allclose(np.asarray(y0),
                                       np.asarray(y_ref),
                                       rtol=1e-4, atol=1e-4)

            convs, graph, _ = _acceptance_case()
            xg = jax.random.normal(jax.random.fold_in(key, 3),
                                   (5, 13, 13, 3))
            yd = run_graph_dense(convs, graph, xg)
            g0 = run_graph(convs, graph, xg, config=GraphConfig(
                tile=4, dispatch="batch_fused",
                use_schedule_cache=False))
            for dp in (2, 4):
                g = run_graph(convs, graph, xg, config=GraphConfig(
                    tile=4, dispatch="batch_fused", data_parallel=dp,
                    use_schedule_cache=False))
                assert np.array_equal(np.asarray(g), np.asarray(g0)), dp
            ge = run_graph(
                convs, graph, xg,
                config=GraphConfig(tile=4, dispatch="batch_fused",
                                   data_parallel=4,
                                   use_schedule_cache=False),
                shard_sizes=[3, 0, 2, 0])
            assert np.array_equal(np.asarray(ge), np.asarray(g0))
            np.testing.assert_allclose(np.asarray(g0), np.asarray(yd),
                                       rtol=1e-4, atol=1e-4)
            print("sharded parity OK")
        """)

    def test_sharded_trace_equals_simulator(self):
        """Per-image traces are placement-independent and EXACTLY equal
        to the network DRAM simulator under sharding."""
        _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core.simulator import simulate_network
            from repro.runtime import GraphConfig, run_graph
            from repro.runtime.fused_exec import network_sim_specs
            from tests.test_graph import _acceptance_case
            assert jax.device_count() == 4

            convs, graph, _ = _acceptance_case(seed=1)
            x = jax.random.normal(jax.random.PRNGKey(8), (5, 13, 13, 3))
            _, tr0 = run_graph(convs, graph, x, return_trace=True,
                               config=GraphConfig(
                                   tile=4, dispatch="batch_fused",
                                   use_schedule_cache=False))
            _, tr = run_graph(convs, graph, x, return_trace=True,
                              config=GraphConfig(
                                  tile=4, dispatch="batch_fused",
                                  data_parallel=4,
                                  use_schedule_cache=False))
            assert tr.shards == 4 and tr.allgather_bytes > 0
            assert len(tr.groups) == len(tr0.groups)
            for g0, g in zip(tr0.groups, tr.groups):
                assert (g0.image, g0.group) == (g.image, g.group)
                assert [r.out_tile for r in g0.records] == \\
                    [r.out_tile for r in g.records]
                assert [r.dep_tiles for r in g0.records] == \\
                    [r.dep_tiles for r in g.records]
            sim = simulate_network(network_sim_specs(tr),
                                   boundary_bytes=tr.boundary_bytes,
                                   fused=True)
            for gt, rep in zip(tr.groups, sim.groups):
                assert gt.fifo_replay().loads == rep.tile_loads
                assert gt.input_load_bytes == rep.input_read_bytes
            assert tr.total_dram_bytes == sim.total_dram_bytes
            print("sharded trace == simulator OK")
        """)

    def test_serving_replicas_exactly_once_under_chaos(self):
        """Replica-aware slot placement: sharded engine == unsharded
        bit-exact, balanced per-replica accounting, and the
        exactly-once contract under the PR 8 fault-storm harness."""
        _run("""
            import jax.numpy as jnp
            import numpy as np
            from repro.runtime import GraphConfig
            from repro.serving import DcnServingEngine
            from repro.serving.errors import RequestFailedError
            from repro.testing import FaultInjector
            from tests.test_serving import _dcn_case
            cfg, params = _dcn_case()

            def images(n, seed=0):
                rng = np.random.default_rng(seed)
                return rng.normal(
                    size=(n, 16, 16, 3)).astype(np.float32)

            shard_graph = GraphConfig(tile=4, dispatch="batch_fused",
                                      data_parallel=4)
            eng0 = DcnServingEngine(params, cfg,
                                    graph=GraphConfig(tile=4), slots=4)
            eng4 = DcnServingEngine(params, cfg, graph=shard_graph,
                                    slots=4)
            assert eng4.replicas == 4
            assert eng4._slot_replica == [0, 1, 2, 3]
            xs = [images(1, seed=i) for i in range(5)]
            r0 = [eng0.submit(x) for x in xs]
            eng0.drain()
            r4 = [eng4.submit(x) for x in xs]
            eng4.drain()
            y0 = np.concatenate([r.result() for r in r0])
            y4 = np.concatenate([r.result() for r in r4])
            assert np.array_equal(y0, y4)
            s = eng4.stats
            assert s["replicas"] == 4
            per = s["per_replica"]
            assert sum(p["images"] for p in per) == 5
            assert [p["images"] for p in per] == [2, 1, 1, 1]
            assert s["allgather_bytes"] > 0
            assert all(p["dram_bytes"] > 0 for p in per)
            snap = eng4.metrics_snapshot()
            assert "serving.replica0.dispatches" in snap

            # chaos: seeded fault storm on the sharded engine
            inj = FaultInjector(kinds=("prepass", "dispatch"),
                                rate=0.3, seed=13)
            eng = DcnServingEngine(params, cfg, graph=shard_graph,
                                   slots=4, faults=inj)
            xs8 = images(8, seed=5)
            ref = [np.asarray(eng0.infer(jnp.asarray(xs8[i][None])))[0]
                   for i in range(8)]
            reqs = [eng.submit(xs8[i]) for i in range(8)]
            done = eng.drain(max_steps=100)
            rids = [r.rid for r in done]
            assert sorted(rids) == [r.rid for r in reqs]
            assert len(rids) == len(set(rids))
            assert eng.drain() == []
            assert inj.total_fired > 0
            for i, r in enumerate(reqs):
                assert r.done
                if r.failed:
                    assert isinstance(r.error, RequestFailedError)
                else:
                    np.testing.assert_allclose(
                        r.result()[0], ref[i], rtol=2e-4, atol=2e-4)
            print("serving replicas exactly-once OK")
        """)


# ---------------------------------------------------------------------------
# In-process coverage for the CI multidevice leg (whole pytest process
# runs under 4 forced host devices there; skipped on 1-device hosts)
# ---------------------------------------------------------------------------


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >= 2 devices (multidevice CI leg)")
class TestShardedInProcess:
    def test_graph_parity_in_process(self):
        from tests.test_graph import _acceptance_case
        from repro.runtime import run_graph
        convs, graph, x = _acceptance_case()
        dp = min(jax.device_count(), 4)
        y0 = run_graph(convs, graph, x, config=GraphConfig(
            tile=4, dispatch="batch_fused", use_schedule_cache=False))
        y = run_graph(convs, graph, x, config=GraphConfig(
            tile=4, dispatch="batch_fused", data_parallel=dp,
            use_schedule_cache=False))
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y0))

    def test_serving_slots_cover_replicas(self):
        from tests.test_serving import _dcn_case
        from repro.serving import DcnServingEngine
        cfg, params = _dcn_case()
        with pytest.raises(ValueError, match="replica"):
            DcnServingEngine(
                params, cfg, slots=1,
                graph=GraphConfig(tile=4, dispatch="batch_fused",
                                  data_parallel=jax.device_count()))
