"""Hypothesis property tests on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; property tests are optional extras")
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import (FifoBuffer, schedule_tiles,
                                  schedule_tiles_device,
                                  sequential_schedule)
from repro.core.tiles import TileGrid, make_square_grid, tdt_from_coords
from repro.kernels.dcn_schedule import tdt_from_coords_device
from repro.core.deform import bli_coefficients, bilinear_sample
from repro.kernels.ops import coords_to_idx_coeff
from repro.optim import quantize, dequantize
from repro.launch.elastic import plan_remesh
from repro.models.params import LogicalAxes, resolve_spec

_SETTINGS = dict(max_examples=25, deadline=None)


class TestSchedulerProperties:
    @given(n=st.integers(4, 30), density=st.floats(0.05, 0.9),
           m=st.integers(1, 20), seed=st.integers(0, 10_000))
    @settings(**_SETTINGS)
    def test_schedule_is_permutation_with_exact_deps(self, n, density, m,
                                                     seed):
        """Algorithm 1 output: every dependent output tile exactly once,
        every input-load list == the tile's dependency set."""
        rng = np.random.default_rng(seed)
        B = rng.random((n, n)) < density
        B[0] = True  # ensure at least one schedulable tile
        sched = schedule_tiles(B, m)
        dep_rows = [o for o in range(n) if B[o].any()]
        assert sorted(sched.oid) == sorted(dep_rows)
        for o, loads in zip(sched.oid, sched.iid):
            assert sorted(loads) == sorted(np.flatnonzero(B[o]).tolist())

    @given(n=st.integers(4, 24), density=st.floats(0.1, 0.7),
           m=st.integers(2, 16), seed=st.integers(0, 10_000))
    @settings(**_SETTINGS)
    def test_scheduling_never_increases_loads(self, n, density, m, seed):
        """Paper Fig. 16: Alg 1 ordering cannot load more tiles than the
        sequential bit-vector baseline under the same FIFO buffer."""
        rng = np.random.default_rng(seed)
        B = rng.random((n, n)) < density
        def replay(s):
            buf = FifoBuffer(m)
            for loads in s.iid:
                for t in loads:
                    buf.touch(t)
            return buf.loads
        assert replay(schedule_tiles(B, m)) <= replay(sequential_schedule(B))

    @given(cap=st.integers(1, 8), seq=st.lists(st.integers(0, 9),
                                               min_size=1, max_size=100))
    @settings(**_SETTINGS)
    def test_fifo_loads_plus_hits_equals_touches(self, cap, seq):
        buf = FifoBuffer(cap)
        for t in seq:
            buf.touch(t)
        assert buf.loads + buf.hits == len(seq)
        assert len(buf.queue) <= cap


class TestDeviceSchedulerProperties:
    """The on-device scheduler is bit-exact vs the host reference on
    arbitrary inputs — same orders, same load lists, and therefore the
    same simulated DRAM tile-load counts."""

    @given(n=st.integers(1, 24), density=st.floats(0.0, 0.95),
           m=st.integers(1, 26), seed=st.integers(0, 10_000))
    @settings(**_SETTINGS)
    def test_device_schedule_identical_to_host(self, n, density, m, seed):
        rng = np.random.default_rng(seed)
        B = rng.random((n, n)) < density
        host = schedule_tiles(B, m)
        dev = schedule_tiles_device(B, m, interpret=True)
        assert dev.oid == host.oid
        assert dev.iid == host.iid
        assert dev.reuse_overlap == host.reuse_overlap

        def replay(s):
            buf = FifoBuffer(m)
            for loads in s.iid:
                for t in loads:
                    buf.touch(t)
            return buf.loads

        assert replay(dev) == replay(host)

    @given(seed=st.integers(0, 10_000), h=st.integers(6, 24),
           w=st.integers(6, 24), th=st.integers(2, 8),
           tw=st.integers(2, 8), m=st.integers(1, 8))
    @settings(**_SETTINGS)
    def test_device_tdt_and_schedule_from_random_offsets(
            self, seed, h, w, th, tw, m):
        """Random sampling fields x random (possibly ragged) tile shapes:
        the device TDT equals the host TDT and both backends schedule it
        to the same simulated DRAM tile-load count."""
        th, tw = min(th, h), min(tw, w)
        grid = TileGrid(h, w, th, tw)
        key = jax.random.PRNGKey(seed)
        coords = jax.random.uniform(
            key, (h, w, 9, 2), minval=-3.0,
            maxval=h + 3.0).astype(jnp.float32)
        B_host = np.asarray(tdt_from_coords(coords, grid, grid))
        B_dev = np.asarray(tdt_from_coords_device(coords, grid, grid,
                                                  interpret=True))
        assert np.array_equal(B_host, B_dev)
        host = schedule_tiles(B_host, m)
        dev = schedule_tiles_device(B_dev, m, interpret=True)
        assert dev.oid == host.oid and dev.iid == host.iid

        def loads(s):
            buf = FifoBuffer(m)
            for dep in s.iid:
                for t in dep:
                    buf.touch(t)
            return buf.loads

        assert loads(dev) == loads(host)


class TestBatchFusedProperties:
    """The concatenated batch grid preserves every image's schedule: its
    per-image FIFO DRAM loads equal the sum of the per-image simulator
    (host Algorithm-1 + FIFO replay) loads, for arbitrary ragged TDTs."""

    @given(n_imgs=st.integers(1, 4), n=st.integers(2, 12),
           density=st.floats(0.0, 0.9), m=st.integers(1, 12),
           seed=st.integers(0, 10_000))
    @settings(**_SETTINGS)
    def test_concat_fifo_loads_equal_sum_of_simulator_loads(
            self, n_imgs, n, density, m, seed):
        from repro.core.scheduler import DeviceSchedule
        from repro.runtime.packing import pack_batch_schedules

        rng = np.random.default_rng(seed)
        tdts = [rng.random((n, n)) < density for _ in range(n_imgs)]
        scheds = [schedule_tiles(B, m) for B in tdts]
        batch = pack_batch_schedules(
            [DeviceSchedule.from_host(s, n) for s in scheds], n, n)

        def replay(s):
            buf = FifoBuffer(m)
            for loads in s.iid:
                for t in loads:
                    buf.touch(t)
            return buf.loads

        sim_total = sum(replay(s) for s in scheds)

        # Replay the concatenated dep rows through per-image FIFOs —
        # exactly the DMA stream the batch-fused grid issues (ragged
        # padding rows carry dep_cnt 0 and load nothing new beyond the
        # elided repeat of the image's last resident dep).
        oid = np.asarray(batch.oid)
        dep = np.asarray(batch.dep_glb)
        cnt = np.asarray(batch.dep_cnt)
        bufs = [FifoBuffer(m) for _ in range(n_imgs)]
        for g in range(oid.shape[0]):
            if oid[g] < 0:
                continue
            img = g // n
            for k in range(cnt[g]):
                bufs[img].touch(int(dep[g, k]) - img * n)
        assert sum(b.loads for b in bufs) == sim_total


class TestBliProperties:
    @given(seed=st.integers(0, 10_000), h=st.integers(4, 16),
           w=st.integers(4, 16))
    @settings(**_SETTINGS)
    def test_coefficients_partition_of_unity(self, seed, h, w):
        key = jax.random.PRNGKey(seed)
        coords = jax.random.uniform(key, (20, 2)) * jnp.array([h - 1, w - 1])
        _, coeffs = bli_coefficients(coords)
        np.testing.assert_allclose(np.asarray(coeffs.sum(-1)), 1.0,
                                   atol=1e-5)
        assert (np.asarray(coeffs) >= -1e-6).all()

    @given(seed=st.integers(0, 10_000))
    @settings(**_SETTINGS)
    def test_bli_is_convex_combination(self, seed):
        """BLI output lies within [min, max] of the 4 neighbours ->
        sampling a constant field returns the constant."""
        key = jax.random.PRNGKey(seed)
        x = jnp.full((1, 8, 8, 3), 2.5)
        coords = jax.random.uniform(key, (1, 8, 8, 9, 2)) * 6.99
        out = bilinear_sample(x, coords)
        np.testing.assert_allclose(np.asarray(out), 2.5, atol=1e-5)

    @given(seed=st.integers(0, 10_000))
    @settings(**_SETTINGS)
    def test_idx_coeff_consistency(self, seed):
        """4-hot decomposition reproduces bilinear_sample exactly."""
        key = jax.random.PRNGKey(seed)
        h = w = 8
        c = 4
        x = jax.random.normal(key, (h, w, c))
        coords = jax.random.uniform(jax.random.fold_in(key, 1),
                                    (30, 2)) * (h - 1.01)
        idx, coeff = coords_to_idx_coeff(coords, h, w)
        flat = x.reshape(-1, c)
        manual = sum(flat[idx[:, j]] * coeff[:, j:j + 1] for j in range(4))
        from repro.kernels.ref import bli_tile_ref
        np.testing.assert_allclose(np.asarray(manual),
                                   np.asarray(bli_tile_ref(x, coords)),
                                   rtol=1e-5, atol=1e-5)


class TestTdtProperties:
    @given(seed=st.integers(0, 10_000), tiles=st.integers(2, 6))
    @settings(**_SETTINGS)
    def test_tdt_monotone_in_tile_size(self, seed, tiles):
        """Coarser tiling -> dependency fraction can only grow."""
        h = w = 24
        key = jax.random.PRNGKey(seed)
        coords = jax.random.uniform(key, (h, w, 9, 2)) * (h - 1.01)
        fine = make_square_grid(h, w, tiles * 2)
        coarse = make_square_grid(h, w, tiles)
        bf = np.asarray(tdt_from_coords(coords, fine, fine))
        bc = np.asarray(tdt_from_coords(coords, coarse, coarse))
        assert bc.mean() >= bf.mean() - 1e-9


class TestQuantizationProperties:
    @given(seed=st.integers(0, 10_000), scale=st.floats(1e-3, 1e3))
    @settings(**_SETTINGS)
    def test_int8_roundtrip_error_bound(self, seed, scale):
        x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed),
                                         (64,))) * scale
        q, s = quantize(jnp.asarray(x))
        err = np.abs(np.asarray(dequantize(q, s)) - x)
        assert (err <= float(s) * 0.5 + 1e-6).all()

    @given(seed=st.integers(0, 100))
    @settings(**_SETTINGS)
    def test_error_feedback_converges(self, seed):
        """Summed error-feedback compression is unbiased over steps: the
        residual stays bounded, so the time-averaged quantized gradient
        approaches the true gradient."""
        g = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (32,)))
        err = np.zeros_like(g)
        acc = np.zeros_like(g)
        for _ in range(64):
            q, s = quantize(jnp.asarray(g + err))
            dec = np.asarray(dequantize(q, s))
            err = g + err - dec
            acc += dec
        np.testing.assert_allclose(acc / 64, g, atol=float(s))


class TestShardingProperties:
    @given(dim=st.integers(1, 64), model=st.sampled_from([1, 2, 4, 8, 16]))
    @settings(**_SETTINGS)
    def test_resolve_spec_divisibility(self, dim, model):
        """Never emits a spec the mesh can't realize."""
        import jax as _jax
        from repro.compat import make_mesh
        if model > len(_jax.devices()):
            return
        mesh = make_mesh((1, model), ("data", "model"))
        spec = resolve_spec(LogicalAxes(("mlp",)), (dim,),
                            {"mlp": "model"}, mesh)
        if spec[0] is not None:
            assert dim % model == 0

    @given(chips=st.integers(1, 4096), mp=st.sampled_from([1, 2, 4, 8, 16]))
    @settings(**_SETTINGS)
    def test_plan_remesh_always_valid(self, chips, mp):
        data, model = plan_remesh(chips, mp)
        assert data * model <= chips
        assert data >= 1 and model >= 1
