"""On-device tile scheduler (kernels.dcn_schedule): bit-exactness vs the
host reference, executor integration, serving stats, and the
schedule-cache tile-shape regression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.deform import conv2d, deformable_conv2d, offsets_to_coords
from repro.core.scheduler import (schedule_tiles, schedule_tiles_device,
                                  sequential_schedule)
from repro.core.tiles import TileGrid, tdt_from_coords
from repro.kernels.dcn_schedule import (greedy_schedule_arrays,
                                        tdt_from_coords_device)
from repro.runtime import (GraphConfig, PipelineConfig, ScheduleCache,
                           build_graph, dcn_pipeline, run_graph,
                           run_graph_dense)
from repro.runtime.cache import coords_digest


def random_coords(rng, h, w, kk=9, spread=4.0):
    """Absolute sampling coordinates incl. out-of-range values (the
    clipped-floor path must behave like the host's)."""
    return jnp.asarray(rng.uniform(-spread, h + spread,
                                   size=(h, w, kk, 2)).astype(np.float32))


def assert_schedules_equal(a, b):
    assert a.oid == b.oid
    assert a.iid == b.iid
    assert a.reuse_overlap == b.reuse_overlap


class TestTdtDeviceKernel:
    @pytest.mark.parametrize("h,w,th,tw", [
        (16, 16, 8, 8),      # even tiling
        (13, 11, 4, 5),      # ragged edges + rectangular tiles
        (8, 8, 8, 8),        # single tile
        (24, 24, 6, 8),      # rectangular, multi-row
    ])
    def test_matches_host_tdt(self, h, w, th, tw):
        rng = np.random.default_rng(h * 100 + w)
        grid = TileGrid(h, w, th, tw)
        coords = random_coords(rng, h, w)
        B_host = np.asarray(tdt_from_coords(coords, grid, grid))
        B_dev = np.asarray(tdt_from_coords_device(coords, grid, grid,
                                                  interpret=True))
        assert B_dev.dtype == bool
        assert np.array_equal(B_host, B_dev)

    def test_all_out_of_range_coords_clip_identically(self):
        grid = TileGrid(12, 12, 4, 4)
        coords = jnp.full((12, 12, 9, 2), 1e6, jnp.float32)
        B_host = np.asarray(tdt_from_coords(coords, grid, grid))
        B_dev = np.asarray(tdt_from_coords_device(coords, grid, grid,
                                                  interpret=True))
        assert np.array_equal(B_host, B_dev)


class TestGreedyDeviceKernel:
    @pytest.mark.parametrize("n,density,m", [
        (6, 0.2, 2), (9, 0.5, 3), (16, 0.35, 4), (16, 0.9, 1),
        (12, 0.6, 20),           # buffer larger than the table
    ])
    def test_matches_host_schedule(self, n, density, m):
        rng = np.random.default_rng(n * 7 + m)
        for trial in range(5):
            B = rng.random((n, n)) < density
            host = schedule_tiles(B, m)
            dev = schedule_tiles_device(B, m, interpret=True)
            assert_schedules_equal(host, dev)

    def test_empty_tdt(self):
        """All-False TDT: the host schedules its argmax pick (tile 0,
        empty load list) once — the device path must reproduce it."""
        B = np.zeros((5, 5), bool)
        host = schedule_tiles(B, 2)
        dev = schedule_tiles_device(B, 2, interpret=True)
        assert host.oid == [0] and host.iid == [[]]
        assert_schedules_equal(host, dev)

    def test_single_tile(self):
        B = np.ones((1, 1), bool)
        assert_schedules_equal(schedule_tiles(B, 1),
                               schedule_tiles_device(B, 1, interpret=True))

    def test_rows_without_deps_are_skipped(self):
        rng = np.random.default_rng(3)
        B = rng.random((10, 10)) < 0.4
        B[2] = False
        B[7] = False
        host = schedule_tiles(B, 3)
        dev = schedule_tiles_device(B, 3, interpret=True)
        assert 2 not in dev.oid and 7 not in dev.oid
        assert_schedules_equal(host, dev)

    def test_rectangular_tdt(self):
        """Composite (cross-layer) tables need not be square."""
        rng = np.random.default_rng(11)
        B = rng.random((6, 14)) < 0.3
        assert_schedules_equal(schedule_tiles(B, 4),
                               schedule_tiles_device(B, 4, interpret=True))

    def test_dense_arrays_shapes(self):
        rng = np.random.default_rng(5)
        B = rng.random((8, 8)) < 0.5
        oid, klass, ovl = greedy_schedule_arrays(jnp.asarray(B), 2,
                                                 interpret=True)
        assert oid.shape == (8, 1) and ovl.shape == (8, 1)
        assert klass.shape == (8, 8)

    def test_backend_dispatch_and_validation(self):
        B = np.ones((2, 2), bool)
        assert_schedules_equal(schedule_tiles(B, 1),
                               schedule_tiles(B, 1, backend="device",
                                              interpret=True))
        with pytest.raises(ValueError, match="backend"):
            schedule_tiles(B, 1, backend="gpu")


class TestMeasuredTdtBackends:
    def test_real_offsets_schedule_bit_exact(self):
        """Oracle configs: TDTs measured from a real stage-1 offset conv,
        across tile shapes and buffer sizes."""
        from benchmarks.workloads import executor_case
        params, x = executor_case(16, 16, 8, 8, 0)
        offsets = conv2d(x, params.w_off, params.b_off)
        coords = offsets_to_coords(offsets.astype(jnp.float32), 3, "dcn2")
        for tile in ((8, 8), (4, 4), (4, 8)):
            grid = TileGrid(16, 16, *tile)
            for m in (1, 2, grid.num_tiles):
                for i in range(x.shape[0]):
                    B_dev = tdt_from_coords_device(coords[i], grid, grid,
                                                   interpret=True)
                    host = schedule_tiles(
                        np.asarray(tdt_from_coords(coords[i], grid, grid)),
                        m)
                    dev = schedule_tiles_device(B_dev, m, interpret=True)
                    assert_schedules_equal(host, dev)


class TestDeviceBackendPipeline:
    def test_matches_xla_and_host_backend(self):
        from benchmarks.workloads import executor_case
        params, x = executor_case(16, 16, 4, 4, 1)
        ref = deformable_conv2d(x, params, 3, "dcn2")
        traces = {}
        for backend in ("host", "device"):
            cfg = PipelineConfig(tile=8, buffer_tiles=2,
                                 use_schedule_cache=False,
                                 schedule_backend=backend)
            y, tr = dcn_pipeline(x, params, config=cfg, return_trace=True)
            np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                       rtol=1e-4, atol=1e-4)
            traces[backend] = tr
        # Bit-exact schedules -> identical executed tile records.
        for im_h, im_d in zip(traces["host"].images,
                              traces["device"].images):
            assert im_h.records == im_d.records
        assert traces["device"].images[0].schedule_backend == "device"
        assert traces["device"].schedule_device_frac == 1.0
        assert traces["host"].schedule_device_frac == 0.0
        assert traces["device"].overlap.schedule_s > 0

    def test_per_tile_dispatch_with_device_schedule(self):
        from benchmarks.workloads import executor_case
        params, x = executor_case(16, 16, 4, 4, 2)
        ref = deformable_conv2d(x, params, 3, "dcn2")
        cfg = PipelineConfig(tile=8, buffer_tiles=2, dispatch="per_tile",
                             use_schedule_cache=False,
                             schedule_backend="device")
        y = dcn_pipeline(x, params, config=cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    def test_invalid_backend_rejected(self):
        with pytest.raises(ValueError, match="schedule backend"):
            PipelineConfig(schedule_backend="asic")
        with pytest.raises(ValueError, match="schedule backend"):
            GraphConfig(schedule_backend="asic")


class TestDeviceBackendGraph:
    @pytest.fixture(scope="class")
    def net(self):
        from repro.models.dcn_models import DcnNetConfig, init_dcn_net
        cfg = DcnNetConfig(name="vgg19", n_deform=2, variant="dcn2",
                           img_size=16, width_mult=0.125)
        params = init_dcn_net(jax.random.PRNGKey(0), cfg)
        graph = build_graph(cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
        return cfg, params, graph, x

    def test_matches_dense_and_host_trace(self, net):
        cfg, params, graph, x = net
        dense = run_graph_dense(params["convs"], graph, x,
                                cfg.max_displacement)
        traces = {}
        for backend in ("host", "device"):
            gc = GraphConfig(tile=4, use_schedule_cache=False,
                             schedule_backend=backend)
            y, tr = run_graph(params["convs"], graph, x, config=gc,
                              max_displacement=cfg.max_displacement,
                              return_trace=True)
            np.testing.assert_allclose(np.asarray(y), np.asarray(dense),
                                       rtol=1e-3, atol=1e-3)
            traces[backend] = tr
        for gh, gd in zip(traces["host"].groups, traces["device"].groups):
            assert gh.records == gd.records
            assert [b.tolist() for b in gh.b_layers] == \
                   [b.tolist() for b in gd.b_layers]
        assert traces["device"].groups[0].schedule_backend == "device"
        assert traces["device"].schedule_device_frac == 1.0
        # Identical schedules -> identical modeled DRAM traffic.
        assert (traces["host"].total_dram_bytes
                == traces["device"].total_dram_bytes)

    def test_serving_stats_expose_schedule_backend(self, net):
        from repro.serving.engine import DcnServingEngine
        cfg, params, graph, x = net
        eng = DcnServingEngine(
            params, cfg,
            graph=GraphConfig(tile=4, schedule_backend="device"))
        eng.infer(x)
        stats = eng.stats
        assert stats["schedule_backend"] == "device"
        assert stats["schedule_s"] > 0
        assert stats["schedule_device_frac"] == 1.0


class TestScheduleCacheTileShape:
    def test_digest_differs_across_tile_shapes(self):
        rng = np.random.default_rng(0)
        coords = random_coords(rng, 16, 16)
        d44 = coords_digest(coords, TileGrid(16, 16, 4, 4))
        d48 = coords_digest(coords, TileGrid(16, 16, 4, 8))
        d88 = coords_digest(coords, TileGrid(16, 16, 8, 8))
        assert len({d44, d48, d88}) == 3

    def test_same_coords_different_tiles_never_collide(self):
        """Regression: two configs sharing coords but differing in
        (tile_h, tile_w) must build two cache entries, not share one."""
        from benchmarks.workloads import executor_case
        from repro.runtime import default_schedule_cache
        params, x = executor_case(16, 16, 4, 4, 5)
        ref = deformable_conv2d(x, params, 3, "dcn2")
        cache = default_schedule_cache()
        cache.clear()
        for tile in ((4, 4), (4, 8), (8, 8)):
            y = dcn_pipeline(x, params,
                             config=PipelineConfig(tile=tile,
                                                   buffer_tiles=2))
            np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                       rtol=1e-4, atol=1e-4)
        # Same coords under three tile shapes (16x16 plane, nothing is
        # clamped): every schedule build must miss.
        assert cache.info()["hits"] == 0
        assert cache.info()["misses"] == 3 * x.shape[0]
        # ... while a genuine replay (same coords AND tile) hits.
        dcn_pipeline(x, params,
                     config=PipelineConfig(tile=(4, 8), buffer_tiles=2))
        assert cache.info()["hits"] == x.shape[0]

    def test_graph_clamped_tiles_share_entries_legitimately(self):
        """Differently-configured tiles that clamp to the SAME effective
        grid on low-res interior groups may share entries (bit-identical
        schedules); only differing effective grids must miss."""
        from repro.models.dcn_models import DcnNetConfig, init_dcn_net
        cfg = DcnNetConfig(name="vgg19", n_deform=1, variant="dcn2",
                           img_size=16, width_mult=0.125)
        params = init_dcn_net(jax.random.PRNGKey(2), cfg)
        graph = build_graph(cfg)
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 16, 3))
        dense = run_graph_dense(params["convs"], graph, x,
                                cfg.max_displacement)
        cache = ScheduleCache(maxsize=32)
        for tile in (4, 8):
            y = run_graph(params["convs"], graph, x,
                          config=GraphConfig(tile=tile),
                          max_displacement=cfg.max_displacement,
                          schedule_cache=cache)
            np.testing.assert_allclose(np.asarray(y), np.asarray(dense),
                                       rtol=1e-3, atol=1e-3)
        # The full-res group's grid differs (4x4 vs 8x8): it must miss
        # on the second run — misses strictly exceed the first run's.
        info = cache.info()
        assert info["misses"] > 5  # first run builds 5 distinct entries

    def test_sequential_schedule_unaffected(self):
        """Backend plumbing must leave the ablation baseline alone."""
        rng = np.random.default_rng(1)
        B = rng.random((6, 6)) < 0.5
        s = sequential_schedule(B)
        assert s.oid == [o for o in range(6) if B[o].any()]
