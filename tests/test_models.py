"""Model-zoo behaviour: block equivalences (chunked == recurrent), MoE
oracle, LM train/decode consistency, per-arch smoke tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm, ssm, xlstm
from repro.models.moe import MoeConfig, _route, init_moe, moe_apply
from repro.models.params import (Maker, abstract_params, param_axes,
                                 param_count)


class TestMamba:
    def test_train_equals_stepwise_decode(self):
        cfg = ssm.MambaConfig(d_model=32, chunk_size=8)
        p = ssm.init_mamba(Maker("init", jax.random.PRNGKey(0)), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        y_train = ssm.mamba_train(p, cfg, x)
        cache = ssm.init_mamba_cache(None, cfg, 2, dtype=jnp.float32)
        outs = []
        for t in range(16):
            o, cache = ssm.mamba_decode(p, cfg, x[:, t:t + 1], cache)
            outs.append(o)
        np.testing.assert_allclose(y_train, jnp.concatenate(outs, 1),
                                   rtol=1e-4, atol=1e-4)

    def test_chunk_size_invariance(self):
        p = ssm.init_mamba(Maker("init", jax.random.PRNGKey(2)),
                           ssm.MambaConfig(d_model=16))
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 16))
        outs = [ssm.mamba_train(p, ssm.MambaConfig(d_model=16, chunk_size=w),
                                x) for w in (4, 8, 32)]
        for o in outs[1:]:
            np.testing.assert_allclose(outs[0], o, rtol=1e-4, atol=1e-4)


class TestXlstm:
    def test_mlstm_chunkwise_equals_recurrence(self):
        cfg = xlstm.XlstmConfig(d_model=32, n_heads=2, chunk_size=4)
        p = xlstm.init_mlstm(Maker("init", jax.random.PRNGKey(4)), cfg)
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 32))
        y_train = xlstm.mlstm_train(p, cfg, x)
        cache = xlstm.init_mlstm_cache(None, cfg, 2)
        outs = []
        for t in range(16):
            o, cache = xlstm.mlstm_decode(p, cfg, x[:, t:t + 1], cache)
            outs.append(o)
        np.testing.assert_allclose(y_train, jnp.concatenate(outs, 1),
                                   rtol=1e-4, atol=1e-4)

    def test_slstm_train_equals_decode(self):
        cfg = xlstm.XlstmConfig(d_model=32, n_heads=2)
        p = xlstm.init_slstm(Maker("init", jax.random.PRNGKey(6)), cfg)
        x = jax.random.normal(jax.random.PRNGKey(7), (2, 12, 32))
        y_train = xlstm.slstm_train(p, cfg, x)
        cache = xlstm.init_slstm_cache(None, cfg, 2)
        outs = []
        for t in range(12):
            o, cache = xlstm.slstm_decode(p, cfg, x[:, t:t + 1], cache)
            outs.append(o)
        np.testing.assert_allclose(y_train, jnp.concatenate(outs, 1),
                                   rtol=1e-4, atol=1e-4)

    def test_mlstm_gate_stability_extreme_inputs(self):
        cfg = xlstm.XlstmConfig(d_model=16, n_heads=2, chunk_size=4)
        p = xlstm.init_mlstm(Maker("init", jax.random.PRNGKey(8)), cfg)
        x = 50.0 * jax.random.normal(jax.random.PRNGKey(9), (1, 16, 16))
        y = xlstm.mlstm_train(p, cfg, x)
        assert np.isfinite(np.asarray(y)).all()


class TestMoe:
    def test_matches_dense_reference(self):
        cfg = MoeConfig(d_model=16, d_ff=32, n_experts=4, top_k=2,
                        capacity_factor=8.0)
        p = init_moe(Maker("init", jax.random.PRNGKey(10)), cfg)
        x = jax.random.normal(jax.random.PRNGKey(11), (2, 8, 16))
        out, aux = moe_apply(p, cfg, x)
        xf = x.reshape(-1, 16)
        gates, eids, _ = _route(p, cfg, xf)
        g = jnp.einsum("td,edf->tef", xf, p["w_gate"])
        u = jnp.einsum("td,edf->tef", xf, p["w_up"])
        ye = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, p["w_down"])
        want = jnp.zeros_like(xf)
        for k in range(2):
            want = want + jnp.take_along_axis(
                ye, eids[:, k, None, None], axis=1)[:, 0] * gates[:, k, None]
        np.testing.assert_allclose(out.reshape(-1, 16), want,
                                   rtol=1e-4, atol=1e-4)
        assert np.isfinite(float(aux))

    def test_capacity_drops_tokens(self):
        cfg = MoeConfig(d_model=8, d_ff=16, n_experts=2, top_k=1,
                        capacity_factor=0.10)
        p = init_moe(Maker("init", jax.random.PRNGKey(12)), cfg)
        x = jax.random.normal(jax.random.PRNGKey(13), (1, 64, 8))
        out, _ = moe_apply(p, cfg, x)
        # some token outputs must be exactly zero (dropped)
        norms = jnp.linalg.norm(out.reshape(-1, 8), axis=-1)
        assert (norms == 0).any()

    def test_sigmoid_router_and_shared_expert(self):
        cfg = MoeConfig(d_model=16, d_ff=16, n_experts=4, top_k=2,
                        n_shared=1, router="sigmoid", routed_scale=2.0)
        p = init_moe(Maker("init", jax.random.PRNGKey(14)), cfg)
        x = jax.random.normal(jax.random.PRNGKey(15), (2, 8, 16))
        out, _ = moe_apply(p, cfg, x)
        assert out.shape == x.shape
        assert np.isfinite(np.asarray(out)).all()

    def test_padded_experts_never_selected(self):
        cfg = MoeConfig(d_model=8, d_ff=8, n_experts=5, top_k=2, ep=2)
        assert cfg.n_experts_padded == 6
        p = init_moe(Maker("init", jax.random.PRNGKey(16)), cfg)
        x = jax.random.normal(jax.random.PRNGKey(17), (1, 32, 8))
        _, eids, _ = _route(p, cfg, x.reshape(-1, 8))
        assert int(eids.max()) < 5


class TestLmConsistency:
    """Teacher-forced decode must reproduce the training forward."""

    pytestmark = pytest.mark.slow  # heaviest suite: full-arch decode loops

    @pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma2-27b",
                                      "jamba-v0.1-52b", "xlstm-1.3b",
                                      "deepseek-v3-671b"])
    def test_decode_matches_train_logits(self, arch):
        import dataclasses
        cfg = configs.get_config(arch, smoke=True)
        if cfg.moe is not None:
            # capacity drops are a train-time approximation: the dropped
            # (token, k) pairs are exactly the train/decode difference, so
            # consistency is tested drop-free.
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=32.0))
        p = lm.init_lm(Maker("init", jax.random.PRNGKey(20)), cfg)
        b, s = 2, 12
        shape = ((b, s + 1, cfg.n_codebooks) if cfg.n_codebooks > 1
                 else (b, s + 1))
        tokens = jax.random.randint(jax.random.PRNGKey(21), shape, 0,
                                    cfg.vocab)
        # train-path logits at every position
        from repro.models.lm import _embed, _logits
        from repro.models.layers import make_norm
        from repro.models.transformer import apply_layers_train
        x = _embed(p, cfg, tokens[:, :-1])
        x, _ = apply_layers_train(p["layers"], cfg, x, {})
        _, norm = make_norm(cfg.norm)
        train_logits = _logits(p, cfg, norm(p["final_norm"], x))

        cache = lm.init_cache(None, cfg, b, s + 4, dtype=jnp.float32)
        for t in range(s):
            tok = tokens[:, t:t + 1]
            pos = jnp.full((b,), t, jnp.int32)
            logits, cache = lm.lm_decode_step(p, cfg, cache, tok, pos)
            np.testing.assert_allclose(
                logits, train_logits[:, t], rtol=2e-3, atol=2e-3,
                err_msg=f"{arch} step {t}")


class TestArchSmoke:
    """Every assigned arch: reduced config, one forward/train step on CPU,
    output shapes + no NaNs (deliverable f)."""

    pytestmark = pytest.mark.slow  # full-arch train/decode steps, ~1min

    @pytest.mark.parametrize("arch", configs.ARCHS)
    def test_train_step_finite(self, arch):
        cfg = configs.get_config(arch, smoke=True)
        p = lm.init_lm(Maker("init", jax.random.PRNGKey(30)), cfg)
        b, s = 2, 16
        shape = ((b, s + 1, cfg.n_codebooks) if cfg.n_codebooks > 1
                 else (b, s + 1))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(31), shape,
                                              0, cfg.vocab)}
        if cfg.d_cross:
            batch["cross_states"] = jax.random.normal(
                jax.random.PRNGKey(32), (b, cfg.n_cross_tokens, cfg.d_cross))
        loss, metrics = lm.lm_loss(p, cfg, batch)
        assert np.isfinite(float(loss)), arch
        grads = jax.grad(lambda pp: lm.lm_loss(pp, cfg, batch)[0])(p)
        gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
        assert np.isfinite(gn) and gn > 0, arch

    @pytest.mark.parametrize("arch", configs.ARCHS)
    def test_decode_step_shapes(self, arch):
        cfg = configs.get_config(arch, smoke=True)
        p = lm.init_lm(Maker("init", jax.random.PRNGKey(33)), cfg)
        b = 2
        cache = lm.init_cache(None, cfg, b, 16, dtype=jnp.float32)
        tok_shape = (b, 1, cfg.n_codebooks) if cfg.n_codebooks > 1 else (b, 1)
        tok = jax.random.randint(jax.random.PRNGKey(34), tok_shape, 0,
                                 cfg.vocab)
        logits, new_cache = lm.lm_decode_step(
            p, cfg, cache, tok, jnp.zeros((b,), jnp.int32))
        assert logits.shape == (b, cfg.n_codebooks, cfg.vocab), arch
        assert np.isfinite(np.asarray(logits)).all(), arch
        assert jax.tree.structure(new_cache) == jax.tree.structure(cache)

    @pytest.mark.parametrize("arch", configs.ARCHS)
    def test_param_axes_structure_matches(self, arch):
        """axes / abstract / init Maker modes agree in structure."""
        cfg = configs.get_config(arch, smoke=True)
        ab = abstract_params(lambda mk: lm.init_lm(mk, cfg))
        axes = param_axes(lambda mk: lm.init_lm(mk, cfg))
        from repro.models.params import LogicalAxes
        flat_ab = jax.tree.leaves(ab)
        flat_ax = jax.tree.leaves(
            axes, is_leaf=lambda x: isinstance(x, LogicalAxes))
        assert len(flat_ab) == len(flat_ax)
        for a, x in zip(flat_ab, flat_ax):
            assert len(a.shape) == len(x.axes)

    def test_full_configs_param_counts(self):
        """Published param counts (sanity for the roofline 6ND terms)."""
        expected = {"deepseek-v3-671b": (630e9, 700e9),
                    "jamba-v0.1-52b": (49e9, 54e9),
                    "gemma2-27b": (26e9, 29e9),
                    "qwen3-1.7b": (1.5e9, 2.1e9),
                    "smollm-360m": (0.3e9, 0.45e9)}
        for arch, (lo, hi) in expected.items():
            cfg = configs.get_config(arch)
            n = param_count(abstract_params(lambda mk: lm.init_lm(mk, cfg)))
            assert lo <= n <= hi, (arch, n)


class TestFlashIntegration:
    """cfg.use_flash routes attention through the Pallas kernel
    (interpret=True on CPU) — full-model output must match the XLA path."""

    def test_use_flash_matches_ref(self):
        import dataclasses
        cfg = configs.get_config("gemma2-27b", smoke=True)
        p = lm.init_lm(Maker("init", jax.random.PRNGKey(50)), cfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(51),
                                              (2, 17), 0, cfg.vocab)}
        loss_ref, _ = lm.lm_loss(p, cfg, batch)
        cfg_flash = dataclasses.replace(cfg, use_flash=True)
        loss_flash, _ = lm.lm_loss(p, cfg_flash, batch)
        np.testing.assert_allclose(float(loss_flash), float(loss_ref),
                                   rtol=1e-3)

    def test_chunked_matches_ref_full_model(self):
        import dataclasses
        cfg = configs.get_config("qwen3-1.7b", smoke=True)
        p = lm.init_lm(Maker("init", jax.random.PRNGKey(52)), cfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(53),
                                              (2, 17), 0, cfg.vocab)}
        loss_ref, _ = lm.lm_loss(p, cfg, batch)
        cfg_c = dataclasses.replace(cfg, attn_impl="chunked", attn_chunk=8)
        loss_c, _ = lm.lm_loss(p, cfg_c, batch)
        np.testing.assert_allclose(float(loss_c), float(loss_ref), rtol=1e-4)


class TestServingEngine:
    def test_continuous_batching(self):
        from repro.serving import DecodeEngine, Request
        cfg = configs.get_config("smollm-360m", smoke=True)
        p = lm.init_lm(Maker("init", jax.random.PRNGKey(40)), cfg)
        eng = DecodeEngine(p, cfg, batch=2, max_len=32)
        for rid in range(5):
            eng.submit(Request(rid=rid, prompt=[1, 2, 3], max_new=4))
        done = eng.run()
        assert len(done) == 5
        assert all(len(r.out) == 4 for r in done)

    def test_greedy_decode_deterministic(self):
        from repro.serving import DecodeEngine, Request
        cfg = configs.get_config("qwen3-1.7b", smoke=True)
        p = lm.init_lm(Maker("init", jax.random.PRNGKey(41)), cfg)
        outs = []
        for _ in range(2):
            eng = DecodeEngine(p, cfg, batch=1, max_len=16)
            eng.submit(Request(rid=0, prompt=[5, 6], max_new=5))
            outs.append(eng.run()[0].out)
        assert outs[0] == outs[1]
