"""Whole-batch fused dispatch (ISSUE 5): oracle, ragged-padding,
zero-host-round-trip and partial-batch-cache tests.

``dispatch="batch_fused"`` concatenates the Algorithm-1 schedules of all
batch images into ONE ragged-padded kernel grid per layer segment, and
with ``schedule_backend="device"`` the device scheduler's arrays flow
directly into the dispatch operands — no host ``TileSchedule`` on the
hot path. These tests pin down that:

  * batch-fused == per-image batched == XLA reference numerics across
    rect tiles, ragged grids, and both schedule backends;
  * the per-image trace records (and therefore the executor-vs-simulator
    DRAM cross-check) are EXACTLY those of per-image dispatch — the
    concatenated grid order is the concatenated schedule order;
  * batches mixing empty and full schedules pad per image with elided
    slots and still compute correctly;
  * the device-backend hot path performs no host TileSchedule builds;
  * partial batch hits in the ScheduleCache skip scheduling only for the
    hit images and splice the misses into the batch grid.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scheduler
from repro.core.deform import (deformable_conv2d, init_deformable_conv,
                               randomize_offset_conv)
from repro.core.scheduler import (DeviceSchedule, schedule_arrays_device,
                                  schedule_tiles)
from repro.core.simulator import simulate_network, simulate_strategies
from repro.core.tiles import (TileGrid, per_pixel_input_tiles,
                              tdt_from_coords)
from repro.kernels.dcn_fused import dcn_fused_batch, dcn_fused_schedule
from repro.models.dcn_models import DcnNetConfig, init_dcn_net
from repro.runtime import (GraphConfig, PipelineConfig, ScheduleCache,
                           dcn_pipeline, pack_batch_schedules,
                           pack_plane_operands, pack_schedule_tiles,
                           run_graph, run_graph_dense)
from repro.runtime.fused_exec import network_sim_specs
from repro.runtime.packing import build_neighbour_tables
from repro.serving import DcnServingEngine

from tests.test_graph import _acceptance_case


def _layer(key, c_in, c_out, variant="dcn2", offset_scale=0.7):
    p = init_deformable_conv(key, c_in, c_out, 3, variant)
    return randomize_offset_conv(p, jax.random.fold_in(key, 1), offset_scale)


class TestBatchFusedPipelineOracle:
    @pytest.mark.parametrize("h,w,tile", [
        (16, 16, 8),        # divisible
        (13, 13, 4),        # non-divisible (ragged edge tiles)
        (12, 10, (3, 5)),   # rectangular plane AND rectangular tiles
        (9, 14, (4, 3)),    # both dims ragged
    ])
    @pytest.mark.parametrize("backend", ["host", "device"])
    def test_batch_fused_equals_batched_equals_xla(self, h, w, tile,
                                                   backend):
        key = jax.random.PRNGKey(h * 37 + w)
        params = _layer(key, 5, 7)
        x = jax.random.normal(jax.random.fold_in(key, 2), (3, h, w, 5))
        y_ref = deformable_conv2d(x, params)
        y_f, tr_f = dcn_pipeline(
            x, params, return_trace=True,
            config=PipelineConfig(tile=tile, dispatch="batch_fused",
                                  schedule_backend=backend,
                                  use_schedule_cache=False))
        y_b = dcn_pipeline(
            x, params,
            config=PipelineConfig(tile=tile, use_schedule_cache=False))
        np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_b),
                                   rtol=1e-5, atol=1e-5)
        # ONE dispatch for the whole batch (vs one per image batched).
        assert tr_f.kernel_dispatches == 1
        assert tr_f.dispatches_per_batch == 1
        assert all(im.dispatch == "batch_fused" for im in tr_f.images)

    def test_batch_of_one(self):
        key = jax.random.PRNGKey(9)
        params = _layer(key, 4, 6)
        x = jax.random.normal(jax.random.fold_in(key, 2), (1, 13, 13, 4))
        y_ref = deformable_conv2d(x, params)
        y = dcn_pipeline(x, params,
                         config=PipelineConfig(tile=4,
                                               dispatch="batch_fused"))
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_records_identical_to_batched(self):
        """The per-image spans of the fused dispatch preserve each
        image's schedule order, so records — and the FIFO replay the
        simulator cross-check consumes — are byte-identical."""
        key = jax.random.PRNGKey(3)
        params = _layer(key, 4, 6)
        x = jax.random.normal(jax.random.fold_in(key, 2), (3, 13, 13, 4))
        _, tr_b = dcn_pipeline(
            x, params, return_trace=True,
            config=PipelineConfig(tile=4, use_schedule_cache=False))
        _, tr_f = dcn_pipeline(
            x, params, return_trace=True,
            config=PipelineConfig(tile=4, dispatch="batch_fused",
                                  use_schedule_cache=False))
        t = tr_f.images[0].grid.num_tiles
        for i, (ib, im) in enumerate(zip(tr_b.images, tr_f.images)):
            assert [r.out_tile for r in ib.records] == \
                [r.out_tile for r in im.records]
            assert [r.dep_tiles for r in ib.records] == \
                [r.dep_tiles for r in im.records]
            assert im.batch_rows == (i * t, (i + 1) * t)
        assert tr_f.fifo_loads() == tr_b.fifo_loads()

    def test_pipeline_fifo_equals_simulator(self):
        """Concatenated-schedule FIFO loads == sum of per-image simulator
        scheduled loads (the executor-vs-simulator invariant, batched
        across the fused grid)."""
        key = jax.random.PRNGKey(11)
        params = _layer(key, 4, 4, offset_scale=1.5)
        x = jax.random.normal(jax.random.fold_in(key, 2), (3, 16, 16, 4))
        m = 2
        _, tr = dcn_pipeline(
            x, params, return_trace=True,
            config=PipelineConfig(tile=8, buffer_tiles=m,
                                  dispatch="batch_fused",
                                  use_schedule_cache=False))
        from repro.core.deform import conv2d, offsets_to_coords
        offsets = conv2d(x, params.w_off, params.b_off)
        coords = offsets_to_coords(offsets.astype(jnp.float32), 3, "dcn2")
        grid = TileGrid(16, 16, 8, 8)
        sim_total = 0
        for i in range(x.shape[0]):
            B = np.asarray(tdt_from_coords(coords[i], grid, grid))
            pp = np.asarray(per_pixel_input_tiles(coords[i], grid))
            rep = simulate_strategies(
                B, pp, grid, channels=4, c_out=4, kernel_size=3,
                buffer_bytes=m * grid.tile_bytes(4, 4), dtype_bytes=4)
            sim_total += rep["scheduled"].tile_loads
        assert tr.fifo_loads() == sim_total


class TestBatchFusedGraphOracle:
    @pytest.mark.parametrize("backend", ["host", "device"])
    def test_matches_dense_and_batched(self, backend):
        convs, graph, x = _acceptance_case()
        y_ref = run_graph_dense(convs, graph, x)
        y_f = run_graph(convs, graph, x, config=GraphConfig(
            tile=4, dispatch="batch_fused", schedule_backend=backend,
            use_schedule_cache=False))
        y_b = run_graph(convs, graph, x, config=GraphConfig(
            tile=4, dispatch="batched", use_schedule_cache=False))
        np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_b),
                                   rtol=1e-5, atol=1e-5)

    def test_one_dispatch_per_segment_per_batch(self):
        """ISSUE 5 acceptance: kernel dispatches per layer segment == 1
        for the WHOLE batch (down from one per image)."""
        convs, graph, x = _acceptance_case()
        x4 = jnp.concatenate([x, x[::-1]])          # batch of 4
        _, tr_b = run_graph(convs, graph, x4,
                            config=GraphConfig(tile=4, dispatch="batched"),
                            return_trace=True)
        _, tr_f = run_graph(convs, graph, x4,
                            config=GraphConfig(tile=4,
                                               dispatch="batch_fused"),
                            return_trace=True)
        n_segments = sum(len(g.layer_stats)
                         for g in tr_b.groups if g.image == 0)
        assert tr_f.dispatches_per_batch == n_segments
        assert tr_b.kernel_dispatches == 4 * n_segments
        assert all(g.kernel_dispatches == 0 for g in tr_f.groups)

    def test_records_and_simulator_exact(self):
        """The executed trace of the fused batch grid must still equal
        the network DRAM simulator EXACTLY, per image."""
        convs, graph, x = _acceptance_case(seed=1)
        _, tr = run_graph(convs, graph, x,
                          config=GraphConfig(tile=4,
                                             dispatch="batch_fused",
                                             use_schedule_cache=False),
                          return_trace=True)
        sim = simulate_network(network_sim_specs(tr),
                               boundary_bytes=tr.boundary_bytes,
                               fused=True)
        for gt, rep in zip(tr.groups, sim.groups):
            assert gt.fifo_replay().loads == rep.tile_loads
            assert gt.input_load_bytes == rep.input_read_bytes
        assert tr.total_dram_bytes == sim.total_dram_bytes

    def test_records_identical_across_dispatch_modes(self):
        convs, graph, x = _acceptance_case(seed=2)
        traces = {}
        for disp in ("batched", "batch_fused"):
            _, tr = run_graph(convs, graph, x,
                              config=GraphConfig(tile=4, dispatch=disp,
                                                 use_schedule_cache=False),
                              return_trace=True)
            traces[disp] = {(g.image, g.group): g for g in tr.groups}
        assert traces["batched"].keys() == traces["batch_fused"].keys()
        for k, gb in traces["batched"].items():
            gf = traces["batch_fused"][k]
            assert [r.out_tile for r in gb.records] == \
                [r.out_tile for r in gf.records]
            assert [r.dep_tiles for r in gb.records] == \
                [r.dep_tiles for r in gf.records]

    @pytest.mark.parametrize("depth", [1, 2, 3])
    def test_staging_depth_overlaps_whole_batches(self, depth):
        """staging_depth stages SEGMENTS of the whole batch; numerics
        must not depend on the queue depth."""
        convs, graph, x = _acceptance_case(seed=4)
        outs = run_graph(convs, graph, x, config=GraphConfig(
            tile=4, dispatch="batch_fused", staging_depth=depth))
        ref = run_graph(convs, graph, x, config=GraphConfig(
            tile=4, dispatch="batch_fused", staging_depth=1))
        np.testing.assert_allclose(np.asarray(outs), np.asarray(ref),
                                   rtol=0, atol=0)


class TestRaggedBatchPadding:
    """Satellite: ragged-batch padding semantics — images whose schedule
    lengths differ pad to the uniform per-image row count with elided
    slots; a batch mixing an EMPTY schedule (the empty-TDT quirk: one
    step, zero deps) with a full one must still compute correctly."""

    def _coords(self, key, grid, n_imgs):
        h, w = grid.h, grid.w
        return jnp.stack([
            jnp.clip(jax.random.uniform(
                jax.random.fold_in(key, i), (h, w, 9, 2)) *
                jnp.asarray([h - 1.0, w - 1.0]), 0.0, None)
            for i in range(n_imgs)])

    def test_mixed_empty_and_full_schedules(self):
        grid = TileGrid(8, 8, 4, 4)
        t = grid.num_tiles
        tp = 16
        key = jax.random.PRNGKey(0)
        coords = self._coords(key, grid, 2)
        x = jax.random.normal(jax.random.fold_in(key, 9), (2, t, tp, 3))
        w = jax.random.normal(jax.random.fold_in(key, 10), (9, 3, 5)) * 0.3
        b = jax.random.normal(jax.random.fold_in(key, 11), (5,)) * 0.1

        # Image 0: the empty-TDT quirk schedule (one step, zero deps).
        # Image 1: a real full schedule from its coords.
        empty = schedule_tiles(np.zeros((t, t), bool), t)
        assert empty.oid == [0] and empty.iid == [[]]
        B1 = np.asarray(tdt_from_coords(coords[1], grid, grid))
        full = schedule_tiles(B1, t)
        scheds = [DeviceSchedule.from_host(empty, t),
                  DeviceSchedule.from_host(full, t)]
        batch = pack_batch_schedules(scheds, t, t)

        # Ragged padding: image 0 contributes 1 valid row, image 1 len(oid).
        oid = np.asarray(batch.oid)
        assert (oid[:t] >= 0).sum() == 1
        assert (oid[t:] >= 0).sum() == len(full.oid)
        # Padded rows' dep entries repeat a real dep of the SAME image
        # (DMA elision across the image boundary).
        dep = np.asarray(batch.dep_glb)
        assert (dep[1:t] == dep[1, 0]).all()
        assert (dep[:t] < t).all() and (dep[t:] >= t).all()

        idx, coeff = jax.vmap(
            lambda c: pack_plane_operands(c, grid, tp))(coords)
        y = dcn_fused_batch(
            x.reshape(2 * t, tp, 3), batch.row_id, batch.dep_glb,
            batch.dep_cnt, idx.reshape(2 * t, tp, 9, 4),
            coeff.reshape(2 * t, tp, 9, 4), w, b, t_in=t, interpret=True)

        # Image 0's single zero-dep row: bias only (packed coeff zeroed).
        np.testing.assert_allclose(
            np.asarray(y[0]), np.broadcast_to(np.asarray(b), (tp, 5)),
            rtol=1e-6, atol=1e-6)
        # Image 1's rows match the per-image batched schedule kernel.
        nb = build_neighbour_tables(coords[1], grid)
        dep_tbl, dep_cnt, idx1, cf1 = pack_schedule_tiles(
            nb, grid, full.oid, full.iid, tp,
            max(len(d) for d in full.iid))
        y1 = dcn_fused_schedule(
            x[1], jnp.asarray(dep_tbl), jnp.asarray(dep_cnt),
            jnp.asarray(idx1), jnp.asarray(cf1), w, b, interpret=True)
        valid = np.asarray(batch.oid[t:]) >= 0
        np.testing.assert_allclose(np.asarray(y[t:][valid]),
                                   np.asarray(y1), rtol=1e-5, atol=1e-5)

    def test_pack_batch_schedules_rejects_mismatched_grids(self):
        s1 = DeviceSchedule(np.zeros(4, np.int32), np.zeros((4, 2), np.int32),
                            np.zeros(4, np.int32), np.zeros(4, np.int32))
        s2 = DeviceSchedule(np.zeros(6, np.int32), np.zeros((6, 2), np.int32),
                            np.zeros(6, np.int32), np.zeros(6, np.int32))
        with pytest.raises(ValueError, match="share the tile grid"):
            pack_batch_schedules([s1, s2], 4, 4)


class TestDeviceScheduleHandoff:
    @pytest.mark.parametrize("seed", range(4))
    def test_device_schedule_bit_exact_vs_host(self, seed):
        """The dense device handoff, lazily assembled, must be byte-equal
        to the host Algorithm-1 schedule (same oid/iid/load order)."""
        rng = np.random.default_rng(seed)
        n = int(rng.integers(4, 12))
        B = rng.random((n, n)) < 0.4
        m = int(rng.integers(1, n + 1))
        host = schedule_tiles(B, m)
        ds = schedule_arrays_device(jnp.asarray(B), m, interpret=True)
        assert ds.to_host() == host

    def test_from_host_round_trip(self):
        rng = np.random.default_rng(7)
        B = rng.random((6, 6)) < 0.5
        host = schedule_tiles(B, 3)
        ds = DeviceSchedule.from_host(host, 6)
        assert ds.to_host() is host      # memoized, no rebuild
        rebuilt = DeviceSchedule(ds.oid, ds.dep_tbl, ds.dep_cnt,
                                 ds.overlap)
        assert rebuilt.to_host() == host

    def test_device_hot_path_builds_no_host_schedule(self):
        """ISSUE 5 acceptance: with schedule_backend="device" and
        dispatch="batch_fused", the hot path (return_trace=False)
        performs NO host TileSchedule construction — pipeline AND graph."""
        key = jax.random.PRNGKey(5)
        params = _layer(key, 4, 4)
        x = jax.random.normal(jax.random.fold_in(key, 2), (2, 13, 13, 4))
        convs, graph, xg = _acceptance_case(seed=3)

        c0 = scheduler.host_schedule_builds.count
        y = dcn_pipeline(x, params, config=PipelineConfig(
            tile=4, dispatch="batch_fused", schedule_backend="device",
            use_schedule_cache=False))
        jax.block_until_ready(y)
        y = run_graph(convs, graph, xg, config=GraphConfig(
            tile=4, dispatch="batch_fused", schedule_backend="device",
            use_schedule_cache=False))
        jax.block_until_ready(y)
        assert scheduler.host_schedule_builds.count == c0

        # ... and the lazy trace path DOES assemble them (off hot path).
        _, tr = dcn_pipeline(x, params, return_trace=True,
                             config=PipelineConfig(
                                 tile=4, dispatch="batch_fused",
                                 schedule_backend="device",
                                 use_schedule_cache=False))
        assert scheduler.host_schedule_builds.count > c0
        assert all(im.records for im in tr.images)


class TestPartialBatchCacheHits:
    def test_mixed_hit_miss_batch(self):
        """Satellite: cached images skip scheduling, misses are built and
        spliced into the batch grid; hit accounting splits into
        image_hits / batch_assemblies. Conv-only groups have
        data-independent digests, so they legitimately hit across
        images; deform groups are keyed per image."""
        from repro.runtime import DeformNode, FusedGroup, partition_graph
        convs, graph, x = _acceptance_case(seed=6)   # batch of 2
        cache = ScheduleCache(maxsize=64)
        cfg = GraphConfig(tile=4, dispatch="batch_fused")
        groups = [s for s in partition_graph(graph,
                                             cfg.onchip_budget_bytes, 4)
                  if isinstance(s, FusedGroup)]
        deform_groups = [gi for gi, g in enumerate(groups)
                         if any(isinstance(nd, DeformNode)
                                for nd in g.nodes)]
        n_groups, n_def = len(groups), len(deform_groups)
        assert n_def >= 1

        y1, tr1 = run_graph(convs, graph, x, config=cfg,
                            schedule_cache=cache, return_trace=True)
        info1 = cache.info()
        # Image 0 misses every group; image 1 misses the deform groups
        # and hits the static (conv-only) ones.
        assert info1["batch_assemblies"] == n_groups
        assert info1["misses"] == n_groups + n_def
        assert info1["image_hits"] == n_groups - n_def

        # Second batch: image 0 replayed (full hit), image 1 new (deform
        # groups miss and are spliced into the batch grid).
        x2 = jnp.concatenate([x[:1], x[1:] * 1.7])
        y2, tr2 = run_graph(convs, graph, x2, config=cfg,
                            schedule_cache=cache, return_trace=True)
        info2 = cache.info()
        assert info2["batch_assemblies"] == 2 * n_groups
        assert info2["misses"] == n_groups + 2 * n_def
        assert info2["image_hits"] == \
            info1["image_hits"] + 2 * n_groups - n_def
        hits = {(g.image, g.group): g.schedule_cache_hit
                for g in tr2.groups}
        assert all(hits[(0, g)] for g in range(n_groups))
        assert not any(hits[(1, g)] for g in deform_groups)

        # The mixed hit/miss batch must equal a cache-less run exactly.
        y_ref = run_graph(convs, graph, x2,
                          config=GraphConfig(tile=4,
                                             dispatch="batch_fused",
                                             use_schedule_cache=False))
        np.testing.assert_array_equal(np.asarray(y2), np.asarray(y_ref))
        # Image 0's rows are identical to the first batch's.
        np.testing.assert_array_equal(np.asarray(y2[0]),
                                      np.asarray(y1[0]))

    def test_serving_stats_expose_batch_counters(self):
        cfg = DcnNetConfig(name="vgg19", n_deform=2, img_size=16,
                           width_mult=0.125, num_classes=4)
        p = init_dcn_net(jax.random.PRNGKey(2), cfg)
        eng = DcnServingEngine(
            p, cfg, graph=GraphConfig(tile=4, dispatch="batch_fused"))
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 16, 3))
        eng.infer(x)
        eng.infer(x)
        s = eng.stats
        assert s["dispatch"] == "batch_fused"
        assert s["batch_assemblies"] > 0
        assert s["image_hits"] > 0                   # second request replays
        assert s["dispatches_per_batch"] == s["kernel_dispatches"] / 2
        assert s["kernel_dispatches"] > 0


class TestConfigValidation:
    def test_batch_fused_accepted_everywhere(self):
        assert PipelineConfig(dispatch="batch_fused").dispatch == \
            "batch_fused"
        assert GraphConfig(dispatch="batch_fused").dispatch == "batch_fused"

    def test_unknown_dispatch_still_rejected(self):
        with pytest.raises(ValueError, match="dispatch"):
            PipelineConfig(dispatch="fused_batch")
        with pytest.raises(ValueError, match="dispatch"):
            GraphConfig(dispatch="mega")
