"""Batched tile-grid dispatch: oracle, trace-invariant and config tests.

The batched executors (ISSUE 3) replace the per-tile Python dispatch loop
with ONE ``pallas_call`` grid per (group, layer segment) — the Algorithm-1
schedule becomes the grid order, the scalar-prefetched dep table the DMA
sequence. These tests pin down that:

  * batched == per-tile == XLA reference numerics (rectangular tiles,
    non-divisible shapes, multi-layer fused groups);
  * the executed trace still equals the DRAM simulator EXACTLY (the
    records are the schedule, which batching preserves);
  * the dispatch count drops from O(num_tiles) per segment to <= the
    number of layer segments per group;
  * empty schedules and degenerate tile configs are handled loudly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.deform import (deformable_conv2d, init_deformable_conv,
                               randomize_offset_conv)
from repro.core.scheduler import schedule_tiles
from repro.core.simulator import simulate_network
from repro.core.tiles import TileGrid
from repro.kernels.dcn_fused import dcn_fused_schedule
from repro.models.dcn_models import DcnNetConfig, dcn_net_apply, init_dcn_net
from repro.runtime import (GraphConfig, PipelineConfig, build_neighbour_tables,
                           dcn_pipeline, pack_schedule_tiles, run_graph,
                           run_graph_dense)
from repro.runtime.fused_exec import network_sim_specs
from repro.serving import DcnServingEngine

from tests.test_graph import _acceptance_case


def _layer(key, c_in, c_out, variant="dcn2", offset_scale=0.7):
    p = init_deformable_conv(key, c_in, c_out, 3, variant)
    return randomize_offset_conv(p, jax.random.fold_in(key, 1), offset_scale)


class TestBatchedPipelineOracle:
    @pytest.mark.parametrize("h,w,tile", [
        (16, 16, 8),        # divisible
        (13, 13, 4),        # non-divisible (edge tiles)
        (12, 10, (3, 5)),   # rectangular plane AND rectangular tiles
        (9, 14, (4, 3)),    # both dims ragged
    ])
    def test_batched_equals_per_tile_equals_xla(self, h, w, tile):
        key = jax.random.PRNGKey(h * 37 + w)
        params = _layer(key, 5, 7)
        x = jax.random.normal(jax.random.fold_in(key, 2), (2, h, w, 5))
        y_ref = deformable_conv2d(x, params)
        y_b, tr_b = dcn_pipeline(
            x, params, return_trace=True,
            config=PipelineConfig(tile=tile, use_schedule_cache=False))
        y_p, tr_p = dcn_pipeline(
            x, params, return_trace=True,
            config=PipelineConfig(tile=tile, dispatch="per_tile",
                                  staging_depth=1,
                                  use_schedule_cache=False))
        np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_p),
                                   rtol=1e-5, atol=1e-5)
        # One batched grid dispatch per image vs one per schedule entry.
        assert tr_b.kernel_dispatches == 2
        assert tr_p.kernel_dispatches == sum(
            len(im.records) for im in tr_p.images)
        assert tr_b.kernel_dispatches < tr_p.kernel_dispatches

    def test_staging_depth_does_not_change_numerics(self):
        key = jax.random.PRNGKey(3)
        params = _layer(key, 4, 6)
        x = jax.random.normal(jax.random.fold_in(key, 2), (3, 13, 13, 4))
        outs = [dcn_pipeline(x, params,
                             config=PipelineConfig(tile=4, staging_depth=d))
                for d in (1, 2, 3)]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                       rtol=0, atol=0)

    def test_overlap_spans_recorded(self):
        key = jax.random.PRNGKey(5)
        params = _layer(key, 4, 4)
        x = jax.random.normal(jax.random.fold_in(key, 2), (3, 16, 16, 4))
        _, tr = dcn_pipeline(
            x, params, return_trace=True,
            config=PipelineConfig(tile=8, use_schedule_cache=False))
        assert tr.overlap.prepass_s > 0
        assert 0.0 <= tr.host_overlap_frac <= 1.0


class TestBatchedGraphOracle:
    @pytest.mark.parametrize("dispatch,depth", [
        ("batched", 1), ("batched", 2), ("per_tile", 2),
    ])
    def test_matches_dense_reference(self, dispatch, depth):
        convs, graph, x = _acceptance_case()
        y_ref = run_graph_dense(convs, graph, x)
        y = run_graph(convs, graph, x,
                      config=GraphConfig(tile=4, dispatch=dispatch,
                                         staging_depth=depth))
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_batched_equals_per_tile(self):
        convs, graph, x = _acceptance_case(seed=3)
        y_b = run_graph(convs, graph, x, config=GraphConfig(
            tile=4, dispatch="batched", use_schedule_cache=False))
        y_p = run_graph(convs, graph, x, config=GraphConfig(
            tile=4, dispatch="per_tile", use_schedule_cache=False))
        np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_p),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("buffer_tiles", [None, 4, 2])
    def test_trace_matches_simulator_exactly(self, buffer_tiles):
        """ISSUE 3 acceptance: the batched path's executed trace still
        agrees EXACTLY with the network simulator's FIFO replay — the
        schedule order became the grid order, so the records are
        byte-identical to the per-tile executor's."""
        convs, graph, x = _acceptance_case()
        _, trace = run_graph(
            convs, graph, x[:1],
            config=GraphConfig(tile=4, buffer_tiles=buffer_tiles,
                               dispatch="batched"),
            return_trace=True)
        sim = simulate_network(network_sim_specs(trace),
                               boundary_bytes=trace.boundary_bytes,
                               fused=True)
        for gt, rep in zip(trace.groups, sim.groups):
            assert gt.fifo_replay().loads == rep.tile_loads
            assert gt.input_load_bytes == rep.input_read_bytes
        assert trace.total_dram_bytes == sim.total_dram_bytes

    def test_records_identical_across_dispatch_modes(self):
        convs, graph, x = _acceptance_case(seed=1)
        traces = {}
        for disp in ("batched", "per_tile"):
            _, tr = run_graph(convs, graph, x[:1],
                              config=GraphConfig(tile=4, dispatch=disp),
                              return_trace=True)
            traces[disp] = tr
        for gb, gp in zip(traces["batched"].groups,
                          traces["per_tile"].groups):
            assert [r.out_tile for r in gb.records] == \
                [r.out_tile for r in gp.records]
            assert [r.dep_tiles for r in gb.records] == \
                [r.dep_tiles for r in gp.records]

    def test_dispatch_count_bounded_by_segments(self):
        """ISSUE 3 acceptance: kernel dispatches per group <= number of
        layer segments (was O(num_tiles x layers))."""
        convs, graph, x = _acceptance_case()
        _, tr_b = run_graph(convs, graph, x[:1],
                            config=GraphConfig(tile=4, dispatch="batched"),
                            return_trace=True)
        _, tr_p = run_graph(convs, graph, x[:1],
                            config=GraphConfig(tile=4, dispatch="per_tile"),
                            return_trace=True)
        for gt in tr_b.groups:
            assert gt.kernel_dispatches <= len(gt.layer_stats)
        assert tr_b.kernel_dispatches < tr_p.kernel_dispatches

    def test_batched_is_default(self):
        convs, graph, x = _acceptance_case()
        assert GraphConfig().dispatch == "batched"
        assert PipelineConfig().dispatch == "batched"
        _, tr = run_graph(convs, graph, x[:1],
                          config=GraphConfig(tile=4), return_trace=True)
        assert all(g.dispatch == "batched" for g in tr.groups)


class TestEmptyScheduleAndPacking:
    def test_fused_schedule_kernel_empty(self):
        x_tiles = jnp.zeros((4, 16, 3))
        dep_tbl = jnp.zeros((0, 2), jnp.int32)
        dep_cnt = jnp.zeros((0,), jnp.int32)
        idx = jnp.zeros((0, 16, 9, 4), jnp.int32)
        coeff = jnp.zeros((0, 16, 9, 4), jnp.float32)
        w = jnp.zeros((9, 3, 5))
        b = jnp.zeros((5,))
        y = dcn_fused_schedule(x_tiles, dep_tbl, dep_cnt, idx, coeff, w, b,
                               interpret=True)
        assert y.shape == (0, 16, 5)

    def test_pack_schedule_tiles_empty_schedule(self):
        grid = TileGrid(8, 8, 4, 4)
        coords = jnp.zeros((8, 8, 9, 2))
        nb = build_neighbour_tables(coords, grid)
        dep_tbl, dep_cnt, idx, coeff = pack_schedule_tiles(
            nb, grid, [], [], 16, 2)
        assert dep_tbl.shape == (0, 2)
        assert dep_cnt.shape == (0,)
        assert idx.shape == (0, 16, 9, 4)

    def test_pack_schedule_tiles_empty_dep_row_zero_coeff(self):
        grid = TileGrid(8, 8, 4, 4)
        coords = jnp.zeros((8, 8, 9, 2))
        nb = build_neighbour_tables(coords, grid)
        dep_tbl, dep_cnt, idx, coeff = pack_schedule_tiles(
            nb, grid, [0, 1], [[0, 1], []], 16, 2)
        assert dep_cnt.tolist() == [2, 0]
        assert coeff[1].sum() == 0.0
        assert coeff[0].sum() > 0.0

    def test_schedule_dense_roundtrip(self):
        B = np.zeros((4, 4), bool)
        B[0, :2] = True
        B[2, 1:] = True
        sched = schedule_tiles(B, 4)
        oid, deps, counts = sched.dense()
        assert oid.tolist() == sched.oid
        for n, d in enumerate(sched.iid):
            assert deps[n, :counts[n]].tolist() == d
            assert not deps[n, counts[n]:].any()


class TestConfigValidation:
    def test_pipeline_tile_exceeds_plane(self):
        key = jax.random.PRNGKey(0)
        params = _layer(key, 4, 4)
        x = jnp.zeros((1, 8, 8, 4))
        with pytest.raises(ValueError, match="exceeds"):
            dcn_pipeline(x, params, tile=16)

    def test_graph_tile_exceeds_plane(self):
        convs, graph, x = _acceptance_case()
        with pytest.raises(ValueError, match="exceeds"):
            run_graph(convs, graph, x, config=GraphConfig(tile=64))

    def test_graph_input_shape_mismatch_raises(self):
        """A size-mismatched image must raise, not silently produce
        garbage from schedules built for the graph's plane."""
        convs, graph, _ = _acceptance_case()     # 13x13 graph
        with pytest.raises(ValueError, match="does not match"):
            run_graph(convs, graph, jnp.zeros((1, 8, 8, 3)),
                      config=GraphConfig(tile=4))

    def test_unknown_dispatch_rejected(self):
        with pytest.raises(ValueError, match="dispatch"):
            GraphConfig(dispatch="warp")
        with pytest.raises(ValueError, match="dispatch"):
            PipelineConfig(dispatch="warp")

    def test_bad_staging_depth_rejected(self):
        with pytest.raises(ValueError, match="staging_depth"):
            GraphConfig(staging_depth=0)
        with pytest.raises(ValueError, match="staging_depth"):
            PipelineConfig(staging_depth=-1)

    def test_graph_backend_clamps_small_images(self):
        """backend="graph" with the DEFAULT GraphConfig (tile=8) must
        still serve images smaller than the tile — the model/serving
        entry points clamp, only the raw executor rejects."""
        cfg = DcnNetConfig(name="vgg19", n_deform=1, img_size=4,
                           width_mult=0.125, num_classes=4)
        p = init_dcn_net(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 4, 3))
        y_xla = dcn_net_apply(p, cfg, x, backend="xla", fused=False)
        y_g = dcn_net_apply(p, cfg, x, backend="graph")
        np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_xla),
                                   rtol=5e-3, atol=5e-3)
        eng = DcnServingEngine(p, cfg)
        y_s = eng.infer(x)
        np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_xla),
                                   rtol=5e-3, atol=5e-3)

    def test_model_backend_still_clamps_interior_planes(self):
        """Deep-stage planes shrink below the requested tile; the model
        entry points clamp per layer/group instead of erroring."""
        cfg = DcnNetConfig(name="vgg19", n_deform=2, img_size=16,
                           width_mult=0.125, num_classes=4)
        p = init_dcn_net(jax.random.PRNGKey(2), cfg)
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 16, 3))
        y_xla = dcn_net_apply(p, cfg, x, backend="xla", fused=False)
        y_g = dcn_net_apply(p, cfg, x, backend="graph",
                            graph=GraphConfig(tile=4))
        np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_xla),
                                   rtol=5e-3, atol=5e-3)


class TestDcnServing:
    def _engine(self):
        cfg = DcnNetConfig(name="vgg19", n_deform=2, img_size=16,
                           width_mult=0.125, num_classes=4)
        p = init_dcn_net(jax.random.PRNGKey(2), cfg)
        return DcnServingEngine(p, cfg, graph=GraphConfig(tile=4)), cfg, p

    def test_replayed_request_hits_schedule_cache(self):
        eng, _, _ = self._engine()
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 16, 3))
        y1 = eng.infer(x)
        miss1 = eng.stats["schedule_cache_misses"]
        assert eng.stats["schedule_cache_hits"] == 0
        y2 = eng.infer(x)
        s = eng.stats
        assert s["schedule_cache_hits"] == miss1    # full replay
        assert s["schedule_cache_misses"] == miss1  # no new builds
        assert s["requests"] == 2 and s["images"] == 2
        assert s["schedule_cache_hit_rate"] == 0.5
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))

    def test_stats_expose_dispatches(self):
        eng, cfg, p = self._engine()
        x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, 16, 3))
        y = eng.infer(x)
        s = eng.stats
        assert s["kernel_dispatches"] > 0
        y_ref = dcn_net_apply(p, cfg, x, backend="xla", fused=False)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=5e-3, atol=5e-3)

    def test_engine_matches_model_graph_backend_exactly(self):
        """The engine's serve path (clamp + run_graph + head) must stay
        the same computation as dcn_net_apply(backend="graph") — pins the
        two graph entry points together bitwise."""
        eng, cfg, p = self._engine()
        x = jax.random.normal(jax.random.PRNGKey(6), (1, 16, 16, 3))
        y_eng = eng.infer(x)
        y_model = dcn_net_apply(p, cfg, x, backend="graph",
                                graph=GraphConfig(tile=4))
        np.testing.assert_array_equal(np.asarray(y_eng),
                                      np.asarray(y_model))
