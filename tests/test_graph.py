"""Oracle + accounting tests for the network-graph executor.

The cross-layer fused executor (repro.runtime.fused_exec) must be
numerically indistinguishable from the dense XLA reference on multi-layer
networks — including a conv -> DCN -> conv fused group, a pool boundary
and shapes that do not divide by the tile size — and its executed trace
must agree EXACTLY with the network-level DRAM-traffic simulator, with
the fused execution strictly cheaper than the per-layer (PR 1) execution
of the same network.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.deform import init_deformable_conv, randomize_offset_conv
from repro.core.fusion import FusionMode, LayerShape, plan_fused_groups
from repro.core.simulator import simulate_network
from repro.core.tiles import TileGrid, compose_tdt, tdt_standard_conv
from repro.models.dcn_models import DcnNetConfig, dcn_net_apply, init_dcn_net
from repro.runtime import (ConvNode, DeformNode, FusedGroup, GraphConfig,
                           NetGraph, PoolNode, UpsampleNode, build_graph,
                           partition_graph, run_graph, run_graph_dense)
from repro.runtime.fused_exec import network_sim_specs


def _conv_p(key, c_in, c_out, scale=0.2):
    return {"w": jax.random.normal(key, (3, 3, c_in, c_out)) * scale,
            "b": jax.random.normal(jax.random.fold_in(key, 1),
                                   (c_out,)) * 0.1}


def _deform_p(key, c_in, c_out, offset_scale=0.5):
    p = init_deformable_conv(key, c_in, c_out, 3, "dcn2")
    return randomize_offset_conv(p, jax.random.fold_in(key, 1), offset_scale)


def _acceptance_case(h=13, w=13, seed=0):
    """conv -> DCN -> conv (one fused group), pool boundary, trailing conv;
    13x13 does not divide by the tile size."""
    key = jax.random.PRNGKey(seed)
    convs = [
        _conv_p(jax.random.fold_in(key, 0), 3, 6),
        _deform_p(jax.random.fold_in(key, 1), 6, 6),
        _conv_p(jax.random.fold_in(key, 2), 6, 8),
        _conv_p(jax.random.fold_in(key, 3), 8, 8),
    ]
    nodes = (ConvNode(0, 3, 6, h, w), DeformNode(1, 6, 6, h, w),
             ConvNode(2, 6, 8, h, w), PoolNode(h, w, 8),
             ConvNode(3, 8, 8, (h - 2) // 2 + 1, (w - 2) // 2 + 1))
    graph = NetGraph(nodes, h, w, 3)
    x = jax.random.normal(jax.random.fold_in(key, 4), (2, h, w, 3))
    return convs, graph, x


class TestGraphOracle:
    def test_acceptance_network_matches_xla(self):
        """ISSUE 2 acceptance: >=3-layer network with conv -> DCN -> conv,
        a pool boundary and a non-divisible shape, within 1e-4."""
        convs, graph, x = _acceptance_case()
        y_ref = run_graph_dense(convs, graph, x)
        y, trace = run_graph(convs, graph, x, config=GraphConfig(tile=4),
                             return_trace=True)
        assert y.shape == y_ref.shape
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
        # conv -> DCN -> conv really fused into ONE group
        first = [g for g in trace.groups if g.image == 0][0]
        assert [s.kind for s in first.layer_stats] == ["conv", "deform",
                                                       "conv"]

    @pytest.mark.parametrize("tile", [2, 4, (3, 5)])
    def test_tile_size_does_not_change_numerics(self, tile):
        convs, graph, x = _acceptance_case(seed=1)
        y_ref = run_graph_dense(convs, graph, x)
        y = run_graph(convs, graph, x, config=GraphConfig(tile=tile))
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_bounded_tile_buffer_recomputes_not_wrong(self):
        """A 1-tile intermediate buffer forces evict+recompute; numerics
        must not change and recomputes must actually happen (bounded
        buffers are a per_tile-dispatch mechanism — batched dispatch
        computes every tile exactly once)."""
        convs, graph, x = _acceptance_case(seed=2)
        y_ref = run_graph_dense(convs, graph, x)
        y, trace = run_graph(
            convs, graph, x,
            config=GraphConfig(tile=4, inter_buffer_tiles=1,
                               dispatch="per_tile"),
            return_trace=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
        assert trace.total_recomputes > 0

    def test_upsample_boundary(self):
        key = jax.random.PRNGKey(5)
        h = w = 6
        convs = [_conv_p(jax.random.fold_in(key, 0), 3, 4),
                 _conv_p(jax.random.fold_in(key, 1), 4, 4)]
        nodes = (ConvNode(0, 3, 4, h, w), UpsampleNode(h, w, 4),
                 ConvNode(1, 4, 4, 2 * h, 2 * w))
        graph = NetGraph(nodes, h, w, 3)
        x = jax.random.normal(jax.random.fold_in(key, 2), (1, h, w, 3))
        y_ref = run_graph_dense(convs, graph, x)
        y = run_graph(convs, graph, x, config=GraphConfig(tile=4))
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_empty_batch(self):
        convs, graph, _ = _acceptance_case()
        x = jnp.zeros((0, 13, 13, 3))
        y = run_graph(convs, graph, x, config=GraphConfig(tile=4))
        assert y.shape == (0, 6, 6, 8)

    def test_tracer_rejected(self):
        convs, graph, x = _acceptance_case()
        with pytest.raises(ValueError, match="host-driven"):
            jax.jit(lambda v: run_graph(convs, graph, v))(x)


class TestGraphAccounting:
    def _trace(self, buffer_tiles=None, seed=0):
        convs, graph, x = _acceptance_case(seed=seed)
        _, trace = run_graph(
            convs, graph, x[:1],
            config=GraphConfig(tile=4, buffer_tiles=buffer_tiles),
            return_trace=True)
        return trace

    @pytest.mark.parametrize("buffer_tiles", [None, 4, 2])
    def test_executed_trace_matches_simulator_exactly(self, buffer_tiles):
        """ISSUE 2 acceptance: network-level simulator and executed trace
        agree exactly (loads and bytes) under the same FIFO model."""
        trace = self._trace(buffer_tiles=buffer_tiles)
        sim = simulate_network(network_sim_specs(trace),
                               boundary_bytes=trace.boundary_bytes,
                               fused=True)
        for gt, rep in zip(trace.groups, sim.groups):
            assert gt.fifo_replay().loads == rep.tile_loads
            assert gt.input_load_bytes == rep.input_read_bytes
            assert gt.output_bytes == rep.output_write_bytes
            assert gt.weight_bytes == rep.weight_read_bytes
        assert trace.total_dram_bytes == sim.total_dram_bytes

    def test_fused_strictly_below_layerwise(self):
        """ISSUE 2 acceptance: fused DRAM traffic strictly below the
        per-layer (PR 1) execution of the same network."""
        trace = self._trace()
        specs = network_sim_specs(trace)
        fused = simulate_network(specs, boundary_bytes=trace.boundary_bytes,
                                 fused=True)
        layerwise = simulate_network(specs,
                                     boundary_bytes=trace.boundary_bytes,
                                     fused=False)
        assert fused.total_dram_bytes < layerwise.total_dram_bytes
        # interior planes are exactly what the fusion removes
        assert sum(g.intermediate_bytes for g in layerwise.groups) > 0
        assert all(g.intermediate_bytes == 0 for g in fused.groups)

    def test_schedule_covers_every_output_tile(self):
        trace = self._trace()
        for gt in trace.groups:
            executed = sorted(r.out_tile for r in gt.records)
            assert executed == list(range(gt.grid.num_tiles))

    def test_group_deps_match_composite_tdt(self):
        """Each group-schedule entry packs exactly the composite-TDT row."""
        trace = self._trace()
        for gt in trace.groups:
            comp = np.asarray(gt.b_layers[-1], bool)
            for b in gt.b_layers[-2::-1]:
                comp = compose_tdt(comp, b)
            for r in gt.records:
                assert sorted(r.dep_tiles) == \
                    np.flatnonzero(comp[r.out_tile]).tolist()


class TestGraphIR:
    def test_compose_tdt_matches_bruteforce(self):
        rng = np.random.default_rng(0)
        a = rng.random((6, 5)) < 0.4
        b = rng.random((5, 7)) < 0.4
        want = np.zeros((6, 7), bool)
        for o in range(6):
            for m in range(5):
                if a[o, m]:
                    want[o] |= b[m]
        np.testing.assert_array_equal(compose_tdt(a, b), want)

    def test_compose_tdt_shape_mismatch(self):
        with pytest.raises(ValueError, match="chain"):
            compose_tdt(np.ones((2, 3), bool), np.ones((4, 2), bool))

    def test_composite_halo_grows(self):
        """Two chained 3x3 convs must reach at least the tiles one conv
        reaches (a 5x5 effective receptive field)."""
        grid = TileGrid(16, 16, 4, 4)
        b1 = tdt_standard_conv(grid, grid)
        comp = compose_tdt(b1, b1)
        assert (comp & ~b1).sum() >= 0
        assert comp.sum() >= b1.sum()

    def test_segnet_decoder_shape_parity(self):
        """Every decoder upsample pairs with a pool that actually ran:
        tiny segnet inputs must come back at input resolution (img_size=8
        used to produce 32x32 logits), in the model AND the graph IR."""
        cfg = DcnNetConfig(name="segnet", n_deform=2, img_size=8,
                           width_mult=0.125, num_classes=3)
        graph = build_graph(cfg)
        assert graph.out_shape[:2] == (8, 8)
        pools = sum(isinstance(n, PoolNode) for n in graph.nodes)
        ups = sum(isinstance(n, UpsampleNode) for n in graph.nodes)
        assert pools == ups
        p = init_dcn_net(jax.random.PRNGKey(0), cfg)
        x = jnp.zeros((1, 8, 8, 3))
        y = dcn_net_apply(p, cfg, x, backend="xla", fused=False)
        assert y.shape == (1, 8, 8, 3)

    def test_build_graph_mirrors_model(self):
        cfg = DcnNetConfig(name="vgg19", n_deform=2, img_size=16,
                           width_mult=0.125, num_classes=4)
        graph = build_graph(cfg)
        layer_nodes = [n for n in graph.nodes
                       if isinstance(n, (ConvNode, DeformNode))]
        plan = cfg.stage_plan(False)
        assert len(layer_nodes) == len(plan)
        assert sum(isinstance(n, DeformNode) for n in layer_nodes) == 2
        # pools appear while the plane is >= 2 pixels on a side
        assert any(isinstance(n, PoolNode) for n in graph.nodes)

    def test_partition_pool_breaks_groups(self):
        convs, graph, _ = _acceptance_case()
        segments = partition_graph(graph, (128 + 256) * 1024)
        kinds = [type(s).__name__ for s in segments]
        assert kinds == ["FusedGroup", "PoolNode", "FusedGroup"]
        assert segments[0].n_layers == 3

    def test_partition_staged_is_singleton(self):
        """A zero on-chip budget forces STAGED: every layer its own group."""
        convs, graph, _ = _acceptance_case()
        segments = partition_graph(graph, onchip_budget_bytes=1)
        groups = [s for s in segments if isinstance(s, FusedGroup)]
        assert all(g.n_layers == 1 for g in groups)
        assert all(p.mode is FusionMode.STAGED
                   for g in groups for p in g.plan.plans)

    def test_plan_fused_groups_saved_bytes(self):
        shapes = [LayerShape(8, 8, 4, 4, dtype_bytes=1)] * 3
        groups = plan_fused_groups(shapes, (128 + 256) * 1024)
        assert len(groups) == 1
        # two interior boundary planes, write + read each
        assert groups[0].dram_bytes_saved == 2 * 2 * 8 * 8 * 4

    def test_netgraph_validates_chain(self):
        with pytest.raises(ValueError, match="accept"):
            NetGraph((ConvNode(0, 3, 4, 8, 8), ConvNode(1, 5, 4, 8, 8)),
                     8, 8, 3)


class TestGraphModelBackend:
    def test_graph_backend_matches_xla(self):
        cfg = DcnNetConfig(name="vgg19", n_deform=2, img_size=16,
                           width_mult=0.125, num_classes=4)
        p = init_dcn_net(jax.random.PRNGKey(2), cfg)
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 16, 3))
        y_xla = dcn_net_apply(p, cfg, x, backend="xla", fused=False)
        y_graph = dcn_net_apply(p, cfg, x, backend="graph",
                                graph=GraphConfig(tile=4))
        np.testing.assert_allclose(np.asarray(y_graph), np.asarray(y_xla),
                                   rtol=5e-3, atol=5e-3)

    @pytest.mark.slow
    def test_graph_backend_segnet(self):
        cfg = DcnNetConfig(name="segnet", n_deform=2, img_size=8,
                           width_mult=0.125, num_classes=3)
        p = init_dcn_net(jax.random.PRNGKey(4), cfg)
        x = jax.random.normal(jax.random.PRNGKey(5), (1, 8, 8, 3))
        y_xla = dcn_net_apply(p, cfg, x, backend="xla", fused=False)
        y_graph = dcn_net_apply(p, cfg, x, backend="graph",
                                graph=GraphConfig(tile=4))
        np.testing.assert_allclose(np.asarray(y_graph), np.asarray(y_xla),
                                   rtol=5e-3, atol=5e-3)
