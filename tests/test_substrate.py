"""Substrate layers: optimizer, data pipeline, checkpointing, elastic."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data import DataConfig, PrefetchIterator, image_batch, token_batch
from repro.launch.elastic import ElasticConfig, StragglerDetector, plan_remesh
from repro.optim import (AdamWConfig, adamw_update, cosine_lr,
                         clip_by_global_norm, global_norm, init_opt_state)


class TestAdamW:
    def _quad_problem(self):
        target = jnp.array([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        def loss(p):
            return jnp.sum((p["w"] - target) ** 2)
        return params, loss, target

    def test_converges_on_quadratic(self):
        params, loss, target = self._quad_problem()
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=5,
                          total_steps=400)
        state = init_opt_state(params, cfg)
        for _ in range(400):
            g = jax.grad(loss)(params)
            params, state, _ = adamw_update(params, g, state, cfg)
        np.testing.assert_allclose(params["w"], target, atol=0.05)

    def test_weight_decay_only_on_matrices(self):
        params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
        cfg = AdamWConfig(lr=1e-2, weight_decay=0.5, clip_norm=None)
        state = init_opt_state(params, cfg)
        zeros = jax.tree.map(jnp.zeros_like, params)
        new, _, _ = adamw_update(params, zeros, state, cfg)
        assert float(jnp.abs(new["w"]).max()) < 1.0   # decayed
        np.testing.assert_allclose(new["b"], params["b"])  # not decayed

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) > 1.0
        np.testing.assert_allclose(global_norm(clipped), 1.0, rtol=1e-5)

    def test_cosine_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
        lrs = [float(cosine_lr(cfg, jnp.asarray(s))) for s in
               (0, 5, 10, 55, 100)]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0)
        assert lrs[2] > lrs[3] > lrs[4]
        assert lrs[4] == pytest.approx(0.1, rel=1e-3)

    def test_bf16_state_dtype(self):
        params = {"w": jnp.ones((4,))}
        cfg = AdamWConfig(state_dtype=jnp.bfloat16)
        state = init_opt_state(params, cfg)
        assert state["m"]["w"].dtype == jnp.bfloat16
        g = {"w": jnp.ones((4,))}
        _, new_state, _ = adamw_update(params, g, state, cfg)
        assert new_state["m"]["w"].dtype == jnp.bfloat16


class TestData:
    def test_determinism_across_restarts(self):
        cfg = DataConfig(seed=7, vocab=100, seq=16, global_batch=4)
        a = token_batch(cfg, 3)
        b = token_batch(cfg, 3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_host_sharding_disjoint(self):
        full = token_batch(DataConfig(seed=1, vocab=50, seq=8,
                                      global_batch=8), 0)
        h0 = token_batch(DataConfig(seed=1, vocab=50, seq=8, global_batch=8,
                                    n_hosts=2, host_id=0), 0)
        h1 = token_batch(DataConfig(seed=1, vocab=50, seq=8, global_batch=8,
                                    n_hosts=2, host_id=1), 0)
        assert h0["tokens"].shape[0] == 4
        assert not np.array_equal(h0["tokens"], h1["tokens"])

    def test_tokens_in_vocab(self):
        cfg = DataConfig(seed=2, vocab=37, seq=32, global_batch=4)
        t = token_batch(cfg, 5)["tokens"]
        assert t.min() >= 0 and t.max() < 37

    def test_prefetch_iterator_ordered(self):
        it = PrefetchIterator(lambda s: {"x": np.full((2,), s)},
                              start_step=4, prefetch=2)
        steps = [next(it)[0] for _ in range(5)]
        it.close()
        assert steps == [4, 5, 6, 7, 8]

    def test_image_batch_shapes(self):
        cfg = DataConfig(seed=3, global_batch=2)
        b = image_batch(cfg, 0, img=16, channels=3, classes=5)
        assert b["images"].shape == (2, 16, 16, 3)
        assert b["labels"].shape == (2,)
        assert np.isfinite(b["images"]).all()


class TestCheckpoint:
    def _tree(self, k=0):
        return {"a": jnp.arange(6.0).reshape(2, 3) + k,
                "nested": {"b": jnp.ones((4,), jnp.int32) * k}}

    def test_save_restore_roundtrip(self, tmp_path):
        tree = self._tree(3)
        ckpt.save(str(tmp_path), 7, tree)
        back = ckpt.restore(str(tmp_path), 7, jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree))
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(a, b)

    def test_keep_n_eviction(self, tmp_path):
        for s in range(6):
            ckpt.save(str(tmp_path), s, self._tree(s), keep=2)
        assert ckpt.completed_steps(str(tmp_path)) == [4, 5]

    def test_torn_write_invisible(self, tmp_path):
        """A .tmp directory (simulated crash mid-write) is never listed."""
        ckpt.save(str(tmp_path), 1, self._tree())
        os.makedirs(tmp_path / "step_00000002.tmp")
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_async_checkpointer(self, tmp_path):
        ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=3)
        for s in range(3):
            ac.save(s, self._tree(s))
        ac.wait()
        assert ckpt.completed_steps(str(tmp_path)) == [0, 1, 2]
        back = ckpt.restore(str(tmp_path), 2, self._tree())
        np.testing.assert_array_equal(back["a"], self._tree(2)["a"])


class TestElastic:
    def test_straggler_detector_fires_after_patience(self):
        det = StragglerDetector(ElasticConfig(straggler_factor=2.0,
                                              patience=2))
        assert not det.observe(1.0)
        assert not det.observe(1.0)
        assert not det.observe(5.0)   # strike 1
        assert det.observe(5.0)       # strike 2 -> fire

    def test_straggler_recovers(self):
        det = StragglerDetector(ElasticConfig(patience=3))
        det.observe(1.0)
        det.observe(9.0)
        assert det.strikes == 1
        det.observe(1.0)
        assert det.strikes == 0

    @pytest.mark.parametrize("chips,mp,want", [
        (512, 16, (32, 16)), (256, 16, (16, 16)), (96, 16, (6, 16)),
        (100, 16, (25, 4)), (7, 16, (7, 1)),
    ])
    def test_plan_remesh(self, chips, mp, want):
        assert plan_remesh(chips, mp) == want
