"""Edge-case tests for runtime/packing.py and the schedule cache.

Packing rewrites global sampling coordinates into packed-buffer addresses;
the cases that historically break such address converters are coordinates
clamped at image borders, rectangular (th != tw) tiles, and offset planes
that push every sample out of range. Each case is oracle-checked against
the XLA reference through the full pipeline, plus direct table-level
invariants.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.deform import (deformable_conv2d, init_deformable_conv,
                               offsets_to_coords, randomize_offset_conv)
from repro.core.tiles import TileGrid
from repro.runtime import dcn_pipeline, default_schedule_cache
from repro.runtime.cache import ScheduleCache, coords_digest
from repro.runtime.packing import (build_neighbour_tables, pack_output_tile,
                                   plane_to_tiles, tiles_to_plane)


def _layer(key, c_in, c_out, offset_scale=0.5):
    params = init_deformable_conv(key, c_in, c_out, 3, "dcn2")
    return randomize_offset_conv(params, jax.random.fold_in(key, 1),
                                 offset_scale)


class TestPackingEdgeCases:
    def test_coords_clamped_at_borders(self):
        """Large offsets drive many samples onto the clamp boundary; the
        pipeline must still match the reference exactly."""
        key = jax.random.PRNGKey(0)
        params = _layer(key, 4, 6, offset_scale=5.0)   # wild offsets
        x = jax.random.normal(jax.random.fold_in(key, 2), (1, 12, 12, 4))
        y_ref = deformable_conv2d(x, params)
        y = dcn_pipeline(x, params, tile=4)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("h,w,tile", [
        (12, 10, (3, 5)),     # rectangular tiles, divisible
        (13, 11, (3, 5)),     # rectangular tiles, non-divisible both axes
        (9, 16, (2, 8)),      # extreme aspect ratio
    ])
    def test_rectangular_tiles(self, h, w, tile):
        key = jax.random.PRNGKey(h * 17 + w)
        params = _layer(key, 5, 7)
        x = jax.random.normal(jax.random.fold_in(key, 2), (2, h, w, 5))
        y_ref = deformable_conv2d(x, params)
        y = dcn_pipeline(x, params, tile=tile)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_all_out_of_range_offset_plane(self):
        """Huge constant offset bias: every sampling coordinate clamps to
        the far image border — one input tile serves the whole plane."""
        key = jax.random.PRNGKey(3)
        params = init_deformable_conv(key, 4, 4, 3, "dcn2")
        params = params._replace(
            b_off=jnp.full(params.b_off.shape, 100.0))     # way out of range
        x = jax.random.normal(jax.random.fold_in(key, 2), (1, 12, 12, 4))
        y_ref = deformable_conv2d(x, params)
        y = dcn_pipeline(x, params, tile=4)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
        # the clamped coordinates all decode into the bottom-right tile
        offsets = jnp.zeros((1, 12, 12, 2 * 9)) + 100.0
        coords = offsets_to_coords(offsets, 3, "dcn2")[0]
        grid = TileGrid(12, 12, 4, 4)
        nb = build_neighbour_tables(coords, grid)
        assert set(np.unique(nb.tile_id)) == {grid.num_tiles - 1}

    def test_neighbour_tables_always_in_range(self):
        key = jax.random.PRNGKey(4)
        params = _layer(key, 3, 3, offset_scale=8.0)
        x = jax.random.normal(jax.random.fold_in(key, 2), (1, 13, 11, 3))
        from repro.core.deform import conv2d
        offsets = conv2d(x, params.w_off, params.b_off)
        coords = offsets_to_coords(offsets.astype(jnp.float32), 3, "dcn2")[0]
        grid = TileGrid(13, 11, 3, 5)
        nb = build_neighbour_tables(coords, grid)
        assert nb.tile_id.min() >= 0
        assert nb.tile_id.max() < grid.num_tiles
        assert nb.offset.min() >= 0
        assert nb.offset.max() < grid.th * grid.tw

    def test_pack_padded_pixels_have_zero_coeff(self):
        """Output tiles overhanging the plane pack coeff=0 for the padded
        pixels, so their contribution is discarded."""
        h, w = 5, 5
        grid = TileGrid(h, w, 4, 4)    # 2x2 grid, heavy overhang
        coords = offsets_to_coords(jnp.zeros((1, h, w, 18)), 3, "dcn2")[0]
        nb = build_neighbour_tables(coords, grid)
        deps = list(range(grid.num_tiles))
        idx, coeff = pack_output_tile(nb, grid, grid.num_tiles - 1, deps,
                                      p_pad=16)
        tp = grid.th * grid.tw
        valid = np.zeros((grid.th, grid.tw), bool)
        valid[:h - 4, :w - 4] = True    # only 1x1 of the last tile is real
        flat = valid.reshape(-1)
        assert idx.shape == (16, 9, 4) and coeff.shape == (16, 9, 4)
        assert np.all(coeff[:tp][~flat] == 0)      # plane-overhang pixels
        assert np.any(coeff[:tp][flat] != 0)       # the real pixel samples

    def test_plane_tiles_roundtrip_rectangular(self):
        x = jnp.arange(13 * 11 * 3, dtype=jnp.float32).reshape(13, 11, 3)
        grid = TileGrid(13, 11, 3, 5)
        np.testing.assert_array_equal(
            np.asarray(tiles_to_plane(plane_to_tiles(x, grid), grid, 13, 11)),
            np.asarray(x))


class TestScheduleCache:
    def test_repeated_input_hits(self):
        """Same batch twice: the second run's schedules all come from the
        LRU cache, and the trace counters surface it."""
        key = jax.random.PRNGKey(7)
        params = _layer(key, 4, 4)
        x = jax.random.normal(jax.random.fold_in(key, 2), (2, 12, 12, 4))
        default_schedule_cache().clear()
        y1, t1 = dcn_pipeline(x, params, tile=4, return_trace=True)
        assert t1.schedule_cache_hits == 0
        assert t1.schedule_cache_misses == 2
        y2, t2 = dcn_pipeline(x, params, tile=4, return_trace=True)
        assert t2.schedule_cache_hits == 2
        assert t2.schedule_cache_misses == 0
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=0, atol=0)

    def test_cache_disabled(self):
        from repro.runtime import PipelineConfig
        key = jax.random.PRNGKey(8)
        params = _layer(key, 4, 4)
        x = jax.random.normal(jax.random.fold_in(key, 2), (1, 8, 8, 4))
        _, tr_off = dcn_pipeline(
            x, params, return_trace=True,
            config=PipelineConfig(tile=4, use_schedule_cache=False))
        assert tr_off.schedule_cache_hits == 0
        assert tr_off.schedule_cache_misses == 0

    def test_digest_distinguishes_floor_changes(self):
        grid = TileGrid(8, 8, 4, 4)
        base = np.full((8, 8, 9, 2), 3.4)
        shifted = base + 0.2           # same cell
        crossed = base + 0.7           # floor flips 3 -> 4
        assert coords_digest(base, grid) == coords_digest(shifted, grid)
        assert coords_digest(base, grid) != coords_digest(crossed, grid)

    def test_lru_eviction(self):
        c = ScheduleCache(maxsize=2)
        c.put("a", 1)
        c.put("b", 2)
        assert c.get("a") == 1         # refresh "a": "b" is now oldest
        c.put("c", 3)
        assert c.get("b") is None
        assert c.get("a") == 1 and c.get("c") == 3
        info = c.info()
        assert info["size"] == 2 and info["maxsize"] == 2

    def test_different_buffer_capacity_misses(self):
        """M is part of the key: capacity changes rebuild the schedule."""
        key = jax.random.PRNGKey(9)
        params = _layer(key, 4, 4)
        x = jax.random.normal(jax.random.fold_in(key, 2), (1, 8, 8, 4))
        default_schedule_cache().clear()
        _, t1 = dcn_pipeline(x, params, tile=4, buffer_tiles=2,
                             return_trace=True)
        _, t2 = dcn_pipeline(x, params, tile=4, buffer_tiles=3,
                             return_trace=True)
        assert t1.schedule_cache_misses == 1
        assert t2.schedule_cache_misses == 1
